# Convenience targets. `cargo build --release && cargo test -q` is the
# tier-1 verification; everything XLA/PJRT additionally needs `make
# artifacts` (Python + JAX) and a build with `--features xla`.

.PHONY: build test artifacts figures bench bench-json bench-schema lint lint-invariants doc

build:
	cargo build --release

test:
	cargo test -q

# Lower the L1 Pallas kernels / L2 JAX model to HLO-text AOT artifacts
# consumed by the PJRT runtime (writes artifacts/manifest.txt).
artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

figures:
	cargo run --release -- figures --all --out results

bench:
	cargo bench

# Machine-readable bench snapshot: run the perf benches with JSON capture
# (the in-repo harness appends `"name": ns_per_op,` fragments when
# BENCH_JSON_DIR is set) and merge them into BENCH_PR10.json so the bench
# trajectory is diffable across PRs (the earlier BENCH_PR*.json files are
# the previous snapshots' schemas; PR 10 adds the hedged-serving and
# deadline-staging rows). Bench names must be unique across the two
# binaries (they are
# today, and `scripts/check_bench_schema` fails on a collision); after
# regenerating, run `make bench-schema` to confirm the snapshot matches
# the harness — the check pins the *highest-numbered* snapshot, so bump
# the filename here when a new PR lands.
bench-json:
	rm -rf target/bench-json && mkdir -p target/bench-json
	BENCH_JSON_DIR=$(CURDIR)/target/bench-json cargo bench --bench perf_hotpaths
	BENCH_JSON_DIR=$(CURDIR)/target/bench-json cargo bench --bench perf_workload
	@ls target/bench-json/*.lines >/dev/null 2>&1 || \
	  { echo "error: benches emitted no JSON fragments (BENCH_JSON_DIR plumbing broken?)"; exit 1; }
	{ echo '{'; \
	  echo '  "_meta": "flat map: benchmark name -> median ns/op from the in-repo bench harness; regenerate with make bench-json",'; \
	  cat target/bench-json/*.lines | sed '$$ s/,$$//'; echo '}'; } > BENCH_PR10.json
	@echo "wrote BENCH_PR10.json"

# Validate every BENCH_PR*.json snapshot (flat name -> ns/op-or-null map,
# no duplicate keys) and, where cargo exists, diff the newest snapshot's
# keys against the names the harness emits in BENCH_LIST mode.
bench-schema:
	python3 scripts/check_bench_schema

lint:
	cargo fmt --all --check
	cargo clippy --all-targets -- -D warnings

# The repo invariant linter (rules D1-D5/S1-S2 over rust/src, allowlist
# in rust/xtask/lint_allow.toml) plus its own fixture/unit suite; see
# DESIGN.md "Static analysis & enforced invariants".
lint-invariants:
	cargo test -q -p xtask
	cargo xtask lint

doc:
	RUSTDOCFLAGS="-D rustdoc::broken_intra_doc_links" cargo doc --no-deps
