# Convenience targets. `cargo build --release && cargo test -q` is the
# tier-1 verification; everything XLA/PJRT additionally needs `make
# artifacts` (Python + JAX) and a build with `--features xla`.

.PHONY: build test artifacts figures bench lint doc

build:
	cargo build --release

test:
	cargo test -q

# Lower the L1 Pallas kernels / L2 JAX model to HLO-text AOT artifacts
# consumed by the PJRT runtime (writes artifacts/manifest.txt).
artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

figures:
	cargo run --release -- figures --all --out results

bench:
	cargo bench

lint:
	cargo fmt --all --check
	cargo clippy --all-targets -- -D warnings

doc:
	RUSTDOCFLAGS="-D rustdoc::broken_intra_doc_links" cargo doc --no-deps
