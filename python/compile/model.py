"""L2: the JAX compute graph for worker subtasks and setup-time encode.

The paper's per-worker computation is the inner product of a coded row
block with the input vector; the setup-time computation is the MDS encode
``A_tilde = G @ A``. Both are thin JAX functions over the L1 Pallas
kernels so that ``aot.py`` lowers kernel + glue into a single HLO module
per tile shape. Python never runs at serve time — the rust runtime
executes the lowered artifacts via PJRT.
"""

import jax
import jax.numpy as jnp

from compile.kernels.encode import encode as _encode_kernel
from compile.kernels.matvec import matvec as _matvec_kernel
from compile.kernels.matvec import matvec_batched as _matvec_batched_kernel


def worker_matvec(a_tile, x, *, tile_r: int = 128):
    """Worker subtask: ``A_tile @ x`` through the Pallas matvec kernel.

    Returns a 1-tuple so the lowered HLO has a tuple root (the rust loader
    unwraps with ``to_tuple1``).
    """
    return (_matvec_kernel(a_tile, x, tile_r=tile_r),)


def worker_matvec_batched(a_tile, xs, *, tile_r: int = 128):
    """Batched worker subtask: ``A_tile @ Xs`` for ``Xs`` of shape (d, B).

    Serving systems batch concurrent requests; the contraction becomes an
    MXU-shaped matmul (see kernels.matvec).
    """
    return (_matvec_batched_kernel(a_tile, xs, tile_r=tile_r),)


def setup_encode(g, a, *, tile: int = 64):
    """Setup-time MDS encode ``G @ A`` through the Pallas matmul kernel."""
    return (_encode_kernel(g, a, tile=tile),)


def lower_worker_matvec(rows: int, d: int, tile_r: int = 128):
    """jit-lower the worker matvec for a concrete ``(rows, d)`` tile."""
    a_spec = jax.ShapeDtypeStruct((rows, d), jnp.float32)
    x_spec = jax.ShapeDtypeStruct((d,), jnp.float32)
    fn = lambda a, x: worker_matvec(a, x, tile_r=min(tile_r, rows))
    return jax.jit(fn).lower(a_spec, x_spec)


def lower_worker_matvec_batched(rows: int, d: int, batch: int, tile_r: int = 128):
    """jit-lower the batched worker matvec for ``(rows, d) x (d, batch)``."""
    a_spec = jax.ShapeDtypeStruct((rows, d), jnp.float32)
    x_spec = jax.ShapeDtypeStruct((d, batch), jnp.float32)
    fn = lambda a, xs: worker_matvec_batched(a, xs, tile_r=min(tile_r, rows))
    return jax.jit(fn).lower(a_spec, x_spec)


def lower_setup_encode(n: int, k: int, d: int, tile: int = 64):
    """jit-lower the encode for concrete ``(n, k, d)``."""
    g_spec = jax.ShapeDtypeStruct((n, k), jnp.float32)
    a_spec = jax.ShapeDtypeStruct((k, d), jnp.float32)
    fn = lambda g, a: setup_encode(g, a, tile=tile)
    return jax.jit(fn).lower(g_spec, a_spec)
