"""L1 Pallas kernel: MDS encode ``A_tilde = G @ A`` as a tiled matmul.

Setup-time operation (runs once per data matrix). Classic three-level
Pallas matmul: grid over ``(n_tiles, d_tiles, k_tiles)`` with a VMEM
scratch accumulator; the ``k`` loop is the innermost grid dimension so the
accumulator stays resident while G/A slabs stream through VMEM — the TPU
equivalent of a CUDA shared-memory blocked matmul. MXU does the
``(TILE_M, TILE_K) x (TILE_K, TILE_N)`` contractions in f32.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 64


def _encode_kernel(g_ref, a_ref, o_ref, acc_ref, *, k_steps: int):
    """Grid step (i, j, kk): acc += G[i, kk] @ A[kk, j]."""
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        g_ref[...], a_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(kk == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


@partial(jax.jit, static_argnames=("tile",))
def encode(g, a, *, tile: int = DEFAULT_TILE):
    """Compute ``g @ a`` with a blocked Pallas matmul.

    ``g`` is ``(n, k)``, ``a`` is ``(k, d)``; all of ``n, k, d`` must be
    divisible by ``tile``.
    """
    n, k = g.shape
    k2, d = a.shape
    if k != k2:
        raise ValueError(f"shape mismatch: G {g.shape} vs A {a.shape}")
    for name, dim in (("n", n), ("k", k), ("d", d)):
        if dim % tile:
            raise ValueError(f"{name}={dim} not divisible by tile={tile}")
    k_steps = k // tile
    grid = (n // tile, d // tile, k_steps)
    kernel = partial(_encode_kernel, k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, tile), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tile, tile), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tile, tile), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        # VMEM scratch accumulator (ANY resolves to VMEM on TPU and a plain
        # buffer in interpret mode).
        scratch_shapes=[pl.MemorySpace.ANY((tile, tile), jnp.float32)],
        interpret=True,
    )(g, a)
