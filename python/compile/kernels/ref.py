"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth the build-time pytest suite checks the kernels
against (``assert_allclose``); they also serve as the L2 fallback path when
experimenting with kernel variants.
"""

import jax.numpy as jnp


def matvec_ref(a, x):
    """Reference ``a @ x`` in f32."""
    return jnp.dot(
        a.astype(jnp.float32),
        x.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def encode_ref(g, a):
    """Reference ``g @ a`` in f32."""
    return jnp.dot(
        g.astype(jnp.float32),
        a.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
