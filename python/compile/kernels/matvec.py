"""L1 Pallas kernel: tiled coded-chunk matvec ``y = A_tile @ x``.

The worker subtask of the paper is the inner product of ``l`` coded rows
with the input vector ``x``. On TPU the natural mapping is:

- rows are tiled into ``(TILE_R, d)`` VMEM-resident slabs streamed from HBM
  by the Pallas grid (``BlockSpec`` below expresses the HBM->VMEM schedule a
  CUDA implementation would do with threadblocks);
- ``x`` is broadcast to every grid step (``lambda i: (0,)`` index map) and
  stays pinned in VMEM;
- the contraction itself is a ``(TILE_R, d) x (d,)`` product: memory-bound
  on the VPU for a single vector, MXU-bound if ``x`` is widened to a batch
  ``(d, B)`` — the kernel body is written so either lowers to one
  ``dot_general``.

CPU-PJRT execution requires ``interpret=True`` (a real TPU lowering emits a
Mosaic custom-call the CPU plugin cannot run); numerics are identical.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default row tile; VMEM footprint per step is
# TILE_R*d*4 + d*4 + TILE_R*4 bytes (~132 KiB at d=256, TILE_R=128),
# far below the ~16 MiB VMEM budget, leaving room for double-buffering.
DEFAULT_TILE_R = 128


def _matvec_kernel(a_ref, x_ref, o_ref):
    """One grid step: o = A_tile @ x for a (TILE_R, d) slab."""
    a = a_ref[...]
    x = x_ref[...]
    # Single dot_general; f32 accumulation (MXU-friendly when x is batched).
    o_ref[...] = jnp.dot(a, x, preferred_element_type=jnp.float32)


def _matvec_batched_kernel(a_ref, x_ref, o_ref):
    """One grid step: O = A_tile @ X for a (TILE_R, d) slab and (d, B) X.

    With a batch of request vectors the contraction becomes an
    (TILE_R×d)·(d×B) matmul — MXU-shaped on TPU instead of a VPU reduction,
    which is the whole point of batching the serving path.
    """
    o_ref[...] = jnp.dot(
        a_ref[...], x_ref[...], preferred_element_type=jnp.float32
    )


@partial(jax.jit, static_argnames=("tile_r",))
def matvec_batched(a, xs, *, tile_r: int = DEFAULT_TILE_R):
    """Compute ``a @ xs`` for a batch ``xs`` of shape ``(d, B)``.

    ``a`` is ``(rows, d)`` with ``rows`` divisible by ``tile_r``.
    """
    rows, d = a.shape
    if rows % tile_r:
        raise ValueError(f"rows={rows} not divisible by tile_r={tile_r}")
    if xs.ndim != 2 or xs.shape[0] != d:
        raise ValueError(f"xs shape {xs.shape} incompatible with a {a.shape}")
    b = xs.shape[1]
    grid = (rows // tile_r,)
    return pl.pallas_call(
        _matvec_batched_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_r, d), lambda i: (i, 0)),
            pl.BlockSpec((d, b), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_r, b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, b), jnp.float32),
        interpret=True,
    )(a, xs)


@partial(jax.jit, static_argnames=("tile_r",))
def matvec(a, x, *, tile_r: int = DEFAULT_TILE_R):
    """Compute ``a @ x`` with a row-tiled Pallas kernel.

    ``a`` is ``(rows, d)`` with ``rows`` divisible by ``tile_r`` (the rust
    runtime pads chunks to tile shape); ``x`` is ``(d,)``.
    """
    rows, d = a.shape
    if rows % tile_r:
        raise ValueError(f"rows={rows} not divisible by tile_r={tile_r}")
    if x.shape != (d,):
        raise ValueError(f"x shape {x.shape} incompatible with a {a.shape}")
    grid = (rows // tile_r,)
    return pl.pallas_call(
        _matvec_kernel,
        grid=grid,
        in_specs=[
            # Stream row slabs; block index i selects rows [i*tile_r, ...).
            pl.BlockSpec((tile_r, d), lambda i: (i, 0)),
            # x is re-used by every step (index map pins block 0).
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_r,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows,), jnp.float32),
        interpret=True,  # CPU-PJRT cannot run Mosaic custom-calls
    )(a, x)
