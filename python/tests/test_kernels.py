"""Kernel-vs-reference correctness: the core L1 signal.

hypothesis sweeps shapes and input distributions; every case asserts
allclose against the pure-jnp oracle in ``compile.kernels.ref``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.encode import encode
from compile.kernels.matvec import matvec
from compile.kernels.ref import encode_ref, matvec_ref

TOL = dict(rtol=2e-5, atol=2e-5)


def rng_array(shape, seed, scale=1.0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype)


# ---------------------------------------------------------------- matvec


class TestMatvec:
    def test_basic(self):
        a = rng_array((256, 256), 0)
        x = rng_array((256,), 1)
        np.testing.assert_allclose(matvec(a, x), matvec_ref(a, x), **TOL)

    def test_single_tile(self):
        a = rng_array((128, 64), 2)
        x = rng_array((64,), 3)
        np.testing.assert_allclose(
            matvec(a, x, tile_r=128), matvec_ref(a, x), **TOL
        )

    def test_many_tiles(self):
        a = rng_array((512, 32), 4)
        x = rng_array((32,), 5)
        np.testing.assert_allclose(
            matvec(a, x, tile_r=64), matvec_ref(a, x), **TOL
        )

    def test_zero_matrix(self):
        a = jnp.zeros((128, 16), jnp.float32)
        x = rng_array((16,), 6)
        np.testing.assert_allclose(matvec(a, x), jnp.zeros(128), **TOL)

    def test_identity_rows(self):
        d = 128
        a = jnp.eye(d, dtype=jnp.float32)
        x = rng_array((d,), 7)
        np.testing.assert_allclose(matvec(a, x, tile_r=64), x, **TOL)

    def test_rejects_non_divisible_rows(self):
        a = rng_array((100, 16), 8)
        x = rng_array((16,), 9)
        with pytest.raises(ValueError):
            matvec(a, x, tile_r=64)

    def test_rejects_bad_x_shape(self):
        a = rng_array((128, 16), 10)
        x = rng_array((32,), 11)
        with pytest.raises(ValueError):
            matvec(a, x)

    @settings(max_examples=25, deadline=None)
    @given(
        rows_tiles=st.integers(1, 4),
        tile_r=st.sampled_from([32, 64, 128]),
        d=st.sampled_from([16, 64, 128, 256]),
        seed=st.integers(0, 2**31 - 1),
        scale=st.sampled_from([1e-3, 1.0, 1e3]),
    )
    def test_matches_ref_swept(self, rows_tiles, tile_r, d, seed, scale):
        rows = rows_tiles * tile_r
        a = rng_array((rows, d), seed, scale)
        x = rng_array((d,), seed + 1, scale)
        got = matvec(a, x, tile_r=tile_r)
        want = matvec_ref(a, x)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4 * scale * scale)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_linearity(self, seed):
        # matvec(a, x+y) == matvec(a, x) + matvec(a, y)
        a = rng_array((128, 32), seed)
        x = rng_array((32,), seed + 1)
        y = rng_array((32,), seed + 2)
        lhs = matvec(a, x + y, tile_r=64)
        rhs = matvec(a, x, tile_r=64) + matvec(a, y, tile_r=64)
        np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- encode


class TestEncode:
    def test_basic(self):
        g = rng_array((256, 128), 20, scale=0.1)
        a = rng_array((128, 192), 21)
        np.testing.assert_allclose(
            encode(g, a, tile=64), encode_ref(g, a), rtol=1e-4, atol=1e-4
        )

    def test_identity_generator(self):
        k = 128
        g = jnp.eye(k, dtype=jnp.float32)
        a = rng_array((k, 64), 22)
        np.testing.assert_allclose(
            encode(g, a, tile=64), a, rtol=1e-5, atol=1e-5
        )

    def test_single_tile(self):
        g = rng_array((64, 64), 23, scale=0.2)
        a = rng_array((64, 64), 24)
        np.testing.assert_allclose(
            encode(g, a, tile=64), encode_ref(g, a), rtol=1e-4, atol=1e-4
        )

    def test_rejects_shape_mismatch(self):
        g = rng_array((64, 64), 25)
        a = rng_array((128, 64), 26)
        with pytest.raises(ValueError):
            encode(g, a)

    def test_rejects_non_divisible(self):
        g = rng_array((96, 96), 27)
        a = rng_array((96, 96), 28)
        with pytest.raises(ValueError):
            encode(g, a, tile=64)

    @settings(max_examples=15, deadline=None)
    @given(
        nt=st.integers(1, 3),
        kt=st.integers(1, 3),
        dt=st.integers(1, 3),
        tile=st.sampled_from([16, 32, 64]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_swept(self, nt, kt, dt, tile, seed):
        g = rng_array((nt * tile, kt * tile), seed, scale=0.3)
        a = rng_array((kt * tile, dt * tile), seed + 1)
        got = encode(g, a, tile=tile)
        want = encode_ref(g, a)
        np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


# --------------------------------------------------- composition property


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_encode_then_matvec_commutes(seed):
    """(G @ A) @ x == G @ (A @ x): the identity MDS decoding relies on."""
    n, k, d = 128, 64, 64
    g = rng_array((n, k), seed, scale=0.3)
    a = rng_array((k, d), seed + 1)
    x = rng_array((d,), seed + 2)
    coded = encode(g, a, tile=64)
    lhs = matvec(coded, x, tile_r=64)
    rhs = matvec_ref(g, matvec_ref(a, x))
    np.testing.assert_allclose(lhs, rhs, rtol=5e-4, atol=5e-4)


# ------------------------------------------------------------- batched


class TestMatvecBatched:
    def test_matches_per_vector_matvec(self):
        from compile.kernels.matvec import matvec_batched

        a = rng_array((256, 64), 30)
        xs = rng_array((64, 8), 31)
        got = matvec_batched(a, xs, tile_r=128)
        for b in range(8):
            np.testing.assert_allclose(
                got[:, b], matvec_ref(a, xs[:, b]), rtol=5e-5, atol=5e-5
            )

    def test_rejects_bad_shapes(self):
        from compile.kernels.matvec import matvec_batched

        a = rng_array((128, 64), 32)
        with pytest.raises(ValueError):
            matvec_batched(a, rng_array((32, 8), 33))
        with pytest.raises(ValueError):
            matvec_batched(rng_array((100, 64), 34), rng_array((64, 8), 35))

    @settings(max_examples=10, deadline=None)
    @given(
        batch=st.integers(1, 16),
        tiles=st.integers(1, 3),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_swept_batches(self, batch, tiles, seed):
        from compile.kernels.matvec import matvec_batched

        rows, d = tiles * 64, 32
        a = rng_array((rows, d), seed)
        xs = rng_array((d, batch), seed + 1)
        got = matvec_batched(a, xs, tile_r=64)
        want = encode_ref(a, xs)  # plain matmul oracle
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
