"""AOT pipeline test: run aot.py end-to-end into a temp dir and validate
the manifest + artifact files."""

import os
import subprocess
import sys

PY_DIR = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_aot_writes_manifest_and_artifacts(tmp_path):
    out = tmp_path / "artifacts"
    proc = subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out-dir",
            str(out),
            "--d",
            "64",
            "--tiles",
            "64,128",
            "--encode-n",
            "128",
            "--encode-k",
            "64",
        ],
        cwd=PY_DIR,
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert proc.returncode == 0, proc.stderr
    manifest = (out / "manifest.txt").read_text().strip().splitlines()
    entries = [l for l in manifest if l and not l.startswith("#")]
    # two matvec tiles + two batched tiles + one encode
    assert len(entries) == 5
    for line in entries:
        parts = line.split()
        fname = parts[-1]
        text = (out / fname).read_text()
        assert "HloModule" in text
        assert "custom-call" not in text.lower()
    kinds = sorted(e.split()[0] for e in entries)
    assert kinds == ["encode", "matvec", "matvec", "matvecb", "matvecb"]
