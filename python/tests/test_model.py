"""L2 model-level tests: lowering shapes, HLO structure, AOT text."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.aot import to_hlo_text
from compile.kernels.ref import matvec_ref


class TestWorkerMatvec:
    def test_returns_tuple(self):
        a = jnp.zeros((128, 64), jnp.float32)
        x = jnp.zeros((64,), jnp.float32)
        out = model.worker_matvec(a, x)
        assert isinstance(out, tuple) and len(out) == 1
        assert out[0].shape == (128,)

    def test_matches_ref(self):
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.standard_normal((256, 64)), jnp.float32)
        x = jnp.asarray(rng.standard_normal(64), jnp.float32)
        (y,) = model.worker_matvec(a, x)
        np.testing.assert_allclose(y, matvec_ref(a, x), rtol=2e-5, atol=2e-5)

    def test_tile_clamped_to_rows(self):
        # rows=64 < default tile 128: lowering must clamp, not fail.
        lowered = model.lower_worker_matvec(64, 32)
        assert lowered is not None


class TestLowering:
    @pytest.mark.parametrize("rows", [64, 128, 256])
    def test_matvec_hlo_text_shape(self, rows):
        d = 64
        text = to_hlo_text(model.lower_worker_matvec(rows, d))
        assert "HloModule" in text
        assert f"f32[{rows},{d}]" in text
        # Tuple root for the rust loader's to_tuple1.
        assert f"(f32[{rows}]" in text

    def test_encode_hlo_text_shape(self):
        text = to_hlo_text(model.lower_setup_encode(256, 64, 128))
        assert "HloModule" in text
        assert "f32[256,64]" in text
        assert "f32[64,128]" in text

    def test_hlo_has_no_custom_calls(self):
        # interpret=True must lower to plain HLO ops a CPU PJRT can run —
        # a mosaic custom-call here would break the rust runtime.
        text = to_hlo_text(model.lower_worker_matvec(128, 64))
        assert "custom-call" not in text.lower()

    def test_matvec_is_fused_dot(self):
        # L2 perf check: the lowered module contains a single dot per tile
        # loop, no transposes of the row block.
        text = to_hlo_text(model.lower_worker_matvec(128, 64, tile_r=128))
        assert text.lower().count("dot(") >= 1


class TestBatchedLowering:
    def test_batched_hlo_shape(self):
        from compile.aot import to_hlo_text
        from compile import model

        text = to_hlo_text(model.lower_worker_matvec_batched(128, 64, 8))
        assert "HloModule" in text
        assert "f32[128,64]" in text
        assert "f32[64,8]" in text
        assert "custom-call" not in text.lower()
