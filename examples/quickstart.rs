//! Quickstart: compute the paper's optimal load allocation for a small
//! heterogeneous cluster and run one live coded matvec job.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use hetcoded::allocation::proposed_allocation;
use hetcoded::coding::Matrix;
use hetcoded::coordinator::{JobConfig, Mode, Session};
use hetcoded::math::Rng;
use hetcoded::model::{ClusterSpec, Group, LatencyModel};

fn main() -> hetcoded::Result<()> {
    // A cluster with two machine generations: 8 fast workers (mu = 8) and
    // 12 older ones (mu = 2); data matrix with k = 128 rows.
    let spec = ClusterSpec::new(
        vec![
            Group::new(8, 8.0, 1.0)?,
            Group::new(12, 2.0, 1.0)?,
        ],
        128,
    )?;

    // Theorem 2: optimal per-group loads + the (n*, k) MDS code.
    let alloc = proposed_allocation(LatencyModel::A, &spec)?;
    println!("optimal allocation for N={} workers:", spec.total_workers());
    for (j, (l, g)) in alloc.loads.iter().zip(&spec.groups).enumerate() {
        println!(
            "  group {j} (mu={:>4}): l*_j = {:>7.2} rows/worker (r*_j = {:.1})",
            g.mu, l, alloc.r[j]
        );
    }
    println!(
        "  code: n* = {:.1} (rate {:.3}), latency bound T* = {:.4e}",
        alloc.n,
        alloc.rate(spec.k as f64),
        alloc.latency_bound.unwrap()
    );

    // Live run through the Session facade: encode a random A, dispatch to
    // 20 worker threads with injected shifted-exponential straggle, decode
    // from the first k rows.
    let d = 64;
    let mut rng = Rng::new(1);
    let a = Matrix::from_fn(spec.k, d, |_, _| rng.normal());
    let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let outcome = Session::builder(&spec)
        .allocation(alloc)
        .data(a)
        .requests(vec![x])
        .config(JobConfig { time_scale: 0.05, ..Default::default() })
        .mode(Mode::Single)
        .build()?
        .serve()?;
    let report = &outcome.jobs[0];
    println!(
        "\nlive job: decoded {} entries in {:.1} ms wall ({} workers used, \
         {} rows), max |err| = {:.2e}",
        report.decoded.len(),
        report.wall_latency.as_secs_f64() * 1e3,
        report.workers_used,
        report.rows_collected,
        report.max_error
    );
    assert!(report.max_error < 1e-8);
    println!("quickstart OK");
    Ok(())
}
