//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! Proves every layer composes:
//!
//! 1. **L1/L2 (build time)** — `make artifacts` lowered the Pallas matvec +
//!    encode kernels through JAX to HLO text;
//! 2. **runtime** — this binary loads `artifacts/manifest.txt`, compiles the
//!    modules on the PJRT CPU client;
//! 3. **L3** — the coordinator encodes a real data matrix **through the AOT
//!    encode executable**, serves a batch of matvec requests over worker
//!    threads with injected heterogeneous straggle (each worker computing
//!    through the AOT matvec executable), decodes each answer from the first
//!    `k` rows, and verifies against the direct product.
//!
//! Reports the latency distribution and compares the proposed allocation
//! against uniform allocation on the same live system. Falls back with a
//! clear message if artifacts are missing.
//!
//! ```sh
//! make artifacts && cargo run --release --example end_to_end
//! ```

use hetcoded::allocation::{proposed_allocation, uniform_allocation};
use hetcoded::coding::{Generator, GeneratorKind, Matrix};
use hetcoded::coordinator::{JobConfig, Mode, Session, XlaService};
use hetcoded::math::Rng;
use hetcoded::model::{ClusterSpec, Group, LatencyModel};
use hetcoded::runtime::DEFAULT_ARTIFACT_DIR;
use std::sync::Arc;

const K: usize = 256; // must match the encode artifact's k
const D: usize = 256; // must match artifact d
const REQUESTS: usize = 16;

fn main() -> hetcoded::Result<()> {
    // 24 workers across three heterogeneity tiers.
    let spec = ClusterSpec::new(
        vec![
            Group::new(6, 8.0, 1.0)?,
            Group::new(8, 4.0, 1.0)?,
            Group::new(10, 1.0, 1.0)?,
        ],
        K,
    )?;

    let svc = match XlaService::new(DEFAULT_ARTIFACT_DIR.into()) {
        Ok(s) => Arc::new(s),
        Err(e) => {
            eprintln!("cannot load AOT artifacts ({e}); run `make artifacts` first");
            std::process::exit(1);
        }
    };
    println!(
        "loaded AOT artifacts (d={}); backend = PJRT CPU via xla crate",
        svc.cols()
    );

    // Real data matrix + requests.
    let mut rng = Rng::new(99);
    let a = Matrix::from_fn(K, D, |_, _| rng.normal());
    let requests: Vec<Vec<f64>> = (0..REQUESTS)
        .map(|_| (0..D).map(|_| rng.normal()).collect())
        .collect();

    // Allocations to compare on the same live system.
    let proposed = proposed_allocation(LatencyModel::A, &spec)?;
    let uniform = uniform_allocation(LatencyModel::A, &spec, proposed.n)?;
    let cfg = JobConfig { time_scale: 0.05, seed: 31, ..Default::default() };

    // Setup-time encode through the AOT encode executable: pad G up to the
    // artifact's (n=1024, k=256) shape, run Ã = G·A on PJRT, and verify
    // against the native encode.
    let (en, ek, _ed) = (1024usize, K, D); // aot.py defaults
    let n_int = proposed.integer_n(&spec);
    assert!(n_int <= en, "allocation n={n_int} exceeds encode artifact n={en}");
    let gen = Generator::new(GeneratorKind::SystematicRandom, n_int, K, 5)?;
    let mut gpad = Matrix::zeros(en, ek);
    for i in 0..n_int {
        for j in 0..K {
            gpad[(i, j)] = gen.matrix()[(i, j)];
        }
    }
    let t0 = hetcoded::runtime::wall_now();
    let coded = svc.encode(&gpad, &a)?;
    let native = gpad.matmul(&a);
    let mut enc_err = 0.0f64;
    for i in 0..en {
        for j in 0..D {
            enc_err = enc_err.max((coded[(i, j)] - native[(i, j)]).abs());
        }
    }
    println!(
        "AOT encode: G({en}x{ek}) @ A({K}x{D}) on PJRT in {:.1} ms, \
         max |err| vs native = {enc_err:.2e}",
        t0.elapsed().as_secs_f64() * 1e3
    );
    assert!(enc_err < 1e-2, "encode error too large");

    for (name, alloc) in [("proposed", &proposed), ("uniform(n*)", &uniform)] {
        let n_int = alloc.integer_n(&spec);
        let report = Session::builder(&spec)
            .allocation((*alloc).clone())
            .data(a.clone())
            .requests(requests.clone())
            .config(cfg.clone())
            .compute(svc.clone() as _)
            .mode(Mode::Sequential)
            .build()?
            .serve()?;
        println!("\n[{name}] n={} rate={:.3}", n_int, K as f64 / n_int as f64);
        println!("  {}", report.recorder.report());
        println!("  worst decode error: {:.2e}", report.worst_error);
        assert!(
            report.worst_error < 1e-2,
            "decode error too large (f32 artifact path)"
        );
        let mean_model: f64 = report
            .jobs
            .iter()
            .filter_map(|j| j.model_latency)
            .sum::<f64>()
            / report.jobs.len() as f64;
        println!(
            "  mean model-time latency: {:.4} (bound T* = {})",
            mean_model,
            alloc
                .latency_bound
                .map_or("-".into(), |b| format!("{b:.4}"))
        );
    }
    // Pipelined serving: all requests in flight concurrently — the
    // throughput view. Shown with the native backend (the PJRT service is a
    // single thread on this box, so overlapping pays off when straggle, not
    // compute, dominates — the regime the paper models).
    let native: Arc<dyn hetcoded::coordinator::Compute> =
        Arc::new(hetcoded::coordinator::NativeCompute);
    let t_seq = hetcoded::runtime::wall_now();
    let seq = Session::builder(&spec)
        .allocation(proposed.clone())
        .data(a.clone())
        .requests(requests.clone())
        .config(cfg.clone())
        .compute(native.clone())
        .mode(Mode::Sequential)
        .build()?
        .serve()?;
    let seq_makespan = t_seq.elapsed();
    let pip = Session::builder(&spec)
        .allocation(proposed.clone())
        .data(a.clone())
        .requests(requests.clone())
        .config(cfg.clone())
        .compute(native)
        .mode(Mode::Pipelined)
        .build()?
        .serve()?;
    let makespan = pip.makespan.unwrap();
    println!(
        "\n[pipelined, native backend] {} requests: makespan {:.1} ms \
         ({:.0} req/s) vs sequential {:.1} ms ({:.1}x)",
        requests.len(),
        makespan.as_secs_f64() * 1e3,
        requests.len() as f64 / makespan.as_secs_f64(),
        seq_makespan.as_secs_f64() * 1e3,
        seq_makespan.as_secs_f64() / makespan.as_secs_f64(),
    );
    assert!(pip.worst_error.max(seq.worst_error) < 1e-8);

    // Batched serving: 8 requests share ONE dispatch per worker — the
    // straggle penalty is paid once for the whole batch and each worker's
    // contraction is the MXU-shaped (l_i × d)·(d × 8) batched artifact.
    let batch: Vec<Vec<f64>> = requests[..8].to_vec();
    let t0 = hetcoded::runtime::wall_now();
    let reports = Session::builder(&spec)
        .allocation(proposed.clone())
        .data(a.clone())
        .requests(batch)
        .config(cfg.clone())
        .compute(svc.clone() as _)
        .mode(Mode::Batched)
        .build()?
        .serve()?
        .jobs;
    let batch_wall = t0.elapsed();
    let worst = reports.iter().map(|r| r.max_error).fold(0.0f64, f64::max);
    println!(
        "\n[batched] {} requests in one coded job: {:.1} ms total \
         ({:.1} ms per request), worst decode error {:.1e}",
        reports.len(),
        batch_wall.as_secs_f64() * 1e3,
        batch_wall.as_secs_f64() * 1e3 / reports.len() as f64,
        worst
    );
    assert!(worst < 1e-2);

    println!("\nend_to_end OK");
    Ok(())
}
