//! Scheme comparison on the paper's Fig.-4 cluster: Monte-Carlo expected
//! latency of every allocation policy at one operating point, with the
//! paper's headline ratios printed.
//!
//! ```sh
//! cargo run --release --example cluster_comparison [N] [samples]
//! ```

use hetcoded::model::{ClusterSpec, LatencyModel};
use hetcoded::sim::{simulate_scheme, Scheme, SimConfig};

fn main() -> hetcoded::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n_total: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(2500);
    let samples: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(10_000);

    let spec = ClusterSpec::paper_five_group(n_total, 10_000);
    let cfg = SimConfig { samples, seed: 2019, threads: 0 };
    println!(
        "five-group cluster: N={} k={} mu=(16,12,8,4,1) alpha=1, {} samples\n",
        spec.total_workers(),
        spec.k,
        samples
    );

    let schemes = [
        Scheme::Proposed,
        Scheme::UniformWithOptimalN,
        Scheme::UniformRate(0.5),
        Scheme::Uncoded,
        Scheme::GroupCode(100.0),
        Scheme::Reisizadeh,
    ];
    println!(
        "{:<22} {:>12} {:>10} {:>8} {:>12}",
        "scheme", "E[T]", "stderr", "rate", "bound"
    );
    let mut proposed_mean = f64::NAN;
    let mut uniform_nstar_mean = f64::NAN;
    let mut group_mean = f64::NAN;
    for scheme in schemes {
        let r = simulate_scheme(&spec, scheme, LatencyModel::A, &cfg)?;
        println!(
            "{:<22} {:>12.4e} {:>10.1e} {:>8.3} {:>12}",
            r.scheme,
            r.mean,
            r.stderr,
            r.rate,
            r.bound.map_or("-".into(), |b| format!("{b:.4e}")),
        );
        match scheme {
            Scheme::Proposed => proposed_mean = r.mean,
            Scheme::UniformWithOptimalN => uniform_nstar_mean = r.mean,
            Scheme::GroupCode(_) => group_mean = r.mean,
            _ => {}
        }
    }
    println!(
        "\npaper headline checks @ N={n_total}:\n  proposed vs uniform(n*): \
         {:.1}% lower (paper: ~18%)\n  group-code / proposed: {:.1}x (paper: \
         10x+ at large N)",
        100.0 * (uniform_nstar_mean - proposed_mean) / uniform_nstar_mean,
        group_mean / proposed_mean
    );
    Ok(())
}
