//! Fully-heterogeneous fleet (paper footnote 1): every worker has its own
//! `(μ_i, α_i)`. We cluster workers into G groups with the in-repo k-means,
//! apply the proposed allocation to the clustered model, and Monte-Carlo
//! compare against (a) uniform allocation and (b) the allocation computed
//! from the true (oracle) group structure.
//!
//! ```sh
//! cargo run --release --example heterogeneous_fleet [G]
//! ```

use hetcoded::allocation::{proposed_allocation, uniform_allocation};
use hetcoded::math::Rng;
use hetcoded::model::clustering::{cluster_workers, WorkerParams};
use hetcoded::model::{ClusterSpec, Group, LatencyModel};
use hetcoded::sim::{latency_any_k, SimConfig};

fn main() -> hetcoded::Result<()> {
    let g: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let k = 10_000usize;

    // A fleet drawn from 4 latent tiers with 15% per-worker jitter.
    let tiers = [(150usize, 16.0, 1.0), (250, 8.0, 1.0), (300, 4.0, 1.2), (300, 1.0, 1.5)];
    let mut rng = Rng::new(42);
    let mut fleet = Vec::new();
    for &(n, mu, alpha) in &tiers {
        for _ in 0..n {
            fleet.push(WorkerParams {
                mu: mu * (1.0 + 0.15 * (rng.next_f64() - 0.5)),
                alpha: alpha * (1.0 + 0.15 * (rng.next_f64() - 0.5)),
            });
        }
    }
    println!("fleet: {} fully-heterogeneous workers, clustering into G={g}", fleet.len());

    // Cluster and build the approximate group model.
    let (groups, _assign) = cluster_workers(&fleet, g, 7)?;
    let spec = ClusterSpec::new(groups.clone(), k)?;
    for (j, grp) in spec.groups.iter().enumerate() {
        println!(
            "  cluster {j}: {} workers, centroid mu={:.2} alpha={:.2}",
            grp.n, grp.mu, grp.alpha
        );
    }

    // Oracle model: the true tiers.
    let oracle = ClusterSpec::new(
        tiers
            .iter()
            .map(|&(n, mu, alpha)| Group { n, mu, alpha })
            .collect(),
        k,
    )?;

    let cfg = SimConfig { samples: 10_000, seed: 11, threads: 0 };
    let clustered_alloc = proposed_allocation(LatencyModel::A, &spec)?;
    let oracle_alloc = proposed_allocation(LatencyModel::A, &oracle)?;
    let uniform = uniform_allocation(LatencyModel::A, &oracle, oracle_alloc.n)?;

    // Evaluate ALL allocations on the ORACLE model (the "real" cluster):
    // map each clustered load to the oracle groups by rank (both sorted by
    // mu descending get the fast-group loads).
    let mapped = map_loads_by_mu(&spec, &clustered_alloc.loads, &oracle);
    let l_clustered = latency_any_k(&oracle, &mapped, LatencyModel::A, &cfg)?;
    let l_oracle = latency_any_k(&oracle, &oracle_alloc.loads, LatencyModel::A, &cfg)?;
    let l_uniform = latency_any_k(&oracle, &uniform.loads, LatencyModel::A, &cfg)?;

    println!("\nexpected latency on the true cluster (10k samples):");
    println!("  proposed w/ oracle groups   : {:.5e}", l_oracle.mean());
    println!("  proposed w/ k-means groups  : {:.5e}", l_clustered.mean());
    println!("  uniform (same n*)           : {:.5e}", l_uniform.mean());
    let penalty = (l_clustered.mean() - l_oracle.mean()) / l_oracle.mean();
    let gain = (l_uniform.mean() - l_clustered.mean()) / l_uniform.mean();
    println!(
        "\nclustering penalty vs oracle: {:.2}% ; gain over uniform: {:.1}%",
        100.0 * penalty,
        100.0 * gain
    );
    assert!(penalty < 0.2, "clustered allocation should be near-oracle");
    println!("heterogeneous_fleet OK");
    Ok(())
}

/// Assign per-group loads computed on `from` to the groups of `to`, pairing
/// groups by their straggling-parameter rank.
fn map_loads_by_mu(from: &ClusterSpec, loads: &[f64], to: &ClusterSpec) -> Vec<f64> {
    let mut from_idx: Vec<usize> = (0..from.groups.len()).collect();
    from_idx.sort_by(|&a, &b| from.groups[b].mu.partial_cmp(&from.groups[a].mu).unwrap());
    let mut to_idx: Vec<usize> = (0..to.groups.len()).collect();
    to_idx.sort_by(|&a, &b| to.groups[b].mu.partial_cmp(&to.groups[a].mu).unwrap());
    let mut out = vec![0.0; to.groups.len()];
    for (rank, &tj) in to_idx.iter().enumerate() {
        // If G differs, clamp to the nearest available rank.
        let fj = from_idx[rank.min(from_idx.len() - 1)];
        out[tj] = loads[fj];
    }
    out
}
