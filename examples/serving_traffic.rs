//! Serving traffic: how allocation policy shapes throughput under load.
//!
//! The paper's Theorem 2 minimizes a *single* job's expected latency. This
//! example shows what that buys a serving system: sweep the arrival rate
//! on the paper's two-group cluster (Fig. 8) and watch each policy's
//! sojourn-time tail — the better allocation sustains a higher rate before
//! its queue blows up, because the single-job latency `E[S]` is the
//! service-side bottleneck `1/E[S]` on throughput.
//!
//! Ends with a small *live* run: a Poisson trace replayed against real
//! worker threads with batched dispatch (an arrivals-mode
//! [`hetcoded::coordinator::Session`]).
//!
//! ```sh
//! cargo run --release --example serving_traffic
//! ```

use hetcoded::allocation::{policy, uniform_allocation};
use hetcoded::coding::Matrix;
use hetcoded::coordinator::{JobConfig, Mode, Session};
use hetcoded::math::Rng;
use hetcoded::model::{ClusterSpec, LatencyModel};
use hetcoded::workload::{
    run_workload_policy, saturation_rate, service_sampler_for,
    ArrivalProcess, WorkloadConfig,
};
use std::time::Duration;

fn main() -> hetcoded::Result<()> {
    let spec = ClusterSpec::paper_two_group(10_000);
    let model = LatencyModel::A;
    println!(
        "cluster: {} workers in {} groups, k = {}\n",
        spec.total_workers(),
        spec.num_groups(),
        spec.k
    );

    // Calibrate the rate axis on the *proposed* policy's saturation point
    // 1/E[S*], then offer the same absolute rates to every policy. All
    // policies come from the central registry by name.
    let proposed = policy::resolve("proposed")?;
    let (_, mut cal) = service_sampler_for(&spec, &*proposed, model)?;
    let sat = saturation_rate(&mut cal, 4_000, 1);
    let es_star = 1.0 / sat;
    println!("proposed E[S] = {es_star:.4e}  (saturation at {sat:.3} jobs/unit time)");

    let policies = [
        ("proposed", policy::resolve("proposed")?),
        ("uniform-n*", policy::resolve("uniform-nstar")?),
        ("group-code r=100", policy::resolve("group-code=100")?),
    ];
    println!(
        "\n{:<18} {:>8} {:>9} {:>6} {:>10} {:>10} {:>7}",
        "policy", "rate", "thruput", "util", "p50", "p99", "maxQ"
    );
    for frac in [0.2, 0.5, 0.8, 0.95] {
        let rate = frac / es_star;
        for (name, p) in &policies {
            let cfg = WorkloadConfig {
                arrivals: ArrivalProcess::Poisson { rate },
                jobs: 3_000,
                servers: 1,
                seed: 2019,
            };
            match run_workload_policy(&spec, &**p, model, &cfg) {
                Ok(r) => println!(
                    "{:<18} {:>8.3} {:>9.3} {:>6.3} {:>10.4e} {:>10.4e} {:>7}",
                    name,
                    rate,
                    r.throughput,
                    r.utilization,
                    r.sojourn_percentile(50.0),
                    r.sojourn_percentile(99.0),
                    r.max_in_system,
                ),
                Err(e) => println!("{name:<18} {rate:>8.3}  error: {e}"),
            }
        }
        println!();
    }

    // Live replay: 12 requests, Poisson arrivals, batched dispatch over
    // real worker threads (native backend; build with `--features xla` and
    // run `make artifacts` for the PJRT path).
    println!("live batched serving (native backend, 10 workers, k = 64):");
    let live_spec = ClusterSpec::new(
        vec![
            hetcoded::model::Group { n: 4, mu: 8.0, alpha: 1.0 },
            hetcoded::model::Group { n: 6, mu: 2.0, alpha: 1.0 },
        ],
        64,
    )?;
    let alloc = uniform_allocation(model, &live_spec, 128.0)?;
    let mut rng = Rng::new(42);
    let a = Matrix::from_fn(64, 16, |_, _| rng.normal());
    let requests: Vec<Vec<f64>> =
        (0..12).map(|_| (0..16).map(|_| rng.normal()).collect()).collect();
    let mut arrival_rng = Rng::new(43);
    let offsets: Vec<Duration> = ArrivalProcess::Poisson { rate: 100.0 }
        .times(12, &mut arrival_rng)?
        .into_iter()
        .map(Duration::from_secs_f64)
        .collect();
    let cfg = JobConfig { time_scale: 0.005, ..Default::default() };
    let outcome = Session::builder(&live_spec)
        .allocation(alloc)
        .data(a)
        .requests(requests)
        .config(cfg)
        .mode(Mode::Arrivals { offsets, max_batch: 4 })
        .build()?
        .serve()?;
    println!("{}", outcome.recorder.report());
    println!(
        "makespan {:.1} ms, worst decode error {:.2e}, encode passes {} \
         (prepared fast path: the matrix was encoded once for the stream)",
        outcome.makespan.unwrap().as_secs_f64() * 1e3,
        outcome.worst_error,
        outcome.encodes
    );
    Ok(())
}
