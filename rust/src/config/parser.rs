//! The TOML-subset tokenizer/parser.

use crate::{Error, Result};
use std::collections::BTreeMap;

/// A parsed value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// 64-bit integer literal.
    Int(i64),
    /// Float literal (also produced by `1e-3` style).
    Float(f64),
    /// Double-quoted string.
    Str(String),
    /// `true` / `false`.
    Bool(bool),
    /// Flat array of values.
    Array(Vec<Value>),
    /// Repeated `[[name]]` tables.
    Tables(Vec<Table>),
}

/// A table: ordered key → value map.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Table {
    entries: BTreeMap<String, Value>,
}

impl Table {
    /// Raw lookup.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    /// Integer (accepts Int only).
    pub fn get_int(&self, key: &str) -> Option<i64> {
        match self.get(key)? {
            Value::Int(v) => Some(*v),
            _ => None,
        }
    }

    /// Float (accepts Float or Int).
    pub fn get_float(&self, key: &str) -> Option<f64> {
        match self.get(key)? {
            Value::Float(v) => Some(*v),
            Value::Int(v) => Some(*v as f64),
            _ => None,
        }
    }

    /// String.
    pub fn get_str(&self, key: &str) -> Option<&str> {
        match self.get(key)? {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Bool.
    pub fn get_bool(&self, key: &str) -> Option<bool> {
        match self.get(key)? {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array of floats (Int entries are widened).
    pub fn get_float_array(&self, key: &str) -> Option<Vec<f64>> {
        match self.get(key)? {
            Value::Array(xs) => xs
                .iter()
                .map(|v| match v {
                    Value::Float(f) => Some(*f),
                    Value::Int(i) => Some(*i as f64),
                    _ => None,
                })
                .collect(),
            _ => None,
        }
    }

    /// Repeated tables (`[[name]]`).
    pub fn get_tables(&self, key: &str) -> Option<&[Table]> {
        match self.get(key)? {
            Value::Tables(ts) => Some(ts),
            _ => None,
        }
    }

    fn insert(&mut self, key: String, value: Value) -> Result<()> {
        if self.entries.contains_key(&key) {
            return Err(Error::Config(format!("duplicate key `{key}`")));
        }
        self.entries.insert(key, value);
        Ok(())
    }
}

/// Parse TOML-subset text into the root table.
pub fn parse(text: &str) -> Result<Table> {
    let mut root = Table::default();
    // Path of the table currently being filled: None = root,
    // Some(name) = last [[name]] or [name].
    let mut current: Option<String> = None;
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        let err = |msg: &str| Error::Config(format!("line {}: {msg}", lineno + 1));
        if let Some(name) = line.strip_prefix("[[").and_then(|s| s.strip_suffix("]]")) {
            let name = name.trim().to_string();
            if name.is_empty() {
                return Err(err("empty table name"));
            }
            match root.entries.entry(name.clone()) {
                std::collections::btree_map::Entry::Vacant(e) => {
                    e.insert(Value::Tables(vec![Table::default()]));
                }
                std::collections::btree_map::Entry::Occupied(mut e) => match e.get_mut() {
                    Value::Tables(ts) => ts.push(Table::default()),
                    _ => return Err(err("key exists with non-table type")),
                },
            }
            current = Some(name);
        } else if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            let name = name.trim().to_string();
            if name.is_empty() {
                return Err(err("empty table name"));
            }
            if root.entries.contains_key(&name) {
                return Err(err(&format!("duplicate table `{name}`")));
            }
            root.entries
                .insert(name.clone(), Value::Tables(vec![Table::default()]));
            current = Some(name);
        } else if let Some(eq) = line.find('=') {
            let key = line[..eq].trim().to_string();
            if key.is_empty() {
                return Err(err("empty key"));
            }
            let value = parse_value(line[eq + 1..].trim())
                .map_err(|m| err(&format!("bad value for `{key}`: {m}")))?;
            let target = match &current {
                None => &mut root,
                Some(name) => match root.entries.get_mut(name) {
                    Some(Value::Tables(ts)) => ts.last_mut().unwrap(),
                    _ => unreachable!("current table always exists"),
                },
            };
            target.insert(key, value)?;
        } else {
            return Err(err("expected `key = value` or `[table]`"));
        }
    }
    Ok(root)
}

fn strip_comment(line: &str) -> &str {
    // No # inside strings in our subset (strings may not contain '#').
    match line.find('#') {
        Some(i) => &line[..i],
        None => line,
    }
}

fn parse_value(s: &str) -> std::result::Result<Value, String> {
    if s.is_empty() {
        return Err("empty".into());
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('"').and_then(|t| t.strip_suffix('"')) {
        if inner.contains('"') {
            return Err("embedded quote".into());
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if let Some(inner) = s.strip_prefix('[').and_then(|t| t.strip_suffix(']')) {
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let items: std::result::Result<Vec<Value>, String> =
            inner.split(',').map(|p| parse_value(p.trim())).collect();
        return Ok(Value::Array(items?));
    }
    // Number: int if it parses as i64 and has no float markers.
    let is_floaty = s.contains('.') || s.contains('e') || s.contains('E');
    if !is_floaty {
        if let Ok(i) = s.parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    s.parse::<f64>()
        .map(Value::Float)
        .map_err(|_| format!("not a number: `{s}`"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        let t = parse("a = 1\nb = 2.5\nc = \"hi\"\nd = true\ne = 1e-3").unwrap();
        assert_eq!(t.get_int("a"), Some(1));
        assert_eq!(t.get_float("b"), Some(2.5));
        assert_eq!(t.get_str("c"), Some("hi"));
        assert_eq!(t.get_bool("d"), Some(true));
        assert_eq!(t.get_float("e"), Some(1e-3));
        // Int widens to float.
        assert_eq!(t.get_float("a"), Some(1.0));
    }

    #[test]
    fn arrays() {
        let t = parse("xs = [1, 2.5, 3]").unwrap();
        assert_eq!(t.get_float_array("xs"), Some(vec![1.0, 2.5, 3.0]));
        let t = parse("xs = []").unwrap();
        assert_eq!(t.get_float_array("xs"), Some(vec![]));
    }

    #[test]
    fn repeated_tables() {
        let t = parse("[[g]]\nx = 1\n[[g]]\nx = 2").unwrap();
        let gs = t.get_tables("g").unwrap();
        assert_eq!(gs.len(), 2);
        assert_eq!(gs[0].get_int("x"), Some(1));
        assert_eq!(gs[1].get_int("x"), Some(2));
    }

    #[test]
    fn single_table() {
        let t = parse("[s]\nx = 3").unwrap();
        assert_eq!(t.get_tables("s").unwrap()[0].get_int("x"), Some(3));
    }

    #[test]
    fn comments_and_blanks() {
        let t = parse("# header\n\na = 1 # trailing\n").unwrap();
        assert_eq!(t.get_int("a"), Some(1));
    }

    #[test]
    fn errors_are_informative() {
        for bad in ["a ==", "= 1", "[unclosed", "a = [1,", "a = \"x", "junk"] {
            let e = parse(bad).unwrap_err();
            let msg = format!("{e}");
            assert!(msg.contains("line 1"), "{bad} -> {msg}");
        }
    }

    #[test]
    fn duplicate_key_rejected() {
        assert!(parse("a = 1\na = 2").is_err());
        assert!(parse("[t]\n[t]").is_err());
    }

    #[test]
    fn negative_and_scientific_numbers() {
        let t = parse("a = -5\nb = -2.5e2").unwrap();
        assert_eq!(t.get_int("a"), Some(-5));
        assert_eq!(t.get_float("b"), Some(-250.0));
    }
}
