//! Minimal TOML-subset configuration parser (no serde in the vendored set).
//!
//! Supports exactly what cluster specs need:
//!
//! ```toml
//! # comment
//! k = 10000
//! name = "fig4"
//! rate = 0.5
//! flag = true
//! mus = [16.0, 12.0, 8.0]
//!
//! [[group]]
//! workers = 300
//! mu = 16.0
//! alpha = 1.0
//! ```
//!
//! i.e. scalar keys (int / float / string / bool), flat arrays of numbers,
//! and repeated `[[table]]` sections. Single `[table]` sections are also
//! accepted.

#![forbid(unsafe_code)]

mod parser;

pub use parser::{parse, Table, Value};

use crate::model::{ClusterSpec, Group};
use crate::{Error, Result};

impl ClusterSpec {
    /// Parse a cluster spec from TOML-subset text: a root-level `k` plus one
    /// `[[group]]` per worker group with `workers`, `mu`, `alpha` keys.
    pub fn from_toml(text: &str) -> Result<ClusterSpec> {
        let root = parse(text)?;
        let k = root
            .get_int("k")
            .ok_or_else(|| Error::Config("missing root key `k`".into()))?;
        if k <= 0 {
            return Err(Error::Config(format!("k must be positive, got {k}")));
        }
        let tables = root
            .get_tables("group")
            .ok_or_else(|| Error::Config("missing [[group]] sections".into()))?;
        let mut groups = Vec::with_capacity(tables.len());
        for (i, t) in tables.iter().enumerate() {
            let workers = t
                .get_int("workers")
                .ok_or_else(|| Error::Config(format!("group {i}: missing `workers`")))?;
            let mu = t
                .get_float("mu")
                .ok_or_else(|| Error::Config(format!("group {i}: missing `mu`")))?;
            let alpha = t.get_float("alpha").unwrap_or(1.0);
            groups.push(Group::new(workers as usize, mu, alpha)?);
        }
        ClusterSpec::new(groups, k as usize)
    }

    /// Load a spec from a file path.
    pub fn from_toml_file(path: &std::path::Path) -> Result<ClusterSpec> {
        let text = std::fs::read_to_string(path)?;
        ClusterSpec::from_toml(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# Fig. 8 cluster
k = 10000

[[group]]
workers = 300
mu = 4.0
alpha = 1.0

[[group]]
workers = 600
mu = 0.5
# alpha defaults to 1.0
"#;

    #[test]
    fn parses_cluster_spec() {
        let spec = ClusterSpec::from_toml(SAMPLE).unwrap();
        assert_eq!(spec.k, 10_000);
        assert_eq!(spec.num_groups(), 2);
        assert_eq!(spec.groups[0].n, 300);
        assert_eq!(spec.groups[1].mu, 0.5);
        assert_eq!(spec.groups[1].alpha, 1.0);
    }

    #[test]
    fn missing_k_rejected() {
        assert!(ClusterSpec::from_toml("[[group]]\nworkers = 3\nmu = 1.0").is_err());
    }

    #[test]
    fn missing_groups_rejected() {
        assert!(ClusterSpec::from_toml("k = 100").is_err());
    }

    #[test]
    fn bad_group_values_rejected() {
        let text = "k = 100\n[[group]]\nworkers = 0\nmu = 1.0";
        assert!(ClusterSpec::from_toml(text).is_err());
    }
}
