//! Hand-rolled CLI argument parsing (no `clap` in the vendored set).
//!
//! Grammar: `hetcoded <subcommand> [--flag value | --switch] [positional...]`.

use crate::{Error, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// First non-flag token (the subcommand).
    pub subcommand: Option<String>,
    /// `--key value` pairs.
    flags: BTreeMap<String, String>,
    /// Bare `--switch` tokens.
    switches: Vec<String>,
    /// Remaining positional tokens.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit token iterator (testable).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err(Error::InvalidSpec("empty flag `--`".into()));
                }
                // `--key=value` or `--key value` or bare switch.
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.switches.push(name.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    /// Parse from the process environment (skipping argv[0]).
    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    /// Raw flag value.
    pub fn flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// Typed flag with default.
    pub fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse::<T>().map_err(|_| {
                Error::InvalidSpec(format!("flag --{key}: cannot parse `{v}`"))
            }),
        }
    }

    /// Required typed flag.
    pub fn require<T: std::str::FromStr>(&self, key: &str) -> Result<T> {
        match self.flags.get(key) {
            None => Err(Error::InvalidSpec(format!("missing required flag --{key}"))),
            Some(v) => v.parse::<T>().map_err(|_| {
                Error::InvalidSpec(format!("flag --{key}: cannot parse `{v}`"))
            }),
        }
    }

    /// Is a bare switch present?
    pub fn switch(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
    }

    /// Comma-separated typed list flag with default, e.g.
    /// `--rho 0.3,0.6,0.9` or `--policies proposed,uniform-nstar`.
    /// Empty segments are skipped, so trailing commas are harmless.
    pub fn get_list<T>(&self, key: &str, default: &[T]) -> Result<Vec<T>>
    where
        T: std::str::FromStr + Clone,
    {
        match self.flags.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.parse::<T>().map_err(|_| {
                        Error::InvalidSpec(format!(
                            "flag --{key}: cannot parse `{s}`"
                        ))
                    })
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn subcommand_flags_positional() {
        let a = Args::parse(toks("figures --fig 4 --samples 1000 out.csv")).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("figures"));
        assert_eq!(a.get::<u32>("fig", 0).unwrap(), 4);
        assert_eq!(a.get::<usize>("samples", 0).unwrap(), 1000);
        assert_eq!(a.positional, vec!["out.csv"]);
    }

    #[test]
    fn equals_form_and_switches() {
        let a = Args::parse(toks("run --seed=42 --verbose")).unwrap();
        assert_eq!(a.get::<u64>("seed", 0).unwrap(), 42);
        assert!(a.switch("verbose"));
        assert!(!a.switch("quiet"));
    }

    #[test]
    fn defaults_and_required() {
        let a = Args::parse(toks("x")).unwrap();
        assert_eq!(a.get::<f64>("q", 1.5).unwrap(), 1.5);
        assert!(a.require::<f64>("q").is_err());
    }

    #[test]
    fn parse_errors() {
        let a = Args::parse(toks("x --n abc")).unwrap();
        assert!(a.get::<u32>("n", 0).is_err());
        assert!(Args::parse(toks("x --")).is_err());
    }

    #[test]
    fn list_flags() {
        let a = Args::parse(toks("w --rho 0.3,0.6,0.9 --policies proposed,uniform-nstar,")).unwrap();
        assert_eq!(a.get_list::<f64>("rho", &[]).unwrap(), vec![0.3, 0.6, 0.9]);
        assert_eq!(
            a.get_list::<String>("policies", &[]).unwrap(),
            vec!["proposed".to_string(), "uniform-nstar".to_string()]
        );
        // Default when absent; parse error surfaces.
        assert_eq!(a.get_list::<u32>("missing", &[7, 8]).unwrap(), vec![7, 8]);
        let b = Args::parse(toks("w --rho 0.3,x")).unwrap();
        assert!(b.get_list::<f64>("rho", &[]).is_err());
    }

    #[test]
    fn negative_flag_values() {
        let a = Args::parse(toks("x --offset -3")).unwrap();
        assert_eq!(a.get::<i32>("offset", 0).unwrap(), -3);
    }
}
