//! Hand-rolled CLI argument parsing (no `clap` in the vendored set).
//!
//! Grammar: `hetcoded <subcommand> [--flag value | --switch] [positional...]`.

#![forbid(unsafe_code)]

use crate::{Error, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// First non-flag token (the subcommand).
    pub subcommand: Option<String>,
    /// `--key value` pairs.
    flags: BTreeMap<String, String>,
    /// Bare `--switch` tokens.
    switches: Vec<String>,
    /// Remaining positional tokens.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit token iterator (testable).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err(Error::InvalidSpec("empty flag `--`".into()));
                }
                // `--key=value` or `--key value` or bare switch.
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if let Some(v) = it.next_if(|n| !n.starts_with("--")) {
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.switches.push(name.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    /// Parse from the process environment (skipping argv[0]).
    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    /// Raw flag value.
    pub fn flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// Typed flag with default.
    pub fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse::<T>().map_err(|_| {
                Error::InvalidSpec(format!("flag --{key}: cannot parse `{v}`"))
            }),
        }
    }

    /// Required typed flag.
    pub fn require<T: std::str::FromStr>(&self, key: &str) -> Result<T> {
        match self.flags.get(key) {
            None => Err(Error::InvalidSpec(format!("missing required flag --{key}"))),
            Some(v) => v.parse::<T>().map_err(|_| {
                Error::InvalidSpec(format!("flag --{key}: cannot parse `{v}`"))
            }),
        }
    }

    /// Is a bare switch present?
    pub fn switch(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
    }

    /// All flag and switch names present on the command line (a name that
    /// parsed as a flag or as a bare switch is reported either way — the
    /// grammar cannot distinguish `--adaptive` at end-of-line from
    /// `--adaptive <value>`, so validation treats the buckets uniformly).
    pub fn given_names(&self) -> impl Iterator<Item = &str> {
        self.flags
            .keys()
            .map(String::as_str)
            .chain(self.switches.iter().map(String::as_str))
    }

    /// Reject any flag/switch not in `allowed`, with a did-you-mean
    /// suggestion and a `hetcoded help <subcommand>` pointer. Before this
    /// check existed a typo like `--max-bath 8` silently ran with the
    /// default.
    pub fn reject_unknown(&self, subcommand: &str, allowed: &[&str]) -> Result<()> {
        for name in self.given_names() {
            if !allowed.contains(&name) {
                let hint = closest_flag(name, allowed)
                    .map(|c| format!(" (did you mean `--{c}`?)"))
                    .unwrap_or_default();
                return Err(Error::InvalidSpec(format!(
                    "unknown flag --{name} for `{subcommand}`{hint}; see \
                     `hetcoded help {subcommand}`"
                )));
            }
        }
        Ok(())
    }

    /// Comma-separated typed list flag with default, e.g.
    /// `--rho 0.3,0.6,0.9` or `--policies proposed,uniform-nstar`.
    /// Empty segments are skipped, so trailing commas are harmless.
    pub fn get_list<T>(&self, key: &str, default: &[T]) -> Result<Vec<T>>
    where
        T: std::str::FromStr + Clone,
    {
        match self.flags.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.parse::<T>().map_err(|_| {
                        Error::InvalidSpec(format!(
                            "flag --{key}: cannot parse `{s}`"
                        ))
                    })
                })
                .collect(),
        }
    }
}

/// The allowed flag nearest to `name` by edit distance, when it is close
/// enough to be a plausible typo (distance ≤ 2, or ≤ 1/3 of the name's
/// length for long flags).
fn closest_flag<'a>(name: &str, allowed: &[&'a str]) -> Option<&'a str> {
    let budget = 2usize.max(name.len() / 3);
    allowed
        .iter()
        .map(|&c| (levenshtein(name, c), c))
        .filter(|(d, _)| *d <= budget)
        .min_by_key(|(d, _)| *d)
        .map(|(_, c)| c)
}

/// Classic two-row Levenshtein distance over bytes (flag names are ASCII).
fn levenshtein(a: &str, b: &str) -> usize {
    let (a, b) = (a.as_bytes(), b.as_bytes());
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn subcommand_flags_positional() {
        let a = Args::parse(toks("figures --fig 4 --samples 1000 out.csv")).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("figures"));
        assert_eq!(a.get::<u32>("fig", 0).unwrap(), 4);
        assert_eq!(a.get::<usize>("samples", 0).unwrap(), 1000);
        assert_eq!(a.positional, vec!["out.csv"]);
    }

    #[test]
    fn equals_form_and_switches() {
        let a = Args::parse(toks("run --seed=42 --verbose")).unwrap();
        assert_eq!(a.get::<u64>("seed", 0).unwrap(), 42);
        assert!(a.switch("verbose"));
        assert!(!a.switch("quiet"));
    }

    #[test]
    fn defaults_and_required() {
        let a = Args::parse(toks("x")).unwrap();
        assert_eq!(a.get::<f64>("q", 1.5).unwrap(), 1.5);
        assert!(a.require::<f64>("q").is_err());
    }

    #[test]
    fn parse_errors() {
        let a = Args::parse(toks("x --n abc")).unwrap();
        assert!(a.get::<u32>("n", 0).is_err());
        assert!(Args::parse(toks("x --")).is_err());
    }

    #[test]
    fn list_flags() {
        let a = Args::parse(toks("w --rho 0.3,0.6,0.9 --policies proposed,uniform-nstar,")).unwrap();
        assert_eq!(a.get_list::<f64>("rho", &[]).unwrap(), vec![0.3, 0.6, 0.9]);
        assert_eq!(
            a.get_list::<String>("policies", &[]).unwrap(),
            vec!["proposed".to_string(), "uniform-nstar".to_string()]
        );
        // Default when absent; parse error surfaces.
        assert_eq!(a.get_list::<u32>("missing", &[7, 8]).unwrap(), vec![7, 8]);
        let b = Args::parse(toks("w --rho 0.3,x")).unwrap();
        assert!(b.get_list::<f64>("rho", &[]).is_err());
    }

    #[test]
    fn negative_flag_values() {
        let a = Args::parse(toks("x --offset -3")).unwrap();
        assert_eq!(a.get::<i32>("offset", 0).unwrap(), -3);
    }

    #[test]
    fn unknown_flags_rejected_with_hint() {
        let allowed = &["max-batch", "rate", "seed", "adaptive"];
        // The motivating typo: --max-bath used to run with the default.
        let a = Args::parse(toks("run --max-bath 8")).unwrap();
        let err = a.reject_unknown("run", allowed).unwrap_err().to_string();
        assert!(err.contains("--max-bath"), "{err}");
        assert!(err.contains("did you mean `--max-batch`?"), "{err}");
        assert!(err.contains("hetcoded help run"), "{err}");
        // Switches are validated too.
        let a = Args::parse(toks("run --adaptiev")).unwrap();
        let err = a.reject_unknown("run", allowed).unwrap_err().to_string();
        assert!(err.contains("did you mean `--adaptive`?"), "{err}");
        // A name far from everything gets no suggestion but still fails.
        let a = Args::parse(toks("run --zzzzzzzzzzzz 1")).unwrap();
        let err = a.reject_unknown("run", allowed).unwrap_err().to_string();
        assert!(!err.contains("did you mean"), "{err}");
        // Known flags pass.
        let a = Args::parse(toks("run --max-batch 8 --adaptive")).unwrap();
        a.reject_unknown("run", allowed).unwrap();
    }

    #[test]
    fn levenshtein_basics() {
        assert_eq!(levenshtein("", ""), 0);
        assert_eq!(levenshtein("abc", "abc"), 0);
        assert_eq!(levenshtein("abc", "abd"), 1);
        assert_eq!(levenshtein("max-bath", "max-batch"), 1);
        assert_eq!(levenshtein("kitten", "sitting"), 3);
        assert_eq!(levenshtein("", "abc"), 3);
    }
}
