//! Tiny in-repo property-testing driver (the vendored crate set has no
//! `proptest`).
//!
//! A property is a closure receiving a seeded [`Rng`]; the driver runs it for
//! a configurable number of cases and, on failure, reports the exact case
//! seed so the run can be replayed deterministically:
//!
//! ```
//! use hetcoded::proptest::property;
//! property("addition commutes", 64, |rng| {
//!     let (a, b) = (rng.next_f64(), rng.next_f64());
//!     if a + b == b + a { Ok(()) } else { Err(format!("{a} {b}")) }
//! });
//! ```

#![forbid(unsafe_code)]

use crate::math::Rng;

/// Default number of cases used by the repo's property tests.
pub const DEFAULT_CASES: usize = 128;

/// Run `cases` random cases of `prop`; panic with the replay seed on failure.
pub fn property<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    // Fixed master seed => CI-stable; per-case seeds reported for replay.
    let mut master = Rng::new(0xC0DE_D15C_0000_0000 ^ fxhash(name));
    for case in 0..cases {
        let case_seed = master.next_u64();
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property `{name}` failed at case {case}/{cases} \
                 (replay seed {case_seed:#018x}): {msg}"
            );
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay<F>(seed: u64, mut prop: F) -> Result<(), String>
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    prop(&mut rng)
}

/// FNV-1a hash for stable name-derived seeds.
fn fxhash(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Helpers for drawing structured random inputs in property tests.
pub mod gen {
    use crate::math::Rng;
    use crate::model::{ClusterSpec, Group};

    /// Random group count in `[1, max_g]`, sizes in `[2, max_n]`,
    /// `μ ∈ [0.1, 20]`, `α ∈ [0.5, 8]`.
    pub fn cluster(rng: &mut Rng, max_g: usize, max_n: usize, k: usize) -> ClusterSpec {
        let g = 1 + rng.gen_range(max_g as u64) as usize;
        let groups = (0..g)
            .map(|_| Group {
                n: 2 + rng.gen_range((max_n - 1) as u64) as usize,
                mu: rng.uniform(0.1, 20.0),
                alpha: rng.uniform(0.5, 8.0),
            })
            .collect();
        ClusterSpec::new(groups, k).expect("generated spec valid")
    }

    /// Random code dimensions: `k ∈ [2, max_k]`, `n ∈ [k, k + max_extra]`.
    pub fn code_dims(
        rng: &mut Rng,
        max_k: usize,
        max_extra: usize,
    ) -> (usize, usize) {
        let k = 2 + rng.gen_range((max_k - 1) as u64) as usize;
        let n = k + rng.gen_range((max_extra + 1) as u64) as usize;
        (n, k)
    }

    /// Random `m`-subset of `0..n`, in random arrival order, no repeats
    /// (partial Fisher–Yates).
    pub fn row_subset(rng: &mut Rng, n: usize, m: usize) -> Vec<usize> {
        assert!(m <= n, "subset of {m} from {n} rows");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..m {
            let j = i + rng.gen_range((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(m);
        idx
    }

    /// Random cluster with all shift parameters equal (group-code compatible).
    pub fn cluster_equal_alpha(
        rng: &mut Rng,
        max_g: usize,
        max_n: usize,
        k: usize,
    ) -> ClusterSpec {
        let mut spec = cluster(rng, max_g, max_n, k);
        let alpha = rng.uniform(0.5, 4.0);
        for g in &mut spec.groups {
            g.alpha = alpha;
        }
        spec
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        property("counts", 10, |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        property("fails", 10, |rng| {
            let v = rng.next_f64();
            if v < 2.0 {
                Err(format!("v={v}"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn replay_is_deterministic() {
        let mut seen = Vec::new();
        let _ = replay(42, |rng| {
            seen.push(rng.next_u64());
            Ok(())
        });
        let mut seen2 = Vec::new();
        let _ = replay(42, |rng| {
            seen2.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(seen, seen2);
    }

    #[test]
    fn generated_clusters_valid() {
        property("gen cluster valid", 50, |rng| {
            let spec = gen::cluster(rng, 6, 100, 1000);
            if spec.total_workers() == 0 || spec.num_groups() == 0 {
                return Err("empty".into());
            }
            Ok(())
        });
    }

    #[test]
    fn generated_code_dims_and_subsets_valid() {
        property("gen code dims/subsets", 100, |rng| {
            let (n, k) = gen::code_dims(rng, 12, 12);
            if !(2..=12).contains(&k) || !(k..=k + 12).contains(&n) {
                return Err(format!("dims out of range: n={n} k={k}"));
            }
            let rows = gen::row_subset(rng, n, k);
            if rows.len() != k {
                return Err(format!("subset size {}", rows.len()));
            }
            let mut sorted = rows.clone();
            sorted.sort_unstable();
            sorted.dedup();
            if sorted.len() != k || sorted.iter().any(|&r| r >= n) {
                return Err(format!("subset invalid: {rows:?}"));
            }
            Ok(())
        });
    }
}
