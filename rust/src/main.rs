//! `hetcoded` CLI — leader entrypoint.
//!
//! Subcommands:
//!
//! - `allocate` — print the allocation every registered policy produces;
//! - `simulate` — Monte-Carlo latency of one policy on a cluster;
//! - `workload` — throughput/utilization/sojourn under sustained traffic;
//! - `figures`  — regenerate paper figures (CSV + ASCII);
//! - `run`      — live coded matvec over the coordinator (native or PJRT);
//! - `help`     — this text.
//!
//! Every policy name is resolved through the central registry
//! ([`hetcoded::allocation::policy`]); every live serving shape goes
//! through the [`Session`] facade. Unknown flags are rejected with a
//! did-you-mean hint ([`Args::reject_unknown`]).

#![forbid(unsafe_code)]

use hetcoded::allocation::policy::{self, Policy, PolicyEntry};
use hetcoded::cli::Args;
use hetcoded::coding::{code, Matrix};
use hetcoded::coordinator::{
    AdaptiveServeConfig, Compute, DegradePolicy, FailureScenario,
    FrontEndConfig, JobConfig, Mode, NativeCompute, RecoveryConfig, Session,
};
use hetcoded::figures::{self, FigureOpts};
use hetcoded::math::Rng;
use hetcoded::model::{ClusterSpec, EstimatorConfig, LatencyModel};
use hetcoded::sim::{simulate_policy, Scheme, SimConfig};
use hetcoded::workload::{
    mean_service, run_admission, run_workload_drift, run_workload_policy,
    service_sampler, service_sampler_for, AdaptPolicy, AdmissionConfig,
    ArrivalProcess, BatchPolicy, DriftSchedule, DriftWorkloadConfig,
    SloConfig, TenantSpec, WorkloadConfig,
};
use hetcoded::{Error, Result};
use std::sync::Arc;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

/// Flags accepted by each subcommand (used by [`Args::reject_unknown`] so
/// a typo like `--max-bath` fails loudly instead of running with the
/// default). Keep in sync with the `help` text.
const ALLOCATE_FLAGS: &[&str] = &[
    "config", "paper", "n-total", "k", "q", "model", "rate", "group-r",
    "analytic",
];
const SIMULATE_FLAGS: &[&str] = &[
    "config", "paper", "n-total", "k", "q", "model", "scheme", "samples",
    "seed", "threads", "rate", "group-r",
];
const WORKLOAD_FLAGS: &[&str] = &[
    "config",
    "paper",
    "n-total",
    "k",
    "q",
    "model",
    "policies",
    "rho",
    "rates",
    "arrivals",
    "jobs",
    "servers",
    "seed",
    "burst-on",
    "burst-off",
    "calib-samples",
    "drift",
    "drift-window",
    "drift-min-obs",
    "drift-threshold",
    "drift-check-every",
    "rate",
    "group-r",
    "shards",
    "drainers",
    "tenants",
    "steal",
    "slo",
    "amortize",
    "max-batch",
];
const FIGURES_FLAGS: &[&str] =
    &["fig", "all", "samples", "points", "seed", "out", "threads", "quick"];
const RUN_FLAGS: &[&str] = &[
    "backend",
    "config",
    "model",
    "k",
    "d",
    "requests",
    "time-scale",
    "seed",
    "dead",
    "mode",
    "rate",
    "max-batch",
    "encode-threads",
    "decode-cache",
    "failures",
    "drift",
    "loss",
    "adaptive",
    "policy",
    "code",
    "shards",
    "tenants",
    "slo",
    "stall",
    "flap",
    "worker-loss",
    "hedge",
    "hedge-quantile",
    "hedge-floor",
    "max-waves",
    "backoff",
    "batch-deadline",
    "quarantine-after",
    "degrade",
];

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("allocate") => {
            args.reject_unknown("allocate", ALLOCATE_FLAGS)?;
            cmd_allocate(args)
        }
        Some("simulate") => {
            args.reject_unknown("simulate", SIMULATE_FLAGS)?;
            cmd_simulate(args)
        }
        Some("workload") => {
            args.reject_unknown("workload", WORKLOAD_FLAGS)?;
            cmd_workload(args)
        }
        Some("figures") => {
            args.reject_unknown("figures", FIGURES_FLAGS)?;
            cmd_figures(args)
        }
        Some("run") => {
            args.reject_unknown("run", RUN_FLAGS)?;
            cmd_run(args)
        }
        Some("help") | None => {
            print!("{}", help_text());
            Ok(())
        }
        Some(other) => Err(Error::InvalidSpec(format!(
            "unknown subcommand `{other}` (see `hetcoded help`)"
        ))),
    }
}

/// Compose the help text; the POLICIES section is generated from the
/// registry so the list can never drift from the code.
fn help_text() -> String {
    let mut policies = String::new();
    for e in policy::entries() {
        let param = match &e.param {
            Some(ps) => format!(" (param: --{} or {}=V, default {})", ps.flag, e.name, ps.default),
            None => String::new(),
        };
        policies.push_str(&format!("    {:<14} {}{}\n", e.name, e.summary, param));
    }
    let mut codes = String::new();
    for e in code::entries() {
        codes.push_str(&format!("    {:<16} {}\n", e.name, e.summary));
    }
    format!(
        "\
hetcoded — optimal load allocation for coded distributed computation
          (Kim, Park, Choi 2019 reproduction)

USAGE: hetcoded <subcommand> [flags]

POLICIES (the registry; any <policy> below, `name` or `name=param`)
{policies}
CODES (the erasure-code registry; `run --code <name>`)
{codes}
SUBCOMMANDS
  allocate  --config <toml> | --paper <fig2|fig4|fig8|fig9> [--n-total N] [--q Q]
            [--model a|b] [--rate R] [--group-r R] [--analytic]
            Print every registered policy's allocation for the cluster.
  simulate  --config <toml> | --paper <...> --scheme <policy> [--samples S]
            [--seed S] [--model a|b] [--rate R] [--group-r R] [--n-total N] [--q Q]
            Monte-Carlo expected latency of one policy.
  workload  [--config <toml> | --paper <...>] [--policies p1,p2=V,...]
            [--rho 0.3,0.6,0.9 | --rates L1,L2,...] [--arrivals poisson|
            deterministic|onoff] [--jobs J] [--servers C] [--seed S]
            [--model a|b] [--burst-on T --burst-off T] [--k K] [--q Q]
            [--calib-samples N] [--drift T:G:F[;...]] [--drift-window W]
            [--drift-min-obs R] [--drift-threshold X] [--drift-check-every C]
            [--shards S] [--drainers D] [--tenants T] [--steal true|false]
            [--slo P99] [--amortize G] [--max-batch B]
            Event-driven queueing simulation: throughput, utilization and
            sojourn percentiles per policy at each arrival rate. Default
            cluster: the paper's 2-group Fig. 8 cluster. --rho gives
            offered load as a fraction of each policy's saturation rate
            1/E[S] (estimated from --calib-samples draws, default 2000);
            --rates gives absolute arrival rates. With --drift (dilate
            group G by factor F at model time T), the run becomes the
            failure/drift experiment instead: the *proposed* allocation
            (--policies is ignored) is served static vs. adaptive (online
            (mu,alpha) estimation + re-solve under the initial coded-row
            budget) through the same drifting cluster at the first
            --rho/--rates entry, and post-drift sojourn tails are
            compared; the --drift-* flags are the estimator knobs
            (defaults 50/100/0.30/10). Any of --shards/--drainers/
            --tenants/--slo switches to the *admission front end*
            simulation instead: tenant traffic split over per-shard DRR
            queues, --drainers work-stealing drain loops (--steal
            true|false), batches of --max-batch (or SLO-adaptive sizing
            against a model-time p99 target with --slo), each batch
            amortized as S*(g + (1-g)*b) with g = --amortize (default
            0.75). Here --rho is offered load per drainer at single-job
            batches, so rho > 1 exercises the regime only batching can
            absorb.
  figures   [--fig N | --all] [--samples S] [--points P] [--seed S]
            [--out DIR] [--quick]
            Regenerate paper figures 2-9 + tail extension 10 (CSV to DIR).
  run       [--backend native|xla] [--config <toml>] [--k K] [--d D]
            [--policy <policy>] [--code <code>] [--requests R]
            [--time-scale T] [--seed S]
            [--dead i,j,...] [--mode seq|pipelined|batched|arrivals]
            [--rate R] [--max-batch B] [--encode-threads T] [--decode-cache C]
            [--failures B:w1,w2[;...]] [--drift B:G:F[;...]] [--adaptive]
            [--loss B:G:P[;...] | B:G:burst:N[;...]]
            [--stall B:w1,w2[;...]] [--flap B:W:PERIOD[;...]]
            [--worker-loss B:W:P[;...]]
            [--hedge true|false] [--hedge-quantile Q] [--hedge-floor T]
            [--max-waves W] [--backoff F] [--batch-deadline F]
            [--quarantine-after Q] [--degrade partial|fail]
            [--shards S] [--tenants T] [--slo P99_SECONDS]
            Here --rate is the *arrivals* rate; parameterized policies
            use the name=param form (e.g. --policy uniform-rate=0.5).
            Live coded matvec jobs through the coordinator's Session
            facade. `--mode arrivals` replays a Poisson trace (`--rate`
            arrivals/s) through the prepared-job fast path: the matrix is
            encoded once and queued requests are served in batches of
            <= --max-batch; `--mode batched` serves all requests as one
            coded batch. --decode-cache only applies to the prepared
            modes (seq/pipelined draw a fresh generator per request, so
            factorizations cannot recur across requests). --failures
            kills workers at a batch index, --drift dilates group G by
            factor F at a batch index, --loss drops group G's packets
            i.i.d. with probability P from a batch index (or everything
            for N batches with the burst form), and --adaptive turns on
            the online estimator + re-allocation loop (all four need
            --mode arrivals); re-allocation re-slices the encoded rows,
            so `encode passes` stays 1 regardless. --code picks the
            erasure code from the CODES registry (default mds-random; the
            sparse code is not MDS — a decode can fail cleanly if an
            unlucky k-subset of rows arrives first; rateless-rlc streams
            rows until any k survive, so it rides out --loss and reports
            the measured overhead rows/k). --stall makes workers go dark
            (alive, never replying) from a batch on, --flap alternates
            PERIOD dark / PERIOD healthy batches, and --worker-loss adds
            per-worker packet drop on top of --loss; all three need the
            recovery layer, which any of them (or any --hedge* knob)
            attaches: per-worker hedge deadlines at the --hedge-quantile
            of the analytic completion law (floored at --hedge-floor
            model time), blown row ranges re-issued to the fastest idle
            workers with x--backoff deadlines per wave (up to
            --max-waves), quarantine after --quarantine-after
            consecutive misses (canary probes re-admit), and at
            --batch-deadline times the slowest staged deadline the batch
            degrades per --degrade (partial: typed partial result with
            an error bound; fail: a decode error) instead of hanging.
            --hedge false keeps the deadlines/accounting but never
            re-dispatches (the baseline arm).
            --shards/--tenants/--slo
            attach the sharded admission front end to --mode arrivals
            (requests round-robin over T tenants, tenant-keyed per-shard
            DRR queues, work-conserving drain); --slo sizes batches
            online against a wall-clock p99 sojourn target in seconds
            (mutually exclusive with --adaptive).
  help      This text.
"
    )
}

fn load_spec(args: &Args) -> Result<ClusterSpec> {
    let n_total = args.get::<usize>("n-total", 2500)?;
    let k = args.get::<usize>("k", 10_000)?;
    let q = args.get::<f64>("q", 1.0)?;
    let spec = if let Some(path) = args.flag("config") {
        ClusterSpec::from_toml_file(std::path::Path::new(path))?
    } else {
        match args.flag("paper").unwrap_or("fig4") {
            "fig2" => ClusterSpec::paper_fig2(k),
            "fig4" | "fig5" | "fig6" | "fig7" => ClusterSpec::paper_five_group(n_total, k),
            "fig8" => ClusterSpec::paper_two_group(k),
            "fig9" => ClusterSpec::paper_three_group_b(n_total, 100_000),
            other => {
                return Err(Error::InvalidSpec(format!(
                    "unknown --paper preset `{other}`"
                )))
            }
        }
    };
    Ok(spec.scaled_mu(q))
}

fn parse_model(args: &Args) -> Result<LatencyModel> {
    match args.flag("model").unwrap_or("a") {
        "a" | "A" => Ok(LatencyModel::A),
        "b" | "B" => Ok(LatencyModel::B),
        other => Err(Error::InvalidSpec(format!("unknown model `{other}`"))),
    }
}

/// Build one registry entry's policy, reading its parameter from the
/// entry's CLI flag (`--rate`, `--group-r`) with the registry default.
fn build_entry_policy(args: &Args, entry: &PolicyEntry) -> Result<Box<dyn Policy>> {
    let param = match &entry.param {
        Some(ps) => Some(args.get::<f64>(ps.flag, ps.default)?),
        None => None,
    };
    entry.build(param)
}

/// Resolve a policy name through the central registry — the **only**
/// name-to-policy translation in the CLI. Accepts `name` (parameter read
/// from the policy's flag, e.g. `--rate` / `--group-r`) or `name=value`.
fn resolve_policy_arg(args: &Args, spec_str: &str) -> Result<Box<dyn Policy>> {
    if spec_str.contains('=') {
        return policy::resolve(spec_str);
    }
    let entry = policy::entry(spec_str.trim())
        .ok_or_else(|| policy::unknown_policy(spec_str.trim()))?;
    build_entry_policy(args, entry)
}

fn cmd_allocate(args: &Args) -> Result<()> {
    let spec = load_spec(args)?;
    let model = parse_model(args)?;
    let k = spec.k as f64;
    println!(
        "cluster: G={} N={} k={}",
        spec.num_groups(),
        spec.total_workers(),
        spec.k
    );
    for (j, g) in spec.groups.iter().enumerate() {
        println!("  group {j}: N_j={} mu={} alpha={}", g.n, g.mu, g.alpha);
    }
    println!();
    // `--analytic` adds the CLT expected-latency estimate (no Monte Carlo).
    let analytic = args.switch("analytic");
    println!(
        "{:<22} {:>10} {:>8}  {:>12}{}  loads l_(j)",
        "policy",
        "n",
        "rate",
        "bound",
        if analytic { "   E[T] (CLT)" } else { "" }
    );
    for entry in policy::entries() {
        // Degrade per row: a bad parameter (or an unsolvable policy) costs
        // one line, not the whole table.
        let p = match build_entry_policy(args, entry) {
            Ok(p) => p,
            Err(e) => {
                println!("{:<22} {e}", entry.name);
                continue;
            }
        };
        match p.allocate(model, &spec) {
            Ok(a) => {
                let loads_s: Vec<String> =
                    a.loads.iter().map(|l| format!("{l:.2}")).collect();
                let clt = if analytic {
                    match hetcoded::model::clt_expected_latency(&spec, &a.loads, model) {
                        Ok(t) => format!("   {t:>10.4e}"),
                        Err(_) => "            -".into(),
                    }
                } else {
                    String::new()
                };
                println!(
                    "{:<22} {:>10.1} {:>8.4}  {:>12}{}  [{}]",
                    p.name(),
                    a.n,
                    k / a.n,
                    a.latency_bound.map_or("-".into(), |b| format!("{b:.4e}")),
                    clt,
                    loads_s.join(", ")
                );
            }
            Err(e) => println!("{:<22} {e}", p.name()),
        }
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let spec = load_spec(args)?;
    let model = parse_model(args)?;
    let p = resolve_policy_arg(args, args.flag("scheme").unwrap_or("proposed"))?;
    let cfg = SimConfig {
        samples: args.get::<usize>("samples", 10_000)?,
        seed: args.get::<u64>("seed", 2019)?,
        threads: args.get::<usize>("threads", 0)?,
    };
    let r = simulate_policy(&spec, &*p, model, &cfg)?;
    println!(
        "scheme={} model={model:?} N={} k={}",
        r.scheme,
        spec.total_workers(),
        spec.k
    );
    println!(
        "E[T] = {:.6e} ± {:.2e}   rate k/n = {:.4}   n = {:.1}",
        r.mean, r.stderr, r.rate, r.n
    );
    if let Some(b) = r.bound {
        println!(
            "analytic bound = {:.6e}   (gap {:+.2}%)",
            b,
            100.0 * (r.mean - b) / b
        );
    }
    Ok(())
}

fn cmd_workload(args: &Args) -> Result<()> {
    let model = parse_model(args)?;
    // Default cluster: the paper's two-group Fig. 8 cluster — the smallest
    // genuinely heterogeneous benchmark in the evaluation.
    let spec = if args.flag("config").is_none() && args.flag("paper").is_none() {
        let k = args.get::<usize>("k", 10_000)?;
        let q = args.get::<f64>("q", 1.0)?;
        ClusterSpec::paper_two_group(k).scaled_mu(q)
    } else {
        load_spec(args)?
    };
    let jobs = args.get::<usize>("jobs", 2_000)?;
    let servers = args.get::<usize>("servers", 1)?;
    let seed = args.get::<u64>("seed", 2019)?;
    let calib = args.get::<usize>("calib-samples", 2_000)?;
    if let Some(drift) = args.flag("drift") {
        return cmd_workload_drift(args, &spec, model, drift, jobs, seed, calib);
    }
    // Any sharding/tenancy/SLO flag switches to the admission-front-end
    // simulation (per-shard DRR queues, work-stealing drainers, adaptive
    // batching) instead of the single-queue table.
    if args.flag("shards").is_some()
        || args.flag("tenants").is_some()
        || args.flag("drainers").is_some()
        || args.flag("slo").is_some()
    {
        return cmd_workload_admission(args, &spec, model, jobs, seed, calib);
    }
    let policy_specs = args.get_list::<String>(
        "policies",
        &["proposed".to_string(), "uniform-nstar".to_string()],
    )?;
    if policy_specs.is_empty() {
        return Err(Error::InvalidSpec("--policies list is empty".into()));
    }
    let rhos = args.get_list::<f64>("rho", &[0.3, 0.6, 0.9])?;
    let abs_rates = match args.flag("rates") {
        Some(_) => Some(args.get_list::<f64>("rates", &[])?),
        None => None,
    };
    if abs_rates.as_ref().map_or(rhos.is_empty(), Vec::is_empty) {
        return Err(Error::InvalidSpec("--rho/--rates list is empty".into()));
    }
    let arrival_kind = args.flag("arrivals").unwrap_or("poisson").to_string();

    // Calibrate each policy's mean service time once; E[S] converts
    // offered-load fractions into absolute rates and sizes burst windows.
    let mut calibrated: Vec<(Box<dyn Policy>, f64)> =
        Vec::with_capacity(policy_specs.len());
    for pname in &policy_specs {
        let p = resolve_policy_arg(args, pname)?;
        let (_, mut sampler) = service_sampler_for(&spec, &*p, model)?;
        let es = mean_service(&mut sampler, calib, seed ^ 0xCA11B);
        calibrated.push((p, es));
    }
    // ON/OFF burst windows must be identical across policies for the table
    // to be a fair same-traffic comparison, so the default (~20 service
    // times) derives from the first policy only.
    let (burst_on, burst_off) = {
        let es_ref = calibrated[0].1;
        (
            args.get::<f64>("burst-on", 20.0 * es_ref)?,
            args.get::<f64>("burst-off", 20.0 * es_ref)?,
        )
    };

    println!(
        "cluster: G={} N={} k={}  model {model:?}  arrivals {arrival_kind}  \
         jobs {jobs}  servers {servers}  seed {seed}",
        spec.num_groups(),
        spec.total_workers(),
        spec.k,
    );
    println!(
        "{:<22} {:>9} {:>6}  {:>9} {:>6} {:>10} {:>10} {:>10} {:>10} {:>7}",
        "policy", "rate", "rho", "thruput", "util", "E[S]", "p50", "p95",
        "p99", "maxQ"
    );
    for (p, es) in &calibrated {
        let es = *es;
        let rates: Vec<f64> = match &abs_rates {
            Some(rs) => rs.clone(),
            None => rhos.iter().map(|r| r / es).collect(),
        };
        for &rate in &rates {
            let arrivals = match arrival_kind.as_str() {
                "deterministic" => ArrivalProcess::Deterministic { rate },
                "poisson" => ArrivalProcess::Poisson { rate },
                "onoff" => ArrivalProcess::OnOff {
                    // The ON rate is boosted so the long-run mean rate
                    // stays `rate`.
                    rate_on: rate * (burst_on + burst_off) / burst_on,
                    mean_on: burst_on,
                    mean_off: burst_off,
                },
                other => {
                    return Err(Error::InvalidSpec(format!(
                        "unknown arrival process `{other}`"
                    )))
                }
            };
            let wcfg = WorkloadConfig { arrivals, jobs, servers, seed };
            let rep = run_workload_policy(&spec, &**p, model, &wcfg)?;
            println!(
                "{:<22} {:>9.4} {:>6.2}  {:>9.4} {:>6.3} {:>10.4e} {:>10.4e} \
                 {:>10.4e} {:>10.4e} {:>7}",
                rep.policy,
                rate,
                rate * es,
                rep.throughput,
                rep.utilization,
                rep.mean_service,
                rep.sojourn_percentile(50.0),
                rep.sojourn_percentile(95.0),
                rep.sojourn_percentile(99.0),
                rep.max_in_system,
            );
        }
    }
    Ok(())
}

/// The sharded admission front end at model-time scale: per-policy
/// saturation rows through [`run_admission`] — throughput, sojourn
/// tails, peak queue depth, steals, and the batch limit the controller
/// settled on. `--rho` here is offered load per *drainer* at single-job
/// batches (`rate = rho * drainers / E[S]`), so rho > 1 exercises the
/// regime only amortized batching can absorb.
fn cmd_workload_admission(
    args: &Args,
    spec: &ClusterSpec,
    model: LatencyModel,
    jobs: usize,
    seed: u64,
    calib: usize,
) -> Result<()> {
    let shards = args.get::<usize>("shards", 4)?;
    let tenants_n = args.get::<usize>("tenants", shards)?;
    let drainers = args.get::<usize>("drainers", shards)?;
    let steal = args.get::<bool>("steal", true)?;
    let amortize = args.get::<f64>("amortize", 0.75)?;
    let max_batch = args.get::<usize>("max-batch", 16)?;
    // --slo S: adaptive batch sizing against a model-time p99 target (the
    // limit may grow past --max-batch, up to max(64, --max-batch)).
    let batch = match args.flag("slo") {
        Some(_) => BatchPolicy::Adaptive(SloConfig {
            target_p99: args.require::<f64>("slo")?,
            max_batch: max_batch.max(64),
            ..Default::default()
        }),
        None => BatchPolicy::Fixed(max_batch),
    };
    let policy_specs = args.get_list::<String>(
        "policies",
        &["proposed".to_string(), "uniform-nstar".to_string()],
    )?;
    if policy_specs.is_empty() {
        return Err(Error::InvalidSpec("--policies list is empty".into()));
    }
    let rhos = args.get_list::<f64>("rho", &[0.5, 0.9, 1.5])?;
    let abs_rates = match args.flag("rates") {
        Some(_) => Some(args.get_list::<f64>("rates", &[])?),
        None => None,
    };
    if abs_rates.as_ref().map_or(rhos.is_empty(), Vec::is_empty) {
        return Err(Error::InvalidSpec("--rho/--rates list is empty".into()));
    }
    let arrival_kind = args.flag("arrivals").unwrap_or("poisson").to_string();
    let batch_desc = match batch {
        BatchPolicy::Fixed(b) => format!("fixed({b})"),
        BatchPolicy::Adaptive(s) => format!("slo(p99<={})", s.target_p99),
    };
    println!(
        "admission front end: G={} N={} k={}  model {model:?}  arrivals \
         {arrival_kind}  jobs {jobs}  shards {shards}  drainers {drainers}  \
         tenants {tenants_n}  steal {steal}  batch {batch_desc}  amortize \
         {amortize}  seed {seed}",
        spec.num_groups(),
        spec.total_workers(),
        spec.k,
    );
    println!(
        "{:<22} {:>9} {:>6}  {:>9} {:>10} {:>10} {:>7} {:>7} {:>7} {:>6}",
        "policy", "rate", "rho", "thruput", "p50", "p99", "maxQ", "steals",
        "meanB", "limit"
    );
    for pname in &policy_specs {
        let p = resolve_policy_arg(args, pname)?;
        let (_, mut sampler) = service_sampler_for(spec, &*p, model)?;
        let es = mean_service(&mut sampler, calib, seed ^ 0xCA11B);
        let rates: Vec<f64> = match &abs_rates {
            Some(rs) => rs.clone(),
            None => rhos.iter().map(|r| r * drainers as f64 / es).collect(),
        };
        for &rate in &rates {
            let per_tenant = rate / tenants_n as f64;
            let arrivals = match arrival_kind.as_str() {
                "deterministic" => {
                    ArrivalProcess::Deterministic { rate: per_tenant }
                }
                "poisson" => ArrivalProcess::Poisson { rate: per_tenant },
                "onoff" => {
                    let burst_on = args.get::<f64>("burst-on", 20.0 * es)?;
                    let burst_off = args.get::<f64>("burst-off", 20.0 * es)?;
                    ArrivalProcess::OnOff {
                        // Boost the ON rate so each tenant's long-run mean
                        // rate stays `per_tenant`.
                        rate_on: per_tenant * (burst_on + burst_off) / burst_on,
                        mean_on: burst_on,
                        mean_off: burst_off,
                    }
                }
                other => {
                    return Err(Error::InvalidSpec(format!(
                        "unknown arrival process `{other}`"
                    )))
                }
            };
            let cfg = AdmissionConfig {
                tenants: (0..tenants_n)
                    .map(|_| TenantSpec { arrivals, weight: 1.0 })
                    .collect(),
                jobs,
                shards,
                drainers,
                steal,
                batch,
                amortize,
                seed,
            };
            let rep = run_admission(spec, &*p, model, &cfg)?;
            println!(
                "{:<22} {:>9.4} {:>6.2}  {:>9.4} {:>10.4e} {:>10.4e} {:>7} \
                 {:>7} {:>7.2} {:>6}",
                rep.policy,
                rate,
                rate * es / drainers as f64,
                rep.throughput,
                rep.sojourn_percentile(50.0),
                rep.sojourn_percentile(99.0),
                rep.max_queue_depth,
                rep.steals,
                rep.mean_batch,
                rep.final_batch_limit,
            );
        }
    }
    Ok(())
}

/// The failure/drift experiment: the proposed allocation served static
/// vs. adaptive through a drifting cluster, post-drift tails compared.
fn cmd_workload_drift(
    args: &Args,
    spec: &ClusterSpec,
    model: LatencyModel,
    drift: &str,
    jobs: usize,
    seed: u64,
    calib: usize,
) -> Result<()> {
    let schedule = DriftSchedule::parse(drift)?;
    if schedule.is_empty() {
        return Err(Error::InvalidSpec("--drift parsed to no events".into()));
    }
    if args.flag("policies").is_some() {
        eprintln!(
            "note: --policies is ignored with --drift (the experiment \
             compares static vs adaptive serving of the proposed \
             allocation)"
        );
    }
    if args.get::<usize>("servers", 1)? != 1 {
        eprintln!(
            "note: --servers is ignored with --drift (the drift experiment \
             models the paper's single-slot cluster)"
        );
    }
    // Calibrate the proposed policy's pre-drift E[S] once: it converts a
    // --rho fraction into a rate and sizes default ON/OFF burst windows.
    let es_pre = {
        let (_, mut sampler) = service_sampler(spec, Scheme::Proposed, model)?;
        mean_service(&mut sampler, calib, seed ^ 0xCA11B)
    };
    // One rate: --rates first entry, else --rho first entry (default 0.7)
    // times the pre-drift saturation rate.
    let rate = if let Some(rs) = args.flag("rates") {
        args.get_list::<f64>("rates", &[])?
            .first()
            .copied()
            .ok_or_else(|| Error::InvalidSpec(format!("empty --rates `{rs}`")))?
    } else {
        let rho = args.get_list::<f64>("rho", &[0.7])?;
        rho.first().copied().unwrap_or(0.7) / es_pre
    };
    let arrivals = match args.flag("arrivals").unwrap_or("poisson") {
        "deterministic" => ArrivalProcess::Deterministic { rate },
        "poisson" => ArrivalProcess::Poisson { rate },
        "onoff" => {
            let burst_on = args.get::<f64>("burst-on", 20.0 * es_pre)?;
            let burst_off = args.get::<f64>("burst-off", 20.0 * es_pre)?;
            ArrivalProcess::OnOff {
                // Boost the ON rate so the long-run mean rate stays `rate`.
                rate_on: rate * (burst_on + burst_off) / burst_on,
                mean_on: burst_on,
                mean_off: burst_off,
            }
        }
        other => {
            return Err(Error::InvalidSpec(format!(
                "unknown arrival process `{other}`"
            )))
        }
    };
    let est = EstimatorConfig {
        window: args.get::<usize>("drift-window", 50)?,
        min_obs: args.get::<usize>("drift-min-obs", 100)?,
        threshold: args.get::<f64>("drift-threshold", 0.30)?,
        check_every: args.get::<usize>("drift-check-every", 10)?,
    };
    let cfg = DriftWorkloadConfig { arrivals, jobs, seed };
    let last_event = schedule.events().last().map(|e| e.at).unwrap_or(0.0);
    println!(
        "drift experiment: G={} N={} k={}  model {model:?}  arrivals {}  \
         rate {rate:.4}  jobs {jobs}  seed {seed}  events {}",
        spec.num_groups(),
        spec.total_workers(),
        spec.k,
        cfg.arrivals.name(),
        schedule.events().len(),
    );
    println!(
        "{:<10} {:>10} {:>10} {:>10} | {:>12} {:>12} {:>9}",
        "policy", "p50", "p95", "p99", "post p99", "post mean", "reallocs"
    );
    for policy in [
        AdaptPolicy::Static,
        AdaptPolicy::Adaptive(est),
    ] {
        match run_workload_drift(spec, model, &cfg, &schedule, &policy) {
            Ok(rep) => {
                // "post" = jobs arriving a settle margin past the last
                // scripted event.
                let t0 = last_event * 1.2;
                let post = rep.sojourn_after(t0);
                println!(
                    "{:<10} {:>10.4e} {:>10.4e} {:>10.4e} | {:>12.4e} {:>12.4e} {:>9}",
                    rep.policy,
                    rep.sojourn.percentile(50.0),
                    rep.sojourn.percentile(95.0),
                    rep.sojourn.percentile(99.0),
                    if post.count() > 0 { post.percentile(99.0) } else { f64::NAN },
                    if post.count() > 0 { post.mean() } else { f64::NAN },
                    rep.reallocations.len(),
                );
                for r in &rep.reallocations {
                    let mus: Vec<String> = r
                        .assumed
                        .groups
                        .iter()
                        .map(|g| format!("{:.2}", g.mu))
                        .collect();
                    println!(
                        "    realloc @ t={:.2} (job {}): mu_hat=[{}]",
                        r.at,
                        r.job,
                        mus.join(", ")
                    );
                }
            }
            Err(e) => println!(
                "{:<10} failed: {e}",
                policy.name()
            ),
        }
    }
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let mut opts = if args.switch("quick") {
        FigureOpts::quick()
    } else {
        FigureOpts::default()
    };
    opts.samples = args.get::<usize>("samples", opts.samples)?;
    opts.points = args.get::<usize>("points", opts.points)?;
    opts.seed = args.get::<u64>("seed", opts.seed)?;
    opts.threads = args.get::<usize>("threads", opts.threads)?;
    let out_dir =
        std::path::PathBuf::from(args.flag("out").unwrap_or("results").to_string());
    let figs: Vec<u8> = if args.switch("all") || args.flag("fig").is_none() {
        figures::ALL_FIGURES.to_vec()
    } else {
        vec![args.require::<u8>("fig")?]
    };
    for f in figs {
        let t0 = hetcoded::runtime::wall_now();
        let fig = figures::generate(f, &opts)?;
        let path = fig.write_csv(&out_dir)?;
        println!("{}", fig.ascii_plot());
        println!(
            "wrote {} ({} series, {:.1}s)\n",
            path.display(),
            fig.series.len(),
            t0.elapsed().as_secs_f64()
        );
    }
    Ok(())
}

/// Bring up the PJRT service for the live `run` path.
#[cfg(feature = "xla")]
fn xla_compute(d: usize) -> Result<Arc<dyn Compute>> {
    let svc = hetcoded::coordinator::XlaService::new(std::path::PathBuf::from(
        hetcoded::runtime::DEFAULT_ARTIFACT_DIR,
    ))?;
    if svc.cols() != d {
        return Err(Error::Runtime(format!(
            "artifacts compiled for d={}, got --d {d}",
            svc.cols()
        )));
    }
    Ok(Arc::new(svc))
}

#[cfg(not(feature = "xla"))]
fn xla_compute(_d: usize) -> Result<Arc<dyn Compute>> {
    Err(Error::Runtime(
        "this binary was built without the `xla` feature; rebuild with \
         `cargo build --features xla` (needs the native xla_extension \
         library)"
            .into(),
    ))
}

fn cmd_run(args: &Args) -> Result<()> {
    let k = args.get::<usize>("k", 256)?;
    let d = args.get::<usize>("d", 256)?;
    let requests = args.get::<usize>("requests", 8)?;
    let seed = args.get::<u64>("seed", 7)?;
    let spec = if let Some(path) = args.flag("config") {
        ClusterSpec::from_toml_file(std::path::Path::new(path))?
    } else {
        // Default live cluster: 3 heterogeneous groups, 24 workers.
        ClusterSpec::new(
            vec![
                hetcoded::model::Group { n: 6, mu: 8.0, alpha: 1.0 },
                hetcoded::model::Group { n: 8, mu: 4.0, alpha: 1.0 },
                hetcoded::model::Group { n: 10, mu: 1.0, alpha: 1.0 },
            ],
            k,
        )?
    };
    let model = parse_model(args)?;
    // Any registered policy can drive the live path (default: proposed).
    // `run` accepts only the `name=value` parameter form: its own `--rate`
    // flag is the *arrivals* rate, so the registry's per-policy flags
    // (`--rate`, `--group-r`) must not be read here.
    let live_policy = policy::resolve(args.flag("policy").unwrap_or("proposed"))?;
    let alloc = live_policy.allocate(model, &spec)?;
    let mut cfg = JobConfig {
        model,
        time_scale: args.get::<f64>("time-scale", 0.02)?,
        seed,
        encode_threads: args.get::<usize>("encode-threads", 0)?,
        decode_cache: args
            .get::<usize>("decode-cache", hetcoded::coding::DEFAULT_FACTOR_CACHE)?,
        ..Default::default()
    };
    if let Some(code_name) = args.flag("code") {
        // Validate through the registry now so a typo fails before any
        // data is generated, with the known names listed.
        code::resolve(code_name)?;
        cfg.code = Some(code_name.to_string());
    }
    if let Some(dead) = args.flag("dead") {
        cfg.dead_workers = dead
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.parse::<usize>()
                    .map_err(|_| Error::InvalidSpec(format!("bad --dead entry `{s}`")))
            })
            .collect::<Result<Vec<_>>>()?;
    }
    let mut rng = Rng::new(seed);
    let a = Matrix::from_fn(spec.k, d, |_, _| rng.normal());
    let reqs: Vec<Vec<f64>> = (0..requests)
        .map(|_| (0..d).map(|_| rng.normal()).collect())
        .collect();

    let backend_name = args.flag("backend").unwrap_or("native");
    let compute: Arc<dyn Compute> = match backend_name {
        "native" => Arc::new(NativeCompute),
        "xla" => xla_compute(d)?,
        other => return Err(Error::InvalidSpec(format!("unknown backend `{other}`"))),
    };

    let mode_name = args.flag("mode").unwrap_or("seq").to_string();
    let scenario = FailureScenario::parse_compound(
        args.flag("failures"),
        args.flag("drift"),
        args.flag("loss"),
        args.flag("stall"),
        args.flag("flap"),
        args.flag("worker-loss"),
    )?;
    let scenario_events = scenario.events().len();
    let adaptive = args.switch("adaptive");
    // The recovery layer attaches when any of its knobs is given, or when
    // the scenario scripts stalls (which hang the collection without it).
    let recovery_knobs = [
        "hedge",
        "hedge-quantile",
        "hedge-floor",
        "max-waves",
        "backoff",
        "batch-deadline",
        "quarantine-after",
        "degrade",
    ];
    // A bare trailing `--hedge` parses as a switch, not a flag.
    let use_recovery = scenario.has_stall()
        || args.switch("hedge")
        || recovery_knobs.into_iter().any(|f| args.flag(f).is_some());
    let recovery = if use_recovery {
        let d = RecoveryConfig::default();
        Some(RecoveryConfig {
            hedge: args.get::<bool>("hedge", true)?,
            hedge_quantile: args.get::<f64>("hedge-quantile", d.hedge_quantile)?,
            deadline_floor: args.get::<f64>("hedge-floor", d.deadline_floor)?,
            max_waves: args.get::<u32>("max-waves", d.max_waves)?,
            backoff: args.get::<f64>("backoff", d.backoff)?,
            batch_deadline_factor: args
                .get::<f64>("batch-deadline", d.batch_deadline_factor)?,
            quarantine_after: args
                .get::<u32>("quarantine-after", d.quarantine_after)?,
            degrade: match args.flag("degrade").unwrap_or("partial") {
                "partial" => DegradePolicy::Partial,
                "fail" => DegradePolicy::Fail,
                other => {
                    return Err(Error::InvalidSpec(format!(
                        "unknown --degrade policy `{other}` (partial|fail)"
                    )))
                }
            },
        })
    } else {
        None
    };
    if (!scenario.is_empty() || adaptive || recovery.is_some())
        && mode_name != "arrivals"
    {
        return Err(Error::InvalidSpec(
            "--failures/--drift/--loss/--stall/--flap/--worker-loss/\
             --adaptive/--hedge* need --mode arrivals (the prepared serving \
             stream)"
                .into(),
        ));
    }
    // Admission front end: any of --shards/--tenants/--slo attaches the
    // sharded multi-tenant drain (with --slo: SLO-adaptive batch sizing).
    let shards = args.get::<usize>("shards", 1)?;
    let tenants = args.get::<usize>("tenants", 1)?;
    let slo = match args.flag("slo") {
        Some(_) => Some(args.require::<f64>("slo")?),
        None => None,
    };
    let use_front = shards != 1 || tenants != 1 || slo.is_some();
    if use_front && mode_name != "arrivals" {
        return Err(Error::InvalidSpec(
            "--shards/--tenants/--slo (the admission front end) need \
             --mode arrivals"
                .into(),
        ));
    }
    if use_front && adaptive {
        return Err(Error::InvalidSpec(
            "--shards/--tenants/--slo and --adaptive are mutually \
             exclusive (both own the drain loop)"
                .into(),
        ));
    }
    let mode = match mode_name.as_str() {
        "seq" => Mode::Sequential,
        "pipelined" => Mode::Pipelined,
        "batched" => Mode::Batched,
        "arrivals" => Mode::PoissonArrivals {
            rate: args.get::<f64>("rate", 50.0)?,
            max_batch: args.get::<usize>("max-batch", 8)?,
        },
        other => {
            return Err(Error::InvalidSpec(format!("unknown --mode `{other}`")))
        }
    };
    println!(
        "live coded matvec: N={} groups={} k={k} d={d} backend={backend_name} \
         mode={mode_name} policy={} code={} n={} (rate {:.3})",
        spec.total_workers(),
        spec.num_groups(),
        live_policy.name(),
        cfg.resolve_code()?.name(),
        alloc.integer_n(&spec),
        spec.k as f64 / alloc.integer_n(&spec) as f64,
    );
    // Attach the *policy object* (not the pre-solved allocation): adaptive
    // re-solves must go through this policy's `allocate_capped`, not the
    // proposed fallback. The header above used the same deterministic
    // solve, so nothing diverges.
    let mut builder = Session::builder(&spec)
        .policy(live_policy)
        .data(a)
        .requests(reqs)
        .config(cfg)
        .compute(compute)
        .scenario(scenario)
        .mode(mode);
    if adaptive {
        builder = builder.adaptive(AdaptiveServeConfig::default());
    }
    if let Some(rcfg) = recovery {
        builder = builder.recovery(rcfg);
    }
    if use_front {
        let cap = args.get::<usize>("max-batch", 8)?;
        builder = builder.front_end(FrontEndConfig {
            shards,
            tenants,
            weights: Vec::new(),
            // --slo S: wall-clock p99 sojourn target in seconds; the
            // controller may grow the limit past --max-batch, up to
            // max(64, --max-batch). Without --slo the mode's fixed
            // --max-batch applies.
            batch: slo.map(|target| {
                BatchPolicy::Adaptive(SloConfig {
                    target_p99: target,
                    max_batch: cap.max(64),
                    ..Default::default()
                })
            }),
        });
    }
    let outcome = builder.build()?.serve()?;
    if let Some(front) = &outcome.front_end {
        println!(
            "front end: {} shards, {} tenants, {} batches (mean {:.2}, max \
             {}), cross-shard {}, final batch limit {} ({} grows / {} \
             shrinks), peak queue {}",
            front.shards,
            front.tenants,
            front.batches,
            front.mean_batch,
            front.max_batch_used,
            front.cross_shard_batches,
            front.final_batch_limit,
            front.batch_grows,
            front.batch_shrinks,
            front.max_queue_depth,
        );
        println!("front end steals (non-home-shard drains): {}", front.steals);
    }
    if let Some(rec) = &outcome.recovery {
        let c = &rec.counters;
        println!(
            "recovery: hedges issued {}  hedge wins {}  wasted rows {}  \
             quarantines {}  degraded batches {}",
            c.hedges_issued,
            c.hedge_wins,
            c.wasted_rows,
            c.quarantines,
            c.degraded_batches,
        );
        for d in &rec.degraded {
            println!(
                "  degraded batch {}: {} rows short of k (error bound \
                 {:.3}) after {:.1} ms",
                d.batch,
                d.deficit,
                d.error_bound,
                d.elapsed.as_secs_f64() * 1e3,
            );
        }
    }
    if adaptive || scenario_events > 0 {
        println!(
            "scenario events {scenario_events}  reallocations {}  \
             post-setup encodes {}  suspected dead {:?}",
            outcome.reallocations,
            outcome.post_setup_encodes,
            outcome.suspected_dead,
        );
    }
    if let Some(rl) = &outcome.rateless {
        println!(
            "rateless: {} rows received / {} issued over {} batches \
             (overhead {:.3}x k, {} extend rounds, {} rows re-encoded)",
            rl.rows_received,
            rl.rows_issued,
            rl.batches,
            rl.overhead,
            rl.extend_rounds,
            rl.re_encoded_rows,
        );
    }
    println!("{}", outcome.recorder.report());
    println!("worst decode error vs direct A·x: {:.3e}", outcome.worst_error);
    match outcome.makespan {
        Some(makespan) => println!(
            "makespan {:.1} ms, encode passes {}, rechunks {}, \
             decode cache {}h/{}m, steady-state allocs {}",
            makespan.as_secs_f64() * 1e3,
            outcome.encodes,
            outcome.rechunks,
            outcome.decode_cache_hits,
            outcome.decode_cache_misses,
            outcome.steady_allocs,
        ),
        None => println!("encode passes {}", outcome.encodes),
    }
    for (i, j) in outcome.jobs.iter().enumerate() {
        println!(
            "  req {i}: wall {:.1}ms model {:.4} workers {} rows {}",
            j.wall_latency.as_secs_f64() * 1e3,
            j.model_latency.unwrap_or(f64::NAN),
            j.workers_used,
            j.rows_collected
        );
    }
    Ok(())
}
