//! Live master/worker coordinator.
//!
//! This is the system the paper *assumes* (Fig. 1): a master holding the
//! input vector `x` dispatches coded subtasks `Ã_i` to `N` workers; worker
//! `i` computes `Ã_i·x` (through the AOT-compiled XLA executable or the
//! native fallback) and replies; the master decodes `A·x` as soon as the
//! aggregated rows reach `k`.
//!
//! Heterogeneous straggling is produced by **injecting** per-worker delays
//! sampled from the paper's shifted-exponential models — the same stochastic
//! process the analysis studies, scaled to wall-clock via
//! [`JobConfig::time_scale`]. Dead workers (permanent failures) are
//! supported; the MDS code tolerates them as long as the surviving load
//! covers `k`.
//!
//! Serving loops go through the [`prepared`] fast path: a [`PreparedJob`]
//! owns the generator, encoded chunks, and factorization-cached decoder,
//! so steady-state batches pay only straggle + collect + solve.
//!
//! Long-lived streams face failures and drift; the [`failures`] module
//! scripts them (deaths, machine slowdowns, group drift) and
//! [`adaptive`] layers the estimator-driven re-allocation loop on top —
//! re-solving the paper's allocation on the estimated surviving cluster
//! and re-slicing the already-encoded rows ([`PreparedJob::rechunk`])
//! with zero additional encode work.

pub mod adaptive;
pub mod compute;
pub mod failures;
pub mod master;
pub mod metrics;
pub mod prepared;
pub mod straggler;

pub use adaptive::{
    serve_arrivals_adaptive, AdaptiveServeConfig, AdaptiveServeReport,
};
pub use compute::{Compute, NativeCompute};
#[cfg(feature = "xla")]
pub use compute::XlaService;
pub use failures::{FailureEvent, FailureKind, FailureScenario, ScenarioState};
pub use master::{
    derive_stream_seed, run_job, run_job_batched, serve_arrivals,
    serve_requests, serve_requests_pipelined, JobConfig, JobReport,
    ServeReport,
};
pub use metrics::LatencyRecorder;
pub use prepared::{PreparedJob, WorkerObservation};
pub use straggler::StragglerInjector;
