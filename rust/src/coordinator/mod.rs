//! Live master/worker coordinator.
//!
//! This is the system the paper *assumes* (Fig. 1): a master holding the
//! input vector `x` dispatches coded subtasks `Ã_i` to `N` workers; worker
//! `i` computes `Ã_i·x` (through the AOT-compiled XLA executable or the
//! native fallback) and replies; the master decodes `A·x` as soon as the
//! aggregated rows reach `k`.
//!
//! Heterogeneous straggling is produced by **injecting** per-worker delays
//! sampled from the paper's shifted-exponential models — the same stochastic
//! process the analysis studies, scaled to wall-clock via
//! [`JobConfig::time_scale`]. Dead workers (permanent failures) are
//! supported; the MDS code tolerates them as long as the surviving load
//! covers `k`.
//!
//! The erasure code itself is pluggable: every setup/encode/decode routes
//! through a [`crate::coding::Code`] resolved once per job from the code
//! registry ([`JobConfig::code`] / [`SessionBuilder::code`] / the CLI
//! `--code` flag), with the generator-kind default reproducing the
//! pre-registry behaviour bit for bit. Everything downstream — allocation,
//! chunking, straggle handling, [`PreparedJob::rechunk`] — is
//! code-agnostic.
//!
//! Serving loops go through the [`prepared`] fast path: a [`PreparedJob`]
//! owns the generator, encoded chunks, and factorization-cached decoder,
//! so steady-state batches pay only straggle + collect + solve — with
//! every parallel kernel on a persistent [`crate::runtime::pool::WorkPool`]
//! (one per session, shareable via [`SessionBuilder::pool`]) and every
//! big per-batch buffer reused ([`ServeOutcome`]'s `steady_allocs`
//! measures that steady-state batches allocate nothing).
//!
//! Arrivals-mode sessions can attach the sharded admission front end
//! ([`frontend`], [`SessionBuilder::front_end`]): tenant-keyed per-shard
//! deficit-round-robin queues, a work-conserving rotating drain, and
//! optionally SLO-adaptive batch sizing — with the degenerate
//! single-shard configuration pinned bit-identical to the plain arrivals
//! drain. Its model-time twin (work-stealing drainers, ≥1M-arrival scale
//! proofs) is [`crate::workload::admission`].
//!
//! Long-lived streams face failures and drift; the [`failures`] module
//! scripts them (deaths, machine slowdowns, group drift, and lossy
//! links — per-packet Bernoulli drops and burst windows) and
//! [`adaptive`] layers the estimator-driven re-allocation loop on top —
//! re-solving the paper's allocation on the estimated surviving cluster
//! and re-slicing the already-encoded rows ([`PreparedJob::rechunk`])
//! with zero additional encode work.
//!
//! Adaptation reacts *between* batches; the [`recovery`] layer
//! ([`SessionBuilder::recovery`]) reacts *inside* one: per-worker hedge
//! deadlines from the analytic quantile law, deadline-blown row ranges
//! re-issued to the fastest helpers with capped exponential backoff
//! (first completion wins, deterministically), a quarantine ring with
//! canary probes for repeat offenders, and a typed degraded outcome —
//! never a hang — when the batch deadline expires short of `k`. This is
//! what lets [`failures`] script outright stalls ([`FailureKind::StallWorker`],
//! [`FailureKind::FlappyWorker`]) rather than just slowdowns.
//!
//! With the rateless fountain (`--code rateless-rlc`) serving switches
//! to the **streaming** collection loop ([`rateless`],
//! [`PreparedJob::run_batch_streamed`]): solicitation rounds of fresh
//! coded rows until any `k` survive the links, with the measured
//! reception overhead surfaced as [`ServeOutcome::rateless`]. The row
//! horizon grows in place when loss or elastic scale-out
//! ([`PreparedJob::extend_rechunk`]) wants more rows than exist — fresh
//! indices only, so the encoder's re-encode counter stays 0.
//!
//! **Entry point**: the [`Session`] facade. Policy × mode × scenario ×
//! adaptivity are orthogonal builder knobs, and every serve returns one
//! [`ServeOutcome`]:
//!
//! ```no_run
//! # use hetcoded::allocation::policy;
//! # use hetcoded::coding::Matrix;
//! # use hetcoded::coordinator::{Mode, Session};
//! # use hetcoded::model::ClusterSpec;
//! # let spec = ClusterSpec::paper_two_group(64);
//! # let a = Matrix::from_fn(64, 8, |_, _| 0.5);
//! # let requests: Vec<Vec<f64>> = vec![vec![0.5; 8]; 4];
//! let outcome = Session::builder(&spec)
//!     .policy(policy::resolve("proposed")?)
//!     .data(a)
//!     .requests(requests)
//!     .mode(Mode::PoissonArrivals { rate: 50.0, max_batch: 8 })
//!     .build()?
//!     .serve()?;
//! # Ok::<(), hetcoded::Error>(())
//! ```
//!
//! The six legacy free functions (`run_job`, `run_job_batched`,
//! `serve_requests`, `serve_requests_pipelined`, `serve_arrivals`,
//! `serve_arrivals_adaptive`) are `#[deprecated]` shims over `Session`,
//! bit-identical under fixed seeds (`rust/tests/session_parity.rs`).

#![forbid(unsafe_code)]

pub mod adaptive;
pub mod compute;
pub mod failures;
pub mod frontend;
pub mod master;
pub mod metrics;
pub mod prepared;
pub mod rateless;
pub mod recovery;
pub mod session;
pub mod straggler;

#[allow(deprecated)]
pub use adaptive::serve_arrivals_adaptive;
pub use adaptive::{AdaptiveServeConfig, AdaptiveServeReport};
pub use compute::{Compute, NativeCompute};
#[cfg(feature = "xla")]
pub use compute::XlaService;
pub use failures::{FailureEvent, FailureKind, FailureScenario, ScenarioState};
pub use frontend::{FrontEndConfig, FrontEndReport};
#[allow(deprecated)]
pub use master::{
    run_job, run_job_batched, serve_arrivals, serve_requests,
    serve_requests_pipelined,
};
pub use master::{derive_stream_seed, JobConfig, JobReport, ServeReport};
pub use metrics::LatencyRecorder;
pub use prepared::{PreparedJob, WorkerObservation};
pub use rateless::{RatelessBatchStats, RatelessSummary, RATELESS_PACKET_ROWS};
pub use recovery::{
    DegradePolicy, DegradedBatch, RecoveryConfig, RecoveryCounters,
    RecoveryEngine, RecoveryReport,
};
pub use session::{Mode, ServeOutcome, Session, SessionBuilder};
pub use straggler::StragglerInjector;
