//! The [`Session`] facade: one entry point for every serving shape.
//!
//! Three PRs of growth left the coordinator with six overlapping free
//! functions (`run_job`, `run_job_batched`, `serve_requests`,
//! `serve_requests_pipelined`, `serve_arrivals`,
//! `serve_arrivals_adaptive`), each with its own signature and report
//! type. A `Session` makes the four orthogonal knobs explicit:
//!
//! - **policy × allocation** — a registry-resolved
//!   [`Policy`](crate::allocation::Policy) (solved at
//!   [`SessionBuilder::build`]) or an explicit [`Allocation`];
//! - **mode** ([`Mode`]) — how requests are scheduled onto the cluster
//!   (single / sequential / pipelined / one batch / arrival replay);
//! - **scenario** ([`FailureScenario`]) — scripted deaths, slowdowns, and
//!   drift against batch indices of an arrivals stream;
//! - **adaptivity** ([`AdaptiveServeConfig`]) — the online estimator +
//!   re-allocation loop on top of the same stream.
//!
//! Every serve returns one [`ServeOutcome`] — the superset of the legacy
//! `JobReport` / `ServeReport` / `AdaptiveServeReport` — with the encode,
//! re-chunk, and decode-cache counters always populated. The legacy free
//! functions survive as `#[deprecated]` shims that build a `Session`,
//! proven bit-identical under fixed seeds by `rust/tests/session_parity.rs`.
//!
//! # State machine
//!
//! ```text
//! SessionBuilder --build()--> Session --serve()--> ServeOutcome
//!   .policy(p) | .allocation(a)     |
//!   .data(A) .requests(X)           +-- Single      -> cold path, 1 job
//!   .config(JobConfig)              +-- Sequential  -> cold path per request
//!   .mode(Mode)                     +-- Pipelined   -> cold path, all in flight
//!   .scenario(s) .adaptive(cfg)     +-- Batched     -> PreparedJob, 1 batch
//!   .compute(backend)               +-- Arrivals    -> PreparedJob stream
//!                                        (+ scenario/adaptive loop)
//! ```
//!
//! # Example
//!
//! ```
//! use hetcoded::allocation::policy;
//! use hetcoded::coding::Matrix;
//! use hetcoded::coordinator::{JobConfig, Mode, Session};
//! use hetcoded::math::Rng;
//! use hetcoded::model::{ClusterSpec, Group};
//!
//! let spec = ClusterSpec::new(
//!     vec![Group { n: 4, mu: 8.0, alpha: 1.0 }, Group { n: 6, mu: 2.0, alpha: 1.0 }],
//!     32,
//! )?;
//! let mut rng = Rng::new(7);
//! let a = Matrix::from_fn(32, 4, |_, _| rng.normal());
//! let requests: Vec<Vec<f64>> =
//!     (0..3).map(|_| (0..4).map(|_| rng.normal()).collect()).collect();
//! let outcome = Session::builder(&spec)
//!     .policy(policy::resolve("uniform-rate=0.5")?)
//!     .data(a)
//!     .requests(requests)
//!     .config(JobConfig { time_scale: 0.002, ..Default::default() })
//!     .mode(Mode::Batched)
//!     .build()?
//!     .serve()?;
//! assert_eq!(outcome.jobs.len(), 3);
//! assert!(outcome.worst_error < 1e-8);
//! assert_eq!(outcome.encodes, 1); // one batch = one encode pass
//! # Ok::<(), hetcoded::Error>(())
//! ```

use crate::allocation::{Allocation, Policy};
use crate::coding::Matrix;
use crate::coordinator::adaptive::{
    serve_arrivals_adaptive_impl, AdaptiveServeConfig,
};
use crate::coordinator::frontend::{
    serve_arrivals_front_impl, FrontEndConfig, FrontEndReport,
};
use crate::coordinator::master::{
    derive_stream_seed, fold_worst_error, run_job_impl, JobConfig, JobReport,
    ServeReport,
};
use crate::coordinator::rateless::RatelessSummary;
use crate::coordinator::recovery::{RecoveryConfig, RecoveryReport};
use crate::coordinator::{
    Compute, FailureScenario, LatencyRecorder, NativeCompute, PreparedJob,
};
use crate::math::Rng;
use crate::model::ClusterSpec;
use crate::runtime::pool::PoolHandle;
use crate::workload::ArrivalProcess;
use crate::{Error, Result};
use std::sync::Arc;
use crate::runtime::wall_now;
use std::time::Duration;

/// Domain-separation tag for the arrival-trace RNG stream of
/// [`Mode::PoissonArrivals`] (kept identical to the historical `run
/// --mode arrivals` derivation so traces replay bit-identically).
pub const ARRIVAL_SEED_TAG: u64 = 0xA221;

/// How a [`Session`] schedules its requests onto the cluster.
#[derive(Clone, Debug)]
pub enum Mode {
    /// Exactly one request through the cold one-shot path (encode,
    /// dispatch, decode) using `JobConfig::seed` as-is — the legacy
    /// `run_job`.
    Single,
    /// Requests one after another; each draws a fresh generator and
    /// straggle realization from a derived seed — the legacy
    /// `serve_requests`.
    Sequential,
    /// Every request's workers dispatched immediately on their own
    /// threads; request `i+1` does not wait for request `i`'s stragglers —
    /// the legacy `serve_requests_pipelined`.
    Pipelined,
    /// All requests as **one** coded batch over a prepared job: each
    /// worker evaluates its chunk against every request in a single
    /// backend call, one straggle realization for the batch — the legacy
    /// `run_job_batched`.
    Batched,
    /// Replay an arrival trace through the prepared fast path: encode
    /// once, drain queued requests in batches of up to `max_batch`.
    /// Scenarios and adaptive re-allocation attach to this mode — the
    /// legacy `serve_arrivals` / `serve_arrivals_adaptive`.
    Arrivals {
        /// Wall-clock arrival offsets from serving start (ascending), one
        /// per request.
        offsets: Vec<Duration>,
        /// Maximum requests drained into one coded batch.
        max_batch: usize,
    },
    /// [`Mode::Arrivals`] with the offsets drawn from a Poisson process at
    /// `rate` arrivals/second (derived deterministically from
    /// `JobConfig::seed` ^ [`ARRIVAL_SEED_TAG`] at build time).
    PoissonArrivals {
        /// Arrival rate in requests per wall-clock second.
        rate: f64,
        /// Maximum requests drained into one coded batch.
        max_batch: usize,
    },
}

/// The unified result of [`Session::serve`]: a superset of the legacy
/// `JobReport` / `ServeReport` / `AdaptiveServeReport` views, with the
/// encode / re-chunk / decode-cache counters always populated (zero for
/// modes where the mechanism cannot fire, e.g. no re-chunks outside
/// arrivals mode; the one-shot cold paths build cache-less decoders, so
/// their cache counters are 0/0 by construction).
#[derive(Debug)]
pub struct ServeOutcome {
    /// Per-request latency metrics (sojourns in arrivals mode).
    pub recorder: LatencyRecorder,
    /// Max decode error across requests (NaN — not 0 — when
    /// [`JobConfig::verify_decode`] is off: nothing was verified).
    pub worst_error: f64,
    /// Per-request reports, in request order.
    pub jobs: Vec<JobReport>,
    /// Wall time for the whole serve (`None` only for [`Mode::Single`],
    /// where the single job's `wall_latency` is the measure).
    pub makespan: Option<Duration>,
    /// Encode passes performed. Prepared modes (batched/arrivals) hold
    /// this at 1 regardless of batch count; the cold modes pay one per
    /// request by construction.
    pub encodes: u64,
    /// Re-chunk (re-allocation) passes on the prepared job.
    pub rechunks: u64,
    /// Decode factorization-cache hits (prepared modes).
    pub decode_cache_hits: u64,
    /// Decode factorization-cache misses (prepared modes).
    pub decode_cache_misses: u64,
    /// Decode factorizations served *around* the cache by the
    /// thrash-bypass guard (prepared modes): a full cache taking this
    /// many consecutive misses stops evicting residents
    /// ([`crate::coding::Decoder::cache_bypasses`]).
    pub decode_cache_bypasses: u64,
    /// Estimator-triggered re-solves (adaptive arrivals mode).
    pub reallocations: u64,
    /// Workers suspected dead by the end of the stream (sorted).
    pub suspected_dead: Vec<usize>,
    /// Encode passes after setup — the adaptation invariant: stays 0, no
    /// matter how many times the stream re-allocates.
    pub post_setup_encodes: u64,
    /// Scratch-arena allocation/grow events after the first batch of a
    /// prepared stream (the first batch sizes the arenas) — the
    /// allocation-free hot-path invariant, measured from
    /// [`crate::coordinator::PreparedJob::scratch_grows`] exactly like
    /// `encodes` is measured from the encoder's call counter: a
    /// steady-state stream holds this at **0** (no big per-batch buffer —
    /// request staging, straggle draws, collection columns, decode RHS —
    /// is allocated after warm-up).
    pub steady_allocs: u64,
    /// The cluster parameters the loop believed at the end (arrivals mode;
    /// differs from the spec only after adaptive re-solves).
    pub assumed_spec: Option<ClusterSpec>,
    /// Admission front-end counters (batches, cross-shard drains, batch
    /// controller decisions, queue depth, per-tenant p99) — populated only
    /// when the session was built with [`SessionBuilder::front_end`].
    pub front_end: Option<FrontEndReport>,
    /// Streaming-collection accounting (rows received/issued, extra
    /// solicitation rounds, reception overhead, re-encoded rows) —
    /// populated only when the session served with the rateless code
    /// through a streaming mode ([`Mode::Batched`] / adaptive arrivals).
    pub rateless: Option<RatelessSummary>,
    /// In-batch recovery accounting (hedges issued/won, wasted rows,
    /// quarantines, degraded batches, one record per degraded batch) —
    /// populated only when the session was built with
    /// [`SessionBuilder::recovery`].
    pub recovery: Option<RecoveryReport>,
}

impl ServeOutcome {
    /// Collapse into the legacy [`ServeReport`] shape (drops the
    /// adaptation and cache counters).
    pub fn into_serve_report(self) -> ServeReport {
        ServeReport {
            recorder: self.recorder,
            worst_error: self.worst_error,
            jobs: self.jobs,
            makespan: self.makespan,
            encodes: self.encodes,
        }
    }

    fn one_shot(
        recorder: LatencyRecorder,
        worst_error: f64,
        jobs: Vec<JobReport>,
        makespan: Option<Duration>,
        encodes: u64,
    ) -> ServeOutcome {
        ServeOutcome {
            recorder,
            worst_error,
            jobs,
            makespan,
            encodes,
            rechunks: 0,
            decode_cache_hits: 0,
            decode_cache_misses: 0,
            decode_cache_bypasses: 0,
            reallocations: 0,
            suspected_dead: Vec::new(),
            post_setup_encodes: 0,
            steady_allocs: 0,
            assumed_spec: None,
            front_end: None,
            rateless: None,
            recovery: None,
        }
    }
}

/// Builder for a [`Session`]; start from [`Session::builder`].
pub struct SessionBuilder {
    spec: ClusterSpec,
    cfg: JobConfig,
    alloc: Option<Allocation>,
    policy: Option<Box<dyn Policy>>,
    data: Option<Matrix>,
    requests: Vec<Vec<f64>>,
    mode: Mode,
    scenario: FailureScenario,
    adaptive: Option<AdaptiveServeConfig>,
    front_end: Option<FrontEndConfig>,
    recovery: Option<RecoveryConfig>,
    compute: Option<Arc<dyn Compute>>,
    pool: Option<PoolHandle>,
    code: Option<String>,
}

impl SessionBuilder {
    /// Share an existing compute pool with this session (several sessions
    /// can serve off one pool — worker threads are spawned once, at pool
    /// construction, never per session or per batch). Without this, the
    /// session resolves a pool at build time via
    /// [`JobConfig::resolve_pool`]: a dedicated
    /// [`crate::runtime::pool::WorkPool`] of [`JobConfig::encode_threads`]
    /// workers when that hint is nonzero, the shared global pool
    /// otherwise.
    pub fn pool(mut self, pool: PoolHandle) -> Self {
        self.pool = Some(pool);
        self
    }
    /// Solve the allocation with this policy at build time (under
    /// `JobConfig::model`). Mutually exclusive with
    /// [`SessionBuilder::allocation`].
    pub fn policy(mut self, policy: Box<dyn Policy>) -> Self {
        self.policy = Some(policy);
        self
    }

    /// Use an explicit, already-solved allocation. Mutually exclusive with
    /// [`SessionBuilder::policy`].
    pub fn allocation(mut self, alloc: Allocation) -> Self {
        self.alloc = Some(alloc);
        self
    }

    /// The uncoded data matrix `A` (`k × d`, `k = spec.k`). Required.
    pub fn data(mut self, a: Matrix) -> Self {
        self.data = Some(a);
        self
    }

    /// The request vectors (each of length `d`) to serve.
    pub fn requests(mut self, requests: Vec<Vec<f64>>) -> Self {
        self.requests = requests;
        self
    }

    /// Job configuration (latency model, seed, time scale, encode threads,
    /// decode cache, …). Defaults to [`JobConfig::default`].
    pub fn config(mut self, cfg: JobConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Serve with the named registry code (`mds-random`,
    /// `mds-vandermonde`, `sparse-parity`; see [`crate::coding::code`]).
    /// Overrides [`JobConfig::code`]; the name is validated at
    /// [`SessionBuilder::build`]. Without this, the code is resolved from
    /// [`JobConfig::generator`] — identical to pre-registry behaviour.
    pub fn code(mut self, name: impl Into<String>) -> Self {
        self.code = Some(name.into());
        self
    }

    /// Serving mode. Defaults to [`Mode::Sequential`].
    pub fn mode(mut self, mode: Mode) -> Self {
        self.mode = mode;
        self
    }

    /// Scripted failure/drift scenario (arrivals modes only).
    pub fn scenario(mut self, scenario: FailureScenario) -> Self {
        self.scenario = scenario;
        self
    }

    /// Enable the online estimator + re-allocation loop (arrivals modes
    /// only). Re-solves go through the session policy's
    /// [`Policy::allocate_capped`] when the session was built with
    /// [`SessionBuilder::policy`]; sessions built from an explicit
    /// allocation re-solve with the paper's proposed projection (the
    /// historical behaviour).
    pub fn adaptive(mut self, cfg: AdaptiveServeConfig) -> Self {
        self.adaptive = Some(cfg);
        self
    }

    /// Attach the sharded admission front end (arrivals modes only):
    /// tenant-keyed per-shard DRR queues, a work-conserving rotating
    /// drain, and optionally SLO-adaptive batch sizing
    /// ([`FrontEndConfig::batch`]). Mutually exclusive with
    /// [`SessionBuilder::adaptive`] (the front end owns the drain loop).
    /// The degenerate [`FrontEndConfig::fifo_parity`] configuration is
    /// bit-identical to serving without a front end.
    pub fn front_end(mut self, cfg: FrontEndConfig) -> Self {
        self.front_end = Some(cfg);
        self
    }

    /// Attach the in-batch recovery layer (arrivals modes only): per-worker
    /// hedge deadlines from the analytic quantile law, deadline-blown rows
    /// re-issued to the fastest helpers with capped exponential backoff,
    /// a quarantine ring with canary probes, and graceful degradation when
    /// the batch deadline expires short of `k`
    /// ([`crate::coordinator::recovery`]). Required for scenarios that
    /// script [`crate::coordinator::FailureKind::StallWorker`] /
    /// [`crate::coordinator::FailureKind::FlappyWorker`] — without it a
    /// stalled worker would block the collection until its batch times
    /// out. Mutually exclusive with [`SessionBuilder::front_end`].
    pub fn recovery(mut self, cfg: RecoveryConfig) -> Self {
        self.recovery = Some(cfg);
        self
    }

    /// Compute backend. Defaults to [`NativeCompute`].
    pub fn compute(mut self, compute: Arc<dyn Compute>) -> Self {
        self.compute = Some(compute);
        self
    }

    /// Validate the configuration and produce a ready-to-serve
    /// [`Session`]: resolves the policy into an allocation, validates it
    /// against the spec, resolves the compute pool (explicit handle >
    /// `JobConfig::pool` > `encode_threads` hint > global pool — built
    /// once here and reused by every batch the session serves), and
    /// materializes Poisson arrival offsets.
    pub fn build(self) -> Result<Session> {
        let a = self.data.ok_or_else(|| {
            Error::InvalidSpec(
                "Session needs the data matrix (SessionBuilder::data)".into(),
            )
        })?;
        if a.rows() != self.spec.k {
            return Err(Error::InvalidSpec(format!(
                "data matrix has {} rows, spec.k = {}",
                a.rows(),
                self.spec.k
            )));
        }
        let (alloc, policy) = match (self.alloc, self.policy) {
            (Some(_), Some(_)) => {
                return Err(Error::InvalidSpec(
                    "Session got both .allocation(..) and .policy(..); \
                     pick one"
                        .into(),
                ))
            }
            (Some(alloc), None) => (alloc, None),
            (None, Some(p)) => {
                let alloc = p.allocate(self.cfg.model, &self.spec)?;
                (alloc, Some(p))
            }
            (None, None) => {
                return Err(Error::InvalidSpec(
                    "Session needs .policy(..) or .allocation(..)".into(),
                ))
            }
        };
        alloc.validate(&self.spec)?;
        // Resolve the session's compute pool once: every encode and
        // decode of every batch runs on it (explicit handle first, then a
        // JobConfig-attached one, then the encode_threads sizing hint,
        // then the shared global pool).
        let mut cfg = self.cfg;
        if let Some(p) = self.pool {
            cfg.pool = Some(p);
        }
        cfg.pool = Some(cfg.resolve_pool());
        if let Some(name) = self.code {
            cfg.code = Some(name);
        }
        // Fail unknown code names here, not on the first serve.
        cfg.resolve_code()?;
        let mode = match self.mode {
            Mode::PoissonArrivals { rate, max_batch } => {
                let mut rng = Rng::new(cfg.seed ^ ARRIVAL_SEED_TAG);
                let offsets = ArrivalProcess::Poisson { rate }
                    .times(self.requests.len(), &mut rng)?
                    .into_iter()
                    .map(Duration::from_secs_f64)
                    .collect();
                Mode::Arrivals { offsets, max_batch }
            }
            m => m,
        };
        if !matches!(mode, Mode::Arrivals { .. })
            && (!self.scenario.is_empty()
                || self.adaptive.is_some()
                || self.front_end.is_some()
                || self.recovery.is_some())
        {
            return Err(Error::InvalidSpec(
                "failure scenarios, adaptive serving, recovery, and the \
                 admission front end need an arrivals mode (Mode::Arrivals \
                 / Mode::PoissonArrivals)"
                    .into(),
            ));
        }
        if let Some(rc) = &self.recovery {
            rc.validate()?;
            if self.front_end.is_some() {
                return Err(Error::InvalidSpec(
                    "the admission front end drains through its own \
                     collection; in-batch recovery is not supported there \
                     (drop .front_end(..) or .recovery(..))"
                        .into(),
                ));
            }
        }
        if self.scenario.has_stall() && self.recovery.is_none() {
            return Err(Error::InvalidSpec(
                "StallWorker / FlappyWorker scenarios stall the collection \
                 indefinitely without the recovery layer; attach \
                 .recovery(RecoveryConfig { .. })"
                    .into(),
            ));
        }
        if let Some(front) = &self.front_end {
            if self.scenario.has_loss() {
                return Err(Error::InvalidSpec(
                    "lossy-link scenarios go through the streaming-aware \
                     drain; the admission front end does not support them \
                     (drop .front_end(..) or the loss events)"
                        .into(),
                ));
            }
            if self.adaptive.is_some() {
                return Err(Error::InvalidSpec(
                    "the admission front end and the adaptive re-allocation \
                     loop both own the drain; pick one (.front_end(..) xor \
                     .adaptive(..))"
                        .into(),
                ));
            }
            front.validate()?;
        }
        Ok(Session {
            spec: self.spec,
            alloc,
            policy,
            a,
            requests: self.requests,
            cfg,
            mode,
            scenario: self.scenario,
            adaptive: self.adaptive,
            front_end: self.front_end,
            recovery: self.recovery,
            compute: self.compute.unwrap_or_else(|| Arc::new(NativeCompute)),
        })
    }
}

/// A fully-configured serving session: spec + allocation + data + requests
/// + mode (+ scenario/adaptivity). Built by [`SessionBuilder`]; serving is
/// side-effect-free on the session, so one session can serve repeatedly
/// (each [`Session::serve`] re-runs the whole configured stream).
pub struct Session {
    spec: ClusterSpec,
    alloc: Allocation,
    /// The policy the session was built from (`None` for explicit
    /// allocations). Adaptive arrivals re-solves go through its
    /// `allocate_capped`, so the adaptation stays on the chosen policy.
    policy: Option<Box<dyn Policy>>,
    a: Matrix,
    requests: Vec<Vec<f64>>,
    cfg: JobConfig,
    mode: Mode,
    scenario: FailureScenario,
    adaptive: Option<AdaptiveServeConfig>,
    front_end: Option<FrontEndConfig>,
    recovery: Option<RecoveryConfig>,
    compute: Arc<dyn Compute>,
}

impl Session {
    /// Start building a session for `spec`.
    pub fn builder(spec: &ClusterSpec) -> SessionBuilder {
        SessionBuilder {
            spec: spec.clone(),
            cfg: JobConfig::default(),
            alloc: None,
            policy: None,
            data: None,
            requests: Vec::new(),
            mode: Mode::Sequential,
            scenario: FailureScenario::none(),
            adaptive: None,
            front_end: None,
            recovery: None,
            compute: None,
            pool: None,
            code: None,
        }
    }

    /// The compute pool this session's kernels run on (resolved at
    /// [`SessionBuilder::build`]). Introspection hook: tests pin that two
    /// sessions sharing a handle really share workers and that serving
    /// never spawns more.
    pub fn pool(&self) -> &PoolHandle {
        self.cfg.pool.as_ref().expect("pool resolved at build")
    }

    /// The allocation this session serves under (solved from the policy at
    /// build time, or the explicit one).
    pub fn allocation(&self) -> &Allocation {
        &self.alloc
    }

    /// The normalized serving mode ([`Mode::PoissonArrivals`] appears as
    /// [`Mode::Arrivals`] with its materialized offsets).
    pub fn mode(&self) -> &Mode {
        &self.mode
    }

    /// Run the configured serve and return the unified outcome.
    pub fn serve(&self) -> Result<ServeOutcome> {
        match &self.mode {
            Mode::Single => self.serve_single(),
            Mode::Sequential => self.serve_sequential(),
            Mode::Pipelined => self.serve_pipelined(),
            Mode::Batched => self.serve_batched(),
            Mode::Arrivals { offsets, max_batch } => {
                self.serve_arrivals(offsets, *max_batch)
            }
            Mode::PoissonArrivals { .. } => unreachable!("normalized in build"),
        }
    }

    fn serve_single(&self) -> Result<ServeOutcome> {
        if self.requests.len() != 1 {
            return Err(Error::InvalidSpec(format!(
                "Mode::Single needs exactly one request, got {}",
                self.requests.len()
            )));
        }
        let report = run_job_impl(
            &self.spec,
            &self.alloc,
            &self.a,
            &self.requests[0],
            Arc::clone(&self.compute),
            &self.cfg,
        )?;
        let mut recorder = LatencyRecorder::new();
        recorder.record(report.wall_latency, report.decoded.len());
        let worst = fold_worst_error(0.0, report.max_error);
        Ok(ServeOutcome::one_shot(recorder, worst, vec![report], None, 1))
    }

    fn serve_sequential(&self) -> Result<ServeOutcome> {
        let start = wall_now();
        let mut recorder = LatencyRecorder::new();
        let mut jobs = Vec::with_capacity(self.requests.len());
        let mut worst = 0.0f64;
        for (i, x) in self.requests.iter().enumerate() {
            let mut jcfg = self.cfg.clone();
            jcfg.seed = derive_stream_seed(self.cfg.seed, i as u64);
            let report = run_job_impl(
                &self.spec,
                &self.alloc,
                &self.a,
                x,
                Arc::clone(&self.compute),
                &jcfg,
            )?;
            recorder.record(report.wall_latency, report.decoded.len());
            worst = fold_worst_error(worst, report.max_error);
            jobs.push(report);
        }
        let encodes = jobs.len() as u64;
        Ok(ServeOutcome::one_shot(
            recorder,
            worst,
            jobs,
            Some(start.elapsed()),
            encodes,
        ))
    }

    fn serve_pipelined(&self) -> Result<ServeOutcome> {
        let start = wall_now();
        let mut handles = Vec::with_capacity(self.requests.len());
        for (i, x) in self.requests.iter().enumerate() {
            let mut jcfg = self.cfg.clone();
            jcfg.seed = derive_stream_seed(self.cfg.seed, i as u64);
            let spec = self.spec.clone();
            let alloc = self.alloc.clone();
            let a = self.a.clone();
            let x = x.clone();
            let cmp = Arc::clone(&self.compute);
            // Allowlisted thread-creation site (lint rule D3): each
            // request thread blocks end-to-end on a full job (including
            // emulated worker sleeps), which would deadlock a
            // fixed-size pool at high concurrency.
            #[allow(clippy::disallowed_methods)]
            handles.push(
                std::thread::Builder::new()
                    .name(format!("request-{i}"))
                    .spawn(move || run_job_impl(&spec, &alloc, &a, &x, cmp, &jcfg))
                    .map_err(|e| {
                        Error::Runtime(format!("spawn request {i}: {e}"))
                    })?,
            );
        }
        let mut recorder = LatencyRecorder::new();
        let mut jobs = Vec::with_capacity(self.requests.len());
        let mut worst = 0.0f64;
        for h in handles {
            let report = h
                .join()
                .map_err(|_| Error::Runtime("request thread panicked".into()))??;
            recorder.record(report.wall_latency, report.decoded.len());
            worst = fold_worst_error(worst, report.max_error);
            jobs.push(report);
        }
        let encodes = jobs.len() as u64; // one cold job (and encode) per request
        Ok(ServeOutcome::one_shot(
            recorder,
            worst,
            jobs,
            Some(start.elapsed()),
            encodes,
        ))
    }

    fn serve_batched(&self) -> Result<ServeOutcome> {
        if self.requests.is_empty() {
            return Err(Error::InvalidSpec("empty request batch".into()));
        }
        let start = wall_now();
        let mut prepared =
            PreparedJob::new(&self.spec, &self.alloc, &self.a, &self.cfg)?;
        // The rateless code serves by streaming (solicitation rounds
        // until any k rows survive); the finite codes dispatch their
        // fixed chunks and stop at k.
        let (reports, rateless) = if prepared.is_rateless() {
            let (reports, stats) = prepared.run_batch_streamed(
                &self.requests,
                Arc::clone(&self.compute),
                self.cfg.seed,
                &[],
            )?;
            let mut summary = RatelessSummary::default();
            summary.absorb(stats);
            summary.finalize(self.spec.k, prepared.re_encoded_rows());
            (reports, Some(summary))
        } else {
            let reports = prepared.run_batch(
                &self.requests,
                Arc::clone(&self.compute),
                self.cfg.seed,
            )?;
            (reports, None)
        };
        let mut recorder = LatencyRecorder::new();
        let mut worst = 0.0f64;
        for r in &reports {
            recorder.record(r.wall_latency, r.decoded.len());
            worst = fold_worst_error(worst, r.max_error);
        }
        let (hits, misses) = prepared.decode_cache_stats();
        Ok(ServeOutcome {
            recorder,
            worst_error: worst,
            jobs: reports,
            makespan: Some(start.elapsed()),
            encodes: prepared.encode_count(),
            rechunks: prepared.rechunk_count(),
            decode_cache_hits: hits,
            decode_cache_misses: misses,
            decode_cache_bypasses: prepared.decode_cache_bypasses(),
            reallocations: 0,
            suspected_dead: Vec::new(),
            post_setup_encodes: prepared.encode_count().saturating_sub(1),
            // One batch: warm-up is the whole serve, nothing after it.
            steady_allocs: 0,
            assumed_spec: None,
            front_end: None,
            rateless,
            recovery: None,
        })
    }

    fn serve_arrivals(
        &self,
        offsets: &[Duration],
        max_batch: usize,
    ) -> Result<ServeOutcome> {
        if let Some(front) = &self.front_end {
            let rep = serve_arrivals_front_impl(
                &self.spec,
                &self.alloc,
                &self.a,
                &self.requests,
                offsets,
                max_batch,
                Arc::clone(&self.compute),
                &self.cfg,
                &self.scenario,
                front,
            )?;
            return Ok(ServeOutcome {
                recorder: rep.serve.recorder,
                worst_error: rep.serve.worst_error,
                jobs: rep.serve.jobs,
                makespan: rep.serve.makespan,
                encodes: rep.serve.encodes,
                rechunks: 0,
                decode_cache_hits: rep.decode_cache.0,
                decode_cache_misses: rep.decode_cache.1,
                decode_cache_bypasses: rep.decode_cache_bypasses,
                reallocations: 0,
                suspected_dead: Vec::new(),
                post_setup_encodes: rep.post_setup_encodes,
                steady_allocs: rep.steady_allocs,
                assumed_spec: None,
                front_end: Some(rep.front),
                rateless: None,
                recovery: None,
            });
        }
        let rep = serve_arrivals_adaptive_impl(
            &self.spec,
            &self.alloc,
            &self.a,
            &self.requests,
            offsets,
            max_batch,
            Arc::clone(&self.compute),
            &self.cfg,
            &self.scenario,
            self.adaptive.as_ref(),
            self.policy.as_deref(),
            self.recovery.as_ref(),
        )?;
        Ok(ServeOutcome {
            recorder: rep.serve.recorder,
            worst_error: rep.serve.worst_error,
            jobs: rep.serve.jobs,
            makespan: rep.serve.makespan,
            encodes: rep.serve.encodes,
            rechunks: rep.rechunks,
            decode_cache_hits: rep.decode_cache.0,
            decode_cache_misses: rep.decode_cache.1,
            decode_cache_bypasses: rep.decode_cache_bypasses,
            reallocations: rep.reallocations,
            suspected_dead: rep.suspected_dead,
            post_setup_encodes: rep.post_setup_encodes,
            steady_allocs: rep.steady_allocs,
            assumed_spec: Some(rep.assumed_spec),
            front_end: None,
            rateless: rep.rateless,
            recovery: rep.recovery,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocation::{policy, uniform_allocation};
    use crate::coordinator::failures::{FailureEvent, FailureKind};
    use crate::model::{Group, LatencyModel};

    fn small_spec() -> ClusterSpec {
        ClusterSpec::new(
            vec![
                Group { n: 4, mu: 8.0, alpha: 1.0 },
                Group { n: 6, mu: 2.0, alpha: 1.0 },
            ],
            64,
        )
        .unwrap()
    }

    fn data(jobs: usize, seed: u64) -> (Matrix, Vec<Vec<f64>>) {
        let mut rng = Rng::new(seed);
        let a = Matrix::from_fn(64, 8, |_, _| rng.normal());
        let reqs = (0..jobs)
            .map(|_| (0..8).map(|_| rng.normal()).collect())
            .collect();
        (a, reqs)
    }

    fn fast_cfg() -> JobConfig {
        JobConfig { time_scale: 0.002, ..Default::default() }
    }

    #[test]
    fn builder_validates_inputs() {
        let spec = small_spec();
        let (a, reqs) = data(2, 91);
        let alloc = uniform_allocation(LatencyModel::A, &spec, 128.0).unwrap();
        // Missing data matrix.
        assert!(Session::builder(&spec)
            .allocation(alloc.clone())
            .requests(reqs.clone())
            .build()
            .is_err());
        // Missing policy/allocation.
        assert!(Session::builder(&spec)
            .data(a.clone())
            .requests(reqs.clone())
            .build()
            .is_err());
        // Both policy and allocation.
        assert!(Session::builder(&spec)
            .allocation(alloc.clone())
            .policy(policy::resolve("proposed").unwrap())
            .data(a.clone())
            .requests(reqs.clone())
            .build()
            .is_err());
        // Scenario outside arrivals mode.
        let scenario = FailureScenario::parse(Some("0:1"), None).unwrap();
        assert!(Session::builder(&spec)
            .allocation(alloc.clone())
            .data(a.clone())
            .requests(reqs.clone())
            .scenario(scenario)
            .mode(Mode::Sequential)
            .build()
            .is_err());
        // Adaptive outside arrivals mode.
        assert!(Session::builder(&spec)
            .allocation(alloc.clone())
            .data(a.clone())
            .requests(reqs.clone())
            .adaptive(AdaptiveServeConfig::default())
            .mode(Mode::Batched)
            .build()
            .is_err());
        // Front end outside arrivals mode.
        assert!(Session::builder(&spec)
            .allocation(alloc.clone())
            .data(a.clone())
            .requests(reqs.clone())
            .front_end(FrontEndConfig::default())
            .mode(Mode::Batched)
            .build()
            .is_err());
        // Front end and adaptive both claim the drain loop.
        assert!(Session::builder(&spec)
            .allocation(alloc.clone())
            .data(a.clone())
            .requests(reqs.clone())
            .front_end(FrontEndConfig::default())
            .adaptive(AdaptiveServeConfig::default())
            .mode(Mode::PoissonArrivals { rate: 100.0, max_batch: 2 })
            .build()
            .is_err());
        // Invalid front-end config fails at build.
        assert!(Session::builder(&spec)
            .allocation(alloc.clone())
            .data(a.clone())
            .requests(reqs.clone())
            .front_end(FrontEndConfig { shards: 0, ..Default::default() })
            .mode(Mode::PoissonArrivals { rate: 100.0, max_batch: 2 })
            .build()
            .is_err());
        // Recovery outside arrivals mode.
        assert!(Session::builder(&spec)
            .allocation(alloc.clone())
            .data(a.clone())
            .requests(reqs.clone())
            .recovery(RecoveryConfig::default())
            .mode(Mode::Batched)
            .build()
            .is_err());
        // Recovery and the front end own different collection loops.
        assert!(Session::builder(&spec)
            .allocation(alloc.clone())
            .data(a.clone())
            .requests(reqs.clone())
            .front_end(FrontEndConfig::default())
            .recovery(RecoveryConfig::default())
            .mode(Mode::PoissonArrivals { rate: 100.0, max_batch: 2 })
            .build()
            .is_err());
        // Invalid recovery knobs fail at build.
        assert!(Session::builder(&spec)
            .allocation(alloc.clone())
            .data(a.clone())
            .requests(reqs.clone())
            .recovery(RecoveryConfig { max_waves: 0, ..Default::default() })
            .mode(Mode::PoissonArrivals { rate: 100.0, max_batch: 2 })
            .build()
            .is_err());
        // Stall scenarios demand the recovery layer (they would otherwise
        // hold the collection hostage until the straggler tail).
        let stall = FailureScenario::new(vec![FailureEvent {
            at_batch: 0,
            kind: FailureKind::StallWorker { worker: 1 },
        }])
        .unwrap();
        assert!(Session::builder(&spec)
            .allocation(alloc.clone())
            .data(a.clone())
            .requests(reqs.clone())
            .scenario(stall)
            .mode(Mode::PoissonArrivals { rate: 100.0, max_batch: 2 })
            .build()
            .is_err());
        // Wrong-shaped data matrix.
        let mut rng = Rng::new(1);
        let wrong = Matrix::from_fn(32, 8, |_, _| rng.normal());
        assert!(Session::builder(&spec)
            .allocation(alloc)
            .data(wrong)
            .requests(reqs)
            .build()
            .is_err());
    }

    #[test]
    fn single_mode_requires_one_request() {
        let spec = small_spec();
        let (a, reqs) = data(2, 92);
        let alloc = uniform_allocation(LatencyModel::A, &spec, 128.0).unwrap();
        let session = Session::builder(&spec)
            .allocation(alloc)
            .data(a)
            .requests(reqs)
            .config(fast_cfg())
            .mode(Mode::Single)
            .build()
            .unwrap();
        assert!(session.serve().is_err());
    }

    #[test]
    fn every_mode_serves_and_populates_counters() {
        let spec = small_spec();
        let (a, reqs) = data(4, 93);
        let alloc = uniform_allocation(LatencyModel::A, &spec, 128.0).unwrap();
        let offsets: Vec<Duration> =
            (0..4).map(|i| Duration::from_millis(2 * i as u64)).collect();
        let modes: Vec<(Mode, u64)> = vec![
            (Mode::Sequential, 4),
            (Mode::Pipelined, 4),
            (Mode::Batched, 1),
            (Mode::Arrivals { offsets, max_batch: 2 }, 1),
            (Mode::PoissonArrivals { rate: 200.0, max_batch: 2 }, 1),
        ];
        for (mode, encodes) in modes {
            let label = format!("{mode:?}");
            let outcome = Session::builder(&spec)
                .allocation(alloc.clone())
                .data(a.clone())
                .requests(reqs.clone())
                .config(fast_cfg())
                .mode(mode)
                .build()
                .unwrap()
                .serve()
                .unwrap();
            assert_eq!(outcome.jobs.len(), 4, "{label}");
            assert_eq!(outcome.recorder.count(), 4, "{label}");
            assert!(outcome.worst_error < 1e-8, "{label}");
            assert_eq!(outcome.encodes, encodes, "{label}");
            assert_eq!(outcome.reallocations, 0, "{label}");
            assert_eq!(outcome.rechunks, 0, "{label}");
            assert_eq!(outcome.post_setup_encodes, 0, "{label}");
            assert!(outcome.suspected_dead.is_empty(), "{label}");
            assert!(outcome.makespan.is_some(), "{label}");
        }
    }

    #[test]
    fn front_end_serves_sharded_multi_tenant() {
        let spec = small_spec();
        let (a, reqs) = data(12, 97);
        let alloc = uniform_allocation(LatencyModel::A, &spec, 128.0).unwrap();
        // All requests pre-arrived: batch composition is deterministic
        // (admission order == index order, independent of wall clock).
        let offsets: Vec<Duration> = vec![Duration::ZERO; 12];
        let outcome = Session::builder(&spec)
            .allocation(alloc)
            .data(a)
            .requests(reqs)
            .config(fast_cfg())
            .front_end(FrontEndConfig {
                shards: 2,
                tenants: 4,
                weights: vec![1.0, 2.0, 1.0, 1.0],
                batch: None,
            })
            .mode(Mode::Arrivals { offsets, max_batch: 3 })
            .build()
            .unwrap()
            .serve()
            .unwrap();
        assert_eq!(outcome.jobs.len(), 12);
        assert!(outcome.worst_error < 1e-8);
        assert_eq!(outcome.encodes, 1);
        assert_eq!(outcome.post_setup_encodes, 0);
        let front = outcome.front_end.expect("front-end counters populated");
        assert_eq!(front.shards, 2);
        assert_eq!(front.tenants, 4);
        assert!(front.batches >= 4, "12 jobs / max 3 per batch");
        assert!(front.max_batch_used <= 3);
        assert_eq!(front.max_queue_depth, 12);
        assert_eq!(
            front.tenant_of,
            (0..12).map(|i| i % 4).collect::<Vec<_>>()
        );
        assert_eq!(front.per_tenant_p99.len(), 4);
    }

    #[test]
    fn policy_resolution_at_build_matches_explicit_allocation() {
        let spec = small_spec();
        let (a, reqs) = data(1, 94);
        let cfg = fast_cfg();
        let by_policy = Session::builder(&spec)
            .policy(policy::resolve("proposed").unwrap())
            .data(a.clone())
            .requests(reqs.clone())
            .config(cfg.clone())
            .mode(Mode::Single)
            .build()
            .unwrap();
        let explicit = crate::allocation::proposed_allocation(cfg.model, &spec).unwrap();
        assert_eq!(by_policy.allocation().loads, explicit.loads);
        let o1 = by_policy.serve().unwrap();
        let o2 = Session::builder(&spec)
            .allocation(explicit)
            .data(a)
            .requests(reqs)
            .config(cfg)
            .mode(Mode::Single)
            .build()
            .unwrap()
            .serve()
            .unwrap();
        assert_eq!(o1.jobs[0].decoded, o2.jobs[0].decoded);
        assert_eq!(o1.jobs[0].rows_collected, o2.jobs[0].rows_collected);
    }

    #[test]
    fn code_knob_validates_at_build_and_serves() {
        let spec = small_spec();
        let (a, reqs) = data(2, 96);
        let alloc = uniform_allocation(LatencyModel::A, &spec, 128.0).unwrap();
        // Unknown names fail at build, not on the first serve.
        assert!(Session::builder(&spec)
            .allocation(alloc.clone())
            .data(a.clone())
            .requests(reqs.clone())
            .code("no-such-code")
            .build()
            .is_err());
        // Naming the default code serves exactly like not naming one.
        let outcome = Session::builder(&spec)
            .allocation(alloc.clone())
            .data(a.clone())
            .requests(reqs.clone())
            .config(fast_cfg())
            .code("mds-random")
            .mode(Mode::Batched)
            .build()
            .unwrap()
            .serve()
            .unwrap();
        assert_eq!(outcome.jobs.len(), 2);
        assert!(outcome.worst_error < 1e-8);
        assert_eq!(outcome.encodes, 1);
        // The sparse code is not MDS: whichever k-subset of rows arrives
        // first either decodes correctly or fails *cleanly* (Err, never a
        // wrong answer or a hang) — that is its documented contract.
        let sparse = Session::builder(&spec)
            .allocation(alloc)
            .data(a)
            .requests(reqs)
            .config(fast_cfg())
            .code("sparse-parity")
            .mode(Mode::Batched)
            .build()
            .unwrap();
        match sparse.serve() {
            Ok(o) => {
                assert_eq!(o.jobs.len(), 2);
                assert!(o.worst_error < 1e-8, "err {}", o.worst_error);
            }
            Err(Error::Decode(_)) | Err(Error::Numerical(_)) => {}
            Err(e) => panic!("sparse-parity serve failed unexpectedly: {e}"),
        }
    }

    #[test]
    fn poisson_offsets_are_seed_deterministic() {
        let spec = small_spec();
        let (a, reqs) = data(3, 95);
        let alloc = uniform_allocation(LatencyModel::A, &spec, 128.0).unwrap();
        let build = || {
            Session::builder(&spec)
                .allocation(alloc.clone())
                .data(a.clone())
                .requests(reqs.clone())
                .config(fast_cfg())
                .mode(Mode::PoissonArrivals { rate: 500.0, max_batch: 4 })
                .build()
                .unwrap()
        };
        let (s1, s2) = (build(), build());
        match (s1.mode(), s2.mode()) {
            (
                Mode::Arrivals { offsets: o1, .. },
                Mode::Arrivals { offsets: o2, .. },
            ) => {
                assert_eq!(o1, o2);
                assert_eq!(o1.len(), 3);
            }
            other => panic!("PoissonArrivals not normalized: {other:?}"),
        }
    }
}
