//! The live sharded admission front end for arrivals-mode serving.
//!
//! [`serve_arrivals_front_impl`] is the wall-clock twin of the model-time
//! simulator in [`crate::workload::admission`]: arrivals land in per-shard
//! [`DrrQueue`]s (tenant-keyed, `shard = tenant % shards`), a
//! round-robin-rotating drain visits the shards work-conservingly (an
//! empty home shard never idles the drain while another shard has
//! backlog — the live analogue of the simulator's work stealing), and
//! each dispatched batch runs on the session's [`PreparedJob`] — whose
//! encode/decode kernels execute on the persistent
//! [`crate::runtime::pool::WorkPool`] resolved at session build. Batch
//! sizing is either the fixed `max_batch` of [`Mode::Arrivals`] or a
//! [`BatchController`] steering the limit against a wall-clock sojourn
//! SLO ([`BatchPolicy::Adaptive`]).
//!
//! # Determinism and parity
//!
//! The drain is one coordinator loop, not racing threads, so dispatch
//! order is a pure function of arrival order and queue state:
//!
//! - **Degenerate config** ([`FrontEndConfig::fifo_parity`]: 1 shard,
//!   1 tenant, no explicit batch policy): the DRR queue collapses to the
//!   FIFO the legacy drain walks, batches are the same contiguous index
//!   ranges, and each batch `b` draws its straggle realization from the
//!   same seed (`derive_stream_seed(cfg.seed, b) ^ STRAGGLE_SEED_TAG`)
//!   through the same [`ScenarioState`] staging — so decoded outputs,
//!   collected row sets, and encode counts are **bit-identical** to
//!   [`Mode::Arrivals`] without a front end (pinned by
//!   `rust/tests/admission.rs`).
//! - **Sharded config**: request→tenant (`i % tenants`) and tenant→shard
//!   (`t % shards`) maps are fixed, per-request reports are emitted
//!   index-ordered regardless of dispatch interleaving (the
//!   [`crate::runtime::pool::WorkPool`] merge pattern), and batch seeds
//!   depend only on the batch counter. Wall-clock timing decides batch
//!   *composition*, so latency metrics vary run to run like any live
//!   serve, but every request's decode remains exact.
//!
//! [`Mode::Arrivals`]: crate::coordinator::Mode::Arrivals
//! [`PreparedJob`]: crate::coordinator::PreparedJob

use crate::allocation::Allocation;
use crate::coding::Matrix;
use crate::coordinator::failures::{FailureScenario, ScenarioState};
use crate::coordinator::master::{
    derive_stream_seed, fold_worst_error, JobConfig, JobReport, ServeReport,
    STRAGGLE_SEED_TAG,
};
use crate::coordinator::{Compute, LatencyRecorder, PreparedJob};
use crate::model::ClusterSpec;
use crate::workload::{BatchController, BatchPolicy, DrrQueue};
use crate::{Error, Result};
use std::sync::Arc;
use crate::runtime::wall_now;
use std::time::Duration;

/// Configuration of the live admission front end
/// ([`crate::coordinator::SessionBuilder::front_end`]).
#[derive(Clone, Debug)]
pub struct FrontEndConfig {
    /// Admission queues; request `i` belongs to tenant `i % tenants`,
    /// tenant `t` is keyed onto shard `t % shards`.
    pub shards: usize,
    /// Tenant count (round-robin request assignment).
    pub tenants: usize,
    /// Per-tenant DRR weights. Empty means unit weights; otherwise must
    /// have exactly `tenants` positive finite entries.
    pub weights: Vec<f64>,
    /// Batch sizing. `None` uses the arrivals mode's `max_batch` as a
    /// fixed limit (the parity default); `Some(BatchPolicy::Adaptive(..))`
    /// steers the limit against a wall-clock sojourn SLO (seconds).
    pub batch: Option<BatchPolicy>,
}

impl Default for FrontEndConfig {
    fn default() -> Self {
        FrontEndConfig {
            shards: 1,
            tenants: 1,
            weights: Vec::new(),
            batch: None,
        }
    }
}

impl FrontEndConfig {
    /// The degenerate configuration pinned bit-identical to the plain
    /// arrivals drain: one shard, one tenant, the mode's own `max_batch`.
    pub fn fifo_parity() -> FrontEndConfig {
        FrontEndConfig::default()
    }

    /// Check the knobs are self-consistent.
    pub fn validate(&self) -> Result<()> {
        if self.shards == 0 || self.tenants == 0 {
            return Err(Error::InvalidSpec(
                "front end needs at least one shard and one tenant".into(),
            ));
        }
        if !self.weights.is_empty() {
            if self.weights.len() != self.tenants {
                return Err(Error::InvalidSpec(format!(
                    "front end has {} tenants but {} weights",
                    self.tenants,
                    self.weights.len()
                )));
            }
            if self.weights.iter().any(|w| !(*w > 0.0) || !w.is_finite()) {
                return Err(Error::InvalidSpec(format!(
                    "front-end weights must be positive and finite, got {:?}",
                    self.weights
                )));
            }
        }
        match self.batch {
            None => Ok(()),
            Some(BatchPolicy::Fixed(0)) => Err(Error::InvalidSpec(
                "front-end fixed batch limit must be positive".into(),
            )),
            Some(BatchPolicy::Fixed(_)) => Ok(()),
            Some(BatchPolicy::Adaptive(slo)) => slo.validate(),
        }
    }
}

/// Front-end counters of one arrivals serve
/// ([`crate::coordinator::ServeOutcome::front_end`]).
#[derive(Clone, Debug)]
pub struct FrontEndReport {
    /// Shards / tenants the stream ran with.
    pub shards: usize,
    /// Tenant count.
    pub tenants: usize,
    /// Batches dispatched.
    pub batches: u64,
    /// Batches drained from a shard other than the rotation's next (the
    /// work-conserving skips — the live analogue of sim-layer steals).
    pub cross_shard_batches: u64,
    /// Batches drained from a shard other than batch `b`'s *home* shard
    /// (`b % shards`) — the exact counterpart of the admission
    /// simulator's [`crate::workload::admission::AdmissionReport::steals`]
    /// (home shard `drainer % shards` there): under the degenerate
    /// [`FrontEndConfig::fifo_parity`] config both are provably 0, pinned
    /// equal by `rust/tests/admission.rs`.
    pub steals: u64,
    /// Mean jobs per batch.
    pub mean_batch: f64,
    /// Largest batch actually dispatched.
    pub max_batch_used: usize,
    /// The batch limit in force at the end of the stream.
    pub final_batch_limit: usize,
    /// Controller grow decisions (0 under a fixed limit).
    pub batch_grows: u64,
    /// Controller shrink decisions (0 under a fixed limit).
    pub batch_shrinks: u64,
    /// Peak requests admitted-but-undispatched across all shards.
    pub max_queue_depth: usize,
    /// Owning tenant of request `i`.
    pub tenant_of: Vec<usize>,
    /// Per-tenant nearest-rank p99 sojourn (zero for a tenant that owned
    /// no requests).
    pub per_tenant_p99: Vec<Duration>,
}

/// What [`serve_arrivals_front_impl`] hands back to the session facade.
pub(crate) struct FrontServeReport {
    pub serve: ServeReport,
    pub decode_cache: (u64, u64),
    pub decode_cache_bypasses: u64,
    pub post_setup_encodes: u64,
    pub steady_allocs: u64,
    pub front: FrontEndReport,
}

/// Nearest-rank p99 over raw samples (order irrelevant).
fn p99(samples: &mut [Duration]) -> Duration {
    if samples.is_empty() {
        return Duration::ZERO;
    }
    samples.sort_unstable();
    let rank =
        ((0.99 * samples.len() as f64).ceil() as usize).clamp(1, samples.len());
    samples[rank - 1]
}

/// The sharded arrivals drain behind
/// [`crate::coordinator::Session::serve`] when a [`FrontEndConfig`] is
/// attached. Mirrors the legacy drain's scenario/seed discipline batch
/// for batch; see the module docs for the parity argument.
#[allow(clippy::too_many_arguments)]
pub(crate) fn serve_arrivals_front_impl(
    spec: &ClusterSpec,
    alloc: &Allocation,
    a: &Matrix,
    requests: &[Vec<f64>],
    arrival_offsets: &[Duration],
    max_batch: usize,
    compute: Arc<dyn Compute>,
    cfg: &JobConfig,
    scenario: &FailureScenario,
    front: &FrontEndConfig,
) -> Result<FrontServeReport> {
    if requests.len() != arrival_offsets.len() {
        return Err(Error::InvalidSpec(format!(
            "{} requests but {} arrival offsets",
            requests.len(),
            arrival_offsets.len()
        )));
    }
    if requests.is_empty() {
        return Err(Error::InvalidSpec(
            "front end needs at least one request".into(),
        ));
    }
    if max_batch == 0 {
        return Err(Error::InvalidSpec("max_batch must be positive".into()));
    }
    if arrival_offsets.windows(2).any(|w| w[1] < w[0]) {
        return Err(Error::InvalidSpec(
            "arrival offsets must be ascending".into(),
        ));
    }
    front.validate()?;
    let batch_policy = front.batch.unwrap_or(BatchPolicy::Fixed(max_batch));
    let mut controller = match batch_policy {
        BatchPolicy::Fixed(_) => None,
        BatchPolicy::Adaptive(slo) => Some(BatchController::new(slo)?),
    };
    let fixed_limit = match batch_policy {
        BatchPolicy::Fixed(b) => b,
        BatchPolicy::Adaptive(_) => 0,
    };
    let n = requests.len();
    let shards = front.shards;
    let tenants = front.tenants;
    let weights: Vec<f64> = if front.weights.is_empty() {
        vec![1.0; tenants]
    } else {
        front.weights.clone()
    };
    let tenant_of: Vec<usize> = (0..n).map(|i| i % tenants).collect();
    // Per-shard arrival streams in index (== arrival) order.
    let mut shard_stream: Vec<Vec<usize>> = vec![Vec::new(); shards];
    for (i, &t) in tenant_of.iter().enumerate() {
        shard_stream[t % shards].push(i);
    }
    let mut next_arrival = vec![0usize; shards];
    let mut queues: Vec<DrrQueue> =
        (0..shards).map(|_| DrrQueue::new(tenants)).collect();

    // Setup once: encode, chunk, decoder state live across batches — the
    // exact discipline of the legacy drain.
    let mut prepared = PreparedJob::new(spec, alloc, a, cfg)?;
    let mut state = ScenarioState::new(spec, &cfg.dead_workers);
    let mut injector_slot: Option<crate::coordinator::StragglerInjector> = None;
    let mut grows_baseline: Option<u64> = None;

    let start = wall_now();
    let mut recorder = LatencyRecorder::new();
    let mut worst = 0.0f64;
    let mut job_slots: Vec<Option<JobReport>> = (0..n).map(|_| None).collect();
    let mut per_tenant: Vec<Vec<Duration>> = vec![Vec::new(); tenants];
    let mut batch_buf: Vec<usize> = Vec::new();
    let mut gather: Vec<Vec<f64>> = Vec::new();
    let mut served = 0usize;
    let mut queued = 0usize;
    let mut batch_idx = 0u64;
    let (mut batches, mut cross_shard, mut batch_jobs) = (0u64, 0u64, 0u64);
    let mut steals = 0u64;
    let mut max_batch_used = 0usize;
    let mut max_depth = 0usize;
    let mut rr = 0usize;

    while served < n {
        // Admit everything that has arrived by now, on every shard.
        let now = start.elapsed();
        for s in 0..shards {
            let stream = &shard_stream[s];
            let cur = &mut next_arrival[s];
            while *cur < stream.len() && arrival_offsets[stream[*cur]] <= now {
                queues[s].push(tenant_of[stream[*cur]], stream[*cur]);
                *cur += 1;
                queued += 1;
            }
        }
        max_depth = max_depth.max(queued);
        // Work-conserving rotation: serve the first backlogged shard from
        // the round-robin cursor onward.
        let mut chosen: Option<(usize, usize)> = None;
        for off in 0..shards {
            let s = (rr + off) % shards;
            if !queues[s].is_empty() {
                chosen = Some((s, off));
                break;
            }
        }
        let Some((s, off)) = chosen else {
            // Nothing admitted anywhere: sleep until the earliest pending
            // arrival (one exists — served + queued < n).
            let mut t_next: Option<Duration> = None;
            for s in 0..shards {
                if next_arrival[s] < shard_stream[s].len() {
                    let t = arrival_offsets[shard_stream[s][next_arrival[s]]];
                    t_next = Some(t_next.map_or(t, |x| x.min(t)));
                }
            }
            let t = t_next.ok_or_else(|| {
                Error::Runtime(
                    "front-end drain stalled with no pending arrivals".into(),
                )
            })?;
            let now = start.elapsed();
            if t > now {
                std::thread::sleep(t - now);
            }
            continue;
        };
        if off > 0 {
            cross_shard += 1;
        }
        // The sim's steal notion, ported: batch `b`'s home shard is
        // `b % shards`; draining any other shard is a steal.
        if s != (batch_idx as usize) % shards {
            steals += 1;
        }
        rr = (s + 1) % shards;
        let limit =
            controller.as_ref().map_or(fixed_limit, BatchController::limit);
        batch_buf.clear();
        queues[s].drain(&weights, limit, &mut batch_buf);
        let b = batch_buf.len();
        queued -= b;

        // Per-batch scenario advance and straggle seed: identical to the
        // legacy drain, keyed by the batch counter alone.
        state.advance(scenario, batch_idx)?;
        let batch_seed =
            derive_stream_seed(cfg.seed, batch_idx) ^ STRAGGLE_SEED_TAG;
        if injector_slot.is_none() {
            injector_slot = Some(state.injector(
                cfg.model,
                prepared.per_worker(),
                cfg.time_scale,
                batch_seed,
            )?);
        } else {
            let inj = injector_slot.as_mut().expect("slot checked above");
            state.injector_into(
                inj,
                cfg.model,
                prepared.per_worker(),
                cfg.time_scale,
                batch_seed,
            )?;
        }
        let injector = injector_slot.as_ref().expect("injector just staged");
        // A contiguous run of indices (always, in the degenerate config)
        // serves straight off the request slice — zero copies, and the
        // exact slice the legacy drain would pass. Cross-tenant batches
        // gather into a reused staging buffer (inner capacity survives
        // via clone_from).
        let contiguous = batch_buf.windows(2).all(|w| w[1] == w[0] + 1);
        let (reports, _observed) = if contiguous {
            let lo = batch_buf[0];
            prepared.run_batch_injected(
                &requests[lo..lo + b],
                Arc::clone(&compute),
                injector,
            )?
        } else {
            if gather.len() < b {
                gather.resize_with(b, Vec::new);
            }
            for (slot, &ji) in gather.iter_mut().zip(batch_buf.iter()) {
                slot.clone_from(&requests[ji]);
            }
            prepared.run_batch_injected(
                &gather[..b],
                Arc::clone(&compute),
                injector,
            )?
        };
        if grows_baseline.is_none() {
            // The first batch sizes every arena; steady state is measured
            // from here.
            grows_baseline = Some(prepared.scratch_grows());
        }
        let done = start.elapsed();
        for (i, report) in reports.into_iter().enumerate() {
            let ji = batch_buf[i];
            let sojourn = done.saturating_sub(arrival_offsets[ji]);
            recorder.record(sojourn, report.decoded.len());
            worst = fold_worst_error(worst, report.max_error);
            per_tenant[tenant_of[ji]].push(sojourn);
            if let Some(c) = controller.as_mut() {
                c.observe(sojourn.as_secs_f64());
            }
            job_slots[ji] = Some(report);
        }
        served += b;
        batch_idx += 1;
        batches += 1;
        batch_jobs += b as u64;
        max_batch_used = max_batch_used.max(b);
    }

    // Index-ordered emission: per-request reports in request order no
    // matter which shard/batch served them.
    let jobs: Vec<JobReport> = job_slots
        .into_iter()
        .enumerate()
        .map(|(i, r)| {
            r.ok_or_else(|| {
                Error::Runtime(format!("request {i} was never dispatched"))
            })
        })
        .collect::<Result<_>>()?;
    let serve = ServeReport {
        recorder,
        worst_error: worst,
        jobs,
        makespan: Some(start.elapsed()),
        encodes: prepared.encode_count(),
    };
    let front_report = FrontEndReport {
        shards,
        tenants,
        batches,
        cross_shard_batches: cross_shard,
        steals,
        mean_batch: batch_jobs as f64 / batches.max(1) as f64,
        max_batch_used,
        final_batch_limit: controller
            .as_ref()
            .map_or(fixed_limit, BatchController::limit),
        batch_grows: controller.as_ref().map_or(0, BatchController::grows),
        batch_shrinks: controller.as_ref().map_or(0, BatchController::shrinks),
        max_queue_depth: max_depth,
        tenant_of,
        per_tenant_p99: per_tenant.iter_mut().map(|s| p99(s)).collect(),
    };
    Ok(FrontServeReport {
        decode_cache: prepared.decode_cache_stats(),
        decode_cache_bypasses: prepared.decode_cache_bypasses(),
        post_setup_encodes: prepared.encode_count().saturating_sub(1),
        steady_allocs: grows_baseline
            .map_or(0, |base| prepared.scratch_grows() - base),
        serve,
        front: front_report,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_validation() {
        assert!(FrontEndConfig::default().validate().is_ok());
        let bad_shards = FrontEndConfig { shards: 0, ..Default::default() };
        assert!(bad_shards.validate().is_err());
        let bad_tenants = FrontEndConfig { tenants: 0, ..Default::default() };
        assert!(bad_tenants.validate().is_err());
        let arity = FrontEndConfig {
            tenants: 3,
            weights: vec![1.0, 2.0],
            ..Default::default()
        };
        assert!(arity.validate().is_err(), "weights/tenants arity");
        let negative = FrontEndConfig {
            tenants: 2,
            weights: vec![1.0, -1.0],
            ..Default::default()
        };
        assert!(negative.validate().is_err(), "negative weight");
        let zero_batch = FrontEndConfig {
            batch: Some(BatchPolicy::Fixed(0)),
            ..Default::default()
        };
        assert!(zero_batch.validate().is_err(), "zero fixed batch");
        let weighted = FrontEndConfig {
            shards: 2,
            tenants: 4,
            weights: vec![1.0, 2.0, 1.0, 4.0],
            batch: Some(BatchPolicy::Fixed(8)),
        };
        assert!(weighted.validate().is_ok());
    }

    #[test]
    fn p99_is_nearest_rank() {
        assert_eq!(p99(&mut []), Duration::ZERO);
        let mut one = vec![Duration::from_millis(5)];
        assert_eq!(p99(&mut one), Duration::from_millis(5));
        // 100 samples: nearest-rank p99 is the 99th order statistic.
        let mut s: Vec<Duration> =
            (1..=100).rev().map(Duration::from_millis).collect();
        assert_eq!(p99(&mut s), Duration::from_millis(99));
    }
}
