//! The master: dispatch, collect-until-`k`, decode.
//!
//! This module owns the **cold one-shot path** ([`run_job_impl`]): build
//! the generator, encode, dispatch to worker threads, collect until `k`,
//! decode. The serving loops live in [`crate::coordinator::Session`]
//! (which composes the cold path, the prepared fast path, and the
//! adaptive stream behind one builder); the free functions here are
//! `#[deprecated]` shims kept for source compatibility, each delegating
//! to an equivalent `Session` and proven bit-identical under fixed seeds
//! by `rust/tests/session_parity.rs`.

use crate::allocation::Allocation;
use crate::coding::code::{self, Code};
use crate::coding::{Decoder, Encoder, GeneratorKind, Matrix};
use crate::coordinator::session::{Mode, Session};
use crate::coordinator::{Compute, LatencyRecorder, StragglerInjector};
use crate::model::{ClusterSpec, LatencyModel};
use crate::runtime::pool::{PoolHandle, WorkPool};
use crate::{Error, Result};
use std::sync::mpsc;
use std::sync::Arc;
use crate::runtime::wall_now;
use std::time::Duration;

/// Configuration for one coded matvec job.
#[derive(Clone, Debug)]
pub struct JobConfig {
    /// Latency model used for straggle injection.
    pub model: LatencyModel,
    /// Seconds of wall time per unit of model time.
    pub time_scale: f64,
    /// RNG seed (straggle delays + generator matrix).
    pub seed: u64,
    /// Workers that never respond (permanent failures).
    pub dead_workers: Vec<usize>,
    /// MDS generator construction. Ignored when [`JobConfig::code`] names
    /// a registry code (the code then owns generator construction).
    pub generator: GeneratorKind,
    /// Registry name of the erasure code to serve with (the CLI `--code`
    /// flag; see [`crate::coding::code`]). `None` — the default — resolves
    /// the code from [`JobConfig::generator`], which keeps pre-registry
    /// configs bit-identical.
    pub code: Option<String>,
    /// Pool-size hint for sessions that build their own compute pool
    /// (`0` = available parallelism): [`crate::coordinator::SessionBuilder`]
    /// without an explicit [`SessionBuilder::pool`] handle builds a
    /// per-session [`WorkPool`] of this many workers when the hint is
    /// nonzero, and shares the global pool otherwise. Results are
    /// bit-identical for any value — this only bounds CPU use.
    ///
    /// (Historically the thread count of a per-call encode spawn; the
    /// name is kept so existing configs and the `--encode-threads` CLI
    /// flag keep working.)
    ///
    /// [`SessionBuilder::pool`]: crate::coordinator::SessionBuilder::pool
    pub encode_threads: usize,
    /// Capacity of the decode factorization cache on the prepared serving
    /// path (`0` disables caching). Each entry holds `O(k²)` doubles —
    /// ~8 MiB at `k = 1024` — so size this down for large `k` or diverse
    /// straggle patterns (see [`crate::coding::Decoder::new`]).
    pub decode_cache: usize,
    /// Recompute the uncoded `A·x` on the master to fill
    /// [`JobReport::max_error`] (default). This is O(k·d) *verification*
    /// work per request — disable it on the prepared serving path to
    /// measure the true straggle + collect + solve critical path
    /// (`max_error` is then NaN).
    pub verify_decode: bool,
    /// The persistent compute pool every parallel kernel of this job
    /// (encode matmul, multi-RHS decode) runs on. `None` = the shared
    /// global pool; sessions fill this at build time so one pool is
    /// reused across every batch of the stream.
    pub pool: Option<PoolHandle>,
}

impl Default for JobConfig {
    fn default() -> Self {
        JobConfig {
            model: LatencyModel::A,
            time_scale: 0.02,
            seed: 0xAB5,
            dead_workers: vec![],
            generator: GeneratorKind::SystematicRandom,
            code: None,
            encode_threads: 0,
            decode_cache: crate::coding::DEFAULT_FACTOR_CACHE,
            verify_decode: true,
            pool: None,
        }
    }
}

impl JobConfig {
    /// Per-call pool resolution: the attached handle if any, otherwise
    /// the shared global pool. Never constructs a pool (so per-request
    /// cold paths cannot regress into per-call spawns); the
    /// `encode_threads` hint is honored at *setup boundaries* via
    /// [`JobConfig::resolve_pool`], and on the cold path by capping the
    /// task split instead ([`crate::coding::Encoder::encode_capped`]).
    pub fn compute_pool(&self) -> PoolHandle {
        match &self.pool {
            Some(p) => Arc::clone(p),
            None => Arc::clone(WorkPool::global()),
        }
    }

    /// Setup-boundary pool resolution (session build, prepared-job
    /// construction): explicit handle first, then the `encode_threads`
    /// sizing hint — a dedicated pool built **once** for the session /
    /// prepared job and reused by every batch — then the shared global
    /// pool. This is what keeps a pre-pool `JobConfig { encode_threads:
    /// 2, .. }` bounding CPU use exactly as it used to.
    pub fn resolve_pool(&self) -> PoolHandle {
        match &self.pool {
            Some(p) => Arc::clone(p),
            None if self.encode_threads > 0 => {
                Arc::new(WorkPool::new(self.encode_threads))
            }
            None => Arc::clone(WorkPool::global()),
        }
    }

    /// Resolve the erasure code every setup/encode/decode of this job
    /// routes through: the registry entry named by [`JobConfig::code`] if
    /// set, otherwise the code for [`JobConfig::generator`]
    /// ([`code::for_kind`] — identical behaviour to the pre-registry
    /// hard-wiring). Errors list the registry's known names.
    pub fn resolve_code(&self) -> Result<Box<dyn Code>> {
        match &self.code {
            Some(name) => code::resolve(name),
            None => Ok(code::for_kind(self.generator)),
        }
    }
}

/// Outcome of one coded matvec job.
#[derive(Clone, Debug)]
pub struct JobReport {
    /// Wall time from dispatch to successful decode.
    pub wall_latency: Duration,
    /// The model-time latency the analysis would record for this sample.
    pub model_latency: Option<f64>,
    /// Decoded `A·x`.
    pub decoded: Vec<f64>,
    /// Max abs error vs the directly computed `A·x` (NaN when
    /// [`JobConfig::verify_decode`] is off).
    pub max_error: f64,
    /// Worker responses consumed before decoding.
    pub workers_used: usize,
    /// Coded rows aggregated before decoding.
    pub rows_collected: usize,
    /// Code length actually used (integer).
    pub n: usize,
    /// Compute backend name.
    pub backend: &'static str,
}

struct WorkerReply {
    #[allow(dead_code)] // kept for diagnostics/logging hooks
    worker: usize,
    pairs: Vec<(usize, f64)>,
}

/// The cold one-shot job: encode, dispatch, collect until `k`, decode.
/// Shared engine behind [`Mode::Single`], [`Mode::Sequential`], and
/// [`Mode::Pipelined`] — and, through them, the deprecated [`run_job`] /
/// [`serve_requests`] / [`serve_requests_pipelined`] shims.
///
/// `a` is the uncoded data matrix (`k × d`, `k = spec.k`); `x` the input
/// vector. Workers are real threads: each sleeps its injected straggle
/// delay, evaluates its chunk through `compute`, and replies; the master
/// returns as soon as `k` rows are aggregated and decoded. Worker threads
/// still sleeping are detached (their late results are discarded), so the
/// measured wall latency is the master's, not the stragglers'.
pub(crate) fn run_job_impl(
    spec: &ClusterSpec,
    alloc: &Allocation,
    a: &Matrix,
    x: &[f64],
    compute: Arc<dyn Compute>,
    cfg: &JobConfig,
) -> Result<JobReport> {
    if a.rows() != spec.k {
        return Err(Error::InvalidSpec(format!(
            "data matrix has {} rows, spec.k = {}",
            a.rows(),
            spec.k
        )));
    }
    alloc.validate(spec)?;
    let per_worker = alloc.per_worker_loads(spec);
    let n: usize = per_worker.iter().sum();

    // Encode & chunk (on the job's pool — no per-call thread spawns; an
    // `encode_threads` cap bounds the task split rather than building a
    // pool per call). Setup and encode route through the resolved
    // `Code`; for the dense MDS codes the call chain is identical to the
    // pre-trait hard-wiring, so the output is bit-identical.
    let job_code = cfg.resolve_code()?;
    let gen = job_code.setup(n, spec.k, cfg.seed ^ GENERATOR_SEED_TAG)?;
    let encoder = Encoder::new(gen.clone());
    let pool = cfg.compute_pool();
    let streams = if cfg.encode_threads > 0 {
        cfg.encode_threads
    } else {
        pool.threads()
    };
    let coded = job_code.encode(&encoder, a, &pool, streams)?;
    let chunks = encoder.chunk(&coded, &per_worker)?;

    // Straggle injection.
    let injector = StragglerInjector::sample(
        spec,
        cfg.model,
        &per_worker,
        cfg.time_scale,
        cfg.seed ^ STRAGGLE_SEED_TAG,
    )?
    .with_dead(cfg.dead_workers.iter().copied());
    let model_latency = injector.analytic_completion(&per_worker, spec.k);

    let x_arc: Arc<Vec<f64>> = Arc::new(x.to_vec());
    let (tx, rx) = mpsc::channel::<WorkerReply>();

    let start = wall_now();
    for chunk in chunks {
        let w = chunk.worker;
        if injector.is_dead(w) {
            continue; // dead worker: its sender never exists
        }
        let delay = injector.wall_delay(w);
        let xref = Arc::clone(&x_arc);
        let cmp = Arc::clone(&compute);
        let sender = tx.clone();
        // Allowlisted thread-creation site (lint rule D3): worker
        // emulation blocks in `sleep` for the injected wall delay, so it
        // cannot occupy a WorkPool worker without starving compute.
        #[allow(clippy::disallowed_methods)]
        std::thread::Builder::new()
            .name(format!("worker-{w}"))
            .spawn(move || {
                std::thread::sleep(delay);
                if let Ok(y) = cmp.matvec(&chunk.rows, &xref) {
                    let pairs: Vec<(usize, f64)> =
                        chunk.row_range.clone().zip(y).collect();
                    let _ = sender.send(WorkerReply { worker: w, pairs });
                }
            })
            .map_err(|e| Error::Runtime(format!("spawn worker {w}: {e}")))?;
    }
    drop(tx); // master holds only the receiver

    // Collect until k rows.
    let mut received: Vec<(usize, f64)> = Vec::with_capacity(spec.k + 64);
    let mut workers_used = 0usize;
    while received.len() < spec.k {
        match rx.recv() {
            Ok(reply) => {
                workers_used += 1;
                received.extend(reply.pairs);
            }
            Err(_) => {
                return Err(Error::Decode(format!(
                    "all live workers replied but only {} of {} rows arrived \
                     (too many dead workers?)",
                    received.len(),
                    spec.k
                )));
            }
        }
    }
    let rows_collected = received.len();
    // One-shot path: the decoder is dropped right here, so skip the
    // factorization cache (no key clone / map insert for a single solve).
    // Serving loops go through `PreparedJob`, which keeps a caching one.
    let decoded = Decoder::with_cache_capacity(gen, 0).decode(&received)?;
    let wall_latency = start.elapsed();

    let max_error = if cfg.verify_decode {
        let truth = a.matvec(x);
        decoded
            .iter()
            .zip(&truth)
            .map(|(d, t)| (d - t).abs())
            .fold(0.0f64, f64::max)
    } else {
        f64::NAN
    };

    Ok(JobReport {
        wall_latency,
        model_latency,
        decoded,
        max_error,
        workers_used,
        rows_collected,
        n,
        backend: compute.name(),
    })
}

/// Run one coded distributed matvec job end-to-end.
///
/// Migration: `Session::builder(spec).allocation(alloc.clone())
/// .data(a.clone()).requests(vec![x.to_vec()]).config(cfg.clone())
/// .compute(compute).mode(Mode::Single).build()?.serve()?` — the single
/// report is `outcome.jobs[0]`.
#[deprecated(
    since = "0.2.0",
    note = "build a coordinator::Session with Mode::Single instead"
)]
pub fn run_job(
    spec: &ClusterSpec,
    alloc: &Allocation,
    a: &Matrix,
    x: &[f64],
    compute: Arc<dyn Compute>,
    cfg: &JobConfig,
) -> Result<JobReport> {
    let outcome = Session::builder(spec)
        .allocation(alloc.clone())
        .data(a.clone())
        .requests(vec![x.to_vec()])
        .config(cfg.clone())
        .compute(compute)
        .mode(Mode::Single)
        .build()?
        .serve()?;
    outcome
        .jobs
        .into_iter()
        .next()
        .ok_or_else(|| Error::Runtime("session produced no job report".into()))
}

/// Domain-separation tag so straggle delays and generator entries never share
/// an RNG stream even though both derive from `JobConfig::seed`.
pub(crate) const STRAGGLE_SEED_TAG: u64 = 0x57A6_61E5_57A6_61E5;

/// Domain-separation tag for the generator-matrix RNG stream.
pub(crate) const GENERATOR_SEED_TAG: u64 = 0x6E6;

/// Per-batch seed derivation shared by every serving loop (and by tests
/// replaying a serving stream batch by batch): batch `i` (0-based) gets
/// `seed + GOLDEN·(i+1)`.
pub fn derive_stream_seed(base: u64, index: u64) -> u64 {
    base.wrapping_add(0x9E37_79B9u64.wrapping_mul(index + 1))
}

/// Fold a per-job `max_error` into a running worst. NaN (verification
/// disabled) is sticky — `f64::max` would silently drop it and report a
/// perfect 0.0 for a stream where nothing was verified.
pub(crate) fn fold_worst_error(worst: f64, max_error: f64) -> f64 {
    if worst.is_nan() || max_error.is_nan() {
        f64::NAN
    } else {
        worst.max(max_error)
    }
}

/// Result of serving a batch of requests.
#[derive(Debug)]
pub struct ServeReport {
    /// Per-request latency metrics.
    pub recorder: LatencyRecorder,
    /// Max decode error across requests (NaN — not 0 — when
    /// [`JobConfig::verify_decode`] is off: nothing was verified).
    pub worst_error: f64,
    /// Per-request reports.
    pub jobs: Vec<JobReport>,
    /// Wall time for the whole batch (set by the pipelined and
    /// arrival-replay serving modes; `None` for the sequential loop).
    pub makespan: Option<Duration>,
    /// Encode passes performed while serving. On the prepared
    /// [`serve_arrivals`] path this is a live measurement (the encoder's
    /// own call counter) and stays `1` regardless of batch count; on the
    /// one-shot loops it is `jobs.len()` by construction — each `run_job`
    /// builds and invokes its encoder exactly once.
    pub encodes: u64,
}

/// Run one **batched** coded matvec job: each worker receives its chunk
/// once and evaluates it against all `B` request vectors in a single
/// backend dispatch (vLLM-style request batching — the contraction becomes
/// an MXU-shaped `(l_i × d)·(d × B)` matmul on the XLA backend). The master
/// waits until the aggregated rows reach `k`, then decodes every request
/// from the *same* row support.
///
/// Compared to [`serve_requests`], a batch pays the straggle penalty once
/// for all `B` requests — per-request latency equals the batch latency, but
/// throughput rises by ~`B`.
///
/// This is the *one-shot* convenience wrapper: it builds a
/// [`crate::coordinator::PreparedJob`] (generator, encode, chunk) and runs
/// a single batch through it, so it re-encodes on every call. Serving
/// loops should use an arrivals-mode [`Session`] (or construct the
/// `PreparedJob` themselves) and reuse it across batches.
///
/// Migration: `Session::builder(spec).allocation(alloc.clone())
/// .data(a.clone()).requests(requests.to_vec()).config(cfg.clone())
/// .compute(compute).mode(Mode::Batched).build()?.serve()?` — the reports
/// are `outcome.jobs`.
#[deprecated(
    since = "0.2.0",
    note = "build a coordinator::Session with Mode::Batched instead"
)]
pub fn run_job_batched(
    spec: &ClusterSpec,
    alloc: &Allocation,
    a: &Matrix,
    requests: &[Vec<f64>],
    compute: Arc<dyn Compute>,
    cfg: &JobConfig,
) -> Result<Vec<JobReport>> {
    let outcome = Session::builder(spec)
        .allocation(alloc.clone())
        .data(a.clone())
        .requests(requests.to_vec())
        .config(cfg.clone())
        .compute(compute)
        .mode(Mode::Batched)
        .build()?
        .serve()?;
    Ok(outcome.jobs)
}

/// Serve `requests` concurrently (pipelined): every request's workers are
/// dispatched immediately on their own threads, so request `i+1` does not
/// wait for request `i`'s stragglers. Returns per-request latencies plus the
/// batch makespan — the throughput view of the system.
///
/// Migration: `Session::builder(spec).allocation(alloc.clone())
/// .data(a.clone()).requests(requests.to_vec()).config(cfg.clone())
/// .compute(compute).mode(Mode::Pipelined).build()?.serve()?`.
#[deprecated(
    since = "0.2.0",
    note = "build a coordinator::Session with Mode::Pipelined instead"
)]
pub fn serve_requests_pipelined(
    spec: &ClusterSpec,
    alloc: &Allocation,
    a: &Matrix,
    requests: &[Vec<f64>],
    compute: Arc<dyn Compute>,
    cfg: &JobConfig,
) -> Result<ServeReport> {
    Session::builder(spec)
        .allocation(alloc.clone())
        .data(a.clone())
        .requests(requests.to_vec())
        .config(cfg.clone())
        .compute(compute)
        .mode(Mode::Pipelined)
        .build()?
        .serve()
        .map(super::ServeOutcome::into_serve_report)
}

/// Serve a *stream* of requests arriving at `arrival_offsets` (wall-clock
/// offsets from the serving start, ascending) through the batched live
/// path: the master sleeps until the head-of-line request has arrived,
/// drains everything queued behind it up to `max_batch` requests, and
/// dispatches the whole batch as **one** coded job via
/// [`crate::coordinator::PreparedJob::run_batch`] — each worker evaluates
/// its chunk against all queued vectors in a single backend call (the
/// MXU-shaped `MatvecBatched` artifacts on the XLA backend, a loop on the
/// native backend).
///
/// This is the live counterpart of the workload layer's queueing
/// simulation ([`crate::workload`]): under light traffic batches have size
/// 1 and the system behaves like [`serve_requests`]; as the arrival rate
/// climbs, queued requests amortize the straggle penalty and per-request
/// throughput rises. The recorder tracks each request's *sojourn* (arrival
/// → decoded), not just its batch's service time.
///
/// The encode is hoisted: one [`crate::coordinator::PreparedJob`]
/// (generator, `Ã = G·A`, per-worker chunks, factorization-cached decoder)
/// is built up front and reused for every batch, so steady-state serving
/// performs zero encode/chunk work after the first batch
/// ([`ServeReport::encodes`] stays 1). Each batch still draws a fresh
/// straggle realization from a derived seed ([`derive_stream_seed`]); the
/// generator itself is fixed for the stream, which only pins *which* MDS
/// code serves the traffic, not the stochastic process being measured.
///
/// This is the static-cluster view: the failure/drift-aware loop with the
/// same batching semantics (and bit-identical behaviour under an empty
/// scenario) attaches through [`SessionBuilder::scenario`] /
/// [`SessionBuilder::adaptive`] on the same arrivals mode.
///
/// Migration: `Session::builder(spec).allocation(alloc.clone())
/// .data(a.clone()).requests(requests.to_vec()).config(cfg.clone())
/// .compute(compute).mode(Mode::Arrivals { offsets, max_batch })
/// .build()?.serve()?`.
///
/// [`SessionBuilder::scenario`]: crate::coordinator::SessionBuilder::scenario
/// [`SessionBuilder::adaptive`]: crate::coordinator::SessionBuilder::adaptive
#[deprecated(
    since = "0.2.0",
    note = "build a coordinator::Session with Mode::Arrivals instead"
)]
#[allow(clippy::too_many_arguments)]
pub fn serve_arrivals(
    spec: &ClusterSpec,
    alloc: &Allocation,
    a: &Matrix,
    requests: &[Vec<f64>],
    arrival_offsets: &[Duration],
    max_batch: usize,
    compute: Arc<dyn Compute>,
    cfg: &JobConfig,
) -> Result<ServeReport> {
    Session::builder(spec)
        .allocation(alloc.clone())
        .data(a.clone())
        .requests(requests.to_vec())
        .config(cfg.clone())
        .compute(compute)
        .mode(Mode::Arrivals {
            offsets: arrival_offsets.to_vec(),
            max_batch,
        })
        .build()?
        .serve()
        .map(super::ServeOutcome::into_serve_report)
}

/// Serve `requests` input vectors sequentially over the same cluster and
/// allocation, recording latency percentiles (the serving-loop view of the
/// system). Each request draws fresh straggle delays (seed-derived).
///
/// Migration: `Session::builder(spec).allocation(alloc.clone())
/// .data(a.clone()).requests(requests.to_vec()).config(cfg.clone())
/// .compute(compute).mode(Mode::Sequential).build()?.serve()?`.
#[deprecated(
    since = "0.2.0",
    note = "build a coordinator::Session with Mode::Sequential instead"
)]
pub fn serve_requests(
    spec: &ClusterSpec,
    alloc: &Allocation,
    a: &Matrix,
    requests: &[Vec<f64>],
    compute: Arc<dyn Compute>,
    cfg: &JobConfig,
) -> Result<ServeReport> {
    Session::builder(spec)
        .allocation(alloc.clone())
        .data(a.clone())
        .requests(requests.to_vec())
        .config(cfg.clone())
        .compute(compute)
        .mode(Mode::Sequential)
        .build()?
        .serve()
        .map(|outcome| {
            // The documented legacy shape: the sequential loop reports no
            // makespan (per-request latencies are the measure).
            let mut report = outcome.into_serve_report();
            report.makespan = None;
            report
        })
}

#[cfg(test)]
// The deprecated shims are exercised deliberately: these tests double as
// regression coverage that each shim still reproduces its historical
// behaviour through the Session facade.
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::allocation::proposed_allocation;
    use crate::coordinator::NativeCompute;
    use crate::math::Rng;
    use crate::model::Group;

    fn small_spec() -> ClusterSpec {
        ClusterSpec::new(
            vec![
                Group { n: 4, mu: 8.0, alpha: 1.0 },
                Group { n: 6, mu: 2.0, alpha: 1.0 },
            ],
            64,
        )
        .unwrap()
    }

    fn data(k: usize, d: usize, seed: u64) -> (Matrix, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let a = Matrix::from_fn(k, d, |_, _| rng.normal());
        let x = (0..d).map(|_| rng.normal()).collect();
        (a, x)
    }

    fn fast_cfg() -> JobConfig {
        JobConfig { time_scale: 0.002, ..Default::default() }
    }

    #[test]
    fn job_decodes_correctly() {
        let spec = small_spec();
        let alloc = proposed_allocation(LatencyModel::A, &spec).unwrap();
        let (a, x) = data(64, 8, 42);
        let report = run_job(
            &spec,
            &alloc,
            &a,
            &x,
            Arc::new(NativeCompute),
            &fast_cfg(),
        )
        .unwrap();
        assert!(report.max_error < 1e-8, "err {}", report.max_error);
        assert_eq!(report.decoded.len(), 64);
        assert!(report.rows_collected >= 64);
        assert!(report.workers_used <= 10);
        assert!(report.model_latency.is_some());
    }

    #[test]
    fn job_survives_dead_workers() {
        // Use a rate-1/2 uniform allocation so the code carries enough
        // redundancy to lose two workers (the proposed allocation on this
        // small cluster is near rate 1 and tolerates almost no failures).
        let spec = small_spec();
        let alloc =
            crate::allocation::uniform_allocation(LatencyModel::A, &spec, 128.0).unwrap();
        let (a, x) = data(64, 8, 43);
        let mut cfg = fast_cfg();
        cfg.dead_workers = vec![0, 5];
        let report =
            run_job(&spec, &alloc, &a, &x, Arc::new(NativeCompute), &cfg).unwrap();
        assert!(report.max_error < 1e-8);
    }

    #[test]
    fn job_fails_with_too_many_dead() {
        let spec = small_spec();
        let alloc = proposed_allocation(LatencyModel::A, &spec).unwrap();
        let (a, x) = data(64, 8, 44);
        let mut cfg = fast_cfg();
        cfg.dead_workers = (0..9).collect(); // one survivor cannot cover k
        let res = run_job(&spec, &alloc, &a, &x, Arc::new(NativeCompute), &cfg);
        assert!(res.is_err());
    }

    #[test]
    fn wall_latency_tracks_model_latency() {
        // The measured wall latency should be close to
        // model_latency * time_scale (compute time is tiny here).
        let spec = small_spec();
        let alloc = proposed_allocation(LatencyModel::A, &spec).unwrap();
        let (a, x) = data(64, 8, 45);
        let cfg = JobConfig { time_scale: 0.05, ..Default::default() };
        let report =
            run_job(&spec, &alloc, &a, &x, Arc::new(NativeCompute), &cfg).unwrap();
        let expected = report.model_latency.unwrap() * 0.05;
        let wall = report.wall_latency.as_secs_f64();
        assert!(
            wall >= expected * 0.9 && wall < expected * 2.0 + 0.05,
            "wall {wall} vs expected {expected}"
        );
    }

    #[test]
    fn batched_job_decodes_every_request() {
        let spec = small_spec();
        let alloc = proposed_allocation(LatencyModel::A, &spec).unwrap();
        let (a, _) = data(64, 8, 50);
        let mut rng = Rng::new(51);
        let requests: Vec<Vec<f64>> =
            (0..4).map(|_| (0..8).map(|_| rng.normal()).collect()).collect();
        let reports = run_job_batched(
            &spec,
            &alloc,
            &a,
            &requests,
            Arc::new(NativeCompute),
            &fast_cfg(),
        )
        .unwrap();
        assert_eq!(reports.len(), 4);
        for r in &reports {
            assert!(r.max_error < 1e-8, "err {}", r.max_error);
            assert_eq!(r.decoded.len(), 64);
        }
        // All requests share one straggle realization → identical latency.
        assert!(reports.windows(2).all(|w| w[0].wall_latency == w[1].wall_latency));
        // Empty batch rejected.
        assert!(run_job_batched(
            &spec,
            &alloc,
            &a,
            &[],
            Arc::new(NativeCompute),
            &fast_cfg()
        )
        .is_err());
    }

    #[test]
    fn pipelined_serving_beats_sequential_makespan() {
        let spec = small_spec();
        let alloc = proposed_allocation(LatencyModel::A, &spec).unwrap();
        let (a, _) = data(64, 8, 48);
        let mut rng = Rng::new(49);
        let requests: Vec<Vec<f64>> =
            (0..6).map(|_| (0..8).map(|_| rng.normal()).collect()).collect();
        let cfg = JobConfig { time_scale: 0.05, ..Default::default() };
        let t0 = wall_now();
        let seq = serve_requests(
            &spec,
            &alloc,
            &a,
            &requests,
            Arc::new(NativeCompute),
            &cfg,
        )
        .unwrap();
        let seq_makespan = t0.elapsed();
        let pip = serve_requests_pipelined(
            &spec,
            &alloc,
            &a,
            &requests,
            Arc::new(NativeCompute),
            &cfg,
        )
        .unwrap();
        assert_eq!(pip.recorder.count(), 6);
        assert!(pip.worst_error < 1e-8);
        let makespan = pip.makespan.unwrap();
        // All six requests overlap: makespan ≈ one request's latency, far
        // below the sequential sum.
        assert!(
            makespan < seq_makespan / 2,
            "pipelined {makespan:?} !< sequential {seq_makespan:?} / 2"
        );
        let _ = seq;
    }

    #[test]
    fn serve_arrivals_batches_queued_requests() {
        let spec = small_spec();
        // Redundant rate-1/2 code so batching has room to decode.
        let alloc =
            crate::allocation::uniform_allocation(LatencyModel::A, &spec, 128.0)
                .unwrap();
        let (a, _) = data(64, 8, 52);
        let mut rng = Rng::new(53);
        let requests: Vec<Vec<f64>> =
            (0..6).map(|_| (0..8).map(|_| rng.normal()).collect()).collect();
        // Two back-to-back bursts: requests 0-2 arrive immediately, 3-5
        // shortly after; each burst should drain as at most two batches of
        // the configured width.
        let offsets: Vec<Duration> = [0u64, 0, 0, 30, 30, 30]
            .iter()
            .map(|&ms| Duration::from_millis(ms))
            .collect();
        let report = serve_arrivals(
            &spec,
            &alloc,
            &a,
            &requests,
            &offsets,
            4,
            Arc::new(NativeCompute),
            &fast_cfg(),
        )
        .unwrap();
        assert_eq!(report.recorder.count(), 6);
        assert_eq!(report.jobs.len(), 6);
        assert!(report.worst_error < 1e-8, "err {}", report.worst_error);
        assert!(report.makespan.is_some());
        // The prepared path encodes once for the whole stream.
        assert_eq!(report.encodes, 1);
        // Sojourn percentiles are well-formed.
        assert!(
            report.recorder.percentile(95.0) >= report.recorder.percentile(50.0)
        );
    }

    #[test]
    fn serve_arrivals_validates_inputs() {
        let spec = small_spec();
        let alloc = proposed_allocation(LatencyModel::A, &spec).unwrap();
        let (a, x) = data(64, 8, 54);
        let reqs = vec![x.clone(), x];
        let ok = vec![Duration::ZERO, Duration::from_millis(1)];
        assert!(serve_arrivals(
            &spec,
            &alloc,
            &a,
            &reqs,
            &ok[..1],
            4,
            Arc::new(NativeCompute),
            &fast_cfg()
        )
        .is_err());
        assert!(serve_arrivals(
            &spec,
            &alloc,
            &a,
            &reqs,
            &ok,
            0,
            Arc::new(NativeCompute),
            &fast_cfg()
        )
        .is_err());
        let unsorted = vec![Duration::from_millis(5), Duration::ZERO];
        assert!(serve_arrivals(
            &spec,
            &alloc,
            &a,
            &reqs,
            &unsorted,
            4,
            Arc::new(NativeCompute),
            &fast_cfg()
        )
        .is_err());
    }

    #[test]
    fn serve_records_all_requests() {
        let spec = small_spec();
        let alloc = proposed_allocation(LatencyModel::A, &spec).unwrap();
        let (a, _) = data(64, 8, 46);
        let mut rng = Rng::new(47);
        let requests: Vec<Vec<f64>> =
            (0..5).map(|_| (0..8).map(|_| rng.normal()).collect()).collect();
        let report = serve_requests(
            &spec,
            &alloc,
            &a,
            &requests,
            Arc::new(NativeCompute),
            &fast_cfg(),
        )
        .unwrap();
        assert_eq!(report.recorder.count(), 5);
        assert!(report.worst_error < 1e-8);
        assert_eq!(report.jobs.len(), 5);
    }
}
