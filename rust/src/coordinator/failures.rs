//! Failure and drift scenarios for the live serving path.
//!
//! The paper's analysis fixes `(N_j, μ_j, α_j)` for the whole job. A
//! serving stream is longer-lived than that assumption: workers die
//! mid-stream, individual machines slow down, and whole groups drift. A
//! [`FailureScenario`] scripts those events against *batch indices* of a
//! serving stream, and [`ScenarioState`] replays them into the concrete
//! knobs the coordinator already has — the effective [`ClusterSpec`] the
//! straggle sampler draws from, the dead-worker set, and per-worker
//! slowdown multipliers ([`StragglerInjector::with_slowdowns`]).
//!
//! The model-time counterpart for the Monte-Carlo/queueing layer (events
//! scripted against the *simulation clock*) is
//! [`crate::workload::drift::DriftSchedule`]; both speak the same kinds of
//! events so an experiment can be mirrored across the two stacks.
//!
//! A "2× slowdown" is time dilation — the machine does everything at half
//! speed — so [`FailureKind::SlowGroup`] scales the shift *and* the tail
//! (`α ← f·α`, `μ ← μ/f`). [`FailureKind::ScaleGroupMu`] is the tail-only
//! drift (μ-drift) for experiments that keep the deterministic part fixed.

use crate::coordinator::StragglerInjector;
use crate::model::{ClusterSpec, LatencyModel};
use crate::{Error, Result};
use std::collections::BTreeSet;

/// One scripted change to the cluster.
#[derive(Clone, Debug, PartialEq)]
pub enum FailureKind {
    /// Permanent deaths: these workers never respond again.
    KillWorkers(Vec<usize>),
    /// Machine-level slowdown: every listed worker's completion times are
    /// dilated by `factor` from this batch on.
    SlowWorkers {
        /// Global worker ids (group-major order).
        workers: Vec<usize>,
        /// Time-dilation factor (`> 1` = slower).
        factor: f64,
    },
    /// Group-level slowdown (time dilation): `α ← f·α`, `μ ← μ/f`.
    SlowGroup {
        /// Group index.
        group: usize,
        /// Time-dilation factor (`> 1` = slower).
        factor: f64,
    },
    /// Tail-only drift of a group's straggling parameter: `μ ← f·μ`.
    ScaleGroupMu {
        /// Group index.
        group: usize,
        /// Multiplicative μ factor (`< 1` = heavier straggling).
        factor: f64,
    },
    /// Lossy links: from this batch on, every packet a worker in `group`
    /// sends is dropped i.i.d. with probability `p` (Bernoulli per
    /// packet, deterministic given the batch seed). Repeated events
    /// *replace* the group's loss rate — loss is a link property, not a
    /// compounding multiplier. `p = 0` heals the link.
    LossyGroup {
        /// Group index.
        group: usize,
        /// Per-packet drop probability in `[0, 1]`.
        p: f64,
    },
    /// Burst drop: every packet from `group` is dropped for `batches`
    /// serving batches starting at the event batch, then the link heals
    /// back to the group's Bernoulli rate (if any). Composable with
    /// kill/slow/drift events at the same batches.
    BurstDrop {
        /// Group index.
        group: usize,
        /// Number of batches the burst lasts (`>= 1`).
        batches: u64,
    },
    /// Stall: from this batch on the worker is *alive but dark* — it
    /// accepts its dispatch and never replies. Unlike
    /// [`FailureKind::KillWorkers`] the coordinator cannot tell up front;
    /// only a blown deadline (the recovery layer) reveals it. Serving a
    /// stall script requires [`crate::coordinator::SessionBuilder::recovery`]
    /// — the legacy collection loop would block forever.
    StallWorker {
        /// Global worker id (group-major order).
        worker: usize,
    },
    /// Flap: starting at this batch the worker alternates `period` dark
    /// batches and `period` healthy batches, dark phase first. The
    /// periodic stall/recover pattern exercises quarantine re-admission.
    FlappyWorker {
        /// Global worker id (group-major order).
        worker: usize,
        /// Batches per phase (`>= 1`).
        period: u64,
    },
    /// Per-*worker* lossy link: from this batch on, every packet this
    /// worker sends is additionally dropped i.i.d. with probability `p`,
    /// composing with any group-level loss (independent channels:
    /// `p = 1 - (1-p_group)(1-p_worker)`). Repeated events replace the
    /// worker's own rate; `p = 0` heals it. Composable with stall/flap.
    LossyWorker {
        /// Global worker id (group-major order).
        worker: usize,
        /// Per-packet drop probability in `[0, 1]`.
        p: f64,
    },
}

/// A [`FailureKind`] that fires before serving batch `at_batch` (0-based).
#[derive(Clone, Debug, PartialEq)]
pub struct FailureEvent {
    /// Batch index the event takes effect at.
    pub at_batch: u64,
    /// What happens.
    pub kind: FailureKind,
}

/// An ordered script of failure/drift events for one serving stream.
#[derive(Clone, Debug, Default)]
pub struct FailureScenario {
    events: Vec<FailureEvent>,
}

impl FailureScenario {
    /// Build a scenario, validating factors and sorting events by batch
    /// (stable, so same-batch events apply in authoring order).
    pub fn new(mut events: Vec<FailureEvent>) -> Result<FailureScenario> {
        for e in &events {
            match &e.kind {
                FailureKind::KillWorkers(ws) => {
                    if ws.is_empty() {
                        return Err(Error::InvalidSpec(
                            "KillWorkers with no workers".into(),
                        ));
                    }
                }
                FailureKind::SlowWorkers { workers, factor } => {
                    if workers.is_empty() {
                        return Err(Error::InvalidSpec(
                            "SlowWorkers with no workers".into(),
                        ));
                    }
                    validate_factor(*factor)?;
                }
                FailureKind::SlowGroup { factor, .. }
                | FailureKind::ScaleGroupMu { factor, .. } => {
                    validate_factor(*factor)?;
                }
                FailureKind::LossyGroup { p, .. } => {
                    if !(*p >= 0.0 && *p <= 1.0) {
                        return Err(Error::InvalidSpec(format!(
                            "loss probability must be in [0, 1], got {p}"
                        )));
                    }
                }
                FailureKind::BurstDrop { batches, .. } => {
                    if *batches == 0 {
                        return Err(Error::InvalidSpec(
                            "BurstDrop must last at least one batch".into(),
                        ));
                    }
                }
                FailureKind::StallWorker { .. } => {}
                FailureKind::FlappyWorker { period, .. } => {
                    if *period == 0 {
                        return Err(Error::InvalidSpec(
                            "FlappyWorker phase must last at least one batch"
                                .into(),
                        ));
                    }
                }
                FailureKind::LossyWorker { p, .. } => {
                    if !(*p >= 0.0 && *p <= 1.0) {
                        return Err(Error::InvalidSpec(format!(
                            "worker loss probability must be in [0, 1], got {p}"
                        )));
                    }
                }
            }
        }
        events.sort_by_key(|e| e.at_batch);
        Ok(FailureScenario { events })
    }

    /// The empty scenario (a plain static stream).
    pub fn none() -> FailureScenario {
        FailureScenario::default()
    }

    /// No events scripted?
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Scripted events, ordered by batch.
    pub fn events(&self) -> &[FailureEvent] {
        &self.events
    }

    /// Does the script contain any lossy-link event
    /// ([`FailureKind::LossyGroup`] / [`FailureKind::BurstDrop`])? The
    /// session uses this to route fixed-`n` MDS serving onto the
    /// loss-aware collection path up front rather than discovering loss
    /// mid-stream.
    pub fn has_loss(&self) -> bool {
        self.events.iter().any(|e| {
            matches!(
                e.kind,
                FailureKind::LossyGroup { .. }
                    | FailureKind::BurstDrop { .. }
                    | FailureKind::LossyWorker { .. }
            )
        })
    }

    /// Does the script contain any stall/flap event? The session refuses
    /// such scripts without a recovery config attached: a stalled worker
    /// holds its rows forever, so the legacy blocking collection loop
    /// would hang waiting for a reply that never comes.
    pub fn has_stall(&self) -> bool {
        self.events.iter().any(|e| {
            matches!(
                e.kind,
                FailureKind::StallWorker { .. }
                    | FailureKind::FlappyWorker { .. }
            )
        })
    }

    /// Parse the CLI mini-syntax:
    ///
    /// - `failures`: `BATCH:w1,w2[;BATCH:w3...]` — kill workers at a batch;
    /// - `drift`: `BATCH:GROUP:FACTOR[;...]` — dilate a group `FACTOR`×
    ///   (i.e. [`FailureKind::SlowGroup`]) at a batch.
    pub fn parse(failures: Option<&str>, drift: Option<&str>) -> Result<FailureScenario> {
        FailureScenario::parse_with_loss(failures, drift, None)
    }

    /// [`FailureScenario::parse`] plus the lossy-link dialect:
    ///
    /// - `loss`: `BATCH:GROUP:P[;...]` — Bernoulli per-packet drop with
    ///   probability `P` on group `GROUP`'s links from batch `BATCH`
    ///   ([`FailureKind::LossyGroup`]); or
    ///   `BATCH:GROUP:burst:BATCHES` — drop *everything* from the group
    ///   for `BATCHES` batches ([`FailureKind::BurstDrop`]).
    pub fn parse_with_loss(
        failures: Option<&str>,
        drift: Option<&str>,
        loss: Option<&str>,
    ) -> Result<FailureScenario> {
        FailureScenario::parse_compound(failures, drift, loss, None, None, None)
    }

    /// The full CLI dialect: [`FailureScenario::parse_with_loss`] plus the
    /// recovery-layer scenarios:
    ///
    /// - `stall`: `BATCH:w1,w2[;...]` — the listed workers go dark
    ///   (alive, never reply) from that batch on
    ///   ([`FailureKind::StallWorker`]);
    /// - `flap`: `BATCH:WORKER:PERIOD[;...]` — the worker alternates
    ///   `PERIOD` dark and `PERIOD` healthy batches
    ///   ([`FailureKind::FlappyWorker`]);
    /// - `worker_loss`: `BATCH:WORKER:P[;...]` — per-worker Bernoulli
    ///   packet drop composing with group loss
    ///   ([`FailureKind::LossyWorker`]).
    pub fn parse_compound(
        failures: Option<&str>,
        drift: Option<&str>,
        loss: Option<&str>,
        stall: Option<&str>,
        flap: Option<&str>,
        worker_loss: Option<&str>,
    ) -> Result<FailureScenario> {
        let mut events = Vec::new();
        if let Some(spec) = stall {
            for part in spec.split(';').filter(|s| !s.is_empty()) {
                let (batch, list) = part.split_once(':').ok_or_else(|| {
                    Error::InvalidSpec(format!(
                        "--stall entry `{part}` is not BATCH:w1,w2"
                    ))
                })?;
                let at_batch = parse_num::<u64>("stall batch", batch)?;
                for w in list.split(',').filter(|s| !s.is_empty()) {
                    events.push(FailureEvent {
                        at_batch,
                        kind: FailureKind::StallWorker {
                            worker: parse_num::<usize>("stall worker", w)?,
                        },
                    });
                }
            }
        }
        if let Some(spec) = flap {
            for part in spec.split(';').filter(|s| !s.is_empty()) {
                let fields: Vec<&str> = part.split(':').collect();
                if fields.len() != 3 {
                    return Err(Error::InvalidSpec(format!(
                        "--flap entry `{part}` is not BATCH:WORKER:PERIOD"
                    )));
                }
                events.push(FailureEvent {
                    at_batch: parse_num::<u64>("flap batch", fields[0])?,
                    kind: FailureKind::FlappyWorker {
                        worker: parse_num::<usize>("flap worker", fields[1])?,
                        period: parse_num::<u64>("flap period", fields[2])?,
                    },
                });
            }
        }
        if let Some(spec) = worker_loss {
            for part in spec.split(';').filter(|s| !s.is_empty()) {
                let fields: Vec<&str> = part.split(':').collect();
                if fields.len() != 3 {
                    return Err(Error::InvalidSpec(format!(
                        "--worker-loss entry `{part}` is not BATCH:WORKER:P"
                    )));
                }
                events.push(FailureEvent {
                    at_batch: parse_num::<u64>("worker-loss batch", fields[0])?,
                    kind: FailureKind::LossyWorker {
                        worker: parse_num::<usize>(
                            "worker-loss worker",
                            fields[1],
                        )?,
                        p: parse_num::<f64>("worker-loss probability", fields[2])?,
                    },
                });
            }
        }
        if let Some(spec) = loss {
            for part in spec.split(';').filter(|s| !s.is_empty()) {
                let fields: Vec<&str> = part.split(':').collect();
                let kind = match fields.as_slice() {
                    [_, group, p] => FailureKind::LossyGroup {
                        group: parse_num::<usize>("loss group", group)?,
                        p: parse_num::<f64>("loss probability", p)?,
                    },
                    [_, group, burst, batches] if burst.trim() == "burst" => {
                        FailureKind::BurstDrop {
                            group: parse_num::<usize>("loss group", group)?,
                            batches: parse_num::<u64>("burst batches", batches)?,
                        }
                    }
                    _ => {
                        return Err(Error::InvalidSpec(format!(
                            "--loss entry `{part}` is not BATCH:GROUP:P or \
                             BATCH:GROUP:burst:BATCHES"
                        )))
                    }
                };
                events.push(FailureEvent {
                    at_batch: parse_num::<u64>("loss batch", fields[0])?,
                    kind,
                });
            }
        }
        if let Some(spec) = failures {
            for part in spec.split(';').filter(|s| !s.is_empty()) {
                let (batch, list) = part.split_once(':').ok_or_else(|| {
                    Error::InvalidSpec(format!(
                        "--failures entry `{part}` is not BATCH:w1,w2"
                    ))
                })?;
                let at_batch = parse_num::<u64>("failures batch", batch)?;
                let workers = list
                    .split(',')
                    .filter(|s| !s.is_empty())
                    .map(|s| parse_num::<usize>("failures worker", s))
                    .collect::<Result<Vec<_>>>()?;
                events.push(FailureEvent {
                    at_batch,
                    kind: FailureKind::KillWorkers(workers),
                });
            }
        }
        if let Some(spec) = drift {
            for part in spec.split(';').filter(|s| !s.is_empty()) {
                let fields: Vec<&str> = part.split(':').collect();
                if fields.len() != 3 {
                    return Err(Error::InvalidSpec(format!(
                        "--drift entry `{part}` is not BATCH:GROUP:FACTOR"
                    )));
                }
                events.push(FailureEvent {
                    at_batch: parse_num::<u64>("drift batch", fields[0])?,
                    kind: FailureKind::SlowGroup {
                        group: parse_num::<usize>("drift group", fields[1])?,
                        factor: parse_num::<f64>("drift factor", fields[2])?,
                    },
                });
            }
        }
        FailureScenario::new(events)
    }
}

fn validate_factor(f: f64) -> Result<()> {
    if !(f > 0.0) || !f.is_finite() {
        return Err(Error::InvalidSpec(format!(
            "scenario factor must be positive and finite, got {f}"
        )));
    }
    Ok(())
}

/// Parse one numeric field of the scenario mini-syntax with a labelled
/// error. Shared with [`crate::workload::drift::DriftSchedule::parse`],
/// the time-indexed dialect of the same syntax.
pub(crate) fn parse_num<T: std::str::FromStr>(what: &str, s: &str) -> Result<T> {
    s.trim()
        .parse::<T>()
        .map_err(|_| Error::InvalidSpec(format!("cannot parse {what} `{s}`")))
}

/// The live truth a scenario has produced so far: effective spec, dead
/// set, and per-worker slowdown multipliers. Advanced batch by batch.
#[derive(Clone, Debug)]
pub struct ScenarioState {
    /// Effective cluster parameters (group-level drift applied).
    pub spec: ClusterSpec,
    /// Workers that have died so far.
    pub dead: BTreeSet<usize>,
    /// Per-worker delay multipliers (machine-level slowdowns).
    pub slow: Vec<f64>,
    /// Per-group Bernoulli packet-loss probability (0 = clean link).
    loss: Vec<f64>,
    /// Per-group burst window: packets drop entirely while
    /// `batch < burst_until[g]`.
    burst_until: Vec<u64>,
    /// Per-worker Bernoulli packet-loss probability, composing with the
    /// group rate (0 = no worker-level loss).
    worker_loss: Vec<f64>,
    /// Per-worker permanent-stall start batch (`None` = never stalled).
    stalled_from: Vec<Option<u64>>,
    /// Per-worker flap schedule `(start, period)`: dark for `period`
    /// batches from `start`, then alive for `period`, repeating.
    flap: Vec<Option<(u64, u64)>>,
    applied: usize,
}

impl ScenarioState {
    /// Fresh state before any event; `initial_dead` seeds the dead set
    /// (e.g. [`crate::coordinator::JobConfig::dead_workers`]).
    pub fn new(spec: &ClusterSpec, initial_dead: &[usize]) -> ScenarioState {
        ScenarioState {
            spec: spec.clone(),
            dead: initial_dead.iter().copied().collect(),
            slow: vec![1.0; spec.total_workers()],
            loss: vec![0.0; spec.num_groups()],
            burst_until: vec![0; spec.num_groups()],
            worker_loss: vec![0.0; spec.total_workers()],
            stalled_from: vec![None; spec.total_workers()],
            flap: vec![None; spec.total_workers()],
            applied: 0,
        }
    }

    /// Apply every not-yet-applied event with `at_batch <= batch`. Returns
    /// `true` when anything changed. Out-of-range worker/group ids are
    /// reported as errors (the scenario was authored against a different
    /// cluster).
    pub fn advance(&mut self, scenario: &FailureScenario, batch: u64) -> Result<bool> {
        let mut changed = false;
        while let Some(e) = scenario.events.get(self.applied) {
            if e.at_batch > batch {
                break;
            }
            self.apply(&e.kind, e.at_batch)?;
            self.applied += 1;
            changed = true;
        }
        Ok(changed)
    }

    /// Effective per-packet drop probability for `group`'s links at
    /// `batch`: 1 inside a burst window, the Bernoulli rate otherwise.
    pub fn loss_probability(&self, group: usize, batch: u64) -> f64 {
        if batch < *self.burst_until.get(group).unwrap_or(&0) {
            return 1.0;
        }
        *self.loss.get(group).unwrap_or(&0.0)
    }

    /// Is any link lossy at `batch` (group Bernoulli rate set, burst
    /// window open, or a per-worker rate set)?
    pub fn any_loss(&self, batch: u64) -> bool {
        (0..self.loss.len()).any(|g| self.loss_probability(g, batch) > 0.0)
            || self.worker_loss.iter().any(|&p| p > 0.0)
    }

    /// Effective per-packet drop probability on `worker`'s link at
    /// `batch`: the group rate and the worker's own rate composed as
    /// independent channels, `1 - (1-p_g)(1-p_w)`.
    pub fn worker_loss_probability(&self, worker: usize, batch: u64) -> f64 {
        let pg = self.loss_probability(self.group_of(worker), batch);
        let pw = *self.worker_loss.get(worker).unwrap_or(&0.0);
        1.0 - (1.0 - pg) * (1.0 - pw)
    }

    /// Is `worker` dark (stalled or in a flap dark phase) at `batch`? A
    /// dark worker accepts its dispatch and never replies — unlike a dead
    /// worker, the coordinator cannot know until a deadline blows.
    pub fn is_stalled(&self, worker: usize, batch: u64) -> bool {
        if let Some(Some(from)) = self.stalled_from.get(worker) {
            if batch >= *from {
                return true;
            }
        }
        if let Some(Some((start, period))) = self.flap.get(worker) {
            if batch >= *start {
                // Phases alternate dark/alive, dark first.
                return ((batch - start) / period) % 2 == 0;
            }
        }
        false
    }

    /// Is any worker dark at `batch`?
    pub fn any_stalled(&self, batch: u64) -> bool {
        (0..self.stalled_from.len()).any(|w| self.is_stalled(w, batch))
    }

    fn apply(&mut self, kind: &FailureKind, at_batch: u64) -> Result<()> {
        let nw = self.spec.total_workers();
        let ng = self.spec.num_groups();
        match kind {
            FailureKind::KillWorkers(ws) => {
                for &w in ws {
                    if w >= nw {
                        return Err(Error::InvalidSpec(format!(
                            "scenario kills worker {w}, cluster has {nw}"
                        )));
                    }
                    self.dead.insert(w);
                }
            }
            FailureKind::SlowWorkers { workers, factor } => {
                for &w in workers {
                    if w >= nw {
                        return Err(Error::InvalidSpec(format!(
                            "scenario slows worker {w}, cluster has {nw}"
                        )));
                    }
                    self.slow[w] *= factor;
                }
            }
            FailureKind::SlowGroup { group, factor } => {
                if *group >= ng {
                    return Err(Error::InvalidSpec(format!(
                        "scenario slows group {group}, cluster has {ng}"
                    )));
                }
                let g = &mut self.spec.groups[*group];
                g.alpha *= factor;
                g.mu /= factor;
            }
            FailureKind::ScaleGroupMu { group, factor } => {
                if *group >= ng {
                    return Err(Error::InvalidSpec(format!(
                        "scenario drifts group {group}, cluster has {ng}"
                    )));
                }
                self.spec.groups[*group].mu *= factor;
            }
            FailureKind::LossyGroup { group, p } => {
                if *group >= ng {
                    return Err(Error::InvalidSpec(format!(
                        "scenario degrades group {group}, cluster has {ng}"
                    )));
                }
                self.loss[*group] = *p;
            }
            FailureKind::BurstDrop { group, batches } => {
                if *group >= ng {
                    return Err(Error::InvalidSpec(format!(
                        "scenario bursts group {group}, cluster has {ng}"
                    )));
                }
                let until = at_batch.saturating_add(*batches);
                let slot = &mut self.burst_until[*group];
                *slot = (*slot).max(until);
            }
            FailureKind::StallWorker { worker } => {
                if *worker >= nw {
                    return Err(Error::InvalidSpec(format!(
                        "scenario stalls worker {worker}, cluster has {nw}"
                    )));
                }
                let slot = &mut self.stalled_from[*worker];
                *slot = Some(slot.map_or(at_batch, |b| b.min(at_batch)));
            }
            FailureKind::FlappyWorker { worker, period } => {
                if *worker >= nw {
                    return Err(Error::InvalidSpec(format!(
                        "scenario flaps worker {worker}, cluster has {nw}"
                    )));
                }
                self.flap[*worker] = Some((at_batch, *period));
            }
            FailureKind::LossyWorker { worker, p } => {
                if *worker >= nw {
                    return Err(Error::InvalidSpec(format!(
                        "scenario degrades worker {worker}, cluster has {nw}"
                    )));
                }
                self.worker_loss[*worker] = *p;
            }
        }
        Ok(())
    }

    /// Group index of a (group-major) worker id.
    pub fn group_of(&self, worker: usize) -> usize {
        let mut w = worker;
        for (j, g) in self.spec.groups.iter().enumerate() {
            if w < g.n {
                return j;
            }
            w -= g.n;
        }
        self.spec.num_groups() - 1
    }

    /// Sample a straggle realization from the *effective* cluster: group
    /// drift via the effective spec, machine slowdowns via delay
    /// multipliers, deaths via the dead set.
    pub fn injector(
        &self,
        model: LatencyModel,
        per_worker_loads: &[usize],
        time_scale: f64,
        seed: u64,
    ) -> Result<StragglerInjector> {
        Ok(StragglerInjector::sample(
            &self.spec,
            model,
            per_worker_loads,
            time_scale,
            seed,
        )?
        .with_slowdowns(&self.slow)?
        .with_dead(self.dead.iter().copied()))
    }

    /// In-place form of [`ScenarioState::injector`]: redraw an existing
    /// injector from the current effective cluster, reusing its buffers
    /// (bit-identical to a fresh [`ScenarioState::injector`] call) — the
    /// adaptive serving loop's per-batch path, which otherwise allocated
    /// one `O(N)` delay vector per batch.
    pub fn injector_into(
        &self,
        inj: &mut StragglerInjector,
        model: LatencyModel,
        per_worker_loads: &[usize],
        time_scale: f64,
        seed: u64,
    ) -> Result<()> {
        inj.resample(&self.spec, model, per_worker_loads, time_scale, seed)?;
        inj.apply_slowdowns(&self.slow)?;
        inj.set_dead(self.dead.iter().copied());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Group;

    fn spec() -> ClusterSpec {
        ClusterSpec::new(
            vec![
                Group { n: 4, mu: 8.0, alpha: 1.0 },
                Group { n: 6, mu: 2.0, alpha: 1.0 },
            ],
            64,
        )
        .unwrap()
    }

    #[test]
    fn events_apply_in_batch_order() {
        let scenario = FailureScenario::new(vec![
            FailureEvent {
                at_batch: 5,
                kind: FailureKind::SlowGroup { group: 0, factor: 2.0 },
            },
            FailureEvent { at_batch: 2, kind: FailureKind::KillWorkers(vec![7]) },
        ])
        .unwrap();
        let mut st = ScenarioState::new(&spec(), &[]);
        assert!(!st.advance(&scenario, 1).unwrap());
        assert!(st.advance(&scenario, 2).unwrap());
        assert!(st.dead.contains(&7));
        assert_eq!(st.spec.groups[0].mu, 8.0);
        assert!(st.advance(&scenario, 10).unwrap());
        assert_eq!(st.spec.groups[0].mu, 4.0);
        assert_eq!(st.spec.groups[0].alpha, 2.0);
        // Events never re-apply.
        assert!(!st.advance(&scenario, 20).unwrap());
        assert_eq!(st.spec.groups[0].mu, 4.0);
    }

    #[test]
    fn worker_slowdowns_compose_and_mu_drift_is_tail_only() {
        let scenario = FailureScenario::new(vec![
            FailureEvent {
                at_batch: 0,
                kind: FailureKind::SlowWorkers { workers: vec![1], factor: 2.0 },
            },
            FailureEvent {
                at_batch: 1,
                kind: FailureKind::SlowWorkers { workers: vec![1, 2], factor: 3.0 },
            },
            FailureEvent {
                at_batch: 1,
                kind: FailureKind::ScaleGroupMu { group: 1, factor: 0.5 },
            },
        ])
        .unwrap();
        let mut st = ScenarioState::new(&spec(), &[0]);
        st.advance(&scenario, 3).unwrap();
        assert_eq!(st.slow[1], 6.0);
        assert_eq!(st.slow[2], 3.0);
        assert_eq!(st.slow[3], 1.0);
        assert_eq!(st.spec.groups[1].mu, 1.0);
        assert_eq!(st.spec.groups[1].alpha, 1.0, "mu drift keeps the shift");
        assert!(st.dead.contains(&0), "initial dead seeded");
        let inj = st.injector(LatencyModel::A, &[16; 10], 1.0, 5).unwrap();
        assert!(inj.is_dead(0));
    }

    #[test]
    fn group_of_maps_group_major_ids() {
        let st = ScenarioState::new(&spec(), &[]);
        assert_eq!(st.group_of(0), 0);
        assert_eq!(st.group_of(3), 0);
        assert_eq!(st.group_of(4), 1);
        assert_eq!(st.group_of(9), 1);
    }

    #[test]
    fn out_of_range_ids_and_bad_factors_rejected() {
        assert!(FailureScenario::new(vec![FailureEvent {
            at_batch: 0,
            kind: FailureKind::SlowGroup { group: 0, factor: 0.0 },
        }])
        .is_err());
        assert!(FailureScenario::new(vec![FailureEvent {
            at_batch: 0,
            kind: FailureKind::KillWorkers(vec![]),
        }])
        .is_err());
        let scenario = FailureScenario::new(vec![FailureEvent {
            at_batch: 0,
            kind: FailureKind::KillWorkers(vec![99]),
        }])
        .unwrap();
        let mut st = ScenarioState::new(&spec(), &[]);
        assert!(st.advance(&scenario, 0).is_err());
        let scenario = FailureScenario::new(vec![FailureEvent {
            at_batch: 0,
            kind: FailureKind::SlowGroup { group: 9, factor: 2.0 },
        }])
        .unwrap();
        let mut st = ScenarioState::new(&spec(), &[]);
        assert!(st.advance(&scenario, 0).is_err());
    }

    #[test]
    fn lossy_links_replace_and_burst_windows_heal() {
        let scenario = FailureScenario::new(vec![
            FailureEvent {
                at_batch: 2,
                kind: FailureKind::LossyGroup { group: 1, p: 0.1 },
            },
            FailureEvent {
                at_batch: 4,
                kind: FailureKind::BurstDrop { group: 0, batches: 3 },
            },
            FailureEvent {
                at_batch: 8,
                kind: FailureKind::LossyGroup { group: 1, p: 0.0 },
            },
        ])
        .unwrap();
        assert!(scenario.has_loss());
        let mut st = ScenarioState::new(&spec(), &[]);
        assert!(!st.any_loss(0));
        st.advance(&scenario, 2).unwrap();
        assert_eq!(st.loss_probability(1, 2), 0.1);
        assert_eq!(st.loss_probability(0, 2), 0.0);
        assert!(st.any_loss(2));
        st.advance(&scenario, 4).unwrap();
        // Burst drops everything on group 0 for batches 4..7, then heals.
        assert_eq!(st.loss_probability(0, 4), 1.0);
        assert_eq!(st.loss_probability(0, 6), 1.0);
        assert_eq!(st.loss_probability(0, 7), 0.0);
        // Loss replaces rather than composes: healing resets group 1.
        st.advance(&scenario, 8).unwrap();
        assert_eq!(st.loss_probability(1, 8), 0.0);
        assert!(!st.any_loss(8));
        // Kill/slow scripts without loss events report has_loss = false.
        assert!(!FailureScenario::parse(Some("3:0"), None).unwrap().has_loss());
    }

    #[test]
    fn loss_validation_rejects_bad_probabilities_and_groups() {
        assert!(FailureScenario::new(vec![FailureEvent {
            at_batch: 0,
            kind: FailureKind::LossyGroup { group: 0, p: 1.5 },
        }])
        .is_err());
        assert!(FailureScenario::new(vec![FailureEvent {
            at_batch: 0,
            kind: FailureKind::LossyGroup { group: 0, p: f64::NAN },
        }])
        .is_err());
        assert!(FailureScenario::new(vec![FailureEvent {
            at_batch: 0,
            kind: FailureKind::BurstDrop { group: 0, batches: 0 },
        }])
        .is_err());
        let scenario = FailureScenario::new(vec![FailureEvent {
            at_batch: 0,
            kind: FailureKind::LossyGroup { group: 9, p: 0.5 },
        }])
        .unwrap();
        let mut st = ScenarioState::new(&spec(), &[]);
        assert!(st.advance(&scenario, 0).is_err());
    }

    #[test]
    fn parses_loss_dialect() {
        let s = FailureScenario::parse_with_loss(
            Some("3:0"),
            None,
            Some("1:1:0.25;5:0:burst:2"),
        )
        .unwrap();
        assert_eq!(s.events().len(), 3);
        assert!(s.has_loss());
        assert_eq!(
            s.events()[0].kind,
            FailureKind::LossyGroup { group: 1, p: 0.25 }
        );
        assert_eq!(s.events()[0].at_batch, 1);
        assert_eq!(
            s.events()[2].kind,
            FailureKind::BurstDrop { group: 0, batches: 2 }
        );
        assert!(FailureScenario::parse_with_loss(None, None, Some("1:2")).is_err());
        assert!(FailureScenario::parse_with_loss(None, None, Some("1:2:x:3"))
            .is_err());
    }

    #[test]
    fn stall_and_flap_schedules_compose_with_loss() {
        let scenario = FailureScenario::new(vec![
            FailureEvent {
                at_batch: 2,
                kind: FailureKind::StallWorker { worker: 1 },
            },
            FailureEvent {
                at_batch: 4,
                kind: FailureKind::FlappyWorker { worker: 5, period: 3 },
            },
            FailureEvent {
                at_batch: 0,
                kind: FailureKind::LossyWorker { worker: 6, p: 0.5 },
            },
            FailureEvent {
                at_batch: 0,
                kind: FailureKind::LossyGroup { group: 1, p: 0.2 },
            },
        ])
        .unwrap();
        assert!(scenario.has_stall());
        assert!(scenario.has_loss());
        let mut st = ScenarioState::new(&spec(), &[]);
        st.advance(&scenario, 10).unwrap();
        // Permanent stall from batch 2 on.
        assert!(st.is_stalled(1, 2));
        assert!(st.is_stalled(1, 100));
        assert!(!st.is_stalled(0, 100));
        // Flap: dark for 3 batches from 4, alive for 3, repeating.
        for b in [4, 5, 6, 10, 11, 12] {
            assert!(st.is_stalled(5, b), "batch {b} should be dark");
        }
        for b in [7, 8, 9, 13] {
            assert!(!st.is_stalled(5, b), "batch {b} should be alive");
        }
        assert!(st.any_stalled(4));
        // Worker loss composes with the group rate (worker 6 is in
        // group 1): 1 - 0.8*0.5 = 0.6; worker 5 gets the group rate only;
        // group-0 workers stay clean.
        assert!((st.worker_loss_probability(6, 10) - 0.6).abs() < 1e-12);
        assert!((st.worker_loss_probability(5, 10) - 0.2).abs() < 1e-12);
        assert_eq!(st.worker_loss_probability(0, 10), 0.0);
        assert!(st.any_loss(10));
        // Stall-only scripts have no loss, loss-only scripts no stall.
        let stall_only = FailureScenario::new(vec![FailureEvent {
            at_batch: 0,
            kind: FailureKind::StallWorker { worker: 0 },
        }])
        .unwrap();
        assert!(stall_only.has_stall() && !stall_only.has_loss());
    }

    #[test]
    fn stall_validation_and_parse_dialects() {
        // Out-of-range ids rejected at apply time.
        for kind in [
            FailureKind::StallWorker { worker: 99 },
            FailureKind::FlappyWorker { worker: 99, period: 2 },
            FailureKind::LossyWorker { worker: 99, p: 0.5 },
        ] {
            let s = FailureScenario::new(vec![FailureEvent {
                at_batch: 0,
                kind,
            }])
            .unwrap();
            let mut st = ScenarioState::new(&spec(), &[]);
            assert!(st.advance(&s, 0).is_err());
        }
        // Bad knobs rejected at build time.
        assert!(FailureScenario::new(vec![FailureEvent {
            at_batch: 0,
            kind: FailureKind::FlappyWorker { worker: 0, period: 0 },
        }])
        .is_err());
        assert!(FailureScenario::new(vec![FailureEvent {
            at_batch: 0,
            kind: FailureKind::LossyWorker { worker: 0, p: 1.5 },
        }])
        .is_err());
        // CLI dialects.
        let s = FailureScenario::parse_compound(
            None,
            None,
            None,
            Some("2:1,3"),
            Some("4:5:3"),
            Some("0:6:0.5"),
        )
        .unwrap();
        assert_eq!(s.events().len(), 4);
        assert!(s.has_stall() && s.has_loss());
        assert_eq!(
            s.events()[0].kind,
            FailureKind::LossyWorker { worker: 6, p: 0.5 }
        );
        assert_eq!(
            s.events()[1].kind,
            FailureKind::StallWorker { worker: 1 }
        );
        assert_eq!(
            s.events()[3].kind,
            FailureKind::FlappyWorker { worker: 5, period: 3 }
        );
        for (stall, flap, wloss) in [
            (Some("nope"), None, None),
            (None, Some("1:2"), None),
            (None, None, Some("1:2:3:4")),
        ] {
            assert!(FailureScenario::parse_compound(
                None, None, None, stall, flap, wloss
            )
            .is_err());
        }
    }

    #[test]
    fn parses_cli_mini_syntax() {
        let s =
            FailureScenario::parse(Some("3:0,5;7:2"), Some("5:1:2.0")).unwrap();
        assert_eq!(s.events().len(), 3);
        assert_eq!(
            s.events()[0].kind,
            FailureKind::KillWorkers(vec![0, 5])
        );
        assert_eq!(s.events()[0].at_batch, 3);
        assert_eq!(
            s.events()[1].kind,
            FailureKind::SlowGroup { group: 1, factor: 2.0 }
        );
        assert_eq!(s.events()[2].at_batch, 7);
        assert!(FailureScenario::parse(Some("nope"), None).is_err());
        assert!(FailureScenario::parse(None, Some("1:2")).is_err());
        assert!(FailureScenario::parse(None, None).unwrap().is_empty());
    }
}
