//! Latency metrics for the serving path.

use crate::math::Summary;
use std::time::Duration;

/// Records per-request latencies and exposes percentiles/throughput.
#[derive(Clone, Debug)]
pub struct LatencyRecorder {
    summary: Summary,
    total_rows: u64,
}

impl Default for LatencyRecorder {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyRecorder {
    /// New empty recorder.
    pub fn new() -> Self {
        LatencyRecorder {
            summary: Summary::keeping_samples(),
            total_rows: 0,
        }
    }

    /// Record one request's wall latency and decoded row count.
    pub fn record(&mut self, latency: Duration, rows: usize) {
        self.summary.add(latency.as_secs_f64());
        self.total_rows += rows as u64;
    }

    /// Requests recorded.
    pub fn count(&self) -> u64 {
        self.summary.count()
    }

    /// Mean latency in seconds.
    pub fn mean(&self) -> f64 {
        self.summary.mean()
    }

    /// Latency percentile (p in [0, 100]).
    pub fn percentile(&self, p: f64) -> f64 {
        self.summary.percentile(p)
    }

    /// Rows decoded per second of cumulative latency (sequential-serving
    /// throughput proxy).
    pub fn rows_per_second(&self) -> f64 {
        let total_time = self.summary.mean() * self.summary.count() as f64;
        if total_time <= 0.0 {
            0.0
        } else {
            self.total_rows as f64 / total_time
        }
    }

    /// One-line report.
    pub fn report(&self) -> String {
        if self.count() == 0 {
            return "no requests recorded".into();
        }
        format!(
            "requests={} mean={:.2}ms p50={:.2}ms p95={:.2}ms p99={:.2}ms rows/s={:.0}",
            self.count(),
            self.mean() * 1e3,
            self.percentile(50.0) * 1e3,
            self.percentile(95.0) * 1e3,
            self.percentile(99.0) * 1e3,
            self.rows_per_second()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let mut rec = LatencyRecorder::new();
        for ms in [10u64, 20, 30, 40, 50] {
            rec.record(Duration::from_millis(ms), 100);
        }
        assert_eq!(rec.count(), 5);
        assert!((rec.mean() - 0.030).abs() < 1e-9);
        assert!((rec.percentile(50.0) - 0.030).abs() < 1e-9);
        // 500 rows over 0.15s cumulative.
        assert!((rec.rows_per_second() - 500.0 / 0.15).abs() < 1e-6);
        assert!(rec.report().contains("requests=5"));
    }

    #[test]
    fn empty_recorder_is_safe() {
        let rec = LatencyRecorder::new();
        assert_eq!(rec.count(), 0);
        assert_eq!(rec.rows_per_second(), 0.0);
        assert_eq!(rec.report(), "no requests recorded");
    }
}
