//! Latency metrics for the serving path.

use crate::math::Summary;
use crate::runtime::wall_now;
use std::time::{Duration, Instant};

/// Records per-request latencies and exposes percentiles/throughput.
///
/// Two throughput views are reported, because they answer different
/// questions:
///
/// - [`LatencyRecorder::rows_per_cpu_second`] divides by **cumulative**
///   per-request latency — the per-request cost view. Under batched or
///   pipelined serving, where requests overlap in time, the cumulative
///   latency counts the same wall-clock interval once per in-flight
///   request, so this *understates* the system's real throughput.
/// - [`LatencyRecorder::rows_per_wall_second`] divides by the measured
///   **wall-clock serving span** ([`LatencyRecorder::wall_span`]) — the
///   system throughput view, correct under overlap.
#[derive(Clone, Debug)]
pub struct LatencyRecorder {
    summary: Summary,
    total_rows: u64,
    /// Instant of the first `record` call plus that request's latency —
    /// together with `last` this spans the serving window.
    first: Option<(Instant, f64)>,
    /// Instant of the most recent `record` call.
    last: Option<Instant>,
}

impl Default for LatencyRecorder {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyRecorder {
    /// New empty recorder.
    pub fn new() -> Self {
        LatencyRecorder {
            summary: Summary::keeping_samples(),
            total_rows: 0,
            first: None,
            last: None,
        }
    }

    /// Record one request's wall latency and decoded row count. Call at
    /// request *completion* (every serving loop does): the wall span is
    /// anchored on completion instants.
    pub fn record(&mut self, latency: Duration, rows: usize) {
        let now = wall_now();
        if self.first.is_none() {
            self.first = Some((now, latency.as_secs_f64()));
        }
        self.last = Some(now);
        self.summary.add(latency.as_secs_f64());
        self.total_rows += rows as u64;
    }

    /// Requests recorded.
    pub fn count(&self) -> u64 {
        self.summary.count()
    }

    /// Mean latency in seconds.
    pub fn mean(&self) -> f64 {
        self.summary.mean()
    }

    /// Latency percentile (p in [0, 100]).
    pub fn percentile(&self, p: f64) -> f64 {
        self.summary.percentile(p)
    }

    /// The wall-clock serving span in seconds: first completion → last
    /// completion, extended back by the first request's own latency (so a
    /// single-request recorder spans exactly that request's latency, and a
    /// sequential stream spans ≈ the sum of its latencies). `0.0` when
    /// nothing was recorded.
    pub fn wall_span(&self) -> f64 {
        match (&self.first, &self.last) {
            (Some((first, first_latency)), Some(last)) => {
                last.duration_since(*first).as_secs_f64() + first_latency
            }
            _ => 0.0,
        }
    }

    /// Rows decoded per second of **cumulative** per-request latency — the
    /// per-request cost view. Under batched/pipelined serving requests
    /// overlap, so this understates system throughput; see
    /// [`LatencyRecorder::rows_per_wall_second`].
    pub fn rows_per_cpu_second(&self) -> f64 {
        let total_time = self.summary.mean() * self.summary.count() as f64;
        if total_time <= 0.0 {
            0.0
        } else {
            self.total_rows as f64 / total_time
        }
    }

    /// Rows decoded per second of **wall-clock** serving span — the system
    /// throughput view, correct when requests overlap (batched, pipelined,
    /// and arrivals serving).
    pub fn rows_per_wall_second(&self) -> f64 {
        let span = self.wall_span();
        if span <= 0.0 {
            0.0
        } else {
            self.total_rows as f64 / span
        }
    }

    /// Historical alias of [`LatencyRecorder::rows_per_cpu_second`]. It
    /// divided by cumulative latency while claiming to be a throughput,
    /// overstating wall time whenever requests overlapped.
    #[deprecated(
        since = "0.2.0",
        note = "use rows_per_cpu_second (same value) or rows_per_wall_second \
                (true throughput under overlap)"
    )]
    pub fn rows_per_second(&self) -> f64 {
        self.rows_per_cpu_second()
    }

    /// One-line report.
    pub fn report(&self) -> String {
        if self.count() == 0 {
            return "no requests recorded".into();
        }
        format!(
            "requests={} mean={:.2}ms p50={:.2}ms p95={:.2}ms p99={:.2}ms \
             rows/cpu-s={:.0} rows/wall-s={:.0}",
            self.count(),
            self.mean() * 1e3,
            self.percentile(50.0) * 1e3,
            self.percentile(95.0) * 1e3,
            self.percentile(99.0) * 1e3,
            self.rows_per_cpu_second(),
            self.rows_per_wall_second()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let mut rec = LatencyRecorder::new();
        for ms in [10u64, 20, 30, 40, 50] {
            rec.record(Duration::from_millis(ms), 100);
        }
        assert_eq!(rec.count(), 5);
        assert!((rec.mean() - 0.030).abs() < 1e-9);
        assert!((rec.percentile(50.0) - 0.030).abs() < 1e-9);
        // 500 rows over 0.15s cumulative.
        assert!((rec.rows_per_cpu_second() - 500.0 / 0.15).abs() < 1e-6);
        assert!(rec.report().contains("requests=5"));
        assert!(rec.report().contains("rows/wall-s="));
    }

    #[test]
    fn wall_span_reflects_overlap() {
        // Five "requests" recorded back-to-back (≈ fully overlapped, as in
        // one decoded batch): the wall span collapses to about the first
        // latency, so the wall rate exceeds the cpu rate — the exact bias
        // the old cumulative-only metric hid.
        let mut rec = LatencyRecorder::new();
        for _ in 0..5 {
            rec.record(Duration::from_millis(30), 100);
        }
        let span = rec.wall_span();
        assert!(span >= 0.030, "span {span} must include the first latency");
        assert!(span < 0.030 + 0.5, "span {span} unexpectedly long");
        assert!(rec.rows_per_wall_second() > rec.rows_per_cpu_second());
    }

    #[test]
    fn single_request_wall_equals_cpu() {
        let mut rec = LatencyRecorder::new();
        rec.record(Duration::from_millis(40), 200);
        // One request: span = its latency (plus the sub-microsecond gap
        // between the two Instant::now() reads).
        let wall = rec.rows_per_wall_second();
        let cpu = rec.rows_per_cpu_second();
        assert!((wall - cpu).abs() / cpu < 1e-3, "wall {wall} vs cpu {cpu}");
    }

    #[test]
    fn sequential_span_tracks_sum_of_latencies() {
        // Records spaced by real sleeps approximate a sequential loop; the
        // span must cover the sleeps plus the first latency.
        let mut rec = LatencyRecorder::new();
        rec.record(Duration::from_millis(5), 10);
        std::thread::sleep(Duration::from_millis(20));
        rec.record(Duration::from_millis(5), 10);
        let span = rec.wall_span();
        assert!(span >= 0.025, "span {span} must cover sleep + first latency");
    }

    #[test]
    fn empty_recorder_is_safe() {
        let rec = LatencyRecorder::new();
        assert_eq!(rec.count(), 0);
        assert_eq!(rec.rows_per_cpu_second(), 0.0);
        assert_eq!(rec.rows_per_wall_second(), 0.0);
        assert_eq!(rec.wall_span(), 0.0);
        assert_eq!(rec.report(), "no requests recorded");
    }
}
