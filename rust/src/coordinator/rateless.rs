//! Streaming (rateless) collection primitives for the serving loop.
//!
//! The MDS fast path fixes the issuance up front: every worker computes
//! its whole chunk, the master stops at `k` rows. With the rateless
//! fountain ([`crate::coding::RatelessCode`]) the issuance itself becomes
//! the control variable: each *round* the master solicits just enough
//! fresh coded rows to cover its deficit (inflated when links are lossy),
//! workers reply, per-packet loss thins the replies, and the loop repeats
//! until **any** `k` rows are in hand. The measured figure of merit is
//! the *overhead* — rows actually received divided by `k` — which this
//! module accumulates into [`RatelessSummary`] for
//! [`crate::coordinator::ServeOutcome`].
//!
//! # Determinism
//!
//! Bit-reproducibility from the seed — at any pool size, and under any
//! thread interleaving — rests on three pillars, all in this module or
//! its callers:
//!
//! 1. **Row identity.** A coded row's coefficients derive purely from
//!    `(generator seed, global row index)`; the rows a round mints depend
//!    only on the deficit schedule.
//! 2. **Packet fate.** Whether a packet survives is a pure function of
//!    `(batch seed, first global row of the packet, loss probability)`
//!    ([`packet_dropped`]) — never of arrival timing.
//! 3. **Receipt order.** The collection loop is a per-round barrier: all
//!    replies of a round are gathered, then processed in global-row
//!    order, so the decode support is independent of `mpsc` arrival
//!    order.
//!
//! Loss probabilities come from the failure-scenario layer
//! ([`crate::coordinator::ScenarioState::loss_probability`]); this module
//! only consumes a per-worker `&[f64]`.

use crate::math::Rng;

/// Rows per loss "packet": the unit the lossy-link model drops. A
/// worker's reply is split into consecutive packets of (at most) this
/// many rows, each surviving or dying independently.
pub const RATELESS_PACKET_ROWS: usize = 4;

/// Hard cap on solicitation rounds per batch — a backstop against a
/// scenario whose links never deliver (`p = 1` everywhere, forever).
pub(crate) const RATELESS_MAX_ROUNDS: u64 = 64;

/// Domain-separation tag for the per-packet loss draws (keeps them
/// independent of the straggle and generator streams derived from the
/// same batch seed).
pub(crate) const LOSS_SEED_TAG: u64 = 0x10C5_10C5_10C5_10C5;

/// Mixing constant spreading consecutive packet-start rows across the
/// seed space (same role as the rateless row tag in `coding::generator`).
const LOSS_MIX: u64 = 0xD6E8_FEB8_6659_FD93;

/// Deterministic per-packet Bernoulli drop. The draw is a pure function
/// of `(batch_seed, packet_row, p)` where `packet_row` is the *global*
/// index of the packet's first row — so the same packet meets the same
/// fate regardless of pool size, chunk split, or arrival order.
pub(crate) fn packet_dropped(batch_seed: u64, packet_row: usize, p: f64) -> bool {
    if p <= 0.0 {
        return false;
    }
    if p >= 1.0 {
        return true;
    }
    let mut rng = Rng::new(
        (batch_seed ^ LOSS_SEED_TAG)
            .wrapping_add((packet_row as u64 + 1).wrapping_mul(LOSS_MIX)),
    );
    rng.next_f64() < p
}

/// Split `issue` rows over eligible workers proportionally to their
/// weights, deterministically. `weights` is `(worker, weight)` in worker
/// id order; floors are assigned first, then the remainder is dealt
/// round-robin from the front. All-zero weights degrade to a uniform
/// split. The returned counts sum to exactly `issue`.
pub(crate) fn proportional_shares(
    issue: usize,
    weights: &[(usize, usize)],
) -> Vec<(usize, usize)> {
    if weights.is_empty() || issue == 0 {
        return Vec::new();
    }
    let total: usize = weights.iter().map(|&(_, w)| w).sum();
    let mut out = Vec::with_capacity(weights.len());
    let mut assigned = 0usize;
    for &(worker, w) in weights {
        let share = if total == 0 {
            issue / weights.len()
        } else {
            issue * w / total
        };
        out.push((worker, share));
        assigned += share;
    }
    let mut rem = issue - assigned;
    let mut i = 0usize;
    while rem > 0 {
        out[i % out.len()].1 += 1;
        rem -= 1;
        i += 1;
    }
    out
}

/// Per-batch streaming tallies, returned by
/// [`crate::coordinator::PreparedJob::run_batch_rateless_injected`].
#[derive(Clone, Copy, Debug, Default)]
pub struct RatelessBatchStats {
    /// Coded rows that survived the lossy links and reached the master.
    pub rows_received: u64,
    /// Coded rows solicited from workers (across all rounds).
    pub rows_issued: u64,
    /// Extra solicitation rounds beyond the first (0 = the initial
    /// issuance crossed `k` on its own).
    pub extend_rounds: u64,
}

/// Stream-level rateless accounting, surfaced through
/// [`crate::coordinator::ServeOutcome`]. All counters are *measured* at
/// the row level by the collection loop and the encoder — none are
/// declared.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RatelessSummary {
    /// Total coded rows received across the stream.
    pub rows_received: u64,
    /// Total coded rows issued (solicited) across the stream.
    pub rows_issued: u64,
    /// Total extra solicitation rounds across the stream.
    pub extend_rounds: u64,
    /// Decode jobs (batches) the totals cover.
    pub batches: u64,
    /// Reception overhead: `rows_received / (batches · k)`. The fountain
    /// ideal is 1.0; per-packet loss pushes it up by at most the round
    /// inflation (≈ 12.5% + one packet per round).
    pub overhead: f64,
    /// Rows re-encoded by the encoder over the job's lifetime — the
    /// elasticity invariant says this stays 0: every extension and
    /// scale-out mints *fresh* row indices.
    pub re_encoded_rows: u64,
}

impl RatelessSummary {
    /// Fold one batch's tallies into the stream totals.
    pub fn absorb(&mut self, batch: RatelessBatchStats) {
        self.rows_received += batch.rows_received;
        self.rows_issued += batch.rows_issued;
        self.extend_rounds += batch.extend_rounds;
        self.batches += 1;
    }

    /// Close the books: compute the overhead ratio and capture the
    /// encoder's re-encode counter.
    pub fn finalize(&mut self, k: usize, re_encoded_rows: u64) {
        self.re_encoded_rows = re_encoded_rows;
        let denom = self.batches.saturating_mul(k as u64);
        self.overhead = if denom == 0 {
            0.0
        } else {
            self.rows_received as f64 / denom as f64
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packet_fate_is_deterministic_and_rate_accurate() {
        // Same (seed, row, p) → same fate, every time.
        for row in [0usize, 3, 64, 1_000_003] {
            for p in [0.05, 0.5, 0.95] {
                let a = packet_dropped(42, row, p);
                let b = packet_dropped(42, row, p);
                assert_eq!(a, b);
            }
        }
        // Degenerate probabilities never consult the RNG.
        assert!(!packet_dropped(7, 0, 0.0));
        assert!(packet_dropped(7, 0, 1.0));
        // Empirical drop rate over many packets tracks p.
        let p = 0.1;
        let drops = (0..10_000)
            .filter(|&r| packet_dropped(9, r, p))
            .count() as f64;
        let rate = drops / 10_000.0;
        assert!(
            (rate - p).abs() < 0.02,
            "empirical drop rate {rate} far from {p}"
        );
        // Different seeds decorrelate the pattern.
        let same = (0..1_000)
            .filter(|&r| packet_dropped(1, r, 0.5) == packet_dropped(2, r, 0.5))
            .count();
        assert!((300..700).contains(&same), "seeds look correlated: {same}");
    }

    #[test]
    fn shares_sum_exactly_and_follow_weights() {
        let shares = proportional_shares(100, &[(0, 30), (1, 10), (3, 60)]);
        assert_eq!(shares.iter().map(|&(_, c)| c).sum::<usize>(), 100);
        assert_eq!(shares, vec![(0, 30), (1, 10), (3, 60)]);
        // Remainder is dealt deterministically from the front.
        let shares = proportional_shares(10, &[(0, 1), (1, 1), (2, 1)]);
        assert_eq!(shares.iter().map(|&(_, c)| c).sum::<usize>(), 10);
        assert_eq!(shares, vec![(0, 4), (1, 3), (2, 3)]);
        // All-zero weights degrade to a uniform split.
        let shares = proportional_shares(7, &[(2, 0), (5, 0)]);
        assert_eq!(shares, vec![(2, 4), (5, 3)]);
        // Degenerate inputs.
        assert!(proportional_shares(0, &[(0, 1)]).is_empty());
        assert!(proportional_shares(5, &[]).is_empty());
    }

    #[test]
    fn summary_overhead_is_rows_over_k_per_batch() {
        let mut s = RatelessSummary::default();
        s.absorb(RatelessBatchStats {
            rows_received: 70,
            rows_issued: 80,
            extend_rounds: 1,
        });
        s.absorb(RatelessBatchStats {
            rows_received: 64,
            rows_issued: 64,
            extend_rounds: 0,
        });
        s.finalize(64, 0);
        assert_eq!(s.batches, 2);
        assert_eq!(s.rows_received, 134);
        assert_eq!(s.rows_issued, 144);
        assert_eq!(s.extend_rounds, 1);
        assert!((s.overhead - 134.0 / 128.0).abs() < 1e-12);
        // Empty stream → overhead 0, not NaN.
        let mut empty = RatelessSummary::default();
        empty.finalize(64, 0);
        assert_eq!(empty.overhead, 0.0);
    }
}
