//! Prepared-job serving fast path.
//!
//! The one-shot entry points ([`crate::coordinator::run_job`] /
//! [`crate::coordinator::run_job_batched`]) pay the full setup cost on
//! every call: build the generator, encode `Ã = G·A` (O(n·k·d)), and slice
//! the coded rows into per-worker chunks. A serving system answers a
//! *stream* of requests against the same data matrix, so all of that is
//! amortizable — the per-batch critical path should be exactly what the
//! paper analyzes: straggle, collect `k` rows, decode.
//!
//! [`PreparedJob`] is that amortization boundary. Built once, it owns the
//! generator, the encoded per-worker chunks (behind `Arc`s, so dispatching
//! a batch clones pointers, not matrices), and a factorization-cached
//! [`Decoder`]. Each [`PreparedJob::run_batch`] call then:
//!
//! 1. samples a fresh straggle realization (seed-derived, like the cold
//!    path),
//! 2. fans the cached chunks out to worker threads (one `(l_i × d)·(d × B)`
//!    batched product per worker),
//! 3. collects row/value pairs until the shared support reaches `k`, and
//! 4. decodes **all** `B` requests through one (cached) factorization via
//!    [`Decoder::decode_batch`].
//!
//! Steady-state serving therefore performs **zero** encode or chunk work
//! after construction — observable through [`PreparedJob::encode_count`] —
//! and repeated straggler patterns (common under group heterogeneity: the
//! `G` group-boundary patterns dominate) skip refactorization entirely.

use crate::allocation::Allocation;
use crate::coding::code::Code;
use crate::coding::encoder::WorkerChunk;
use crate::coding::{Decoder, Encoder, GeneratorKind, Matrix};
use crate::coordinator::master::{
    JobConfig, JobReport, GENERATOR_SEED_TAG, STRAGGLE_SEED_TAG,
};
use crate::coordinator::rateless::{
    packet_dropped, proportional_shares, RatelessBatchStats,
    RATELESS_MAX_ROUNDS, RATELESS_PACKET_ROWS,
};
use crate::coordinator::recovery::{
    DegradePolicy, DegradedBatch, RecoveryEngine,
};
use crate::coordinator::{Compute, StragglerInjector};
use crate::model::ClusterSpec;
use crate::runtime::pool::PoolHandle;
use crate::{Error, Result};
use std::sync::{mpsc, Arc};
use std::time::Duration;
use crate::runtime::wall_now;

/// Seed mix for hedge-wave packet fates: re-transmissions are independent
/// Bernoulli trials, so a row dropped on the original delivery (keyed by
/// `batch_seed` alone) gets a fresh draw on every retry wave.
const HEDGE_FATE_TAG: u64 = 0x4ED6_0FA7_E4ED_60FA;

/// One worker's reply for a whole request batch.
struct BatchReply {
    worker: usize,
    range: std::ops::Range<usize>,
    /// Index into the hedged path's task table (`0` on the legacy paths,
    /// which identify replies by `range` alone).
    task: usize,
    /// One result vector per request.
    ys: Vec<Vec<f64>>,
}

/// Row payload of one hedged-path task: original dispatches carry their
/// chunk's contiguous range, hedge re-issues and canaries carry explicit
/// (possibly scattered) row lists.
enum TaskRows {
    Contiguous(std::ops::Range<usize>),
    Scattered(Vec<usize>),
}

impl TaskRows {
    fn len(&self) -> usize {
        match self {
            TaskRows::Contiguous(r) => r.len(),
            TaskRows::Scattered(v) => v.len(),
        }
    }

    fn at(&self, i: usize) -> usize {
        match self {
            TaskRows::Contiguous(r) => r.start + i,
            TaskRows::Scattered(v) => v[i],
        }
    }
}

/// One in-flight unit of the hedged collection loop. Tasks are never
/// cancelled — a blown task is only marked non-pending, and a late reply
/// from it still contributes rows (first-completion-wins is a dedup rule
/// on the row support, not a kill switch).
struct HedgeTask {
    /// Worker executing this task.
    executor: usize,
    /// Worker whose deadline blow this task covers (`usize::MAX` for
    /// pool-wide repair waves with no single lineage).
    origin: usize,
    rows: TaskRows,
    /// Absolute wall offset from batch start; past it the task is blown.
    deadline: Duration,
    /// Retry wave: `0` = original dispatch / canary, `>= 1` = hedge.
    wave: u32,
    pending: bool,
    is_hedge: bool,
    is_canary: bool,
}

/// One consumed worker reply, as the estimator sees it: which worker, how
/// many rows it carried, and its (model-time) completion. Only replies the
/// master actually consumed before reaching `k` rows appear — together
/// with the dispatch count this is a type-II censored sample
/// ([`crate::model::SpeedEstimator`]).
#[derive(Clone, Copy, Debug)]
pub struct WorkerObservation {
    /// Global worker id (group-major).
    pub worker: usize,
    /// Coded rows the worker carried this batch.
    pub load: usize,
    /// Model-time completion (the injected straggle delay).
    pub model_time: f64,
}

/// A coded job prepared for repeated serving: generator, encoded chunks,
/// and dispatch plan built once; per-batch work is straggle + collect +
/// (factorization-cached) decode.
#[derive(Debug)]
pub struct PreparedJob {
    spec: ClusterSpec,
    cfg: JobConfig,
    per_worker: Vec<usize>,
    n: usize,
    /// The erasure code every setup/encode/decode of this job routes
    /// through (resolved once from [`JobConfig::resolve_code`]). For the
    /// dense MDS codes the trait's default methods delegate to the exact
    /// pre-trait call chain, so prepared serving is bit-identical.
    code: Box<dyn Code>,
    /// The uncoded data matrix — kept when `cfg.verify_decode` (for
    /// ground-truth error reporting) and always for the rateless code
    /// (the master mints fresh coded rows from it when the stream
    /// extends past the materialized prefix); `None` otherwise, dropping
    /// the O(k·d) copy.
    a: Option<Matrix>,
    /// The encoder that produced `chunks`; its call counter is the live
    /// measurement behind [`PreparedJob::encode_count`] — any future code
    /// path that re-encodes through this job shows up there.
    encoder: Encoder,
    /// The encoded matrix `Ã = G·A`, kept so adaptation can re-slice it
    /// ([`PreparedJob::rechunk`]) without a fresh encode pass. This is a
    /// deliberate memory-for-adaptability trade: the chunks hold copies of
    /// the same rows, so a prepared job carries ~2× the encoded data
    /// (O(n·d) each). Sharing one `Arc<Matrix>` with range-view chunks
    /// would halve it but needs a view type in the `Matrix` layer.
    coded: Matrix,
    /// Encoded per-worker chunks; `Arc` so batch dispatch clones pointers.
    chunks: Vec<Arc<WorkerChunk>>,
    /// Re-chunk (re-allocation) passes performed since construction.
    rechunks: u64,
    decoder: Decoder,
    /// The persistent compute pool encode/decode kernels run on (resolved
    /// once from [`JobConfig::compute_pool`]).
    pool: PoolHandle,
    /// Reusable collection buffers (row support + per-request columns) —
    /// the worker-output arena.
    rows_buf: Vec<usize>,
    cols_buf: Vec<Vec<f64>>,
    /// Reusable straggle-draw buffer for [`PreparedJob::run_batch`]
    /// (redrawn in place per batch; `None` until the first batch).
    injector_scratch: Option<StragglerInjector>,
    /// Reusable sort buffer for the analytic-completion computation.
    completion_order: Vec<usize>,
    /// Reusable request-dispatch arena: reclaimed via `Arc::try_unwrap`
    /// once the previous batch's stragglers have drained.
    xs_slot: Option<Arc<Vec<Vec<f64>>>>,
    /// High-water-mark parking lots for inner buffers evicted when a
    /// batch shrinks (arrival batches vary in size; without these, every
    /// smaller batch would drop sized buffers a later bigger batch then
    /// re-allocates).
    xs_spare: Vec<Vec<f64>>,
    cols_spare: Vec<Vec<f64>>,
    /// Scratch-arena allocation/grow events (see
    /// [`PreparedJob::scratch_grows`]).
    grows: u64,
}

impl PreparedJob {
    /// Validate, encode, and chunk once. `cfg.seed` fixes the generator
    /// for the job's whole lifetime (batch-level straggle realizations are
    /// derived from the per-batch seed instead); `cfg.encode_threads`
    /// drives the blocked parallel encode kernel.
    pub fn new(
        spec: &ClusterSpec,
        alloc: &Allocation,
        a: &Matrix,
        cfg: &JobConfig,
    ) -> Result<PreparedJob> {
        if a.rows() != spec.k {
            return Err(Error::InvalidSpec(format!(
                "data matrix has {} rows, spec.k = {}",
                a.rows(),
                spec.k
            )));
        }
        alloc.validate(spec)?;
        let per_worker = alloc.per_worker_loads(spec);
        let n: usize = per_worker.iter().sum();
        let code = cfg.resolve_code()?;
        let gen = code.setup(n, spec.k, cfg.seed ^ GENERATOR_SEED_TAG)?;
        let encoder = Encoder::new(gen.clone());
        // Setup boundary: honors the `encode_threads` hint by building a
        // dedicated pool once for this job's whole lifetime.
        let pool = cfg.resolve_pool();
        let coded = code.encode(&encoder, a, &pool, pool.threads())?;
        let chunks = encoder
            .chunk(&coded, &per_worker)?
            .into_iter()
            .map(Arc::new)
            .collect();
        let mut decoder = Decoder::with_cache_capacity(gen, cfg.decode_cache);
        decoder.set_pool(Some(Arc::clone(&pool)));
        let rateless = code.generator() == GeneratorKind::RatelessRlc;
        Ok(PreparedJob {
            spec: spec.clone(),
            cfg: cfg.clone(),
            per_worker,
            n,
            code,
            a: (cfg.verify_decode || rateless).then(|| a.clone()),
            encoder,
            coded,
            chunks,
            rechunks: 0,
            decoder,
            pool,
            rows_buf: Vec::new(),
            cols_buf: Vec::new(),
            injector_scratch: None,
            completion_order: Vec::new(),
            xs_slot: None,
            xs_spare: Vec::new(),
            cols_spare: Vec::new(),
            grows: 0,
        })
    }

    /// The compute pool this job's kernels run on.
    pub fn pool(&self) -> &PoolHandle {
        &self.pool
    }

    /// The erasure code this job serves with.
    pub fn code(&self) -> &dyn Code {
        self.code.as_ref()
    }

    /// Scratch-arena allocation/grow events since construction — one per
    /// batch that had to allocate or enlarge a big per-batch buffer (the
    /// request-dispatch arena, the straggle-draw buffer, the collection
    /// buffers, or the decoder's RHS/solve staging). The first batch sizes
    /// everything; a steady-state stream holds this flat afterwards, which
    /// is the measured invariant behind
    /// [`crate::coordinator::ServeOutcome`]'s `steady_allocs` (mirroring
    /// the `encodes == 1` pattern: counted where the buffers live, not
    /// declared).
    pub fn scratch_grows(&self) -> u64 {
        self.grows + self.decoder.scratch_grows()
    }

    /// Code length `n` actually used.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Current integer per-worker loads (group-major; `0` = drained or
    /// dead worker holding no chunk).
    pub fn per_worker(&self) -> &[usize] {
        &self.per_worker
    }

    /// Re-allocations performed through [`PreparedJob::rechunk`].
    pub fn rechunk_count(&self) -> u64 {
        self.rechunks
    }

    /// Re-allocate: re-slice the **already-encoded** rows into a new
    /// per-worker split (one entry per worker; `0` drains a worker, e.g. a
    /// dead one). The split may cover only `k ≤ Σ l_i ≤ n` rows — the rows
    /// were minted once at construction, and any `≥ k` subset of the MDS
    /// code decodes. Performs zero encode work, observable through
    /// [`PreparedJob::encode_count`]; the decoder (and its factorization
    /// cache) carries over because the generator is unchanged.
    pub fn rechunk(&mut self, per_worker: &[usize]) -> Result<()> {
        if per_worker.len() != self.spec.total_workers() {
            return Err(Error::InvalidSpec(format!(
                "{} loads for {} workers",
                per_worker.len(),
                self.spec.total_workers()
            )));
        }
        let chunks = self.encoder.rechunk(&self.coded, per_worker)?;
        self.per_worker = per_worker.to_vec();
        self.chunks = chunks.into_iter().map(Arc::new).collect();
        self.rechunks += 1;
        Ok(())
    }

    /// Whether this job serves with the rateless fountain — the only
    /// code whose row horizon can grow after setup.
    pub fn is_rateless(&self) -> bool {
        self.code.generator() == GeneratorKind::RatelessRlc
    }

    /// Grow the rateless row horizon to `new_n`: mint coefficient rows
    /// `[n, new_n)` (pure functions of `(seed, index)` — no existing row
    /// is touched), extend the generator prefix, and append the fresh
    /// coded rows. No-op if `new_n ≤ n`. The zero-re-encode claim is
    /// *measured* by [`PreparedJob::re_encoded_rows`]: every extension
    /// starts at the encoder's watermark, so the overlap counter stays 0.
    fn extend_horizon(&mut self, new_n: usize) -> Result<()> {
        if new_n <= self.n {
            return Ok(());
        }
        let a = self.a.as_ref().ok_or_else(|| {
            Error::Runtime("rateless job lost its data matrix".into())
        })?;
        let fresh = self.code.encode_rows(
            &self.encoder,
            a,
            self.n..new_n,
            &self.pool,
            self.pool.threads(),
        )?;
        self.encoder.extend_to(new_n)?;
        for r in 0..fresh.rows() {
            self.coded.push_row(fresh.row(r))?;
        }
        self.n = new_n;
        Ok(())
    }

    /// Elastic scale-out: re-allocate like [`PreparedJob::rechunk`], but
    /// when the new loads want more rows than exist (`Σ l_i > n`) and the
    /// code is rateless, mint exactly the missing tail first. Newly
    /// arriving capacity therefore gets **fresh** row ranges — the
    /// previously issued rows are never re-encoded, and
    /// [`PreparedJob::re_encoded_rows`] measures that rather than
    /// declaring it. Finite codes keep the hard `n` ceiling (the rechunk
    /// error explains that re-encoding is their only way out).
    pub fn extend_rechunk(&mut self, per_worker: &[usize]) -> Result<()> {
        let total: usize = per_worker.iter().sum();
        if total > self.n && self.is_rateless() {
            self.extend_horizon(total)?;
        }
        self.rechunk(per_worker)
    }

    /// Encode passes performed through this job's encoder since
    /// construction — a live measurement (the encoder's own call counter),
    /// not a declared constant. The steady-state serving invariant is that
    /// this stays 1 no matter how many batches run.
    pub fn encode_count(&self) -> u64 {
        self.encoder.encode_calls()
    }

    /// Coded rows produced by this job's encoder (row-level counter; the
    /// setup encode contributes `n`).
    pub fn rows_encoded(&self) -> u64 {
        self.encoder.rows_encoded()
    }

    /// Rows encoded *again* — ranges overlapping the encoder's
    /// high-water mark. The rateless elasticity invariant is that this
    /// stays 0 across any schedule of streaming extensions and
    /// scale-outs.
    pub fn re_encoded_rows(&self) -> u64 {
        self.encoder.re_encoded_rows()
    }

    /// Decode factorizations served *around* the LRU cache by the
    /// thrash-bypass guard.
    pub fn decode_cache_bypasses(&self) -> u64 {
        self.decoder.cache_bypasses()
    }

    /// Decode factorization-cache `(hits, misses)` counters.
    pub fn decode_cache_stats(&self) -> (u64, u64) {
        self.decoder.cache_stats()
    }

    /// Serve one request batch through the prepared plan. `batch_seed`
    /// drives the straggle realization only (the generator is fixed);
    /// workers and dead-worker handling match the cold
    /// [`crate::coordinator::run_job_batched`] path exactly.
    pub fn run_batch(
        &mut self,
        requests: &[Vec<f64>],
        compute: Arc<dyn Compute>,
        batch_seed: u64,
    ) -> Result<Vec<JobReport>> {
        // Redraw the straggle realization into the reusable injector —
        // bit-identical to a fresh sample, no per-batch allocation after
        // the first batch.
        let mut injector = match self.injector_scratch.take() {
            Some(inj) => inj,
            None => {
                self.grows += 1;
                StragglerInjector::sample(
                    &self.spec,
                    self.cfg.model,
                    &self.per_worker,
                    self.cfg.time_scale,
                    batch_seed ^ STRAGGLE_SEED_TAG,
                )?
            }
        };
        injector.resample(
            &self.spec,
            self.cfg.model,
            &self.per_worker,
            self.cfg.time_scale,
            batch_seed ^ STRAGGLE_SEED_TAG,
        )?;
        injector.set_dead(self.cfg.dead_workers.iter().copied());
        let result = self.run_batch_injected(requests, compute, &injector);
        self.injector_scratch = Some(injector);
        result.map(|(reports, _)| reports)
    }

    /// Stage the batch's request vectors in the reusable dispatch arena.
    ///
    /// Worker threads hold the returned `Arc` while they sleep out their
    /// straggle delays, so the buffer cannot simply be overwritten — it is
    /// *reclaimed* via `Arc::try_unwrap` at the next batch once every
    /// straggler has dropped its clone. Steady state (same-shaped batches,
    /// stragglers drained between batches) then touches no allocator; a
    /// straggler still alive from the previous batch forces one fresh
    /// allocation, which is counted, not hidden.
    fn stage_requests(&mut self, requests: &[Vec<f64>]) -> Arc<Vec<Vec<f64>>> {
        let mut buf = match self.xs_slot.take().map(Arc::try_unwrap) {
            Some(Ok(v)) => v,
            _ => {
                self.grows += 1;
                Vec::new()
            }
        };
        if buf.capacity() < requests.len() {
            self.grows += 1;
        }
        // Shrink by parking sized inner buffers (a later bigger batch
        // reclaims them); grow from the parking lot before the allocator.
        while buf.len() > requests.len() {
            self.xs_spare.push(buf.pop().expect("len checked"));
        }
        while buf.len() < requests.len() {
            buf.push(self.xs_spare.pop().unwrap_or_default());
        }
        let mut inner_grew = false;
        for (dst, src) in buf.iter_mut().zip(requests) {
            inner_grew |= dst.capacity() < src.len();
            dst.clear();
            dst.extend_from_slice(src);
        }
        self.grows += u64::from(inner_grew);
        let arc = Arc::new(buf);
        self.xs_slot = Some(Arc::clone(&arc));
        arc
    }

    /// [`PreparedJob::run_batch`] with an explicit straggle realization —
    /// the hook the failure/drift scenario layer uses to sample from the
    /// *effective* cluster ([`crate::coordinator::ScenarioState`]) rather
    /// than the spec the job was prepared for. Also returns the consumed
    /// worker replies as [`WorkerObservation`]s so an online estimator can
    /// watch the stream.
    pub fn run_batch_injected(
        &mut self,
        requests: &[Vec<f64>],
        compute: Arc<dyn Compute>,
        injector: &StragglerInjector,
    ) -> Result<(Vec<JobReport>, Vec<WorkerObservation>)> {
        self.run_batch_lossy(requests, compute, injector, &[], 0)
    }

    /// [`PreparedJob::run_batch_injected`] over lossy links: each reply
    /// is split into packets of [`RATELESS_PACKET_ROWS`] rows and each
    /// packet survives its worker's Bernoulli draw
    /// ([`crate::coordinator::rateless::packet_dropped`], keyed by
    /// `batch_seed` and the packet's first global row) or vanishes.
    /// `loss` is the per-worker delivery loss probability (empty = none,
    /// which is the bit-identical legacy path). The fixed-`n` MDS code
    /// has no recourse when the surviving support falls below `k`: the
    /// batch fails with a clean sub-`k` decode error — exactly the
    /// ceiling the rateless path removes.
    pub fn run_batch_lossy(
        &mut self,
        requests: &[Vec<f64>],
        compute: Arc<dyn Compute>,
        injector: &StragglerInjector,
        loss: &[f64],
        batch_seed: u64,
    ) -> Result<(Vec<JobReport>, Vec<WorkerObservation>)> {
        if requests.is_empty() {
            return Err(Error::InvalidSpec("empty request batch".into()));
        }
        if injector.len() != self.spec.total_workers() {
            return Err(Error::InvalidSpec(format!(
                "injector covers {} workers, cluster has {}",
                injector.len(),
                self.spec.total_workers()
            )));
        }
        let b = requests.len();
        let k = self.spec.k;
        let model_latency = injector.analytic_completion_with(
            &self.per_worker,
            k,
            &mut self.completion_order,
        );

        let xs_arc = self.stage_requests(requests);
        let (tx, rx) = mpsc::channel::<BatchReply>();
        let start = wall_now();
        for chunk in &self.chunks {
            let w = chunk.worker;
            if injector.is_dead(w) {
                continue;
            }
            let delay = injector.wall_delay(w);
            let chunk = Arc::clone(chunk);
            let xs = Arc::clone(&xs_arc);
            let cmp = Arc::clone(&compute);
            let sender = tx.clone();
            // Allowlisted thread-creation site (lint rule D3): worker
            // emulation blocks in `sleep` for the injected wall delay,
            // so it cannot occupy a WorkPool worker.
            #[allow(clippy::disallowed_methods)]
            std::thread::Builder::new()
                .name(format!("worker-{w}"))
                .spawn(move || {
                    std::thread::sleep(delay);
                    if let Ok(ys) = cmp.matvec_batch(&chunk.rows, &xs) {
                        let _ = sender.send(BatchReply {
                            worker: w,
                            range: chunk.row_range.clone(),
                            task: 0,
                            ys,
                        });
                    }
                })
                .map_err(|e| Error::Runtime(format!("spawn worker {w}: {e}")))?;
        }
        drop(tx); // master holds only the receiver

        // Collect the shared row support until k rows, into arenas
        // reserved to the hard bound (`n` coded rows exist in total) so
        // capacity is fixed up front instead of drifting with straggle
        // realizations — after this block, collection itself can never
        // allocate. A shrinking batch parks its surplus columns; a
        // growing one reclaims them before touching the allocator.
        let mut grew = self.rows_buf.capacity() < self.n;
        self.rows_buf.clear();
        self.rows_buf.reserve(self.n);
        while self.cols_buf.len() > b {
            self.cols_spare
                .push(self.cols_buf.pop().expect("len checked"));
        }
        while self.cols_buf.len() < b {
            self.cols_buf.push(self.cols_spare.pop().unwrap_or_default());
        }
        for col in self.cols_buf.iter_mut() {
            grew |= col.capacity() < self.n;
            col.clear();
            col.reserve(self.n);
        }
        self.grows += u64::from(grew);
        let mut workers_used = 0usize;
        let mut observed = Vec::new();
        while self.rows_buf.len() < k {
            match rx.recv() {
                Ok(reply) => {
                    workers_used += 1;
                    observed.push(WorkerObservation {
                        worker: reply.worker,
                        load: reply.range.len(),
                        model_time: injector.model_delay(reply.worker),
                    });
                    let p = loss.get(reply.worker).copied().unwrap_or(0.0);
                    if p <= 0.0 {
                        self.rows_buf.extend(reply.range.clone());
                        for (col, y) in self.cols_buf.iter_mut().zip(&reply.ys)
                        {
                            col.extend_from_slice(y);
                        }
                    } else {
                        self.absorb_lossy_reply(&reply, p, batch_seed);
                    }
                }
                Err(_) => {
                    return Err(Error::Decode(format!(
                        "only {} of {} rows arrived ({})",
                        self.rows_buf.len(),
                        k,
                        if loss.is_empty() {
                            "too many dead workers?"
                        } else {
                            "dead workers or lossy links; the fixed-n code \
                             cannot solicit more rows"
                        }
                    )))
                }
            }
        }
        let rows_collected = self.rows_buf.len();
        let decoded_all = self.code.decode_rows(
            &mut self.decoder,
            &self.rows_buf,
            &self.cols_buf[..b],
        )?;
        let wall_latency = start.elapsed();

        let mut reports = Vec::with_capacity(b);
        for (decoded, request) in decoded_all.into_iter().zip(requests) {
            // Ground-truth verification is O(k·d) master-side work per
            // request — real serving disables it (`cfg.verify_decode`).
            // Gated on the flag, not on `a`: rateless jobs keep the data
            // matrix around for row minting even when not verifying.
            let max_error = if self.cfg.verify_decode {
                let truth = self
                    .a
                    .as_ref()
                    .expect("verify_decode keeps the data matrix")
                    .matvec(request);
                decoded
                    .iter()
                    .zip(&truth)
                    .map(|(d, t)| (d - t).abs())
                    .fold(0.0f64, f64::max)
            } else {
                f64::NAN
            };
            reports.push(JobReport {
                wall_latency,
                model_latency,
                decoded,
                max_error,
                workers_used,
                rows_collected,
                n: self.n,
                backend: compute.name(),
            });
        }
        Ok((reports, observed))
    }

    /// Append the surviving packets of one reply to the collection
    /// arenas; returns the number of rows that made it. Packet fate is a
    /// pure function of `(batch_seed, first global row, p)` — see
    /// [`crate::coordinator::rateless::packet_dropped`].
    fn absorb_lossy_reply(
        &mut self,
        reply: &BatchReply,
        p: f64,
        batch_seed: u64,
    ) -> u64 {
        let start = reply.range.start;
        let len = reply.range.len();
        let mut survivors = 0u64;
        let mut off = 0usize;
        while off < len {
            let pk = RATELESS_PACKET_ROWS.min(len - off);
            if !packet_dropped(batch_seed, start + off, p) {
                self.rows_buf.extend(start + off..start + off + pk);
                for (col, y) in self.cols_buf.iter_mut().zip(&reply.ys) {
                    col.extend_from_slice(&y[off..off + pk]);
                }
                survivors += pk as u64;
            }
            off += pk;
        }
        survivors
    }

    /// [`PreparedJob::run_batch_rateless_injected`] with the straggle
    /// realization derived from `batch_seed` — the streaming analogue of
    /// [`PreparedJob::run_batch`]. Returns the per-batch streaming
    /// tallies alongside the reports.
    pub fn run_batch_streamed(
        &mut self,
        requests: &[Vec<f64>],
        compute: Arc<dyn Compute>,
        batch_seed: u64,
        loss: &[f64],
    ) -> Result<(Vec<JobReport>, RatelessBatchStats)> {
        let mut injector = match self.injector_scratch.take() {
            Some(inj) => inj,
            None => {
                self.grows += 1;
                StragglerInjector::sample(
                    &self.spec,
                    self.cfg.model,
                    &self.per_worker,
                    self.cfg.time_scale,
                    batch_seed ^ STRAGGLE_SEED_TAG,
                )?
            }
        };
        injector.resample(
            &self.spec,
            self.cfg.model,
            &self.per_worker,
            self.cfg.time_scale,
            batch_seed ^ STRAGGLE_SEED_TAG,
        )?;
        injector.set_dead(self.cfg.dead_workers.iter().copied());
        let result = self.run_batch_rateless_injected(
            requests,
            compute,
            &injector,
            loss,
            batch_seed,
        );
        self.injector_scratch = Some(injector);
        result.map(|(reports, _, stats)| (reports, stats))
    }

    /// Serve one batch by **streaming**: instead of dispatching fixed
    /// chunks and stopping at `k`, the master runs solicitation rounds —
    /// each round issues just enough *fresh* coded rows to cover its
    /// deficit (inflated by ≈12.5% plus one packet when links are
    /// lossy), split over live workers proportionally to their loads,
    /// and the round's surviving packets join the decode support. Rows
    /// come first from the already-encoded prefix; when a round needs
    /// more, the horizon grows in place ([`PreparedJob::extend_horizon`])
    /// by minting rows at fresh indices — never re-encoding, which
    /// [`PreparedJob::re_encoded_rows`] measures.
    ///
    /// The result is bit-reproducible from the seed at any pool size:
    /// row coefficients, packet fates, and the processing order (a
    /// per-round barrier sorted by global row) are all arrival-order
    /// independent.
    pub fn run_batch_rateless_injected(
        &mut self,
        requests: &[Vec<f64>],
        compute: Arc<dyn Compute>,
        injector: &StragglerInjector,
        loss: &[f64],
        batch_seed: u64,
    ) -> Result<(Vec<JobReport>, Vec<WorkerObservation>, RatelessBatchStats)>
    {
        if requests.is_empty() {
            return Err(Error::InvalidSpec("empty request batch".into()));
        }
        if injector.len() != self.spec.total_workers() {
            return Err(Error::InvalidSpec(format!(
                "injector covers {} workers, cluster has {}",
                injector.len(),
                self.spec.total_workers()
            )));
        }
        if !self.is_rateless() {
            return Err(Error::InvalidSpec(format!(
                "streamed serving needs the rateless code, job uses {}",
                self.code.name()
            )));
        }
        let b = requests.len();
        let k = self.spec.k;
        let model_latency = injector.analytic_completion_with(
            &self.per_worker,
            k,
            &mut self.completion_order,
        );
        // Issuance weights: live workers whose link can deliver at all
        // (a fully dark link — burst window, p = 1 — earns no rows this
        // batch; Bernoulli-lossy links stay in and the inflation covers
        // their expected shortfall).
        let mut weights: Vec<(usize, usize)> = Vec::new();
        for (w, &l) in self.per_worker.iter().enumerate() {
            if injector.is_dead(w) {
                continue;
            }
            if loss.get(w).copied().unwrap_or(0.0) >= 1.0 {
                continue;
            }
            weights.push((w, l));
        }
        if weights.is_empty() {
            return Err(Error::Decode(
                "no worker can deliver rows (all dead or fully lossy)".into(),
            ));
        }
        let lossy = weights
            .iter()
            .any(|&(w, _)| loss.get(w).copied().unwrap_or(0.0) > 0.0);

        let xs_arc = self.stage_requests(requests);
        let start = wall_now();
        let mut grew = self.rows_buf.capacity() < self.n;
        self.rows_buf.clear();
        self.rows_buf.reserve(self.n);
        while self.cols_buf.len() > b {
            self.cols_spare
                .push(self.cols_buf.pop().expect("len checked"));
        }
        while self.cols_buf.len() < b {
            self.cols_buf.push(self.cols_spare.pop().unwrap_or_default());
        }
        for col in self.cols_buf.iter_mut() {
            grew |= col.capacity() < self.n;
            col.clear();
            col.reserve(self.n);
        }
        self.grows += u64::from(grew);

        let mut stats = RatelessBatchStats::default();
        let mut observed = Vec::new();
        let mut contributed = vec![false; self.spec.total_workers()];
        let mut cursor = 0usize; // next unissued global row this batch
        let mut rounds = 0u64;
        while self.rows_buf.len() < k {
            if rounds >= RATELESS_MAX_ROUNDS {
                return Err(Error::Decode(format!(
                    "streamed collection stalled after {rounds} rounds \
                     with {} of {k} rows (links too lossy?)",
                    self.rows_buf.len()
                )));
            }
            let deficit = k - self.rows_buf.len();
            let inflation = if lossy {
                deficit.div_ceil(8) + RATELESS_PACKET_ROWS
            } else {
                0
            };
            let issue = deficit + inflation;
            if cursor + issue > self.n {
                self.extend_horizon(cursor + issue)?;
            }
            let shares = proportional_shares(issue, &weights);
            let (tx, rx) = mpsc::channel::<BatchReply>();
            let mut next_row = cursor;
            for &(w, cnt) in &shares {
                if cnt == 0 {
                    continue;
                }
                let range = next_row..next_row + cnt;
                next_row = range.end;
                let idx: Vec<usize> = range.clone().collect();
                let chunk = Arc::new(WorkerChunk {
                    worker: w,
                    row_range: range,
                    rows: self.coded.select_rows(&idx),
                });
                let delay = injector.wall_delay(w);
                let xs = Arc::clone(&xs_arc);
                let cmp = Arc::clone(&compute);
                let sender = tx.clone();
                // Allowlisted thread-creation site (lint rule D3): same
                // sleep-then-compute emulation as the fixed-chunk path.
                #[allow(clippy::disallowed_methods)]
                std::thread::Builder::new()
                    .name(format!("worker-{w}"))
                    .spawn(move || {
                        std::thread::sleep(delay);
                        if let Ok(ys) = cmp.matvec_batch(&chunk.rows, &xs) {
                            let _ = sender.send(BatchReply {
                                worker: w,
                                range: chunk.row_range.clone(),
                                task: 0,
                                ys,
                            });
                        }
                    })
                    .map_err(|e| {
                        Error::Runtime(format!("spawn worker {w}: {e}"))
                    })?;
            }
            drop(tx);
            cursor += issue;
            stats.rows_issued += issue as u64;
            // Round barrier: gather every reply, then process in global
            // row order so the decode support never depends on arrival
            // timing.
            let mut replies: Vec<BatchReply> = rx.iter().collect();
            replies.sort_by_key(|r| r.range.start);
            for reply in &replies {
                contributed[reply.worker] = true;
                observed.push(WorkerObservation {
                    worker: reply.worker,
                    load: reply.range.len(),
                    model_time: injector.model_delay(reply.worker),
                });
                let p = loss.get(reply.worker).copied().unwrap_or(0.0);
                let got = if p <= 0.0 {
                    self.rows_buf.extend(reply.range.clone());
                    for (col, y) in self.cols_buf.iter_mut().zip(&reply.ys) {
                        col.extend_from_slice(y);
                    }
                    reply.range.len() as u64
                } else {
                    self.absorb_lossy_reply(reply, p, batch_seed)
                };
                stats.rows_received += got;
            }
            rounds += 1;
        }
        stats.extend_rounds = rounds.saturating_sub(1);

        let rows_collected = self.rows_buf.len();
        let decoded_all = self.code.decode_rows(
            &mut self.decoder,
            &self.rows_buf,
            &self.cols_buf[..b],
        )?;
        let wall_latency = start.elapsed();
        let workers_used = contributed.iter().filter(|&&c| c).count();
        let mut reports = Vec::with_capacity(b);
        for (decoded, request) in decoded_all.into_iter().zip(requests) {
            let max_error = if self.cfg.verify_decode {
                let truth = self
                    .a
                    .as_ref()
                    .expect("verify_decode keeps the data matrix")
                    .matvec(request);
                decoded
                    .iter()
                    .zip(&truth)
                    .map(|(d, t)| (d - t).abs())
                    .fold(0.0f64, f64::max)
            } else {
                f64::NAN
            };
            reports.push(JobReport {
                wall_latency,
                model_latency,
                decoded,
                max_error,
                workers_used,
                rows_collected,
                n: self.n,
                backend: compute.name(),
            });
        }
        Ok((reports, observed, stats))
    }

    /// Spawn a worker-emulation thread for an explicit row list (hedge
    /// re-issues and canary probes): the rows are gathered from the cached
    /// encoded matrix — `select_rows`, never a re-encode — and the reply
    /// carries the task id so the master matches it without guessing.
    fn spawn_scattered(
        &self,
        task: usize,
        w: usize,
        rows: &[usize],
        delay: Duration,
        xs: &Arc<Vec<Vec<f64>>>,
        compute: &Arc<dyn Compute>,
        tx: &mpsc::Sender<BatchReply>,
    ) -> Result<()> {
        let mat = self.coded.select_rows(rows);
        let xs = Arc::clone(xs);
        let cmp = Arc::clone(compute);
        let sender = tx.clone();
        // Allowlisted thread-creation site (lint rule D3): same
        // sleep-then-compute emulation as the fixed-chunk path.
        #[allow(clippy::disallowed_methods)]
        std::thread::Builder::new()
            .name(format!("hedge-{w}"))
            .spawn(move || {
                std::thread::sleep(delay);
                if let Ok(ys) = cmp.matvec_batch(&mat, &xs) {
                    let _ = sender.send(BatchReply {
                        worker: w,
                        range: 0..0,
                        task,
                        ys,
                    });
                }
            })
            .map_err(|e| Error::Runtime(format!("spawn hedge on {w}: {e}")))?;
        Ok(())
    }

    /// Issue one hedge task covering `rows` for the blown lineage of
    /// `origin` at retry wave `wave` (`>= 1`). The executor is picked
    /// deterministically from the engine's speed-ranked helper list,
    /// rotated by wave so consecutive retries of one lineage fan out
    /// across distinct workers; its deadline is its own analytic quantile
    /// for this load, stretched by `backoff^(wave-1)`. Returns whether a
    /// task was actually issued (no helpers → `false`).
    #[allow(clippy::too_many_arguments)]
    fn issue_hedge(
        &self,
        tasks: &mut Vec<HedgeTask>,
        engine: &mut RecoveryEngine,
        origin: usize,
        rows: Vec<usize>,
        wave: u32,
        now: Duration,
        alive: &[bool],
        stalled: &[bool],
        injector: &StragglerInjector,
        xs: &Arc<Vec<Vec<f64>>>,
        compute: &Arc<dyn Compute>,
        tx: &mpsc::Sender<BatchReply>,
    ) -> Result<bool> {
        if rows.is_empty() {
            return Ok(false);
        }
        let helpers = engine.ranked_helpers(origin, alive);
        if helpers.is_empty() {
            return Ok(false);
        }
        let executor = helpers[((wave.max(1) - 1) as usize) % helpers.len()];
        let ts = self.cfg.time_scale;
        let base = engine.deadline_for_load(executor, rows.len());
        let backoff = engine.config().backoff.powi(wave.max(1) as i32 - 1);
        let deadline = now + Duration::from_secs_f64(base * backoff * ts);
        let task = tasks.len();
        if !stalled.get(executor).copied().unwrap_or(false) {
            // The helper's speed this batch is its straggle draw, pro-rated
            // to the hedge's row count (same machine, same epoch — the
            // per-row rate of the draw carries over).
            let load = self.per_worker[executor].max(1) as f64;
            let delay_model =
                injector.model_delay(executor) * rows.len() as f64 / load;
            let delay = Duration::from_secs_f64(delay_model * ts);
            self.spawn_scattered(task, executor, &rows, delay, xs, compute, tx)?;
        }
        tasks.push(HedgeTask {
            executor,
            origin,
            rows: TaskRows::Scattered(rows),
            deadline,
            wave,
            pending: true,
            is_hedge: true,
            is_canary: false,
        });
        engine.note_hedges_issued(1);
        Ok(true)
    }

    /// Mint `cnt` fresh rateless rows past the watermark (zero re-encodes,
    /// measured by [`PreparedJob::re_encoded_rows`]) and grow the dedup
    /// bitmap to match; returns the fresh global indices.
    fn mint_fresh(
        &mut self,
        cnt: usize,
        have: &mut Vec<bool>,
    ) -> Result<Vec<usize>> {
        let first = self.n;
        self.extend_horizon(first + cnt)?;
        have.resize(self.n, false);
        Ok((first..first + cnt).collect())
    }

    /// [`PreparedJob::run_batch_lossy`] under the deadline/hedging engine
    /// ([`crate::coordinator::recovery`]). Differences from the legacy
    /// collection loop, none of which change a failure-free batch:
    ///
    /// - Every dispatch gets a hedge deadline (its analytic runtime
    ///   quantile, staged in the engine from the estimator's current
    ///   specs); a blown deadline re-issues the task's *missing* rows to
    ///   the fastest ranked helper — spare MDS row copies under `mds-*`
    ///   codes, fresh minted rows under `rateless-rlc` — with exponential
    ///   backoff across waves.
    /// - Replies deduplicate by global row index (`first-completion-wins`):
    ///   whichever copy lands first contributes, duplicates count as
    ///   `wasted_rows`. When any hedge fired, the support is sorted by row
    ///   index before decode, so the decoded bytes are a pure function of
    ///   the final support *set*, not of arrival order between copies.
    /// - `stalled[w]` marks workers that are alive but dark this batch
    ///   (scripted `StallWorker`/`FlappyWorker`): their thread never
    ///   replies, but the channel stays open — the master's clock, not a
    ///   hangup, detects them.
    /// - Quarantined workers are not dispatched: their chunk is hedged to
    ///   healthy workers at wave 1 immediately, and a single canary row
    ///   probes them; an in-deadline canary reply re-admits the worker at
    ///   the batch boundary.
    /// - If the batch deadline expires short of `k`, the engine degrades
    ///   per policy: `Fail` is a typed decode error, `Partial` returns the
    ///   sorted partial support as a [`DegradedBatch`] plus per-request
    ///   placeholder reports (empty `decoded`, NaN error) — never a hang.
    #[allow(clippy::too_many_arguments)]
    pub fn run_batch_hedged(
        &mut self,
        requests: &[Vec<f64>],
        compute: Arc<dyn Compute>,
        injector: &StragglerInjector,
        loss: &[f64],
        batch_seed: u64,
        stalled: &[bool],
        engine: &mut RecoveryEngine,
    ) -> Result<(Vec<JobReport>, Vec<WorkerObservation>, Option<DegradedBatch>)>
    {
        if requests.is_empty() {
            return Err(Error::InvalidSpec("empty request batch".into()));
        }
        let nw = self.spec.total_workers();
        if injector.len() != nw {
            return Err(Error::InvalidSpec(format!(
                "injector covers {} workers, cluster has {nw}",
                injector.len()
            )));
        }
        let b = requests.len();
        let k = self.spec.k;
        let ts = self.cfg.time_scale;
        let alive: Vec<bool> = (0..nw).map(|w| !injector.is_dead(w)).collect();
        let any_stalled_live = (0..nw).any(|w| {
            alive[w]
                && self.per_worker[w] > 0
                && stalled.get(w).copied().unwrap_or(false)
        });
        // The analytic completion law does not model stalls or hedges —
        // only report it when it actually describes the batch.
        let model_latency = if any_stalled_live {
            None
        } else {
            injector.analytic_completion_with(
                &self.per_worker,
                k,
                &mut self.completion_order,
            )
        };

        let xs_arc = self.stage_requests(requests);
        let (tx, rx) = mpsc::channel::<BatchReply>();
        let start = wall_now();

        // Original dispatch: skip dead and quarantined workers; stalled
        // workers get a task (and a deadline) but no thread — alive but
        // dark. The master keeps `tx` for the whole collection, so a
        // fully-stalled fleet times out instead of hanging up.
        let mut tasks: Vec<HedgeTask> = Vec::new();
        let mut quarantined_chunks: Vec<usize> = Vec::new();
        for (ci, chunk) in self.chunks.iter().enumerate() {
            let w = chunk.worker;
            if injector.is_dead(w) || chunk.row_range.is_empty() {
                continue;
            }
            if engine.is_quarantined(w) {
                quarantined_chunks.push(ci);
                continue;
            }
            engine.note_dispatched(w);
            let task = tasks.len();
            tasks.push(HedgeTask {
                executor: w,
                origin: w,
                rows: TaskRows::Contiguous(chunk.row_range.clone()),
                deadline: Duration::from_secs_f64(
                    engine.deadline_model(w) * ts,
                ),
                wave: 0,
                pending: true,
                is_hedge: false,
                is_canary: false,
            });
            if stalled.get(w).copied().unwrap_or(false) {
                continue;
            }
            let delay = injector.wall_delay(w);
            let chunk = Arc::clone(chunk);
            let xs = Arc::clone(&xs_arc);
            let cmp = Arc::clone(&compute);
            let sender = tx.clone();
            // Allowlisted thread-creation site (lint rule D3): worker
            // emulation blocks in `sleep` for the injected wall delay.
            #[allow(clippy::disallowed_methods)]
            std::thread::Builder::new()
                .name(format!("worker-{w}"))
                .spawn(move || {
                    std::thread::sleep(delay);
                    if let Ok(ys) = cmp.matvec_batch(&chunk.rows, &xs) {
                        let _ = sender.send(BatchReply {
                            worker: w,
                            range: chunk.row_range.clone(),
                            task,
                            ys,
                        });
                    }
                })
                .map_err(|e| Error::Runtime(format!("spawn worker {w}: {e}")))?;
        }

        let batch_wall_deadline = {
            let dispatchable: Vec<bool> = (0..nw)
                .map(|w| alive[w] && self.per_worker[w] > 0)
                .collect();
            Duration::from_secs_f64(
                engine.batch_deadline_model(&dispatchable) * ts,
            )
        };

        // Collection arenas (same reserve discipline as the legacy path)
        // plus the first-completion-wins dedup bitmap.
        let mut grew = self.rows_buf.capacity() < self.n;
        self.rows_buf.clear();
        self.rows_buf.reserve(self.n);
        while self.cols_buf.len() > b {
            self.cols_spare
                .push(self.cols_buf.pop().expect("len checked"));
        }
        while self.cols_buf.len() < b {
            self.cols_buf.push(self.cols_spare.pop().unwrap_or_default());
        }
        for col in self.cols_buf.iter_mut() {
            grew |= col.capacity() < self.n;
            col.clear();
            col.reserve(self.n);
        }
        self.grows += u64::from(grew);
        let mut have = vec![false; self.n];

        // Quarantine handling: canary probe (one row, its own deadline)
        // plus an immediate wave-1 cover of the whole chunk — the ring
        // never holds the batch hostage.
        let hedge_on = engine.config().hedge;
        for ci in quarantined_chunks {
            let (w, range) = {
                let c = &self.chunks[ci];
                (c.worker, c.row_range.clone())
            };
            let canary_row = range.start;
            let task = tasks.len();
            tasks.push(HedgeTask {
                executor: w,
                origin: w,
                rows: TaskRows::Scattered(vec![canary_row]),
                deadline: Duration::from_secs_f64(
                    engine.deadline_for_load(w, 1) * ts,
                ),
                wave: 0,
                pending: true,
                is_hedge: false,
                is_canary: true,
            });
            if !stalled.get(w).copied().unwrap_or(false) {
                let load = self.per_worker[w].max(1) as f64;
                let delay = Duration::from_secs_f64(
                    injector.model_delay(w) / load * ts,
                );
                self.spawn_scattered(
                    task,
                    w,
                    &[canary_row],
                    delay,
                    &xs_arc,
                    &compute,
                    &tx,
                )?;
            }
            if hedge_on {
                self.issue_hedge(
                    &mut tasks,
                    engine,
                    w,
                    range.collect(),
                    1,
                    Duration::ZERO,
                    &alive,
                    stalled,
                    injector,
                    &xs_arc,
                    &compute,
                    &tx,
                )?;
            }
        }

        let max_waves = engine.config().max_waves;
        let mut workers_used = 0usize;
        let mut observed = Vec::new();
        let mut any_hedge = tasks.iter().any(|t| t.is_hedge);
        let mut repair_wave = 0u32;
        while self.rows_buf.len() < k {
            let now = start.elapsed();
            if now >= batch_wall_deadline {
                break; // degrade below
            }
            // Blown deadlines: mark, blame originals, re-issue missing
            // rows at the next wave (capped).
            let mut to_issue: Vec<(usize, Vec<usize>, u32)> = Vec::new();
            for t in tasks.iter_mut() {
                if !t.pending || now < t.deadline {
                    continue;
                }
                t.pending = false;
                if !t.is_hedge && !t.is_canary {
                    engine.note_blown(t.origin);
                }
                if hedge_on && !t.is_canary && t.wave < max_waves {
                    let missing: Vec<usize> = (0..t.rows.len())
                        .map(|i| t.rows.at(i))
                        .filter(|&r| !have[r])
                        .collect();
                    if !missing.is_empty() {
                        to_issue.push((t.origin, missing, t.wave + 1));
                    }
                }
            }
            for (origin, rows, wave) in to_issue {
                let rows = if self.is_rateless() {
                    self.mint_fresh(rows.len(), &mut have)?
                } else {
                    rows
                };
                any_hedge |= self.issue_hedge(
                    &mut tasks, engine, origin, rows, wave, now, &alive,
                    stalled, injector, &xs_arc, &compute, &tx,
                )?;
            }
            // Everything resolved but the support is short (loss ate
            // packets, or no helper was available): pool-wide repair
            // waves re-solicit the deficit from spare redundancy.
            if self.rows_buf.len() < k && !tasks.iter().any(|t| t.pending) {
                if hedge_on && repair_wave < max_waves {
                    repair_wave += 1;
                    let deficit = k - self.rows_buf.len();
                    let lossy = loss.iter().any(|&p| p > 0.0);
                    let inflation = if lossy {
                        deficit.div_ceil(8) + RATELESS_PACKET_ROWS
                    } else {
                        0
                    };
                    let want = deficit + inflation;
                    let rows = if self.is_rateless() {
                        self.mint_fresh(want, &mut have)?
                    } else {
                        (0..self.n).filter(|&r| !have[r]).take(want).collect()
                    };
                    let now = start.elapsed();
                    any_hedge |= self.issue_hedge(
                        &mut tasks, engine, usize::MAX, rows, repair_wave,
                        now, &alive, stalled, injector, &xs_arc, &compute,
                        &tx,
                    )?;
                }
                // else: wait out the batch deadline — a blown straggler
                // may still land.
            }
            let now = start.elapsed();
            let mut next = batch_wall_deadline;
            for t in &tasks {
                if t.pending && t.deadline < next {
                    next = t.deadline;
                }
            }
            let reply = match rx.recv_timeout(next.saturating_sub(now)) {
                Ok(reply) => reply,
                // Timeout: loop back to blow processing. Disconnect is
                // unreachable (the master holds `tx`), treated the same.
                Err(_) => continue,
            };
            let arrived = start.elapsed();
            let (cnt, wave, is_hedge, is_canary, in_time) = {
                let t = &tasks[reply.task];
                (
                    t.rows.len(),
                    t.wave,
                    t.is_hedge,
                    t.is_canary,
                    arrived <= t.deadline,
                )
            };
            workers_used += 1;
            let load = self.per_worker[reply.worker].max(1);
            let prorate = if is_hedge || is_canary {
                cnt as f64 / load as f64
            } else {
                1.0
            };
            observed.push(WorkerObservation {
                worker: reply.worker,
                load: cnt,
                model_time: injector.model_delay(reply.worker) * prorate,
            });
            // Absorb: packetized like the legacy lossy path (original
            // deliveries keep the exact legacy fate seed — bit-parity),
            // hedge waves re-draw fates, duplicates are dropped.
            let p = loss.get(reply.worker).copied().unwrap_or(0.0);
            let fate_seed = if wave == 0 {
                batch_seed
            } else {
                batch_seed ^ HEDGE_FATE_TAG.wrapping_mul(wave as u64)
            };
            let (mut fresh, mut dup) = (0u64, 0u64);
            let mut off = 0usize;
            while off < cnt {
                let pk = RATELESS_PACKET_ROWS.min(cnt - off);
                let t = &tasks[reply.task];
                let first = t.rows.at(off);
                if p <= 0.0 || !packet_dropped(fate_seed, first, p) {
                    for i in off..off + pk {
                        let r = t.rows.at(i);
                        if have[r] {
                            dup += 1;
                            continue;
                        }
                        have[r] = true;
                        self.rows_buf.push(r);
                        for (col, ys) in
                            self.cols_buf.iter_mut().zip(&reply.ys)
                        {
                            col.push(ys[i]);
                        }
                        fresh += 1;
                    }
                }
                off += pk;
            }
            if dup > 0 {
                engine.note_wasted_rows(dup);
            }
            if is_hedge && fresh > 0 {
                engine.note_hedge_win();
            }
            if is_canary && in_time {
                engine.note_canary_ok(reply.worker);
            }
            tasks[reply.task].pending = false;
        }

        if self.rows_buf.len() < k {
            // Batch deadline expired short of k — degrade per policy.
            let elapsed = start.elapsed();
            let deficit = k - self.rows_buf.len();
            match engine.config().degrade {
                DegradePolicy::Fail => {
                    return Err(Error::Decode(format!(
                        "batch deadline expired with {} of {k} rows \
                         (deficit {deficit}); degrade policy is fail",
                        self.rows_buf.len()
                    )));
                }
                DegradePolicy::Partial => {
                    let mut rows = self.rows_buf.clone();
                    rows.sort_unstable();
                    let degraded = DegradedBatch {
                        batch: 0, // caller stamps the run-level index
                        rows,
                        deficit,
                        error_bound: deficit as f64 / k as f64,
                        elapsed,
                    };
                    let reports = (0..b)
                        .map(|_| JobReport {
                            wall_latency: elapsed,
                            model_latency: None,
                            decoded: Vec::new(),
                            max_error: f64::NAN,
                            workers_used,
                            rows_collected: k - deficit,
                            n: self.n,
                            backend: compute.name(),
                        })
                        .collect();
                    return Ok((reports, observed, Some(degraded)));
                }
            }
        }

        // First-completion-wins determinism: once any hedge fired, sort
        // the support jointly by global row index so the decoded bytes
        // depend only on the final support set, never on which copy of a
        // row landed first. Hedge-free batches keep the exact legacy
        // arrival order (bit-parity with the unhedged path).
        if any_hedge {
            let m = self.rows_buf.len();
            let mut perm: Vec<usize> = (0..m).collect();
            perm.sort_by_key(|&i| self.rows_buf[i]);
            let sorted_rows: Vec<usize> =
                perm.iter().map(|&i| self.rows_buf[i]).collect();
            self.rows_buf.clear();
            self.rows_buf.extend_from_slice(&sorted_rows);
            for col in self.cols_buf.iter_mut().take(b) {
                let sorted: Vec<f64> =
                    perm.iter().map(|&i| col[i]).collect();
                col.clear();
                col.extend_from_slice(&sorted);
            }
        }

        let rows_collected = self.rows_buf.len();
        let decoded_all = self.code.decode_rows(
            &mut self.decoder,
            &self.rows_buf,
            &self.cols_buf[..b],
        )?;
        let wall_latency = start.elapsed();
        let mut reports = Vec::with_capacity(b);
        for (decoded, request) in decoded_all.into_iter().zip(requests) {
            let max_error = if self.cfg.verify_decode {
                let truth = self
                    .a
                    .as_ref()
                    .expect("verify_decode keeps the data matrix")
                    .matvec(request);
                decoded
                    .iter()
                    .zip(&truth)
                    .map(|(d, t)| (d - t).abs())
                    .fold(0.0f64, f64::max)
            } else {
                f64::NAN
            };
            reports.push(JobReport {
                wall_latency,
                model_latency,
                decoded,
                max_error,
                workers_used,
                rows_collected,
                n: self.n,
                backend: compute.name(),
            });
        }
        Ok((reports, observed, None))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocation::uniform_allocation;
    use crate::coordinator::NativeCompute;
    use crate::math::Rng;
    use crate::model::{Group, LatencyModel};

    fn small_spec() -> ClusterSpec {
        ClusterSpec::new(
            vec![
                Group { n: 4, mu: 8.0, alpha: 1.0 },
                Group { n: 6, mu: 2.0, alpha: 1.0 },
            ],
            64,
        )
        .unwrap()
    }

    fn fast_cfg() -> JobConfig {
        JobConfig { time_scale: 0.002, ..Default::default() }
    }

    #[test]
    fn prepared_batches_decode_and_amortize_setup() {
        let spec = small_spec();
        let alloc =
            uniform_allocation(LatencyModel::A, &spec, 128.0).unwrap();
        let mut rng = Rng::new(71);
        let a = Matrix::from_fn(64, 8, |_, _| rng.normal());
        let mut prepared =
            PreparedJob::new(&spec, &alloc, &a, &fast_cfg()).unwrap();
        assert_eq!(prepared.encode_count(), 1);
        for batch in 0..3u64 {
            let requests: Vec<Vec<f64>> = (0..4)
                .map(|_| (0..8).map(|_| rng.normal()).collect())
                .collect();
            let reports = prepared
                .run_batch(&requests, Arc::new(NativeCompute), 1000 + batch)
                .unwrap();
            assert_eq!(reports.len(), 4);
            for r in &reports {
                assert!(r.max_error < 1e-8, "batch {batch}: err {}", r.max_error);
                assert_eq!(r.decoded.len(), 64);
                assert!(r.rows_collected >= 64);
            }
        }
        // The whole point: serving three batches encoded exactly once.
        assert_eq!(prepared.encode_count(), 1);
        let (_, misses) = prepared.decode_cache_stats();
        assert!(misses >= 1);
    }

    #[test]
    fn prepared_rejects_bad_inputs() {
        let spec = small_spec();
        let alloc =
            uniform_allocation(LatencyModel::A, &spec, 128.0).unwrap();
        let mut rng = Rng::new(72);
        let wrong = Matrix::from_fn(32, 8, |_, _| rng.normal());
        assert!(PreparedJob::new(&spec, &alloc, &wrong, &fast_cfg()).is_err());
        let a = Matrix::from_fn(64, 8, |_, _| rng.normal());
        let mut prepared =
            PreparedJob::new(&spec, &alloc, &a, &fast_cfg()).unwrap();
        assert!(prepared.run_batch(&[], Arc::new(NativeCompute), 1).is_err());
    }

    #[test]
    fn verify_decode_off_skips_ground_truth() {
        let spec = small_spec();
        let alloc = uniform_allocation(LatencyModel::A, &spec, 128.0).unwrap();
        let mut rng = Rng::new(74);
        let a = Matrix::from_fn(64, 8, |_, _| rng.normal());
        let mut cfg = fast_cfg();
        cfg.verify_decode = false;
        let mut prepared = PreparedJob::new(&spec, &alloc, &a, &cfg).unwrap();
        let reqs: Vec<Vec<f64>> =
            (0..2).map(|_| (0..8).map(|_| rng.normal()).collect()).collect();
        let reports =
            prepared.run_batch(&reqs, Arc::new(NativeCompute), 3).unwrap();
        // Decode still happens; only the O(k·d) verification is skipped.
        assert!(reports.iter().all(|r| r.max_error.is_nan()));
        assert!(reports.iter().all(|r| r.decoded.len() == 64));
    }

    #[test]
    fn rechunk_reallocates_without_reencoding() {
        let spec = small_spec();
        let alloc = uniform_allocation(LatencyModel::A, &spec, 128.0).unwrap();
        let mut rng = Rng::new(75);
        let a = Matrix::from_fn(64, 8, |_, _| rng.normal());
        let mut prepared =
            PreparedJob::new(&spec, &alloc, &a, &fast_cfg()).unwrap();
        assert_eq!(prepared.encode_count(), 1);
        let n = prepared.n();
        let reqs: Vec<Vec<f64>> =
            (0..3).map(|_| (0..8).map(|_| rng.normal()).collect()).collect();
        prepared.run_batch(&reqs, Arc::new(NativeCompute), 1).unwrap();

        // Drain worker 0 and redistribute its rows to workers 1 and 2.
        let mut pw = prepared.per_worker().to_vec();
        let drained = pw[0];
        pw[1] += drained - drained / 2;
        pw[2] += drained / 2;
        pw[0] = 0;
        prepared.rechunk(&pw).unwrap();
        assert_eq!(prepared.rechunk_count(), 1);
        assert_eq!(prepared.per_worker()[0], 0);
        assert_eq!(prepared.per_worker().iter().sum::<usize>(), n);

        let reports =
            prepared.run_batch(&reqs, Arc::new(NativeCompute), 2).unwrap();
        for r in &reports {
            assert!(r.max_error < 1e-8, "post-rechunk err {}", r.max_error);
            assert_eq!(r.decoded.len(), 64);
        }
        // The whole point: re-allocation re-sliced cached rows, no encode.
        assert_eq!(prepared.encode_count(), 1);

        // Partial cover (k <= rows < n) also serves fine.
        let mut partial = prepared.per_worker().to_vec();
        let spare = n - 64; // redundancy beyond k
        let take = spare.min(partial[9]);
        partial[9] -= take;
        prepared.rechunk(&partial).unwrap();
        let reports =
            prepared.run_batch(&reqs, Arc::new(NativeCompute), 3).unwrap();
        assert!(reports.iter().all(|r| r.max_error < 1e-8));
        assert_eq!(prepared.encode_count(), 1);

        // Invalid splits rejected: wrong arity, beyond-n, sub-k.
        assert!(prepared.rechunk(&[1, 2, 3]).is_err());
        assert!(prepared.rechunk(&[n; 10]).is_err());
        assert!(prepared.rechunk(&[1; 10]).is_err());
    }

    #[test]
    fn steady_state_batches_do_not_grow_scratch() {
        // The allocation-free hot-path invariant, measured: after the
        // first batch sizes the arenas (and its stragglers drain so the
        // dispatch Arc can be reclaimed), same-shaped batches perform
        // zero big-buffer allocations.
        let spec = small_spec();
        let alloc = uniform_allocation(LatencyModel::A, &spec, 128.0).unwrap();
        let mut rng = Rng::new(76);
        let a = Matrix::from_fn(64, 8, |_, _| rng.normal());
        let mut cfg = fast_cfg();
        cfg.verify_decode = false;
        let mut prepared = PreparedJob::new(&spec, &alloc, &a, &cfg).unwrap();
        let requests: Vec<Vec<f64>> = (0..4)
            .map(|_| (0..8).map(|_| rng.normal()).collect())
            .collect();
        let drain = std::time::Duration::from_millis(60);
        for seed in 0..2u64 {
            prepared.run_batch(&requests, Arc::new(NativeCompute), seed).unwrap();
            std::thread::sleep(drain); // let stragglers release the Arc
        }
        let warmed = prepared.scratch_grows();
        assert!(warmed > 0, "first batch must have sized the arenas");
        for seed in 2..8u64 {
            prepared.run_batch(&requests, Arc::new(NativeCompute), seed).unwrap();
            std::thread::sleep(drain);
        }
        assert_eq!(
            prepared.scratch_grows(),
            warmed,
            "steady-state batches allocated big buffers"
        );
        assert_eq!(prepared.encode_count(), 1);
    }

    #[test]
    fn streamed_batches_issue_exactly_k_rows_when_links_are_clean() {
        let spec = small_spec();
        let alloc = uniform_allocation(LatencyModel::A, &spec, 128.0).unwrap();
        let mut rng = Rng::new(80);
        let a = Matrix::from_fn(64, 8, |_, _| rng.normal());
        let mut cfg = fast_cfg();
        cfg.code = Some("rateless-rlc".into());
        let mut prepared = PreparedJob::new(&spec, &alloc, &a, &cfg).unwrap();
        let reqs: Vec<Vec<f64>> =
            (0..3).map(|_| (0..8).map(|_| rng.normal()).collect()).collect();
        let (reports, stats) = prepared
            .run_batch_streamed(&reqs, Arc::new(NativeCompute), 11, &[])
            .unwrap();
        // Clean links: one round, exactly k rows solicited and received
        // — the fountain ideal (overhead 1.0).
        assert_eq!(stats.rows_issued, 64);
        assert_eq!(stats.rows_received, 64);
        assert_eq!(stats.extend_rounds, 0);
        assert!(reports.iter().all(|r| r.max_error < 1e-6));
        assert_eq!(prepared.re_encoded_rows(), 0);
        // Streaming never re-runs the full encode pass.
        assert_eq!(prepared.encode_count(), 1);
    }

    #[test]
    fn streamed_batches_ride_out_per_packet_loss() {
        let spec = small_spec();
        let alloc = uniform_allocation(LatencyModel::A, &spec, 128.0).unwrap();
        let mut rng = Rng::new(81);
        let a = Matrix::from_fn(64, 8, |_, _| rng.normal());
        let mut cfg = fast_cfg();
        cfg.code = Some("rateless-rlc".into());
        let mut prepared = PreparedJob::new(&spec, &alloc, &a, &cfg).unwrap();
        let reqs: Vec<Vec<f64>> =
            (0..2).map(|_| (0..8).map(|_| rng.normal()).collect()).collect();
        // 30% per-packet loss on every worker; the stream keeps
        // soliciting until k rows survive.
        let loss = vec![0.3; spec.total_workers()];
        for seed in 0..3u64 {
            let (reports, stats) = prepared
                .run_batch_streamed(&reqs, Arc::new(NativeCompute), seed, &loss)
                .unwrap();
            assert!(reports.iter().all(|r| r.max_error < 1e-6));
            assert!(stats.rows_received >= 64);
            assert!(stats.rows_issued >= stats.rows_received);
        }
        // Lost packets forced extensions, but never a re-encode.
        assert_eq!(prepared.re_encoded_rows(), 0);
        assert_eq!(prepared.encode_count(), 1);
        // Fully dark links on every worker: clean refusal, not a hang.
        let dark = vec![1.0; spec.total_workers()];
        assert!(prepared
            .run_batch_streamed(&reqs, Arc::new(NativeCompute), 9, &dark)
            .is_err());
    }

    #[test]
    fn streamed_results_are_bit_identical_across_loss_free_reruns() {
        // The determinism pillar: same seeds → byte-identical decode,
        // regardless of thread interleavings across reruns.
        let spec = small_spec();
        let alloc = uniform_allocation(LatencyModel::A, &spec, 128.0).unwrap();
        let mut rng = Rng::new(82);
        let a = Matrix::from_fn(64, 8, |_, _| rng.normal());
        let mut cfg = fast_cfg();
        cfg.code = Some("rateless-rlc".into());
        let reqs: Vec<Vec<f64>> =
            (0..2).map(|_| (0..8).map(|_| rng.normal()).collect()).collect();
        let loss = vec![0.25; spec.total_workers()];
        let run = |cfg: &JobConfig| {
            let mut prepared = PreparedJob::new(&spec, &alloc, &a, cfg).unwrap();
            let (reports, stats) = prepared
                .run_batch_streamed(&reqs, Arc::new(NativeCompute), 5, &loss)
                .unwrap();
            let bits: Vec<Vec<u64>> = reports
                .iter()
                .map(|r| r.decoded.iter().map(|v| v.to_bits()).collect())
                .collect();
            (bits, stats.rows_received, stats.rows_issued)
        };
        let first = run(&cfg);
        let second = run(&cfg);
        assert_eq!(first, second);
    }

    #[test]
    fn lossy_fixed_n_fails_sub_k_once_losses_exceed_redundancy() {
        let spec = small_spec();
        let alloc = uniform_allocation(LatencyModel::A, &spec, 128.0).unwrap();
        let mut rng = Rng::new(83);
        let a = Matrix::from_fn(64, 8, |_, _| rng.normal());
        let cfg = fast_cfg();
        let mut prepared = PreparedJob::new(&spec, &alloc, &a, &cfg).unwrap();
        let reqs: Vec<Vec<f64>> =
            (0..2).map(|_| (0..8).map(|_| rng.normal()).collect()).collect();
        let injector = StragglerInjector::sample(
            &spec,
            cfg.model,
            prepared.per_worker(),
            cfg.time_scale,
            7,
        )
        .unwrap();
        // Dark links on group 1 (workers 4..10): they carry more than
        // the n - k redundancy, so the fixed-n code cannot reach k.
        let mut loss = vec![0.0; spec.total_workers()];
        for p in loss.iter_mut().skip(4) {
            *p = 1.0;
        }
        let err = prepared
            .run_batch_lossy(&reqs, Arc::new(NativeCompute), &injector, &loss, 3)
            .unwrap_err();
        assert!(
            err.to_string().contains("lossy"),
            "unexpected error: {err}"
        );
        // Mild loss within the redundancy budget still decodes.
        let mild = vec![0.0; spec.total_workers()];
        let (reports, _) = prepared
            .run_batch_lossy(&reqs, Arc::new(NativeCompute), &injector, &mild, 3)
            .unwrap();
        assert!(reports.iter().all(|r| r.max_error < 1e-8));
    }

    #[test]
    fn extend_rechunk_scales_out_past_n_without_re_encoding() {
        let spec = small_spec();
        let alloc = uniform_allocation(LatencyModel::A, &spec, 128.0).unwrap();
        let mut rng = Rng::new(84);
        let a = Matrix::from_fn(64, 8, |_, _| rng.normal());
        let mut cfg = fast_cfg();
        cfg.code = Some("rateless-rlc".into());
        let mut prepared = PreparedJob::new(&spec, &alloc, &a, &cfg).unwrap();
        let n0 = prepared.n();
        let reqs: Vec<Vec<f64>> =
            (0..2).map(|_| (0..8).map(|_| rng.normal()).collect()).collect();
        prepared.run_batch(&reqs, Arc::new(NativeCompute), 1).unwrap();

        // Scale out: every worker takes 3 more rows than it had — the
        // loads now want more rows than were ever encoded.
        let grown: Vec<usize> =
            prepared.per_worker().iter().map(|&l| l + 3).collect();
        let total: usize = grown.iter().sum();
        assert!(total > n0);
        prepared.extend_rechunk(&grown).unwrap();
        assert_eq!(prepared.n(), total);
        assert_eq!(prepared.rechunk_count(), 1);
        // Measured, not declared: the extension minted only fresh rows.
        assert_eq!(prepared.re_encoded_rows(), 0);
        assert_eq!(prepared.encode_count(), 1);

        // Both serving styles still decode over the grown horizon.
        let reports =
            prepared.run_batch(&reqs, Arc::new(NativeCompute), 2).unwrap();
        assert!(reports.iter().all(|r| r.max_error < 1e-6));
        let (reports, _) = prepared
            .run_batch_streamed(&reqs, Arc::new(NativeCompute), 3, &[])
            .unwrap();
        assert!(reports.iter().all(|r| r.max_error < 1e-6));
        assert_eq!(prepared.re_encoded_rows(), 0);

        // Finite codes keep the hard ceiling.
        let mut mds = PreparedJob::new(&spec, &alloc, &a, &fast_cfg()).unwrap();
        let grown: Vec<usize> =
            mds.per_worker().iter().map(|&l| l + 3).collect();
        assert!(mds.extend_rechunk(&grown).is_err());
        assert!(!mds.is_rateless());
    }

    #[test]
    fn prepared_survives_dead_workers_and_fails_cleanly_past_redundancy() {
        let spec = small_spec();
        let alloc =
            uniform_allocation(LatencyModel::A, &spec, 128.0).unwrap();
        let mut rng = Rng::new(73);
        let a = Matrix::from_fn(64, 8, |_, _| rng.normal());
        let reqs: Vec<Vec<f64>> =
            (0..2).map(|_| (0..8).map(|_| rng.normal()).collect()).collect();
        let mut cfg = fast_cfg();
        cfg.dead_workers = vec![0, 5];
        let mut prepared = PreparedJob::new(&spec, &alloc, &a, &cfg).unwrap();
        let reports =
            prepared.run_batch(&reqs, Arc::new(NativeCompute), 9).unwrap();
        assert!(reports.iter().all(|r| r.max_error < 1e-8));
        // Kill enough workers that k rows can never arrive.
        let mut cfg = fast_cfg();
        cfg.dead_workers = (0..9).collect();
        let mut prepared = PreparedJob::new(&spec, &alloc, &a, &cfg).unwrap();
        assert!(prepared.run_batch(&reqs, Arc::new(NativeCompute), 9).is_err());
    }
}
