//! Per-batch deadline / hedging engine (the in-batch recovery layer).
//!
//! The adaptive loop reacts to failures *between* batches by re-solving the
//! allocation; this module reacts *inside* one. Every dispatched worker gets
//! a hedge deadline — a configurable quantile of its own analytic runtime
//! law ([`crate::model::order_stats::hedge_deadline`]), derived from the
//! estimator's current group specs — and a worker that blows its deadline
//! has its missing rows re-issued to the fastest idle workers: spare MDS
//! row copies under `mds-*` codes, fresh `encode_rows` extensions above the
//! watermark under `rateless-rlc` (zero re-encodes either way). Retry waves
//! back off exponentially (`backoff^wave`) up to `max_waves`; replies
//! deduplicate by global row index, so whichever copy lands first wins and
//! the decoded output is a pure function of the final support set.
//!
//! Workers that blow their deadline in `quarantine_after` *consecutive*
//! batches enter a quarantine ring: they are excluded from dispatch, their
//! chunk is hedged to healthy workers at wave 0, and each batch probes them
//! with a single canary row. A canary reply before its deadline re-admits
//! the worker. This subsumes the adaptive loop's cruder consecutive-miss
//! death suspicion with an in-band probe.
//!
//! If the *batch* deadline (`batch_deadline_factor ×` the largest per-worker
//! deadline) expires with fewer than `k` rows, the engine degrades per
//! [`DegradePolicy`]: `Fail` surfaces a decode error, `Partial` records a
//! typed [`DegradedBatch`] carrying the partial row set and an error bound —
//! the serving loop never hangs and never panics on compound failures.
//!
//! Everything here is pure bookkeeping in model time — wall-clock scaling
//! (`JobConfig::time_scale`) and the actual `recv_timeout` loop live in
//! `coordinator/prepared.rs`; this module never reads a clock.

use std::time::Duration;

use crate::model::{order_stats, ClusterSpec, LatencyModel};
use crate::{Error, Result};

/// What to do when the batch deadline expires with fewer than `k` rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DegradePolicy {
    /// Surface `Error::Decode` for the batch (strict serving).
    Fail,
    /// Record a typed [`DegradedBatch`] (partial support + error bound) and
    /// keep serving subsequent batches.
    Partial,
}

/// Knobs for the deadline/hedging engine.
#[derive(Clone, Copy, Debug)]
pub struct RecoveryConfig {
    /// Issue hedge re-dispatches when deadlines blow. With `false` the
    /// engine still enforces the batch deadline (degrade instead of hang),
    /// but never re-issues rows — the "hedging disabled" baseline arm.
    pub hedge: bool,
    /// Quantile of the per-worker analytic runtime law used as the hedge
    /// deadline (e.g. `0.95` = p95). Must lie in `(0, 1)`.
    pub hedge_quantile: f64,
    /// Model-time floor under every deadline, so workers whose load rounds
    /// to a few rows are not hedged on a degenerate quantile.
    pub deadline_floor: f64,
    /// Maximum retry waves per lineage (original dispatch = wave 0).
    pub max_waves: u32,
    /// Exponential backoff base across retry waves (`>= 1`): the wave-`w`
    /// hedge gets `backoff^w ×` its target's base deadline.
    pub backoff: f64,
    /// The batch deadline is this factor times the largest per-worker
    /// deadline of the dispatch (`> 1`).
    pub batch_deadline_factor: f64,
    /// Consecutive deadline-blown batches before a worker is quarantined.
    pub quarantine_after: u32,
    /// Policy when the batch deadline expires short of `k` rows.
    pub degrade: DegradePolicy,
}

impl Default for RecoveryConfig {
    fn default() -> Self {
        RecoveryConfig {
            hedge: true,
            hedge_quantile: 0.95,
            deadline_floor: 0.05,
            max_waves: 4,
            backoff: 1.5,
            batch_deadline_factor: 16.0,
            quarantine_after: 3,
            degrade: DegradePolicy::Partial,
        }
    }
}

impl RecoveryConfig {
    /// Validate the knob ranges.
    pub fn validate(&self) -> Result<()> {
        if !(self.hedge_quantile > 0.0 && self.hedge_quantile < 1.0) {
            return Err(Error::Config(format!(
                "hedge quantile must be in (0, 1), got {}",
                self.hedge_quantile
            )));
        }
        if !self.deadline_floor.is_finite() || self.deadline_floor < 0.0 {
            return Err(Error::Config(format!(
                "deadline floor must be finite and >= 0, got {}",
                self.deadline_floor
            )));
        }
        if self.max_waves == 0 {
            return Err(Error::Config("max_waves must be >= 1".into()));
        }
        if !self.backoff.is_finite() || self.backoff < 1.0 {
            return Err(Error::Config(format!(
                "hedge backoff must be finite and >= 1, got {}",
                self.backoff
            )));
        }
        if !self.batch_deadline_factor.is_finite()
            || self.batch_deadline_factor <= 1.0
        {
            return Err(Error::Config(format!(
                "batch deadline factor must be finite and > 1, got {}",
                self.batch_deadline_factor
            )));
        }
        if self.quarantine_after == 0 {
            return Err(Error::Config("quarantine_after must be >= 1".into()));
        }
        Ok(())
    }
}

/// Hedge/retry/quarantine/degrade event counters, surfaced through
/// `ServeOutcome` and the CLI summary line.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RecoveryCounters {
    /// Hedge tasks issued (re-dispatches plus quarantine-chunk covers).
    pub hedges_issued: u64,
    /// Hedge replies that contributed at least one new row to the support.
    pub hedge_wins: u64,
    /// Rows that arrived already present in the support (the price of
    /// speculation — duplicates are dropped, first completion wins).
    pub wasted_rows: u64,
    /// Workers that entered the quarantine ring.
    pub quarantines: u64,
    /// Batches that expired short of `k` rows and degraded.
    pub degraded_batches: u64,
}

/// A batch that expired short of `k` rows under `DegradePolicy::Partial`.
#[derive(Clone, Debug)]
pub struct DegradedBatch {
    /// Batch index within the serving run.
    pub batch: u64,
    /// Sorted global row indices collected before the deadline.
    pub rows: Vec<usize>,
    /// Rows still missing toward `k`.
    pub deficit: usize,
    /// Fraction of output coordinates the partial support cannot pin down
    /// (`deficit / k` — the rank shortfall of any decode from this set).
    pub error_bound: f64,
    /// Wall time spent before giving up (bounded by the batch deadline).
    pub elapsed: Duration,
}

/// Final recovery report attached to `ServeOutcome`.
#[derive(Clone, Debug, Default)]
pub struct RecoveryReport {
    /// Event counters for the whole run.
    pub counters: RecoveryCounters,
    /// One record per degraded batch (empty under `DegradePolicy::Fail`).
    pub degraded: Vec<DegradedBatch>,
}

/// Per-run engine state: deadlines staged per batch, blown-streak and
/// quarantine bookkeeping carried *across* batches, and the counters.
#[derive(Clone, Debug)]
pub struct RecoveryEngine {
    cfg: RecoveryConfig,
    workers: usize,
    /// Consecutive deadline-blown batches per worker.
    streak: Vec<u32>,
    /// Quarantine ring membership.
    quarantined: Vec<bool>,
    // --- staged per batch by `stage()` ---
    model: LatencyModel,
    k: f64,
    /// Per-worker model-time hedge deadline for the staged loads.
    deadline: Vec<f64>,
    /// Per-worker `(mu, alpha)` of the staged (estimator-current) spec.
    params: Vec<(f64, f64)>,
    /// Expected model time per row, for ranking hedge targets.
    unit: Vec<f64>,
    /// Dispatched this batch (original full-chunk dispatch, not canary).
    dispatched: Vec<bool>,
    /// Blew the original-dispatch deadline this batch.
    blown: Vec<bool>,
    /// Canary row answered before its deadline this batch.
    canary_ok: Vec<bool>,
    counters: RecoveryCounters,
    degraded: Vec<DegradedBatch>,
}

impl RecoveryEngine {
    /// Engine for a fleet of `workers` workers.
    pub fn new(cfg: RecoveryConfig, workers: usize) -> Result<Self> {
        cfg.validate()?;
        if workers == 0 {
            return Err(Error::Config("recovery needs at least one worker".into()));
        }
        Ok(RecoveryEngine {
            cfg,
            workers,
            streak: vec![0; workers],
            quarantined: vec![false; workers],
            model: LatencyModel::A,
            k: 1.0,
            deadline: vec![0.0; workers],
            params: vec![(1.0, 1.0); workers],
            unit: vec![0.0; workers],
            dispatched: vec![false; workers],
            blown: vec![false; workers],
            canary_ok: vec![false; workers],
            counters: RecoveryCounters::default(),
            degraded: Vec::new(),
        })
    }

    /// The engine's configuration.
    pub fn config(&self) -> &RecoveryConfig {
        &self.cfg
    }

    /// Stage deadlines for one batch from the estimator's *current* group
    /// specs and the live per-worker loads. Resets the per-batch flags;
    /// streaks and quarantine membership persist.
    pub fn stage(
        &mut self,
        model: LatencyModel,
        spec: &ClusterSpec,
        per_worker: &[usize],
    ) -> Result<()> {
        if spec.total_workers() != self.workers
            || per_worker.len() != self.workers
        {
            return Err(Error::Config(format!(
                "recovery engine sized for {} workers, staged {} loads over a \
                 {}-worker spec",
                self.workers,
                per_worker.len(),
                spec.total_workers()
            )));
        }
        self.model = model;
        self.k = spec.k as f64;
        let mut w = 0usize;
        for g in &spec.groups {
            for _ in 0..g.n {
                let load = per_worker[w] as f64;
                self.params[w] = (g.mu, g.alpha);
                self.deadline[w] = order_stats::hedge_deadline(
                    model,
                    load.max(1.0),
                    self.k,
                    self.cfg.hedge_quantile,
                    g.mu,
                    g.alpha,
                    self.cfg.deadline_floor,
                );
                // Expected model time per row: E[T]/l = (alpha + 1/mu)
                // scaled by the model's load term — the ranking key for
                // "fastest" hedge targets.
                self.unit[w] = match model {
                    LatencyModel::A => (g.alpha + 1.0 / g.mu) / self.k,
                    LatencyModel::B => g.alpha + 1.0 / g.mu,
                };
                w += 1;
            }
        }
        self.dispatched.iter_mut().for_each(|d| *d = false);
        self.blown.iter_mut().for_each(|b| *b = false);
        self.canary_ok.iter_mut().for_each(|c| *c = false);
        Ok(())
    }

    /// Model-time hedge deadline staged for worker `w`'s full chunk.
    pub fn deadline_model(&self, w: usize) -> f64 {
        self.deadline[w]
    }

    /// Model-time deadline for a `rows`-row task on worker `w` (hedge
    /// re-issues carry only the missing rows, canaries exactly one).
    pub fn deadline_for_load(&self, w: usize, rows: usize) -> f64 {
        let (mu, alpha) = self.params[w];
        order_stats::hedge_deadline(
            self.model,
            (rows as f64).max(1.0),
            self.k,
            self.cfg.hedge_quantile,
            mu,
            alpha,
            self.cfg.deadline_floor,
        )
    }

    /// Model-time batch deadline: `batch_deadline_factor ×` the largest
    /// staged per-worker deadline among `dispatchable` workers.
    pub fn batch_deadline_model(&self, dispatchable: &[bool]) -> f64 {
        let widest = self
            .deadline
            .iter()
            .zip(dispatchable)
            .filter(|(_, d)| **d)
            .map(|(dl, _)| *dl)
            .fold(self.cfg.deadline_floor, f64::max);
        self.cfg.batch_deadline_factor * widest
    }

    /// Is worker `w` in the quarantine ring?
    pub fn is_quarantined(&self, w: usize) -> bool {
        self.quarantined[w]
    }

    /// Record that worker `w` received its original full-chunk dispatch.
    pub fn note_dispatched(&mut self, w: usize) {
        self.dispatched[w] = true;
    }

    /// Record that worker `w` blew its original-dispatch deadline.
    pub fn note_blown(&mut self, w: usize) {
        if self.dispatched[w] {
            self.blown[w] = true;
        }
    }

    /// Record that quarantined worker `w` answered its canary in time.
    pub fn note_canary_ok(&mut self, w: usize) {
        self.canary_ok[w] = true;
    }

    /// Count `n` issued hedge tasks.
    pub fn note_hedges_issued(&mut self, n: u64) {
        self.counters.hedges_issued += n;
    }

    /// Count a hedge reply that contributed at least one new row.
    pub fn note_hedge_win(&mut self) {
        self.counters.hedge_wins += 1;
    }

    /// Count `n` duplicate rows dropped by first-completion-wins.
    pub fn note_wasted_rows(&mut self, n: u64) {
        self.counters.wasted_rows += n;
    }

    /// Record a degraded batch (policy `Partial`).
    pub fn note_degraded(&mut self, d: DegradedBatch) {
        self.counters.degraded_batches += 1;
        self.degraded.push(d);
    }

    /// Hedge targets for a blown task of `exclude`, fastest first: live
    /// dispatched workers outside the quarantine ring, ranked by expected
    /// per-row model time (ties broken by worker id — deterministic).
    pub fn ranked_helpers(&self, exclude: usize, alive: &[bool]) -> Vec<usize> {
        let mut h: Vec<usize> = (0..self.workers)
            .filter(|&w| {
                w != exclude
                    && alive.get(w).copied().unwrap_or(false)
                    && self.dispatched[w]
                    && !self.quarantined[w]
            })
            .collect();
        h.sort_by(|&a, &b| {
            self.unit[a]
                .total_cmp(&self.unit[b])
                .then(a.cmp(&b))
        });
        h
    }

    /// Close out the staged batch: advance blown streaks, move workers in
    /// and out of the quarantine ring. Call once per batch, after the
    /// collection loop resolves.
    pub fn finish_batch(&mut self) {
        for w in 0..self.workers {
            if self.quarantined[w] {
                if self.canary_ok[w] {
                    // Canary answered in time — re-admit, fresh record.
                    self.quarantined[w] = false;
                    self.streak[w] = 0;
                }
                continue;
            }
            if !self.dispatched[w] {
                continue;
            }
            if self.blown[w] {
                self.streak[w] += 1;
                // Quarantine only makes sense when hedging can cover the
                // ringed worker's chunk; the hedging-disabled baseline arm
                // tracks streaks but never drains anyone.
                if self.cfg.hedge && self.streak[w] >= self.cfg.quarantine_after
                {
                    self.quarantined[w] = true;
                    self.counters.quarantines += 1;
                }
            } else {
                self.streak[w] = 0;
            }
        }
    }

    /// Current blown streak for worker `w` (test/diagnostic surface).
    pub fn streak(&self, w: usize) -> u32 {
        self.streak[w]
    }

    /// Counters so far.
    pub fn counters(&self) -> RecoveryCounters {
        self.counters
    }

    /// Final report for `ServeOutcome`.
    pub fn into_report(self) -> RecoveryReport {
        RecoveryReport { counters: self.counters, degraded: self.degraded }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Group;

    fn spec() -> ClusterSpec {
        ClusterSpec::new(
            vec![
                Group { n: 2, mu: 8.0, alpha: 1.0 },
                Group { n: 3, mu: 2.0, alpha: 1.0 },
            ],
            64,
        )
        .expect("valid spec")
    }

    #[test]
    fn config_validation_rejects_bad_knobs() {
        let ok = RecoveryConfig::default();
        assert!(ok.validate().is_ok());
        for bad in [
            RecoveryConfig { hedge_quantile: 0.0, ..ok },
            RecoveryConfig { hedge_quantile: 1.0, ..ok },
            RecoveryConfig { deadline_floor: -1.0, ..ok },
            RecoveryConfig { deadline_floor: f64::NAN, ..ok },
            RecoveryConfig { max_waves: 0, ..ok },
            RecoveryConfig { backoff: 0.5, ..ok },
            RecoveryConfig { batch_deadline_factor: 1.0, ..ok },
            RecoveryConfig { quarantine_after: 0, ..ok },
        ] {
            assert!(bad.validate().is_err(), "{bad:?} should be rejected");
        }
        assert!(RecoveryEngine::new(ok, 0).is_err());
    }

    #[test]
    fn staged_deadlines_follow_the_analytic_quantile() {
        let cfg = RecoveryConfig { deadline_floor: 0.0, ..Default::default() };
        let mut eng = RecoveryEngine::new(cfg, 5).expect("engine");
        let sp = spec();
        let loads = [10usize, 10, 20, 20, 20];
        eng.stage(LatencyModel::A, &sp, &loads).expect("stage");
        for w in 0..5 {
            let (mu, alpha) = if w < 2 { (8.0, 1.0) } else { (2.0, 1.0) };
            let want = order_stats::hedge_deadline(
                LatencyModel::A,
                loads[w] as f64,
                64.0,
                cfg.hedge_quantile,
                mu,
                alpha,
                0.0,
            );
            assert_eq!(eng.deadline_model(w), want, "worker {w}");
        }
        // Batch deadline keys off the widest dispatchable deadline.
        let all = [true; 5];
        let widest = (0..5).map(|w| eng.deadline_model(w)).fold(0.0, f64::max);
        assert!(
            (eng.batch_deadline_model(&all)
                - cfg.batch_deadline_factor * widest)
                .abs()
                < 1e-12
        );
        // Helpers rank the fast group (smaller per-row time) first.
        (0..5).for_each(|w| eng.note_dispatched(w));
        let ranked = eng.ranked_helpers(0, &all);
        assert_eq!(ranked, vec![1, 2, 3, 4]);
        // Mismatched sizes are a config error, not a panic.
        assert!(eng.stage(LatencyModel::A, &sp, &[1, 2]).is_err());
    }

    #[test]
    fn quarantine_lifecycle_enter_probe_readmit() {
        let cfg = RecoveryConfig { quarantine_after: 2, ..Default::default() };
        let mut eng = RecoveryEngine::new(cfg, 5).expect("engine");
        let sp = spec();
        let loads = [10usize, 10, 20, 20, 20];
        // Batch 1: worker 3 blows — streak 1, not yet quarantined.
        eng.stage(LatencyModel::A, &sp, &loads).expect("stage");
        (0..5).for_each(|w| eng.note_dispatched(w));
        eng.note_blown(3);
        eng.finish_batch();
        assert_eq!(eng.streak(3), 1);
        assert!(!eng.is_quarantined(3));
        // Batch 2: blows again — enters the ring.
        eng.stage(LatencyModel::A, &sp, &loads).expect("stage");
        (0..5).for_each(|w| eng.note_dispatched(w));
        eng.note_blown(3);
        eng.finish_batch();
        assert!(eng.is_quarantined(3));
        assert_eq!(eng.counters().quarantines, 1);
        // Batch 3: quarantined — canary misses, stays in the ring.
        eng.stage(LatencyModel::A, &sp, &loads).expect("stage");
        (0..5).filter(|&w| w != 3).for_each(|w| eng.note_dispatched(w));
        eng.finish_batch();
        assert!(eng.is_quarantined(3));
        // Quarantined workers never rank as hedge helpers.
        assert!(!eng.ranked_helpers(0, &[true; 5]).contains(&3));
        // Batch 4: canary answers — re-admitted with a clean streak.
        eng.stage(LatencyModel::A, &sp, &loads).expect("stage");
        (0..5).filter(|&w| w != 3).for_each(|w| eng.note_dispatched(w));
        eng.note_canary_ok(3);
        eng.finish_batch();
        assert!(!eng.is_quarantined(3));
        assert_eq!(eng.streak(3), 0);
        // A healthy batch resets a partial streak.
        eng.stage(LatencyModel::A, &sp, &loads).expect("stage");
        (0..5).for_each(|w| eng.note_dispatched(w));
        eng.note_blown(1);
        eng.finish_batch();
        assert_eq!(eng.streak(1), 1);
        eng.stage(LatencyModel::A, &sp, &loads).expect("stage");
        (0..5).for_each(|w| eng.note_dispatched(w));
        eng.finish_batch();
        assert_eq!(eng.streak(1), 0);
        // Counters fold into the report.
        eng.note_hedges_issued(3);
        eng.note_hedge_win();
        eng.note_wasted_rows(7);
        eng.note_degraded(DegradedBatch {
            batch: 9,
            rows: vec![0, 1],
            deficit: 62,
            error_bound: 62.0 / 64.0,
            elapsed: Duration::from_millis(5),
        });
        let rep = eng.into_report();
        assert_eq!(rep.counters.hedges_issued, 3);
        assert_eq!(rep.counters.hedge_wins, 1);
        assert_eq!(rep.counters.wasted_rows, 7);
        assert_eq!(rep.counters.quarantines, 1);
        assert_eq!(rep.counters.degraded_batches, 1);
        assert_eq!(rep.degraded.len(), 1);
        assert_eq!(rep.degraded[0].batch, 9);
    }
}
