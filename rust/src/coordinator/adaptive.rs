//! Failure- and drift-aware serving: the adaptive re-allocation loop.
//!
//! This is the engine behind an arrivals-mode
//! [`crate::coordinator::Session`] with a scenario and/or adaptivity
//! attached — a plain arrivals stream plus three production concerns
//! layered on the same prepared fast path:
//!
//! 1. **Scenario injection** — each batch's straggle realization is drawn
//!    from the *effective* cluster a [`FailureScenario`] has produced so
//!    far (deaths, machine slowdowns, group drift), not the spec the job
//!    was prepared for.
//! 2. **Online estimation** — the consumed worker replies of every batch
//!    (a type-II censored sample) feed a [`SpeedEstimator`]; workers that
//!    keep missing batches are suspected dead after
//!    [`AdaptiveServeConfig::death_after`] consecutive misses.
//! 3. **Re-allocation without re-encoding** — when the estimator detects
//!    drift (or deaths are suspected), the allocation is re-solved on the
//!    estimated surviving cluster through the session policy's
//!    [`crate::allocation::Policy::allocate_capped`] (the paper's
//!    projection, [`crate::allocation::proposed_allocation_capped`], when
//!    no policy object is attached), budgeted to the `n` coded rows that
//!    already exist, and the encoded rows are
//!    re-sliced via [`PreparedJob::rechunk`]. The steady-state invariant
//!    survives adaptation: [`AdaptiveServeReport::post_setup_encodes`]
//!    stays **0** no matter how many times the stream re-allocates.
//!
//! The whole loop is **code-agnostic**: it never touches
//! `Encoder`/`Decoder` directly, only the [`PreparedJob`] it was handed —
//! which routes setup/encode/decode through the job's resolved
//! [`crate::coding::Code`]. Re-slicing already-encoded rows via
//! [`PreparedJob::rechunk`] is pure row bookkeeping, so adaptation works
//! unchanged for every registry code (including the sparse-parity code,
//! whose non-MDS decode failures surface as clean batch errors here like
//! any other decode error).
//!
//! The model-time mirror of this loop for the queueing layer is
//! [`crate::workload::drift::run_workload_drift`].

use crate::allocation::{
    proposed_allocation, proposed_allocation_capped, Allocation, Policy,
};
use crate::coding::Matrix;
use crate::coordinator::failures::{FailureScenario, ScenarioState};
use crate::coordinator::master::{derive_stream_seed, STRAGGLE_SEED_TAG};
use crate::coordinator::rateless::RatelessSummary;
use crate::coordinator::recovery::{
    RecoveryConfig, RecoveryEngine, RecoveryReport,
};
use crate::coordinator::{
    Compute, JobConfig, LatencyRecorder, PreparedJob, ServeReport,
    WorkerObservation,
};
use crate::model::{ClusterSpec, EstimatorConfig, SpeedEstimator};
use crate::{Error, Result};
use std::collections::BTreeMap;
use std::sync::Arc;
use crate::runtime::wall_now;
use std::time::Duration;

/// Knobs of the live adaptive loop.
#[derive(Clone, Copy, Debug)]
pub struct AdaptiveServeConfig {
    /// Estimator window / trust / drift-threshold / cadence knobs
    /// (`check_every` counts *batches* here).
    pub est: EstimatorConfig,
    /// Suspect a worker dead after this many consecutive batches in which
    /// it was dispatched to but never consumed. The master cannot tell a
    /// corpse from an extreme straggler, and a drained suspect never gets
    /// another chance to reply, so a false suspicion permanently shifts
    /// that worker's load elsewhere (the only rollback is a re-solve that
    /// fails, which un-suspects its triggers). Under a redundant code
    /// where ~half the workers go unconsumed per batch, a healthy worker
    /// hits a `d`-batch miss streak with probability ~`0.5^d` per window —
    /// the default of 16 makes that ~1.5e-5, negligible over realistic
    /// streams, at the cost of detecting true deaths a few batches later.
    /// Deployments with a real liveness signal (heartbeats) should feed it
    /// through [`crate::coordinator::FailureScenario`]/
    /// [`crate::coordinator::JobConfig::dead_workers`] instead and set
    /// this high.
    pub death_after: usize,
}

impl Default for AdaptiveServeConfig {
    fn default() -> Self {
        AdaptiveServeConfig {
            est: EstimatorConfig { min_obs: 40, check_every: 4, ..Default::default() },
            death_after: 16,
        }
    }
}

/// [`ServeReport`] plus the adaptation trace.
#[derive(Debug)]
pub struct AdaptiveServeReport {
    /// The underlying serving metrics (sojourns, errors, makespan, and the
    /// measured `encodes` counter).
    pub serve: ServeReport,
    /// Re-allocations performed (estimator-triggered re-solves).
    pub reallocations: u64,
    /// Re-chunk passes (== reallocations; separate counter so tests can
    /// pin the invariant from the [`PreparedJob`] side).
    pub rechunks: u64,
    /// Workers suspected dead by the end of the stream (sorted).
    pub suspected_dead: Vec<usize>,
    /// Encode passes performed *after* construction — the re-allocation
    /// invariant: always 0, adaptation re-slices cached coded rows.
    pub post_setup_encodes: u64,
    /// Scratch-arena allocation/grow events *after the first batch* (the
    /// first batch sizes the arenas) — the allocation-free hot-path
    /// invariant: 0 in steady state, measured from
    /// [`PreparedJob::scratch_grows`], not declared.
    pub steady_allocs: u64,
    /// The cluster parameters the loop believed at the end (assumed spec
    /// updated by each re-allocation from the estimator).
    pub assumed_spec: ClusterSpec,
    /// Decode factorization-cache `(hits, misses)` over the stream.
    pub decode_cache: (u64, u64),
    /// Decode factorizations served around the cache by the thrash-bypass
    /// guard.
    pub decode_cache_bypasses: u64,
    /// Streaming-collection accounting — `Some` iff the job served with
    /// the rateless code.
    pub rateless: Option<RatelessSummary>,
    /// Hedge/quarantine/degrade accounting — `Some` iff a
    /// [`RecoveryConfig`] was attached to the run.
    pub recovery: Option<RecoveryReport>,
}

/// Serve an arrival stream under a failure/drift scenario, optionally
/// adapting the allocation online. With an empty scenario and `adapt:
/// None` this is exactly a plain arrivals-mode stream, bit-identical
/// straggle realizations included.
///
/// Migration: `Session::builder(spec).allocation(alloc.clone())
/// .data(a.clone()).requests(requests.to_vec()).config(cfg.clone())
/// .compute(compute).mode(Mode::Arrivals { offsets, max_batch })
/// .scenario(scenario.clone()).adaptive(adapt_cfg).build()?.serve()?` —
/// the adaptation trace lands in the unified
/// [`crate::coordinator::ServeOutcome`] counters.
#[deprecated(
    since = "0.2.0",
    note = "build a coordinator::Session with Mode::Arrivals plus \
            .scenario(..)/.adaptive(..) instead"
)]
#[allow(clippy::too_many_arguments)]
pub fn serve_arrivals_adaptive(
    spec: &ClusterSpec,
    alloc: &Allocation,
    a: &Matrix,
    requests: &[Vec<f64>],
    arrival_offsets: &[Duration],
    max_batch: usize,
    compute: Arc<dyn Compute>,
    cfg: &JobConfig,
    scenario: &FailureScenario,
    adapt: Option<&AdaptiveServeConfig>,
) -> Result<AdaptiveServeReport> {
    let mut builder = crate::coordinator::Session::builder(spec)
        .allocation(alloc.clone())
        .data(a.clone())
        .requests(requests.to_vec())
        .config(cfg.clone())
        .compute(compute)
        .scenario(scenario.clone())
        .mode(crate::coordinator::Mode::Arrivals {
            offsets: arrival_offsets.to_vec(),
            max_batch,
        });
    if let Some(ad) = adapt {
        builder = builder.adaptive(*ad);
    }
    // Note: built from an explicit allocation (no policy object), so
    // re-solves use the proposed projection — the historical behaviour of
    // this function, preserved bit-identically.
    let outcome = builder.build()?.serve()?;
    let assumed_spec = outcome.assumed_spec.unwrap_or_else(|| spec.clone());
    Ok(AdaptiveServeReport {
        serve: ServeReport {
            recorder: outcome.recorder,
            worst_error: outcome.worst_error,
            jobs: outcome.jobs,
            makespan: outcome.makespan,
            encodes: outcome.encodes,
        },
        reallocations: outcome.reallocations,
        rechunks: outcome.rechunks,
        suspected_dead: outcome.suspected_dead,
        post_setup_encodes: outcome.post_setup_encodes,
        steady_allocs: outcome.steady_allocs,
        assumed_spec,
        decode_cache: (outcome.decode_cache_hits, outcome.decode_cache_misses),
        decode_cache_bypasses: outcome.decode_cache_bypasses,
        rateless: outcome.rateless,
        recovery: outcome.recovery,
    })
}

/// The adaptive serving engine behind arrivals-mode
/// [`crate::coordinator::Session::serve`] (and the deprecated
/// [`serve_arrivals_adaptive`] shim).
///
/// `resolve_policy` is the policy whose
/// [`crate::allocation::Policy::allocate_capped`] re-solves the
/// allocation on the estimated surviving cluster; `None` (sessions built
/// from an explicit allocation, and the legacy shim) falls back to the
/// paper's proposed projection — the historical behaviour. A policy whose
/// capped solve refuses the budget simply keeps the current chunking
/// (the existing failed-re-solve fallback).
#[allow(clippy::too_many_arguments)]
pub(crate) fn serve_arrivals_adaptive_impl(
    spec: &ClusterSpec,
    alloc: &Allocation,
    a: &Matrix,
    requests: &[Vec<f64>],
    arrival_offsets: &[Duration],
    max_batch: usize,
    compute: Arc<dyn Compute>,
    cfg: &JobConfig,
    scenario: &FailureScenario,
    adapt: Option<&AdaptiveServeConfig>,
    resolve_policy: Option<&dyn Policy>,
    recovery: Option<&RecoveryConfig>,
) -> Result<AdaptiveServeReport> {
    if requests.len() != arrival_offsets.len() {
        return Err(Error::InvalidSpec(format!(
            "{} requests but {} arrival offsets",
            requests.len(),
            arrival_offsets.len()
        )));
    }
    if max_batch == 0 {
        return Err(Error::InvalidSpec("max_batch must be positive".into()));
    }
    if arrival_offsets.windows(2).any(|w| w[1] < w[0]) {
        return Err(Error::InvalidSpec(
            "arrival offsets must be ascending".into(),
        ));
    }
    if let Some(ad) = adapt {
        ad.est.validate()?;
        if ad.death_after == 0 {
            return Err(Error::InvalidSpec("death_after must be positive".into()));
        }
    }

    // Setup once: encode, chunk, decoder state live across batches and
    // across re-allocations.
    let mut prepared = PreparedJob::new(spec, alloc, a, cfg)?;
    // Serving style is a property of the code: the rateless fountain
    // streams (solicitation rounds until any k rows survive), everything
    // else dispatches fixed chunks — over lossy links via the
    // packet-filtered collection, which can fail sub-k.
    let streaming = prepared.is_rateless();
    let mut rl_summary = streaming.then(RatelessSummary::default);
    let lossy_scenario = scenario.has_loss();
    let mut state = ScenarioState::new(spec, &cfg.dead_workers);
    let window = adapt.map_or(1, |ad| ad.est.window);
    let mut estimator =
        SpeedEstimator::new(spec.num_groups(), cfg.model, spec.k, window)?;
    // What the master currently believes about the cluster; re-solves
    // replace it with the estimator's view.
    let mut assumed = spec.clone();
    let total_workers = spec.total_workers();
    let mut consecutive_miss = vec![0usize; total_workers];
    let mut suspected: Vec<bool> = vec![false; total_workers];
    let mut reallocations = 0u64;
    // In-batch recovery layer (hedged re-dispatch, quarantine, graceful
    // degradation). When attached, every batch — streaming or not — serves
    // through the deadline-driven hedged collection, and the engine's
    // quarantine ring subsumes the consecutive-miss death suspicion below.
    let mut engine = match recovery {
        Some(rc) => Some(RecoveryEngine::new(*rc, total_workers)?),
        None => None,
    };
    let mut stall_buf = vec![false; total_workers];

    let start = wall_now();
    let mut recorder = LatencyRecorder::new();
    let mut jobs = Vec::with_capacity(requests.len());
    let mut worst = 0.0f64;
    let mut next = 0usize;
    let mut batch_idx = 0u64;
    // Reusable straggle-draw buffer (redrawn in place per batch) and the
    // post-first-batch baseline for the steady-allocation invariant.
    let mut injector_slot: Option<crate::coordinator::StragglerInjector> = None;
    let mut grows_baseline: Option<u64> = None;
    // Per-batch per-worker drop probabilities under lossy-link scenarios
    // (refilled in place each batch; burst windows change it over time).
    let mut loss_buf = vec![0.0f64; total_workers];
    while next < requests.len() {
        // Block until the head-of-line request has arrived.
        let now = start.elapsed();
        if arrival_offsets[next] > now {
            std::thread::sleep(arrival_offsets[next] - now);
        }
        // Drain everything already queued, bounded by the batch width.
        let now = start.elapsed();
        let mut end = next + 1;
        while end < requests.len()
            && end - next < max_batch
            && arrival_offsets[end] <= now
        {
            end += 1;
        }
        state.advance(scenario, batch_idx)?;
        // One base stream per batch, split into independent substreams:
        // the straggler injector draws under `^ STRAGGLE_SEED_TAG`, packet
        // fates under `^ LOSS_SEED_TAG` (inside `packet_dropped`). Sharing
        // the raw stream would correlate slowness with loss.
        let stream_seed = derive_stream_seed(cfg.seed, batch_idx);
        let batch_seed = stream_seed ^ STRAGGLE_SEED_TAG;
        if injector_slot.is_none() {
            injector_slot = Some(state.injector(
                cfg.model,
                prepared.per_worker(),
                cfg.time_scale,
                batch_seed,
            )?);
        } else {
            let inj = injector_slot.as_mut().expect("slot checked above");
            state.injector_into(
                inj,
                cfg.model,
                prepared.per_worker(),
                cfg.time_scale,
                batch_seed,
            )?;
        }
        let injector = injector_slot.as_ref().expect("injector just staged");
        if lossy_scenario {
            for (w, p) in loss_buf.iter_mut().enumerate() {
                // Per-worker link loss composed with the group scripting
                // (reduces to the group probability when no LossyWorker
                // events are scripted — bit-parity with older scenarios).
                *p = state.worker_loss_probability(w, batch_idx);
            }
        }
        let (reports, observed) = if let Some(eng) = engine.as_mut() {
            for (w, s) in stall_buf.iter_mut().enumerate() {
                *s = state.is_stalled(w, batch_idx);
            }
            eng.stage(cfg.model, &assumed, prepared.per_worker())?;
            let loss: &[f64] = if lossy_scenario { &loss_buf } else { &[] };
            let (reports, observed, degraded) = prepared.run_batch_hedged(
                &requests[next..end],
                Arc::clone(&compute),
                injector,
                loss,
                stream_seed,
                &stall_buf,
                eng,
            )?;
            if let Some(mut d) = degraded {
                d.batch = batch_idx;
                eng.note_degraded(d);
            }
            eng.finish_batch();
            (reports, observed)
        } else if streaming {
            let loss: &[f64] = if lossy_scenario { &loss_buf } else { &[] };
            let (reports, observed, stats) = prepared.run_batch_rateless_injected(
                &requests[next..end],
                Arc::clone(&compute),
                injector,
                loss,
                stream_seed,
            )?;
            if let Some(s) = rl_summary.as_mut() {
                s.absorb(stats);
            }
            (reports, observed)
        } else if lossy_scenario {
            prepared.run_batch_lossy(
                &requests[next..end],
                Arc::clone(&compute),
                injector,
                &loss_buf,
                stream_seed,
            )?
        } else {
            prepared.run_batch_injected(
                &requests[next..end],
                Arc::clone(&compute),
                injector,
            )?
        };
        if grows_baseline.is_none() {
            // The first batch sizes every arena; steady state is measured
            // from here.
            grows_baseline = Some(prepared.scratch_grows());
        }
        let done = start.elapsed();
        for (i, report) in reports.into_iter().enumerate() {
            let sojourn = done.saturating_sub(arrival_offsets[next + i]);
            recorder.record(sojourn, report.decoded.len());
            worst = crate::coordinator::master::fold_worst_error(
                worst,
                report.max_error,
            );
            jobs.push(report);
        }
        next = end;
        batch_idx += 1;

        if let Some(ad) = adapt {
            digest_batch(
                &state,
                prepared.per_worker(),
                &observed,
                &mut estimator,
                &mut consecutive_miss,
                // Rateless rounds split shares proportionally and can
                // legitimately hand a worker zero rows, and lossy links
                // erase whole replies — silence is not death evidence
                // there, so only the loss-free fixed-chunk path counts
                // misses. Speed observations still feed the estimator.
                // With a recovery engine attached the quarantine ring
                // subsumes miss-based death suspicion entirely.
                !streaming && !lossy_scenario && engine.is_none(),
            );
            if batch_idx % ad.est.check_every as u64 == 0 {
                let mut new_suspects = Vec::new();
                for (w, miss) in consecutive_miss.iter().enumerate() {
                    if !suspected[w]
                        && prepared.per_worker()[w] > 0
                        && *miss >= ad.death_after
                    {
                        suspected[w] = true;
                        new_suspects.push(w);
                    }
                }
                let drifted = estimator.deviates_from(
                    &assumed,
                    ad.est.threshold,
                    ad.est.min_obs,
                );
                if !new_suspects.is_empty() || drifted {
                    let attempt = (|| -> Result<(ClusterSpec, Vec<usize>)> {
                        let alive_counts = alive_per_group(&state, &suspected);
                        let est_spec = estimator.estimated_spec(
                            &assumed,
                            &alive_counts,
                            ad.est.min_obs,
                        )?;
                        // Finite codes answer to the coded-row ceiling
                        // (`n` rows exist, period); the rateless fountain
                        // does not — solve unconstrained and let
                        // `extend_rechunk` mint whatever the optimum asks
                        // for (slack of one bump per group so the Hamilton
                        // rounding never hits its own budget).
                        let (realloc, cap) = if streaming {
                            let r = match resolve_policy {
                                Some(p) => p.allocate(cfg.model, &est_spec)?,
                                None => {
                                    proposed_allocation(cfg.model, &est_spec)?
                                }
                            };
                            let target: f64 = r
                                .loads
                                .iter()
                                .zip(&alive_counts)
                                .map(|(&l, &n)| l * n as f64)
                                .sum();
                            let cap = (target.ceil() as usize
                                + est_spec.num_groups())
                            .max(spec.k);
                            (r, cap)
                        } else {
                            let r = match resolve_policy {
                                Some(p) => p.allocate_capped(
                                    cfg.model,
                                    &est_spec,
                                    prepared.n() as f64,
                                )?,
                                None => proposed_allocation_capped(
                                    cfg.model,
                                    &est_spec,
                                    prepared.n() as f64,
                                )?,
                            };
                            (r, prepared.n())
                        };
                        let per_worker = integer_per_worker_capped(
                            &state,
                            &suspected,
                            &realloc.loads,
                            cap,
                            spec.k,
                        )?;
                        Ok((est_spec, per_worker))
                    })();
                    match attempt {
                        Ok((est_spec, per_worker)) => {
                            // Identical to `rechunk` for finite codes;
                            // grows the coded horizon first when a
                            // rateless split overshoots the current `n`.
                            prepared.extend_rechunk(&per_worker)?;
                            assumed = est_spec;
                            estimator.flush();
                            consecutive_miss.fill(0);
                            reallocations += 1;
                        }
                        Err(_) => {
                            // A re-solve that cannot cover `k` within the
                            // coded-row budget (e.g. over-eager suspicion
                            // of slow-but-alive workers) must not abort a
                            // stream that is still serving: keep the
                            // current working chunking and give the new
                            // suspects another chance to reply.
                            for &w in &new_suspects {
                                suspected[w] = false;
                                consecutive_miss[w] = 0;
                            }
                        }
                    }
                }
            }
        }
    }
    let serve = ServeReport {
        recorder,
        worst_error: worst,
        jobs,
        makespan: Some(start.elapsed()),
        encodes: prepared.encode_count(),
    };
    let rateless = rl_summary.map(|mut s| {
        s.finalize(spec.k, prepared.re_encoded_rows());
        s
    });
    Ok(AdaptiveServeReport {
        serve,
        reallocations,
        rechunks: prepared.rechunk_count(),
        suspected_dead: suspected
            .iter()
            .enumerate()
            .filter_map(|(w, &s)| s.then_some(w))
            .collect(),
        post_setup_encodes: prepared.encode_count().saturating_sub(1),
        steady_allocs: grows_baseline
            .map_or(0, |base| prepared.scratch_grows() - base),
        assumed_spec: assumed,
        decode_cache: prepared.decode_cache_stats(),
        decode_cache_bypasses: prepared.decode_cache_bypasses(),
        rateless,
        recovery: engine.map(RecoveryEngine::into_report),
    })
}

/// Feed one batch's consumed replies into the estimator (bucketed into
/// per-`(group, load)` censored samples — the tight-budget integerization
/// can split a group across two adjacent loads, and workers racing under
/// different loads have different distributions) and, when `count_misses`,
/// bump the miss counters of dispatched workers that stayed silent —
/// silence only implies death on the loss-free fixed-chunk path.
fn digest_batch(
    state: &ScenarioState,
    per_worker: &[usize],
    observed: &[WorkerObservation],
    estimator: &mut SpeedEstimator,
    consecutive_miss: &mut [usize],
    count_misses: bool,
) {
    // The master's observation horizon: the batch completed (and it
    // stopped listening) at the last consumed reply's model time; every
    // silent worker is known to still be computing then.
    let mut horizon = 0.0f64;
    let mut seen = vec![false; per_worker.len()];
    // (group, load) -> consumed times; at most two loads per group.
    let mut buckets: BTreeMap<(usize, usize), Vec<f64>> = BTreeMap::new();
    for obs in observed {
        let g = state.group_of(obs.worker);
        buckets.entry((g, obs.load)).or_default().push(obs.model_time);
        seen[obs.worker] = true;
        horizon = horizon.max(obs.model_time);
    }
    let mut dispatched: BTreeMap<(usize, usize), usize> = BTreeMap::new();
    for (w, &l) in per_worker.iter().enumerate() {
        if l > 0 {
            *dispatched.entry((state.group_of(w), l)).or_default() += 1;
            if seen[w] {
                consecutive_miss[w] = 0;
            } else if count_misses {
                consecutive_miss[w] += 1;
            }
        }
    }
    for ((g, load), times) in &buckets {
        let n = dispatched.get(&(*g, *load)).copied().unwrap_or(times.len());
        estimator.observe(*g, *load as f64, n, times, horizon);
    }
}

/// Surviving workers per group: everything not suspected dead. (Workers
/// drained by an earlier re-chunk are still alive — they can be re-loaded.)
fn alive_per_group(state: &ScenarioState, suspected: &[bool]) -> Vec<usize> {
    let mut alive = vec![0usize; state.spec.num_groups()];
    for (w, &s) in suspected.iter().enumerate() {
        if !s {
            alive[state.group_of(w)] += 1;
        }
    }
    alive
}

/// Integerize per-group real loads into a per-worker split under the
/// coded-row budget: floor every alive worker's load, Hamilton-bump whole
/// groups by descending fractional part while the budget allows, and if
/// flooring still left the total below `k` (the tight-budget corner where
/// a whole-group bump would overshoot the cap), top up **single workers**
/// round-robin — within-group loads then differ by at most one row, which
/// is why the estimator feed buckets observations by `(group, load)`.
/// Suspected-dead workers get 0. Feasible whenever `cap ≥ k` and anyone
/// survives: per-worker bumps reach `k` exactly.
///
/// Sibling of [`crate::allocation::largest_remainder_loads`], which
/// solves the unconstrained variant (hit the real-valued target exactly,
/// full membership); this one answers to a hard row cap, a `k` floor, and
/// per-group survivor counts. Keep their bump rules (descending
/// fractional order, at most one bump per group, `1e-9` float slack) in
/// sync when touching either.
fn integer_per_worker_capped(
    state: &ScenarioState,
    suspected: &[bool],
    group_loads: &[f64],
    cap: usize,
    k: usize,
) -> Result<Vec<usize>> {
    let num_groups = state.spec.num_groups();
    if group_loads.len() != num_groups {
        return Err(Error::InvalidSpec("group load arity mismatch".into()));
    }
    if group_loads.iter().any(|l| !l.is_finite() || *l < 0.0) {
        return Err(Error::InvalidSpec(format!(
            "group loads must be finite and nonnegative, got {group_loads:?}"
        )));
    }
    if cap < k {
        return Err(Error::InvalidSpec(format!(
            "coded-row budget {cap} cannot cover k = {k}"
        )));
    }
    let alive = alive_per_group(state, suspected);
    if alive.iter().all(|&n| n == 0) {
        return Err(Error::InvalidSpec(
            "no surviving workers to re-allocate onto".into(),
        ));
    }
    let mut ints: Vec<usize> =
        group_loads.iter().map(|&l| l.floor() as usize).collect();
    let mut total: usize =
        ints.iter().zip(&alive).map(|(&l, &n)| l * n).sum();
    let target: f64 = group_loads
        .iter()
        .zip(&alive)
        .map(|(&l, &n)| l * n as f64)
        .sum();
    let frac = |j: usize| group_loads[j] - group_loads[j].floor();
    let mut order: Vec<usize> = (0..num_groups).collect();
    order.sort_by(|&a, &b| frac(b).total_cmp(&frac(a)).then(a.cmp(&b)));
    for &j in &order {
        if (total as f64) + 1e-9 >= target {
            break;
        }
        if alive[j] == 0 || frac(j) <= 0.0 || total + alive[j] > cap {
            continue;
        }
        ints[j] += 1;
        total += alive[j];
    }
    let mut per_worker: Vec<usize> = suspected
        .iter()
        .enumerate()
        .map(|(w, &s)| if s { 0 } else { ints[state.group_of(w)] })
        .collect();
    // Tight-budget top-up: hand out single rows to alive workers
    // round-robin until the split covers k (cap ≥ k makes this feasible).
    while total < k {
        for (w, &s) in suspected.iter().enumerate() {
            if total >= k {
                break;
            }
            if !s {
                per_worker[w] += 1;
                total += 1;
            }
        }
    }
    Ok(per_worker)
}

#[cfg(test)]
// The deprecated shim is exercised deliberately: these tests double as
// regression coverage that it reproduces the historical behaviour through
// the Session facade.
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::allocation::uniform_allocation;
    use crate::coordinator::failures::{FailureEvent, FailureKind};
    use crate::coordinator::NativeCompute;
    use crate::math::Rng;
    use crate::model::{Group, LatencyModel};

    fn small_spec() -> ClusterSpec {
        ClusterSpec::new(
            vec![
                Group { n: 4, mu: 8.0, alpha: 1.0 },
                Group { n: 6, mu: 2.0, alpha: 1.0 },
            ],
            64,
        )
        .unwrap()
    }

    fn stream(
        jobs: usize,
        gap_ms: u64,
        seed: u64,
    ) -> (Matrix, Vec<Vec<f64>>, Vec<Duration>) {
        let mut rng = Rng::new(seed);
        let a = Matrix::from_fn(64, 8, |_, _| rng.normal());
        let reqs: Vec<Vec<f64>> = (0..jobs)
            .map(|_| (0..8).map(|_| rng.normal()).collect())
            .collect();
        let offsets = (0..jobs)
            .map(|i| Duration::from_millis(gap_ms * i as u64))
            .collect();
        (a, reqs, offsets)
    }

    #[test]
    fn matches_plain_serve_arrivals_without_scenario() {
        // Empty scenario + no adaptation must reproduce serve_arrivals
        // exactly (it delegates here): same decode results, one encode.
        let spec = small_spec();
        let alloc = uniform_allocation(LatencyModel::A, &spec, 128.0).unwrap();
        let (a, reqs, offsets) = stream(6, 5, 81);
        let cfg = JobConfig { time_scale: 0.002, ..Default::default() };
        let rep = serve_arrivals_adaptive(
            &spec,
            &alloc,
            &a,
            &reqs,
            &offsets,
            4,
            Arc::new(NativeCompute),
            &cfg,
            &FailureScenario::none(),
            None,
        )
        .unwrap();
        assert_eq!(rep.serve.recorder.count(), 6);
        assert!(rep.serve.worst_error < 1e-8);
        assert_eq!(rep.serve.encodes, 1);
        assert_eq!(rep.reallocations, 0);
        assert_eq!(rep.post_setup_encodes, 0);
        assert!(rep.suspected_dead.is_empty());
    }

    #[test]
    fn suspects_scenario_killed_workers_and_reallocates_without_encoding() {
        let spec = small_spec();
        // Rate-1/2 code: plenty of redundancy to lose two workers.
        let alloc = uniform_allocation(LatencyModel::A, &spec, 128.0).unwrap();
        let (a, reqs, offsets) = stream(14, 4, 82);
        let cfg = JobConfig { time_scale: 0.002, ..Default::default() };
        let scenario = FailureScenario::new(vec![FailureEvent {
            at_batch: 2,
            kind: FailureKind::KillWorkers(vec![0, 5]),
        }])
        .unwrap();
        let adapt = AdaptiveServeConfig {
            est: EstimatorConfig {
                // Huge min_obs: isolates the death path from drift noise.
                min_obs: 1_000_000,
                check_every: 1,
                ..Default::default()
            },
            death_after: 3,
        };
        let rep = serve_arrivals_adaptive(
            &spec,
            &alloc,
            &a,
            &reqs,
            &offsets,
            1,
            Arc::new(NativeCompute),
            &cfg,
            &scenario,
            Some(&adapt),
        )
        .unwrap();
        assert_eq!(rep.serve.recorder.count(), 14);
        assert!(rep.serve.worst_error < 1e-8, "err {}", rep.serve.worst_error);
        assert!(rep.reallocations >= 1);
        assert_eq!(rep.rechunks, rep.reallocations);
        // Both scripted deaths suspected (they miss every batch).
        for w in [0usize, 5] {
            assert!(rep.suspected_dead.contains(&w), "worker {w} not suspected");
        }
        // The invariant under adaptation: zero post-setup encodes.
        assert_eq!(rep.post_setup_encodes, 0);
        assert_eq!(rep.serve.encodes, 1);
    }

    #[test]
    fn integerization_respects_budget_and_k() {
        let spec = small_spec();
        let state = ScenarioState::new(&spec, &[]);
        let suspected = vec![false; 10];
        // Real loads ~ rate-1/2: 12.8 per worker, budget 130.
        let pw = integer_per_worker_capped(
            &state,
            &suspected,
            &[12.8, 12.8],
            130,
            64,
        )
        .unwrap();
        let total: usize = pw.iter().sum();
        assert!(total >= 64 && total <= 130, "total {total}");
        // Group-uniform loads.
        assert!(pw[..4].iter().all(|&l| l == pw[0]));
        assert!(pw[4..].iter().all(|&l| l == pw[4]));
        // Dead workers drained; budget that cannot cover k is refused.
        let mut dead = vec![false; 10];
        for w in 0..8 {
            dead[w] = true;
        }
        let pw = integer_per_worker_capped(
            &state,
            &dead,
            &[16.0, 40.0],
            130,
            64,
        )
        .unwrap();
        assert!(pw[..8].iter().all(|&l| l == 0));
        assert!(pw[8] * 2 >= 64);
        assert!(integer_per_worker_capped(&state, &dead, &[16.0, 20.0], 50, 64)
            .is_err());
        let all_dead = vec![true; 10];
        assert!(integer_per_worker_capped(&state, &all_dead, &[8.0, 8.0], 130, 64)
            .is_err());
    }

    #[test]
    fn tight_budget_splits_within_a_group() {
        // The corner where a whole-group bump overshoots the cap: only
        // group 0 (4 workers) survives, floors cover 60 < k = 62, and
        // bumping the whole group (+4 = 64) would blow the 63-row budget.
        // Per-worker top-up hands two workers one extra row each instead
        // of refusing.
        let spec = small_spec();
        let state = ScenarioState::new(&spec, &[]);
        let mut suspected = vec![false; 10];
        for w in 4..10 {
            suspected[w] = true;
        }
        let pw = integer_per_worker_capped(
            &state,
            &suspected,
            &[15.9, 0.0],
            63,
            62,
        )
        .unwrap();
        assert!(pw[4..].iter().all(|&l| l == 0));
        let total: usize = pw.iter().sum();
        assert_eq!(total, 62);
        let max = *pw[..4].iter().max().unwrap();
        let min = *pw[..4].iter().min().unwrap();
        assert!(max - min <= 1, "within-group split must stay adjacent");
    }

    #[test]
    fn fixed_code_rides_out_burst_loss_within_redundancy() {
        // A burst window blacks out group 0's links entirely (all packets
        // dropped, deterministically). Group 0 carries ~52 of 128 rows at
        // rate 1/2, so the surviving ~76 still cover k = 64 and the MDS
        // stream serves every job through the packet-filtered collection.
        let spec = small_spec();
        let alloc = uniform_allocation(LatencyModel::A, &spec, 128.0).unwrap();
        let (a, reqs, offsets) = stream(8, 4, 91);
        let cfg = JobConfig { time_scale: 0.002, ..Default::default() };
        let scenario = FailureScenario::new(vec![FailureEvent {
            at_batch: 2,
            kind: FailureKind::BurstDrop { group: 0, batches: 3 },
        }])
        .unwrap();
        let rep = serve_arrivals_adaptive(
            &spec,
            &alloc,
            &a,
            &reqs,
            &offsets,
            1,
            Arc::new(NativeCompute),
            &cfg,
            &scenario,
            None,
        )
        .unwrap();
        assert_eq!(rep.serve.recorder.count(), 8);
        assert!(rep.serve.worst_error < 1e-8, "err {}", rep.serve.worst_error);
        assert_eq!(rep.serve.encodes, 1);
        // Finite codes never populate the streaming summary.
        assert!(rep.rateless.is_none());
    }

    #[test]
    fn rateless_streams_through_loss_and_reports_overhead() {
        // 20% i.i.d. packet loss on both groups from batch 1: the fixed-n
        // collection would gamble on ≥ k survivors per batch, the fountain
        // just keeps soliciting. Every job must complete, and the summary
        // must carry measured (not declared) accounting.
        let spec = small_spec();
        let alloc = uniform_allocation(LatencyModel::A, &spec, 128.0).unwrap();
        let (a, reqs, offsets) = stream(6, 4, 92);
        let cfg = JobConfig {
            time_scale: 0.002,
            code: Some("rateless-rlc".into()),
            ..Default::default()
        };
        let scenario = FailureScenario::new(vec![
            FailureEvent {
                at_batch: 1,
                kind: FailureKind::LossyGroup { group: 0, p: 0.2 },
            },
            FailureEvent {
                at_batch: 1,
                kind: FailureKind::LossyGroup { group: 1, p: 0.2 },
            },
        ])
        .unwrap();
        let rep = serve_arrivals_adaptive(
            &spec,
            &alloc,
            &a,
            &reqs,
            &offsets,
            2,
            Arc::new(NativeCompute),
            &cfg,
            &scenario,
            None,
        )
        .unwrap();
        assert_eq!(rep.serve.recorder.count(), 6);
        assert!(rep.serve.worst_error < 1e-6, "err {}", rep.serve.worst_error);
        let summary = rep.rateless.expect("rateless jobs populate the summary");
        assert!(summary.batches >= 1);
        assert!(summary.rows_received >= summary.batches * spec.k as u64);
        assert!(summary.rows_issued >= summary.rows_received);
        assert!(summary.overhead >= 1.0, "overhead {}", summary.overhead);
        // The elasticity invariant, measured: soliciting extra rows under
        // loss minted fresh row ids only.
        assert_eq!(summary.re_encoded_rows, 0);
        assert_eq!(rep.post_setup_encodes, 0);
        assert_eq!(rep.serve.encodes, 1);
    }

    #[test]
    fn rateless_drift_resolve_extends_instead_of_capping() {
        // Start at the elastic worst case — a rate-1 allocation, n == k ==
        // 64, zero slack — and slow group 0 by 4× so the estimator's
        // re-solve wants real redundancy. A finite code would be pinned at
        // the n-row ceiling; the fountain's re-solve runs uncapped and
        // `extend_rechunk` mints the difference with zero re-encodes.
        let spec = small_spec();
        let alloc = uniform_allocation(LatencyModel::A, &spec, 64.0).unwrap();
        let (a, reqs, offsets) = stream(16, 4, 93);
        let cfg = JobConfig {
            time_scale: 0.002,
            code: Some("rateless-rlc".into()),
            ..Default::default()
        };
        let scenario = FailureScenario::new(vec![FailureEvent {
            at_batch: 2,
            kind: FailureKind::SlowGroup { group: 0, factor: 4.0 },
        }])
        .unwrap();
        let adapt = AdaptiveServeConfig {
            est: EstimatorConfig {
                min_obs: 4,
                check_every: 2,
                threshold: 0.5,
                ..Default::default()
            },
            death_after: 3,
        };
        let rep = serve_arrivals_adaptive(
            &spec,
            &alloc,
            &a,
            &reqs,
            &offsets,
            1,
            Arc::new(NativeCompute),
            &cfg,
            &scenario,
            Some(&adapt),
        )
        .unwrap();
        assert_eq!(rep.serve.recorder.count(), 16);
        assert!(rep.serve.worst_error < 1e-6, "err {}", rep.serve.worst_error);
        assert!(rep.reallocations >= 1, "drift must trigger a re-solve");
        let summary = rep.rateless.expect("rateless jobs populate the summary");
        // Scale-out is free: no previously issued row was re-encoded, and
        // the single setup encode is still the only encode pass.
        assert_eq!(summary.re_encoded_rows, 0);
        assert_eq!(rep.post_setup_encodes, 0);
        assert_eq!(rep.serve.encodes, 1);
        // Streaming silence is not death evidence: nobody was buried.
        assert!(rep.suspected_dead.is_empty());
    }
}
