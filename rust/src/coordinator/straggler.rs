//! Straggle-delay injection.
//!
//! Deterministically samples each worker's delay from its group's
//! shifted-exponential runtime distribution and scales to wall-clock time.
//! This reproduces the paper's stochastic process on real threads: the model
//! *is* the cluster's behaviour, so injecting it exercises the full
//! coordinator code path (dispatch → straggle → compute → collect → decode)
//! under exactly the analyzed distribution.

use crate::math::Rng;
use crate::model::{ClusterSpec, LatencyModel, RuntimeDist};
use crate::{Error, Result};
use std::collections::BTreeSet;
use std::time::Duration;

/// Per-worker injected delays plus the dead-worker set.
#[derive(Clone, Debug)]
pub struct StragglerInjector {
    delays: Vec<f64>,
    dead: BTreeSet<usize>,
    time_scale: f64,
}

impl StragglerInjector {
    /// Sample one delay per worker (group-major order matching
    /// `Allocation::per_worker_loads`). `loads` are the *integer* per-worker
    /// row counts; `time_scale` converts model time to wall seconds.
    pub fn sample(
        spec: &ClusterSpec,
        model: LatencyModel,
        per_worker_loads: &[usize],
        time_scale: f64,
        seed: u64,
    ) -> Result<StragglerInjector> {
        let mut inj = StragglerInjector {
            delays: Vec::with_capacity(per_worker_loads.len()),
            dead: BTreeSet::new(),
            time_scale,
        };
        inj.resample(spec, model, per_worker_loads, time_scale, seed)?;
        Ok(inj)
    }

    /// Redraw this injector in place — the serving hot path's reuse hook.
    /// Draws exactly what [`StragglerInjector::sample`] would (same RNG
    /// stream, same delays, bit for bit) but into the existing delay
    /// buffer, clearing the dead set, so a per-batch realization costs no
    /// allocation after the first batch.
    pub fn resample(
        &mut self,
        spec: &ClusterSpec,
        model: LatencyModel,
        per_worker_loads: &[usize],
        time_scale: f64,
        seed: u64,
    ) -> Result<()> {
        if per_worker_loads.len() != spec.total_workers() {
            return Err(Error::InvalidSpec(format!(
                "{} loads for {} workers",
                per_worker_loads.len(),
                spec.total_workers()
            )));
        }
        if !(time_scale > 0.0) {
            return Err(Error::InvalidSpec("time_scale must be positive".into()));
        }
        self.time_scale = time_scale;
        self.dead.clear();
        let mut rng = Rng::new(seed);
        self.delays.clear();
        let mut w = 0usize;
        for g in &spec.groups {
            for _ in 0..g.n {
                if per_worker_loads[w] == 0 {
                    // Drained worker (e.g. after an adaptive re-chunk):
                    // nothing dispatched, so it never completes. Dispatch
                    // loops skip it; `analytic_completion` ignores it.
                    self.delays.push(f64::INFINITY);
                } else {
                    let dist = RuntimeDist::new(
                        model,
                        per_worker_loads[w] as f64,
                        spec.k as f64,
                        g.mu,
                        g.alpha,
                    );
                    self.delays.push(dist.sample(&mut rng));
                }
                w += 1;
            }
        }
        Ok(())
    }

    /// Mark workers as permanently failed (they never respond).
    pub fn with_dead(mut self, dead: impl IntoIterator<Item = usize>) -> Self {
        self.set_dead(dead);
        self
    }

    /// In-place form of [`StragglerInjector::with_dead`].
    pub fn set_dead(&mut self, dead: impl IntoIterator<Item = usize>) {
        self.dead.clear();
        self.dead.extend(dead);
    }

    /// Multiply each worker's sampled delay by a per-worker slowdown
    /// factor (`1.0` = unchanged) — the scenario layer's hook for
    /// machine-level slowdowns on top of the group-level distribution.
    pub fn with_slowdowns(mut self, factors: &[f64]) -> Result<Self> {
        self.apply_slowdowns(factors)?;
        Ok(self)
    }

    /// In-place form of [`StragglerInjector::with_slowdowns`].
    pub fn apply_slowdowns(&mut self, factors: &[f64]) -> Result<()> {
        if factors.len() != self.delays.len() {
            return Err(Error::InvalidSpec(format!(
                "{} slowdown factors for {} workers",
                factors.len(),
                self.delays.len()
            )));
        }
        if factors.iter().any(|f| !(*f > 0.0) || !f.is_finite()) {
            return Err(Error::InvalidSpec(
                "slowdown factors must be positive and finite".into(),
            ));
        }
        for (d, f) in self.delays.iter_mut().zip(factors) {
            *d *= f;
        }
        Ok(())
    }

    /// Number of workers.
    pub fn len(&self) -> usize {
        self.delays.len()
    }

    /// True when no workers are configured.
    pub fn is_empty(&self) -> bool {
        self.delays.is_empty()
    }

    /// Is this worker dead?
    pub fn is_dead(&self, worker: usize) -> bool {
        self.dead.contains(&worker)
    }

    /// Model-time delay for a worker.
    pub fn model_delay(&self, worker: usize) -> f64 {
        self.delays[worker]
    }

    /// Wall-clock delay for a worker.
    pub fn wall_delay(&self, worker: usize) -> Duration {
        Duration::from_secs_f64(self.delays[worker] * self.time_scale)
    }

    /// The model-time the paper's analysis would record for this sample:
    /// the instant cumulative collected load first reaches `k`, given the
    /// per-worker loads (dead and zero-load workers excluded).
    pub fn analytic_completion(&self, per_worker_loads: &[usize], k: usize) -> Option<f64> {
        self.analytic_completion_with(per_worker_loads, k, &mut Vec::new())
    }

    /// [`StragglerInjector::analytic_completion`] with a caller-provided
    /// sort buffer, so per-batch serving loops avoid the `O(N)` allocation
    /// (the buffer is cleared and refilled; contents on entry are ignored).
    pub fn analytic_completion_with(
        &self,
        per_worker_loads: &[usize],
        k: usize,
        order: &mut Vec<usize>,
    ) -> Option<f64> {
        order.clear();
        order.extend(
            (0..self.delays.len())
                .filter(|&w| !self.is_dead(w) && per_worker_loads[w] > 0),
        );
        order.sort_by(|&a, &b| self.delays[a].total_cmp(&self.delays[b]));
        let mut cum = 0usize;
        for &w in order.iter() {
            cum += per_worker_loads[w];
            if cum >= k {
                return Some(self.delays[w]);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Group;

    fn spec() -> ClusterSpec {
        ClusterSpec::new(
            vec![
                Group { n: 4, mu: 4.0, alpha: 1.0 },
                Group { n: 6, mu: 1.0, alpha: 1.0 },
            ],
            100,
        )
        .unwrap()
    }

    #[test]
    fn one_delay_per_worker_deterministic() {
        let loads = vec![20usize; 10];
        let a = StragglerInjector::sample(&spec(), LatencyModel::A, &loads, 1.0, 5).unwrap();
        let b = StragglerInjector::sample(&spec(), LatencyModel::A, &loads, 1.0, 5).unwrap();
        assert_eq!(a.len(), 10);
        for w in 0..10 {
            assert_eq!(a.model_delay(w), b.model_delay(w));
        }
    }

    #[test]
    fn resample_matches_fresh_sample_and_reuses_buffer() {
        let loads = vec![20usize; 10];
        let mut inj =
            StragglerInjector::sample(&spec(), LatencyModel::A, &loads, 1.0, 5)
                .unwrap()
                .with_dead([1]);
        let cap = inj.delays.capacity();
        // Redraw with a different seed: identical to a fresh sample, dead
        // set cleared, no reallocation.
        inj.resample(&spec(), LatencyModel::A, &loads, 0.5, 9).unwrap();
        let fresh =
            StragglerInjector::sample(&spec(), LatencyModel::A, &loads, 0.5, 9)
                .unwrap();
        for w in 0..10 {
            assert_eq!(
                inj.model_delay(w).to_bits(),
                fresh.model_delay(w).to_bits(),
                "worker {w}"
            );
            assert_eq!(inj.wall_delay(w), fresh.wall_delay(w));
        }
        assert!(!inj.is_dead(1), "resample must clear the dead set");
        assert_eq!(inj.delays.capacity(), cap, "resample reallocated");
        // Invalid arguments still rejected in place.
        assert!(inj.resample(&spec(), LatencyModel::A, &loads[..9], 1.0, 5).is_err());
        assert!(inj.resample(&spec(), LatencyModel::A, &loads, 0.0, 5).is_err());
    }

    #[test]
    fn completion_scratch_variant_matches() {
        let loads = vec![30usize; 10];
        let inj =
            StragglerInjector::sample(&spec(), LatencyModel::A, &loads, 1.0, 8).unwrap();
        let want = inj.analytic_completion(&loads, 100);
        let mut scratch = Vec::new();
        assert_eq!(inj.analytic_completion_with(&loads, 100, &mut scratch), want);
        let cap = scratch.capacity();
        assert_eq!(inj.analytic_completion_with(&loads, 100, &mut scratch), want);
        assert_eq!(scratch.capacity(), cap);
    }

    #[test]
    fn delays_respect_model_shift() {
        let loads = vec![50usize; 10];
        let inj =
            StragglerInjector::sample(&spec(), LatencyModel::A, &loads, 1.0, 6).unwrap();
        // Model A shift = alpha * l / k = 0.5 for all groups here.
        for w in 0..10 {
            assert!(inj.model_delay(w) >= 0.5, "worker {w}");
        }
    }

    #[test]
    fn wall_delay_scaling() {
        let loads = vec![50usize; 10];
        let inj =
            StragglerInjector::sample(&spec(), LatencyModel::A, &loads, 0.001, 6).unwrap();
        for w in 0..10 {
            let wall = inj.wall_delay(w).as_secs_f64();
            // Duration has ns resolution; compare at that granularity.
            assert!((wall - inj.model_delay(w) * 0.001).abs() < 2e-9);
        }
    }

    #[test]
    fn dead_workers_tracked() {
        let loads = vec![20usize; 10];
        let inj = StragglerInjector::sample(&spec(), LatencyModel::A, &loads, 1.0, 7)
            .unwrap()
            .with_dead([2, 5]);
        assert!(inj.is_dead(2));
        assert!(!inj.is_dead(3));
    }

    #[test]
    fn analytic_completion_matches_definition() {
        let loads = vec![30usize; 10]; // 300 total, k=100 → need 4 fastest
        let inj =
            StragglerInjector::sample(&spec(), LatencyModel::A, &loads, 1.0, 8).unwrap();
        let t = inj.analytic_completion(&loads, 100).unwrap();
        // Exactly ceil(100/30)=4 workers must have delay <= t.
        let done = (0..10).filter(|&w| inj.model_delay(w) <= t).count();
        assert_eq!(done, 4);
    }

    #[test]
    fn analytic_completion_none_when_too_many_dead() {
        let loads = vec![30usize; 10];
        let inj = StragglerInjector::sample(&spec(), LatencyModel::A, &loads, 1.0, 9)
            .unwrap()
            .with_dead(0..8); // only 2 alive → 60 rows < k
        assert!(inj.analytic_completion(&loads, 100).is_none());
    }

    #[test]
    fn zero_load_workers_never_complete() {
        let mut loads = vec![20usize; 10];
        loads[3] = 0;
        loads[7] = 0;
        let inj =
            StragglerInjector::sample(&spec(), LatencyModel::A, &loads, 1.0, 5).unwrap();
        assert!(inj.model_delay(3).is_infinite());
        assert!(inj.model_delay(7).is_infinite());
        assert!(inj.model_delay(0).is_finite());
        // Completion still well-defined over the loaded workers
        // (8 x 20 = 160 >= k = 100).
        let t = inj.analytic_completion(&loads, 100).unwrap();
        assert!(t.is_finite());
    }

    #[test]
    fn slowdowns_scale_delays() {
        let loads = vec![20usize; 10];
        let base =
            StragglerInjector::sample(&spec(), LatencyModel::A, &loads, 1.0, 6).unwrap();
        let mut factors = vec![1.0; 10];
        factors[2] = 2.0;
        let slowed =
            StragglerInjector::sample(&spec(), LatencyModel::A, &loads, 1.0, 6)
                .unwrap()
                .with_slowdowns(&factors)
                .unwrap();
        for w in 0..10 {
            let expect = base.model_delay(w) * factors[w];
            assert!((slowed.model_delay(w) - expect).abs() < 1e-15, "worker {w}");
        }
        // Invalid factor vectors rejected.
        assert!(base.clone().with_slowdowns(&[1.0; 9]).is_err());
        assert!(base.clone().with_slowdowns(&[0.0; 10]).is_err());
        let mut nan = vec![1.0; 10];
        nan[0] = f64::NAN;
        assert!(base.clone().with_slowdowns(&nan).is_err());
    }

    #[test]
    fn rejects_bad_config() {
        let loads = vec![20usize; 9];
        assert!(
            StragglerInjector::sample(&spec(), LatencyModel::A, &loads, 1.0, 5).is_err()
        );
        let loads = vec![20usize; 10];
        assert!(
            StragglerInjector::sample(&spec(), LatencyModel::A, &loads, 0.0, 5).is_err()
        );
    }
}
