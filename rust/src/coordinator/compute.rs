//! Worker compute backends.
//!
//! Workers either compute natively (pure-Rust matvec — useful for tests and
//! for clusters larger than the PJRT service can serve efficiently) or
//! through `XlaService` (requires the `xla` cargo feature), a dedicated
//! thread owning the PJRT `Runtime` that serves matvec requests over a
//! channel. PJRT wrapper handles are not `Sync`, so the service thread is
//! the ownership boundary; worker threads hold only a cloneable submission
//! handle.

use crate::coding::Matrix;
#[cfg(feature = "xla")]
use crate::runtime::Runtime;
use crate::{Error, Result};
#[cfg(feature = "xla")]
use std::sync::mpsc;

/// A compute backend workers call to evaluate `rows · x`.
pub trait Compute: Send + Sync {
    /// Evaluate the chunk inner products.
    fn matvec(&self, rows: &Matrix, x: &[f64]) -> Result<Vec<f64>>;

    /// Evaluate the chunk against a *batch* of request vectors; returns one
    /// result vector per request. Default: loop over [`Compute::matvec`];
    /// backends with a batched artifact (`XlaService`) override this with a
    /// single MXU-shaped dispatch.
    fn matvec_batch(&self, rows: &Matrix, xs: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
        xs.iter().map(|x| self.matvec(rows, x)).collect()
    }

    /// Backend display name (for reports).
    fn name(&self) -> &'static str;
}

/// Pure-Rust reference backend.
pub struct NativeCompute;

impl Compute for NativeCompute {
    fn matvec(&self, rows: &Matrix, x: &[f64]) -> Result<Vec<f64>> {
        if rows.cols() != x.len() {
            return Err(Error::InvalidSpec(format!(
                "chunk cols {} vs x len {}",
                rows.cols(),
                x.len()
            )));
        }
        Ok(rows.matvec(x))
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(feature = "xla")]
enum Request {
    Matvec {
        rows: Matrix,
        x: Vec<f64>,
        reply: mpsc::Sender<Result<Vec<f64>>>,
    },
    Encode {
        g: Matrix,
        a: Matrix,
        reply: mpsc::Sender<Result<Matrix>>,
    },
    MatvecBatch {
        rows: Matrix,
        xs: Vec<Vec<f64>>,
        reply: mpsc::Sender<Result<Vec<Vec<f64>>>>,
    },
    Shutdown,
}

/// Channel-fronted PJRT compute service.
///
/// PJRT wrapper handles are not `Send` (they hold `Rc`s over raw pointers),
/// so the [`Runtime`] is constructed *inside* the service thread and never
/// crosses a thread boundary. Requests are serialized through that thread;
/// with realistic straggle injection the queueing delay is negligible
/// relative to the injected delays, and the numerics are exactly the AOT
/// artifact's.
#[cfg(feature = "xla")]
pub struct XlaService {
    tx: mpsc::Sender<Request>,
    handle: std::sync::Mutex<Option<std::thread::JoinHandle<()>>>,
    cols: usize,
}

#[cfg(feature = "xla")]
impl XlaService {
    /// Spawn the service thread, loading artifacts from `dir` in-thread.
    /// Fails fast if the artifacts cannot be loaded/compiled.
    // Allowlisted thread-creation site (lint rule D3): the PJRT client
    // is not Sync, so XLA work cannot ride the shared WorkPool — it
    // lives on one dedicated service thread behind a channel.
    #[allow(clippy::disallowed_methods)]
    pub fn new(dir: std::path::PathBuf) -> Result<XlaService> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<usize>>();
        let handle = std::thread::Builder::new()
            .name("xla-service".into())
            .spawn(move || {
                let runtime = match Runtime::load(&dir) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(rt.cols()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::Matvec { rows, x, reply } => {
                            let _ = reply.send(runtime.matvec(&rows, &x));
                        }
                        Request::Encode { g, a, reply } => {
                            let _ = reply.send(runtime.encode(&g, &a));
                        }
                        Request::MatvecBatch { rows, xs, reply } => {
                            let _ = reply.send(runtime.matvec_batched(&rows, &xs));
                        }
                        Request::Shutdown => break,
                    }
                }
            })
            .map_err(|e| Error::Runtime(format!("spawn xla service: {e}")))?;
        let cols = ready_rx
            .recv()
            .map_err(|_| Error::Runtime("xla service died during load".into()))??;
        Ok(XlaService {
            tx,
            handle: std::sync::Mutex::new(Some(handle)),
            cols,
        })
    }

    /// Input width `d` the loaded artifacts expect.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Run the AOT encode executable `G · A` (setup path). Shapes must match
    /// the encode artifact exactly.
    pub fn encode(&self, g: &Matrix, a: &Matrix) -> Result<Matrix> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Request::Encode {
                g: g.clone(),
                a: a.clone(),
                reply: reply_tx,
            })
            .map_err(|_| Error::Runtime("xla service stopped".into()))?;
        reply_rx
            .recv()
            .map_err(|_| Error::Runtime("xla service dropped reply".into()))?
    }

    /// Gracefully stop the service thread.
    pub fn shutdown(&self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

#[cfg(feature = "xla")]
impl Compute for XlaService {
    fn matvec(&self, rows: &Matrix, x: &[f64]) -> Result<Vec<f64>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Request::Matvec {
                rows: rows.clone(),
                x: x.to_vec(),
                reply: reply_tx,
            })
            .map_err(|_| Error::Runtime("xla service stopped".into()))?;
        reply_rx
            .recv()
            .map_err(|_| Error::Runtime("xla service dropped reply".into()))?
    }

    fn matvec_batch(&self, rows: &Matrix, xs: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Request::MatvecBatch {
                rows: rows.clone(),
                xs: xs.to_vec(),
                reply: reply_tx,
            })
            .map_err(|_| Error::Runtime("xla service stopped".into()))?;
        reply_rx
            .recv()
            .map_err(|_| Error::Runtime("xla service dropped reply".into()))?
    }

    fn name(&self) -> &'static str {
        "xla-pjrt"
    }
}

#[cfg(feature = "xla")]
impl Drop for XlaService {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::Rng;

    #[test]
    fn native_matches_matrix_matvec() {
        let mut rng = Rng::new(4);
        let m = Matrix::from_fn(7, 5, |_, _| rng.normal());
        let x: Vec<f64> = (0..5).map(|_| rng.normal()).collect();
        let y = NativeCompute.matvec(&m, &x).unwrap();
        assert_eq!(y, m.matvec(&x));
        assert_eq!(NativeCompute.name(), "native");
    }

    #[test]
    fn native_rejects_bad_shapes() {
        let m = Matrix::zeros(3, 4);
        assert!(NativeCompute.matvec(&m, &[1.0, 2.0]).is_err());
    }
}
