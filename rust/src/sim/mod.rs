//! Monte-Carlo cluster simulator.
//!
//! Estimates the expected computation latency `E[T_{r:N}]` of §II-C: sample
//! every worker's completion time from its shifted-exponential runtime
//! distribution and record the instant the master has aggregated `k` coded
//! rows. The engine is multi-threaded — deterministic per-stream RNG
//! splits executed on the persistent [`crate::runtime::pool::WorkPool`]
//! (no thread spawns per call, summaries merged in stream order, results
//! byte-identical at any pool size) — because the paper's figures need
//! `10^4` samples across dozens of sweep points.

#![forbid(unsafe_code)]

pub mod montecarlo;
pub mod schemes;

pub use montecarlo::{
    latency_any_k, latency_any_k_detailed, latency_per_group, monte_carlo,
    monte_carlo_scratch, monte_carlo_scratch_inner_on, AnyKSampler,
    GroupMaxSampler, SimConfig,
};
pub use schemes::{
    scheme_allocation, simulate_policy, simulate_scheme, Scheme, SchemeResult,
};
