//! Monte-Carlo cluster simulator.
//!
//! Estimates the expected computation latency `E[T_{r:N}]` of §II-C: sample
//! every worker's completion time from its shifted-exponential runtime
//! distribution and record the instant the master has aggregated `k` coded
//! rows. The engine is multi-threaded (deterministic per-thread RNG streams)
//! because the paper's figures need `10^4` samples across dozens of sweep
//! points.

pub mod montecarlo;
pub mod schemes;

pub use montecarlo::{
    latency_any_k, latency_any_k_detailed, latency_per_group, monte_carlo,
    monte_carlo_scratch, AnyKSampler, GroupMaxSampler, SimConfig,
};
pub use schemes::{
    scheme_allocation, simulate_policy, simulate_scheme, Scheme, SchemeResult,
};
