//! Core Monte-Carlo estimators.

use crate::math::{Rng, Summary};
use crate::model::{ClusterSpec, LatencyModel};
use crate::runtime::pool::WorkPool;
use crate::{Error, Result};

/// Simulation configuration.
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    /// Number of Monte-Carlo samples (the paper uses 10^4).
    pub samples: usize,
    /// Base RNG seed; every run with the same seed is bit-reproducible.
    pub seed: u64,
    /// Number of worker threads (`0` = use available parallelism).
    pub threads: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { samples: 10_000, seed: 0x5EED, threads: 0 }
    }
}

impl SimConfig {
    fn effective_threads(&self, samples: usize) -> usize {
        let hw = if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
        };
        hw.min(samples.max(1))
    }
}

/// Run `cfg.samples` evaluations of `f` (one latency sample each) across
/// threads with deterministic per-thread RNG streams, merging the summaries.
pub fn monte_carlo<F>(cfg: &SimConfig, f: F) -> Summary
where
    F: Fn(&mut Rng) -> f64 + Sync,
{
    monte_carlo_scratch(cfg, || (), |rng, _| f(rng))
}

/// [`monte_carlo`] with a per-thread scratch state built by `init` — lets the
/// hot loop reuse sample buffers instead of allocating per sample (§Perf).
pub fn monte_carlo_scratch<S, I, F>(cfg: &SimConfig, init: I, f: F) -> Summary
where
    I: Fn() -> S + Sync,
    F: Fn(&mut Rng, &mut S) -> f64 + Sync,
{
    monte_carlo_scratch_inner(cfg, false, init, f)
}

/// Like [`monte_carlo_scratch`] but optionally retaining every sample so the
/// caller can read percentiles (tail-latency analysis). Runs on the shared
/// global [`WorkPool`].
pub fn monte_carlo_scratch_inner<S, I, F>(
    cfg: &SimConfig,
    keep_samples: bool,
    init: I,
    f: F,
) -> Summary
where
    I: Fn() -> S + Sync,
    F: Fn(&mut Rng, &mut S) -> f64 + Sync,
{
    monte_carlo_scratch_inner_on(
        WorkPool::global_ref(),
        cfg,
        keep_samples,
        init,
        f,
    )
}

/// The Monte-Carlo engine on an explicit pool handle.
///
/// The sample *partition* is fixed by `cfg.threads` alone: stream `t` of
/// `T = cfg.effective_threads()` draws its `samples/T (+1)` samples from
/// the seed-derived RNG stream `seed ^ GOLDEN·(t+1)`, with per-stream
/// scratch (the sampler's buffers) built once and reused across all of
/// that stream's iterations. The pool only *executes* the streams —
/// stream summaries are collected and merged in stream-index order
/// ([`WorkPool::run_collect`]) — so for a fixed `cfg` the result is
/// byte-identical on any pool size (the pool-identity suite pins this
/// across pools of 1/2/7/16 workers). No threads are spawned per call:
/// figure sweeps dispatch hundreds of these back-to-back onto the same
/// persistent workers.
pub fn monte_carlo_scratch_inner_on<S, I, F>(
    pool: &WorkPool,
    cfg: &SimConfig,
    keep_samples: bool,
    init: I,
    f: F,
) -> Summary
where
    I: Fn() -> S + Sync,
    F: Fn(&mut Rng, &mut S) -> f64 + Sync,
{
    let new_summary = || if keep_samples { Summary::keeping_samples() } else { Summary::new() };
    let threads = cfg.effective_threads(cfg.samples);
    if threads <= 1 {
        let mut rng = Rng::new(cfg.seed);
        let mut scratch = init();
        let mut s = new_summary();
        for _ in 0..cfg.samples {
            s.add(f(&mut rng, &mut scratch));
        }
        return s;
    }
    let per = cfg.samples / threads;
    let extra = cfg.samples % threads;
    let seed = cfg.seed;
    let summaries = pool.run_collect(threads, |t| {
        // Derive an independent stream per task index (not per worker:
        // the stream split is the deterministic unit, the pool worker
        // that happens to run it is not).
        let mut rng = Rng::new(
            seed ^ (0x9E37_79B9_7F4A_7C15u64.wrapping_mul(t as u64 + 1)),
        );
        let count = per + usize::from(t < extra);
        let mut scratch = init();
        let mut s = new_summary();
        for _ in 0..count {
            s.add(f(&mut rng, &mut scratch));
        }
        s
    });
    let mut total = new_summary();
    for s in &summaries {
        total.merge(s);
    }
    total
}

/// Per-group sampling parameters precomputed out of the hot loop.
#[derive(Clone, Copy, Debug)]
struct GroupSampler {
    n: usize,
    shift: f64,
    scale: f64,
    load: f64,
}

fn group_samplers(
    spec: &ClusterSpec,
    loads: &[f64],
    model: LatencyModel,
) -> Result<Vec<GroupSampler>> {
    if loads.len() != spec.num_groups() {
        return Err(Error::InvalidSpec(format!(
            "{} loads for {} groups",
            loads.len(),
            spec.num_groups()
        )));
    }
    let k = spec.k as f64;
    Ok(spec
        .groups
        .iter()
        .zip(loads)
        .map(|(g, &l)| {
            let (shift, scale) = match model {
                LatencyModel::A => (g.alpha * l / k, l / (k * g.mu)),
                LatencyModel::B => (g.alpha * l, l / g.mu),
            };
            GroupSampler { n: g.n, shift, scale, load: l }
        })
        .collect())
}

/// Expected latency when the master decodes from **any** set of workers whose
/// loads sum to at least `k` (the paper's `(n, k)` MDS over the whole
/// matrix). `loads` are per-group, real-valued.
///
/// Returns the sample summary; `Summary::mean()` estimates `λ_{r:N}`.
pub fn latency_any_k(
    spec: &ClusterSpec,
    loads: &[f64],
    model: LatencyModel,
    cfg: &SimConfig,
) -> Result<Summary> {
    latency_any_k_inner(spec, loads, model, cfg, false)
}

/// [`latency_any_k`] retaining every sample: `Summary::percentile` works on
/// the result (tail-latency analysis; costs 8·samples bytes).
pub fn latency_any_k_detailed(
    spec: &ClusterSpec,
    loads: &[f64],
    model: LatencyModel,
    cfg: &SimConfig,
) -> Result<Summary> {
    latency_any_k_inner(spec, loads, model, cfg, true)
}

/// One group's lazy order-statistic stream state. Per-group parameters are
/// inlined so the merge loop touches one cache line per group
/// (micro-iteration 4).
#[derive(Clone, Copy, Debug, Default)]
struct GroupCursor {
    /// Current order-statistic time (head of this group's stream).
    time: f64,
    /// Exponential accumulator `E_(i)`.
    e: f64,
    shift: f64,
    scale: f64,
    load: f64,
    /// Workers not yet emitted (excluding the head).
    remaining: usize,
}

/// Reusable single-draw sampler of the **any-`k`** completion time: the
/// instant the master has aggregated `k` coded rows from an `(n, k)` MDS
/// code over the whole matrix (§II-C).
///
/// §Perf (iteration 3): no sampling-then-sorting at all. The Rényi
/// representation generates each group's exponential order statistics
/// *already sorted* in O(1) per step:
///
/// ```text
/// E_(1) = Exp/n,   E_(i+1) = E_(i) + Exp/(n - i)
/// ```
///
/// so each group becomes a lazy ascending stream of completion times
/// (shift + scale·E is monotone). A G-way merge (linear min over G ≤ a
/// handful of groups) accumulates loads until k — only the m* workers
/// that actually matter are ever materialized, and nothing is sorted.
/// History (per 1k samples at N=2500): naive full-sort 96 ms →
/// selection+partial sort 55 ms → ziggurat 46 ms → this merge with
/// inlined cursors 43.7 ms (EXPERIMENTS.md §Perf).
///
/// [`latency_any_k`] wraps this in the multi-threaded Monte-Carlo engine;
/// the workload layer draws one sample per *job* instead (service times of
/// a queueing simulation), which is why the sampler is exposed on its own.
#[derive(Clone, Debug)]
pub struct AnyKSampler {
    samplers: Vec<GroupSampler>,
    cursors: Vec<GroupCursor>,
    k: f64,
}

impl AnyKSampler {
    /// Validate the allocation and precompute per-group parameters.
    pub fn new(
        spec: &ClusterSpec,
        loads: &[f64],
        model: LatencyModel,
    ) -> Result<AnyKSampler> {
        let samplers = group_samplers(spec, loads, model)?;
        let total_load: f64 = samplers.iter().map(|s| s.load * s.n as f64).sum();
        let k = spec.k as f64;
        if total_load + 1e-9 < k {
            return Err(Error::InvalidSpec(format!(
                "total coded rows {total_load:.3} < k = {k}; undecodable"
            )));
        }
        let cursors = vec![GroupCursor::default(); samplers.len()];
        Ok(AnyKSampler { samplers, cursors, k })
    }

    /// Draw one completion-time sample (one coded job).
    pub fn sample(&mut self, rng: &mut Rng) -> f64 {
        for (c, gs) in self.cursors.iter_mut().zip(&self.samplers) {
            let e = rng.exp1() / gs.n as f64;
            *c = GroupCursor {
                time: gs.shift + gs.scale * e,
                e,
                shift: gs.shift,
                scale: gs.scale,
                load: gs.load,
                remaining: gs.n - 1,
            };
        }
        let mut cum = 0.0;
        let mut last = 0.0;
        loop {
            // Linear min over G groups (G is tiny; beats a heap).
            let mut g = 0usize;
            let mut best = self.cursors[0].time;
            for (j, c) in self.cursors.iter().enumerate().skip(1) {
                if c.time < best {
                    best = c.time;
                    g = j;
                }
            }
            if !best.is_finite() {
                // Every worker has been consumed. `new()` guaranteed
                // total load ≥ k, so this is the float-drift corner of a
                // critically-loaded (rate-1) allocation where the
                // element-wise `cum` lands a few ulps short of `k`: the
                // job completes when the final worker did.
                return last;
            }
            last = best;
            let c = &mut self.cursors[g];
            cum += c.load;
            if cum >= self.k - 1e-9 {
                return best;
            }
            if c.remaining == 0 {
                c.time = f64::INFINITY;
            } else {
                c.e += rng.exp1() / c.remaining as f64;
                c.remaining -= 1;
                c.time = c.shift + c.scale * c.e;
            }
        }
    }
}

/// Reusable single-draw sampler of the **group-code** completion time of
/// [33]: the master must receive `ceil(r_j)` results from *each* group `j`
/// (group-wise decode), so one draw is `max_j` of the `r_j`-th order
/// statistic. §Perf: the order statistic is generated directly via the
/// Rényi recursion in O(r_j) — no buffer, no selection.
#[derive(Clone, Debug)]
pub struct GroupMaxSampler {
    samplers: Vec<GroupSampler>,
    r_int: Vec<usize>,
}

impl GroupMaxSampler {
    /// Validate the allocation and clamp each `r_j` into `[1, N_j]`.
    pub fn new(
        spec: &ClusterSpec,
        loads: &[f64],
        r_per_group: &[f64],
        model: LatencyModel,
    ) -> Result<GroupMaxSampler> {
        let samplers = group_samplers(spec, loads, model)?;
        if r_per_group.len() != samplers.len() {
            return Err(Error::InvalidSpec("r vector length mismatch".into()));
        }
        let r_int: Vec<usize> = r_per_group
            .iter()
            .zip(&samplers)
            .map(|(&r, gs)| {
                let ri = r.ceil() as usize;
                ri.clamp(1, gs.n)
            })
            .collect();
        Ok(GroupMaxSampler { samplers, r_int })
    }

    /// Draw one completion-time sample (one coded job).
    pub fn sample(&mut self, rng: &mut Rng) -> f64 {
        let mut worst = f64::NEG_INFINITY;
        for (gs, &rj) in self.samplers.iter().zip(&self.r_int) {
            let mut e = 0.0;
            for i in 0..rj {
                e += rng.exp1() / (gs.n - i) as f64;
            }
            worst = worst.max(gs.shift + gs.scale * e);
        }
        worst
    }
}

fn latency_any_k_inner(
    spec: &ClusterSpec,
    loads: &[f64],
    model: LatencyModel,
    cfg: &SimConfig,
    keep_samples: bool,
) -> Result<Summary> {
    let base = AnyKSampler::new(spec, loads, model)?;
    Ok(monte_carlo_scratch_inner(
        cfg,
        keep_samples,
        || base.clone(),
        |rng, sampler: &mut AnyKSampler| sampler.sample(rng),
    ))
}

/// Expected latency of the **group-code** scheme of [33]: the master must
/// receive `ceil(r_j)` results from *each* group `j` (group-wise decode),
/// so the latency is `max_j T^{l}_{r_j:N_j}`.
pub fn latency_per_group(
    spec: &ClusterSpec,
    loads: &[f64],
    r_per_group: &[f64],
    model: LatencyModel,
    cfg: &SimConfig,
) -> Result<Summary> {
    let base = GroupMaxSampler::new(spec, loads, r_per_group, model)?;
    Ok(monte_carlo_scratch(
        cfg,
        || base.clone(),
        |rng, sampler: &mut GroupMaxSampler| sampler.sample(rng),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{order_stats, Group};

    fn quick_cfg() -> SimConfig {
        SimConfig { samples: 4_000, seed: 77, threads: 2 }
    }

    #[test]
    fn homogeneous_any_k_matches_order_statistics() {
        // One group, uniform load l = k/r: the master needs exactly r
        // completions, so E[T] = (l/k)(α + (H_N - H_{N-r})/μ).
        let (n, k, r) = (50usize, 1000usize, 30usize);
        let l = k as f64 / r as f64;
        let spec =
            ClusterSpec::new(vec![Group { n, mu: 2.0, alpha: 1.0 }], k).unwrap();
        let s = latency_any_k(&spec, &[l], LatencyModel::A, &quick_cfg()).unwrap();
        let analytic = order_stats::group_latency_exact(
            LatencyModel::A,
            l,
            k as f64,
            n as u64,
            r as u64,
            2.0,
            1.0,
        );
        assert!(
            (s.mean() - analytic).abs() < 4.0 * s.stderr() + 0.005 * analytic,
            "MC {} vs analytic {analytic}",
            s.mean()
        );
    }

    #[test]
    fn per_group_matches_single_group_order_stat() {
        let (n, k, r) = (40usize, 1000usize, 25usize);
        let l = 10.0;
        let spec =
            ClusterSpec::new(vec![Group { n, mu: 1.5, alpha: 1.0 }], k).unwrap();
        let s = latency_per_group(&spec, &[l], &[r as f64], LatencyModel::A, &quick_cfg())
            .unwrap();
        let analytic = order_stats::group_latency_exact(
            LatencyModel::A,
            l,
            k as f64,
            n as u64,
            r as u64,
            1.5,
            1.0,
        );
        assert!(
            (s.mean() - analytic).abs() < 4.0 * s.stderr() + 0.005 * analytic,
            "MC {} vs analytic {analytic}",
            s.mean()
        );
    }

    #[test]
    fn any_k_sampler_matches_engine_stream() {
        // The exposed sampler must replicate the engine's draw order exactly:
        // a single-threaded engine run and a hand-rolled loop over
        // `AnyKSampler::sample` with the same seed are bit-identical.
        let spec = ClusterSpec::paper_two_group(1000);
        let loads = vec![2.0, 2.0];
        let cfg = SimConfig { samples: 500, seed: 11, threads: 1 };
        let engine = latency_any_k(&spec, &loads, LatencyModel::A, &cfg).unwrap();
        let mut sampler =
            AnyKSampler::new(&spec, &loads, LatencyModel::A).unwrap();
        let mut rng = Rng::new(11);
        let mut by_hand = Summary::new();
        for _ in 0..500 {
            by_hand.add(sampler.sample(&mut rng));
        }
        assert_eq!(engine.mean(), by_hand.mean());
        assert_eq!(engine.max(), by_hand.max());
    }

    #[test]
    fn critically_loaded_allocation_never_returns_infinity() {
        // Uncoded (rate-1) allocation whose per-worker load k/N is inexact:
        // the element-wise load accumulation in the merge can land a few
        // ulps short of k after ~900 adds, which used to return +inf once
        // every cursor was exhausted.
        let spec = ClusterSpec::paper_two_group(10_000); // N = 900
        let loads = vec![10_000.0 / 900.0; 2];
        let mut s = AnyKSampler::new(&spec, &loads, LatencyModel::A).unwrap();
        let mut rng = Rng::new(3);
        for _ in 0..5_000 {
            let t = s.sample(&mut rng);
            assert!(t.is_finite() && t > 0.0, "sample {t}");
        }
    }

    #[test]
    fn group_max_sampler_rejects_mismatched_r() {
        let spec = ClusterSpec::paper_two_group(1000);
        assert!(GroupMaxSampler::new(
            &spec,
            &[2.0, 2.0],
            &[10.0],
            LatencyModel::A
        )
        .is_err());
    }

    #[test]
    fn undecodable_load_rejected() {
        let spec = ClusterSpec::new(vec![Group { n: 10, mu: 1.0, alpha: 1.0 }], 1000).unwrap();
        // 10 workers x 50 rows = 500 < k.
        assert!(latency_any_k(&spec, &[50.0], LatencyModel::A, &quick_cfg()).is_err());
    }

    #[test]
    fn deterministic_given_seed_and_threads() {
        let spec = ClusterSpec::paper_two_group(1000);
        let loads = vec![2.0, 2.0];
        let cfg = SimConfig { samples: 1_000, seed: 5, threads: 3 };
        let a = latency_any_k(&spec, &loads, LatencyModel::A, &cfg).unwrap();
        let b = latency_any_k(&spec, &loads, LatencyModel::A, &cfg).unwrap();
        assert_eq!(a.mean(), b.mean());
    }

    #[test]
    fn stream_split_is_pool_size_invariant() {
        // cfg.threads fixes the deterministic stream partition; the pool
        // only executes it. Any pool size must reproduce the same summary
        // byte for byte.
        use crate::runtime::pool::WorkPool;
        let spec = ClusterSpec::paper_two_group(1000);
        let loads = vec![2.0, 2.0];
        let cfg = SimConfig { samples: 700, seed: 23, threads: 5 };
        let base = AnyKSampler::new(&spec, &loads, LatencyModel::A).unwrap();
        let reference = monte_carlo_scratch_inner_on(
            &WorkPool::new(1),
            &cfg,
            false,
            || base.clone(),
            |rng, s: &mut AnyKSampler| s.sample(rng),
        );
        for pool_size in [2usize, 7, 16] {
            let pool = WorkPool::new(pool_size);
            let got = monte_carlo_scratch_inner_on(
                &pool,
                &cfg,
                false,
                || base.clone(),
                |rng, s: &mut AnyKSampler| s.sample(rng),
            );
            assert_eq!(got.mean().to_bits(), reference.mean().to_bits());
            assert_eq!(got.max().to_bits(), reference.max().to_bits());
            assert_eq!(got.count(), reference.count());
        }
    }

    #[test]
    fn parallel_equals_more_samples_statistically() {
        // Threaded and single-threaded runs agree within Monte-Carlo error.
        let spec = ClusterSpec::paper_two_group(1000);
        let loads = vec![3.0, 3.0];
        let c1 = SimConfig { samples: 8_000, seed: 9, threads: 1 };
        let c4 = SimConfig { samples: 8_000, seed: 9, threads: 4 };
        let a = latency_any_k(&spec, &loads, LatencyModel::A, &c1).unwrap();
        let b = latency_any_k(&spec, &loads, LatencyModel::A, &c4).unwrap();
        let tol = 4.0 * (a.stderr() + b.stderr());
        assert!((a.mean() - b.mean()).abs() < tol);
    }

    #[test]
    fn more_workers_lower_latency_proposed_style() {
        // Sanity: scaling the cluster down should increase latency when the
        // load per worker is fixed by k/N-style scaling.
        let spec1 = ClusterSpec::paper_five_group(500, 1000);
        let spec2 = ClusterSpec::paper_five_group(2000, 1000);
        let l1 = 2.0 * 1000.0 / 500.0; // rate-1/2 uniform
        let l2 = 2.0 * 1000.0 / 2000.0;
        let a =
            latency_any_k(&spec1, &vec![l1; 5], LatencyModel::A, &quick_cfg()).unwrap();
        let b =
            latency_any_k(&spec2, &vec![l2; 5], LatencyModel::A, &quick_cfg()).unwrap();
        assert!(a.mean() > b.mean());
    }

    #[test]
    fn model_b_latency_scales_with_k() {
        let spec_small = ClusterSpec::paper_two_group(100);
        let spec_big = ClusterSpec::paper_two_group(1000);
        // Same per-worker load fraction of k: l = k/300.
        let a = latency_any_k(
            &spec_small,
            &vec![100.0 / 300.0 * 2.0; 2],
            LatencyModel::B,
            &quick_cfg(),
        )
        .unwrap();
        let b = latency_any_k(
            &spec_big,
            &vec![1000.0 / 300.0 * 2.0; 2],
            LatencyModel::B,
            &quick_cfg(),
        )
        .unwrap();
        let ratio = b.mean() / a.mean();
        assert!((ratio - 10.0).abs() < 0.5, "ratio {ratio}");
    }
}
