//! Wiring of allocation policies into the Monte-Carlo engine.

use crate::allocation::{
    group_code_allocation, proposed_allocation, reisizadeh_allocation,
    uncoded_allocation, uniform_allocation, Allocation,
};
use crate::model::{ClusterSpec, LatencyModel};
use crate::sim::{latency_any_k, latency_per_group, SimConfig};
use crate::Result;

/// A named end-to-end scheme from the paper's evaluation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Scheme {
    /// Proposed allocation (Theorem 2 / Corollary 2) with its `(n*, k)` code.
    Proposed,
    /// Rate-1 uniform allocation; every worker must finish.
    Uncoded,
    /// Uniform allocation using the optimal code length `n*` from Theorem 2.
    UniformWithOptimalN,
    /// Uniform allocation with an explicit rate `k/n`.
    UniformRate(f64),
    /// Fixed-`r` group code of [33] (simulated group-wise decode).
    GroupCode(f64),
    /// Load allocation of [32] (Appendix D).
    Reisizadeh,
}

impl Scheme {
    /// Stable display name used in figures and CSV output.
    pub fn name(&self) -> String {
        match self {
            Scheme::Proposed => "proposed".into(),
            Scheme::Uncoded => "uncoded".into(),
            Scheme::UniformWithOptimalN => "uniform-n*".into(),
            Scheme::UniformRate(r) => format!("uniform-rate-{r:.3}"),
            Scheme::GroupCode(r) => format!("group-code-r{r:.0}"),
            Scheme::Reisizadeh => "reisizadeh".into(),
        }
    }
}

/// Outcome of simulating one scheme on one cluster.
#[derive(Clone, Debug)]
pub struct SchemeResult {
    /// Scheme display name.
    pub scheme: String,
    /// Monte-Carlo mean latency.
    pub mean: f64,
    /// Standard error of the mean.
    pub stderr: f64,
    /// Analytic bound, when the policy defines one (`T*`, `1/r`, …).
    pub bound: Option<f64>,
    /// Code rate `k/n` actually used.
    pub rate: f64,
    /// Real-valued code length.
    pub n: f64,
}

/// The [`Allocation`] a scheme induces on `spec` — the policy half of
/// [`simulate_scheme`], reused by the workload layer to build per-job
/// service-time samplers.
pub fn scheme_allocation(
    spec: &ClusterSpec,
    scheme: Scheme,
    model: LatencyModel,
) -> Result<Allocation> {
    let k = spec.k as f64;
    match scheme {
        Scheme::Proposed => proposed_allocation(model, spec),
        Scheme::Uncoded => uncoded_allocation(model, spec),
        Scheme::UniformWithOptimalN => {
            let opt = proposed_allocation(model, spec)?;
            uniform_allocation(model, spec, opt.n)
        }
        Scheme::UniformRate(rate) => uniform_allocation(model, spec, k / rate),
        Scheme::GroupCode(r) => group_code_allocation(model, spec, r),
        Scheme::Reisizadeh => reisizadeh_allocation(model, spec),
    }
}

/// Simulate `scheme` on `spec` under `model`.
pub fn simulate_scheme(
    spec: &ClusterSpec,
    scheme: Scheme,
    model: LatencyModel,
    cfg: &SimConfig,
) -> Result<SchemeResult> {
    let k = spec.k as f64;
    let a = scheme_allocation(spec, scheme, model)?;
    let s = match scheme {
        Scheme::GroupCode(_) => {
            latency_per_group(spec, &a.loads, &a.r, model, cfg)?
        }
        _ => latency_any_k(spec, &a.loads, model, cfg)?,
    };
    // Only the policies for which the paper derives a latency expression
    // report a bound (`T*` for the proposed optimum, `1/r` for the group
    // code); the rest are simulation-only baselines.
    let bound = match scheme {
        Scheme::Proposed | Scheme::GroupCode(_) => a.latency_bound,
        _ => None,
    };
    Ok(SchemeResult {
        scheme: scheme.name(),
        mean: s.mean(),
        stderr: s.stderr(),
        bound,
        rate: k / a.n,
        n: a.n,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SimConfig {
        SimConfig { samples: 3_000, seed: 123, threads: 2 }
    }

    #[test]
    fn proposed_achieves_its_bound_at_scale() {
        // Theorem 3: λ_{r:N} → T* as N → ∞. At N=2500 the gap should be
        // small (a few percent).
        let spec = ClusterSpec::paper_five_group(2500, 10_000);
        let r = simulate_scheme(&spec, Scheme::Proposed, LatencyModel::A, &cfg()).unwrap();
        let bound = r.bound.unwrap();
        assert!(r.mean >= bound * 0.999, "mean {} below bound {bound}", r.mean);
        assert!(
            (r.mean - bound) / bound < 0.10,
            "gap too large: mean {} vs bound {bound}",
            r.mean
        );
    }

    #[test]
    fn proposed_beats_uniform_and_uncoded() {
        let spec = ClusterSpec::paper_five_group(2500, 10_000);
        let p = simulate_scheme(&spec, Scheme::Proposed, LatencyModel::A, &cfg()).unwrap();
        let u = simulate_scheme(&spec, Scheme::UniformWithOptimalN, LatencyModel::A, &cfg())
            .unwrap();
        let unc = simulate_scheme(&spec, Scheme::Uncoded, LatencyModel::A, &cfg()).unwrap();
        assert!(p.mean < u.mean, "proposed {} !< uniform {}", p.mean, u.mean);
        assert!(p.mean < unc.mean);
    }

    #[test]
    fn group_code_latency_floors_at_one_over_r() {
        // As N grows with fixed r, the group-code latency converges to 1/r
        // and stops improving — the phenomenon behind Fig. 4.
        let r = 100.0;
        let small = ClusterSpec::paper_five_group(500, 10_000);
        let big = ClusterSpec::paper_five_group(8_000, 10_000);
        let a = simulate_scheme(&small, Scheme::GroupCode(r), LatencyModel::A, &cfg()).unwrap();
        let b = simulate_scheme(&big, Scheme::GroupCode(r), LatencyModel::A, &cfg()).unwrap();
        assert!(b.mean >= 1.0 / r * 0.999, "mean {} below floor", b.mean);
        assert!(b.mean < a.mean);
        // Large-N latency is within 15% of the 1/r floor.
        assert!((b.mean - 0.01) / 0.01 < 0.15, "mean {}", b.mean);
    }

    #[test]
    fn proposed_vastly_beats_group_code_at_large_n() {
        // Fig. 4 headline: ≥10x at large N.
        let spec = ClusterSpec::paper_five_group(10_000, 10_000);
        let p = simulate_scheme(&spec, Scheme::Proposed, LatencyModel::A, &cfg()).unwrap();
        let g = simulate_scheme(&spec, Scheme::GroupCode(100.0), LatencyModel::A, &cfg())
            .unwrap();
        assert!(
            g.mean / p.mean > 5.0,
            "expected large gain, got {}x",
            g.mean / p.mean
        );
    }

    #[test]
    fn reisizadeh_matches_proposed_model_b() {
        let spec = ClusterSpec::paper_three_group_b(1000, 100_000);
        let p = simulate_scheme(&spec, Scheme::Proposed, LatencyModel::B, &cfg()).unwrap();
        let z = simulate_scheme(&spec, Scheme::Reisizadeh, LatencyModel::B, &cfg()).unwrap();
        let tol = 4.0 * (p.stderr + z.stderr);
        assert!((p.mean - z.mean).abs() < tol, "{} vs {}", p.mean, z.mean);
    }

    #[test]
    fn uniform_rate_sweep_is_unimodal_ish() {
        // Fig. 8: there is an interior optimal rate (near 0.52 for the paper's
        // 2-group cluster) — check the ends are worse than the middle.
        let spec = ClusterSpec::paper_two_group(10_000);
        let lo = simulate_scheme(&spec, Scheme::UniformRate(0.35), LatencyModel::A, &cfg())
            .unwrap();
        let mid = simulate_scheme(&spec, Scheme::UniformRate(0.52), LatencyModel::A, &cfg())
            .unwrap();
        let hi = simulate_scheme(&spec, Scheme::UniformRate(0.9), LatencyModel::A, &cfg())
            .unwrap();
        assert!(mid.mean < lo.mean, "mid {} !< lo {}", mid.mean, lo.mean);
        assert!(mid.mean < hi.mean, "mid {} !< hi {}", mid.mean, hi.mean);
    }
}
