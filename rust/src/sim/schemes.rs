//! Wiring of allocation policies into the Monte-Carlo engine.
//!
//! The engine is policy-agnostic: it needs an [`Allocation`], a
//! [`DecodeRule`] to pick the order-statistic sampler, and a display name —
//! exactly the [`Policy`] trait. [`simulate_policy`] is the primary entry
//! point; the [`Scheme`] enum survives as a `Copy` convenience for code
//! that enumerates the paper's evaluation set, and delegates everything to
//! its [`Policy`] object.

use crate::allocation::{
    Allocation, DecodeRule, GroupCodePolicy, Policy, ProposedPolicy,
    ReisizadehPolicy, UncodedPolicy, UniformOptimalNPolicy, UniformRatePolicy,
};
use crate::model::{ClusterSpec, LatencyModel};
use crate::sim::{latency_any_k, latency_per_group, SimConfig};
use crate::Result;

/// A named end-to-end scheme from the paper's evaluation — the `Copy`
/// value-type view of the policy set. Each variant denotes one
/// [`Policy`] object ([`Scheme::policy`]); new policies beyond the paper's
/// evaluation set need **no** variant here — implement [`Policy`] and add
/// a registry line ([`crate::allocation::policy::REGISTRY`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Scheme {
    /// Proposed allocation (Theorem 2 / Corollary 2) with its `(n*, k)` code.
    Proposed,
    /// Rate-1 uniform allocation; every worker must finish.
    Uncoded,
    /// Uniform allocation using the optimal code length `n*` from Theorem 2.
    UniformWithOptimalN,
    /// Uniform allocation with an explicit rate `k/n`.
    UniformRate(f64),
    /// Fixed-`r` group code of [33] (simulated group-wise decode).
    GroupCode(f64),
    /// Load allocation of [32] (Appendix D).
    Reisizadeh,
}

impl Scheme {
    /// The [`Policy`] object this scheme denotes. Parameter validation
    /// happens when the policy allocates (invalid rates/`r` surface as
    /// `InvalidSpec`), matching the registry-built objects exactly.
    pub fn policy(&self) -> Box<dyn Policy> {
        match *self {
            Scheme::Proposed => Box::new(ProposedPolicy),
            Scheme::Uncoded => Box::new(UncodedPolicy),
            Scheme::UniformWithOptimalN => Box::new(UniformOptimalNPolicy),
            Scheme::UniformRate(rate) => Box::new(UniformRatePolicy { rate }),
            Scheme::GroupCode(r) => Box::new(GroupCodePolicy { r }),
            Scheme::Reisizadeh => Box::new(ReisizadehPolicy),
        }
    }

    /// Stable display name used in figures and CSV output (delegates to
    /// [`Policy::name`]).
    pub fn name(&self) -> String {
        self.policy().name()
    }
}

/// Outcome of simulating one scheme on one cluster.
#[derive(Clone, Debug)]
pub struct SchemeResult {
    /// Scheme display name.
    pub scheme: String,
    /// Monte-Carlo mean latency.
    pub mean: f64,
    /// Standard error of the mean.
    pub stderr: f64,
    /// Analytic bound, when the policy defines one (`T*`, `1/r`, …).
    pub bound: Option<f64>,
    /// Code rate `k/n` actually used.
    pub rate: f64,
    /// Real-valued code length.
    pub n: f64,
}

/// The [`Allocation`] a scheme induces on `spec` — the policy half of
/// [`simulate_scheme`], reused by the workload layer to build per-job
/// service-time samplers.
pub fn scheme_allocation(
    spec: &ClusterSpec,
    scheme: Scheme,
    model: LatencyModel,
) -> Result<Allocation> {
    scheme.policy().allocate(model, spec)
}

/// Simulate any [`Policy`] on `spec` under `model`: allocate, pick the
/// order-statistic sampler from the policy's [`DecodeRule`], and run the
/// Monte-Carlo engine. This is how `simulate --scheme` and the figure
/// harness evaluate registry-resolved policies.
pub fn simulate_policy(
    spec: &ClusterSpec,
    policy: &dyn Policy,
    model: LatencyModel,
    cfg: &SimConfig,
) -> Result<SchemeResult> {
    let k = spec.k as f64;
    let a = policy.allocate(model, spec)?;
    let s = match policy.decode_rule() {
        DecodeRule::PerGroup => latency_per_group(spec, &a.loads, &a.r, model, cfg)?,
        DecodeRule::AnyK => latency_any_k(spec, &a.loads, model, cfg)?,
    };
    // Only the policies for which the paper derives a latency expression
    // report a bound (`T*` for the proposed optimum, `1/r` for the group
    // code); the rest are simulation-only baselines.
    let bound = if policy.reports_bound() { a.latency_bound } else { None };
    Ok(SchemeResult {
        scheme: policy.name(),
        mean: s.mean(),
        stderr: s.stderr(),
        bound,
        rate: k / a.n,
        n: a.n,
    })
}

/// Simulate `scheme` on `spec` under `model` ([`simulate_policy`] over the
/// scheme's [`Policy`] object).
pub fn simulate_scheme(
    spec: &ClusterSpec,
    scheme: Scheme,
    model: LatencyModel,
    cfg: &SimConfig,
) -> Result<SchemeResult> {
    simulate_policy(spec, &*scheme.policy(), model, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SimConfig {
        SimConfig { samples: 3_000, seed: 123, threads: 2 }
    }

    #[test]
    fn proposed_achieves_its_bound_at_scale() {
        // Theorem 3: λ_{r:N} → T* as N → ∞. At N=2500 the gap should be
        // small (a few percent).
        let spec = ClusterSpec::paper_five_group(2500, 10_000);
        let r = simulate_scheme(&spec, Scheme::Proposed, LatencyModel::A, &cfg()).unwrap();
        let bound = r.bound.unwrap();
        assert!(r.mean >= bound * 0.999, "mean {} below bound {bound}", r.mean);
        assert!(
            (r.mean - bound) / bound < 0.10,
            "gap too large: mean {} vs bound {bound}",
            r.mean
        );
    }

    #[test]
    fn proposed_beats_uniform_and_uncoded() {
        let spec = ClusterSpec::paper_five_group(2500, 10_000);
        let p = simulate_scheme(&spec, Scheme::Proposed, LatencyModel::A, &cfg()).unwrap();
        let u = simulate_scheme(&spec, Scheme::UniformWithOptimalN, LatencyModel::A, &cfg())
            .unwrap();
        let unc = simulate_scheme(&spec, Scheme::Uncoded, LatencyModel::A, &cfg()).unwrap();
        assert!(p.mean < u.mean, "proposed {} !< uniform {}", p.mean, u.mean);
        assert!(p.mean < unc.mean);
    }

    #[test]
    fn group_code_latency_floors_at_one_over_r() {
        // As N grows with fixed r, the group-code latency converges to 1/r
        // and stops improving — the phenomenon behind Fig. 4.
        let r = 100.0;
        let small = ClusterSpec::paper_five_group(500, 10_000);
        let big = ClusterSpec::paper_five_group(8_000, 10_000);
        let a = simulate_scheme(&small, Scheme::GroupCode(r), LatencyModel::A, &cfg()).unwrap();
        let b = simulate_scheme(&big, Scheme::GroupCode(r), LatencyModel::A, &cfg()).unwrap();
        assert!(b.mean >= 1.0 / r * 0.999, "mean {} below floor", b.mean);
        assert!(b.mean < a.mean);
        // Large-N latency is within 15% of the 1/r floor.
        assert!((b.mean - 0.01) / 0.01 < 0.15, "mean {}", b.mean);
    }

    #[test]
    fn proposed_vastly_beats_group_code_at_large_n() {
        // Fig. 4 headline: ≥10x at large N.
        let spec = ClusterSpec::paper_five_group(10_000, 10_000);
        let p = simulate_scheme(&spec, Scheme::Proposed, LatencyModel::A, &cfg()).unwrap();
        let g = simulate_scheme(&spec, Scheme::GroupCode(100.0), LatencyModel::A, &cfg())
            .unwrap();
        assert!(
            g.mean / p.mean > 5.0,
            "expected large gain, got {}x",
            g.mean / p.mean
        );
    }

    #[test]
    fn reisizadeh_matches_proposed_model_b() {
        let spec = ClusterSpec::paper_three_group_b(1000, 100_000);
        let p = simulate_scheme(&spec, Scheme::Proposed, LatencyModel::B, &cfg()).unwrap();
        let z = simulate_scheme(&spec, Scheme::Reisizadeh, LatencyModel::B, &cfg()).unwrap();
        let tol = 4.0 * (p.stderr + z.stderr);
        assert!((p.mean - z.mean).abs() < tol, "{} vs {}", p.mean, z.mean);
    }

    #[test]
    fn uniform_rate_sweep_is_unimodal_ish() {
        // Fig. 8: there is an interior optimal rate (near 0.52 for the paper's
        // 2-group cluster) — check the ends are worse than the middle.
        let spec = ClusterSpec::paper_two_group(10_000);
        let lo = simulate_scheme(&spec, Scheme::UniformRate(0.35), LatencyModel::A, &cfg())
            .unwrap();
        let mid = simulate_scheme(&spec, Scheme::UniformRate(0.52), LatencyModel::A, &cfg())
            .unwrap();
        let hi = simulate_scheme(&spec, Scheme::UniformRate(0.9), LatencyModel::A, &cfg())
            .unwrap();
        assert!(mid.mean < lo.mean, "mid {} !< lo {}", mid.mean, lo.mean);
        assert!(mid.mean < hi.mean, "mid {} !< hi {}", mid.mean, hi.mean);
    }

    #[test]
    fn scheme_and_registry_policies_agree() {
        // The Scheme enum and the registry must denote the same objects:
        // identical names and identical allocations.
        let spec = ClusterSpec::paper_two_group(10_000);
        let pairs: [(Scheme, &str); 6] = [
            (Scheme::Proposed, "proposed"),
            (Scheme::Uncoded, "uncoded"),
            (Scheme::UniformWithOptimalN, "uniform-nstar"),
            (Scheme::UniformRate(0.5), "uniform-rate=0.5"),
            (Scheme::GroupCode(100.0), "group-code=100"),
            (Scheme::Reisizadeh, "reisizadeh"),
        ];
        for (scheme, spec_str) in pairs {
            let reg = crate::allocation::policy::resolve(spec_str).unwrap();
            assert_eq!(scheme.name(), reg.name(), "{spec_str}");
            let a = scheme_allocation(&spec, scheme, LatencyModel::A).unwrap();
            let b = reg.allocate(LatencyModel::A, &spec).unwrap();
            assert_eq!(a.loads, b.loads, "{spec_str}");
            assert_eq!(a.n, b.n, "{spec_str}");
        }
    }
}
