//! Deterministic pseudo-random number generation.
//!
//! The environment vendors no `rand` crate, so we implement
//! **xoshiro256++** (Blackman & Vigna) seeded through **SplitMix64** —
//! the standard, well-tested construction. All Monte-Carlo results in the
//! repo are reproducible from a fixed seed.

/// xoshiro256++ generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `(0, 1]` (never exactly zero; safe for `ln`).
    #[inline]
    pub fn next_f64_open(&mut self) -> f64 {
        1.0 - self.next_f64()
    }

    /// Standard exponential variate (rate 1) by inversion (one `ln` per
    /// draw). Kept as the reference implementation; the hot paths use the
    /// ziggurat sampler [`Rng::exp1`].
    #[inline]
    pub fn exp1_inversion(&mut self) -> f64 {
        -self.next_f64_open().ln()
    }

    /// Standard exponential variate via the Marsaglia–Tsang ziggurat
    /// (§Perf iteration 2): ~98% of draws cost one u64 + one table compare,
    /// no transcendental. Falls back to `ln` only in the wedge/tail.
    #[inline]
    pub fn exp1(&mut self) -> f64 {
        let tables = zig_tables();
        loop {
            let bits = self.next_u64();
            let i = (bits & 0xFF) as usize;
            // 53-bit uniform in [0,1).
            let u = (bits >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
            let x = u * tables.x[i];
            if x < tables.x[i + 1] {
                return x; // inside the layer: fast path
            }
            if i == 0 {
                // Tail beyond R: memoryless restart shifted by R.
                return ZIG_R + self.exp1_inversion();
            }
            // Wedge: accept against the true density.
            let f_hi = tables.f[i];
            let f_lo = tables.f[i + 1];
            if f_lo + (f_hi - f_lo) * self.next_f64() < (-x).exp() {
                return x;
            }
        }
    }

    /// Exponential variate with rate `mu`.
    #[inline]
    pub fn exp(&mut self, mu: f64) -> f64 {
        debug_assert!(mu > 0.0);
        self.exp1() / mu
    }

    /// Standard normal via Box–Muller (used only in asymptotics tests).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64_open();
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Uniform integer in `[0, bound)` (Lemire rejection-free-ish reduction).
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        // Widening multiply; bias is negligible for our bounds (< 2^32).
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Split off an independent child generator (for per-thread streams).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

/// Ziggurat cutoff for the 256-layer exponential tables.
const ZIG_R: f64 = 7.697_117_470_131_487;
/// Common layer area `V` for the 256-layer exponential ziggurat.
const ZIG_V: f64 = 3.949_659_822_581_572e-3;

struct ZigTables {
    /// Layer x-coordinates, `x[0] = V·e^R` (virtual base), `x[256] = 0`.
    x: [f64; 257],
    /// `f[i] = exp(-x[i])`.
    f: [f64; 257],
}

fn zig_tables() -> &'static ZigTables {
    static TABLES: std::sync::OnceLock<ZigTables> = std::sync::OnceLock::new();
    TABLES.get_or_init(|| {
        let mut x = [0.0f64; 257];
        x[0] = ZIG_V * ZIG_R.exp(); // V / f(R)
        x[1] = ZIG_R;
        for i in 2..256 {
            // Next layer boundary: f(x_i) = f(x_{i-1}) + V / x_{i-1}.
            x[i] = -(ZIG_V / x[i - 1] + (-x[i - 1]).exp()).ln();
        }
        x[256] = 0.0;
        let mut f = [0.0f64; 257];
        for i in 0..257 {
            f[i] = (-x[i]).exp();
        }
        ZigTables { x, f }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut rng = Rng::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.next_f64();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn exponential_moments() {
        let mut rng = Rng::new(11);
        let n = 200_000;
        let mu = 2.5;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.exp(mu);
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!((mean - 1.0 / mu).abs() < 0.01);
        assert!((var - 1.0 / (mu * mu)).abs() < 0.02);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(13);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s += x;
            s2 += x * x;
        }
        assert!((s / n as f64).abs() < 0.01);
        assert!((s2 / n as f64 - 1.0).abs() < 0.02);
    }

    #[test]
    fn ziggurat_tables_are_consistent() {
        let t = zig_tables();
        // Monotone decreasing layer boundaries.
        for i in 1..256 {
            assert!(t.x[i] > t.x[i + 1], "x not decreasing at {i}");
        }
        // Every layer has (approximately) the common area V:
        // x_i * (f(x_{i+1}) - f(x_i)) = V.
        for i in 1..255 {
            let area = t.x[i] * (t.f[i + 1] - t.f[i]);
            assert!(
                (area - ZIG_V).abs() < 1e-12,
                "layer {i} area {area} != V"
            );
        }
        // Base layer: x_1*f(x_1) + tail area = V.
        let tail = (-ZIG_R as f64).exp(); // ∫_R^∞ e^-x dx = e^-R
        let base = t.x[1] * t.f[1] + tail;
        assert!((base - ZIG_V).abs() < 1e-12, "base area {base}");
    }

    #[test]
    fn ziggurat_matches_inversion_distribution() {
        // Compare empirical CDF of the ziggurat sampler against the exact
        // exponential CDF at several quantiles, plus first two moments.
        let mut rng = Rng::new(31);
        let n = 400_000;
        let mut xs: Vec<f64> = Vec::with_capacity(n);
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.exp1();
            s += x;
            s2 += x * x;
            xs.push(x);
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!((mean - 1.0).abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
        xs.sort_by(f64::total_cmp);
        for q in [0.1, 0.5, 0.9, 0.99, 0.999] {
            let emp = xs[(q * n as f64) as usize];
            let exact = -(1.0f64 - q).ln();
            assert!(
                (emp - exact).abs() < 0.05 * exact.max(0.2),
                "quantile {q}: {emp} vs {exact}"
            );
        }
        // Tail beyond R must be populated (memoryless restart works).
        assert!(*xs.last().unwrap() > ZIG_R * 0.9);
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut rng = Rng::new(17);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.gen_range(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(19);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn split_streams_are_independent() {
        let mut parent = Rng::new(23);
        let mut c1 = parent.split();
        let mut c2 = parent.split();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(same, 0);
    }
}
