//! Special functions: error function and the standard normal CDF.
//!
//! Needed by the CLT-based analytic latency estimator
//! ([`crate::model::analytic`]). Implemented from scratch (no numerics
//! crates): Taylor series for small arguments, a Lentz continued fraction
//! for the complementary tail.

/// Error function `erf(x)`.
pub fn erf(x: f64) -> f64 {
    1.0 - erfc(x)
}

/// Complementary error function `erfc(x)`; relative error ≲ 1e-13 over the
/// range the estimator uses.
pub fn erfc(x: f64) -> f64 {
    if x < 0.0 {
        return 2.0 - erfc(-x);
    }
    if x > 27.0 {
        return 0.0; // underflows f64
    }
    if x < 0.5 {
        // Taylor: erf(x) = 2/√π Σ (-1)^n x^{2n+1} / (n! (2n+1)).
        let mut sum = x;
        let mut pow = x;
        let mut fact = 1.0;
        for n in 1..60 {
            pow *= x * x;
            fact *= n as f64;
            let c = pow / (fact * (2 * n + 1) as f64);
            if n % 2 == 1 {
                sum -= c;
            } else {
                sum += c;
            }
            if c.abs() < 1e-18 {
                break;
            }
        }
        return 1.0 - std::f64::consts::FRAC_2_SQRT_PI * sum;
    }
    // Classic continued fraction (DLMF 7.9.4), evaluated backward:
    //   erfc(x) = e^{-x²}/√π · 1/(x + (1/2)/(x + 1/(x + (3/2)/(x + 2/(x+…)))))
    // with numerators n/2. Fixed depth is ample for x ≥ 0.5.
    let depth = if x < 2.0 { 400 } else { 80 };
    let mut t = x;
    for n in (1..=depth).rev() {
        t = x + (n as f64 / 2.0) / t;
    }
    (-x * x).exp() / (t * std::f64::consts::PI.sqrt())
}

/// Standard normal CDF `Φ(x)`.
pub fn normal_cdf(x: f64) -> f64 {
    0.5 * erfc(-x / std::f64::consts::SQRT_2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_reference_values() {
        // scipy.special.erf references.
        let cases = [
            (0.0, 0.0),
            (0.1, 0.1124629160182849),
            (0.5, 0.5204998778130465),
            (1.0, 0.8427007929497149),
            (2.0, 0.9953222650189527),
            (3.0, 0.9999779095030014),
        ];
        for (x, want) in cases {
            let got = erf(x);
            assert!((got - want).abs() < 1e-10, "erf({x}) = {got} want {want}");
            assert!((erf(-x) + want).abs() < 1e-10, "odd symmetry at {x}");
        }
    }

    #[test]
    fn erfc_tail() {
        assert!((erfc(4.0) - 1.541725790028002e-8).abs() < 1e-16);
        assert!((erfc(6.0) - 2.1519736712498913e-17).abs() < 1e-24);
        assert_eq!(erfc(30.0), 0.0);
    }

    #[test]
    fn normal_cdf_reference_values() {
        let cases = [
            (0.0, 0.5),
            (1.0, 0.8413447460685429),
            (-1.0, 0.15865525393145707),
            (1.959963984540054, 0.975),
            (-3.0, 0.0013498980316300933),
        ];
        for (x, want) in cases {
            let got = normal_cdf(x);
            assert!((got - want).abs() < 1e-9, "Phi({x}) = {got} want {want}");
        }
    }

    #[test]
    fn cdf_monotone_and_bounded() {
        let mut prev = 0.0;
        for i in -600..=600 {
            let x = i as f64 / 100.0;
            let p = normal_cdf(x);
            assert!((0.0..=1.0).contains(&p));
            assert!(p >= prev - 1e-15, "not monotone at {x}");
            prev = p;
        }
    }

    #[test]
    fn erf_erfc_complementarity() {
        for i in 0..100 {
            let x = -5.0 + 0.1 * i as f64;
            assert!((erf(x) + erfc(x) - 1.0).abs() < 1e-13, "x={x}");
        }
    }
}
