//! Summary statistics for Monte-Carlo estimates and benchmarks.

use std::sync::OnceLock;

/// Online (Welford) accumulator with percentile support on demand.
///
/// Percentiles require sample retention ([`Summary::keeping_samples`]).
/// The sorted view is computed once and cached; `add`/`merge` invalidate
/// it, so a p50/p95/p99 report triple reads one sort, not three.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    samples: Vec<f64>,
    keep_samples: bool,
    /// Lazily sorted copy of `samples`; rebuilt after any mutation.
    sorted: OnceLock<Vec<f64>>,
}

impl Summary {
    /// New accumulator that keeps raw samples (enables percentiles).
    pub fn keeping_samples() -> Self {
        Summary {
            keep_samples: true,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            ..Default::default()
        }
    }

    /// New accumulator without sample retention (O(1) memory).
    pub fn new() -> Self {
        Summary {
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            ..Default::default()
        }
    }

    /// Add one observation.
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        if self.keep_samples {
            self.samples.push(x);
            self.sorted = OnceLock::new();
        }
    }

    /// Are percentiles available — i.e. do the retained samples cover every
    /// observation? `false` after merging in a summary that did not retain
    /// its samples (percentiles over a subset would silently lie).
    pub fn keeps_samples(&self) -> bool {
        self.keep_samples
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sample mean.
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Unbiased sample variance.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Standard error of the mean.
    pub fn stderr(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.stddev() / (self.n as f64).sqrt()
        }
    }

    /// Minimum observation.
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Maximum observation.
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Percentile in `[0, 100]` (nearest-rank on sorted retained samples).
    ///
    /// The sorted vector is built on first use and cached until the next
    /// `add`/`merge`, so repeated percentile reads cost one sort total
    /// (bit-identical to sorting per call: same multiset, same rank rule).
    ///
    /// Panics if samples were not retained (see [`Summary::keeps_samples`]).
    pub fn percentile(&self, p: f64) -> f64 {
        assert!(
            self.keep_samples,
            "Summary percentiles need sample retention \
             (built without, or merged with a non-retaining summary)"
        );
        assert!(!self.samples.is_empty());
        let xs = self.sorted.get_or_init(|| {
            let mut xs = self.samples.clone();
            // total_cmp: same order as partial_cmp on non-NaN data, and
            // cannot panic if a NaN ever slips in.
            xs.sort_by(f64::total_cmp);
            xs
        });
        let rank = ((p / 100.0) * (xs.len() as f64 - 1.0)).round() as usize;
        xs[rank.min(xs.len() - 1)]
    }

    /// Median (p50).
    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    /// Merge another accumulator into this one (parallel Welford merge,
    /// Chan et al.). Used to reduce per-thread Monte-Carlo summaries.
    ///
    /// Retention propagates by *coverage*: the merged summary keeps samples
    /// iff every observation in the merged set has a retained sample —
    /// i.e. each non-empty side retained its own. Otherwise the samples are
    /// dropped and `keeps_samples()` turns false (percentiles over a subset
    /// would be silently wrong, and a stale retention flag after absorbing
    /// a non-retaining summary used to panic only much later).
    pub fn merge(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        // `other` is non-empty from here on.
        let covered = (self.n == 0 || self.keep_samples) && other.keep_samples;
        if self.n == 0 {
            // Copy the moment state; retention follows coverage rather than
            // blindly inheriting `other`'s flag.
            *self = other.clone();
            self.keep_samples = covered;
            if !covered {
                self.samples = Vec::new();
            }
            self.sorted = OnceLock::new();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let n = n1 + n2;
        let delta = other.mean - self.mean;
        self.mean += delta * n2 / n;
        self.m2 += other.m2 + delta * delta * n1 * n2 / n;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        if covered {
            self.samples.extend_from_slice(&other.samples);
        } else {
            self.keep_samples = false;
            self.samples = Vec::new();
        }
        self.sorted = OnceLock::new();
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.6e} ± {:.2e} (min {:.3e}, max {:.3e})",
            self.n,
            self.mean(),
            self.stderr(),
            self.min,
            self.max
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_variance_exact() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // Unbiased variance of this classic dataset is 32/7.
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn percentiles() {
        let mut s = Summary::keeping_samples();
        for i in 1..=100 {
            s.add(i as f64);
        }
        assert!((s.median() - 50.0).abs() <= 1.0);
        assert!((s.percentile(95.0) - 95.0).abs() <= 1.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
    }

    #[test]
    fn stderr_shrinks() {
        let mut small = Summary::new();
        let mut big = Summary::new();
        let mut rng = crate::math::Rng::new(5);
        for i in 0..10_000 {
            let x = rng.next_f64();
            if i < 100 {
                small.add(x);
            }
            big.add(x);
        }
        assert!(big.stderr() < small.stderr());
    }

    #[test]
    fn merge_matches_sequential() {
        let mut rng = crate::math::Rng::new(21);
        let xs: Vec<f64> = (0..10_000).map(|_| rng.normal()).collect();
        let mut seq = Summary::new();
        for &x in &xs {
            seq.add(x);
        }
        let mut a = Summary::new();
        let mut b = Summary::new();
        for &x in &xs[..3_000] {
            a.add(x);
        }
        for &x in &xs[3_000..] {
            b.add(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), seq.count());
        assert!((a.mean() - seq.mean()).abs() < 1e-12);
        assert!((a.variance() - seq.variance()).abs() < 1e-10);
        assert_eq!(a.min(), seq.min());
        assert_eq!(a.max(), seq.max());
    }

    #[test]
    fn merge_with_empty_is_identity() {
        let mut a = Summary::new();
        a.add(1.0);
        a.add(2.0);
        let before = (a.count(), a.mean(), a.variance());
        a.merge(&Summary::new());
        assert_eq!(before, (a.count(), a.mean(), a.variance()));
        let mut empty = Summary::new();
        empty.merge(&a);
        assert_eq!(empty.count(), 2);
        assert!((empty.mean() - 1.5).abs() < 1e-15);
    }

    #[test]
    #[should_panic]
    fn percentile_requires_retention() {
        let mut s = Summary::new();
        s.add(1.0);
        s.percentile(50.0);
    }

    #[test]
    fn merge_keeps_full_sample_set_when_both_retain() {
        // Regression: percentiles after a merge must see *every* sample,
        // not just one side's.
        let mut a = Summary::keeping_samples();
        let mut b = Summary::keeping_samples();
        for i in 1..=50 {
            a.add(i as f64);
        }
        for i in 51..=100 {
            b.add(i as f64);
        }
        a.merge(&b);
        assert!(a.keeps_samples());
        assert_eq!(a.percentile(100.0), 100.0);
        assert_eq!(a.percentile(0.0), 1.0);
        assert!((a.median() - 50.0).abs() <= 1.0);
    }

    #[test]
    fn merge_with_non_retaining_side_drops_retention_explicitly() {
        // Regression: merging a non-retaining summary used to leave the
        // retention flag true with a silent subset of samples.
        let mut keep = Summary::keeping_samples();
        keep.add(1.0);
        keep.add(2.0);
        let mut plain = Summary::new();
        plain.add(10.0);
        keep.merge(&plain);
        assert!(!keep.keeps_samples(), "subset percentiles must be refused");
        assert_eq!(keep.count(), 3);
        assert!((keep.mean() - 13.0 / 3.0).abs() < 1e-12);
        assert_eq!(keep.max(), 10.0);
    }

    #[test]
    fn merge_into_empty_propagates_retention_by_coverage() {
        // Regression: the `self.n == 0` branch cloned `other` wholesale,
        // clobbering the retention state. An empty accumulator absorbing a
        // retaining one covers all observations, so percentiles work; an
        // empty *retaining* accumulator absorbing a non-retaining one
        // cannot, so `keeps_samples` must turn false.
        let mut full = Summary::keeping_samples();
        for i in 1..=10 {
            full.add(i as f64);
        }
        let mut empty = Summary::new();
        empty.merge(&full);
        assert!(empty.keeps_samples());
        assert_eq!(empty.percentile(100.0), 10.0);

        let mut plain = Summary::new();
        plain.add(5.0);
        let mut empty_keeping = Summary::keeping_samples();
        empty_keeping.merge(&plain);
        assert!(!empty_keeping.keeps_samples());
        assert_eq!(empty_keeping.count(), 1);
    }

    #[test]
    fn percentile_cache_invalidated_by_add_and_merge() {
        let mut s = Summary::keeping_samples();
        for i in 1..=9 {
            s.add(i as f64);
        }
        assert_eq!(s.percentile(100.0), 9.0); // builds the cache
        s.add(100.0);
        assert_eq!(s.percentile(100.0), 100.0); // add invalidated it
        let mut t = Summary::keeping_samples();
        t.add(200.0);
        s.merge(&t);
        assert_eq!(s.percentile(100.0), 200.0); // merge invalidated it
        assert_eq!(s.percentile(0.0), 1.0);
    }

    #[test]
    fn cached_percentiles_match_fresh_sort() {
        // Bit-identical: repeated reads through the cache equal a freshly
        // built summary's first read, across a spread of percentiles.
        let mut rng = crate::math::Rng::new(17);
        let xs: Vec<f64> = (0..1_000).map(|_| rng.normal()).collect();
        let mut a = Summary::keeping_samples();
        for &x in &xs {
            a.add(x);
        }
        let probes = [0.0, 10.0, 50.0, 90.0, 95.0, 99.0, 100.0];
        let first: Vec<f64> = probes.iter().map(|&p| a.percentile(p)).collect();
        let again: Vec<f64> = probes.iter().map(|&p| a.percentile(p)).collect();
        assert_eq!(first, again);
        let mut b = Summary::keeping_samples();
        for &x in &xs {
            b.add(x);
        }
        for (&p, &v) in probes.iter().zip(&first) {
            assert_eq!(b.percentile(p), v, "p{p}");
        }
    }
}
