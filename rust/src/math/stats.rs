//! Summary statistics for Monte-Carlo estimates and benchmarks.

/// Online (Welford) accumulator with percentile support on demand.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    samples: Vec<f64>,
    keep_samples: bool,
}

impl Summary {
    /// New accumulator that keeps raw samples (enables percentiles).
    pub fn keeping_samples() -> Self {
        Summary {
            keep_samples: true,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            ..Default::default()
        }
    }

    /// New accumulator without sample retention (O(1) memory).
    pub fn new() -> Self {
        Summary {
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            ..Default::default()
        }
    }

    /// Add one observation.
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        if self.keep_samples {
            self.samples.push(x);
        }
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sample mean.
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Unbiased sample variance.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Standard error of the mean.
    pub fn stderr(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.stddev() / (self.n as f64).sqrt()
        }
    }

    /// Minimum observation.
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Maximum observation.
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Percentile in `[0, 100]` (nearest-rank on sorted retained samples).
    ///
    /// Panics if samples were not retained.
    pub fn percentile(&self, p: f64) -> f64 {
        assert!(self.keep_samples, "Summary built without sample retention");
        assert!(!self.samples.is_empty());
        let mut xs = self.samples.clone();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (xs.len() as f64 - 1.0)).round() as usize;
        xs[rank.min(xs.len() - 1)]
    }

    /// Median (p50).
    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    /// Merge another accumulator into this one (parallel Welford merge,
    /// Chan et al.). Used to reduce per-thread Monte-Carlo summaries.
    pub fn merge(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let n = n1 + n2;
        let delta = other.mean - self.mean;
        self.mean += delta * n2 / n;
        self.m2 += other.m2 + delta * delta * n1 * n2 / n;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        if self.keep_samples {
            self.samples.extend_from_slice(&other.samples);
        }
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.6e} ± {:.2e} (min {:.3e}, max {:.3e})",
            self.n,
            self.mean(),
            self.stderr(),
            self.min,
            self.max
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_variance_exact() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // Unbiased variance of this classic dataset is 32/7.
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn percentiles() {
        let mut s = Summary::keeping_samples();
        for i in 1..=100 {
            s.add(i as f64);
        }
        assert!((s.median() - 50.0).abs() <= 1.0);
        assert!((s.percentile(95.0) - 95.0).abs() <= 1.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
    }

    #[test]
    fn stderr_shrinks() {
        let mut small = Summary::new();
        let mut big = Summary::new();
        let mut rng = crate::math::Rng::new(5);
        for i in 0..10_000 {
            let x = rng.next_f64();
            if i < 100 {
                small.add(x);
            }
            big.add(x);
        }
        assert!(big.stderr() < small.stderr());
    }

    #[test]
    fn merge_matches_sequential() {
        let mut rng = crate::math::Rng::new(21);
        let xs: Vec<f64> = (0..10_000).map(|_| rng.normal()).collect();
        let mut seq = Summary::new();
        for &x in &xs {
            seq.add(x);
        }
        let mut a = Summary::new();
        let mut b = Summary::new();
        for &x in &xs[..3_000] {
            a.add(x);
        }
        for &x in &xs[3_000..] {
            b.add(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), seq.count());
        assert!((a.mean() - seq.mean()).abs() < 1e-12);
        assert!((a.variance() - seq.variance()).abs() < 1e-10);
        assert_eq!(a.min(), seq.min());
        assert_eq!(a.max(), seq.max());
    }

    #[test]
    fn merge_with_empty_is_identity() {
        let mut a = Summary::new();
        a.add(1.0);
        a.add(2.0);
        let before = (a.count(), a.mean(), a.variance());
        a.merge(&Summary::new());
        assert_eq!(before, (a.count(), a.mean(), a.variance()));
        let mut empty = Summary::new();
        empty.merge(&a);
        assert_eq!(empty.count(), 2);
        assert!((empty.mean() - 1.5).abs() < 1e-15);
    }

    #[test]
    #[should_panic]
    fn percentile_requires_retention() {
        let mut s = Summary::new();
        s.add(1.0);
        s.percentile(50.0);
    }
}
