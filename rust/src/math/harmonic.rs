//! Harmonic numbers and the expected order statistics of exponentials.
//!
//! The paper's eq. (6) derives the expected `r`-th order statistic of `N`
//! shifted exponentials via `H_N - H_{N-r}` and then uses the approximation
//! `H_N - H_{N-r} ≈ log(N / (N - r))`. Both forms are provided; the figure
//! harness uses the exact harmonic form for finite-N analytic curves and the
//! log form where the paper does.

/// Euler–Mascheroni constant.
pub const EULER_GAMMA: f64 = 0.577_215_664_901_532_9;

/// `H_n = Σ_{i=1..n} 1/i`, exact summation for small `n`, asymptotic
/// expansion (`ln n + γ + 1/2n - 1/12n² + 1/120n⁴`) for large `n`.
pub fn harmonic(n: u64) -> f64 {
    if n == 0 {
        return 0.0;
    }
    if n <= 128 {
        let mut h = 0.0;
        // Sum smallest-first for accuracy.
        for i in (1..=n).rev() {
            h += 1.0 / i as f64;
        }
        return h;
    }
    let x = n as f64;
    x.ln() + EULER_GAMMA + 1.0 / (2.0 * x) - 1.0 / (12.0 * x * x)
        + 1.0 / (120.0 * x * x * x * x)
}

/// The paper's approximation `H_N - H_{N-r} ≈ log(N / (N - r))`.
///
/// Requires `r < N`; `r` may be real-valued (the analysis relaxes integrality).
pub fn harmonic_diff_log_approx(n: f64, r: f64) -> f64 {
    assert!(r < n && r >= 0.0, "need 0 <= r < n, got r={r}, n={n}");
    (n / (n - r)).ln()
}

/// Expected `r`-th order statistic of `N` i.i.d. `Exp(μ)` variables:
/// `(H_N - H_{N-r}) / μ` (exact harmonic form).
pub fn order_stat_exp_mean(n: u64, r: u64, mu: f64) -> f64 {
    assert!(r <= n && r >= 1, "need 1 <= r <= n");
    assert!(mu > 0.0);
    (harmonic(n) - harmonic(n - r)) / mu
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_harmonics_exact() {
        assert_eq!(harmonic(0), 0.0);
        assert_eq!(harmonic(1), 1.0);
        assert!((harmonic(2) - 1.5).abs() < 1e-15);
        assert!((harmonic(4) - (1.0 + 0.5 + 1.0 / 3.0 + 0.25)).abs() < 1e-15);
    }

    #[test]
    fn asymptotic_matches_exact_at_crossover() {
        // Exact sum for n slightly above the crossover vs the expansion.
        let exact: f64 = (1..=200u64).map(|i| 1.0 / i as f64).sum();
        assert!((harmonic(200) - exact).abs() < 1e-12);
        let exact128: f64 = (1..=128u64).map(|i| 1.0 / i as f64).sum();
        let exact129 = exact128 + 1.0 / 129.0;
        assert!((harmonic(129) - exact129).abs() < 1e-12);
    }

    #[test]
    fn log_approx_quality() {
        // The approximation error H_N - H_{N-r} vs log(N/(N-r)) is O(r/(N(N-r))).
        let n = 1000u64;
        let r = 500u64;
        let exact = harmonic(n) - harmonic(n - r);
        let approx = harmonic_diff_log_approx(n as f64, r as f64);
        assert!((exact - approx).abs() < 1e-3, "{exact} vs {approx}");
    }

    #[test]
    fn order_stat_exp_known_values() {
        // Max of N exponentials: E = H_N / μ.
        let e = order_stat_exp_mean(10, 10, 2.0);
        assert!((e - harmonic(10) / 2.0).abs() < 1e-15);
        // Min of N exponentials: E = 1/(N μ).
        let e = order_stat_exp_mean(10, 1, 1.0);
        assert!((e - 0.1).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn log_approx_domain_panics() {
        harmonic_diff_log_approx(10.0, 10.0);
    }
}
