//! Real branches of the Lambert W function.
//!
//! The paper's optimal allocation (Theorem 2) is expressed through the lower
//! branch `W_{-1}` evaluated at `z_j = -exp(-(α_j μ_j + 1)) ∈ [-1/e, 0)`.
//! Two numerically delicate regimes matter for the reproduction:
//!
//! - `α μ → 0` pushes `z → -1/e` (the branch point, where both branches meet
//!   at `W = -1` and the derivative blows up). We switch to the branch-point
//!   series in `p = sqrt(2 (1 + e z))`.
//! - `α μ` large (the paper evaluates up to `μ < 750`) underflows
//!   `exp(-(αμ+1))` to `0.0` in f64. [`wm1_neg_exp`] therefore solves the
//!   *log-form* equation `w + log(-w) = -t` for `w = W_{-1}(-e^{-t})`
//!   directly, which never forms the underflowing argument.
//!
//! References for the constants/series: Corless et al., "On the Lambert W
//! function", Adv. Comput. Math. 5 (1996).

/// Machine-precision tolerance used for Halley iterations.
const TOL: f64 = 1e-14;
const MAX_ITER: usize = 64;

/// Principal branch `W_0(x)` for `x >= -1/e`.
///
/// Returns `NaN` outside the domain.
pub fn lambert_w0(x: f64) -> f64 {
    let inv_e = (-1.0f64).exp();
    if x < -inv_e - 1e-15 || x.is_nan() {
        return f64::NAN;
    }
    if x.abs() < 1e-300 {
        return 0.0;
    }
    // Initial guess.
    let mut w = if x < -0.25 {
        // Branch-point series: W0 = -1 + p - p^2/3 + 11 p^3/72 ...
        let p = (2.0 * (1.0 + std::f64::consts::E * x)).max(0.0).sqrt();
        -1.0 + p - p * p / 3.0 + 11.0 * p * p * p / 72.0
    } else if x < std::f64::consts::E {
        // ln(1+x) tracks W0 well on (-0.25, e) and Halley polishes it.
        x.ln_1p()
    } else {
        // Asymptotic: log(x) - log(log(x)).
        let l1 = x.ln();
        let l2 = l1.ln();
        l1 - l2 + l2 / l1
    };
    halley(x, &mut w);
    w
}

/// Lower branch `W_{-1}(x)` for `x ∈ [-1/e, 0)`.
///
/// Returns `NaN` outside the domain. `W_{-1}(-1/e) = -1`,
/// `W_{-1}(x) → -∞` as `x → 0⁻`.
pub fn lambert_wm1(x: f64) -> f64 {
    let inv_e = (-1.0f64).exp();
    if x >= 0.0 || x < -inv_e - 1e-15 || x.is_nan() {
        return f64::NAN;
    }
    // 1 + e*x ∈ [0, 1); p → 0 at the branch point.
    let q = 1.0 + std::f64::consts::E * x;
    if q <= 0.0 {
        return -1.0;
    }
    let p = (2.0 * q).sqrt();
    if p < 1e-5 {
        // Branch-point series, lower sign: W_{-1} = -1 - p - p^2/3 - 11p^3/72.
        return -1.0 - p - p * p / 3.0 - 11.0 * p * p * p / 72.0;
    }
    let mut w = if x < -0.1 {
        // Moderate region: seed from the series and polish.
        -1.0 - p - p * p / 3.0 - 11.0 * p * p * p / 72.0
    } else {
        // Near zero: asymptotic W_{-1}(x) ≈ log(-x) - log(-log(-x)).
        let l1 = (-x).ln();
        let l2 = (-l1).ln();
        l1 - l2 + l2 / l1
    };
    halley(x, &mut w);
    w
}

/// `W_{-1}(-e^{-t})` for `t >= 1`, computed entirely in log space.
///
/// This is the exact quantity the paper's allocation formulas need with
/// `t = α_j μ_j + 1`; it stays finite and accurate even when `e^{-t}`
/// underflows (`t ≳ 745`). Solves `w + log(-w) + t = 0` by Newton with a
/// branch-point series fallback near `t = 1`.
pub fn wm1_neg_exp(t: f64) -> f64 {
    assert!(t >= 1.0 - 1e-12, "wm1_neg_exp requires t >= 1, got {t}");
    if t <= 1.0 {
        return -1.0;
    }
    // Near the branch point (t -> 1+): z = -e^{-t}, 1 + e z = 1 - e^{1-t}.
    let q = -(1.0 - t).exp_m1(); // 1 - e^{1-t}, accurate for small t-1
    let p = (2.0 * q).sqrt();
    let mut w = if p < 1e-5 {
        return -1.0 - p - p * p / 3.0 - 11.0 * p * p * p / 72.0;
    } else if t < 2.0 {
        -1.0 - p - p * p / 3.0 - 11.0 * p * p * p / 72.0
    } else {
        // Asymptotic: w ≈ -t - log(t).
        -t - t.ln()
    };
    // Newton on f(w) = w + ln(-w) + t;  f'(w) = 1 + 1/w = (w+1)/w.
    for _ in 0..MAX_ITER {
        let f = w + (-w).ln() + t;
        let fp = (w + 1.0) / w;
        let step = f / fp;
        let w_new = w - step;
        // Keep the iterate in the branch domain (w < -1).
        let w_new = if w_new >= -1.0 { (w - 1.0) / 2.0 - 0.5 } else { w_new };
        if (w_new - w).abs() <= TOL * w.abs().max(1.0) {
            return w_new;
        }
        w = w_new;
    }
    w
}

/// Halley's iteration for `w e^w = x`, refining `w` in place.
fn halley(x: f64, w: &mut f64) {
    for _ in 0..MAX_ITER {
        let ew = w.exp();
        let wew = *w * ew;
        let f = wew - x;
        if f == 0.0 {
            return;
        }
        let denom = ew * (*w + 1.0) - (*w + 2.0) * f / (2.0 * *w + 2.0);
        if denom == 0.0 || !denom.is_finite() {
            return;
        }
        let step = f / denom;
        let w_new = *w - step;
        if (w_new - *w).abs() <= TOL * w.abs().max(1e-10) {
            *w = w_new;
            return;
        }
        *w = w_new;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: f64, b: f64, tol: f64) {
        assert!(
            (a - b).abs() <= tol * b.abs().max(1.0),
            "{a} vs {b} (tol {tol})"
        );
    }

    #[test]
    fn w0_reference_values() {
        // Omega constant: W0(1).
        assert_close(lambert_w0(1.0), 0.567_143_290_409_783_8, 1e-13);
        assert_close(lambert_w0(0.0), 0.0, 1e-15);
        assert_close(lambert_w0(std::f64::consts::E), 1.0, 1e-13);
        assert_close(lambert_w0(10.0), 1.745_528_002_740_699, 1e-12);
        // Branch point.
        assert_close(lambert_w0(-(-1.0f64).exp()), -1.0, 1e-6);
    }

    #[test]
    fn wm1_reference_values() {
        // Values cross-checked with scipy.special.lambertw(x, -1).
        assert_close(lambert_wm1(-0.1), -3.577_152_063_957_297, 1e-12);
        assert_close(lambert_wm1(-0.2), -2.542_641_357_773_526, 1e-12);
        assert_close(lambert_wm1(-0.3), -1.781_337_023_421_627, 1e-10);
        // Near the branch point: verify through the defining equation
        // (w e^w = x) rather than a literature constant.
        let w = lambert_wm1(-0.35);
        assert!(w < -1.0);
        assert_close(w * w.exp(), -0.35, 1e-10);
        assert_close(lambert_wm1(-(-1.0f64).exp()), -1.0, 1e-6);
        assert!(lambert_wm1(-1e-8) < -20.0);
    }

    #[test]
    fn wm1_domain() {
        assert!(lambert_wm1(0.1).is_nan());
        assert!(lambert_wm1(-0.4).is_nan()); // below -1/e ≈ -0.3679
        assert!(lambert_w0(-0.4).is_nan());
    }

    #[test]
    fn wm1_satisfies_defining_equation() {
        // Property: W e^W = x across the domain.
        for i in 1..=360 {
            let x = -0.001 * i as f64 / std::f64::consts::E; // in (-1/e, 0)
            let w = lambert_wm1(x);
            let back = w * w.exp();
            assert_close(back, x, 1e-9);
        }
    }

    #[test]
    fn w0_satisfies_defining_equation() {
        for i in 0..200 {
            let x = -0.3678 + 0.1 * i as f64;
            let w = lambert_w0(x);
            let back = w * w.exp();
            assert!((back - x).abs() <= 1e-9 * x.abs().max(1.0), "x={x} w={w}");
        }
    }

    #[test]
    fn wm1_neg_exp_matches_direct_eval() {
        // For moderate t both paths must agree.
        for i in 0..100 {
            let t = 1.0 + 0.25 * i as f64;
            let direct = lambert_wm1(-(-t).exp());
            let logform = wm1_neg_exp(t);
            assert_close(logform, direct, 1e-10);
        }
    }

    #[test]
    fn wm1_neg_exp_no_underflow() {
        // t = αμ + 1 with μ = 750 (paper's evaluation ceiling): e^{-751}
        // underflows but the log-form stays accurate: w + ln(-w) = -t.
        let t = 751.0;
        let w = wm1_neg_exp(t);
        assert!(w < -751.0);
        let resid = w + (-w).ln() + t;
        assert!(resid.abs() < 1e-9, "residual {resid}");
    }

    #[test]
    fn wm1_neg_exp_branch_point() {
        assert_close(wm1_neg_exp(1.0), -1.0, 1e-12);
        // t = 1 + 1e-10: series regime, w ≈ -1 - sqrt(2e-10).
        let w = wm1_neg_exp(1.0 + 1e-10);
        assert!(w < -1.0 && w > -1.0001);
    }

    #[test]
    fn wm1_monotone_decreasing_in_t() {
        let mut prev = wm1_neg_exp(1.0);
        for i in 1..500 {
            let t = 1.0 + i as f64 * 0.5;
            let w = wm1_neg_exp(t);
            assert!(w < prev, "not monotone at t={t}");
            prev = w;
        }
    }
}
