//! Numerical substrate: Lambert W, harmonic numbers, RNG, statistics.
//!
//! Everything here is implemented from scratch (the build environment vendors
//! no numerics crates) and unit-tested against published reference values.

#![forbid(unsafe_code)]

pub mod harmonic;
pub mod lambertw;
pub mod rng;
pub mod special;
pub mod stats;

pub use harmonic::{harmonic, harmonic_diff_log_approx, order_stat_exp_mean};
pub use lambertw::{lambert_w0, lambert_wm1, wm1_neg_exp};
pub use rng::Rng;
pub use special::{erf, erfc, normal_cdf};
pub use stats::Summary;
