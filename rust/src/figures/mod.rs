//! Figure harness: regenerates every figure in the paper's evaluation.
//!
//! Each `figN` module produces a [`Figure`] — named series of `(x, y)`
//! points — from the same simulation/analytic code paths the library
//! exposes. The CLI (`hetcoded figures`) writes CSVs and renders ASCII
//! plots; EXPERIMENTS.md records the paper-vs-measured comparison.

#![forbid(unsafe_code)]

pub mod ext_tail;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;

use crate::{Error, Result};

/// One plotted series.
#[derive(Clone, Debug)]
pub struct Series {
    /// Legend label.
    pub name: String,
    /// `(x, y)` points in sweep order.
    pub points: Vec<(f64, f64)>,
}

/// A regenerated figure.
#[derive(Clone, Debug)]
pub struct Figure {
    /// Identifier, e.g. `"fig4"`.
    pub id: String,
    /// Human title matching the paper.
    pub title: String,
    /// Axis labels.
    pub xlabel: String,
    /// Axis labels.
    pub ylabel: String,
    /// Log-scale flags for (x, y).
    pub log: (bool, bool),
    /// The series.
    pub series: Vec<Series>,
}

/// Options shared by all figure generators.
#[derive(Clone, Copy, Debug)]
pub struct FigureOpts {
    /// Monte-Carlo samples per point (paper: 10^4).
    pub samples: usize,
    /// RNG seed.
    pub seed: u64,
    /// Sweep resolution (points per series; generators may clamp).
    pub points: usize,
    /// Simulation threads (0 = auto).
    pub threads: usize,
}

impl Default for FigureOpts {
    fn default() -> Self {
        FigureOpts { samples: 10_000, seed: 2019, points: 12, threads: 0 }
    }
}

impl FigureOpts {
    /// Reduced-cost options for tests/smoke runs.
    pub fn quick() -> Self {
        FigureOpts { samples: 800, seed: 2019, points: 5, threads: 0 }
    }

    pub(crate) fn sim_config(&self) -> crate::sim::SimConfig {
        crate::sim::SimConfig {
            samples: self.samples,
            seed: self.seed,
            threads: self.threads,
        }
    }
}

/// Generate a figure by number (2–9).
pub fn generate(fig: u8, opts: &FigureOpts) -> Result<Figure> {
    match fig {
        2 => fig2::generate(opts),
        3 => fig3::generate(opts),
        4 => fig4::generate(opts),
        5 => fig5::generate(opts),
        6 => fig6::generate(opts),
        7 => fig7::generate(opts),
        8 => fig8::generate(opts),
        9 => fig9::generate(opts),
        // Extension beyond the paper: tail-latency percentiles.
        10 => ext_tail::generate(opts),
        other => Err(Error::InvalidSpec(format!(
            "unknown figure {other} (paper has figures 2-9; 10 = tail extension)"
        ))),
    }
}

/// All figure numbers in the paper's evaluation, plus the tail-latency
/// extension (10).
pub const ALL_FIGURES: [u8; 9] = [2, 3, 4, 5, 6, 7, 8, 9, 10];

impl Figure {
    /// CSV rendering: `x,<series...>` header then one row per x value
    /// (series are re-keyed on x; missing points are empty cells).
    pub fn to_csv(&self) -> String {
        let mut xs: Vec<f64> = self
            .series
            .iter()
            .flat_map(|s| s.points.iter().map(|p| p.0))
            .collect();
        // total_cmp: sweep points are finite by construction; identical
        // order to the old partial_cmp sort, without the NaN panic.
        xs.sort_by(f64::total_cmp);
        xs.dedup_by(|a, b| (*a - *b).abs() < 1e-12 * a.abs().max(1e-300));
        let mut out = String::new();
        out.push_str("x");
        for s in &self.series {
            out.push(',');
            out.push_str(&s.name.replace(',', ";"));
        }
        out.push('\n');
        for &x in &xs {
            out.push_str(&format!("{x:.10e}"));
            for s in &self.series {
                out.push(',');
                if let Some(p) = s
                    .points
                    .iter()
                    .find(|p| (p.0 - x).abs() < 1e-12 * x.abs().max(1e-300))
                {
                    out.push_str(&format!("{:.10e}", p.1));
                }
            }
            out.push('\n');
        }
        out
    }

    /// Write the CSV to `dir/<id>.csv`, creating `dir` if needed.
    pub fn write_csv(&self, dir: &std::path::Path) -> Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.csv", self.id));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }

    /// Terminal ASCII plot (70×22 grid, one marker char per series).
    pub fn ascii_plot(&self) -> String {
        const W: usize = 70;
        const H: usize = 22;
        const MARKS: [char; 8] = ['*', '+', 'o', 'x', '#', '@', '%', '&'];
        let map = |v: f64, log: bool| if log { v.max(1e-300).log10() } else { v };
        let (mut xlo, mut xhi) = (f64::INFINITY, f64::NEG_INFINITY);
        let (mut ylo, mut yhi) = (f64::INFINITY, f64::NEG_INFINITY);
        for s in &self.series {
            for &(x, y) in &s.points {
                let (mx, my) = (map(x, self.log.0), map(y, self.log.1));
                if mx.is_finite() && my.is_finite() {
                    xlo = xlo.min(mx);
                    xhi = xhi.max(mx);
                    ylo = ylo.min(my);
                    yhi = yhi.max(my);
                }
            }
        }
        if !xlo.is_finite() || !ylo.is_finite() {
            return format!("{}: no finite points\n", self.id);
        }
        if (xhi - xlo).abs() < 1e-12 {
            xhi = xlo + 1.0;
        }
        if (yhi - ylo).abs() < 1e-12 {
            yhi = ylo + 1.0;
        }
        let mut grid = vec![vec![' '; W]; H];
        for (si, s) in self.series.iter().enumerate() {
            let mark = MARKS[si % MARKS.len()];
            for &(x, y) in &s.points {
                let (mx, my) = (map(x, self.log.0), map(y, self.log.1));
                if !mx.is_finite() || !my.is_finite() {
                    continue;
                }
                let col = (((mx - xlo) / (xhi - xlo)) * (W - 1) as f64).round() as usize;
                let row = (((my - ylo) / (yhi - ylo)) * (H - 1) as f64).round() as usize;
                grid[H - 1 - row.min(H - 1)][col.min(W - 1)] = mark;
            }
        }
        let mut out = String::new();
        out.push_str(&format!("{} — {}\n", self.id, self.title));
        let scale = |lo: f64, hi: f64, log: bool| {
            if log {
                format!("[1e{lo:.1}, 1e{hi:.1}] (log)")
            } else {
                format!("[{lo:.4}, {hi:.4}]")
            }
        };
        out.push_str(&format!(
            "y: {} = {}\n",
            self.ylabel,
            scale(ylo, yhi, self.log.1)
        ));
        for row in grid {
            out.push('|');
            out.extend(row);
            out.push('\n');
        }
        out.push('+');
        out.extend(std::iter::repeat('-').take(W));
        out.push('\n');
        out.push_str(&format!(
            "x: {} = {}\n",
            self.xlabel,
            scale(xlo, xhi, self.log.0)
        ));
        for (si, s) in self.series.iter().enumerate() {
            out.push_str(&format!("  {} {}\n", MARKS[si % MARKS.len()], s.name));
        }
        out
    }
}

/// Log-spaced sweep values `10^lo .. 10^hi` inclusive.
pub fn logspace(lo: f64, hi: f64, n: usize) -> Vec<f64> {
    assert!(n >= 2);
    (0..n)
        .map(|i| 10f64.powf(lo + (hi - lo) * i as f64 / (n - 1) as f64))
        .collect()
}

/// Linearly spaced sweep values.
pub fn linspace(lo: f64, hi: f64, n: usize) -> Vec<f64> {
    assert!(n >= 2);
    (0..n)
        .map(|i| lo + (hi - lo) * i as f64 / (n - 1) as f64)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_figure() -> Figure {
        Figure {
            id: "test".into(),
            title: "t".into(),
            xlabel: "x".into(),
            ylabel: "y".into(),
            log: (false, false),
            series: vec![
                Series { name: "a".into(), points: vec![(1.0, 2.0), (2.0, 3.0)] },
                Series { name: "b".into(), points: vec![(1.0, 5.0)] },
            ],
        }
    }

    #[test]
    fn csv_has_header_and_rows() {
        let csv = sample_figure().to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "x,a,b");
        assert_eq!(lines.len(), 3);
        assert!(lines[1].contains("2.0000000000e0"));
        // b has no point at x=2 → trailing empty cell.
        assert!(lines[2].ends_with(','));
    }

    #[test]
    fn ascii_plot_renders() {
        let plot = sample_figure().ascii_plot();
        assert!(plot.contains('*'));
        assert!(plot.contains('+'));
        assert!(plot.contains("test"));
    }

    #[test]
    fn spacing_helpers() {
        let l = logspace(-2.0, 1.0, 4);
        assert!((l[0] - 0.01).abs() < 1e-12);
        assert!((l[3] - 10.0).abs() < 1e-9);
        let s = linspace(0.0, 1.0, 3);
        assert_eq!(s, vec![0.0, 0.5, 1.0]);
    }

    #[test]
    fn unknown_figure_rejected() {
        assert!(generate(1, &FigureOpts::quick()).is_err());
        assert!(generate(11, &FigureOpts::quick()).is_err());
    }
}
