//! Fig. 3: MDS rate `k/n*` of the proposed allocation for a fixed group 1
//! (`N₁ = 100, μ₁ = 1, α₁ = 1`) as `(N₂, μ₂)` vary (`α₂ = 1`).
//!
//! The paper highlights that, unlike the single-group case, the rate is
//! **not** monotone increasing in `μ₂`.

use crate::allocation::proposed_allocation;
use crate::figures::{logspace, Figure, FigureOpts, Series};
use crate::model::{ClusterSpec, Group, LatencyModel};
use crate::Result;

/// Generate Fig. 3 (one series per `N₂`, sweeping `μ₂`).
pub fn generate(opts: &FigureOpts) -> Result<Figure> {
    let k = 10_000usize;
    let mus = logspace(-2.0, 2.0, (opts.points * 3).max(24));
    let mut series = Vec::new();
    for n2 in [25usize, 50, 100, 200, 400] {
        let mut points = Vec::with_capacity(mus.len());
        for &mu2 in &mus {
            let spec = ClusterSpec::new(
                vec![
                    Group { n: 100, mu: 1.0, alpha: 1.0 },
                    Group { n: n2, mu: mu2, alpha: 1.0 },
                ],
                k,
            )?;
            let a = proposed_allocation(LatencyModel::A, &spec)?;
            points.push((mu2, a.rate(k as f64)));
        }
        series.push(Series { name: format!("N2 = {n2}"), points });
    }
    Ok(Figure {
        id: "fig3".into(),
        title: "MDS rate k/n* vs (N2, mu2); N1=100, mu1=1, alpha=1".into(),
        xlabel: "mu2".into(),
        ylabel: "rate k/n*".into(),
        log: (true, false),
        series,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rates_in_unit_interval() {
        let fig = generate(&FigureOpts::quick()).unwrap();
        for s in &fig.series {
            for &(_, rate) in &s.points {
                assert!(rate > 0.0 && rate <= 1.0, "rate {rate}");
            }
        }
    }

    #[test]
    fn rate_non_monotone_in_mu2() {
        // The paper's "interestingly, it is not true" observation: for some
        // N2 the rate dips then rises (or vice versa) as mu2 grows.
        let fig = generate(&FigureOpts::default()).unwrap();
        let mut found_non_monotone = false;
        for s in &fig.series {
            let ys: Vec<f64> = s.points.iter().map(|p| p.1).collect();
            let increasing = ys.windows(2).all(|w| w[1] >= w[0] - 1e-12);
            let decreasing = ys.windows(2).all(|w| w[1] <= w[0] + 1e-12);
            if !increasing && !decreasing {
                found_non_monotone = true;
            }
        }
        assert!(found_non_monotone, "expected a non-monotone rate curve");
    }
}
