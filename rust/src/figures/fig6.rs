//! Fig. 6: MDS rate `k/n*` of the proposed allocation vs `q` at `N = 2500`
//! (five-group cluster). Analytic — no simulation needed.
//!
//! Paper observations: rate ≈ ½ in `q ∈ [10^-1.5, 10^-1]`, rate ≈ 0.99 at
//! `q = 10^1.5`.

use crate::allocation::proposed_allocation;
use crate::figures::{logspace, Figure, FigureOpts, Series};
use crate::model::{ClusterSpec, LatencyModel};
use crate::Result;

/// Generate Fig. 6.
pub fn generate(opts: &FigureOpts) -> Result<Figure> {
    let k = 10_000usize;
    let base = ClusterSpec::paper_five_group(2500, k);
    let qs = logspace(-2.0, 1.5, (opts.points * 3).max(30));
    let points: Result<Vec<(f64, f64)>> = qs
        .iter()
        .map(|&q| {
            let spec = base.scaled_mu(q);
            let a = proposed_allocation(LatencyModel::A, &spec)?;
            Ok((q, a.rate(k as f64)))
        })
        .collect();
    Ok(Figure {
        id: "fig6".into(),
        title: "Rate k/n* vs q at N = 2500 (five groups)".into(),
        xlabel: "q (scale of mu)".into(),
        ylabel: "rate k/n*".into(),
        log: (true, false),
        series: vec![Series { name: "k/n*".into(), points: points? }],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_anchor_points() {
        let fig = generate(&FigureOpts::default()).unwrap();
        let pts = &fig.series[0].points;
        // Rate near 1/2 somewhere in q ∈ [10^-1.5, 10^-1].
        let mid: Vec<&(f64, f64)> = pts
            .iter()
            .filter(|p| p.0 >= 10f64.powf(-1.5) && p.0 <= 0.1)
            .collect();
        assert!(!mid.is_empty());
        assert!(
            mid.iter().any(|p| (p.1 - 0.5).abs() < 0.08),
            "no rate near 1/2 in the mid-q band: {mid:?}"
        );
        // Rate ≈ 0.99 at q = 10^1.5.
        let last = pts.last().unwrap();
        assert!(last.1 > 0.95, "rate at q=10^1.5 is {}", last.1);
    }

    #[test]
    fn rate_monotone_increasing_in_q() {
        // Scaling all mus together preserves ordering => rate increases.
        let fig = generate(&FigureOpts::quick()).unwrap();
        let pts = &fig.series[0].points;
        for w in pts.windows(2) {
            assert!(w[1].1 >= w[0].1 - 1e-9, "rate dipped at q={}", w[1].0);
        }
    }
}
