//! Fig. 5: expected latency vs `q` (scale of `μ`) at fixed `N = 2500`
//! for the five-group cluster of Fig. 4.

use crate::allocation::{optimal_latency_bound, policy};
use crate::figures::{logspace, Figure, FigureOpts, Series};
use crate::model::{ClusterSpec, LatencyModel};
use crate::sim::simulate_policy;
use crate::Result;

const GROUP_R: f64 = 100.0;

/// Generate Fig. 5.
pub fn generate(opts: &FigureOpts) -> Result<Figure> {
    let k = 10_000usize;
    let base = ClusterSpec::paper_five_group(2500, k);
    let qs = logspace(-2.0, 1.5, opts.points.max(6));
    let cfg = opts.sim_config();
    let p_proposed = policy::resolve("proposed")?;
    let p_uncoded = policy::resolve("uncoded")?;
    let p_nstar = policy::resolve("uniform-nstar")?;
    let p_half = policy::resolve("uniform-rate=0.5")?;

    let mut proposed = vec![];
    let mut uncoded = vec![];
    let mut uniform_nstar = vec![];
    let mut uniform_half = vec![];
    let mut group_bound = vec![];
    let mut t_star = vec![];
    for &q in &qs {
        let spec = base.scaled_mu(q);
        proposed.push((
            q,
            simulate_policy(&spec, &*p_proposed, LatencyModel::A, &cfg)?.mean,
        ));
        uncoded.push((
            q,
            simulate_policy(&spec, &*p_uncoded, LatencyModel::A, &cfg)?.mean,
        ));
        uniform_nstar.push((
            q,
            simulate_policy(&spec, &*p_nstar, LatencyModel::A, &cfg)?.mean,
        ));
        uniform_half.push((
            q,
            simulate_policy(&spec, &*p_half, LatencyModel::A, &cfg)?.mean,
        ));
        group_bound.push((q, 1.0 / GROUP_R));
        t_star.push((q, optimal_latency_bound(LatencyModel::A, &spec)));
    }
    Ok(Figure {
        id: "fig5".into(),
        title: "Expected latency vs q at N = 2500 (five groups)".into(),
        xlabel: "q (scale of mu)".into(),
        ylabel: "expected latency".into(),
        log: (true, true),
        series: vec![
            Series { name: "proposed".into(), points: proposed },
            Series { name: "uncoded".into(), points: uncoded },
            Series { name: "uniform n*".into(), points: uniform_nstar },
            Series { name: "uniform rate 1/2".into(), points: uniform_half },
            Series { name: "group-code bound 1/r".into(), points: group_bound },
            Series { name: "proposed bound T*".into(), points: t_star },
        ],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn series<'f>(fig: &'f Figure, name: &str) -> &'f [(f64, f64)] {
        &fig.series.iter().find(|s| s.name == name).unwrap().points
    }

    #[test]
    fn proposed_achieves_bound_across_q() {
        let fig = generate(&FigureOpts::quick()).unwrap();
        let prop = series(&fig, "proposed");
        let bound = series(&fig, "proposed bound T*");
        for (p, b) in prop.iter().zip(bound) {
            let gap = (p.1 - b.1) / b.1;
            assert!(gap > -0.01 && gap < 0.25, "q={} gap {gap}", p.0);
        }
    }

    #[test]
    fn uncoded_approaches_bound_at_large_q() {
        // Paper: uncoded approaches T* as q -> 10^1.5.
        let fig = generate(&FigureOpts::quick()).unwrap();
        let unc = series(&fig, "uncoded");
        let bound = series(&fig, "proposed bound T*");
        let first_ratio = unc[0].1 / bound[0].1;
        let last_ratio = unc.last().unwrap().1 / bound.last().unwrap().1;
        assert!(
            last_ratio < first_ratio,
            "uncoded/bound should shrink with q: {first_ratio} -> {last_ratio}"
        );
        assert!(last_ratio < 2.0, "uncoded should be near bound at q=10^1.5");
    }

    #[test]
    fn uniform_nstar_achieves_bound_at_small_q() {
        // Paper: for q <= 1e-2 uniform-with-n* sits on the lower bound.
        let fig = generate(&FigureOpts::quick()).unwrap();
        let uni = series(&fig, "uniform n*");
        let bound = series(&fig, "proposed bound T*");
        let ratio = uni[0].1 / bound[0].1;
        assert!(ratio < 1.1, "at q=1e-2 uniform n* ratio {ratio}");
    }
}
