//! Extension figure (beyond the paper): **tail latency** of the proposed vs
//! uniform allocation.
//!
//! The paper optimizes the *expected* latency; production serving systems
//! care about p95/p99. This figure shows that the proposed allocation's
//! advantage widens in the tail — uniform allocation leaves the slow group
//! holding loads it occasionally cannot absorb, fattening the upper
//! percentiles, while the proposed allocation equalizes group completion
//! profiles (Theorem 1) and thereby compresses the distribution.

use crate::allocation::{proposed_allocation, uniform_allocation};
use crate::figures::{Figure, FigureOpts, Series};
use crate::model::{ClusterSpec, LatencyModel};
use crate::sim::latency_any_k_detailed;
use crate::Result;

/// Generate the tail-latency extension figure (percentile vs N).
pub fn generate(opts: &FigureOpts) -> Result<Figure> {
    let k = 10_000usize;
    let all_ns: [usize; 5] = [250, 500, 1000, 2500, 5000];
    let ns: Vec<usize> = all_ns.iter().copied().take(opts.points.max(3)).collect();
    let cfg = opts.sim_config();

    let mut series: Vec<Series> = ["proposed p50", "proposed p99", "uniform p50", "uniform p99"]
        .iter()
        .map(|name| Series { name: (*name).into(), points: vec![] })
        .collect();
    for &n_total in &ns {
        let spec = ClusterSpec::paper_five_group(n_total, k);
        let x = spec.total_workers() as f64;
        let prop = proposed_allocation(LatencyModel::A, &spec)?;
        let uni = uniform_allocation(LatencyModel::A, &spec, prop.n)?;
        let sp = latency_any_k_detailed(&spec, &prop.loads, LatencyModel::A, &cfg)?;
        let su = latency_any_k_detailed(&spec, &uni.loads, LatencyModel::A, &cfg)?;
        series[0].points.push((x, sp.percentile(50.0)));
        series[1].points.push((x, sp.percentile(99.0)));
        series[2].points.push((x, su.percentile(50.0)));
        series[3].points.push((x, su.percentile(99.0)));
    }
    Ok(Figure {
        id: "ext_tail".into(),
        title: "Extension: tail latency, proposed vs uniform(n*)".into(),
        xlabel: "total workers N".into(),
        ylabel: "latency percentile".into(),
        log: (true, true),
        series,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn proposed_tail_tighter_than_uniform() {
        let mut opts = FigureOpts::quick();
        opts.samples = 3_000;
        let fig = generate(&opts).unwrap();
        let p99_prop = &fig.series[1].points;
        let p99_uni = &fig.series[3].points;
        for (p, u) in p99_prop.iter().zip(p99_uni) {
            assert!(
                p.1 < u.1,
                "proposed p99 {} !< uniform p99 {} at N={}",
                p.1,
                u.1,
                p.0
            );
        }
    }

    #[test]
    fn percentiles_ordered() {
        let mut opts = FigureOpts::quick();
        opts.samples = 2_000;
        let fig = generate(&opts).unwrap();
        for (p50, p99) in fig.series[0].points.iter().zip(&fig.series[1].points) {
            assert!(p50.1 <= p99.1);
        }
    }
}
