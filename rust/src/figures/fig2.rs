//! Fig. 2: `N·T*` as a function of the straggling-parameter scale `q`.
//!
//! Paper setting: `N = (1000, 2000, 3000)`, `μ = (2, 1, 0.5)`, `α = 1`.
//! Because `T* = Θ(1/N)` (the paper's claim), `N·T*` curves for scaled
//! clusters must collapse onto each other; we plot the paper's cluster plus
//! 2× and 4× scalings to exhibit the collapse.

use crate::allocation::optimal_latency_bound;
use crate::figures::{logspace, Figure, FigureOpts, Series};
use crate::model::{ClusterSpec, LatencyModel};
use crate::Result;

/// Generate Fig. 2.
pub fn generate(opts: &FigureOpts) -> Result<Figure> {
    let base = ClusterSpec::paper_fig2(10_000);
    let qs = logspace(-2.0, 1.5, opts.points.max(8));
    let mut series = Vec::new();
    for scale in [1.0, 2.0, 4.0] {
        let spec = base.scaled_workers(scale);
        let n_total = spec.total_workers() as f64;
        let points = qs
            .iter()
            .map(|&q| {
                let scaled = spec.scaled_mu(q);
                (q, n_total * optimal_latency_bound(LatencyModel::A, &scaled))
            })
            .collect();
        series.push(Series {
            name: format!("N = {} (x{scale:.0})", spec.total_workers()),
            points,
        });
    }
    Ok(Figure {
        id: "fig2".into(),
        title: "N x T* vs scale q of mu (T* = Theta(1/N))".into(),
        xlabel: "q (scale of mu)".into(),
        ylabel: "N x T*".into(),
        log: (true, true),
        series,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn curves_collapse() {
        // N·T* identical across worker scalings at every q.
        let fig = generate(&FigureOpts::quick()).unwrap();
        assert_eq!(fig.series.len(), 3);
        let a = &fig.series[0].points;
        let b = &fig.series[2].points;
        for (pa, pb) in a.iter().zip(b) {
            assert!((pa.1 - pb.1).abs() < 1e-9 * pa.1, "{} vs {}", pa.1, pb.1);
        }
    }

    #[test]
    fn n_t_star_decreases_with_q() {
        // More reliable workers (larger mu) => lower latency.
        let fig = generate(&FigureOpts::quick()).unwrap();
        let pts = &fig.series[0].points;
        for w in pts.windows(2) {
            assert!(w[1].1 < w[0].1, "not decreasing at q={}", w[1].0);
        }
    }
}
