//! Fig. 9: model-B comparison — the proposed allocation (Corollary 2) vs the
//! load-allocation algorithm of [32] — on the three-group cluster
//! `N = (3,3,4)·N/10`, `μ = (1,4,8)`, `α = (1,4,12)`, `k = 10⁵`.
//!
//! Both schemes achieve the lower bound `T*_b` (they coincide under group
//! heterogeneity; see `allocation::reisizadeh`).

use crate::allocation::{optimal_latency_bound, policy};
use crate::figures::{Figure, FigureOpts, Series};
use crate::model::{ClusterSpec, LatencyModel};
use crate::sim::simulate_policy;
use crate::Result;

/// Generate Fig. 9.
pub fn generate(opts: &FigureOpts) -> Result<Figure> {
    let k = 100_000usize;
    let all_ns: [usize; 6] = [250, 500, 1000, 2000, 4000, 8000];
    let ns: Vec<usize> = all_ns.iter().copied().take(opts.points.max(4)).collect();
    let cfg = opts.sim_config();
    let p_proposed = policy::resolve("proposed")?;
    let p_reis = policy::resolve("reisizadeh")?;

    let mut proposed = vec![];
    let mut reisizadeh = vec![];
    let mut bound = vec![];
    for &n_total in &ns {
        let spec = ClusterSpec::paper_three_group_b(n_total, k);
        let x = spec.total_workers() as f64;
        proposed.push((
            x,
            simulate_policy(&spec, &*p_proposed, LatencyModel::B, &cfg)?.mean,
        ));
        reisizadeh.push((
            x,
            simulate_policy(&spec, &*p_reis, LatencyModel::B, &cfg)?.mean,
        ));
        bound.push((x, optimal_latency_bound(LatencyModel::B, &spec)));
    }
    Ok(Figure {
        id: "fig9".into(),
        title: "Model B: proposed vs [32] allocation (3 groups, k = 1e5)".into(),
        xlabel: "total workers N".into(),
        ylabel: "expected latency".into(),
        log: (true, true),
        series: vec![
            Series { name: "proposed (Cor. 2)".into(), points: proposed },
            Series { name: "reisizadeh [32]".into(), points: reisizadeh },
            Series { name: "lower bound T*_b".into(), points: bound },
        ],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn both_schemes_achieve_bound() {
        let fig = generate(&FigureOpts::quick()).unwrap();
        let prop = &fig.series[0].points;
        let reis = &fig.series[1].points;
        let bound = &fig.series[2].points;
        for ((p, z), b) in prop.iter().zip(reis).zip(bound) {
            assert!(p.1 >= b.1 * 0.99, "proposed {} below bound {}", p.1, b.1);
            // Schemes coincide.
            assert!(
                (p.1 - z.1).abs() / p.1 < 0.05,
                "proposed {} vs reisizadeh {}",
                p.1,
                z.1
            );
            // Achieves the bound to within ~15% at these N.
            assert!((p.1 - b.1) / b.1 < 0.30, "gap at N={}: {} vs {}", p.0, p.1, b.1);
        }
    }

    #[test]
    fn latency_scales_one_over_n() {
        let fig = generate(&FigureOpts::quick()).unwrap();
        let b = &fig.series[2].points;
        let ratio = b[0].1 / b.last().unwrap().1;
        let n_ratio = b.last().unwrap().0 / b[0].0;
        assert!((ratio / n_ratio - 1.0).abs() < 0.05, "bound not ~1/N");
    }
}
