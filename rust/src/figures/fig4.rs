//! Fig. 4: expected latency vs total worker count `N` for the five-group
//! cluster (`N_j = (3,4,5,6,7)·N/25`, `μ = (16,12,8,4,1)`, `α = 1`,
//! group-code `r = 100`).
//!
//! Series (as in the paper): proposed (MC), uncoded, uniform with `n*`,
//! uniform with rate ½, group-code lower bound `1/r`, proposed lower bound
//! `T*` — plus, as an extension, the *simulated* group-code scheme.

use crate::allocation::{optimal_latency_bound, policy};
use crate::figures::{Figure, FigureOpts, Series};
use crate::model::{ClusterSpec, LatencyModel};
use crate::sim::simulate_policy;
use crate::Result;

const GROUP_R: f64 = 100.0;

/// Generate Fig. 4.
pub fn generate(opts: &FigureOpts) -> Result<Figure> {
    let k = 10_000usize;
    // Total-N sweep, log-ish spacing; multiples of 25 keep group sizes exact.
    let all_ns: [usize; 7] = [250, 500, 1000, 2500, 5000, 10_000, 20_000];
    let ns: Vec<usize> = all_ns.iter().copied().take(opts.points.max(4)).collect();
    let cfg = opts.sim_config();
    // Policies resolved once through the central registry.
    let p_proposed = policy::resolve("proposed")?;
    let p_uncoded = policy::resolve("uncoded")?;
    let p_nstar = policy::resolve("uniform-nstar")?;
    let p_half = policy::resolve("uniform-rate=0.5")?;
    let p_group = policy::resolve("group-code=100")?;

    let mut proposed = vec![];
    let mut uncoded = vec![];
    let mut uniform_nstar = vec![];
    let mut uniform_half = vec![];
    let mut group_sim = vec![];
    let mut group_bound = vec![];
    let mut t_star = vec![];
    for &n_total in &ns {
        let spec = ClusterSpec::paper_five_group(n_total, k);
        let x = spec.total_workers() as f64;
        let p = simulate_policy(&spec, &*p_proposed, LatencyModel::A, &cfg)?;
        proposed.push((x, p.mean));
        uncoded.push((
            x,
            simulate_policy(&spec, &*p_uncoded, LatencyModel::A, &cfg)?.mean,
        ));
        uniform_nstar.push((
            x,
            simulate_policy(&spec, &*p_nstar, LatencyModel::A, &cfg)?.mean,
        ));
        uniform_half.push((
            x,
            simulate_policy(&spec, &*p_half, LatencyModel::A, &cfg)?.mean,
        ));
        if n_total as f64 > GROUP_R {
            group_sim.push((
                x,
                simulate_policy(&spec, &*p_group, LatencyModel::A, &cfg)?.mean,
            ));
        }
        group_bound.push((x, 1.0 / GROUP_R));
        t_star.push((x, optimal_latency_bound(LatencyModel::A, &spec)));
    }
    Ok(Figure {
        id: "fig4".into(),
        title: "Expected latency vs N (five groups, r = 100)".into(),
        xlabel: "total workers N".into(),
        ylabel: "expected latency".into(),
        log: (true, true),
        series: vec![
            Series { name: "proposed".into(), points: proposed },
            Series { name: "uncoded".into(), points: uncoded },
            Series { name: "uniform n*".into(), points: uniform_nstar },
            Series { name: "uniform rate 1/2".into(), points: uniform_half },
            Series { name: "group code (sim)".into(), points: group_sim },
            Series { name: "group-code bound 1/r".into(), points: group_bound },
            Series { name: "proposed bound T*".into(), points: t_star },
        ],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn series<'f>(fig: &'f Figure, name: &str) -> &'f [(f64, f64)] {
        &fig.series.iter().find(|s| s.name == name).unwrap().points
    }

    #[test]
    fn proposed_tracks_bound_and_beats_group_code() {
        let mut opts = FigureOpts::quick();
        opts.points = 5; // up to N=5000
        let fig = generate(&opts).unwrap();
        let prop = series(&fig, "proposed");
        let bound = series(&fig, "proposed bound T*");
        for (p, b) in prop.iter().zip(bound) {
            assert!(p.1 >= b.1 * 0.995, "mean {} below bound {}", p.1, b.1);
            assert!(p.1 <= b.1 * 1.35, "mean {} too far above bound {}", p.1, b.1);
        }
        // At the largest N, proposed is far below the group-code floor 1/r.
        let last = prop.last().unwrap();
        assert!(
            last.1 < 0.01 / 3.0,
            "expected >3x gain over 1/r at N=5000, got latency {}",
            last.1
        );
    }

    #[test]
    fn latency_decreases_with_n_for_proposed_only() {
        let mut opts = FigureOpts::quick();
        opts.points = 5;
        let fig = generate(&opts).unwrap();
        let prop = series(&fig, "proposed");
        for w in prop.windows(2) {
            assert!(w[1].1 < w[0].1, "proposed not improving at N={}", w[1].0);
        }
        // Group-code sim saturates near 1/r: last two points within 20%.
        let gc = series(&fig, "group code (sim)");
        if gc.len() >= 2 {
            let a = gc[gc.len() - 2].1;
            let b = gc[gc.len() - 1].1;
            assert!((a / b - 1.0).abs() < 0.5, "group code should flatten");
        }
    }
}
