//! Fig. 8: expected latency vs MDS code rate under uniform allocation for
//! the two-group cluster `N = (300, 600)`, `μ = (4, 0.5)`, `α = 1`.
//!
//! Paper observations: the best uniform rate is near **0.52**, and the
//! proposed allocation is ≈**10 % below** that best uniform point.

use crate::allocation::policy;
use crate::figures::{linspace, Figure, FigureOpts, Series};
use crate::model::{ClusterSpec, LatencyModel};
use crate::sim::simulate_policy;
use crate::Result;

/// Generate Fig. 8.
pub fn generate(opts: &FigureOpts) -> Result<Figure> {
    let k = 10_000usize;
    let spec = ClusterSpec::paper_two_group(k);
    let cfg = opts.sim_config();
    let rates = linspace(0.35, 0.95, (opts.points * 2).max(13));

    let mut uniform = Vec::with_capacity(rates.len());
    for &rate in &rates {
        let p = policy::resolve(&format!("uniform-rate={rate}"))?;
        let r = simulate_policy(&spec, &*p, LatencyModel::A, &cfg)?;
        uniform.push((rate, r.mean));
    }
    let prop =
        simulate_policy(&spec, &*policy::resolve("proposed")?, LatencyModel::A, &cfg)?;
    let proposed_line: Vec<(f64, f64)> =
        rates.iter().map(|&rt| (rt, prop.mean)).collect();
    let bound_line: Vec<(f64, f64)> =
        rates.iter().map(|&rt| (rt, prop.bound.unwrap())).collect();

    Ok(Figure {
        id: "fig8".into(),
        title: "Latency vs rate, uniform allocation (2 groups)".into(),
        xlabel: "rate k/n".into(),
        ylabel: "expected latency".into(),
        log: (false, false),
        series: vec![
            Series { name: "uniform (rate sweep)".into(), points: uniform },
            Series { name: "proposed".into(), points: proposed_line },
            Series { name: "proposed bound T*".into(), points: bound_line },
        ],
    })
}

/// The best uniform rate and its latency (used by EXPERIMENTS.md and tests).
pub fn best_uniform_rate(fig: &Figure) -> (f64, f64) {
    fig.series[0]
        .points
        .iter()
        .copied()
        .min_by(|a, b| a.1.total_cmp(&b.1))
        .expect("non-empty sweep")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn optimum_near_paper_value() {
        let mut opts = FigureOpts::quick();
        opts.samples = 2_000;
        opts.points = 12;
        let fig = generate(&opts).unwrap();
        let (best_rate, best_latency) = best_uniform_rate(&fig);
        assert!(
            (0.40..0.65).contains(&best_rate),
            "best uniform rate {best_rate} far from paper's 0.52"
        );
        // Proposed ~10% better than the best uniform point.
        let prop = fig.series[1].points[0].1;
        let gain = (best_latency - prop) / best_latency;
        assert!(
            gain > 0.02 && gain < 0.30,
            "proposed gain over best uniform = {gain} (paper: ~0.10)"
        );
    }

    #[test]
    fn sweep_is_u_shaped() {
        let mut opts = FigureOpts::quick();
        opts.samples = 2_000;
        opts.points = 12;
        let fig = generate(&opts).unwrap();
        let pts = &fig.series[0].points;
        let first = pts.first().unwrap().1;
        let last = pts.last().unwrap().1;
        let (_, best) = best_uniform_rate(&fig);
        assert!(best < first && best < last, "no interior minimum");
    }
}
