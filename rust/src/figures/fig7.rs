//! Fig. 7: expected latency of uniform load allocation at several fixed MDS
//! rates vs `q`, compared with the proposed allocation (N = 2500, five
//! groups).
//!
//! Paper observation: at `q = 1` the rate-⅔ uniform code beats the uniform
//! scheme that reuses the optimal `n*`.

use crate::allocation::policy;
use crate::figures::{logspace, Figure, FigureOpts, Series};
use crate::model::{ClusterSpec, LatencyModel};
use crate::sim::simulate_policy;
use crate::Result;

/// Generate Fig. 7.
pub fn generate(opts: &FigureOpts) -> Result<Figure> {
    let k = 10_000usize;
    let base = ClusterSpec::paper_five_group(2500, k);
    let qs = logspace(-2.0, 1.5, opts.points.max(6));
    let cfg = opts.sim_config();
    let rates = [0.5, 2.0 / 3.0, 0.75, 0.9];
    let p_proposed = policy::resolve("proposed")?;
    let p_nstar = policy::resolve("uniform-nstar")?;
    let p_rates = rates
        .iter()
        .map(|&rate| policy::resolve(&format!("uniform-rate={rate}")))
        .collect::<Result<Vec<_>>>()?;

    let mut series: Vec<Series> = Vec::new();
    let mut proposed = vec![];
    let mut uniform_nstar = vec![];
    let mut per_rate: Vec<Vec<(f64, f64)>> = vec![vec![]; rates.len()];
    for &q in &qs {
        let spec = base.scaled_mu(q);
        proposed.push((
            q,
            simulate_policy(&spec, &*p_proposed, LatencyModel::A, &cfg)?.mean,
        ));
        uniform_nstar.push((
            q,
            simulate_policy(&spec, &*p_nstar, LatencyModel::A, &cfg)?.mean,
        ));
        for (i, p) in p_rates.iter().enumerate() {
            per_rate[i].push((
                q,
                simulate_policy(&spec, &**p, LatencyModel::A, &cfg)?.mean,
            ));
        }
    }
    series.push(Series { name: "proposed".into(), points: proposed });
    series.push(Series { name: "uniform n*".into(), points: uniform_nstar });
    for (i, &rate) in rates.iter().enumerate() {
        series.push(Series {
            name: format!("uniform rate {rate:.3}"),
            points: per_rate[i].clone(),
        });
    }
    Ok(Figure {
        id: "fig7".into(),
        title: "Uniform allocation at fixed rates vs q (N = 2500)".into(),
        xlabel: "q (scale of mu)".into(),
        ylabel: "expected latency".into(),
        log: (true, true),
        series,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn proposed_never_beaten() {
        let fig = generate(&FigureOpts::quick()).unwrap();
        let prop = &fig.series[0].points;
        for s in &fig.series[1..] {
            for (p, other) in prop.iter().zip(&s.points) {
                assert!(
                    p.1 <= other.1 * 1.03,
                    "proposed {} beaten by {} ({}) at q={}",
                    p.1,
                    s.name,
                    other.1,
                    p.0
                );
            }
        }
    }

    #[test]
    fn rate_two_thirds_beats_nstar_uniform_at_q1() {
        // The paper's q=1 observation.
        let mut opts = FigureOpts::quick();
        opts.points = 8; // ensure a q near 1 exists
        let fig = generate(&opts).unwrap();
        let nstar = &fig.series[1].points;
        let two_thirds = &fig
            .series
            .iter()
            .find(|s| s.name.starts_with("uniform rate 0.667"))
            .unwrap()
            .points;
        // Closest sweep point to q = 1.
        let idx = nstar
            .iter()
            .enumerate()
            .min_by(|a, b| {
                (a.1 .0 - 1.0).abs().total_cmp(&(b.1 .0 - 1.0).abs())
            })
            .unwrap()
            .0;
        assert!(
            two_thirds[idx].1 < nstar[idx].1 * 1.05,
            "rate-2/3 {} should be <= uniform-n* {} near q=1",
            two_thirds[idx].1,
            nstar[idx].1
        );
    }
}
