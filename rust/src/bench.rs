//! In-repo micro/macro benchmark harness (the vendored crate set has no
//! `criterion`).
//!
//! Benches live in `rust/benches/*.rs` with `harness = false` and call
//! [`run`] / [`run_with_target`]; `cargo bench` drives them. The harness
//! auto-calibrates the iteration count to a target measurement window and
//! reports min / median / p95 wall time plus derived throughput.

#![forbid(unsafe_code)]

use crate::math::Summary;
use crate::runtime::wall_now;
use std::time::Duration;

/// One benchmark's measurements.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Benchmark name.
    pub name: String,
    /// Iterations measured (after warm-up).
    pub iters: u64,
    /// Median wall time per iteration (seconds).
    pub median: f64,
    /// Minimum wall time per iteration (seconds).
    pub min: f64,
    /// 95th-percentile wall time per iteration (seconds).
    pub p95: f64,
    /// Mean wall time per iteration (seconds).
    pub mean: f64,
}

impl BenchResult {
    /// Pretty one-line report (time auto-scaled).
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  (min {}, p95 {}, {} iters)",
            self.name,
            fmt_time(self.median),
            fmt_time(self.min),
            fmt_time(self.p95),
            self.iters
        )
    }
}

/// Format seconds with an auto-scaled unit.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// When the `BENCH_JSON_DIR` environment variable is set, every measured
/// benchmark appends a `"name": ns_per_op,` line to
/// `$BENCH_JSON_DIR/<bench-binary>.lines`; `make bench-json` merges the
/// per-binary fragments into the current `BENCH_PR<N>.json` snapshot
/// (flat name → ns/op map, `BENCH_PR7.json` as of this PR) so the repo's
/// bench trajectory is machine-diffable across PRs.
fn json_append(name: &str, median_secs: f64) {
    let Ok(dir) = std::env::var("BENCH_JSON_DIR") else {
        return;
    };
    let stem = std::env::args()
        .next()
        .and_then(|p| {
            std::path::Path::new(&p)
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
        })
        .unwrap_or_else(|| "bench".into());
    let path = std::path::Path::new(&dir).join(format!("{stem}.lines"));
    let line = format!("  \"{}\": {:.0},\n", name.replace('"', "'"), median_secs * 1e9);
    use std::io::Write;
    if let Ok(mut f) =
        std::fs::OpenOptions::new().create(true).append(true).open(&path)
    {
        let _ = f.write_all(line.as_bytes());
    }
}

/// When the `BENCH_LIST` environment variable is set, benches emit one
/// `bench: <name>` line per benchmark instead of measuring anything —
/// `scripts/check_bench_schema` diffs that list against the keys of the
/// current `BENCH_PR<N>.json` snapshot so the schema can never drift
/// from the harness.
fn list_only(name: &str) -> Option<BenchResult> {
    std::env::var_os("BENCH_LIST")?;
    println!("bench: {name}");
    Some(BenchResult {
        name: name.to_string(),
        iters: 0,
        median: 0.0,
        min: 0.0,
        p95: 0.0,
        mean: 0.0,
    })
}

/// Benchmark `f`, auto-calibrating iterations to ~`target` of measurement.
pub fn run_with_target<F: FnMut()>(name: &str, target: Duration, mut f: F) -> BenchResult {
    if let Some(listed) = list_only(name) {
        return listed;
    }
    // Warm-up & calibration: time one call, derive iteration count.
    let t0 = wall_now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((target.as_secs_f64() / once).ceil() as u64).clamp(3, 10_000);
    let mut s = Summary::keeping_samples();
    for _ in 0..iters {
        let t = wall_now();
        f();
        s.add(t.elapsed().as_secs_f64());
    }
    let r = BenchResult {
        name: name.to_string(),
        iters,
        median: s.median(),
        min: s.min(),
        p95: s.percentile(95.0),
        mean: s.mean(),
    };
    println!("{}", r.report());
    json_append(&r.name, r.median);
    r
}

/// Benchmark with the default 2-second target window.
pub fn run<F: FnMut()>(name: &str, f: F) -> BenchResult {
    run_with_target(name, Duration::from_secs(2), f)
}

/// Quick benchmark for long-running macro benches (smaller window).
pub fn run_quick<F: FnMut()>(name: &str, f: F) -> BenchResult {
    run_with_target(name, Duration::from_millis(300), f)
}

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Print a section header in bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = run_with_target("noop-ish", Duration::from_millis(20), || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.iters >= 3);
        assert!(r.median >= 0.0);
        assert!(r.min <= r.median && r.median <= r.p95.max(r.median));
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with("ms"));
        assert!(fmt_time(2e-6).ends_with("µs"));
        assert!(fmt_time(2e-9).ends_with("ns"));
    }
}
