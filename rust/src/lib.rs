//! # hetcoded
//!
//! Production-quality reproduction of *"Optimal Load Allocation for Coded
//! Distributed Computation in Heterogeneous Clusters"* (Kim, Park, Choi, 2019).
//!
//! ## The public API in three types
//!
//! Everything composes through three abstractions:
//!
//! - **[`allocation::Policy`]** — one load-allocation scheme (how many
//!   coded rows each worker group gets). The central **registry**
//!   ([`allocation::policy`]) is the single source of truth for policy
//!   names: `allocation::policy::resolve("proposed")?` hands back a
//!   `Box<dyn Policy>` that the simulator ([`sim::simulate_policy`]), the
//!   queueing layer ([`workload::run_workload_policy`]), and the live
//!   coordinator all accept. New schemes are one module + one registry
//!   line.
//! - **[`coding::Code`]** — one erasure code (setup / encode /
//!   decode-rows), with its own registry ([`coding::code`]) mirroring
//!   the policy one: `mds-random` (default), `mds-vandermonde`, the
//!   non-MDS `sparse-parity` with an O(nnz) CSR encode, and the
//!   `rateless-rlc` fountain whose generator is an infinite seeded row
//!   stream — workers stream rows until any `k` survive, so serving
//!   rides out lossy links and scales past the setup `n` with zero
//!   re-encodes. Policy and code are orthogonal axes, resolved
//!   independently at session build.
//! - **[`coordinator::Session`]** — one live serve. Policy × code ×
//!   mode × scenario × adaptivity are orthogonal builder knobs; every
//!   serve returns a unified [`coordinator::ServeOutcome`]:
//!
//! ```no_run
//! use hetcoded::allocation::policy;
//! use hetcoded::coding::Matrix;
//! use hetcoded::coordinator::{Mode, Session};
//! use hetcoded::model::ClusterSpec;
//!
//! let spec = ClusterSpec::paper_two_group(256);
//! let a = Matrix::from_fn(256, 64, |i, j| ((i + j) as f64).sin());
//! let requests: Vec<Vec<f64>> = vec![vec![1.0; 64]; 32];
//! let outcome = Session::builder(&spec)
//!     .policy(policy::resolve("proposed")?)
//!     .code("mds-vandermonde") // erasure code by registry name
//!     .data(a)
//!     .requests(requests)
//!     .mode(Mode::PoissonArrivals { rate: 100.0, max_batch: 8 })
//!     .build()?
//!     .serve()?;
//! println!("{}", outcome.recorder.report());
//! assert_eq!(outcome.encodes, 1); // prepared fast path: one encode per stream
//! # Ok::<(), hetcoded::Error>(())
//! ```
//!
//! The six pre-facade serving functions (`run_job`, `run_job_batched`,
//! `serve_requests`, `serve_requests_pipelined`, `serve_arrivals`,
//! `serve_arrivals_adaptive`) remain as `#[deprecated]` shims over
//! `Session`, bit-identical under fixed seeds.
//!
//! ## Layer inventory
//!
//! - the **math substrate**: Lambert W (both real branches), harmonic numbers,
//!   a deterministic xoshiro/SplitMix RNG, summary statistics ([`math`]);
//! - the paper's two **shifted-exponential runtime models** (eqs. (1) and
//!   (30)) and analytic order statistics (eq. (6)) ([`model`]);
//! - every **load-allocation policy** evaluated by the paper: the proposed
//!   optimum (Theorem 2), its model-B variant (Corollary 2), uniform / uncoded
//!   allocation, the fixed-`r` group code of [33] (Theorem 4), and the scheme
//!   of Reisizadeh et al. [32] (Appendix D) ([`allocation`]), behind the
//!   [`allocation::Policy`] trait + registry;
//! - a real-valued **coding layer** behind the pluggable [`coding::Code`]
//!   trait: systematic-random and Vandermonde MDS, an LDPC-style
//!   sparse-parity code, and a rateless random-linear fountain with an
//!   extensible generator, plus an encoder, an any-k decoder, and its
//!   own dense (`Matrix`) and sparse (`CsrMatrix`) linear algebra
//!   ([`coding`]);
//! - a **persistent compute pool** ([`runtime::pool`]) every parallel hot
//!   path (blocked matmul, encode, multi-RHS decode, Monte-Carlo sweeps)
//!   runs on — fixed worker threads, deterministic index-ordered
//!   reduction (bit-identical results at any pool size), no per-call
//!   thread spawns;
//! - a **Monte-Carlo cluster simulator** reproducing Figs. 4–9 ([`sim`]);
//! - a **workload layer** modelling sustained job traffic — arrival
//!   processes, FIFO queueing, and throughput/utilization/sojourn metrics
//!   on top of the single-job latency law ([`workload`]), plus
//!   failure/drift schedules and the static-vs-adaptive allocation
//!   experiment ([`workload::drift`]), and the **sharded admission front
//!   end** ([`workload::admission`]): tenant-keyed shard queues, a
//!   work-stealing drain, deficit-round-robin fairness, and SLO-adaptive
//!   batching, bit-reproducible at ≥1M arrivals — with a live twin on
//!   the coordinator ([`coordinator::frontend`],
//!   [`coordinator::SessionBuilder::front_end`]);
//! - a **live master/worker coordinator** that executes AOT-compiled XLA
//!   artifacts via PJRT with injected straggle delays ([`coordinator`],
//!   [`runtime`]), scripted failure/drift scenarios
//!   ([`coordinator::failures`]), and an online-estimating adaptive
//!   re-allocation loop that re-slices encoded rows without re-encoding
//!   ([`coordinator::adaptive`], [`model::estimator`]) — all served
//!   through [`coordinator::Session`];
//! - the **figure harness** regenerating every plot in the paper
//!   ([`figures`]), resolving its policies through the registry.
//!
//! The PJRT/XLA execution path is gated behind the `xla` cargo feature
//! (off by default) so the analytical and simulation layers build and test
//! without the native `xla_extension` library; the `NativeCompute` backend
//! always works.
//!
//! See `DESIGN.md` for the system inventory (and its "Public API map")
//! and `EXPERIMENTS.md` for paper-vs-measured results.

// `unsafe` is confined to `runtime/pool.rs` (lint rule S1); every
#![deny(unsafe_op_in_unsafe_fn)]

pub mod allocation;
pub mod bench;
pub mod cli;
pub mod coding;
pub mod config;
pub mod coordinator;
pub mod figures;
pub mod math;
pub mod model;
pub mod proptest;
pub mod runtime;
pub mod sim;
pub mod workload;

/// Crate-wide error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// A cluster/allocation specification was invalid.
    #[error("invalid specification: {0}")]
    InvalidSpec(String),
    /// A numerical routine failed to converge or hit a domain error.
    #[error("numerical error: {0}")]
    Numerical(String),
    /// Decoding failed (singular system / not enough rows).
    #[error("decode error: {0}")]
    Decode(String),
    /// The fixed-r group-code equation (29) has no solution (paper §III-D).
    #[error("group-code equation has no solution: {0}")]
    NoSolution(String),
    /// Configuration file parse error.
    #[error("config error: {0}")]
    Config(String),
    /// XLA/PJRT runtime error.
    #[error("runtime error: {0}")]
    Runtime(String),
    /// I/O error.
    #[error(transparent)]
    Io(#[from] std::io::Error),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(feature = "xla")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(format!("{e:?}"))
    }
}
