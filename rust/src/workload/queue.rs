//! Event-driven FIFO queueing simulation of a coded cluster under load.
//!
//! The model: jobs arrive at the master (an [`ArrivalProcess`]) and wait in
//! an unbounded FIFO queue. The cluster runs at most `servers` coded jobs
//! concurrently (the paper's setting is `servers = 1`: one matvec fans out
//! to *all* workers); each job in service occupies one slot for an i.i.d.
//! service time drawn from the policy's single-job completion-time
//! distribution ([`ServiceSampler`]). With Poisson arrivals and one slot
//! this is an M/G/1 queue whose service law is the paper's `T_{r:N}`.
//!
//! Because arrivals are generated up front and service times are i.i.d.,
//! the simulation is a single O(n · log servers) pass (earliest-free-slot
//! selection via a min-heap) and is bit-reproducible from a seed.
//!
//! The multi-queue generalization of this simulator — per-tenant sharded
//! admission, work stealing, adaptive batching — lives in
//! [`crate::workload::admission`]; with one shard and one tenant it
//! reproduces this FIFO path bit-for-bit.

use crate::allocation::Policy;
use crate::math::{Rng, Summary};
use crate::model::{ClusterSpec, LatencyModel};
use crate::sim::Scheme;
use crate::workload::arrivals::ArrivalProcess;
use crate::workload::service::{service_sampler_for, ServiceSampler};
use crate::{Error, Result};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Order-preserving integer key for a nonnegative finite model time: the
/// IEEE-754 bit pattern of a nonnegative `f64` compares exactly like the
/// value, so `(time_key(t), index)` tuples are totally ordered heap keys
/// with no `PartialOrd` wrapper types. `-0.0` (whose bit pattern would
/// otherwise sort above every positive time) normalizes to `+0.0`.
pub(crate) fn time_key(t: f64) -> u64 {
    if t <= 0.0 {
        0
    } else {
        t.to_bits()
    }
}

/// Configuration of one throughput-under-load run.
#[derive(Clone, Copy, Debug)]
pub struct WorkloadConfig {
    /// Traffic model.
    pub arrivals: ArrivalProcess,
    /// Number of jobs to simulate.
    pub jobs: usize,
    /// Concurrent coded jobs the cluster sustains (1 = the paper's
    /// whole-cluster fan-out).
    pub servers: usize,
    /// Base seed; arrivals and service draws use split substreams.
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            arrivals: ArrivalProcess::Poisson { rate: 1.0 },
            jobs: 2_000,
            servers: 1,
            seed: 0x10AD,
        }
    }
}

/// Raw per-job trace of a queue simulation (all times in model units).
#[derive(Clone, Debug)]
pub struct QueueTrace {
    /// Arrival time of job `i` (ascending).
    pub arrivals: Vec<f64>,
    /// Instant job `i` entered service (ascending — FIFO).
    pub starts: Vec<f64>,
    /// Instant job `i` completed.
    pub finishes: Vec<f64>,
    /// Server slot that ran job `i`.
    pub server_of: Vec<usize>,
}

/// Simulate a FIFO queue with `servers` slots over explicit arrival times.
///
/// Jobs enter service in arrival order on the earliest-free slot; since the
/// earliest-free time is non-decreasing as jobs are assigned, start times
/// are monotone — proper FIFO. Returns the full trace so callers (and the
/// invariant tests) can inspect every job.
pub fn simulate_queue(
    arrival_times: &[f64],
    service: &mut ServiceSampler,
    servers: usize,
    rng: &mut Rng,
) -> Result<QueueTrace> {
    if servers == 0 {
        return Err(Error::InvalidSpec("servers must be positive".into()));
    }
    if arrival_times.iter().any(|&t| !t.is_finite() || t < 0.0)
        || arrival_times.windows(2).any(|w| w[1] < w[0])
    {
        return Err(Error::InvalidSpec(
            "arrival times must be finite, nonnegative and ascending".into(),
        ));
    }
    let n = arrival_times.len();
    // Earliest-free slot via a min-heap keyed `(free_time_bits, slot)`.
    // `time_key` is order-isomorphic to the time, so the heap minimum is
    // exactly the linear scan's first strict minimum: equal free times
    // tie-break on the lower slot index, bit-for-bit the old behaviour,
    // at O(log servers) per arrival instead of O(servers).
    let mut free: BinaryHeap<Reverse<(u64, usize)>> =
        (0..servers).map(|i| Reverse((time_key(0.0), i))).collect();
    let mut starts = Vec::with_capacity(n);
    let mut finishes = Vec::with_capacity(n);
    let mut server_of = Vec::with_capacity(n);
    for &t in arrival_times {
        let Reverse((bits, idx)) = free.pop().expect("one heap entry per slot");
        let ft = f64::from_bits(bits);
        let start = t.max(ft);
        let finish = start + service.sample(rng);
        free.push(Reverse((time_key(finish), idx)));
        starts.push(start);
        finishes.push(finish);
        server_of.push(idx);
    }
    Ok(QueueTrace { arrivals: arrival_times.to_vec(), starts, finishes, server_of })
}

/// Aggregate metrics of one throughput-under-load run.
#[derive(Clone, Debug)]
pub struct WorkloadReport {
    /// Policy display name.
    pub policy: String,
    /// Arrival-process display name.
    pub arrival_process: String,
    /// Long-run offered arrival rate `λ`.
    pub offered_rate: f64,
    /// Jobs simulated (== jobs completed; the queue is lossless).
    pub jobs: usize,
    /// Concurrent service slots.
    pub servers: usize,
    /// Observation window: first arrival to last completion. (Measuring
    /// from t = 0 instead padded the window with the idle head period
    /// before any traffic existed, biasing throughput and utilization low
    /// — worst under slow deterministic traffic, whose first job arrives a
    /// full interarrival gap after 0.)
    pub makespan: f64,
    /// Completed jobs per unit model time.
    pub throughput: f64,
    /// Busy time / (makespan · servers), in `[0, 1]`.
    pub utilization: f64,
    /// Empirical mean service time `E[S]`. An empty trace has no service
    /// draws to average, so the report is explicitly all-zero (see
    /// [`WorkloadReport::from_trace`]) rather than a `0/1` artifact.
    pub mean_service: f64,
    /// Sojourn times (arrival → completion); retains samples, so
    /// percentiles are available.
    pub sojourn: Summary,
    /// Waiting times (arrival → service start); retains samples.
    pub wait: Summary,
    /// Time-average number of jobs in the system.
    pub mean_in_system: f64,
    /// Peak number of jobs in the system.
    pub max_in_system: usize,
}

impl WorkloadReport {
    /// Sojourn-time percentile (`p` in `[0, 100]`).
    pub fn sojourn_percentile(&self, p: f64) -> f64 {
        self.sojourn.percentile(p)
    }

    /// Build the report from a raw trace.
    ///
    /// An **empty trace** (zero jobs) yields an explicitly all-zero report
    /// — zero makespan/throughput/utilization/`mean_service` and empty
    /// sojourn/wait summaries — rather than metrics fabricated from
    /// clamped denominators: there is no observation window and no service
    /// draw to average, so every "mean" is undefined and reported as 0.
    pub fn from_trace(
        policy: String,
        arrivals: &ArrivalProcess,
        servers: usize,
        trace: &QueueTrace,
    ) -> WorkloadReport {
        let n = trace.arrivals.len();
        if n == 0 {
            return WorkloadReport {
                policy,
                arrival_process: arrivals.name().to_string(),
                offered_rate: arrivals.mean_rate(),
                jobs: 0,
                servers,
                makespan: 0.0,
                throughput: 0.0,
                utilization: 0.0,
                mean_service: 0.0,
                sojourn: Summary::keeping_samples(),
                wait: Summary::keeping_samples(),
                mean_in_system: 0.0,
                max_in_system: 0,
            };
        }
        // Window = [first arrival, last completion]: the system is
        // trivially empty before traffic starts, so counting that stretch
        // in the denominator under-reports throughput and utilization.
        let first_arrival = trace.arrivals.first().copied().unwrap_or(0.0);
        let last_finish = trace
            .finishes
            .iter()
            .fold(f64::NEG_INFINITY, |acc, &f| acc.max(f));
        let makespan = last_finish - first_arrival;
        let mut sojourn = Summary::keeping_samples();
        let mut wait = Summary::keeping_samples();
        let mut busy = 0.0;
        for i in 0..n {
            sojourn.add(trace.finishes[i] - trace.arrivals[i]);
            wait.add(trace.starts[i] - trace.arrivals[i]);
            busy += trace.finishes[i] - trace.starts[i];
        }
        // Number-in-system sweep: +1 at arrival, −1 at completion;
        // departures sort before arrivals at equal times.
        let mut events: Vec<(f64, i64)> = Vec::with_capacity(2 * n);
        for &t in &trace.arrivals {
            events.push((t, 1));
        }
        for &t in &trace.finishes {
            events.push((t, -1));
        }
        // total_cmp: same order as partial_cmp on the finite times
        // simulate_queue produces, and panic-free if a caller hands
        // from_trace a trace with a NaN.
        events.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let mut depth = 0i64;
        let mut max_depth = 0i64;
        let mut last_t = first_arrival;
        let mut area = 0.0;
        for (t, d) in events {
            area += depth as f64 * (t - last_t);
            last_t = t;
            depth += d;
            max_depth = max_depth.max(depth);
        }
        WorkloadReport {
            policy,
            arrival_process: arrivals.name().to_string(),
            offered_rate: arrivals.mean_rate(),
            jobs: n,
            servers,
            makespan,
            throughput: if makespan > 0.0 { n as f64 / makespan } else { 0.0 },
            utilization: if makespan > 0.0 {
                busy / (makespan * servers as f64)
            } else {
                0.0
            },
            mean_service: busy / n as f64,
            sojourn,
            wait,
            mean_in_system: if makespan > 0.0 { area / makespan } else { 0.0 },
            max_in_system: max_depth as usize,
        }
    }
}

/// Run one complete throughput-under-load experiment for any [`Policy`]:
/// generate arrivals, build the policy's service sampler on `spec`, run
/// the queue, and summarize. Bit-reproducible from `cfg.seed`. This is the
/// entry point `workload --policies` uses for registry-resolved policies.
pub fn run_workload_policy(
    spec: &ClusterSpec,
    policy: &dyn Policy,
    model: LatencyModel,
    cfg: &WorkloadConfig,
) -> Result<WorkloadReport> {
    if cfg.jobs == 0 {
        return Err(Error::InvalidSpec("workload needs at least one job".into()));
    }
    let (_, mut sampler) = service_sampler_for(spec, policy, model)?;
    let mut root = Rng::new(cfg.seed);
    let mut arrival_rng = root.split();
    let mut service_rng = root.split();
    let arrivals = cfg.arrivals.times(cfg.jobs, &mut arrival_rng)?;
    let trace =
        simulate_queue(&arrivals, &mut sampler, cfg.servers, &mut service_rng)?;
    Ok(WorkloadReport::from_trace(
        policy.name(),
        &cfg.arrivals,
        cfg.servers,
        &trace,
    ))
}

/// [`run_workload_policy`] over a [`Scheme`]'s policy object.
pub fn run_workload(
    spec: &ClusterSpec,
    scheme: Scheme,
    model: LatencyModel,
    cfg: &WorkloadConfig,
) -> Result<WorkloadReport> {
    run_workload_policy(spec, &*scheme.policy(), model, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{order_stats, Group};
    use crate::workload::service::service_sampler;

    fn cfg(rate: f64, jobs: usize) -> WorkloadConfig {
        WorkloadConfig {
            arrivals: ArrivalProcess::Poisson { rate },
            jobs,
            servers: 1,
            seed: 2019,
        }
    }

    #[test]
    fn fifo_invariants_hold() {
        // No job lost; FIFO start order; per-slot completion times monotone;
        // sojourn ≥ wait ≥ 0.
        let spec = ClusterSpec::paper_two_group(10_000);
        let (_, mut sampler) =
            service_sampler(&spec, Scheme::Proposed, LatencyModel::A).unwrap();
        let mut rng = Rng::new(5);
        let arrivals = ArrivalProcess::Poisson { rate: 20.0 }
            .times(500, &mut rng)
            .unwrap();
        for servers in [1usize, 3] {
            let t = simulate_queue(&arrivals, &mut sampler, servers, &mut rng)
                .unwrap();
            assert_eq!(t.arrivals.len(), 500);
            assert_eq!(t.starts.len(), 500);
            assert_eq!(t.finishes.len(), 500);
            assert!(t.starts.windows(2).all(|w| w[1] >= w[0]), "FIFO starts");
            let mut last_finish = vec![0.0f64; servers];
            for i in 0..500 {
                assert!(t.starts[i] >= t.arrivals[i]);
                assert!(t.finishes[i] > t.starts[i]);
                let s = t.server_of[i];
                assert!(s < servers);
                assert!(
                    t.finishes[i] >= last_finish[s],
                    "slot {s} completions must be monotone"
                );
                last_finish[s] = t.finishes[i];
            }
        }
    }

    /// Reference copy of the pre-heap earliest-free-slot selection (linear
    /// scan, first strict minimum ⇒ lowest index at ties); the heap path
    /// must reproduce it bit-for-bit, `server_of` included.
    fn simulate_queue_linear(
        arrival_times: &[f64],
        service: &mut ServiceSampler,
        servers: usize,
        rng: &mut Rng,
    ) -> QueueTrace {
        let mut free = vec![0.0f64; servers];
        let mut starts = Vec::new();
        let mut finishes = Vec::new();
        let mut server_of = Vec::new();
        for &t in arrival_times {
            let mut idx = 0usize;
            let mut ft = free[0];
            for (i, &x) in free.iter().enumerate().skip(1) {
                if x < ft {
                    ft = x;
                    idx = i;
                }
            }
            let start = t.max(ft);
            let finish = start + service.sample(rng);
            free[idx] = finish;
            starts.push(start);
            finishes.push(finish);
            server_of.push(idx);
        }
        QueueTrace { arrivals: arrival_times.to_vec(), starts, finishes, server_of }
    }

    #[test]
    fn heap_slot_selection_matches_linear_scan_bit_for_bit() {
        let spec = ClusterSpec::paper_two_group(10_000);
        let (_, sampler) =
            service_sampler(&spec, Scheme::Proposed, LatencyModel::A).unwrap();
        for servers in [1usize, 2, 3, 7] {
            let mut arr_rng = Rng::new(41 + servers as u64);
            let arrivals = ArrivalProcess::Poisson { rate: 30.0 }
                .times(400, &mut arr_rng)
                .unwrap();
            let mut s1 = sampler.clone();
            let mut s2 = sampler.clone();
            let mut r1 = Rng::new(17);
            let mut r2 = Rng::new(17);
            let heap = simulate_queue(&arrivals, &mut s1, servers, &mut r1)
                .unwrap();
            let lin =
                simulate_queue_linear(&arrivals, &mut s2, servers, &mut r2);
            assert_eq!(heap.starts, lin.starts, "servers {servers}");
            assert_eq!(heap.finishes, lin.finishes, "servers {servers}");
            assert_eq!(heap.server_of, lin.server_of, "servers {servers}");
        }
    }

    #[test]
    fn equal_free_times_tie_break_on_lowest_slot() {
        // Four simultaneous arrivals on four all-idle slots: every slot is
        // free at exactly 0.0, so the tie-break alone decides placement —
        // slots 0, 1, 2, 3 in arrival order, the linear scan's rule.
        let spec = ClusterSpec::paper_two_group(10_000);
        let (_, mut sampler) =
            service_sampler(&spec, Scheme::Proposed, LatencyModel::A).unwrap();
        let mut rng = Rng::new(3);
        let arrivals = [0.0, 0.0, 0.0, 0.0, 5.0, 5.0];
        let t = simulate_queue(&arrivals, &mut sampler, 4, &mut rng).unwrap();
        assert_eq!(&t.server_of[..4], &[0, 1, 2, 3]);
        assert_eq!(&t.starts[..4], &[0.0, 0.0, 0.0, 0.0]);
        // The two t = 5 arrivals land on the two earliest-freed slots, in
        // freed order (or lowest index if still tied at 5.0).
        assert!(t.starts[4] >= 5.0 && t.starts[5] >= t.starts[4]);
    }

    #[test]
    fn empty_trace_reports_all_zero() {
        let trace = QueueTrace {
            arrivals: vec![],
            starts: vec![],
            finishes: vec![],
            server_of: vec![],
        };
        let rep = WorkloadReport::from_trace(
            "test".into(),
            &ArrivalProcess::Poisson { rate: 1.0 },
            2,
            &trace,
        );
        assert_eq!(rep.jobs, 0);
        assert_eq!(rep.makespan, 0.0);
        assert_eq!(rep.throughput, 0.0);
        assert_eq!(rep.utilization, 0.0);
        assert_eq!(rep.mean_service, 0.0, "no service draws, no mean");
        assert_eq!(rep.mean_in_system, 0.0);
        assert_eq!(rep.max_in_system, 0);
        assert_eq!(rep.sojourn.count(), 0);
        assert_eq!(rep.wait.count(), 0);
    }

    #[test]
    fn bursty_arrivals_keep_fifo_and_raise_peak_depth() {
        // ON/OFF traffic at the same long-run mean rate as a Poisson
        // stream: the queue invariants (monotone FIFO starts, start ≥
        // arrival, finish > start) must survive the bursts, and the burst
        // peak backlog must exceed the Poisson baseline's.
        let spec = ClusterSpec::paper_two_group(10_000);
        let (_, mut sampler) =
            service_sampler(&spec, Scheme::Proposed, LatencyModel::A).unwrap();
        let es = crate::workload::service::mean_service(&mut sampler, 2_000, 1);
        let rate = 0.7 / es;
        let (on, off) = (50.0 * es, 50.0 * es);
        let onoff = ArrivalProcess::OnOff {
            // ON rate boosted so the long-run mean rate stays `rate`.
            rate_on: rate * (on + off) / on,
            mean_on: on,
            mean_off: off,
        };
        let mut arr_rng = Rng::new(23);
        let times = onoff.times(2_000, &mut arr_rng).unwrap();
        let mut svc_rng = Rng::new(29);
        let t = simulate_queue(&times, &mut sampler, 1, &mut svc_rng).unwrap();
        assert!(t.starts.windows(2).all(|w| w[1] >= w[0]), "FIFO under burst");
        for i in 0..times.len() {
            assert!(t.starts[i] >= t.arrivals[i]);
            assert!(t.finishes[i] > t.starts[i]);
        }
        let mk = |arrivals| WorkloadConfig { arrivals, jobs: 2_000, servers: 1, seed: 23 };
        let burst = run_workload(&spec, Scheme::Proposed, LatencyModel::A, &mk(onoff))
            .unwrap();
        let pois = run_workload(
            &spec,
            Scheme::Proposed,
            LatencyModel::A,
            &mk(ArrivalProcess::Poisson { rate }),
        )
        .unwrap();
        assert!(
            burst.max_in_system > pois.max_in_system,
            "burst peak {} must exceed Poisson baseline {}",
            burst.max_in_system,
            pois.max_in_system
        );
    }

    #[test]
    fn run_workload_is_deterministic() {
        let spec = ClusterSpec::paper_two_group(10_000);
        let a = run_workload(&spec, Scheme::Proposed, LatencyModel::A, &cfg(5.0, 300))
            .unwrap();
        let b = run_workload(&spec, Scheme::Proposed, LatencyModel::A, &cfg(5.0, 300))
            .unwrap();
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.sojourn.mean(), b.sojourn.mean());
        assert_eq!(a.max_in_system, b.max_in_system);
        assert_eq!(a.jobs, 300);
    }

    #[test]
    fn utilization_matches_offered_load_single_group() {
        // M/G/1 sanity on a single-group cluster with the uncoded policy:
        // service is the N-th order statistic with closed-form mean E[S]
        // (eq. (6)), so for ρ = λ·E[S] < 1 the long-run busy fraction must
        // approach ρ.
        let (n, k) = (40usize, 1000usize);
        let spec =
            ClusterSpec::new(vec![Group { n, mu: 2.0, alpha: 1.0 }], k).unwrap();
        let es = order_stats::group_latency_exact(
            LatencyModel::A,
            k as f64 / n as f64,
            k as f64,
            n as u64,
            n as u64,
            2.0,
            1.0,
        );
        let rho = 0.6;
        let wcfg = WorkloadConfig {
            arrivals: ArrivalProcess::Poisson { rate: rho / es },
            jobs: 4_000,
            servers: 1,
            seed: 99,
        };
        let rep =
            run_workload(&spec, Scheme::Uncoded, LatencyModel::A, &wcfg).unwrap();
        assert!(
            (rep.utilization - rho).abs() / rho < 0.05,
            "utilization {} vs ρ {rho}",
            rep.utilization
        );
        // Empirical mean service must also track the closed form.
        assert!(
            (rep.mean_service - es).abs() / es < 0.05,
            "E[S] {} vs exact {es}",
            rep.mean_service
        );
    }

    #[test]
    fn heavier_load_lengthens_sojourn() {
        let spec = ClusterSpec::paper_two_group(10_000);
        let (_, mut sampler) =
            service_sampler(&spec, Scheme::Proposed, LatencyModel::A).unwrap();
        let es = crate::workload::service::mean_service(&mut sampler, 2_000, 1);
        let light =
            run_workload(&spec, Scheme::Proposed, LatencyModel::A, &cfg(0.2 / es, 800))
                .unwrap();
        let heavy =
            run_workload(&spec, Scheme::Proposed, LatencyModel::A, &cfg(0.9 / es, 800))
                .unwrap();
        assert!(heavy.sojourn.mean() > light.sojourn.mean());
        assert!(heavy.sojourn_percentile(95.0) > light.sojourn_percentile(95.0));
        assert!(heavy.utilization > light.utilization);
        assert!(light.utilization <= 1.0 + 1e-12);
    }

    #[test]
    fn extra_servers_raise_saturated_throughput() {
        // Offered load ≈ 2 service rates: one slot saturates at ~1/E[S],
        // two slots at ~2/E[S].
        let spec = ClusterSpec::paper_two_group(10_000);
        let (_, mut sampler) =
            service_sampler(&spec, Scheme::Proposed, LatencyModel::A).unwrap();
        let es = crate::workload::service::mean_service(&mut sampler, 2_000, 1);
        let mk = |servers| WorkloadConfig {
            arrivals: ArrivalProcess::Poisson { rate: 2.5 / es },
            jobs: 1_500,
            servers,
            seed: 7,
        };
        let one =
            run_workload(&spec, Scheme::Proposed, LatencyModel::A, &mk(1)).unwrap();
        let two =
            run_workload(&spec, Scheme::Proposed, LatencyModel::A, &mk(2)).unwrap();
        assert!(
            two.throughput > 1.5 * one.throughput,
            "1 slot {} vs 2 slots {}",
            one.throughput,
            two.throughput
        );
    }

    #[test]
    fn makespan_starts_at_first_arrival() {
        // Regression: a trace whose first job arrives late must not count
        // the idle head period. Two unit-service jobs arriving at t = 100
        // and 101 span [100, 102]: throughput 1 job per unit time, not
        // 2/102 ≈ 0.02.
        let trace = QueueTrace {
            arrivals: vec![100.0, 101.0],
            starts: vec![100.0, 101.0],
            finishes: vec![101.0, 102.0],
            server_of: vec![0, 0],
        };
        let rep = WorkloadReport::from_trace(
            "test".into(),
            &ArrivalProcess::Deterministic { rate: 1.0 },
            1,
            &trace,
        );
        assert!((rep.makespan - 2.0).abs() < 1e-12, "makespan {}", rep.makespan);
        assert!((rep.throughput - 1.0).abs() < 1e-12);
        assert!((rep.utilization - 1.0).abs() < 1e-12);
        assert!((rep.mean_in_system - 1.0).abs() < 1e-12);
    }

    #[test]
    fn slow_deterministic_traffic_throughput_tracks_rate() {
        // Slow deterministic arrivals with few jobs: the first job arrives
        // a full 1/rate after t = 0, so the old from-zero makespan diluted
        // throughput and utilization noticeably at this scale.
        let spec = ClusterSpec::paper_two_group(10_000);
        let rate = 0.5;
        let wcfg = WorkloadConfig {
            arrivals: ArrivalProcess::Deterministic { rate },
            jobs: 40,
            servers: 1,
            seed: 11,
        };
        let rep =
            run_workload(&spec, Scheme::Proposed, LatencyModel::A, &wcfg).unwrap();
        // 40 jobs over a (40-1)/rate window plus one trailing service
        // (approximated by the mean; services here are ≪ the window).
        let expect = 40.0 / (39.0 / rate + rep.mean_service);
        assert!(
            (rep.throughput - expect).abs() / expect < 1e-3,
            "throughput {} vs {expect}",
            rep.throughput
        );
        // Utilization over the traffic window ≈ ρ = λ·E[S].
        let rho = rate * rep.mean_service;
        assert!(
            (rep.utilization - rho).abs() / rho < 0.06,
            "utilization {} vs ρ {rho}",
            rep.utilization
        );
    }

    #[test]
    fn invalid_configs_rejected() {
        let spec = ClusterSpec::paper_two_group(10_000);
        let mut bad = cfg(1.0, 100);
        bad.servers = 0;
        assert!(run_workload(&spec, Scheme::Proposed, LatencyModel::A, &bad).is_err());
        let mut none = cfg(1.0, 0);
        none.jobs = 0;
        assert!(run_workload(&spec, Scheme::Proposed, LatencyModel::A, &none).is_err());
        let (_, mut sampler) =
            service_sampler(&spec, Scheme::Proposed, LatencyModel::A).unwrap();
        let mut rng = Rng::new(1);
        assert!(simulate_queue(&[2.0, 1.0], &mut sampler, 1, &mut rng).is_err());
        assert!(simulate_queue(&[-1.0, 1.0], &mut sampler, 1, &mut rng).is_err());
        assert!(
            simulate_queue(&[f64::NAN, 1.0], &mut sampler, 1, &mut rng).is_err()
        );
    }
}
