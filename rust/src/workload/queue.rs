//! Event-driven FIFO queueing simulation of a coded cluster under load.
//!
//! The model: jobs arrive at the master (an [`ArrivalProcess`]) and wait in
//! an unbounded FIFO queue. The cluster runs at most `servers` coded jobs
//! concurrently (the paper's setting is `servers = 1`: one matvec fans out
//! to *all* workers); each job in service occupies one slot for an i.i.d.
//! service time drawn from the policy's single-job completion-time
//! distribution ([`ServiceSampler`]). With Poisson arrivals and one slot
//! this is an M/G/1 queue whose service law is the paper's `T_{r:N}`.
//!
//! Because arrivals are generated up front and service times are i.i.d.,
//! the simulation is a single O(n · servers) pass — no event heap — and is
//! bit-reproducible from a seed.

use crate::allocation::Policy;
use crate::math::{Rng, Summary};
use crate::model::{ClusterSpec, LatencyModel};
use crate::sim::Scheme;
use crate::workload::arrivals::ArrivalProcess;
use crate::workload::service::{service_sampler_for, ServiceSampler};
use crate::{Error, Result};

/// Configuration of one throughput-under-load run.
#[derive(Clone, Copy, Debug)]
pub struct WorkloadConfig {
    /// Traffic model.
    pub arrivals: ArrivalProcess,
    /// Number of jobs to simulate.
    pub jobs: usize,
    /// Concurrent coded jobs the cluster sustains (1 = the paper's
    /// whole-cluster fan-out).
    pub servers: usize,
    /// Base seed; arrivals and service draws use split substreams.
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            arrivals: ArrivalProcess::Poisson { rate: 1.0 },
            jobs: 2_000,
            servers: 1,
            seed: 0x10AD,
        }
    }
}

/// Raw per-job trace of a queue simulation (all times in model units).
#[derive(Clone, Debug)]
pub struct QueueTrace {
    /// Arrival time of job `i` (ascending).
    pub arrivals: Vec<f64>,
    /// Instant job `i` entered service (ascending — FIFO).
    pub starts: Vec<f64>,
    /// Instant job `i` completed.
    pub finishes: Vec<f64>,
    /// Server slot that ran job `i`.
    pub server_of: Vec<usize>,
}

/// Simulate a FIFO queue with `servers` slots over explicit arrival times.
///
/// Jobs enter service in arrival order on the earliest-free slot; since the
/// earliest-free time is non-decreasing as jobs are assigned, start times
/// are monotone — proper FIFO. Returns the full trace so callers (and the
/// invariant tests) can inspect every job.
pub fn simulate_queue(
    arrival_times: &[f64],
    service: &mut ServiceSampler,
    servers: usize,
    rng: &mut Rng,
) -> Result<QueueTrace> {
    if servers == 0 {
        return Err(Error::InvalidSpec("servers must be positive".into()));
    }
    if arrival_times.iter().any(|&t| !t.is_finite() || t < 0.0)
        || arrival_times.windows(2).any(|w| w[1] < w[0])
    {
        return Err(Error::InvalidSpec(
            "arrival times must be finite, nonnegative and ascending".into(),
        ));
    }
    let n = arrival_times.len();
    let mut free = vec![0.0f64; servers];
    let mut starts = Vec::with_capacity(n);
    let mut finishes = Vec::with_capacity(n);
    let mut server_of = Vec::with_capacity(n);
    for &t in arrival_times {
        // Earliest-free slot (linear scan; `servers` is small).
        let mut idx = 0usize;
        let mut ft = free[0];
        for (i, &x) in free.iter().enumerate().skip(1) {
            if x < ft {
                ft = x;
                idx = i;
            }
        }
        let start = t.max(ft);
        let finish = start + service.sample(rng);
        free[idx] = finish;
        starts.push(start);
        finishes.push(finish);
        server_of.push(idx);
    }
    Ok(QueueTrace { arrivals: arrival_times.to_vec(), starts, finishes, server_of })
}

/// Aggregate metrics of one throughput-under-load run.
#[derive(Clone, Debug)]
pub struct WorkloadReport {
    /// Policy display name.
    pub policy: String,
    /// Arrival-process display name.
    pub arrival_process: String,
    /// Long-run offered arrival rate `λ`.
    pub offered_rate: f64,
    /// Jobs simulated (== jobs completed; the queue is lossless).
    pub jobs: usize,
    /// Concurrent service slots.
    pub servers: usize,
    /// Observation window: first arrival to last completion. (Measuring
    /// from t = 0 instead padded the window with the idle head period
    /// before any traffic existed, biasing throughput and utilization low
    /// — worst under slow deterministic traffic, whose first job arrives a
    /// full interarrival gap after 0.)
    pub makespan: f64,
    /// Completed jobs per unit model time.
    pub throughput: f64,
    /// Busy time / (makespan · servers), in `[0, 1]`.
    pub utilization: f64,
    /// Empirical mean service time `E[S]`.
    pub mean_service: f64,
    /// Sojourn times (arrival → completion); retains samples, so
    /// percentiles are available.
    pub sojourn: Summary,
    /// Waiting times (arrival → service start); retains samples.
    pub wait: Summary,
    /// Time-average number of jobs in the system.
    pub mean_in_system: f64,
    /// Peak number of jobs in the system.
    pub max_in_system: usize,
}

impl WorkloadReport {
    /// Sojourn-time percentile (`p` in `[0, 100]`).
    pub fn sojourn_percentile(&self, p: f64) -> f64 {
        self.sojourn.percentile(p)
    }

    /// Build the report from a raw trace.
    pub fn from_trace(
        policy: String,
        arrivals: &ArrivalProcess,
        servers: usize,
        trace: &QueueTrace,
    ) -> WorkloadReport {
        let n = trace.arrivals.len();
        // Window = [first arrival, last completion]: the system is
        // trivially empty before traffic starts, so counting that stretch
        // in the denominator under-reports throughput and utilization.
        let first_arrival = trace.arrivals.first().copied().unwrap_or(0.0);
        let last_finish = trace
            .finishes
            .iter()
            .fold(f64::NEG_INFINITY, |acc, &f| acc.max(f));
        let makespan = if n == 0 { 0.0 } else { last_finish - first_arrival };
        let mut sojourn = Summary::keeping_samples();
        let mut wait = Summary::keeping_samples();
        let mut busy = 0.0;
        for i in 0..n {
            sojourn.add(trace.finishes[i] - trace.arrivals[i]);
            wait.add(trace.starts[i] - trace.arrivals[i]);
            busy += trace.finishes[i] - trace.starts[i];
        }
        // Number-in-system sweep: +1 at arrival, −1 at completion;
        // departures sort before arrivals at equal times.
        let mut events: Vec<(f64, i64)> = Vec::with_capacity(2 * n);
        for &t in &trace.arrivals {
            events.push((t, 1));
        }
        for &t in &trace.finishes {
            events.push((t, -1));
        }
        // total_cmp: same order as partial_cmp on the finite times
        // simulate_queue produces, and panic-free if a caller hands
        // from_trace a trace with a NaN.
        events.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let mut depth = 0i64;
        let mut max_depth = 0i64;
        let mut last_t = first_arrival;
        let mut area = 0.0;
        for (t, d) in events {
            area += depth as f64 * (t - last_t);
            last_t = t;
            depth += d;
            max_depth = max_depth.max(depth);
        }
        let jobs_f = n.max(1) as f64;
        WorkloadReport {
            policy,
            arrival_process: arrivals.name().to_string(),
            offered_rate: arrivals.mean_rate(),
            jobs: n,
            servers,
            makespan,
            throughput: if makespan > 0.0 { n as f64 / makespan } else { 0.0 },
            utilization: if makespan > 0.0 {
                busy / (makespan * servers as f64)
            } else {
                0.0
            },
            mean_service: busy / jobs_f,
            sojourn,
            wait,
            mean_in_system: if makespan > 0.0 { area / makespan } else { 0.0 },
            max_in_system: max_depth as usize,
        }
    }
}

/// Run one complete throughput-under-load experiment for any [`Policy`]:
/// generate arrivals, build the policy's service sampler on `spec`, run
/// the queue, and summarize. Bit-reproducible from `cfg.seed`. This is the
/// entry point `workload --policies` uses for registry-resolved policies.
pub fn run_workload_policy(
    spec: &ClusterSpec,
    policy: &dyn Policy,
    model: LatencyModel,
    cfg: &WorkloadConfig,
) -> Result<WorkloadReport> {
    if cfg.jobs == 0 {
        return Err(Error::InvalidSpec("workload needs at least one job".into()));
    }
    let (_, mut sampler) = service_sampler_for(spec, policy, model)?;
    let mut root = Rng::new(cfg.seed);
    let mut arrival_rng = root.split();
    let mut service_rng = root.split();
    let arrivals = cfg.arrivals.times(cfg.jobs, &mut arrival_rng)?;
    let trace =
        simulate_queue(&arrivals, &mut sampler, cfg.servers, &mut service_rng)?;
    Ok(WorkloadReport::from_trace(
        policy.name(),
        &cfg.arrivals,
        cfg.servers,
        &trace,
    ))
}

/// [`run_workload_policy`] over a [`Scheme`]'s policy object.
pub fn run_workload(
    spec: &ClusterSpec,
    scheme: Scheme,
    model: LatencyModel,
    cfg: &WorkloadConfig,
) -> Result<WorkloadReport> {
    run_workload_policy(spec, &*scheme.policy(), model, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{order_stats, Group};
    use crate::workload::service::service_sampler;

    fn cfg(rate: f64, jobs: usize) -> WorkloadConfig {
        WorkloadConfig {
            arrivals: ArrivalProcess::Poisson { rate },
            jobs,
            servers: 1,
            seed: 2019,
        }
    }

    #[test]
    fn fifo_invariants_hold() {
        // No job lost; FIFO start order; per-slot completion times monotone;
        // sojourn ≥ wait ≥ 0.
        let spec = ClusterSpec::paper_two_group(10_000);
        let (_, mut sampler) =
            service_sampler(&spec, Scheme::Proposed, LatencyModel::A).unwrap();
        let mut rng = Rng::new(5);
        let arrivals = ArrivalProcess::Poisson { rate: 20.0 }
            .times(500, &mut rng)
            .unwrap();
        for servers in [1usize, 3] {
            let t = simulate_queue(&arrivals, &mut sampler, servers, &mut rng)
                .unwrap();
            assert_eq!(t.arrivals.len(), 500);
            assert_eq!(t.starts.len(), 500);
            assert_eq!(t.finishes.len(), 500);
            assert!(t.starts.windows(2).all(|w| w[1] >= w[0]), "FIFO starts");
            let mut last_finish = vec![0.0f64; servers];
            for i in 0..500 {
                assert!(t.starts[i] >= t.arrivals[i]);
                assert!(t.finishes[i] > t.starts[i]);
                let s = t.server_of[i];
                assert!(s < servers);
                assert!(
                    t.finishes[i] >= last_finish[s],
                    "slot {s} completions must be monotone"
                );
                last_finish[s] = t.finishes[i];
            }
        }
    }

    #[test]
    fn run_workload_is_deterministic() {
        let spec = ClusterSpec::paper_two_group(10_000);
        let a = run_workload(&spec, Scheme::Proposed, LatencyModel::A, &cfg(5.0, 300))
            .unwrap();
        let b = run_workload(&spec, Scheme::Proposed, LatencyModel::A, &cfg(5.0, 300))
            .unwrap();
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.sojourn.mean(), b.sojourn.mean());
        assert_eq!(a.max_in_system, b.max_in_system);
        assert_eq!(a.jobs, 300);
    }

    #[test]
    fn utilization_matches_offered_load_single_group() {
        // M/G/1 sanity on a single-group cluster with the uncoded policy:
        // service is the N-th order statistic with closed-form mean E[S]
        // (eq. (6)), so for ρ = λ·E[S] < 1 the long-run busy fraction must
        // approach ρ.
        let (n, k) = (40usize, 1000usize);
        let spec =
            ClusterSpec::new(vec![Group { n, mu: 2.0, alpha: 1.0 }], k).unwrap();
        let es = order_stats::group_latency_exact(
            LatencyModel::A,
            k as f64 / n as f64,
            k as f64,
            n as u64,
            n as u64,
            2.0,
            1.0,
        );
        let rho = 0.6;
        let wcfg = WorkloadConfig {
            arrivals: ArrivalProcess::Poisson { rate: rho / es },
            jobs: 4_000,
            servers: 1,
            seed: 99,
        };
        let rep =
            run_workload(&spec, Scheme::Uncoded, LatencyModel::A, &wcfg).unwrap();
        assert!(
            (rep.utilization - rho).abs() / rho < 0.05,
            "utilization {} vs ρ {rho}",
            rep.utilization
        );
        // Empirical mean service must also track the closed form.
        assert!(
            (rep.mean_service - es).abs() / es < 0.05,
            "E[S] {} vs exact {es}",
            rep.mean_service
        );
    }

    #[test]
    fn heavier_load_lengthens_sojourn() {
        let spec = ClusterSpec::paper_two_group(10_000);
        let (_, mut sampler) =
            service_sampler(&spec, Scheme::Proposed, LatencyModel::A).unwrap();
        let es = crate::workload::service::mean_service(&mut sampler, 2_000, 1);
        let light =
            run_workload(&spec, Scheme::Proposed, LatencyModel::A, &cfg(0.2 / es, 800))
                .unwrap();
        let heavy =
            run_workload(&spec, Scheme::Proposed, LatencyModel::A, &cfg(0.9 / es, 800))
                .unwrap();
        assert!(heavy.sojourn.mean() > light.sojourn.mean());
        assert!(heavy.sojourn_percentile(95.0) > light.sojourn_percentile(95.0));
        assert!(heavy.utilization > light.utilization);
        assert!(light.utilization <= 1.0 + 1e-12);
    }

    #[test]
    fn extra_servers_raise_saturated_throughput() {
        // Offered load ≈ 2 service rates: one slot saturates at ~1/E[S],
        // two slots at ~2/E[S].
        let spec = ClusterSpec::paper_two_group(10_000);
        let (_, mut sampler) =
            service_sampler(&spec, Scheme::Proposed, LatencyModel::A).unwrap();
        let es = crate::workload::service::mean_service(&mut sampler, 2_000, 1);
        let mk = |servers| WorkloadConfig {
            arrivals: ArrivalProcess::Poisson { rate: 2.5 / es },
            jobs: 1_500,
            servers,
            seed: 7,
        };
        let one =
            run_workload(&spec, Scheme::Proposed, LatencyModel::A, &mk(1)).unwrap();
        let two =
            run_workload(&spec, Scheme::Proposed, LatencyModel::A, &mk(2)).unwrap();
        assert!(
            two.throughput > 1.5 * one.throughput,
            "1 slot {} vs 2 slots {}",
            one.throughput,
            two.throughput
        );
    }

    #[test]
    fn makespan_starts_at_first_arrival() {
        // Regression: a trace whose first job arrives late must not count
        // the idle head period. Two unit-service jobs arriving at t = 100
        // and 101 span [100, 102]: throughput 1 job per unit time, not
        // 2/102 ≈ 0.02.
        let trace = QueueTrace {
            arrivals: vec![100.0, 101.0],
            starts: vec![100.0, 101.0],
            finishes: vec![101.0, 102.0],
            server_of: vec![0, 0],
        };
        let rep = WorkloadReport::from_trace(
            "test".into(),
            &ArrivalProcess::Deterministic { rate: 1.0 },
            1,
            &trace,
        );
        assert!((rep.makespan - 2.0).abs() < 1e-12, "makespan {}", rep.makespan);
        assert!((rep.throughput - 1.0).abs() < 1e-12);
        assert!((rep.utilization - 1.0).abs() < 1e-12);
        assert!((rep.mean_in_system - 1.0).abs() < 1e-12);
    }

    #[test]
    fn slow_deterministic_traffic_throughput_tracks_rate() {
        // Slow deterministic arrivals with few jobs: the first job arrives
        // a full 1/rate after t = 0, so the old from-zero makespan diluted
        // throughput and utilization noticeably at this scale.
        let spec = ClusterSpec::paper_two_group(10_000);
        let rate = 0.5;
        let wcfg = WorkloadConfig {
            arrivals: ArrivalProcess::Deterministic { rate },
            jobs: 40,
            servers: 1,
            seed: 11,
        };
        let rep =
            run_workload(&spec, Scheme::Proposed, LatencyModel::A, &wcfg).unwrap();
        // 40 jobs over a (40-1)/rate window plus one trailing service
        // (approximated by the mean; services here are ≪ the window).
        let expect = 40.0 / (39.0 / rate + rep.mean_service);
        assert!(
            (rep.throughput - expect).abs() / expect < 1e-3,
            "throughput {} vs {expect}",
            rep.throughput
        );
        // Utilization over the traffic window ≈ ρ = λ·E[S].
        let rho = rate * rep.mean_service;
        assert!(
            (rep.utilization - rho).abs() / rho < 0.06,
            "utilization {} vs ρ {rho}",
            rep.utilization
        );
    }

    #[test]
    fn invalid_configs_rejected() {
        let spec = ClusterSpec::paper_two_group(10_000);
        let mut bad = cfg(1.0, 100);
        bad.servers = 0;
        assert!(run_workload(&spec, Scheme::Proposed, LatencyModel::A, &bad).is_err());
        let mut none = cfg(1.0, 0);
        none.jobs = 0;
        assert!(run_workload(&spec, Scheme::Proposed, LatencyModel::A, &none).is_err());
        let (_, mut sampler) =
            service_sampler(&spec, Scheme::Proposed, LatencyModel::A).unwrap();
        let mut rng = Rng::new(1);
        assert!(simulate_queue(&[2.0, 1.0], &mut sampler, 1, &mut rng).is_err());
        assert!(simulate_queue(&[-1.0, 1.0], &mut sampler, 1, &mut rng).is_err());
        assert!(
            simulate_queue(&[f64::NAN, 1.0], &mut sampler, 1, &mut rng).is_err()
        );
    }
}
