//! Drift schedules and the adaptive-vs-static workload experiment.
//!
//! This is the model-time mirror of the live scenario layer
//! ([`crate::coordinator::failures`]): events scripted against the
//! *simulation clock* mutate the true cluster mid-stream — group
//! slowdowns (time dilation), tail-only μ-drift, and worker deaths — while
//! the queueing simulation keeps serving jobs.
//!
//! [`run_workload_drift`] runs the paper's single-slot FIFO cluster
//! through such a schedule under one of two policies:
//!
//! - **Static** ([`AdaptPolicy::Static`]): the allocation solved for the
//!   initial spec is kept forever — the paper's standing assumption.
//! - **Adaptive** ([`AdaptPolicy::Adaptive`]): the master watches the
//!   per-worker completions it consumes (a type-II censored sample per
//!   job, exactly what a real master sees), recovers `(μ̂, α̂)` per group
//!   via [`SpeedEstimator`], and when the estimates deviate from the
//!   assumed parameters — or cluster membership changes — re-solves the
//!   paper's allocation on the estimated surviving cluster, budgeted to
//!   the coded rows that already exist
//!   ([`crate::allocation::proposed_allocation_capped`]; re-allocating
//!   never re-encodes, mirroring [`crate::coordinator::PreparedJob::rechunk`]).
//!
//! The headline experiment: under a mid-stream 2× slowdown of one group
//! at an arrival rate the drifted-but-re-solved cluster can still sustain,
//! the static policy's queue goes *unstable* (offered load `ρ` crosses 1,
//! sojourn grows linearly with time) while the adaptive policy detects the
//! drift within a few jobs and returns to a stable steady state — orders
//! of magnitude apart in sojourn p99.

use crate::allocation::{proposed_allocation, proposed_allocation_capped};
use crate::math::{Rng, Summary};
use crate::model::{
    CensoredSample, ClusterSpec, EstimatorConfig, LatencyModel,
    SpeedEstimator,
};
use crate::workload::arrivals::ArrivalProcess;
use crate::{Error, Result};

/// One scripted change to the true cluster, keyed by model time.
#[derive(Clone, Debug, PartialEq)]
pub enum DriftKind {
    /// Group-level slowdown (time dilation): `α ← f·α`, `μ ← μ/f`.
    SlowGroup {
        /// Group index.
        group: usize,
        /// Time-dilation factor (`> 1` = slower).
        factor: f64,
    },
    /// Tail-only drift: `μ ← f·μ`.
    ScaleGroupMu {
        /// Group index.
        group: usize,
        /// Multiplicative μ factor.
        factor: f64,
    },
    /// Permanent deaths of `count` workers in a group.
    KillWorkers {
        /// Group index.
        group: usize,
        /// Workers lost.
        count: usize,
    },
}

/// A [`DriftKind`] taking effect at model time `at`.
#[derive(Clone, Debug, PartialEq)]
pub struct DriftEvent {
    /// Model time the event fires at.
    pub at: f64,
    /// What happens.
    pub kind: DriftKind,
}

/// An ordered script of drift events over model time.
#[derive(Clone, Debug, Default)]
pub struct DriftSchedule {
    events: Vec<DriftEvent>,
}

impl DriftSchedule {
    /// Build a schedule, validating and sorting by time (stable).
    pub fn new(mut events: Vec<DriftEvent>) -> Result<DriftSchedule> {
        for e in &events {
            if !e.at.is_finite() || e.at < 0.0 {
                return Err(Error::InvalidSpec(format!(
                    "drift event time must be finite and nonnegative, got {}",
                    e.at
                )));
            }
            match e.kind {
                DriftKind::SlowGroup { factor, .. }
                | DriftKind::ScaleGroupMu { factor, .. } => {
                    if !(factor > 0.0) || !factor.is_finite() {
                        return Err(Error::InvalidSpec(format!(
                            "drift factor must be positive and finite, got {factor}"
                        )));
                    }
                }
                DriftKind::KillWorkers { count, .. } => {
                    if count == 0 {
                        return Err(Error::InvalidSpec(
                            "KillWorkers with count 0".into(),
                        ));
                    }
                }
            }
        }
        events.sort_by(|a, b| a.at.total_cmp(&b.at));
        Ok(DriftSchedule { events })
    }

    /// The empty schedule.
    pub fn none() -> DriftSchedule {
        DriftSchedule::default()
    }

    /// No events scripted?
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Scripted events, ordered by time.
    pub fn events(&self) -> &[DriftEvent] {
        &self.events
    }

    /// The true cluster at model time `t`: effective parameters and alive
    /// worker counts per group. Errors if an event references a group the
    /// spec does not have.
    pub fn state_at(
        &self,
        base: &ClusterSpec,
        t: f64,
    ) -> Result<(ClusterSpec, Vec<usize>)> {
        let mut spec = base.clone();
        let mut alive: Vec<usize> = base.groups.iter().map(|g| g.n).collect();
        let ng = spec.num_groups();
        let check = move |g: usize| -> Result<()> {
            if g >= ng {
                return Err(Error::InvalidSpec(format!(
                    "drift event references group {g}, cluster has {ng}"
                )));
            }
            Ok(())
        };
        for e in self.events.iter().take_while(|e| e.at <= t) {
            match e.kind {
                DriftKind::SlowGroup { group, factor } => {
                    check(group)?;
                    spec.groups[group].alpha *= factor;
                    spec.groups[group].mu /= factor;
                }
                DriftKind::ScaleGroupMu { group, factor } => {
                    check(group)?;
                    spec.groups[group].mu *= factor;
                }
                DriftKind::KillWorkers { group, count } => {
                    check(group)?;
                    alive[group] = alive[group].saturating_sub(count);
                }
            }
        }
        Ok((spec, alive))
    }

    /// Parse the CLI mini-syntax `TIME:GROUP:FACTOR[;...]` into a schedule
    /// of [`DriftKind::SlowGroup`] events (the time-indexed dialect of
    /// [`crate::coordinator::FailureScenario::parse`]'s `--drift` syntax).
    pub fn parse(spec: &str) -> Result<DriftSchedule> {
        use crate::coordinator::failures::parse_num;
        let mut events = Vec::new();
        for part in spec.split(';').filter(|s| !s.is_empty()) {
            let fields: Vec<&str> = part.split(':').collect();
            if fields.len() != 3 {
                return Err(Error::InvalidSpec(format!(
                    "--drift entry `{part}` is not TIME:GROUP:FACTOR"
                )));
            }
            events.push(DriftEvent {
                at: parse_num::<f64>("drift time", fields[0])?,
                kind: DriftKind::SlowGroup {
                    group: parse_num::<usize>("drift group", fields[1])?,
                    factor: parse_num::<f64>("drift factor", fields[2])?,
                },
            });
        }
        DriftSchedule::new(events)
    }
}

/// Per-group cursor of the Rényi order-statistic stream (ascending worker
/// completion times in O(1) per step), plus the censored-observation
/// accumulator for the estimator.
#[derive(Clone, Copy, Debug, Default)]
struct ObsCursor {
    time: f64,
    e: f64,
    shift: f64,
    scale: f64,
    load: f64,
    remaining: usize,
    // Consumed-responder statistics (what the master observed).
    r: usize,
    min_t: f64,
    sum_t: f64,
    max_t: f64,
}

/// Sample one job's completion time on the true cluster `(spec, alive)`
/// under per-group loads, recording per-group consumed-responder
/// statistics into `cursors`. Returns `None` when the surviving loaded
/// capacity cannot reach `k` (the job would hang forever).
fn sample_job(
    spec: &ClusterSpec,
    alive: &[usize],
    loads: &[f64],
    model: LatencyModel,
    rng: &mut Rng,
    cursors: &mut Vec<ObsCursor>,
) -> Option<f64> {
    let k = spec.k as f64;
    cursors.clear();
    for ((g, &n_alive), &l) in spec.groups.iter().zip(alive).zip(loads) {
        let (shift, scale) = match model {
            LatencyModel::A => (g.alpha * l / k, l / (k * g.mu)),
            LatencyModel::B => (g.alpha * l, l / g.mu),
        };
        let mut c = ObsCursor {
            shift,
            scale,
            load: l,
            min_t: f64::INFINITY,
            max_t: f64::NEG_INFINITY,
            ..Default::default()
        };
        if n_alive == 0 || !(l > 0.0) {
            c.time = f64::INFINITY;
            c.remaining = 0;
        } else {
            let e = rng.exp1() / n_alive as f64;
            c.e = e;
            c.time = shift + scale * e;
            c.remaining = n_alive - 1;
        }
        cursors.push(c);
    }
    let mut cum = 0.0;
    loop {
        let mut g = 0usize;
        let mut best = cursors[0].time;
        for (j, c) in cursors.iter().enumerate().skip(1) {
            if c.time < best {
                best = c.time;
                g = j;
            }
        }
        if !best.is_finite() {
            return None; // every worker consumed, k never reached
        }
        let c = &mut cursors[g];
        c.r += 1;
        c.min_t = c.min_t.min(best);
        c.max_t = c.max_t.max(best);
        c.sum_t += best;
        cum += c.load;
        if cum >= k - 1e-9 {
            return Some(best);
        }
        if c.remaining == 0 {
            c.time = f64::INFINITY;
        } else {
            c.e += rng.exp1() / c.remaining as f64;
            c.remaining -= 1;
            c.time = c.shift + c.scale * c.e;
        }
    }
}

/// How the master reacts to the drifting truth.
#[derive(Clone, Copy, Debug)]
pub enum AdaptPolicy {
    /// Keep the t = 0 allocation forever (the paper's assumption).
    Static,
    /// Estimate `(μ̂, α̂)` online and re-solve on deviation or membership
    /// change.
    Adaptive(EstimatorConfig),
}

impl AdaptPolicy {
    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            AdaptPolicy::Static => "static",
            AdaptPolicy::Adaptive(_) => "adaptive",
        }
    }
}

/// Configuration of one drift experiment run.
#[derive(Clone, Copy, Debug)]
pub struct DriftWorkloadConfig {
    /// Traffic model.
    pub arrivals: ArrivalProcess,
    /// Jobs to simulate.
    pub jobs: usize,
    /// Base seed (arrivals and services use split substreams).
    pub seed: u64,
}

/// One re-allocation the adaptive policy performed.
#[derive(Clone, Debug)]
pub struct Realloc {
    /// Model time of the re-solve.
    pub at: f64,
    /// Job index that triggered it.
    pub job: usize,
    /// The spec the allocator believed (estimated parameters, observed
    /// membership).
    pub assumed: ClusterSpec,
    /// The new per-group loads.
    pub loads: Vec<f64>,
}

/// Outcome of one [`run_workload_drift`] run.
#[derive(Clone, Debug)]
pub struct DriftReport {
    /// Policy display name.
    pub policy: String,
    /// Arrival time of job `i`.
    pub arrivals: Vec<f64>,
    /// Service start of job `i`.
    pub starts: Vec<f64>,
    /// Completion of job `i`.
    pub finishes: Vec<f64>,
    /// Re-allocations performed (empty for static).
    pub reallocations: Vec<Realloc>,
    /// Sojourn times over the whole run (retains samples).
    pub sojourn: Summary,
}

impl DriftReport {
    /// Sojourn summary over jobs arriving at or after `t0` (steady-state
    /// windows: pass the post-drift settle point).
    pub fn sojourn_after(&self, t0: f64) -> Summary {
        let mut s = Summary::keeping_samples();
        for i in 0..self.arrivals.len() {
            if self.arrivals[i] >= t0 {
                s.add(self.finishes[i] - self.arrivals[i]);
            }
        }
        s
    }

    /// Sojourn percentile over jobs arriving at or after `t0`.
    pub fn sojourn_percentile_after(&self, t0: f64, p: f64) -> f64 {
        self.sojourn_after(t0).percentile(p)
    }
}

/// Run the drift experiment: a single-slot FIFO queue over the paper's
/// cluster whose true parameters follow `schedule`, served under `policy`.
/// The allocation starts at the proposed optimum for the initial spec;
/// the adaptive policy may re-solve under the initial coded-row budget
/// (`n` is fixed at t = 0 — re-allocating re-slices, never re-encodes).
/// Bit-reproducible from `cfg.seed`.
pub fn run_workload_drift(
    spec: &ClusterSpec,
    model: LatencyModel,
    cfg: &DriftWorkloadConfig,
    schedule: &DriftSchedule,
    policy: &AdaptPolicy,
) -> Result<DriftReport> {
    if cfg.jobs == 0 {
        return Err(Error::InvalidSpec("drift run needs at least one job".into()));
    }
    if let AdaptPolicy::Adaptive(est_cfg) = policy {
        est_cfg.validate()?;
    }
    let alloc0 = proposed_allocation(model, spec)?;
    let n_budget = alloc0.n;
    let mut loads = alloc0.loads.clone();
    let mut assumed = spec.clone();
    let mut estimator = match policy {
        AdaptPolicy::Adaptive(c) => {
            Some(SpeedEstimator::new(spec.num_groups(), model, spec.k, c.window)?)
        }
        AdaptPolicy::Static => None,
    };

    let mut root = Rng::new(cfg.seed);
    let mut arrival_rng = root.split();
    let mut service_rng = root.split();
    let arrivals = cfg.arrivals.times(cfg.jobs, &mut arrival_rng)?;

    let mut starts = Vec::with_capacity(cfg.jobs);
    let mut finishes = Vec::with_capacity(cfg.jobs);
    let mut sojourn = Summary::keeping_samples();
    let mut reallocations = Vec::new();
    let mut cursors: Vec<ObsCursor> = Vec::with_capacity(spec.num_groups());
    let mut free = 0.0f64;
    let mut since_check = 0usize;
    for (i, &arr) in arrivals.iter().enumerate() {
        let start = arr.max(free);
        let (eff_spec, alive) = schedule.state_at(spec, start)?;

        // Membership changes are observed (heartbeats), so the adaptive
        // policy reacts to deaths immediately; speeds need estimation.
        if let (Some(est), AdaptPolicy::Adaptive(ec)) = (&mut estimator, policy)
        {
            let membership_changed = assumed
                .groups
                .iter()
                .zip(&alive)
                .any(|(g, &a)| g.n != a);
            // Drift checks run on the configured cadence (membership
            // changes are reacted to immediately); resetting the counter
            // per check — not per re-allocation — keeps `check_every` an
            // actual period rather than a one-time warm-up.
            let mut drifted = false;
            if since_check >= ec.check_every {
                since_check = 0;
                drifted = est.deviates_from(&assumed, ec.threshold, ec.min_obs);
            }
            if membership_changed || drifted {
                since_check = 0;
                let est_spec = est.estimated_spec(&assumed, &alive, ec.min_obs)?;
                let re = proposed_allocation_capped(model, &est_spec, n_budget)?;
                loads = re.loads;
                assumed = est_spec;
                est.flush();
                reallocations.push(Realloc {
                    at: start,
                    job: i,
                    assumed: assumed.clone(),
                    loads: loads.clone(),
                });
            }
        }

        let Some(completion) = sample_job(
            &eff_spec,
            &alive,
            &loads,
            model,
            &mut service_rng,
            &mut cursors,
        ) else {
            return Err(Error::InvalidSpec(format!(
                "cluster lost decodability at t = {start:.4} (job {i}): \
                 surviving loaded capacity < k under policy `{}`",
                policy.name()
            )));
        };
        let finish = start + completion;
        starts.push(start);
        finishes.push(finish);
        sojourn.add(finish - arr);
        free = finish;

        if let Some(est) = &mut estimator {
            for (g, c) in cursors.iter().enumerate() {
                if c.r > 0 {
                    // The master's observation horizon is the job's
                    // completion: every silent worker is known to still
                    // be computing at that instant.
                    est.observe_stats(
                        g,
                        c.load,
                        CensoredSample {
                            r: c.r,
                            n: alive[g],
                            min_t: c.min_t,
                            sum_t: c.sum_t,
                            max_t: c.max_t,
                            censor_t: completion,
                        },
                    );
                }
            }
            since_check += 1;
        }
    }
    Ok(DriftReport {
        policy: policy.name().to_string(),
        arrivals,
        starts,
        finishes,
        reallocations,
        sojourn,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Group;

    fn spec3() -> ClusterSpec {
        ClusterSpec::new(
            vec![
                Group { n: 6, mu: 8.0, alpha: 1.0 },
                Group { n: 8, mu: 4.0, alpha: 1.0 },
                Group { n: 10, mu: 1.0, alpha: 1.0 },
            ],
            1000,
        )
        .unwrap()
    }

    #[test]
    fn schedule_state_tracks_events_in_time_order() {
        let s = DriftSchedule::new(vec![
            DriftEvent {
                at: 10.0,
                kind: DriftKind::SlowGroup { group: 0, factor: 2.0 },
            },
            DriftEvent {
                at: 5.0,
                kind: DriftKind::KillWorkers { group: 2, count: 3 },
            },
            DriftEvent {
                at: 20.0,
                kind: DriftKind::ScaleGroupMu { group: 1, factor: 0.5 },
            },
        ])
        .unwrap();
        let base = spec3();
        let (sp, alive) = s.state_at(&base, 0.0).unwrap();
        assert_eq!(sp, base);
        assert_eq!(alive, vec![6, 8, 10]);
        let (sp, alive) = s.state_at(&base, 7.0).unwrap();
        assert_eq!(alive, vec![6, 8, 7]);
        assert_eq!(sp.groups[0].mu, 8.0);
        let (sp, _) = s.state_at(&base, 15.0).unwrap();
        assert_eq!(sp.groups[0].mu, 4.0);
        assert_eq!(sp.groups[0].alpha, 2.0);
        assert_eq!(sp.groups[1].mu, 4.0);
        let (sp, _) = s.state_at(&base, 25.0).unwrap();
        assert_eq!(sp.groups[1].mu, 2.0);
        assert_eq!(sp.groups[1].alpha, 1.0, "mu drift keeps the shift");
    }

    #[test]
    fn schedule_validation_and_parsing() {
        assert!(DriftSchedule::new(vec![DriftEvent {
            at: -1.0,
            kind: DriftKind::SlowGroup { group: 0, factor: 2.0 },
        }])
        .is_err());
        assert!(DriftSchedule::new(vec![DriftEvent {
            at: 0.0,
            kind: DriftKind::ScaleGroupMu { group: 0, factor: 0.0 },
        }])
        .is_err());
        assert!(DriftSchedule::new(vec![DriftEvent {
            at: 0.0,
            kind: DriftKind::KillWorkers { group: 0, count: 0 },
        }])
        .is_err());
        let s = DriftSchedule::parse("10:0:2.0;20:1:1.5").unwrap();
        assert_eq!(s.events().len(), 2);
        assert_eq!(
            s.events()[0].kind,
            DriftKind::SlowGroup { group: 0, factor: 2.0 }
        );
        assert!(DriftSchedule::parse("10:0").is_err());
        assert!(DriftSchedule::parse("x:0:2").is_err());
        // Out-of-range group surfaces at state_at.
        let s = DriftSchedule::parse("1:9:2.0").unwrap();
        assert!(s.state_at(&spec3(), 2.0).is_err());
    }

    #[test]
    fn no_drift_static_matches_mg1_expectations() {
        // Sanity: with an empty schedule the drift runner is an ordinary
        // M/G/1 run at the proposed allocation — utilization-style checks
        // come from the queue module; here just determinism + stability.
        let spec = spec3();
        let cfg = DriftWorkloadConfig {
            arrivals: ArrivalProcess::Poisson { rate: 4.0 },
            jobs: 500,
            seed: 31,
        };
        let a = run_workload_drift(
            &spec,
            LatencyModel::A,
            &cfg,
            &DriftSchedule::none(),
            &AdaptPolicy::Static,
        )
        .unwrap();
        let b = run_workload_drift(
            &spec,
            LatencyModel::A,
            &cfg,
            &DriftSchedule::none(),
            &AdaptPolicy::Static,
        )
        .unwrap();
        assert_eq!(a.sojourn.mean(), b.sojourn.mean());
        assert_eq!(a.finishes, b.finishes);
        assert!(a.reallocations.is_empty());
        // FIFO invariants.
        assert!(a.starts.windows(2).all(|w| w[1] >= w[0]));
        for i in 0..a.arrivals.len() {
            assert!(a.starts[i] >= a.arrivals[i]);
            assert!(a.finishes[i] > a.starts[i]);
        }
    }

    #[test]
    fn adaptive_with_no_drift_does_not_thrash() {
        // False-positive guard: on a stable cluster the estimator must not
        // keep re-solving.
        let spec = spec3();
        let cfg = DriftWorkloadConfig {
            arrivals: ArrivalProcess::Poisson { rate: 4.0 },
            jobs: 800,
            seed: 32,
        };
        let rep = run_workload_drift(
            &spec,
            LatencyModel::A,
            &cfg,
            &DriftSchedule::none(),
            &AdaptPolicy::Adaptive(EstimatorConfig::default()),
        )
        .unwrap();
        assert!(
            rep.reallocations.is_empty(),
            "{} spurious re-allocations",
            rep.reallocations.len()
        );
    }

    #[test]
    fn adaptive_recovers_from_worker_deaths() {
        // Kill enough of the biggest group that the static allocation's
        // surviving rows cannot cover k: static fails, adaptive observes
        // the membership change, re-solves within the original coded-row
        // budget, and keeps serving.
        let spec = spec3();
        let alloc = proposed_allocation(LatencyModel::A, &spec).unwrap();
        // Loads are near-critical (n/k ~ 1.2): losing 8 of group 2's 10
        // workers drops static capacity below k.
        let lost_rows: f64 = alloc.loads[2] * 8.0;
        assert!(
            alloc.n - lost_rows < spec.k as f64,
            "test premise: deaths must break static decodability \
             (n {} - lost {lost_rows} vs k {})",
            alloc.n,
            spec.k
        );
        let schedule = DriftSchedule::new(vec![DriftEvent {
            at: 30.0,
            kind: DriftKind::KillWorkers { group: 2, count: 8 },
        }])
        .unwrap();
        let cfg = DriftWorkloadConfig {
            arrivals: ArrivalProcess::Poisson { rate: 2.0 },
            jobs: 400,
            seed: 33,
        };
        let static_run = run_workload_drift(
            &spec,
            LatencyModel::A,
            &cfg,
            &schedule,
            &AdaptPolicy::Static,
        );
        assert!(static_run.is_err(), "static must lose decodability");
        let adaptive = run_workload_drift(
            &spec,
            LatencyModel::A,
            &cfg,
            &schedule,
            &AdaptPolicy::Adaptive(EstimatorConfig::default()),
        )
        .unwrap();
        assert_eq!(adaptive.finishes.len(), 400);
        assert!(!adaptive.reallocations.is_empty());
        // The re-solve observed the shrunken membership.
        let re = &adaptive.reallocations[0];
        assert_eq!(re.assumed.groups[2].n, 2);
    }
}
