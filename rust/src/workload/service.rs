//! Per-job service-time samplers.
//!
//! In the queueing view of the system, "service" of one job is the whole
//! coded fan-out/fan-in: encode dispatch, straggling workers, and the
//! decode barrier at `k` aggregated rows. Its duration is therefore exactly
//! the single-job completion time the paper analyzes (§II-C), so the
//! samplers here are the simulator's [`AnyKSampler`] / [`GroupMaxSampler`]
//! wrapped per policy: one draw = one job's service time.

use crate::allocation::{Allocation, DecodeRule, Policy};
use crate::math::Rng;
use crate::model::{ClusterSpec, LatencyModel};
use crate::sim::{AnyKSampler, GroupMaxSampler, Scheme};
use crate::{Error, Result};

/// A policy-specific sampler of i.i.d. single-job service times.
#[derive(Clone, Debug)]
pub enum ServiceSampler {
    /// Any-`k` MDS decode over the whole matrix (proposed, uniform,
    /// uncoded, and the scheme of [32]).
    AnyK(AnyKSampler),
    /// Group-wise decode of the fixed-`r` group code of [33]: the job
    /// completes when *every* group has returned its `r_j` results.
    GroupMax(GroupMaxSampler),
    /// Rateless (any-`k` fountain) serving over a uniformly lossy fabric:
    /// landing one useful row over a link that drops each packet i.i.d.
    /// with probability `p` costs `1/(1-p)` streamed rows in expectation,
    /// and because both the shift and the scale of every worker's latency
    /// law are linear in its load, inflating all loads by that factor
    /// scales every finish time — and hence the whole any-`k` completion
    /// law — by exactly `inflation`.
    LossyAnyK {
        /// The loss-free any-`k` sampler over the policy's allocation.
        inner: AnyKSampler,
        /// Expected streamed-rows-per-useful-row factor `1/(1-p)`.
        inflation: f64,
    },
    /// Hedged any-`k` serving: if the primary fan-out has not completed by
    /// `trigger` (the hedge deadline), the job is speculatively re-issued
    /// to spare capacity and the first completion wins —
    /// `S = min(S₁, trigger + S₂)` with `S₁, S₂` i.i.d. draws of the
    /// clean any-`k` law. The queueing-layer mirror of the live in-batch
    /// recovery engine ([`crate::coordinator::recovery`]), with the same
    /// idealization the [`ServiceSampler::LossyAnyK`] mirror makes for
    /// streamed loss: retry *waves* are folded into one independent
    /// re-draw rather than simulated wave by wave.
    Hedged {
        /// The clean any-`k` sampler over the policy's allocation.
        inner: AnyKSampler,
        /// Model-time hedge trigger (e.g. the p95 of the completion law).
        trigger: f64,
    },
}

impl ServiceSampler {
    /// Draw one job's service time.
    pub fn sample(&mut self, rng: &mut Rng) -> f64 {
        match self {
            ServiceSampler::AnyK(s) => s.sample(rng),
            ServiceSampler::GroupMax(s) => s.sample(rng),
            ServiceSampler::LossyAnyK { inner, inflation } => {
                *inflation * inner.sample(rng)
            }
            ServiceSampler::Hedged { inner, trigger } => {
                let s1 = inner.sample(rng);
                if s1 <= *trigger {
                    // The hedge never fires — one draw, like the clean law
                    // (and the RNG stream stays aligned with it).
                    s1
                } else {
                    s1.min(*trigger + inner.sample(rng))
                }
            }
        }
    }
}

/// Build any [`Policy`]'s allocation on `spec` together with its
/// service-time sampler — the sampler family follows the policy's
/// [`DecodeRule`], so registry-resolved policies plug straight into the
/// queueing layer.
pub fn service_sampler_for(
    spec: &ClusterSpec,
    policy: &dyn Policy,
    model: LatencyModel,
) -> Result<(Allocation, ServiceSampler)> {
    let alloc = policy.allocate(model, spec)?;
    let sampler = match policy.decode_rule() {
        DecodeRule::PerGroup => ServiceSampler::GroupMax(GroupMaxSampler::new(
            spec,
            &alloc.loads,
            &alloc.r,
            model,
        )?),
        DecodeRule::AnyK => {
            ServiceSampler::AnyK(AnyKSampler::new(spec, &alloc.loads, model)?)
        }
    };
    Ok((alloc, sampler))
}

/// Build `scheme`'s allocation on `spec` together with its service-time
/// sampler ([`service_sampler_for`] over the scheme's [`Policy`] object).
pub fn service_sampler(
    spec: &ClusterSpec,
    scheme: Scheme,
    model: LatencyModel,
) -> Result<(Allocation, ServiceSampler)> {
    service_sampler_for(spec, &*scheme.policy(), model)
}

/// Build a policy's allocation together with its service-time law under
/// rateless serving over a uniformly lossy fabric — the queueing-layer
/// mirror of the live streamed collection (`run --code rateless-rlc
/// --loss`). Per-packet loss with probability `loss` inflates the
/// expected streamed rows per useful row by `1/(1-loss)`, and the any-`k`
/// completion law scales by exactly that factor (see
/// [`ServiceSampler::LossyAnyK`]); the solicitation rounds of the live
/// collection loop are folded into that expectation rather than simulated
/// round by round. Heterogeneous per-group loss belongs to the live
/// scenario layer ([`crate::coordinator::failures`]) — a single scaling
/// factor cannot represent it, so this mirror takes one fabric-wide `p`.
///
/// The fountain decodes any-`k` by construction, so group-decode policies
/// have no lossy mirror and are rejected.
pub fn lossy_service_sampler(
    spec: &ClusterSpec,
    policy: &dyn Policy,
    model: LatencyModel,
    loss: f64,
) -> Result<(Allocation, ServiceSampler)> {
    if !(0.0..1.0).contains(&loss) {
        return Err(Error::InvalidSpec(format!(
            "per-packet loss probability must be in [0, 1), got {loss}"
        )));
    }
    let (alloc, base) = service_sampler_for(spec, policy, model)?;
    let inner = match base {
        ServiceSampler::AnyK(s) => s,
        _ => {
            return Err(Error::InvalidSpec(
                "group-decode policies have no rateless mirror: the \
                 fountain decodes any-k"
                    .into(),
            ))
        }
    };
    let inflation = 1.0 / (1.0 - loss);
    Ok((alloc, ServiceSampler::LossyAnyK { inner, inflation }))
}

/// Build a policy's allocation together with its service-time law under
/// hedged serving ([`ServiceSampler::Hedged`]): one speculative re-issue
/// at `trigger` model-time units, first completion wins. `trigger` is the
/// hedge deadline in the same model-time units the samplers draw in —
/// derive it from the completion law's quantile (e.g.
/// [`crate::model::order_stats::hedge_deadline`]) to mirror the live
/// engine's deadline staging.
///
/// Hedging re-dispatches through the any-`k` decode (spare MDS rows /
/// fresh rateless rows), so group-decode policies are rejected like they
/// are for the lossy mirror.
pub fn hedged_service_sampler(
    spec: &ClusterSpec,
    policy: &dyn Policy,
    model: LatencyModel,
    trigger: f64,
) -> Result<(Allocation, ServiceSampler)> {
    if !trigger.is_finite() || trigger <= 0.0 {
        return Err(Error::InvalidSpec(format!(
            "hedge trigger must be positive and finite, got {trigger}"
        )));
    }
    let (alloc, base) = service_sampler_for(spec, policy, model)?;
    let inner = match base {
        ServiceSampler::AnyK(s) => s,
        _ => {
            return Err(Error::InvalidSpec(
                "group-decode policies have no hedged mirror: hedges \
                 re-dispatch through the any-k decode"
                    .into(),
            ))
        }
    };
    Ok((alloc, ServiceSampler::Hedged { inner, trigger }))
}

/// Estimate the mean service time `E[S]` with `samples` deterministic
/// draws. Used to convert offered-load fractions `ρ` into absolute arrival
/// rates `λ = ρ / E[S]` before a sweep.
pub fn mean_service(sampler: &mut ServiceSampler, samples: usize, seed: u64) -> f64 {
    let samples = samples.max(1);
    let mut rng = Rng::new(seed);
    let mut sum = 0.0;
    for _ in 0..samples {
        sum += sampler.sample(&mut rng);
    }
    sum / samples as f64
}

/// A policy's saturation arrival rate `1/E[S]` — the M/G/1 stability
/// boundary, and the serving-side meaning of the paper's Theorem 2: a
/// policy that shaves expected single-job latency sustains proportionally
/// more traffic. Estimated from `samples` deterministic draws.
pub fn saturation_rate(sampler: &mut ServiceSampler, samples: usize, seed: u64) -> f64 {
    1.0 / mean_service(sampler, samples, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::order_stats;

    #[test]
    fn every_scheme_yields_a_sampler() {
        let spec = ClusterSpec::paper_two_group(10_000);
        for scheme in [
            Scheme::Proposed,
            Scheme::Uncoded,
            Scheme::UniformWithOptimalN,
            Scheme::UniformRate(0.5),
            Scheme::GroupCode(100.0),
            Scheme::Reisizadeh,
        ] {
            let (alloc, mut sampler) =
                service_sampler(&spec, scheme, LatencyModel::A)
                    .unwrap_or_else(|e| panic!("{}: {e}", scheme.name()));
            alloc.validate(&spec).unwrap();
            let mut rng = Rng::new(9);
            let s = sampler.sample(&mut rng);
            assert!(s.is_finite() && s > 0.0, "{}: sample {s}", scheme.name());
        }
    }

    #[test]
    fn lossy_sampler_scales_the_clean_law_by_the_row_inflation() {
        // Same seed drives both samplers, so every lossy draw must be the
        // clean draw times 1/(1-p) bit-for-bit — the model is a pure
        // rescaling of the any-k law, not a different stochastic process.
        let spec = ClusterSpec::paper_two_group(10_000);
        let (_, mut clean) =
            service_sampler(&spec, Scheme::Proposed, LatencyModel::A).unwrap();
        let (_, mut lossy) = lossy_service_sampler(
            &spec,
            &*Scheme::Proposed.policy(),
            LatencyModel::A,
            0.2,
        )
        .unwrap();
        let inflation = 1.0 / (1.0 - 0.2);
        let (mut a, mut b) = (Rng::new(41), Rng::new(41));
        for _ in 0..200 {
            let c = clean.sample(&mut a);
            assert_eq!(lossy.sample(&mut b), inflation * c);
        }
    }

    #[test]
    fn hedged_sampler_is_first_completion_of_two_clean_draws() {
        // Same seed drives both samplers: every hedged draw must equal
        // min(s1, trigger + s2) computed from the clean law by hand —
        // with the second draw consumed only when the hedge fires, so
        // hedge-free samples leave the RNG stream aligned with the clean
        // sampler's.
        let spec = ClusterSpec::paper_two_group(10_000);
        let (_, mut clean) =
            service_sampler(&spec, Scheme::Proposed, LatencyModel::A).unwrap();
        // Trigger near the clean median so both branches get exercised.
        let trigger = mean_service(&mut clean, 2_000, 5);
        let (_, mut hedged) = hedged_service_sampler(
            &spec,
            &*Scheme::Proposed.policy(),
            LatencyModel::A,
            trigger,
        )
        .unwrap();
        let (mut a, mut b) = (Rng::new(43), Rng::new(43));
        let (mut fired, mut skipped) = (0usize, 0usize);
        for _ in 0..500 {
            let s1 = clean.sample(&mut a);
            let want = if s1 <= trigger {
                skipped += 1;
                s1
            } else {
                fired += 1;
                s1.min(trigger + clean.sample(&mut a))
            };
            let got = hedged.sample(&mut b);
            assert_eq!(got, want);
            assert!(got <= s1, "hedging never hurts a single job");
        }
        assert!(fired > 0 && skipped > 0, "fired {fired} skipped {skipped}");
        // A hedged draw never exceeds trigger + a fresh service time, so
        // the tail is capped: E[S_hedged] <= E[S_clean].
        let (_, mut h2) = hedged_service_sampler(
            &spec,
            &*Scheme::Proposed.policy(),
            LatencyModel::A,
            trigger,
        )
        .unwrap();
        let (_, mut c2) =
            service_sampler(&spec, Scheme::Proposed, LatencyModel::A).unwrap();
        let eh = mean_service(&mut h2, 4_000, 11);
        let ec = mean_service(&mut c2, 4_000, 11);
        assert!(eh <= ec, "hedged mean {eh} vs clean {ec}");
    }

    #[test]
    fn hedged_sampler_rejects_group_decode_and_bad_triggers() {
        let spec = ClusterSpec::paper_two_group(10_000);
        let err = hedged_service_sampler(
            &spec,
            &*Scheme::GroupCode(100.0).policy(),
            LatencyModel::A,
            1.0,
        )
        .unwrap_err();
        assert!(err.to_string().contains("any-k"), "{err}");
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            assert!(
                hedged_service_sampler(
                    &spec,
                    &*Scheme::Proposed.policy(),
                    LatencyModel::A,
                    bad,
                )
                .is_err(),
                "trigger {bad} must be rejected"
            );
        }
    }

    #[test]
    fn lossy_sampler_rejects_group_decode_and_bad_probabilities() {
        let spec = ClusterSpec::paper_two_group(10_000);
        let err = lossy_service_sampler(
            &spec,
            &*Scheme::GroupCode(100.0).policy(),
            LatencyModel::A,
            0.1,
        )
        .unwrap_err();
        assert!(err.to_string().contains("any-k"), "{err}");
        for bad in [-0.1, 1.0, 1.5] {
            assert!(
                lossy_service_sampler(
                    &spec,
                    &*Scheme::Proposed.policy(),
                    LatencyModel::A,
                    bad,
                )
                .is_err(),
                "loss {bad} must be rejected"
            );
        }
    }

    #[test]
    fn mean_service_matches_closed_form_single_group() {
        // Uncoded on a single group: every one of the N workers must finish
        // its l = k/N rows, so E[S] is the N-th order statistic's mean,
        // (l/k)(α + (H_N − H_0)/μ) — closed form via `group_latency_exact`.
        let (n, k) = (40usize, 1000usize);
        let spec = crate::model::ClusterSpec::new(
            vec![crate::model::Group { n, mu: 2.0, alpha: 1.0 }],
            k,
        )
        .unwrap();
        let (_, mut sampler) =
            service_sampler(&spec, Scheme::Uncoded, LatencyModel::A).unwrap();
        let est = mean_service(&mut sampler, 20_000, 7);
        let exact = order_stats::group_latency_exact(
            LatencyModel::A,
            k as f64 / n as f64,
            k as f64,
            n as u64,
            n as u64,
            2.0,
            1.0,
        );
        assert!(
            (est - exact).abs() / exact < 0.02,
            "MC {est} vs exact {exact}"
        );
    }

    #[test]
    fn saturation_rate_inverts_mean_service() {
        let spec = ClusterSpec::paper_two_group(10_000);
        let (_, mut s1) =
            service_sampler(&spec, Scheme::Proposed, LatencyModel::A).unwrap();
        let (_, mut s2) =
            service_sampler(&spec, Scheme::Proposed, LatencyModel::A).unwrap();
        let es = mean_service(&mut s1, 500, 3);
        let sat = saturation_rate(&mut s2, 500, 3);
        assert!((sat * es - 1.0).abs() < 1e-12, "sat {sat} es {es}");
    }

    #[test]
    fn mean_service_is_deterministic() {
        let spec = ClusterSpec::paper_two_group(10_000);
        let (_, mut s1) =
            service_sampler(&spec, Scheme::Proposed, LatencyModel::A).unwrap();
        let (_, mut s2) =
            service_sampler(&spec, Scheme::Proposed, LatencyModel::A).unwrap();
        assert_eq!(mean_service(&mut s1, 500, 3), mean_service(&mut s2, 500, 3));
    }
}
