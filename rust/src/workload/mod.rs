//! Workload layer: job arrivals, queueing, and throughput under load.
//!
//! The paper optimizes the latency of a **single** coded matvec job on a
//! heterogeneous cluster. A serving system, by contrast, faces a *stream*
//! of jobs; what matters is throughput, utilization, and the sojourn-time
//! tail. This module turns the one-shot simulator into that traffic model
//! in three stages:
//!
//! 1. **Arrivals** ([`ArrivalProcess`]) — deterministic-rate, Poisson, or
//!    bursty ON/OFF job streams, drawn from the repo's deterministic RNG;
//! 2. **Queue + dispatch** ([`simulate_queue`] / [`run_workload`]) — an
//!    unbounded FIFO queue in front of the cluster, which runs at most
//!    `servers` coded jobs at a time; each job in service draws its
//!    duration from the chosen policy's single-job completion-time law;
//! 3. **Metrics** ([`WorkloadReport`]) — throughput, utilization,
//!    queue-depth statistics, and sojourn-time percentiles (p50/p95/p99)
//!    alongside the existing expected-latency summaries.
//!
//! # How this maps onto the paper's single-job model
//!
//! The queueing model treats one coded job's fan-out → straggle → decode
//! cycle as an indivisible *service* whose duration is exactly the paper's
//! `T_{r:N}` (§II-C): the [`ServiceSampler`] draws it with the same Rényi
//! order-statistics merge the Monte-Carlo engine uses
//! ([`crate::sim::AnyKSampler`]). With Poisson arrivals and `servers = 1`
//! the system is an M/G/1 queue whose service distribution is the paper's
//! latency law — so the paper's headline quantity `E[T]` becomes the
//! service-side bottleneck `1/E[T]` on throughput, and allocation policies
//! that shave expected latency (Theorem 2) translate directly into extra
//! sustainable arrival rate before the queue blows up.
//!
//! The live counterpart is [`crate::coordinator::serve_arrivals`], which
//! replays an arrival trace against the thread coordinator with batched
//! dispatch (the `MatvecBatched` artifacts on the XLA backend).
//!
//! At million-request scale the single FIFO queue is itself the
//! bottleneck; the [`admission`] module generalizes it into a sharded,
//! multi-tenant front end — tenant-keyed shard queues, a work-stealing
//! drain, deficit-round-robin fairness ([`DrrQueue`]), and an SLO-aware
//! adaptive batch controller ([`BatchController`]) — that stays
//! bit-identical to [`simulate_queue`] in its degenerate one-shard,
//! one-tenant configuration ([`AdmissionConfig::fifo_parity`]).
//!
//! When the cluster itself is the moving part — workers dying, machines
//! slowing, group parameters drifting — the [`drift`] module scripts the
//! truth over model time and [`run_workload_drift`] compares the paper's
//! static allocation against the estimator-driven adaptive policy (the
//! live mirror is [`crate::coordinator::serve_arrivals_adaptive`]).
//!
//! When the *links* rather than the workers are unreliable, the fixed-`n`
//! service laws above stop applying — a dropped packet erases rows, not
//! workers — and the rateless fountain (`rateless-rlc`) streams extra
//! rows until any `k` survive. [`lossy_service_sampler`] is that path's
//! queueing mirror: the any-`k` law scaled by the expected row inflation
//! `1/(1-p)` under uniform per-packet loss `p`. The live counterpart is
//! the streamed collection loop behind `run --code rateless-rlc --loss`
//! (per-group loss scenarios live in [`crate::coordinator::failures`]).
//!
//! Deadline-driven hedging has a queueing mirror too:
//! [`hedged_service_sampler`] replaces the clean any-`k` law `S` with the
//! first-completion law `min(S₁, trigger + S₂)` — one fresh re-dispatch
//! fired when a job outlives its hedge trigger, the static analogue of
//! the live [`crate::coordinator::recovery`] engine's repair waves.
//!
//! # Example
//!
//! ```no_run
//! use hetcoded::model::{ClusterSpec, LatencyModel};
//! use hetcoded::sim::Scheme;
//! use hetcoded::workload::{run_workload, ArrivalProcess, WorkloadConfig};
//!
//! let spec = ClusterSpec::paper_two_group(10_000);
//! let cfg = WorkloadConfig {
//!     arrivals: ArrivalProcess::Poisson { rate: 5.0 },
//!     jobs: 2_000,
//!     servers: 1,
//!     seed: 2019,
//! };
//! let report = run_workload(&spec, Scheme::Proposed, LatencyModel::A, &cfg)?;
//! println!(
//!     "throughput {:.3}/s  util {:.2}  p99 sojourn {:.4}",
//!     report.throughput,
//!     report.utilization,
//!     report.sojourn_percentile(99.0),
//! );
//! # Ok::<(), hetcoded::Error>(())
//! ```

#![forbid(unsafe_code)]

pub mod admission;
pub mod arrivals;
pub mod drift;
pub mod queue;
pub mod service;

pub use admission::{
    generate_jobs, run_admission, simulate_admission, AdmissionConfig,
    AdmissionJob, AdmissionReport, BatchController, BatchPolicy, DrrQueue,
    SloConfig, TenantSpec,
};
pub use arrivals::ArrivalProcess;
pub use drift::{
    run_workload_drift, AdaptPolicy, DriftEvent, DriftKind, DriftReport,
    DriftSchedule, DriftWorkloadConfig, Realloc,
};
pub use queue::{
    run_workload, run_workload_policy, simulate_queue, QueueTrace,
    WorkloadConfig, WorkloadReport,
};
pub use service::{
    hedged_service_sampler, lossy_service_sampler, mean_service,
    saturation_rate, service_sampler, service_sampler_for, ServiceSampler,
};
