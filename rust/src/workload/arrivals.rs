//! Job arrival processes.
//!
//! A traffic model is a point process on the model-time axis; each point is
//! one matvec job submitted to the master. Three families cover the usual
//! serving regimes:
//!
//! - [`ArrivalProcess::Deterministic`] — a fixed-rate clock (closed-loop
//!   load generators, batch pipelines);
//! - [`ArrivalProcess::Poisson`] — memoryless open-loop traffic, the
//!   M/·/· baseline of queueing theory;
//! - [`ArrivalProcess::OnOff`] — an interrupted Poisson process
//!   (exponential ON bursts separated by exponential OFF silences), the
//!   standard bursty-traffic model.
//!
//! All draws go through the repo's deterministic [`Rng`], so a fixed seed
//! reproduces the exact arrival trace.

use crate::math::Rng;
use crate::{Error, Result};

/// A job arrival process (jobs per unit of model time).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// Evenly spaced arrivals: job `i` arrives at `i / rate`.
    Deterministic {
        /// Arrival rate `λ` (jobs per unit model time).
        rate: f64,
    },
    /// Poisson process: i.i.d. exponential interarrivals with mean `1/rate`.
    Poisson {
        /// Arrival rate `λ` (jobs per unit model time).
        rate: f64,
    },
    /// Bursty ON/OFF (interrupted Poisson) process: during an ON period
    /// (exponential, mean `mean_on`) arrivals are Poisson at `rate_on`;
    /// OFF periods (exponential, mean `mean_off`) are silent. The long-run
    /// mean rate is `rate_on · mean_on / (mean_on + mean_off)`.
    OnOff {
        /// Arrival rate during ON periods.
        rate_on: f64,
        /// Mean ON-period duration.
        mean_on: f64,
        /// Mean OFF-period duration.
        mean_off: f64,
    },
}

impl ArrivalProcess {
    /// Short display name for tables and CSV columns.
    pub fn name(&self) -> &'static str {
        match self {
            ArrivalProcess::Deterministic { .. } => "deterministic",
            ArrivalProcess::Poisson { .. } => "poisson",
            ArrivalProcess::OnOff { .. } => "onoff",
        }
    }

    /// Long-run mean arrival rate `λ`.
    pub fn mean_rate(&self) -> f64 {
        match *self {
            ArrivalProcess::Deterministic { rate }
            | ArrivalProcess::Poisson { rate } => rate,
            ArrivalProcess::OnOff { rate_on, mean_on, mean_off } => {
                rate_on * mean_on / (mean_on + mean_off)
            }
        }
    }

    /// Check all parameters are positive and finite.
    pub fn validate(&self) -> Result<()> {
        let bad = |name: &str, v: f64| {
            Err(Error::InvalidSpec(format!(
                "arrival process: {name} must be positive and finite, got {v}"
            )))
        };
        match *self {
            ArrivalProcess::Deterministic { rate }
            | ArrivalProcess::Poisson { rate } => {
                if !(rate > 0.0) || !rate.is_finite() {
                    return bad("rate", rate);
                }
            }
            ArrivalProcess::OnOff { rate_on, mean_on, mean_off } => {
                if !(rate_on > 0.0) || !rate_on.is_finite() {
                    return bad("rate_on", rate_on);
                }
                if !(mean_on > 0.0) || !mean_on.is_finite() {
                    return bad("mean_on", mean_on);
                }
                if !(mean_off > 0.0) || !mean_off.is_finite() {
                    return bad("mean_off", mean_off);
                }
            }
        }
        Ok(())
    }

    /// Generate the first `n` arrival times (ascending, deterministic given
    /// the `rng` state).
    pub fn times(&self, n: usize, rng: &mut Rng) -> Result<Vec<f64>> {
        self.validate()?;
        let mut out = Vec::with_capacity(n);
        match *self {
            ArrivalProcess::Deterministic { rate } => {
                for i in 1..=n {
                    out.push(i as f64 / rate);
                }
            }
            ArrivalProcess::Poisson { rate } => {
                let mut t = 0.0;
                for _ in 0..n {
                    t += rng.exp1() / rate;
                    out.push(t);
                }
            }
            ArrivalProcess::OnOff { rate_on, mean_on, mean_off } => {
                // Alternate ON/OFF windows; Poisson arrivals that would land
                // beyond the current ON window are discarded (the process
                // restarts afresh in the next window — memorylessness makes
                // this exact).
                let mut t = 0.0;
                while out.len() < n {
                    let on_end = t + rng.exp1() * mean_on;
                    let mut a = t;
                    loop {
                        a += rng.exp1() / rate_on;
                        if a > on_end || out.len() >= n {
                            break;
                        }
                        out.push(a);
                    }
                    t = on_end + rng.exp1() * mean_off;
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_is_evenly_spaced() {
        let mut rng = Rng::new(1);
        let ts = ArrivalProcess::Deterministic { rate: 4.0 }
            .times(8, &mut rng)
            .unwrap();
        assert_eq!(ts.len(), 8);
        for (i, &t) in ts.iter().enumerate() {
            assert!((t - (i as f64 + 1.0) / 4.0).abs() < 1e-12);
        }
    }

    #[test]
    fn poisson_mean_interarrival_matches_rate() {
        let mut rng = Rng::new(2);
        let rate = 3.0;
        let n = 50_000;
        let ts = ArrivalProcess::Poisson { rate }.times(n, &mut rng).unwrap();
        assert_eq!(ts.len(), n);
        assert!(ts.windows(2).all(|w| w[1] >= w[0]), "not sorted");
        let mean_gap = ts.last().unwrap() / n as f64;
        assert!(
            (mean_gap - 1.0 / rate).abs() < 0.01,
            "mean gap {mean_gap} vs {}",
            1.0 / rate
        );
    }

    #[test]
    fn onoff_long_run_rate_matches_formula() {
        let p = ArrivalProcess::OnOff {
            rate_on: 10.0,
            mean_on: 2.0,
            mean_off: 3.0,
        };
        assert!((p.mean_rate() - 4.0).abs() < 1e-12);
        let mut rng = Rng::new(3);
        let n = 50_000;
        let ts = p.times(n, &mut rng).unwrap();
        assert!(ts.windows(2).all(|w| w[1] >= w[0]), "not sorted");
        let emp_rate = n as f64 / ts.last().unwrap();
        assert!(
            (emp_rate - p.mean_rate()).abs() / p.mean_rate() < 0.05,
            "empirical {emp_rate} vs {}",
            p.mean_rate()
        );
    }

    #[test]
    fn deterministic_under_fixed_seed() {
        for p in [
            ArrivalProcess::Poisson { rate: 2.0 },
            ArrivalProcess::OnOff { rate_on: 8.0, mean_on: 1.0, mean_off: 1.0 },
        ] {
            let a = p.times(1000, &mut Rng::new(42)).unwrap();
            let b = p.times(1000, &mut Rng::new(42)).unwrap();
            assert_eq!(a, b, "{}", p.name());
        }
    }

    #[test]
    fn invalid_parameters_rejected() {
        let mut rng = Rng::new(4);
        for p in [
            ArrivalProcess::Poisson { rate: 0.0 },
            ArrivalProcess::Deterministic { rate: -1.0 },
            ArrivalProcess::Poisson { rate: f64::NAN },
            ArrivalProcess::OnOff { rate_on: 1.0, mean_on: 0.0, mean_off: 1.0 },
            ArrivalProcess::OnOff { rate_on: 1.0, mean_on: 1.0, mean_off: -2.0 },
        ] {
            assert!(p.validate().is_err());
            assert!(p.times(10, &mut rng).is_err());
        }
    }
}
