//! Sharded admission front end: multi-tenant queues, work-stealing drain,
//! and SLO-aware adaptive batching — the model-time simulator.
//!
//! [`simulate_queue`](crate::workload::simulate_queue) models one FIFO
//! queue in front of the cluster; at millions of arrivals that single
//! queue is the bottleneck the rest of the stack was optimized around.
//! This module generalizes it into the admission layer of ROADMAP item 4:
//!
//! 1. **Sharded queues** — arrivals are tenant-keyed onto
//!    [`AdmissionConfig::shards`] independent queues (`shard = tenant %
//!    shards`), so admission contention splits `shards` ways and every
//!    tenant's stream stays FIFO within its shard;
//! 2. **Work-stealing drain** — [`AdmissionConfig::drainers`] drain loops
//!    (the model-time mirror of threads feeding the persistent
//!    [`crate::runtime::pool::WorkPool`]) each own a home shard
//!    (`drainer % shards`) and, with [`AdmissionConfig::steal`] on, scan
//!    the other shards home-first-rotation when theirs is empty — idle
//!    capacity follows the backlog;
//! 3. **Deficit-round-robin fairness** — each shard's queue
//!    ([`DrrQueue`]) holds per-tenant FIFO subqueues drained by weighted
//!    deficit round robin, so a bursty tenant can saturate only its own
//!    weight share, not the whole batch;
//! 4. **SLO-aware batching** — a [`BatchController`] sizes the batch
//!    limit online from a sliding window of observed sojourns against a
//!    p99 target ([`SloConfig`]), with hysteresis: multiplicative growth
//!    under violation, slow additive shrink well below target.
//!
//! Batching pays because a coded batch amortizes its fixed per-dispatch
//! work (encode reuse, straggle realization, decode factorization — the
//! PR 2/5 hot path) across members: a `b`-job batch costs `S · (γ + (1-γ)
//! · b)` where `γ =` [`AdmissionConfig::amortize`] is the fixed fraction,
//! so per-drainer capacity approaches `1 / ((1-γ)·E[S])` as `b` grows —
//! the lever that lets adaptive batching absorb a load step that sinks a
//! fixed single-job drain.
//!
//! # Determinism
//!
//! The whole simulation is an event loop over a min-heap keyed
//! `(time_bits, drainer)` — exactly the [`WorkPool`]'s index-ordered
//! reduction pattern, so results are bit-reproducible from
//! [`AdmissionConfig::seed`]: tenant arrival streams draw from per-tenant
//! [`Rng::split`] substreams in tenant order, the merged job list is
//! sorted `(arrival, tenant, index)`, shard assignment is a pure function
//! of the tenant, and every tie (equal free times, equal next-arrival
//! rekeys) breaks on the drainer index. With `shards = 1`, one tenant,
//! stealing off and single-job batches, the RNG discipline and dispatch
//! order collapse to [`run_workload_policy`]'s exactly — the
//! [`AdmissionConfig::fifo_parity`] configuration is **bit-identical** to
//! the legacy FIFO path (pinned by `rust/tests/admission.rs`).
//!
//! [`WorkPool`]: crate::runtime::pool::WorkPool
//! [`run_workload_policy`]: crate::workload::run_workload_policy
//!
//! # Example
//!
//! ```no_run
//! use hetcoded::allocation::policy;
//! use hetcoded::model::{ClusterSpec, LatencyModel};
//! use hetcoded::workload::{
//!     run_admission, AdmissionConfig, ArrivalProcess, BatchPolicy,
//!     SloConfig, TenantSpec,
//! };
//!
//! let spec = ClusterSpec::paper_two_group(10_000);
//! let cfg = AdmissionConfig {
//!     tenants: (0..8)
//!         .map(|_| TenantSpec {
//!             arrivals: ArrivalProcess::Poisson { rate: 2.0 },
//!             weight: 1.0,
//!         })
//!         .collect(),
//!     jobs: 1_000_000,
//!     shards: 4,
//!     drainers: 4,
//!     steal: true,
//!     batch: BatchPolicy::Adaptive(SloConfig::default()),
//!     amortize: 0.75,
//!     seed: 2019,
//! };
//! let p = policy::resolve("proposed")?;
//! let rep = run_admission(&spec, &*p, LatencyModel::A, &cfg)?;
//! println!(
//!     "thruput {:.3}  p99 {:.4}  maxQ {}  steals {}",
//!     rep.throughput,
//!     rep.sojourn_percentile(99.0),
//!     rep.max_queue_depth,
//!     rep.steals,
//! );
//! # Ok::<(), hetcoded::Error>(())
//! ```

use crate::allocation::Policy;
use crate::math::{Rng, Summary};
use crate::model::{ClusterSpec, LatencyModel};
use crate::workload::arrivals::ArrivalProcess;
use crate::workload::queue::time_key;
use crate::workload::service::{service_sampler_for, ServiceSampler};
use crate::{Error, Result};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

/// One tenant of the admission layer: its traffic and its fairness weight.
#[derive(Clone, Copy, Debug)]
pub struct TenantSpec {
    /// The tenant's own arrival stream (drawn from a dedicated RNG
    /// substream, so tenants are statistically independent).
    pub arrivals: ArrivalProcess,
    /// Deficit-round-robin quantum per visit. Under sustained backlog a
    /// tenant receives batch slots proportional to its weight.
    pub weight: f64,
}

/// How the drain loop sizes its batches.
#[derive(Clone, Copy, Debug)]
pub enum BatchPolicy {
    /// A fixed batch limit (the legacy `max_batch` knob).
    Fixed(usize),
    /// A [`BatchController`] sizes the limit online against an SLO.
    Adaptive(SloConfig),
}

/// Knobs of the [`BatchController`].
#[derive(Clone, Copy, Debug)]
pub struct SloConfig {
    /// The sojourn SLO: keep windowed p99 sojourn at or below this.
    pub target_p99: f64,
    /// Smallest batch limit the controller may choose (≥ 1).
    pub min_batch: usize,
    /// Largest batch limit the controller may choose.
    pub max_batch: usize,
    /// Sliding window of completed-job sojourns the p99 is measured over.
    pub window: usize,
    /// Control decisions happen every this many observed completions.
    pub decide_every: usize,
}

impl Default for SloConfig {
    fn default() -> Self {
        SloConfig {
            target_p99: 1.0,
            min_batch: 1,
            max_batch: 64,
            window: 256,
            decide_every: 64,
        }
    }
}

impl SloConfig {
    /// Check the knobs are self-consistent.
    pub fn validate(&self) -> Result<()> {
        if !(self.target_p99 > 0.0) || !self.target_p99.is_finite() {
            return Err(Error::InvalidSpec(format!(
                "SLO target_p99 must be positive and finite, got {}",
                self.target_p99
            )));
        }
        if self.min_batch == 0 || self.max_batch < self.min_batch {
            return Err(Error::InvalidSpec(format!(
                "SLO batch range [{}, {}] must satisfy 1 <= min <= max",
                self.min_batch, self.max_batch
            )));
        }
        if self.window < 2 || self.decide_every == 0 {
            return Err(Error::InvalidSpec(
                "SLO window must be >= 2 and decide_every >= 1".into(),
            ));
        }
        Ok(())
    }
}

/// Online batch-limit controller with hysteresis.
///
/// Observed sojourns feed a sliding window; every
/// [`SloConfig::decide_every`] observations the windowed p99 is compared
/// against [`SloConfig::target_p99`]:
///
/// - **above target** → the limit doubles (clamped to `max_batch`):
///   violation means the drain is capacity-starved, and batch
///   amortization buys capacity multiplicatively, so the response is
///   multiplicative too;
/// - **below half the target** → the limit shrinks by one: large batches
///   trade per-job latency for capacity, so idle headroom is returned
///   slowly, one slot at a time;
/// - **in between** → hold. The dead band is the hysteresis that keeps
///   the limit from oscillating around the target.
#[derive(Clone, Debug)]
pub struct BatchController {
    cfg: SloConfig,
    limit: usize,
    window: VecDeque<f64>,
    since_decision: usize,
    grows: u64,
    shrinks: u64,
}

impl BatchController {
    /// Controller starting at `cfg.min_batch`.
    pub fn new(cfg: SloConfig) -> Result<BatchController> {
        cfg.validate()?;
        Ok(BatchController {
            cfg,
            limit: cfg.min_batch,
            window: VecDeque::with_capacity(cfg.window),
            since_decision: 0,
            grows: 0,
            shrinks: 0,
        })
    }

    /// The current batch limit.
    pub fn limit(&self) -> usize {
        self.limit
    }

    /// Times the limit was grown (doubled) so far.
    pub fn grows(&self) -> u64 {
        self.grows
    }

    /// Times the limit was shrunk so far.
    pub fn shrinks(&self) -> u64 {
        self.shrinks
    }

    /// Feed one completed job's sojourn and run a control decision every
    /// `decide_every` observations.
    pub fn observe(&mut self, sojourn: f64) {
        if self.window.len() == self.cfg.window {
            self.window.pop_front();
        }
        self.window.push_back(sojourn);
        self.since_decision += 1;
        if self.since_decision >= self.cfg.decide_every {
            self.since_decision = 0;
            self.decide();
        }
    }

    /// Windowed nearest-rank p99.
    fn window_p99(&self) -> f64 {
        let mut s: Vec<f64> = self.window.iter().copied().collect();
        s.sort_by(f64::total_cmp);
        let rank = ((0.99 * s.len() as f64).ceil() as usize).clamp(1, s.len());
        s[rank - 1]
    }

    fn decide(&mut self) {
        // Don't steer off a nearly-empty window (stream warm-up).
        if self.window.len() < self.cfg.window / 2 {
            return;
        }
        let p99 = self.window_p99();
        if p99 > self.cfg.target_p99 {
            if self.limit < self.cfg.max_batch {
                self.limit = (self.limit * 2).min(self.cfg.max_batch);
                self.grows += 1;
            }
        } else if p99 < 0.5 * self.cfg.target_p99
            && self.limit > self.cfg.min_batch
        {
            self.limit -= 1;
            self.shrinks += 1;
        }
    }
}

/// One shard's admission queue: per-tenant FIFO subqueues drained by
/// weighted deficit round robin.
///
/// Classic DRR: a round-robin cursor visits tenants; a visit to a
/// backlogged tenant adds its weight to that tenant's deficit, and the
/// tenant dequeues one job per unit of deficit. An emptied tenant's
/// deficit resets to zero — idle tenants cannot hoard credit and then
/// burst past their share. Single tenant at weight 1 degenerates to plain
/// FIFO (every visit drains exactly the head job).
#[derive(Clone, Debug)]
pub struct DrrQueue {
    per_tenant: Vec<VecDeque<usize>>,
    deficit: Vec<f64>,
    cursor: usize,
    len: usize,
}

impl DrrQueue {
    /// Empty queue over `tenants` subqueues.
    pub fn new(tenants: usize) -> DrrQueue {
        DrrQueue {
            per_tenant: vec![VecDeque::new(); tenants],
            deficit: vec![0.0; tenants],
            cursor: 0,
            len: 0,
        }
    }

    /// Enqueue `job` (an opaque index) for `tenant`.
    pub fn push(&mut self, tenant: usize, job: usize) {
        self.per_tenant[tenant].push_back(job);
        self.len += 1;
    }

    /// Jobs currently queued across all tenants.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no tenant has backlog.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Dequeue up to `limit` jobs by weighted DRR into `out` (appended in
    /// dequeue order). `weights[t]` is tenant `t`'s quantum; all weights
    /// must be positive (validated by [`AdmissionConfig::validate`]).
    pub fn drain(&mut self, weights: &[f64], limit: usize, out: &mut Vec<usize>) {
        let tenants = self.per_tenant.len();
        while out.len() < limit && self.len > 0 {
            let t = self.cursor;
            self.cursor = (self.cursor + 1) % tenants;
            if self.per_tenant[t].is_empty() {
                self.deficit[t] = 0.0;
                continue;
            }
            self.deficit[t] += weights[t];
            while self.deficit[t] >= 1.0 && out.len() < limit {
                match self.per_tenant[t].pop_front() {
                    Some(j) => {
                        out.push(j);
                        self.len -= 1;
                        self.deficit[t] -= 1.0;
                    }
                    None => break,
                }
            }
            if self.per_tenant[t].is_empty() {
                self.deficit[t] = 0.0;
            }
        }
    }
}

/// Configuration of one admission-front-end run.
#[derive(Clone, Debug)]
pub struct AdmissionConfig {
    /// The tenants (at least one). Tenant `t` is keyed onto shard
    /// `t % shards`.
    pub tenants: Vec<TenantSpec>,
    /// Total jobs across all tenants (split evenly, first `jobs % T`
    /// tenants take the remainder).
    pub jobs: usize,
    /// Admission queues.
    pub shards: usize,
    /// Concurrent drain loops (service slots). Drainer `d`'s home shard
    /// is `d % shards`; without stealing, every shard needs a home
    /// drainer (`drainers >= shards`).
    pub drainers: usize,
    /// Work stealing: an idle drainer scans the other shards
    /// (home-first rotation) instead of sleeping on its own.
    pub steal: bool,
    /// Batch sizing: fixed limit or SLO-adaptive controller.
    pub batch: BatchPolicy,
    /// Fixed fraction `γ ∈ [0, 1)` of a batch's service time: a `b`-job
    /// batch takes `S · (γ + (1-γ)·b)` where `S` is one service draw.
    /// `γ = 0` means no amortization (a batch costs the sum of its
    /// members); single-job batches always cost exactly `S`.
    pub amortize: f64,
    /// Base seed; per-tenant arrivals and the service stream use split
    /// substreams ([`Rng::split`], in tenant order, service last — with
    /// one tenant this is bit-identical to
    /// [`crate::workload::run_workload_policy`]'s discipline).
    pub seed: u64,
}

impl AdmissionConfig {
    /// The degenerate configuration pinned bit-identical to the legacy
    /// FIFO path ([`crate::workload::run_workload_policy`]): one shard,
    /// one unit-weight tenant, stealing off, single-job batches (the
    /// amortization scale never engages), `drainers` = the FIFO sim's
    /// `servers`.
    pub fn fifo_parity(
        arrivals: ArrivalProcess,
        jobs: usize,
        servers: usize,
        seed: u64,
    ) -> AdmissionConfig {
        AdmissionConfig {
            tenants: vec![TenantSpec { arrivals, weight: 1.0 }],
            jobs,
            shards: 1,
            drainers: servers,
            steal: false,
            batch: BatchPolicy::Fixed(1),
            amortize: 0.0,
            seed,
        }
    }

    /// Check the whole configuration is self-consistent.
    pub fn validate(&self) -> Result<()> {
        if self.tenants.is_empty() {
            return Err(Error::InvalidSpec(
                "admission needs at least one tenant".into(),
            ));
        }
        for (t, spec) in self.tenants.iter().enumerate() {
            spec.arrivals.validate()?;
            if !(spec.weight > 0.0) || !spec.weight.is_finite() {
                return Err(Error::InvalidSpec(format!(
                    "tenant {t} weight must be positive and finite, got {}",
                    spec.weight
                )));
            }
        }
        if self.jobs == 0 {
            return Err(Error::InvalidSpec(
                "admission needs at least one job".into(),
            ));
        }
        if self.shards == 0 || self.drainers == 0 {
            return Err(Error::InvalidSpec(
                "shards and drainers must be positive".into(),
            ));
        }
        if !self.steal && self.drainers < self.shards {
            return Err(Error::InvalidSpec(format!(
                "{} shards but only {} drainers: with stealing off every \
                 shard needs a home drainer (enable steal or add drainers)",
                self.shards, self.drainers
            )));
        }
        if !(0.0..1.0).contains(&self.amortize) {
            return Err(Error::InvalidSpec(format!(
                "amortize must be in [0, 1), got {}",
                self.amortize
            )));
        }
        match self.batch {
            BatchPolicy::Fixed(b) if b == 0 => Err(Error::InvalidSpec(
                "fixed batch limit must be positive".into(),
            )),
            BatchPolicy::Fixed(_) => Ok(()),
            BatchPolicy::Adaptive(slo) => slo.validate(),
        }
    }
}

/// One admitted request in the merged, index-ordered arrival stream.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionJob {
    /// Arrival time (model units).
    pub arrival: f64,
    /// Owning tenant (indexes [`AdmissionConfig::tenants`]).
    pub tenant: usize,
}

/// Draw every tenant's arrival stream and merge them into one ascending,
/// index-ordered job list (ties break on tenant, then per-tenant index —
/// the fixed merge order that makes multi-tenant runs reproducible).
/// Returns the job list and the service-stream RNG (split from the same
/// root *after* the tenant streams, preserving the legacy discipline).
pub fn generate_jobs(cfg: &AdmissionConfig) -> Result<(Vec<AdmissionJob>, Rng)> {
    cfg.validate()?;
    let mut root = Rng::new(cfg.seed);
    let mut arrival_rngs: Vec<Rng> =
        cfg.tenants.iter().map(|_| root.split()).collect();
    let service_rng = root.split();
    let t_count = cfg.tenants.len();
    let base = cfg.jobs / t_count;
    let extra = cfg.jobs % t_count;
    let mut tagged: Vec<(f64, usize, usize)> = Vec::with_capacity(cfg.jobs);
    for (t, spec) in cfg.tenants.iter().enumerate() {
        let count = base + usize::from(t < extra);
        let times = spec.arrivals.times(count, &mut arrival_rngs[t])?;
        for (i, at) in times.into_iter().enumerate() {
            tagged.push((at, t, i));
        }
    }
    tagged.sort_by(|a, b| {
        a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2))
    });
    let jobs = tagged
        .into_iter()
        .map(|(arrival, tenant, _)| AdmissionJob { arrival, tenant })
        .collect();
    Ok((jobs, service_rng))
}

/// Aggregate metrics of one admission-front-end run.
#[derive(Clone, Debug)]
pub struct AdmissionReport {
    /// Policy display name (`"explicit"` for [`simulate_admission`] runs
    /// over a hand-built job list).
    pub policy: String,
    /// Jobs completed (== jobs admitted; the queues are lossless).
    pub jobs: usize,
    /// Shards / drainers / tenants of the run.
    pub shards: usize,
    /// Drain loops.
    pub drainers: usize,
    /// Tenant count.
    pub tenants: usize,
    /// First arrival to last completion (model units).
    pub makespan: f64,
    /// Completed jobs per unit model time.
    pub throughput: f64,
    /// Sojourn times (arrival → completion); retains samples.
    pub sojourn: Summary,
    /// Waiting times (arrival → batch start); retains samples.
    pub wait: Summary,
    /// Per-tenant sojourn summaries (retain samples) — the isolation
    /// metric: a bursty tenant shows up here, not in its neighbours.
    pub per_tenant_sojourn: Vec<Summary>,
    /// Batches dispatched.
    pub batches: u64,
    /// Batches a drainer drained from a non-home shard.
    pub steals: u64,
    /// Mean jobs per batch.
    pub mean_batch: f64,
    /// Largest batch actually dispatched.
    pub max_batch_used: usize,
    /// The batch limit in force at the end ([`BatchController::limit`];
    /// the fixed limit under [`BatchPolicy::Fixed`]).
    pub final_batch_limit: usize,
    /// Controller grow decisions (0 under a fixed policy).
    pub batch_grows: u64,
    /// Controller shrink decisions (0 under a fixed policy).
    pub batch_shrinks: u64,
    /// Peak jobs waiting (admitted, not yet dispatched) across all shards.
    pub max_queue_depth: usize,
    /// Time-average jobs waiting across all shards.
    pub mean_queue_depth: f64,
    /// Arrival time of job `i` (ascending; the merged stream order).
    pub arrivals: Vec<f64>,
    /// Batch-start time of job `i`.
    pub starts: Vec<f64>,
    /// Completion time of job `i`.
    pub finishes: Vec<f64>,
    /// Owning tenant of job `i`.
    pub tenant_of: Vec<usize>,
    /// Drainer that served job `i`.
    pub drainer_of: Vec<usize>,
}

impl AdmissionReport {
    /// Sojourn-time percentile (`p` in `[0, 100]`).
    pub fn sojourn_percentile(&self, p: f64) -> f64 {
        self.sojourn.percentile(p)
    }

    /// One tenant's sojourn percentile.
    pub fn tenant_percentile(&self, tenant: usize, p: f64) -> f64 {
        self.per_tenant_sojourn[tenant].percentile(p)
    }
}

/// Run the event-driven admission simulation over an explicit job list.
///
/// `jobs` must be ascending in arrival time with tenant indices inside
/// `cfg.tenants`; `rng` is the service stream (one draw per batch). This
/// is the test- and load-step-facing entry point; [`run_admission`]
/// wraps it with tenant-stream generation and a policy-derived sampler.
pub fn simulate_admission(
    jobs: &[AdmissionJob],
    sampler: &mut ServiceSampler,
    cfg: &AdmissionConfig,
    rng: &mut Rng,
) -> Result<AdmissionReport> {
    cfg.validate()?;
    if jobs.is_empty() {
        return Err(Error::InvalidSpec(
            "admission needs at least one job".into(),
        ));
    }
    let t_count = cfg.tenants.len();
    if jobs
        .iter()
        .any(|j| !j.arrival.is_finite() || j.arrival < 0.0 || j.tenant >= t_count)
    {
        return Err(Error::InvalidSpec(
            "admission jobs must have finite nonnegative arrivals and \
             in-range tenants"
                .into(),
        ));
    }
    if jobs.windows(2).any(|w| w[1].arrival < w[0].arrival) {
        return Err(Error::InvalidSpec(
            "admission jobs must be ascending in arrival time".into(),
        ));
    }
    let shards = cfg.shards;
    let weights: Vec<f64> = cfg.tenants.iter().map(|t| t.weight).collect();
    // Tenant-keyed shard streams: global job indices in arrival order.
    let mut shard_jobs: Vec<Vec<usize>> = vec![Vec::new(); shards];
    for (i, j) in jobs.iter().enumerate() {
        shard_jobs[j.tenant % shards].push(i);
    }
    let mut next_arrival = vec![0usize; shards];
    let mut queues: Vec<DrrQueue> =
        (0..shards).map(|_| DrrQueue::new(t_count)).collect();
    let mut controller = match cfg.batch {
        BatchPolicy::Fixed(_) => None,
        BatchPolicy::Adaptive(slo) => Some(BatchController::new(slo)?),
    };
    let fixed_limit = match cfg.batch {
        BatchPolicy::Fixed(b) => b,
        BatchPolicy::Adaptive(_) => 0,
    };
    let gamma = cfg.amortize;

    // Drainer min-heap keyed `(free_time_bits, drainer)` — the same
    // order-isomorphic keying as `simulate_queue`, ties on drainer index.
    let mut heap: BinaryHeap<Reverse<(u64, usize)>> =
        (0..cfg.drainers).map(|d| Reverse((time_key(0.0), d))).collect();
    let n = jobs.len();
    let mut starts = vec![0.0f64; n];
    let mut finishes = vec![0.0f64; n];
    let mut drainer_of = vec![0usize; n];
    let mut remaining = n;
    let mut batch_buf: Vec<usize> = Vec::new();
    let (mut batches, mut steals, mut batch_jobs) = (0u64, 0u64, 0u64);
    let mut max_batch_used = 0usize;

    while remaining > 0 {
        let Some(Reverse((bits, d))) = heap.pop() else {
            // Unreachable under `validate` (every shard is reachable by a
            // live drainer), kept as a loud failure rather than a hang.
            return Err(Error::Runtime(format!(
                "admission deadlock: {remaining} jobs unserved with no \
                 runnable drainer"
            )));
        };
        let t_free = f64::from_bits(bits);
        let home = d % shards;
        let span = if cfg.steal { shards } else { 1 };
        // Admit everything arrived by now shard-by-shard (home first,
        // then rotation when stealing) and stop at the first backlog.
        let mut chosen: Option<(usize, bool)> = None;
        for off in 0..span {
            let s = (home + off) % shards;
            let stream = &shard_jobs[s];
            let cur = &mut next_arrival[s];
            while *cur < stream.len() && jobs[stream[*cur]].arrival <= t_free {
                queues[s].push(jobs[stream[*cur]].tenant, stream[*cur]);
                *cur += 1;
            }
            if !queues[s].is_empty() {
                chosen = Some((s, off > 0));
                break;
            }
        }
        match chosen {
            Some((s, stolen)) => {
                let limit =
                    controller.as_ref().map_or(fixed_limit, BatchController::limit);
                batch_buf.clear();
                queues[s].drain(&weights, limit, &mut batch_buf);
                let b = batch_buf.len();
                let raw = sampler.sample(rng);
                // Amortized batch service; b == 1 short-circuits to the
                // raw draw so single-job batches are bit-identical to the
                // FIFO path (γ + (1-γ)·1 need not round to exactly 1.0).
                let svc = if b == 1 {
                    raw
                } else {
                    raw * (gamma + (1.0 - gamma) * b as f64)
                };
                let start = t_free;
                let finish = start + svc;
                for &ji in &batch_buf {
                    starts[ji] = start;
                    finishes[ji] = finish;
                    drainer_of[ji] = d;
                }
                if let Some(c) = controller.as_mut() {
                    // Batch members complete together, so their sojourns
                    // are final at dispatch — feed them now (the signal
                    // lags by one batch either way).
                    for &ji in &batch_buf {
                        c.observe(finish - jobs[ji].arrival);
                    }
                }
                remaining -= b;
                batches += 1;
                batch_jobs += b as u64;
                max_batch_used = max_batch_used.max(b);
                if stolen {
                    steals += 1;
                }
                heap.push(Reverse((time_key(finish), d)));
            }
            None => {
                // Nothing pending anywhere this drainer may serve: sleep
                // until the next arrival it could take, or retire.
                let mut t_next = f64::INFINITY;
                for off in 0..span {
                    let s = (home + off) % shards;
                    if next_arrival[s] < shard_jobs[s].len() {
                        t_next = t_next
                            .min(jobs[shard_jobs[s][next_arrival[s]]].arrival);
                    }
                }
                if t_next.is_finite() {
                    heap.push(Reverse((time_key(t_next), d)));
                }
            }
        }
    }

    // Post-pass metrics over the completed trace.
    let first_arrival = jobs[0].arrival;
    let last_finish =
        finishes.iter().fold(f64::NEG_INFINITY, |acc, &f| acc.max(f));
    let makespan = last_finish - first_arrival;
    let mut sojourn = Summary::keeping_samples();
    let mut wait = Summary::keeping_samples();
    let mut per_tenant: Vec<Summary> =
        (0..t_count).map(|_| Summary::keeping_samples()).collect();
    for (i, j) in jobs.iter().enumerate() {
        sojourn.add(finishes[i] - j.arrival);
        wait.add(starts[i] - j.arrival);
        per_tenant[j.tenant].add(finishes[i] - j.arrival);
    }
    // Waiting-count sweep: +1 at arrival, -1 at batch start; arrivals
    // first at ties so a zero-wait job contributes a zero-width spike.
    let mut events: Vec<(f64, i64)> = Vec::with_capacity(2 * n);
    for j in jobs {
        events.push((j.arrival, 1));
    }
    for &s in &starts {
        events.push((s, -1));
    }
    events.sort_by(|a, b| a.0.total_cmp(&b.0).then(b.1.cmp(&a.1)));
    let (mut depth, mut max_depth) = (0i64, 0i64);
    let mut last_t = first_arrival;
    let mut area = 0.0;
    for (t, e) in events {
        area += depth as f64 * (t - last_t);
        last_t = t;
        depth += e;
        max_depth = max_depth.max(depth);
    }
    Ok(AdmissionReport {
        policy: "explicit".into(),
        jobs: n,
        shards,
        drainers: cfg.drainers,
        tenants: t_count,
        makespan,
        throughput: if makespan > 0.0 { n as f64 / makespan } else { 0.0 },
        sojourn,
        wait,
        per_tenant_sojourn: per_tenant,
        batches,
        steals,
        mean_batch: batch_jobs as f64 / batches.max(1) as f64,
        max_batch_used,
        final_batch_limit: controller
            .as_ref()
            .map_or(fixed_limit, BatchController::limit),
        batch_grows: controller.as_ref().map_or(0, BatchController::grows),
        batch_shrinks: controller.as_ref().map_or(0, BatchController::shrinks),
        max_queue_depth: max_depth as usize,
        mean_queue_depth: if makespan > 0.0 { area / makespan } else { 0.0 },
        arrivals: jobs.iter().map(|j| j.arrival).collect(),
        starts,
        finishes,
        tenant_of: jobs.iter().map(|j| j.tenant).collect(),
        drainer_of,
    })
}

/// Run one complete admission-front-end experiment for any [`Policy`]:
/// draw every tenant's arrivals, build the policy's service sampler on
/// `spec`, run the sharded event loop, and summarize. Bit-reproducible
/// from `cfg.seed`; the [`AdmissionConfig::fifo_parity`] configuration is
/// bit-identical to [`crate::workload::run_workload_policy`].
pub fn run_admission(
    spec: &ClusterSpec,
    policy: &dyn Policy,
    model: LatencyModel,
    cfg: &AdmissionConfig,
) -> Result<AdmissionReport> {
    let (_, mut sampler) = service_sampler_for(spec, policy, model)?;
    let (jobs, mut service_rng) = generate_jobs(cfg)?;
    let mut rep = simulate_admission(&jobs, &mut sampler, cfg, &mut service_rng)?;
    rep.policy = policy.name();
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Group;
    use crate::sim::Scheme;
    use crate::workload::queue::simulate_queue;
    use crate::workload::service::service_sampler;

    fn small_spec() -> ClusterSpec {
        ClusterSpec::new(
            vec![
                Group { n: 4, mu: 8.0, alpha: 1.0 },
                Group { n: 6, mu: 2.0, alpha: 1.0 },
            ],
            64,
        )
        .unwrap()
    }

    fn uniform_tenants(t: usize, rate_each: f64) -> Vec<TenantSpec> {
        (0..t)
            .map(|_| TenantSpec {
                arrivals: ArrivalProcess::Poisson { rate: rate_each },
                weight: 1.0,
            })
            .collect()
    }

    #[test]
    fn fifo_parity_matches_simulate_queue_bit_for_bit() {
        // The degenerate config against the legacy path's exact internals
        // (same splits, same sampler, same trace) — starts and finishes
        // must be bit-equal for 1 and for 3 service slots.
        let spec = small_spec();
        for servers in [1usize, 3] {
            let cfg = AdmissionConfig::fifo_parity(
                ArrivalProcess::Poisson { rate: 3.0 },
                600,
                servers,
                0x90_1D,
            );
            let (_, mut sampler) =
                service_sampler(&spec, Scheme::Proposed, LatencyModel::A).unwrap();
            let mut root = Rng::new(cfg.seed);
            let mut arrival_rng = root.split();
            let mut service_rng = root.split();
            let arrivals = ArrivalProcess::Poisson { rate: 3.0 }
                .times(600, &mut arrival_rng)
                .unwrap();
            let legacy =
                simulate_queue(&arrivals, &mut sampler, servers, &mut service_rng)
                    .unwrap();
            let p = crate::allocation::policy::resolve("proposed").unwrap();
            let adm = run_admission(&spec, &*p, LatencyModel::A, &cfg).unwrap();
            assert_eq!(adm.arrivals, legacy.arrivals, "servers {servers}");
            assert_eq!(adm.starts, legacy.starts, "servers {servers}");
            assert_eq!(adm.finishes, legacy.finishes, "servers {servers}");
            assert_eq!(adm.batches as usize, 600);
            assert_eq!(adm.steals, 0);
        }
    }

    #[test]
    fn multi_shard_run_is_deterministic() {
        let spec = small_spec();
        let cfg = AdmissionConfig {
            tenants: uniform_tenants(8, 1.5),
            jobs: 3_000,
            shards: 4,
            drainers: 4,
            steal: true,
            batch: BatchPolicy::Adaptive(SloConfig {
                target_p99: 2.0,
                ..Default::default()
            }),
            amortize: 0.75,
            seed: 0xD15C,
        };
        let p = crate::allocation::policy::resolve("proposed").unwrap();
        let a = run_admission(&spec, &*p, LatencyModel::A, &cfg).unwrap();
        let b = run_admission(&spec, &*p, LatencyModel::A, &cfg).unwrap();
        assert_eq!(a.starts, b.starts);
        assert_eq!(a.finishes, b.finishes);
        assert_eq!(a.drainer_of, b.drainer_of);
        assert_eq!(a.steals, b.steals);
        assert_eq!(a.max_queue_depth, b.max_queue_depth);
        assert_eq!(a.jobs, 3_000);
    }

    #[test]
    fn per_tenant_streams_stay_fifo() {
        // Tenant-keyed sharding + per-tenant FIFO subqueues: each
        // tenant's jobs start in its own arrival order even with
        // stealing and adaptive batches in play.
        let spec = small_spec();
        let cfg = AdmissionConfig {
            tenants: uniform_tenants(5, 2.0),
            jobs: 2_000,
            shards: 2,
            drainers: 3,
            steal: true,
            batch: BatchPolicy::Fixed(4),
            amortize: 0.5,
            seed: 7,
        };
        let p = crate::allocation::policy::resolve("proposed").unwrap();
        let rep = run_admission(&spec, &*p, LatencyModel::A, &cfg).unwrap();
        let mut last_start = vec![0.0f64; 5];
        for i in 0..rep.jobs {
            let t = rep.tenant_of[i];
            assert!(rep.starts[i] >= rep.arrivals[i], "job {i} started early");
            assert!(rep.finishes[i] > rep.starts[i]);
            assert!(
                rep.starts[i] >= last_start[t],
                "tenant {t} starts must be monotone"
            );
            last_start[t] = rep.starts[i];
        }
    }

    #[test]
    fn drr_splits_batch_slots_by_weight() {
        let mut q = DrrQueue::new(2);
        for i in 0..10 {
            q.push(0, i);
        }
        for i in 10..20 {
            q.push(1, i);
        }
        let mut out = Vec::new();
        q.drain(&[3.0, 1.0], 8, &mut out);
        assert_eq!(out.len(), 8);
        let t0 = out.iter().filter(|&&j| j < 10).count();
        assert_eq!(t0, 6, "weight 3:1 over 8 slots is a 6:2 split, got {out:?}");
        // Within-tenant order is FIFO.
        let t0_jobs: Vec<usize> = out.iter().copied().filter(|&j| j < 10).collect();
        assert_eq!(t0_jobs, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn drr_single_tenant_is_fifo() {
        let mut q = DrrQueue::new(1);
        for i in 0..6 {
            q.push(0, i);
        }
        let mut out = Vec::new();
        q.drain(&[1.0], 4, &mut out);
        assert_eq!(out, vec![0, 1, 2, 3]);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn controller_grows_on_violation_and_shrinks_when_idle() {
        let slo = SloConfig {
            target_p99: 1.0,
            min_batch: 1,
            max_batch: 16,
            window: 8,
            decide_every: 4,
        };
        let mut c = BatchController::new(slo).unwrap();
        assert_eq!(c.limit(), 1);
        for _ in 0..8 {
            c.observe(5.0); // far above target
        }
        assert!(c.limit() >= 4, "violations must double the limit, got {}", c.limit());
        assert!(c.grows() >= 2);
        let peak = c.limit();
        for _ in 0..40 {
            c.observe(0.01); // far below half-target
        }
        assert!(c.limit() < peak, "idle stream must shrink the limit");
        assert!(c.shrinks() >= 1);
        // Inside the dead band: hold.
        let held = c.limit();
        for _ in 0..8 {
            c.observe(0.8);
        }
        assert_eq!(c.limit(), held, "hysteresis dead band must hold the limit");
    }

    #[test]
    fn stealing_is_work_conserving_under_skew() {
        // All traffic on tenant 0 (shard 0); tenant 1 idle-ish. With
        // stealing, drainer 1 serves shard 0's backlog: batches get
        // stolen and the run finishes no later.
        let spec = small_spec();
        let mk = |steal| AdmissionConfig {
            tenants: vec![
                TenantSpec {
                    arrivals: ArrivalProcess::Poisson { rate: 6.0 },
                    weight: 1.0,
                },
                TenantSpec {
                    arrivals: ArrivalProcess::Poisson { rate: 0.05 },
                    weight: 1.0,
                },
            ],
            jobs: 1_200,
            shards: 2,
            drainers: 2,
            steal,
            batch: BatchPolicy::Fixed(1),
            amortize: 0.0,
            seed: 0x5EA1,
        };
        let p = crate::allocation::policy::resolve("proposed").unwrap();
        let with = run_admission(&spec, &*p, LatencyModel::A, &mk(true)).unwrap();
        let without =
            run_admission(&spec, &*p, LatencyModel::A, &mk(false)).unwrap();
        assert!(with.steals > 0, "skewed load must trigger steals");
        assert!(
            with.makespan <= without.makespan,
            "stealing is work-conserving: {} vs {}",
            with.makespan,
            without.makespan
        );
    }

    #[test]
    fn amortized_batches_raise_capacity() {
        // Deterministic overload: single-job batches can't keep up, wide
        // amortized batches (γ = 0.75 → 16-job batch ≈ 4.75 S, not 16 S)
        // can.
        let spec = small_spec();
        let (_, sampler) =
            service_sampler(&spec, Scheme::Proposed, LatencyModel::A).unwrap();
        let es =
            crate::workload::service::mean_service(&mut sampler.clone(), 2_000, 1);
        let jobs: Vec<AdmissionJob> = (0..2_000)
            .map(|i| AdmissionJob { arrival: i as f64 * es / 2.5, tenant: 0 })
            .collect();
        let mk = |b| AdmissionConfig {
            tenants: uniform_tenants(1, 1.0),
            jobs: jobs.len(),
            shards: 1,
            drainers: 1,
            steal: false,
            batch: BatchPolicy::Fixed(b),
            amortize: 0.75,
            seed: 1,
        };
        let run = |b| {
            let mut s = sampler.clone();
            let mut rng = Rng::new(99);
            simulate_admission(&jobs, &mut s, &mk(b), &mut rng).unwrap()
        };
        let narrow = run(1);
        let wide = run(16);
        assert!(
            wide.makespan < 0.6 * narrow.makespan,
            "amortized batches must absorb a 2.5x overload: wide {} vs \
             narrow {}",
            wide.makespan,
            narrow.makespan
        );
        assert!(wide.mean_batch > 4.0, "mean batch {}", wide.mean_batch);
    }

    #[test]
    fn invalid_configs_rejected() {
        let ok = AdmissionConfig {
            tenants: uniform_tenants(2, 1.0),
            jobs: 10,
            shards: 2,
            drainers: 2,
            steal: false,
            batch: BatchPolicy::Fixed(4),
            amortize: 0.5,
            seed: 1,
        };
        assert!(ok.validate().is_ok());
        let mut c = ok.clone();
        c.tenants.clear();
        assert!(c.validate().is_err(), "no tenants");
        let mut c = ok.clone();
        c.tenants[0].weight = 0.0;
        assert!(c.validate().is_err(), "zero weight");
        let mut c = ok.clone();
        c.jobs = 0;
        assert!(c.validate().is_err(), "no jobs");
        let mut c = ok.clone();
        c.shards = 0;
        assert!(c.validate().is_err(), "zero shards");
        let mut c = ok.clone();
        c.drainers = 1; // 2 shards, steal off: shard 1 unreachable
        assert!(c.validate().is_err(), "orphan shard without steal");
        c.steal = true;
        assert!(c.validate().is_ok(), "steal makes every shard reachable");
        let mut c = ok.clone();
        c.amortize = 1.0;
        assert!(c.validate().is_err(), "gamma = 1 means free batches");
        let mut c = ok.clone();
        c.batch = BatchPolicy::Fixed(0);
        assert!(c.validate().is_err(), "empty batches");
        let mut c = ok;
        c.batch = BatchPolicy::Adaptive(SloConfig {
            min_batch: 8,
            max_batch: 4,
            ..Default::default()
        });
        assert!(c.validate().is_err(), "inverted batch range");
    }
}
