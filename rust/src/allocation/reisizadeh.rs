//! The load allocation of Reisizadeh et al. [32] (paper Appendix D).
//!
//! Under group heterogeneity:
//!
//! ```text
//! δ_j = -(W_{-1}(-e^{-(α_j μ_j + 1)}) + 1) / μ_j
//! s   = Σ_j N_j μ_j / (1 + μ_j δ_j)
//! l̃_j = k / (s δ_j),     ñ = Σ_j N_j l̃_j .
//! ```
//!
//! A pleasing structural fact (asserted in the tests): with
//! `w_j = W_{-1}(-e^{-(α_j μ_j+1)})` one has `1 + μ_j δ_j = -w_j` and
//! `δ_j = ξ*_j`, so `s` equals the paper's `S = Σ r*_j/ξ*_j` and the [32]
//! allocation **coincides with the proposed allocation** of Theorem 2 /
//! Corollary 2 under group heterogeneity — which is exactly why Fig. 9 shows
//! both achieving the lower bound `T*_b`.

use crate::allocation::Allocation;
use crate::math::wm1_neg_exp;
use crate::model::{ClusterSpec, LatencyModel};
use crate::Result;

/// Compute the [32] allocation (Appendix D) for `spec`.
pub fn reisizadeh_allocation(model: LatencyModel, spec: &ClusterSpec) -> Result<Allocation> {
    let k = spec.k as f64;
    let deltas: Vec<f64> = spec
        .groups
        .iter()
        .map(|g| {
            let w = wm1_neg_exp(g.alpha * g.mu + 1.0);
            -(w + 1.0) / g.mu
        })
        .collect();
    let s: f64 = spec
        .groups
        .iter()
        .zip(&deltas)
        .map(|(g, &d)| g.n as f64 * g.mu / (1.0 + g.mu * d))
        .sum();
    let loads: Vec<f64> = deltas.iter().map(|&d| k / (s * d)).collect();
    let n: f64 = loads
        .iter()
        .zip(&spec.groups)
        .map(|(&l, g)| l * g.n as f64)
        .sum();
    Ok(Allocation {
        model,
        policy: "reisizadeh".into(),
        loads,
        r: vec![],
        n,
        latency_bound: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocation::proposed_allocation;
    use crate::model::{xi_star, Group};

    #[test]
    fn delta_equals_xi_star() {
        // δ_j = -(w+1)/μ and ξ* = α + log(-w)/μ coincide because
        // log(-w) = -(αμ+1) - w.
        for (mu, alpha) in [(1.0, 1.0), (4.0, 4.0), (8.0, 12.0), (0.5, 1.0)] {
            let w = wm1_neg_exp(alpha * mu + 1.0);
            let delta = -(w + 1.0) / mu;
            let xs = xi_star(mu, alpha);
            assert!((delta - xs).abs() < 1e-10 * xs, "{delta} vs {xs}");
        }
    }

    #[test]
    fn coincides_with_proposed_under_group_heterogeneity() {
        // The structural identity behind Fig. 9: [32]'s allocation equals the
        // proposed one.
        let spec = ClusterSpec::paper_three_group_b(1000, 100_000);
        let rz = reisizadeh_allocation(LatencyModel::B, &spec).unwrap();
        let prop = proposed_allocation(LatencyModel::B, &spec).unwrap();
        for (a, b) in rz.loads.iter().zip(&prop.loads) {
            assert!((a - b).abs() < 1e-9 * b, "{a} vs {b}");
        }
        assert!((rz.n - prop.n).abs() < 1e-9 * prop.n);
    }

    #[test]
    fn validates_and_positive() {
        let spec = ClusterSpec::new(
            vec![
                Group { n: 50, mu: 1.0, alpha: 2.0 },
                Group { n: 70, mu: 6.0, alpha: 1.0 },
            ],
            5_000,
        )
        .unwrap();
        let a = reisizadeh_allocation(LatencyModel::B, &spec).unwrap();
        a.validate(&spec).unwrap();
        assert!(a.loads.iter().all(|&l| l > 0.0));
    }
}
