//! Integer load refinement.
//!
//! The paper rounds `l*_(j)` with a plain ceil and argues the effect is
//! negligible for large `k`. For small/medium `k` (where the live
//! coordinator operates) the ceil can shift latency by several percent, so
//! this module provides two better integerizations:
//!
//! - [`largest_remainder_loads`]: rounds while preserving the *total* coded
//!   row count `n = Σ N_j l_j` as closely as an integer per-group split
//!   allows (Hamilton apportionment on the fractional parts);
//! - [`optimize_integer_loads`]: local search around the rounded point that
//!   minimizes the CLT analytic latency ([`crate::model::analytic`]) subject
//!   to decodability (`Σ N_j l_j ≥ k`).

use crate::allocation::Allocation;
use crate::model::{clt_expected_latency, ClusterSpec};
use crate::{Error, Result};

/// Hamilton (largest-remainder) rounding of per-group loads: floor each
/// `l_j`, then hand out **at most one** extra row per group, in order of
/// descending fractional part, until the integer total `Σ N_j l_j` first
/// reaches the real-valued `n` (so the code never loses decodability).
///
/// One pass suffices: bumping every group with a nonzero remainder yields
/// the plain-ceil total, which already covers the real-valued target, so
/// no group is ever bumped twice and no group with a nonzero remainder is
/// skipped in favour of a second helping elsewhere.
///
/// Non-finite or negative loads are rejected with
/// [`Error::InvalidSpec`] instead of panicking inside the sort.
pub fn largest_remainder_loads(spec: &ClusterSpec, loads: &[f64]) -> Result<Vec<usize>> {
    if loads.len() != spec.num_groups() {
        return Err(Error::InvalidSpec("load vector length mismatch".into()));
    }
    if loads.iter().any(|&l| !l.is_finite() || l < 0.0) {
        return Err(Error::InvalidSpec(format!(
            "loads must be finite and nonnegative, got {loads:?}"
        )));
    }
    let mut ints: Vec<usize> = loads.iter().map(|&l| l.floor() as usize).collect();
    let target: f64 = loads
        .iter()
        .zip(&spec.groups)
        .map(|(&l, g)| l * g.n as f64)
        .sum();
    // Descending fractional part; ties broken by group index for
    // determinism. total_cmp cannot panic (and the inputs are finite).
    let frac = |j: usize| loads[j] - loads[j].floor();
    let mut order: Vec<usize> = (0..loads.len()).collect();
    order.sort_by(|&a, &b| frac(b).total_cmp(&frac(a)).then(a.cmp(&b)));
    let mut total: usize =
        ints.iter().zip(&spec.groups).map(|(&l, g)| l * g.n).sum();
    for j in order {
        // The 1e-9 slack absorbs float drift when every load is integral
        // but the accumulated real-valued target rounds a hair above the
        // exact integer total.
        if (total as f64) + 1e-9 >= target {
            break;
        }
        if frac(j) <= 0.0 {
            // Only fractional remainders earn a bump; with all of them
            // exhausted the totals agree exactly, so this is unreachable
            // in exact arithmetic and merely defends against drift.
            break;
        }
        ints[j] += 1;
        total += spec.groups[j].n;
    }
    // Guarantee every group gets at least one row.
    for v in ints.iter_mut() {
        if *v == 0 {
            *v = 1;
        }
    }
    Ok(ints)
}

/// Local search over integer loads minimizing the analytic latency.
///
/// Starts from [`largest_remainder_loads`] and tries single-group ±1 moves
/// while `Σ N_j l_j ≥ k` holds, accepting strict improvements, until a local
/// optimum (or `max_iters`).
pub fn optimize_integer_loads(
    spec: &ClusterSpec,
    alloc: &Allocation,
    max_iters: usize,
) -> Result<Vec<usize>> {
    let mut ints = largest_remainder_loads(spec, &alloc.loads)?;
    let model = alloc.model;
    let eval = |ints: &[usize]| -> Result<f64> {
        let loads: Vec<f64> = ints.iter().map(|&l| l as f64).collect();
        clt_expected_latency(spec, &loads, model)
    };
    let mut best = eval(&ints)?;
    for _ in 0..max_iters {
        let mut improved = false;
        for j in 0..ints.len() {
            for delta in [-1i64, 1] {
                let cand_j = ints[j] as i64 + delta;
                if cand_j < 1 {
                    continue;
                }
                let mut cand = ints.clone();
                cand[j] = cand_j as usize;
                let total: usize =
                    cand.iter().zip(&spec.groups).map(|(&l, g)| l * g.n).sum();
                if total < spec.k {
                    continue;
                }
                if let Ok(t) = eval(&cand) {
                    if t < best * (1.0 - 1e-12) {
                        best = t;
                        ints = cand;
                        improved = true;
                    }
                }
            }
        }
        if !improved {
            break;
        }
    }
    Ok(ints)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocation::proposed_allocation;
    use crate::model::{Group, LatencyModel};

    fn spec() -> ClusterSpec {
        ClusterSpec::new(
            vec![
                Group { n: 8, mu: 8.0, alpha: 1.0 },
                Group { n: 12, mu: 2.0, alpha: 1.0 },
            ],
            256,
        )
        .unwrap()
    }

    #[test]
    fn largest_remainder_preserves_decodability() {
        let s = spec();
        let a = proposed_allocation(LatencyModel::A, &s).unwrap();
        let ints = largest_remainder_loads(&s, &a.loads).unwrap();
        let total: usize = ints.iter().zip(&s.groups).map(|(&l, g)| l * g.n).sum();
        assert!(total >= s.k, "total {total} < k");
        // Total within one worker-group of the real-valued n.
        let max_group = s.groups.iter().map(|g| g.n).max().unwrap();
        assert!((total as f64 - a.n) < max_group as f64 + 1.0);
    }

    #[test]
    fn largest_remainder_beats_or_ties_plain_ceil_total() {
        // Plain ceil over-allocates; largest remainder should allocate no
        // more than ceil does.
        let s = spec();
        let a = proposed_allocation(LatencyModel::A, &s).unwrap();
        let lr = largest_remainder_loads(&s, &a.loads).unwrap();
        let ceil = a.integer_loads();
        let t_lr: usize = lr.iter().zip(&s.groups).map(|(&l, g)| l * g.n).sum();
        let t_ceil: usize = ceil.iter().zip(&s.groups).map(|(&l, g)| l * g.n).sum();
        assert!(t_lr <= t_ceil, "LR total {t_lr} > ceil total {t_ceil}");
    }

    #[test]
    fn optimizer_never_worse_than_rounding() {
        let s = spec();
        let a = proposed_allocation(LatencyModel::A, &s).unwrap();
        let rounded = largest_remainder_loads(&s, &a.loads).unwrap();
        let optimized = optimize_integer_loads(&s, &a, 32).unwrap();
        let eval = |ints: &[usize]| {
            let loads: Vec<f64> = ints.iter().map(|&l| l as f64).collect();
            clt_expected_latency(&s, &loads, LatencyModel::A).unwrap()
        };
        assert!(eval(&optimized) <= eval(&rounded) * (1.0 + 1e-12));
    }

    #[test]
    fn optimizer_stays_decodable_and_positive() {
        let s = spec();
        let a = proposed_allocation(LatencyModel::A, &s).unwrap();
        let opt = optimize_integer_loads(&s, &a, 32).unwrap();
        assert!(opt.iter().all(|&l| l >= 1));
        let total: usize = opt.iter().zip(&s.groups).map(|(&l, g)| l * g.n).sum();
        assert!(total >= s.k);
    }

    #[test]
    fn rejects_mismatched_lengths() {
        let s = spec();
        assert!(largest_remainder_loads(&s, &[1.0]).is_err());
    }

    #[test]
    fn rejects_nan_and_negative_loads() {
        // Regression: NaN used to panic inside the remainder sort's
        // `partial_cmp().unwrap()`; now it is a structured error.
        let s = spec();
        assert!(largest_remainder_loads(&s, &[f64::NAN, 1.0]).is_err());
        assert!(largest_remainder_loads(&s, &[f64::INFINITY, 1.0]).is_err());
        assert!(largest_remainder_loads(&s, &[-0.5, 1.0]).is_err());
    }

    #[test]
    fn at_most_one_bump_per_group_and_none_without_remainder() {
        // Regression: the old hand-out loop could revisit groups up to four
        // times. True Hamilton gives each group at most floor+1, and a
        // group with an integral load is never bumped.
        let s = ClusterSpec::new(
            vec![
                Group { n: 3, mu: 4.0, alpha: 1.0 },
                Group { n: 5, mu: 2.0, alpha: 1.0 },
                Group { n: 7, mu: 1.0, alpha: 1.0 },
            ],
            64,
        )
        .unwrap();
        let loads = [10.9, 6.0, 4.7];
        let ints = largest_remainder_loads(&s, &loads).unwrap();
        for (j, (&i, &l)) in ints.iter().zip(&loads).enumerate() {
            assert!(
                i == l.floor() as usize || i == l.floor() as usize + 1,
                "group {j}: {i} not in {{floor, floor+1}} of {l}"
            );
        }
        // 6.0 is integral: no bump.
        assert_eq!(ints[1], 6);
        // Highest remainder (group 0) is served first; target needs only
        // one bump of group 0 (3 rows cover the 0.9·3 + 0.7·7 = 7.6-row
        // fractional shortfall? no — 3 < 7.6, so group 2's bump lands too).
        let total: usize = ints.iter().zip(&s.groups).map(|(&l, g)| l * g.n).sum();
        let target = 10.9 * 3.0 + 6.0 * 5.0 + 4.7 * 7.0;
        assert!(total as f64 >= target - 1e-9);
    }

    #[test]
    fn integral_loads_round_trip_exactly() {
        let s = spec();
        let ints = largest_remainder_loads(&s, &[4.0, 7.0]).unwrap();
        assert_eq!(ints, vec![4, 7]);
    }
}
