//! The paper's proposed optimal load allocation (Theorem 2 / Corollary 2).
//!
//! For each group `j` with parameters `(N_j, μ_j, α_j)`:
//!
//! ```text
//! w_j  = W_{-1}(-e^{-(α_j μ_j + 1)})                      (Lambert lower branch)
//! r*_j = N_j (1 + 1/w_j)                                   (eq. 15)
//! ξ*_j = α_j + log(-w_j)/μ_j                               (eq. 17)
//! S    = Σ_j r*_j/ξ*_j = Σ_j (-μ_j N_j / w_j)              (eq. 17)
//! l*_j = k / (ξ*_j · S)                                    (eq. 16, refactored)
//! T*   = 1/S            [model A, eq. 18]
//! T*_b = k/S            [model B, eq. 33]
//! ```
//!
//! The load vector is the same under both models (Corollary 2 has the same
//! `r*` and `l*`); only the bound scales by `k`.

use crate::allocation::Allocation;
use crate::math::wm1_neg_exp;
use crate::model::{ClusterSpec, LatencyModel};
use crate::Result;

/// Compute the proposed optimal allocation for `spec` under `model`.
pub fn proposed_allocation(model: LatencyModel, spec: &ClusterSpec) -> Result<Allocation> {
    let k = spec.k as f64;
    let g = spec.num_groups();
    let mut w = Vec::with_capacity(g);
    let mut r_star = Vec::with_capacity(g);
    let mut xi_star = Vec::with_capacity(g);
    for grp in &spec.groups {
        let t = grp.alpha * grp.mu + 1.0;
        let wj = wm1_neg_exp(t);
        w.push(wj);
        r_star.push(grp.n as f64 * (1.0 + 1.0 / wj));
        // log(-w) = -(t + w), avoiding a second transcendental call.
        xi_star.push(grp.alpha + (-(t + wj)) / grp.mu);
    }
    // S = Σ r*_j / ξ*_j = Σ (-μ_j N_j / w_j).
    let s: f64 = spec
        .groups
        .iter()
        .zip(&w)
        .map(|(grp, &wj)| -grp.mu * grp.n as f64 / wj)
        .sum();
    let loads: Vec<f64> = xi_star.iter().map(|&xj| k / (xj * s)).collect();
    let n: f64 = loads
        .iter()
        .zip(&spec.groups)
        .map(|(&l, grp)| l * grp.n as f64)
        .sum();
    let bound = optimal_latency_bound(model, spec);
    Ok(Allocation {
        model,
        policy: "proposed".into(),
        loads,
        r: r_star,
        n,
        latency_bound: Some(bound),
    })
}

/// [`proposed_allocation`] under a coded-row budget: re-solving on a
/// drifted/shrunken cluster mid-stream must not mint new coded rows (the
/// matrix was encoded once, `n_cap` rows exist), so when the unconstrained
/// optimum wants `n > n_cap` every load is scaled down proportionally to
/// fit. The scaled point stays decodable as long as `n_cap ≥ k`, and the
/// equal-ξ structure of Theorem 1 is preserved (scaling `l` uniformly
/// scales each group's completion-time axis identically), so it is the
/// natural projection of the optimum onto the budget.
///
/// Errors when the spec is degenerate (e.g. no surviving workers) or the
/// budget cannot cover `k`.
pub fn proposed_allocation_capped(
    model: LatencyModel,
    spec: &ClusterSpec,
    n_cap: f64,
) -> Result<Allocation> {
    if !(n_cap >= spec.k as f64) {
        return Err(crate::Error::InvalidSpec(format!(
            "coded-row budget {n_cap} cannot cover k = {}",
            spec.k
        )));
    }
    let mut a = proposed_allocation(model, spec)?;
    if !a.n.is_finite()
        || a.loads.iter().any(|l| !l.is_finite() || !(*l > 0.0))
    {
        return Err(crate::Error::InvalidSpec(
            "degenerate cluster: proposed allocation is non-finite \
             (no surviving capacity?)"
                .into(),
        ));
    }
    if a.n > n_cap {
        let c = n_cap / a.n;
        for l in &mut a.loads {
            *l *= c;
        }
        a.n = n_cap;
        // The per-group waiting quantiles r*_j and the latency bound refer
        // to the unconstrained optimum; they do not survive the scaling.
        a.r.clear();
        a.latency_bound = None;
        a.policy = "proposed-capped".into();
    }
    Ok(a)
}

/// The analytic minimum expected latency: `T*` (eq. 18) for model A,
/// `T*_b = k·T*` (eq. 33) for model B.
pub fn optimal_latency_bound(model: LatencyModel, spec: &ClusterSpec) -> f64 {
    let s: f64 = spec
        .groups
        .iter()
        .map(|grp| {
            let wj = wm1_neg_exp(grp.alpha * grp.mu + 1.0);
            -grp.mu * grp.n as f64 / wj
        })
        .sum();
    match model {
        LatencyModel::A => 1.0 / s,
        LatencyModel::B => spec.k as f64 / s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::wm1_neg_exp;
    use crate::model::{order_stats, Group};

    fn homogeneous(n: usize, mu: f64, alpha: f64, k: usize) -> ClusterSpec {
        ClusterSpec::new(vec![Group { n, mu, alpha }], k).unwrap()
    }

    #[test]
    fn homogeneous_reduces_to_lee_et_al() {
        // Remark 1: with one group, l* = k / (N (1 + 1/W)) and
        // T* = -W/(μN), the result of [4].
        let (n, mu, alpha, k) = (100usize, 2.0, 1.0, 10_000usize);
        let spec = homogeneous(n, mu, alpha, k);
        let a = proposed_allocation(LatencyModel::A, &spec).unwrap();
        let w = wm1_neg_exp(alpha * mu + 1.0);
        let l_expect = k as f64 / (n as f64 * (1.0 + 1.0 / w));
        assert!((a.loads[0] - l_expect).abs() < 1e-9 * l_expect);
        let t_expect = -w / (mu * n as f64);
        assert!((a.latency_bound.unwrap() - t_expect).abs() < 1e-12);
    }

    #[test]
    fn model_b_bound_scales_by_k() {
        let spec = ClusterSpec::paper_three_group_b(1000, 100_000);
        let ta = optimal_latency_bound(LatencyModel::A, &spec);
        let tb = optimal_latency_bound(LatencyModel::B, &spec);
        assert!((tb / ta - 100_000.0).abs() < 1e-6 * 100_000.0);
        // Loads are identical across models (Corollary 2).
        let aa = proposed_allocation(LatencyModel::A, &spec).unwrap();
        let ab = proposed_allocation(LatencyModel::B, &spec).unwrap();
        for (x, y) in aa.loads.iter().zip(&ab.loads) {
            assert_eq!(x, y);
        }
    }

    #[test]
    fn constraint_eq5_satisfied() {
        // Σ_j r*_j l*_j = k (the MDS recovery constraint).
        let spec = ClusterSpec::paper_five_group(2500, 10_000);
        let a = proposed_allocation(LatencyModel::A, &spec).unwrap();
        let sum: f64 = a.r.iter().zip(&a.loads).map(|(r, l)| r * l).sum();
        assert!((sum - 10_000.0).abs() < 1e-6 * 10_000.0, "sum={sum}");
    }

    #[test]
    fn group_latencies_equalized_theorem_1() {
        // λ^{l*}_{r*_j:N_j} must be equal across groups (Theorem 1) and equal
        // to the bound T*.
        let spec = ClusterSpec::paper_five_group(2500, 10_000);
        let a = proposed_allocation(LatencyModel::A, &spec).unwrap();
        let t_star = a.latency_bound.unwrap();
        for (j, grp) in spec.groups.iter().enumerate() {
            let lam = order_stats::group_latency(
                LatencyModel::A,
                a.loads[j],
                spec.k as f64,
                grp.n as f64,
                a.r[j],
                grp.mu,
                grp.alpha,
            );
            assert!(
                (lam - t_star).abs() < 1e-9 * t_star,
                "group {j}: {lam} vs {t_star}"
            );
        }
    }

    #[test]
    fn r_star_strictly_inside_groups() {
        let spec = ClusterSpec::paper_five_group(2500, 10_000);
        let a = proposed_allocation(LatencyModel::A, &spec).unwrap();
        for (rj, grp) in a.r.iter().zip(&spec.groups) {
            assert!(*rj > 0.0 && *rj < grp.n as f64);
        }
    }

    #[test]
    fn t_star_is_theta_one_over_n() {
        // Fig. 2 claim: T* = Θ(1/N). Doubling every group should halve T*.
        let spec = ClusterSpec::paper_fig2(10_000);
        let t1 = optimal_latency_bound(LatencyModel::A, &spec);
        let spec2 = spec.scaled_workers(2.0);
        let t2 = optimal_latency_bound(LatencyModel::A, &spec2);
        assert!((t1 / t2 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn faster_groups_get_more_load() {
        // With equal alpha, a larger mu (less straggling in model A scale
        // 1/(k mu)) ... the optimal load l*_j = k/(ξ*_j S) decreases in ξ*_j;
        // ξ* decreases with mu, so higher-mu groups receive MORE rows.
        let spec = ClusterSpec::new(
            vec![
                Group { n: 100, mu: 8.0, alpha: 1.0 },
                Group { n: 100, mu: 1.0, alpha: 1.0 },
            ],
            10_000,
        )
        .unwrap();
        let a = proposed_allocation(LatencyModel::A, &spec).unwrap();
        assert!(
            a.loads[0] > a.loads[1],
            "fast group load {} <= slow group load {}",
            a.loads[0],
            a.loads[1]
        );
    }

    #[test]
    fn validates_against_spec() {
        let spec = ClusterSpec::paper_two_group(10_000);
        let a = proposed_allocation(LatencyModel::A, &spec).unwrap();
        a.validate(&spec).unwrap();
        assert!(a.rate(10_000.0) > 0.0 && a.rate(10_000.0) < 1.0);
    }

    #[test]
    fn capped_allocation_respects_budget_and_decodability() {
        let spec = ClusterSpec::paper_two_group(10_000);
        let free = proposed_allocation(LatencyModel::A, &spec).unwrap();
        // Loose cap: identical to the unconstrained solution.
        let loose =
            proposed_allocation_capped(LatencyModel::A, &spec, free.n * 2.0).unwrap();
        assert_eq!(loose.loads, free.loads);
        assert!(loose.latency_bound.is_some());
        // Tight cap: scaled onto the budget, still decodable.
        let cap = free.n * 0.8;
        assert!(cap >= 10_000.0, "test needs cap >= k");
        let tight = proposed_allocation_capped(LatencyModel::A, &spec, cap).unwrap();
        assert!((tight.n - cap).abs() < 1e-6 * cap);
        tight.validate(&spec).unwrap();
        for (t, f) in tight.loads.iter().zip(&free.loads) {
            assert!((t / f - cap / free.n).abs() < 1e-12);
        }
        // Budget below k is refused.
        assert!(
            proposed_allocation_capped(LatencyModel::A, &spec, 9_000.0).is_err()
        );
    }

    #[test]
    fn large_mu_stays_finite() {
        // Paper evaluates up to mu < 750; allocation must not overflow.
        let spec = ClusterSpec::new(
            vec![
                Group { n: 100, mu: 740.0, alpha: 1.0 },
                Group { n: 100, mu: 1.0, alpha: 1.0 },
            ],
            10_000,
        )
        .unwrap();
        let a = proposed_allocation(LatencyModel::A, &spec).unwrap();
        assert!(a.loads.iter().all(|l| l.is_finite() && *l > 0.0));
        assert!(a.latency_bound.unwrap().is_finite());
    }
}
