//! Load-allocation policies.
//!
//! Every policy evaluated in the paper's §IV is implemented:
//!
//! | Policy | Paper reference | Module |
//! |--------|-----------------|--------|
//! | Proposed optimum | Theorem 2 (model A), Corollary 2 (model B) | [`proposed`] |
//! | Uniform given `n` (incl. uncoded `n=k`) | §III-D-1 | [`uniform`] |
//! | Fixed-`r` group code of [33] | §III-D-2, Theorem 4 | [`group_code`] |
//! | Heterogeneous scheme of [32] | Appendix D | [`reisizadeh`] |
//!
//! All policies produce an [`Allocation`]: per-group real-valued loads
//! `l_(j)`, the implied `(n, k)` MDS code, and (where the paper defines one)
//! the analytic latency lower bound.
//!
//! The free functions above are the raw solvers; the [`policy`] module
//! wraps each in a [`Policy`] object and registers it in the central
//! **registry**, which is the single source of truth for policy names
//! across the CLI, the simulator, the workload layer, and the figure
//! harness. New schemes implement [`Policy`] in one module and add one
//! [`policy::PolicyEntry`] line.

#![forbid(unsafe_code)]

pub mod group_code;
pub mod integerize;
pub mod policy;
pub mod proposed;
pub mod reisizadeh;
pub mod uniform;

pub use group_code::{group_code_allocation, integer_group_r, solve_group_r};
pub use integerize::{largest_remainder_loads, optimize_integer_loads};
pub use policy::{
    DecodeRule, GroupCodePolicy, ParamSpec, Policy, PolicyEntry,
    ProposedPolicy, ReisizadehPolicy, UncodedPolicy, UniformOptimalNPolicy,
    UniformRatePolicy,
};
pub use proposed::{
    optimal_latency_bound, proposed_allocation, proposed_allocation_capped,
};
pub use reisizadeh::reisizadeh_allocation;
pub use uniform::{uncoded_allocation, uniform_allocation};

use crate::model::{ClusterSpec, LatencyModel};
use crate::{Error, Result};

/// Result of running an allocation policy on a cluster.
#[derive(Clone, Debug)]
pub struct Allocation {
    /// Which latency model the analytic quantities refer to.
    pub model: LatencyModel,
    /// Human-readable policy name (for figures/logs).
    pub policy: String,
    /// Real-valued per-group loads `l_(j)` (coded rows per worker).
    pub loads: Vec<f64>,
    /// Per-group expected completion counts `r_j` used by the analysis
    /// (empty when the policy does not define them, e.g. plain uniform).
    pub r: Vec<f64>,
    /// Real-valued code length `n = Σ N_j l_(j)`.
    pub n: f64,
    /// Analytic expected-latency lower bound, when the policy defines one.
    pub latency_bound: Option<f64>,
}

impl Allocation {
    /// Code rate `k/n`.
    pub fn rate(&self, k: f64) -> f64 {
        k / self.n
    }

    /// Integer per-group loads `⌈l_(j)⌉` (paper §III-B: ceil; effect is
    /// negligible at practical `k`).
    pub fn integer_loads(&self) -> Vec<usize> {
        self.loads.iter().map(|&l| l.ceil().max(1.0) as usize).collect()
    }

    /// Integer code length implied by [`Allocation::integer_loads`].
    pub fn integer_n(&self, spec: &ClusterSpec) -> usize {
        self.integer_loads()
            .iter()
            .zip(&spec.groups)
            .map(|(&l, g)| l * g.n)
            .sum()
    }

    /// Expand per-group loads into one entry per worker (group-major order),
    /// using integer loads.
    pub fn per_worker_loads(&self, spec: &ClusterSpec) -> Vec<usize> {
        let ints = self.integer_loads();
        let mut out = Vec::with_capacity(spec.total_workers());
        for (l, g) in ints.iter().zip(&spec.groups) {
            out.extend(std::iter::repeat(*l).take(g.n));
        }
        out
    }

    /// Validate structural invariants against a spec.
    pub fn validate(&self, spec: &ClusterSpec) -> Result<()> {
        if self.loads.len() != spec.num_groups() {
            return Err(Error::InvalidSpec(format!(
                "allocation has {} groups, spec has {}",
                self.loads.len(),
                spec.num_groups()
            )));
        }
        if self.loads.iter().any(|&l| !(l > 0.0) || !l.is_finite()) {
            return Err(Error::InvalidSpec(format!(
                "non-positive load in {:?}",
                self.loads
            )));
        }
        let n: f64 = self
            .loads
            .iter()
            .zip(&spec.groups)
            .map(|(&l, g)| l * g.n as f64)
            .sum();
        if (n - self.n).abs() > 1e-6 * n.max(1.0) {
            return Err(Error::InvalidSpec(format!(
                "n field {} inconsistent with loads ({n})",
                self.n
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Group;

    fn spec() -> ClusterSpec {
        ClusterSpec::new(
            vec![
                Group { n: 10, mu: 2.0, alpha: 1.0 },
                Group { n: 20, mu: 1.0, alpha: 1.0 },
            ],
            1000,
        )
        .unwrap()
    }

    #[test]
    fn integerization_rounds_up() {
        let a = Allocation {
            model: LatencyModel::A,
            policy: "test".into(),
            loads: vec![10.2, 5.9],
            r: vec![],
            n: 10.2 * 10.0 + 5.9 * 20.0,
            latency_bound: None,
        };
        assert_eq!(a.integer_loads(), vec![11, 6]);
        assert_eq!(a.integer_n(&spec()), 11 * 10 + 6 * 20);
    }

    #[test]
    fn per_worker_expansion() {
        let a = Allocation {
            model: LatencyModel::A,
            policy: "test".into(),
            loads: vec![3.0, 2.0],
            r: vec![],
            n: 3.0 * 10.0 + 2.0 * 20.0,
            latency_bound: None,
        };
        let w = a.per_worker_loads(&spec());
        assert_eq!(w.len(), 30);
        assert!(w[..10].iter().all(|&l| l == 3));
        assert!(w[10..].iter().all(|&l| l == 2));
    }

    #[test]
    fn validation_catches_inconsistency() {
        let mut a = Allocation {
            model: LatencyModel::A,
            policy: "test".into(),
            loads: vec![3.0, 2.0],
            r: vec![],
            n: 70.0,
            latency_bound: None,
        };
        assert!(a.validate(&spec()).is_ok());
        a.n = 50.0;
        assert!(a.validate(&spec()).is_err());
        a.n = 70.0;
        a.loads = vec![3.0];
        assert!(a.validate(&spec()).is_err());
        a.loads = vec![3.0, -1.0];
        assert!(a.validate(&spec()).is_err());
    }
}
