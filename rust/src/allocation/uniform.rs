//! Uniform load allocation (paper §III-D-1) and the uncoded baseline.
//!
//! Every worker receives `l = n/N` coded rows regardless of its group. The
//! recovery condition becomes `Σ r_j = kN/n` completions from anywhere in the
//! cluster. The uncoded scheme is the special case `n = k` (rate 1), which
//! requires *all* workers to finish.

use crate::allocation::Allocation;
use crate::model::{ClusterSpec, LatencyModel};
use crate::{Error, Result};

/// Uniform allocation for a given code length `n >= k`.
pub fn uniform_allocation(
    model: LatencyModel,
    spec: &ClusterSpec,
    n: f64,
) -> Result<Allocation> {
    let k = spec.k as f64;
    if n < k {
        return Err(Error::InvalidSpec(format!(
            "uniform allocation needs n >= k (n={n}, k={k})"
        )));
    }
    let total = spec.total_workers() as f64;
    let l = n / total;
    // Completions required: r = kN/n (eq. 26).
    let r_needed = k * total / n;
    if r_needed > total {
        return Err(Error::InvalidSpec(format!(
            "required completions {r_needed} exceed worker count {total}"
        )));
    }
    Ok(Allocation {
        model,
        policy: format!("uniform(rate {:.3})", k / n),
        loads: vec![l; spec.num_groups()],
        r: vec![],
        n,
        latency_bound: None,
    })
}

/// The uncoded baseline: `n = k`, all `N` workers must finish.
pub fn uncoded_allocation(model: LatencyModel, spec: &ClusterSpec) -> Result<Allocation> {
    let mut a = uniform_allocation(model, spec, spec.k as f64)?;
    a.policy = "uncoded".into();
    Ok(a)
}

/// Number of worker completions the master must wait for under uniform
/// allocation (`⌈ kN/n ⌉` with real-valued analysis value `kN/n`).
pub fn uniform_completions_needed(spec: &ClusterSpec, n: f64) -> f64 {
    spec.k as f64 * spec.total_workers() as f64 / n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_loads_equal_across_groups() {
        let spec = ClusterSpec::paper_five_group(2500, 10_000);
        let a = uniform_allocation(LatencyModel::A, &spec, 20_000.0).unwrap();
        assert!(a.loads.iter().all(|&l| (l - 8.0).abs() < 1e-12));
        a.validate(&spec).unwrap();
    }

    #[test]
    fn rate_half_doubles_load_vs_uncoded() {
        let spec = ClusterSpec::paper_five_group(2500, 10_000);
        let coded = uniform_allocation(LatencyModel::A, &spec, 20_000.0).unwrap();
        let uncoded = uncoded_allocation(LatencyModel::A, &spec).unwrap();
        assert!((coded.loads[0] / uncoded.loads[0] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn completions_formula_eq26() {
        let spec = ClusterSpec::paper_five_group(2500, 10_000);
        // rate 1/2: need kN/n = N/2 completions.
        let r = uniform_completions_needed(&spec, 20_000.0);
        assert!((r - 1250.0).abs() < 1e-9);
        // uncoded: need all N.
        let r = uniform_completions_needed(&spec, 10_000.0);
        assert!((r - 2500.0).abs() < 1e-9);
    }

    #[test]
    fn rejects_n_below_k() {
        let spec = ClusterSpec::paper_five_group(2500, 10_000);
        assert!(uniform_allocation(LatencyModel::A, &spec, 5_000.0).is_err());
    }

    #[test]
    fn uncoded_is_rate_one() {
        let spec = ClusterSpec::paper_two_group(6_000);
        let a = uncoded_allocation(LatencyModel::A, &spec).unwrap();
        assert!((a.rate(6_000.0) - 1.0).abs() < 1e-12);
    }
}
