//! The fixed-`r` group-code scheme of Kim/Sohn/Moon [33] (paper §III-D-2).
//!
//! The data matrix is split into `r` equal submatrices (`l = k/r` rows per
//! worker regardless of `N`), group `j` is assigned `r_j` submatrices encoded
//! with an `(N_j, r_j)` MDS code, and the master decodes group-wise after
//! receiving `r_j` results from each group. The per-group counts solve
//! eq. (29):
//!
//! ```text
//! r_j + Σ_{j'≠j} N_j' (1 - (1 - r_j/N_j)^{μ_j'/μ_j}) = r .
//! ```
//!
//! **Reproduction note on the paper's no-solution claim.** The paper states
//! that (29) may have no solution for `G > 2`, citing
//! `G=3, r=200, N=(100,200,300), μ=(3,2,1)`. In the *real-valued* relaxation
//! this is not so: substituting the equalization variable
//! `c = (1/μ_j) log(N_j/(N_j - r_j))` collapses all `G` equations into the
//! single strictly-increasing equation `Σ_j N_j (1 - e^{-μ_j c}) = r`,
//! which has a unique root for every `0 < r < N` (the cited instance gives
//! `r = (53.26, 79.55, 67.19)`). What genuinely can fail is an **integer**
//! solution — `(N_j, r_j)` MDS codes need integer `r_j`, and rounding the
//! real root generally breaks `Σ r_j = r`; [`integer_group_r`] reports that.
//! The asymptotic latency of the scheme is `1/r` (model A), which Fig. 4
//! plots as "lower bound of group code".

use crate::allocation::Allocation;
use crate::model::{ClusterSpec, LatencyModel};
use crate::{Error, Result};

/// Solve eq. (29) for group `j`'s completion count `r_j` by bisection.
///
/// The left-hand side is strictly increasing in `r_j` on `(0, N_j)`, so a
/// solution exists iff `lim_{r_j→N_j⁻} LHS > r` (the limit may be finite
/// when some exponent `μ_j'/μ_j < 1` keeps other groups below saturation —
/// that is exactly the paper's no-solution case).
pub fn solve_group_r(spec: &ClusterSpec, j: usize, r: f64) -> Result<f64> {
    let nj = spec.groups[j].n as f64;
    let muj = spec.groups[j].mu;
    let lhs = |rj: f64| -> f64 {
        let mut acc = rj;
        for (jp, grp) in spec.groups.iter().enumerate() {
            if jp == j {
                continue;
            }
            let njp = grp.n as f64;
            let expo = grp.mu / muj;
            acc += njp * (1.0 - (1.0 - rj / nj).powf(expo));
        }
        acc
    };
    // Feasibility: LHS at r_j -> N_j^- saturates to N (every group finishes),
    // but approach it numerically.
    let hi0 = nj * (1.0 - 1e-12);
    if lhs(hi0) < r {
        return Err(Error::NoSolution(format!(
            "group {j}: max attainable aggregate {:.3} < r = {r}",
            lhs(hi0)
        )));
    }
    let (mut lo, mut hi) = (0.0f64, hi0);
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if lhs(mid) < r {
            lo = mid;
        } else {
            hi = mid;
        }
        if hi - lo < 1e-13 * nj {
            break;
        }
    }
    Ok(0.5 * (lo + hi))
}

/// Integer per-group counts for the `(N_j, r_j)` MDS codes: rounds the
/// real-valued solution and reports whether an exact integer solution with
/// `Σ r_j = r` exists under equalization (generally it does not — the
/// phenomenon behind the paper's `G > 2` no-solution remark).
///
/// Returns `(r_int, exact)` where `r_int` is the nearest-integer rounding
/// with the total fixed up greedily to `r` and `exact` is whether plain
/// rounding already summed to `r`.
pub fn integer_group_r(spec: &ClusterSpec, r: f64) -> Result<(Vec<usize>, bool)> {
    let mut rs = Vec::with_capacity(spec.num_groups());
    for j in 0..spec.num_groups() {
        rs.push(solve_group_r(spec, j, r)?);
    }
    let target = r.round() as i64;
    let mut ints: Vec<i64> = rs.iter().map(|&x| x.round() as i64).collect();
    let exact = ints.iter().sum::<i64>() == target;
    // Greedy fix-up: adjust the entries with the largest rounding slack.
    let mut diff = target - ints.iter().sum::<i64>();
    let mut order: Vec<usize> = (0..ints.len()).collect();
    order.sort_by(|&a, &b| {
        let fa = (rs[a] - rs[a].round()).abs();
        let fb = (rs[b] - rs[b].round()).abs();
        // total_cmp, descending: slacks are |x - round(x)| of finite
        // loads, so never NaN; keeps the exact order the solver pinned.
        fb.total_cmp(&fa)
    });
    let mut oi = 0;
    while diff != 0 && !order.is_empty() {
        let j = order[oi % order.len()];
        let step = diff.signum();
        let cand = ints[j] + step;
        if cand >= 1 && cand < spec.groups[j].n as i64 {
            ints[j] = cand;
            diff -= step;
        }
        oi += 1;
        if oi > 10_000 {
            return Err(Error::NoSolution(format!(
                "cannot reach integer total r = {target}"
            )));
        }
    }
    Ok((ints.into_iter().map(|x| x as usize).collect(), exact))
}

/// Full fixed-`r` allocation: uniform load `l = k/r`, per-group `r_j` from
/// eq. (29), consistency-checked (`Σ r_j ≈ r`). Requires equal shift
/// parameters across groups (paper footnote 4) and `r <= N`.
pub fn group_code_allocation(
    model: LatencyModel,
    spec: &ClusterSpec,
    r: f64,
) -> Result<Allocation> {
    let k = spec.k as f64;
    let total = spec.total_workers() as f64;
    if r <= 0.0 || r > total {
        return Err(Error::InvalidSpec(format!(
            "need 0 < r <= N (r={r}, N={total})"
        )));
    }
    let alpha0 = spec.groups[0].alpha;
    if spec
        .groups
        .iter()
        .any(|g| (g.alpha - alpha0).abs() > 1e-12)
    {
        return Err(Error::InvalidSpec(
            "group-code scheme of [33] requires equal shift parameters".into(),
        ));
    }
    let mut rs = Vec::with_capacity(spec.num_groups());
    for j in 0..spec.num_groups() {
        rs.push(solve_group_r(spec, j, r)?);
    }
    // Consistency: the same aggregate equation must give Σ r_j = r.
    let sum: f64 = rs.iter().sum();
    if (sum - r).abs() > 1e-3 * r {
        return Err(Error::NoSolution(format!(
            "inconsistent per-group solution: Σ r_j = {sum:.4} != r = {r}"
        )));
    }
    let l = k / r;
    let n = l * total;
    // Asymptotic latency of the scheme (paper §III-D-2): 1/r under model A,
    // k/r under model B.
    let bound = match model {
        LatencyModel::A => 1.0 / r,
        LatencyModel::B => k / r,
    };
    Ok(Allocation {
        model,
        policy: format!("group-code(r={r})"),
        loads: vec![l; spec.num_groups()],
        r: rs,
        n,
        latency_bound: Some(bound),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Group;

    #[test]
    fn two_group_solution_satisfies_eq29() {
        let spec = ClusterSpec::new(
            vec![
                Group { n: 300, mu: 4.0, alpha: 1.0 },
                Group { n: 600, mu: 0.5, alpha: 1.0 },
            ],
            10_000,
        )
        .unwrap();
        let r = 400.0;
        let a = group_code_allocation(LatencyModel::A, &spec, r).unwrap();
        assert!((a.r.iter().sum::<f64>() - r).abs() < 1e-6 * r);
        // Check eq. (28) equalization: (1/mu_j) log(N_j/(N_j-r_j)) equal.
        let v0 = (300.0f64 / (300.0 - a.r[0])).ln() / 4.0;
        let v1 = (600.0f64 / (600.0 - a.r[1])).ln() / 0.5;
        assert!((v0 - v1).abs() < 1e-6 * v0.max(v1), "{v0} vs {v1}");
    }

    #[test]
    fn paper_no_solution_example_real_vs_integer() {
        // §III-D cites G=3, r=200, N=(100,200,300), μ=(3,2,1) as having no
        // solution. The real-valued relaxation *does* solve (see module
        // docs): r ≈ (53.26, 79.55, 67.19). The failure is integrality:
        // plain rounding misses Σ r_j = r.
        let spec = ClusterSpec::new(
            vec![
                Group { n: 100, mu: 3.0, alpha: 1.0 },
                Group { n: 200, mu: 2.0, alpha: 1.0 },
                Group { n: 300, mu: 1.0, alpha: 1.0 },
            ],
            10_000,
        )
        .unwrap();
        let a = group_code_allocation(LatencyModel::A, &spec, 200.0).unwrap();
        assert!((a.r[0] - 53.26).abs() < 0.05, "r_1 = {}", a.r[0]);
        assert!((a.r[1] - 79.55).abs() < 0.05, "r_2 = {}", a.r[1]);
        assert!((a.r[2] - 67.19).abs() < 0.05, "r_3 = {}", a.r[2]);
        // Integer fix-up still produces a usable assignment.
        let (ints, exact) = integer_group_r(&spec, 200.0).unwrap();
        assert_eq!(ints.iter().sum::<usize>(), 200);
        let _ = exact; // exactness is instance-dependent
    }

    #[test]
    fn five_group_paper_setting_solves() {
        let spec = ClusterSpec::paper_five_group(2500, 10_000);
        let a = group_code_allocation(LatencyModel::A, &spec, 100.0).unwrap();
        assert!((a.r.iter().sum::<f64>() - 100.0).abs() < 0.1);
        assert!((a.loads[0] - 100.0).abs() < 1e-9); // l = k/r = 10000/100
        assert!((a.latency_bound.unwrap() - 0.01).abs() < 1e-12); // 1/r
    }

    #[test]
    fn load_is_k_over_r_independent_of_n() {
        // The defining property of [33]: load fixed as N grows.
        for total in [1000usize, 2000, 4000] {
            let spec = ClusterSpec::paper_five_group(total, 10_000);
            let a = group_code_allocation(LatencyModel::A, &spec, 100.0).unwrap();
            assert!((a.loads[0] - 100.0).abs() < 1e-9);
        }
    }

    #[test]
    fn rejects_unequal_alpha() {
        let spec = ClusterSpec::paper_three_group_b(1000, 10_000);
        assert!(group_code_allocation(LatencyModel::A, &spec, 100.0).is_err());
    }

    #[test]
    fn rejects_r_out_of_range() {
        let spec = ClusterSpec::paper_two_group(1000);
        assert!(group_code_allocation(LatencyModel::A, &spec, 0.0).is_err());
        assert!(group_code_allocation(LatencyModel::A, &spec, 1e9).is_err());
    }

    #[test]
    fn homogeneous_split_proportional() {
        // Equal mu: r_j proportional to N_j.
        let spec = ClusterSpec::new(
            vec![
                Group { n: 100, mu: 2.0, alpha: 1.0 },
                Group { n: 300, mu: 2.0, alpha: 1.0 },
            ],
            1000,
        )
        .unwrap();
        let a = group_code_allocation(LatencyModel::A, &spec, 200.0).unwrap();
        assert!((a.r[0] - 50.0).abs() < 1e-6);
        assert!((a.r[1] - 150.0).abs() < 1e-6);
    }
}
