//! The [`Policy`] trait and the central policy **registry** — the single
//! source of truth for allocation-policy names.
//!
//! Before this module existed, every policy was a free function with its
//! own signature, and each CLI subcommand (`simulate --scheme`,
//! `workload --policies`, `allocate`) kept a private `match` from name
//! strings to those functions. Adding a policy meant editing five call
//! sites. Now a policy is one object implementing [`Policy`], and every
//! name-to-policy resolution in the crate — CLI subcommands, the figure
//! harness, tests — goes through [`resolve`] / [`entries`]. Adding a new
//! scheme (e.g. a communication-delay-aware allocation à la Sun et al.,
//! arXiv:2109.11246) is one module implementing the trait plus **one
//! [`PolicyEntry`] line** in [`REGISTRY`].
//!
//! Policies are **code-agnostic**: an allocation assigns integer row
//! counts `l_i` and never inspects the generator, so the same policy
//! serves under any [`crate::coding::Code`] registry entry (the code
//! registry in [`crate::coding::code`] deliberately mirrors this one —
//! `policy × code` are orthogonal axes, resolved independently at session
//! build). Only [`Policy::decode_rule`] touches decode semantics, and it
//! describes the *allocation's* completion rule, not the code's algebra.
//!
//! # Example
//!
//! ```
//! use hetcoded::allocation::policy::{self, DecodeRule, Policy};
//! use hetcoded::model::{ClusterSpec, LatencyModel};
//!
//! let spec = ClusterSpec::paper_two_group(10_000);
//! // Resolve by registry name; parameterized policies take `name=value`.
//! let p = policy::resolve("proposed")?;
//! let alloc = p.allocate(LatencyModel::A, &spec)?;
//! assert!(alloc.latency_bound.is_some());
//! assert_eq!(p.decode_rule(), DecodeRule::AnyK);
//!
//! let g = policy::resolve("group-code=100")?;
//! assert_eq!(g.decode_rule(), DecodeRule::PerGroup);
//! # Ok::<(), hetcoded::Error>(())
//! ```

use crate::allocation::{
    group_code_allocation, proposed_allocation, proposed_allocation_capped,
    reisizadeh_allocation, uncoded_allocation, uniform_allocation, Allocation,
};
use crate::model::{ClusterSpec, LatencyModel};
use crate::{Error, Result};

/// How a policy's code decodes: from **any** `k` aggregated rows (the
/// `(n, k)` MDS code over the whole matrix, §II-C) or **per group** (the
/// fixed-`r` group code of [33], which needs `r_j` completions from every
/// group). The simulator and the workload layer pick their order-statistic
/// sampler from this, so a new policy never has to touch either.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecodeRule {
    /// Job completes once any workers holding `k` coded rows finish.
    AnyK,
    /// Job completes once every group has returned its `r_j` results
    /// (the allocation's [`Allocation::r`] vector must be populated).
    PerGroup,
}

/// A load-allocation policy: everything the rest of the crate needs to
/// know about one scheme from the paper's evaluation (or a new one).
///
/// Implementations are cheap value objects; the registry hands them out as
/// `Box<dyn Policy>`. The [`crate::sim`] engine, the [`crate::workload`]
/// queueing layer, and the [`crate::coordinator::Session`] facade all
/// consume `&dyn Policy`, so a new scheme is a drop-in.
pub trait Policy: Send + Sync + std::fmt::Debug {
    /// Stable display name used in figures, CSV output, and reports
    /// (e.g. `"uniform-rate-0.500"`). Distinct from the registry name,
    /// which is the CLI-facing spelling (e.g. `"uniform-rate"`).
    fn name(&self) -> String;

    /// Solve the policy's allocation on `spec` under `model`.
    fn allocate(&self, model: LatencyModel, spec: &ClusterSpec) -> Result<Allocation>;

    /// [`Policy::allocate`] under a coded-row budget: the solution's `n`
    /// must not exceed `n_cap` (re-solving mid-stream must not mint coded
    /// rows — see [`crate::coordinator::PreparedJob::rechunk`]). The
    /// default refuses budgets the unconstrained solution overruns;
    /// policies with a principled projection (the proposed optimum)
    /// override it.
    fn allocate_capped(
        &self,
        model: LatencyModel,
        spec: &ClusterSpec,
        n_cap: f64,
    ) -> Result<Allocation> {
        let a = self.allocate(model, spec)?;
        if a.n > n_cap {
            return Err(Error::InvalidSpec(format!(
                "policy `{}` wants n = {:.1} > coded-row budget {n_cap} and \
                 defines no capped projection",
                self.name(),
                a.n
            )));
        }
        Ok(a)
    }

    /// Which completion rule the code decodes under (drives the
    /// order-statistic sampler choice in `sim` and `workload`).
    fn decode_rule(&self) -> DecodeRule {
        DecodeRule::AnyK
    }

    /// Whether the paper derives a closed-form expected-latency bound for
    /// this policy (`T*` for the proposed optimum, `1/r` for the group
    /// code); simulation results surface [`Allocation::latency_bound`]
    /// only when this is true.
    fn reports_bound(&self) -> bool {
        false
    }
}

/// The proposed optimal allocation (Theorem 2 / Corollary 2).
#[derive(Clone, Copy, Debug, Default)]
pub struct ProposedPolicy;

impl Policy for ProposedPolicy {
    fn name(&self) -> String {
        "proposed".into()
    }

    fn allocate(&self, model: LatencyModel, spec: &ClusterSpec) -> Result<Allocation> {
        proposed_allocation(model, spec)
    }

    fn allocate_capped(
        &self,
        model: LatencyModel,
        spec: &ClusterSpec,
        n_cap: f64,
    ) -> Result<Allocation> {
        proposed_allocation_capped(model, spec, n_cap)
    }

    fn reports_bound(&self) -> bool {
        true
    }
}

/// The uncoded baseline: rate-1 uniform, every worker must finish.
#[derive(Clone, Copy, Debug, Default)]
pub struct UncodedPolicy;

impl Policy for UncodedPolicy {
    fn name(&self) -> String {
        "uncoded".into()
    }

    fn allocate(&self, model: LatencyModel, spec: &ClusterSpec) -> Result<Allocation> {
        uncoded_allocation(model, spec)
    }
}

/// Uniform allocation reusing the proposed optimum's code length `n*`
/// (§III-D-1) — isolates the *allocation shape* from the *code rate*.
#[derive(Clone, Copy, Debug, Default)]
pub struct UniformOptimalNPolicy;

impl Policy for UniformOptimalNPolicy {
    fn name(&self) -> String {
        "uniform-n*".into()
    }

    fn allocate(&self, model: LatencyModel, spec: &ClusterSpec) -> Result<Allocation> {
        let opt = proposed_allocation(model, spec)?;
        uniform_allocation(model, spec, opt.n)
    }
}

/// Uniform allocation with an explicit code rate `k/n`.
#[derive(Clone, Copy, Debug)]
pub struct UniformRatePolicy {
    /// Code rate `k/n` in `(0, 1]`.
    pub rate: f64,
}

impl UniformRatePolicy {
    /// Validate the rate and build the policy.
    pub fn new(rate: f64) -> Result<UniformRatePolicy> {
        if !(rate > 0.0 && rate <= 1.0) || !rate.is_finite() {
            return Err(Error::InvalidSpec(format!(
                "uniform-rate needs a rate in (0, 1], got {rate}"
            )));
        }
        Ok(UniformRatePolicy { rate })
    }
}

impl Policy for UniformRatePolicy {
    fn name(&self) -> String {
        format!("uniform-rate-{:.3}", self.rate)
    }

    fn allocate(&self, model: LatencyModel, spec: &ClusterSpec) -> Result<Allocation> {
        uniform_allocation(model, spec, spec.k as f64 / self.rate)
    }
}

/// The fixed-`r` group code of [33] (§III-D-2, Theorem 4): group-wise
/// decode, so the completion rule is per-group.
#[derive(Clone, Copy, Debug)]
pub struct GroupCodePolicy {
    /// Target per-group completion count `r`.
    pub r: f64,
}

impl GroupCodePolicy {
    /// Validate `r` and build the policy.
    pub fn new(r: f64) -> Result<GroupCodePolicy> {
        if !(r > 0.0) || !r.is_finite() {
            return Err(Error::InvalidSpec(format!(
                "group-code needs a positive finite r, got {r}"
            )));
        }
        Ok(GroupCodePolicy { r })
    }
}

impl Policy for GroupCodePolicy {
    fn name(&self) -> String {
        format!("group-code-r{:.0}", self.r)
    }

    fn allocate(&self, model: LatencyModel, spec: &ClusterSpec) -> Result<Allocation> {
        group_code_allocation(model, spec, self.r)
    }

    fn decode_rule(&self) -> DecodeRule {
        DecodeRule::PerGroup
    }

    fn reports_bound(&self) -> bool {
        true
    }
}

/// The heterogeneous allocation of Reisizadeh et al. [32] (Appendix D).
#[derive(Clone, Copy, Debug, Default)]
pub struct ReisizadehPolicy;

impl Policy for ReisizadehPolicy {
    fn name(&self) -> String {
        "reisizadeh".into()
    }

    fn allocate(&self, model: LatencyModel, spec: &ClusterSpec) -> Result<Allocation> {
        reisizadeh_allocation(model, spec)
    }
}

/// Metadata for a policy's optional scalar parameter: which CLI flag feeds
/// it, its default, and what it means.
#[derive(Clone, Copy, Debug)]
pub struct ParamSpec {
    /// CLI flag name (without `--`) that supplies the parameter when the
    /// `name=value` form is not used.
    pub flag: &'static str,
    /// Value used when neither `name=value` nor the flag is given.
    pub default: f64,
    /// One-line human description of the parameter.
    pub what: &'static str,
}

/// One registry row: the CLI-facing name, a summary for `help`, the
/// optional parameter, and the constructor.
pub struct PolicyEntry {
    /// CLI-facing policy name (`--scheme`, `--policies`, `--policy`).
    pub name: &'static str,
    /// One-line description for help output.
    pub summary: &'static str,
    /// Scalar parameter, if the policy takes one.
    pub param: Option<ParamSpec>,
    builder: fn(Option<f64>) -> Result<Box<dyn Policy>>,
}

impl PolicyEntry {
    /// Build the policy, defaulting a missing parameter and rejecting a
    /// parameter the policy does not take.
    pub fn build(&self, param: Option<f64>) -> Result<Box<dyn Policy>> {
        match (&self.param, param) {
            (None, Some(v)) => Err(Error::InvalidSpec(format!(
                "policy `{}` takes no parameter (got `{v}`)",
                self.name
            ))),
            (None, None) => (self.builder)(None),
            (Some(ps), p) => (self.builder)(Some(p.unwrap_or(ps.default))),
        }
    }
}

impl std::fmt::Debug for PolicyEntry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PolicyEntry")
            .field("name", &self.name)
            .field("param", &self.param)
            .finish()
    }
}

/// The registry itself. **This slice is the single source of truth for
/// policy names**: every CLI subcommand and the figure harness resolve
/// through it. Adding a policy = implementing [`Policy`] in one module and
/// appending one entry here.
pub static REGISTRY: &[PolicyEntry] = &[
    PolicyEntry {
        name: "proposed",
        summary: "optimal allocation of Theorem 2 / Corollary 2",
        param: None,
        builder: |_| Ok(Box::new(ProposedPolicy)),
    },
    PolicyEntry {
        name: "uncoded",
        summary: "rate-1 uniform baseline (every worker must finish)",
        param: None,
        builder: |_| Ok(Box::new(UncodedPolicy)),
    },
    PolicyEntry {
        name: "uniform-nstar",
        summary: "uniform allocation at the optimal code length n*",
        param: None,
        builder: |_| Ok(Box::new(UniformOptimalNPolicy)),
    },
    PolicyEntry {
        name: "uniform-rate",
        summary: "uniform allocation at an explicit code rate k/n",
        param: Some(ParamSpec { flag: "rate", default: 0.5, what: "code rate in (0, 1]" }),
        builder: |p| {
            UniformRatePolicy::new(p.expect("registry supplies the default"))
                .map(|x| Box::new(x) as Box<dyn Policy>)
        },
    },
    PolicyEntry {
        name: "group-code",
        summary: "fixed-r group code of [33] (group-wise decode)",
        param: Some(ParamSpec {
            flag: "group-r",
            default: 100.0,
            what: "per-group completion target r",
        }),
        builder: |p| {
            GroupCodePolicy::new(p.expect("registry supplies the default"))
                .map(|x| Box::new(x) as Box<dyn Policy>)
        },
    },
    PolicyEntry {
        name: "reisizadeh",
        summary: "heterogeneous allocation of Reisizadeh et al. [32]",
        param: None,
        builder: |_| Ok(Box::new(ReisizadehPolicy)),
    },
];

/// All registry rows, in display order.
pub fn entries() -> &'static [PolicyEntry] {
    REGISTRY
}

/// Look up one registry row by CLI name.
pub fn entry(name: &str) -> Option<&'static PolicyEntry> {
    REGISTRY.iter().find(|e| e.name == name)
}

/// Every registered CLI policy name, in display order.
pub fn policy_names() -> Vec<&'static str> {
    REGISTRY.iter().map(|e| e.name).collect()
}

/// Resolve a policy spec string: `name` (parameter defaulted) or
/// `name=value` for parameterized policies, e.g. `"uniform-rate=0.4"` or
/// `"group-code=120"`. Unknown names list the registry.
pub fn resolve(spec: &str) -> Result<Box<dyn Policy>> {
    let (name, param) = match spec.split_once('=') {
        Some((n, v)) => {
            let p = v.trim().parse::<f64>().map_err(|_| {
                Error::InvalidSpec(format!(
                    "policy `{n}`: cannot parse parameter `{v}`"
                ))
            })?;
            (n.trim(), Some(p))
        }
        None => (spec.trim(), None),
    };
    let e = entry(name).ok_or_else(|| unknown_policy(name))?;
    e.build(param)
}

/// The error for an unresolvable policy name, listing what the registry
/// does know.
pub fn unknown_policy(name: &str) -> Error {
    Error::InvalidSpec(format!(
        "unknown policy `{name}` (known: {})",
        policy_names().join(", ")
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_are_unique_and_resolve() {
        let names = policy_names();
        for (i, n) in names.iter().enumerate() {
            assert!(
                !names[i + 1..].contains(n),
                "duplicate registry name `{n}`"
            );
            let p = resolve(n).unwrap_or_else(|e| panic!("{n}: {e}"));
            assert!(!p.name().is_empty());
        }
        assert!(resolve("no-such-policy").is_err());
    }

    #[test]
    fn every_policy_allocates_on_the_paper_cluster() {
        let spec = ClusterSpec::paper_two_group(10_000);
        for e in entries() {
            let p = e.build(None).unwrap();
            let a = p
                .allocate(LatencyModel::A, &spec)
                .unwrap_or_else(|err| panic!("{}: {err}", e.name));
            a.validate(&spec).unwrap();
            if p.decode_rule() == DecodeRule::PerGroup {
                assert_eq!(a.r.len(), spec.num_groups());
            }
        }
    }

    #[test]
    fn param_syntax_and_validation() {
        let p = resolve("uniform-rate=0.4").unwrap();
        assert_eq!(p.name(), "uniform-rate-0.400");
        assert!(resolve("uniform-rate=1.5").is_err());
        assert!(resolve("uniform-rate=x").is_err());
        assert!(resolve("group-code=0").is_err());
        // Parameter on a parameter-less policy is rejected.
        assert!(entry("proposed").unwrap().build(Some(1.0)).is_err());
        // Defaults flow from the registry.
        let g = resolve("group-code").unwrap();
        assert_eq!(g.name(), "group-code-r100");
    }

    #[test]
    fn default_capped_allocation_refuses_overrun() {
        let spec = ClusterSpec::paper_two_group(10_000);
        let unc = UncodedPolicy;
        // Uncoded wants n = k exactly; a budget of k passes, below-k is
        // refused by the allocation itself.
        let a = unc
            .allocate_capped(LatencyModel::A, &spec, spec.k as f64)
            .unwrap();
        assert!((a.n - spec.k as f64).abs() < 1e-9);
        let ur = UniformRatePolicy::new(0.5).unwrap();
        assert!(ur
            .allocate_capped(LatencyModel::A, &spec, spec.k as f64)
            .is_err());
        // The proposed policy projects onto the budget instead.
        let p = ProposedPolicy;
        let free = p.allocate(LatencyModel::A, &spec).unwrap();
        let capped = p
            .allocate_capped(LatencyModel::A, &spec, free.n * 0.9)
            .unwrap();
        assert!(capped.n <= free.n * 0.9 + 1e-6);
    }
}
