//! PJRT runtime: load and execute AOT-compiled XLA artifacts.
//!
//! `make artifacts` runs `python/compile/aot.py` once, lowering the L2 JAX
//! model (which calls the L1 Pallas kernels) to **HLO text** — the
//! interchange format this image's `xla_extension 0.5.1` accepts (serialized
//! protos from jax ≥ 0.5 carry 64-bit instruction ids it rejects). This
//! module loads `artifacts/manifest.txt`, compiles one executable per tile
//! variant on the PJRT CPU client, and exposes typed entry points; Python is
//! never on the request path.
//!
//! Worker subtasks have heterogeneous row counts `l_i`, while AOT artifacts
//! have fixed shapes, so matvec executables come in **row-bucketed tiles**
//! (e.g. 64/128/256/512 rows × fixed `d`); a chunk is padded with zero rows
//! up to the smallest tile that fits, and the padding rows are discarded
//! from the result.

//! Manifest parsing is always available; the PJRT `Runtime` itself (and
//! everything touching the `xla` crate) is gated behind the `xla` cargo
//! feature, since it needs the native `xla_extension` library at link time.
//!
//! The other half of this module is the CPU-side execution substrate: the
//! persistent [`pool::WorkPool`] every parallel hot path (blocked matmul,
//! encode, multi-RHS decode, Monte-Carlo sweeps) runs on instead of
//! spawning threads per call.

pub mod clock;
pub mod pool;

pub use clock::wall_now;
pub use pool::{PoolHandle, WorkPool};

#[cfg(feature = "xla")]
use crate::coding::Matrix;
use crate::{Error, Result};
#[cfg(feature = "xla")]
use std::path::Path;
use std::path::PathBuf;

/// Default artifacts directory relative to the repo root.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// One artifact as listed in `manifest.txt`.
#[derive(Clone, Debug, PartialEq)]
pub enum ArtifactKind {
    /// `matvec <rows> <cols> <file>`: computes `A_tile · x`.
    Matvec { rows: usize, cols: usize },
    /// `matvecb <rows> <cols> <batch> <file>`: computes `A_tile · Xs` for a
    /// `(cols, batch)` request batch (MXU-shaped contraction).
    MatvecBatched { rows: usize, cols: usize, batch: usize },
    /// `encode <n> <k> <d> <file>`: computes `G · A`.
    Encode { n: usize, k: usize, d: usize },
}

/// Parsed manifest entry.
#[derive(Clone, Debug)]
pub struct ManifestEntry {
    /// Kind + shape.
    pub kind: ArtifactKind,
    /// HLO text file (relative to the artifacts dir).
    pub path: PathBuf,
}

/// Parse `manifest.txt` content.
pub fn parse_manifest(text: &str) -> Result<Vec<ManifestEntry>> {
    let mut out = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let parts: Vec<&str> = line.split_whitespace().collect();
        let err = |msg: &str| {
            Error::Runtime(format!("manifest line {}: {msg}", lineno + 1))
        };
        let parse_usize = |s: &str| {
            s.parse::<usize>()
                .map_err(|_| err(&format!("bad integer `{s}`")))
        };
        match parts.as_slice() {
            ["matvec", rows, cols, file] => out.push(ManifestEntry {
                kind: ArtifactKind::Matvec {
                    rows: parse_usize(rows)?,
                    cols: parse_usize(cols)?,
                },
                path: PathBuf::from(file),
            }),
            ["matvecb", rows, cols, batch, file] => out.push(ManifestEntry {
                kind: ArtifactKind::MatvecBatched {
                    rows: parse_usize(rows)?,
                    cols: parse_usize(cols)?,
                    batch: parse_usize(batch)?,
                },
                path: PathBuf::from(file),
            }),
            ["encode", n, k, d, file] => out.push(ManifestEntry {
                kind: ArtifactKind::Encode {
                    n: parse_usize(n)?,
                    k: parse_usize(k)?,
                    d: parse_usize(d)?,
                },
                path: PathBuf::from(file),
            }),
            _ => return Err(err(&format!("unrecognized entry `{line}`"))),
        }
    }
    if out.is_empty() {
        return Err(Error::Runtime("manifest is empty".into()));
    }
    Ok(out)
}

/// A loaded PJRT runtime with compiled executables.
#[cfg(feature = "xla")]
pub struct Runtime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    /// Matvec tiles sorted by row count ascending; all share `cols`.
    matvec_tiles: Vec<(usize, xla::PjRtLoadedExecutable)>,
    /// Batched matvec tiles `(rows, batch, exe)`, sorted by rows.
    matvecb_tiles: Vec<(usize, usize, xla::PjRtLoadedExecutable)>,
    cols: usize,
    /// Optional encode executable with its `(n, k, d)` shape.
    encode: Option<(usize, usize, usize, xla::PjRtLoadedExecutable)>,
}

#[cfg(feature = "xla")]
impl Runtime {
    /// Load all artifacts from `dir` and compile them on the PJRT CPU client.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest_path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} (run `make artifacts`): {e}",
                manifest_path.display()
            ))
        })?;
        let entries = parse_manifest(&text)?;
        let client = xla::PjRtClient::cpu()?;
        let mut matvec_tiles = Vec::new();
        let mut matvecb_tiles = Vec::new();
        let mut cols_seen: Option<usize> = None;
        let mut encode = None;
        for entry in entries {
            let full = dir.join(&entry.path);
            let proto = xla::HloModuleProto::from_text_file(&full)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            match entry.kind {
                ArtifactKind::Matvec { rows, cols } => {
                    if let Some(c) = cols_seen {
                        if c != cols {
                            return Err(Error::Runtime(format!(
                                "matvec tiles disagree on cols: {c} vs {cols}"
                            )));
                        }
                    }
                    cols_seen = Some(cols);
                    matvec_tiles.push((rows, exe));
                }
                ArtifactKind::MatvecBatched { rows, cols, batch } => {
                    if let Some(c) = cols_seen {
                        if c != cols {
                            return Err(Error::Runtime(format!(
                                "matvecb tiles disagree on cols: {c} vs {cols}"
                            )));
                        }
                    }
                    cols_seen = Some(cols);
                    matvecb_tiles.push((rows, batch, exe));
                }
                ArtifactKind::Encode { n, k, d } => {
                    encode = Some((n, k, d, exe));
                }
            }
        }
        if matvec_tiles.is_empty() {
            return Err(Error::Runtime("no matvec tiles in manifest".into()));
        }
        matvec_tiles.sort_by_key(|(r, _)| *r);
        matvecb_tiles.sort_by_key(|(r, _, _)| *r);
        Ok(Runtime {
            client,
            matvec_tiles,
            matvecb_tiles,
            cols: cols_seen.unwrap(),
            encode,
        })
    }

    /// Load from the default `artifacts/` directory.
    pub fn load_default() -> Result<Runtime> {
        Runtime::load(Path::new(DEFAULT_ARTIFACT_DIR))
    }

    /// Input width `d` all matvec tiles expect.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Available tile row counts (ascending).
    pub fn tile_rows(&self) -> Vec<usize> {
        self.matvec_tiles.iter().map(|(r, _)| *r).collect()
    }

    /// Largest tile (max rows a single call can handle).
    pub fn max_tile_rows(&self) -> usize {
        self.matvec_tiles.last().map(|(r, _)| *r).unwrap_or(0)
    }

    /// Compute `rows · x` through the AOT executable, bucketing the chunk to
    /// the smallest tile that fits and discarding padded rows.
    ///
    /// Chunks larger than the largest tile are processed in tile-sized
    /// pieces.
    pub fn matvec(&self, rows: &Matrix, x: &[f64]) -> Result<Vec<f64>> {
        if rows.cols() != self.cols {
            return Err(Error::Runtime(format!(
                "chunk has {} cols, artifacts compiled for {}",
                rows.cols(),
                self.cols
            )));
        }
        if x.len() != self.cols {
            return Err(Error::Runtime(format!(
                "x has {} entries, expected {}",
                x.len(),
                self.cols
            )));
        }
        let x32: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        let mut out = Vec::with_capacity(rows.rows());
        let max_tile = self.max_tile_rows();
        let mut start = 0usize;
        while start < rows.rows() {
            let l = (rows.rows() - start).min(max_tile);
            let (tile_rows, exe) = self.pick_tile(l);
            // Pack the chunk (f32) with zero-row padding to the tile shape.
            let mut buf = vec![0f32; tile_rows * self.cols];
            for i in 0..l {
                let src = rows.row(start + i);
                for (j, &v) in src.iter().enumerate() {
                    buf[i * self.cols + j] = v as f32;
                }
            }
            let a_lit = xla::Literal::vec1(&buf)
                .reshape(&[tile_rows as i64, self.cols as i64])?;
            let x_lit = xla::Literal::vec1(&x32);
            let result = exe.execute::<xla::Literal>(&[a_lit, x_lit])?[0][0]
                .to_literal_sync()?;
            let y = result.to_tuple1()?.to_vec::<f32>()?;
            out.extend(y[..l].iter().map(|&v| v as f64));
            start += l;
        }
        Ok(out)
    }

    fn pick_tile(&self, l: usize) -> (usize, &xla::PjRtLoadedExecutable) {
        for (r, exe) in &self.matvec_tiles {
            if *r >= l {
                return (*r, exe);
            }
        }
        let (r, exe) = self.matvec_tiles.last().unwrap();
        (*r, exe)
    }

    /// Batch width of the batched matvec artifacts (None if absent).
    pub fn batch_width(&self) -> Option<usize> {
        self.matvecb_tiles.first().map(|(_, b, _)| *b)
    }

    /// Compute `rows · Xs` for a request batch `Xs` (column-major batch:
    /// `xs[b]` is request `b`, each of length `cols`). Uses the batched
    /// (MXU-shaped) artifacts; the batch is zero-padded up to the artifact
    /// batch width and extra columns are discarded.
    pub fn matvec_batched(&self, rows: &Matrix, xs: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
        let bw = self.batch_width().ok_or_else(|| {
            Error::Runtime("no batched matvec artifacts loaded".into())
        })?;
        if xs.is_empty() || xs.len() > bw {
            return Err(Error::Runtime(format!(
                "batch size {} outside 1..={bw}",
                xs.len()
            )));
        }
        if rows.cols() != self.cols {
            return Err(Error::Runtime(format!(
                "chunk has {} cols, artifacts compiled for {}",
                rows.cols(),
                self.cols
            )));
        }
        for (b, x) in xs.iter().enumerate() {
            if x.len() != self.cols {
                return Err(Error::Runtime(format!(
                    "request {b} has {} entries, expected {}",
                    x.len(),
                    self.cols
                )));
            }
        }
        // Pack Xs as (d, bw) with zero columns beyond the live batch.
        let mut xbuf = vec![0f32; self.cols * bw];
        for (b, x) in xs.iter().enumerate() {
            for (j, &v) in x.iter().enumerate() {
                xbuf[j * bw + b] = v as f32;
            }
        }
        let mut out: Vec<Vec<f64>> = vec![Vec::with_capacity(rows.rows()); xs.len()];
        let max_tile = self.matvecb_tiles.last().map(|(r, _, _)| *r).unwrap();
        let mut start = 0usize;
        while start < rows.rows() {
            let l = (rows.rows() - start).min(max_tile);
            let (tile_rows, exe) = self
                .matvecb_tiles
                .iter()
                .find(|(r, _, _)| *r >= l)
                .map(|(r, _, e)| (*r, e))
                .unwrap_or_else(|| {
                    let (r, _, e) = self.matvecb_tiles.last().unwrap();
                    (*r, e)
                });
            let mut abuf = vec![0f32; tile_rows * self.cols];
            for i in 0..l {
                for (j, &v) in rows.row(start + i).iter().enumerate() {
                    abuf[i * self.cols + j] = v as f32;
                }
            }
            let a_lit = xla::Literal::vec1(&abuf)
                .reshape(&[tile_rows as i64, self.cols as i64])?;
            let x_lit =
                xla::Literal::vec1(&xbuf).reshape(&[self.cols as i64, bw as i64])?;
            let result = exe.execute::<xla::Literal>(&[a_lit, x_lit])?[0][0]
                .to_literal_sync()?;
            let y = result.to_tuple1()?.to_vec::<f32>()?; // (tile_rows, bw) row-major
            for i in 0..l {
                for (b, o) in out.iter_mut().enumerate() {
                    o.push(y[i * bw + b] as f64);
                }
            }
            start += l;
        }
        Ok(out)
    }

    /// Shape of the encode executable, if present: `(n, k, d)`.
    pub fn encode_shape(&self) -> Option<(usize, usize, usize)> {
        self.encode.as_ref().map(|(n, k, d, _)| (*n, *k, *d))
    }

    /// Run the AOT encode `G · A`. Shapes must match the artifact exactly
    /// (encode is a setup-time operation; no bucketing).
    pub fn encode(&self, g: &Matrix, a: &Matrix) -> Result<Matrix> {
        let (n, k, d, exe) = self
            .encode
            .as_ref()
            .ok_or_else(|| Error::Runtime("no encode artifact loaded".into()))?;
        if g.rows() != *n || g.cols() != *k || a.rows() != *k || a.cols() != *d {
            return Err(Error::Runtime(format!(
                "encode artifact is ({n},{k},{d}); got G {}x{}, A {}x{}",
                g.rows(),
                g.cols(),
                a.rows(),
                a.cols()
            )));
        }
        let g32: Vec<f32> = g.data().iter().map(|&v| v as f32).collect();
        let a32: Vec<f32> = a.data().iter().map(|&v| v as f32).collect();
        let g_lit = xla::Literal::vec1(&g32).reshape(&[*n as i64, *k as i64])?;
        let a_lit = xla::Literal::vec1(&a32).reshape(&[*k as i64, *d as i64])?;
        let result = exe.execute::<xla::Literal>(&[g_lit, a_lit])?[0][0]
            .to_literal_sync()?;
        let y = result.to_tuple1()?.to_vec::<f32>()?;
        Ok(Matrix::from_vec(*n, *d, y.into_iter().map(|v| v as f64).collect()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parsing() {
        let text = "\n# comment\nmatvec 64 256 matvec_r64.hlo.txt\n\
                    matvec 128 256 matvec_r128.hlo.txt\n\
                    encode 1024 256 256 encode.hlo.txt\n";
        let entries = parse_manifest(text).unwrap();
        assert_eq!(entries.len(), 3);
        assert_eq!(
            entries[0].kind,
            ArtifactKind::Matvec { rows: 64, cols: 256 }
        );
        assert_eq!(
            entries[2].kind,
            ArtifactKind::Encode { n: 1024, k: 256, d: 256 }
        );
    }

    #[test]
    fn manifest_errors() {
        assert!(parse_manifest("").is_err());
        assert!(parse_manifest("bogus 1 2 f").is_err());
        assert!(parse_manifest("matvec x 256 f").is_err());
        assert!(parse_manifest("matvec 64 f").is_err());
    }
}
