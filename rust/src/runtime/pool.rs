//! Persistent compute pool: the crate-wide replacement for per-call
//! `std::thread::scope` spawns.
//!
//! # Why a pool
//!
//! Every hot path that used to parallelize — the blocked matmul behind
//! encode, the Monte-Carlo sweeps behind the figures, the multi-RHS decode
//! — paid a fresh OS-thread spawn per *call*. That cost (tens of µs per
//! thread) is invisible for one big encode but dominates exactly the small
//! per-call work items the paper's optimal allocation produces for slow
//! groups, and a serving loop pays it once per batch, forever. A
//! [`WorkPool`] spawns its workers **once**; after that a parallel region
//! is one channel push per helper plus an atomic fetch-add per task.
//!
//! # Determinism
//!
//! The pool never decides *what* the work units are — callers fix the task
//! partition (row ranges, RNG stream indices, column chunks) up front, and
//! the pool only executes it. Results are reduced in **task-index order**
//! ([`WorkPool::run_collect`] slot `i` belongs to task `i`;
//! [`WorkPool::run_chunks_mut`] chunk `i` is the `i`-th slice), so outputs
//! are byte-identical no matter how many workers the pool has, which
//! worker ran which task, or in what order tasks finished. This is the
//! invariant the bit-identity suite (`rust/tests/pool_identity.rs`) pins
//! across pool sizes {1, 2, 7, 16}.
//!
//! # Scheduling ("work-stealing-lite")
//!
//! Tasks of one parallel region are claimed from a shared atomic cursor —
//! a degenerate single-queue form of work stealing: an idle worker always
//! takes the next undone task, so uneven task costs self-balance without
//! any per-worker deques. The **caller participates**: it claims tasks in
//! the same loop as the workers, which (a) keeps a 1-worker pool exactly
//! as fast as the single-threaded code and (b) makes nested use safe — a
//! pool task that opens its own parallel region drains that region itself
//! if every worker is busy, so the pool cannot deadlock on itself.

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// A shareable handle to a [`WorkPool`] — what gets threaded through
/// [`crate::coordinator::JobConfig`] and
/// [`crate::coordinator::SessionBuilder::pool`] so one pool serves every
/// batch of a session (or several sessions at once).
pub type PoolHandle = Arc<WorkPool>;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Queue state shared between the pool handle and its worker threads.
struct PoolShared {
    queue: Mutex<PoolQueue>,
    available: Condvar,
    /// Tasks executed across all parallel regions (introspection/tests).
    tasks_run: AtomicU64,
    /// Parallel regions executed (introspection/tests).
    scopes_run: AtomicU64,
}

struct PoolQueue {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

/// A fixed-size persistent worker pool executing scoped task batches.
///
/// Construction spawns `threads - 1` background workers (the calling
/// thread is always the `threads`-th execution context of a parallel
/// region); `Drop` shuts them down and joins. Most code should share the
/// process-wide [`WorkPool::global`] pool rather than constructing one.
pub struct WorkPool {
    shared: Arc<PoolShared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    threads: usize,
}

impl std::fmt::Debug for WorkPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkPool")
            .field("threads", &self.threads)
            .field("spawned_workers", &self.workers.len())
            .field("tasks_run", &self.tasks_run())
            .finish()
    }
}

/// State of one `scope_run` parallel region, shared with helper jobs.
///
/// `data`/`call` form a lifetime-erased pointer to the caller's closure
/// (a monomorphized trampoline instead of a `dyn` fat pointer, so no
/// lifetime gymnastics). Soundness rests on two facts: (1) `scope_run`
/// does not return until `done == tasks` (the completion latch), and a
/// task index is only ever claimed before that point, so every call
/// through `data` happens while the closure is alive; (2) a helper job
/// that is dequeued *after* the region completed claims an index `>=
/// tasks` and exits without touching `data` (holding the stale raw
/// pointer is fine — it is never dereferenced).
struct ScopeState {
    data: *const (),
    // SAFETY: `call` is only invoked through `run_scope_tasks` under the
    // latch discipline above, with `data` as its first argument.
    call: unsafe fn(*const (), usize),
    tasks: usize,
    next: AtomicUsize,
    done: AtomicUsize,
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
    finished: Mutex<bool>,
    cv: Condvar,
}

// SAFETY: the raw closure pointer is only dereferenced under the latch
// discipline documented on `ScopeState`; everything else is Sync.
unsafe impl Send for ScopeState {}
unsafe impl Sync for ScopeState {}

/// Monomorphized trampoline: reconstitute the erased closure and call it.
///
/// # Safety
/// `p` must point to a live `F` (guaranteed by the `ScopeState` latch).
unsafe fn call_closure<F: Fn(usize) + Sync>(p: *const (), i: usize) {
    // SAFETY: the caller's contract above — `p` points to a live `F`
    // for the duration of this call.
    unsafe { (*(p as *const F))(i) }
}

/// Claim-and-run loop shared by the calling thread and helper jobs.
fn run_scope_tasks(st: &ScopeState) {
    loop {
        let i = st.next.fetch_add(1, Ordering::Relaxed);
        if i >= st.tasks {
            return;
        }
        // SAFETY: see `ScopeState` — a claimed index < tasks keeps the
        // region (and the closure) alive until `done` is counted below.
        let result =
            catch_unwind(AssertUnwindSafe(|| unsafe { (st.call)(st.data, i) }));
        if let Err(payload) = result {
            let mut slot = st.panic.lock().expect("panic slot poisoned");
            slot.get_or_insert(payload);
        }
        if st.done.fetch_add(1, Ordering::AcqRel) + 1 == st.tasks {
            let mut fin = st.finished.lock().expect("latch poisoned");
            *fin = true;
            st.cv.notify_all();
        }
    }
}

/// Raw-pointer wrapper so disjoint-index writers can be captured by a
/// `Sync` closure. Callers guarantee disjointness.
struct SendPtr<T>(*mut T);
// SAFETY: used only for writes to caller-guaranteed-disjoint indices
// while the owning buffer is pinned by a blocked `scope_run` caller.
unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

impl WorkPool {
    /// Build a pool with `threads` execution contexts (`0` = available
    /// parallelism). Spawns `threads - 1` background workers; the thread
    /// that opens a parallel region is always the remaining context, so
    /// `WorkPool::new(1)` spawns nothing and runs everything inline.
    // This is the one sanctioned thread-creation site (lint rule D3 and
    // clippy disallowed-methods both point here).
    #[allow(clippy::disallowed_methods)]
    pub fn new(threads: usize) -> WorkPool {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
        } else {
            threads
        };
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(PoolQueue { jobs: VecDeque::new(), shutdown: false }),
            available: Condvar::new(),
            tasks_run: AtomicU64::new(0),
            scopes_run: AtomicU64::new(0),
        });
        let workers = (0..threads.saturating_sub(1))
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("hetcoded-pool-{i}"))
                    .spawn(move || worker_loop(&sh))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkPool { shared, workers, threads }
    }

    /// The process-wide shared pool, sized to available parallelism and
    /// built on first use. Sessions without an explicit
    /// [`PoolHandle`] run here; it is never torn down.
    pub fn global() -> &'static PoolHandle {
        static GLOBAL: OnceLock<PoolHandle> = OnceLock::new();
        GLOBAL.get_or_init(|| Arc::new(WorkPool::new(0)))
    }

    /// The global pool as a plain reference — shorthand for kernel call
    /// sites that take `&WorkPool` rather than a handle.
    pub fn global_ref() -> &'static WorkPool {
        WorkPool::global().as_ref()
    }

    /// Execution contexts (background workers + the calling thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Background worker threads actually spawned (`threads() - 1`). The
    /// "no thread leak" introspection hook: this is fixed at construction
    /// and never grows, no matter how many sessions share the pool.
    pub fn spawned_workers(&self) -> usize {
        self.workers.len()
    }

    /// Tasks executed since construction (all parallel regions).
    pub fn tasks_run(&self) -> u64 {
        self.shared.tasks_run.load(Ordering::Relaxed)
    }

    /// Parallel regions executed since construction.
    pub fn scopes_run(&self) -> u64 {
        self.shared.scopes_run.load(Ordering::Relaxed)
    }

    /// Run `f(0..tasks)` across the pool, blocking until every task has
    /// completed. The calling thread participates; task panics are
    /// propagated to the caller after the region drains. `f` fixes the
    /// work partition — results must not depend on which worker runs which
    /// task (the pool guarantees nothing about assignment, only that each
    /// index runs exactly once).
    pub fn scope_run<F>(&self, tasks: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if tasks == 0 {
            return;
        }
        self.shared.scopes_run.fetch_add(1, Ordering::Relaxed);
        self.shared.tasks_run.fetch_add(tasks as u64, Ordering::Relaxed);
        let helpers = self.workers.len().min(tasks.saturating_sub(1));
        if helpers == 0 {
            // Inline fast path: nothing to coordinate with.
            for i in 0..tasks {
                f(i);
            }
            return;
        }
        // Lifetime-erased; `scope_run` blocks on the latch below until
        // all claimed tasks finish, so `f` outlives every call.
        let state = Arc::new(ScopeState {
            data: &f as *const F as *const (),
            call: call_closure::<F>,
            tasks,
            next: AtomicUsize::new(0),
            done: AtomicUsize::new(0),
            panic: Mutex::new(None),
            finished: Mutex::new(false),
            cv: Condvar::new(),
        });
        {
            let mut q = self.shared.queue.lock().expect("pool queue poisoned");
            for _ in 0..helpers {
                let st = Arc::clone(&state);
                q.jobs.push_back(Box::new(move || run_scope_tasks(&st)));
            }
        }
        // One wakeup per helper job pushed.
        for _ in 0..helpers {
            self.shared.available.notify_one();
        }
        run_scope_tasks(&state);
        let mut fin = state.finished.lock().expect("latch poisoned");
        while !*fin {
            fin = state.cv.wait(fin).expect("latch poisoned");
        }
        drop(fin);
        if let Some(payload) = state.panic.lock().expect("panic slot").take() {
            resume_unwind(payload);
        }
    }

    /// Run `f(0..tasks)` and collect the return values **in task-index
    /// order** — the deterministic reduction primitive (task `i`'s result
    /// lands in slot `i` regardless of scheduling).
    pub fn run_collect<T, F>(&self, tasks: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let mut out: Vec<Option<T>> = std::iter::repeat_with(|| None)
            .take(tasks)
            .collect();
        let slots = SendPtr(out.as_mut_ptr());
        self.scope_run(tasks, |i| {
            let v = f(i);
            // SAFETY: each task writes exactly its own index (disjoint),
            // and `scope_run` keeps `out` pinned until every write lands.
            unsafe { *slots.0.add(i) = Some(v) };
        });
        out.into_iter()
            .map(|slot| slot.expect("pool task completed without a result"))
            .collect()
    }

    /// Split `data` into `chunk_len`-sized pieces (last one shorter) and
    /// run `f(chunk_index, chunk)` for each across the pool — the parallel
    /// equivalent of `data.chunks_mut(chunk_len).enumerate()`, with chunk
    /// `i` always the `i`-th slice so writers stay deterministic.
    pub fn run_chunks_mut<T, F>(&self, data: &mut [T], chunk_len: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        let n = data.len();
        if n == 0 {
            return;
        }
        assert!(chunk_len > 0, "chunk_len must be positive");
        let tasks = n.div_ceil(chunk_len);
        let base = SendPtr(data.as_mut_ptr());
        self.scope_run(tasks, |i| {
            let start = i * chunk_len;
            let len = chunk_len.min(n - start);
            // SAFETY: chunks are disjoint by construction and `data` is
            // pinned by the blocked `scope_run` caller.
            let chunk =
                unsafe { std::slice::from_raw_parts_mut(base.0.add(start), len) };
            f(i, chunk);
        });
    }
}

fn worker_loop(shared: &PoolShared) {
    loop {
        let job = {
            let mut q = shared.queue.lock().expect("pool queue poisoned");
            loop {
                if let Some(job) = q.jobs.pop_front() {
                    break job;
                }
                if q.shutdown {
                    return;
                }
                q = shared.available.wait(q).expect("pool queue poisoned");
            }
        };
        job();
    }
}

impl Drop for WorkPool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().expect("pool queue poisoned");
            q.shutdown = true;
        }
        self.shared.available.notify_all();
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn runs_every_task_exactly_once() {
        for threads in [1usize, 2, 7, 16] {
            let pool = WorkPool::new(threads);
            let hits: Vec<AtomicUsize> =
                (0..100).map(|_| AtomicUsize::new(0)).collect();
            pool.scope_run(100, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "threads={threads}"
            );
            assert_eq!(pool.tasks_run(), 100);
            assert_eq!(pool.scopes_run(), 1);
        }
    }

    #[test]
    fn collect_is_index_ordered_for_any_pool_size() {
        let expect: Vec<usize> = (0..57).map(|i| i * i).collect();
        for threads in [1usize, 2, 7, 16] {
            let pool = WorkPool::new(threads);
            let got = pool.run_collect(57, |i| i * i);
            assert_eq!(got, expect, "threads={threads}");
        }
    }

    #[test]
    fn chunks_mut_partitions_disjointly() {
        let mut data = vec![0u32; 103];
        let pool = WorkPool::new(5);
        pool.run_chunks_mut(&mut data, 10, |ci, chunk| {
            for v in chunk.iter_mut() {
                *v += 1 + ci as u32;
            }
        });
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, 1 + (i / 10) as u32, "index {i}");
        }
        // Empty data and zero tasks are no-ops.
        pool.run_chunks_mut(&mut [] as &mut [u32], 4, |_, _| unreachable!());
        pool.scope_run(0, |_| unreachable!());
    }

    #[test]
    fn worker_count_is_fixed_and_reused() {
        let pool = WorkPool::new(4);
        assert_eq!(pool.threads(), 4);
        assert_eq!(pool.spawned_workers(), 3);
        for _ in 0..50 {
            pool.scope_run(8, |_| {});
        }
        // 50 regions later: same worker set, no spawn per call.
        assert_eq!(pool.spawned_workers(), 3);
        assert_eq!(pool.scopes_run(), 50);
        assert_eq!(pool.tasks_run(), 400);
    }

    #[test]
    fn single_context_pool_runs_inline() {
        let pool = WorkPool::new(1);
        assert_eq!(pool.spawned_workers(), 0);
        let got = pool.run_collect(9, |i| i + 1);
        assert_eq!(got, (1..=9).collect::<Vec<_>>());
    }

    #[test]
    fn nested_regions_complete() {
        // A pool task opening its own region must drain it even when every
        // other worker is busy — the caller-participates rule.
        let pool = WorkPool::new(2);
        let sums = pool.run_collect(4, |i| {
            let inner = pool.run_collect(3, |j| (i + 1) * (j + 1));
            inner.iter().sum::<usize>()
        });
        assert_eq!(sums, vec![6, 12, 18, 24]);
    }

    #[test]
    fn task_panic_propagates_after_drain() {
        let pool = WorkPool::new(3);
        let done = AtomicUsize::new(0);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.scope_run(10, |i| {
                if i == 4 {
                    panic!("task 4 exploded");
                }
                done.fetch_add(1, Ordering::Relaxed);
            });
        }));
        assert!(result.is_err(), "panic must propagate to the caller");
        // The pool survives the panic and keeps serving.
        assert_eq!(pool.run_collect(3, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn global_pool_is_a_singleton() {
        let a = Arc::as_ptr(WorkPool::global());
        let b = Arc::as_ptr(WorkPool::global());
        assert_eq!(a, b);
        assert!(WorkPool::global().threads() >= 1);
    }
}
