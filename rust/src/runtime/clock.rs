//! The one sanctioned wall-clock read.
//!
//! Live serving code measures real elapsed time (SLO windows, bench
//! timing, liveness deadlines) through [`wall_now`] so there is exactly
//! one `Instant::now` call site in the crate. The point is not
//! abstraction — it is enforcement: clippy's `disallowed-methods` bans
//! `Instant::now`/`SystemTime::now` everywhere else, and lint rule D4
//! additionally bans `wall_now` itself inside `sim/` and `model/`,
//! where only virtual time is allowed. A wall read in live coordinator
//! code is legitimate; one in the simulator silently destroys run
//! reproducibility, which is why the two are separated at the lint
//! layer rather than by convention.

use std::time::Instant;

/// Current wall-clock instant. Live-path code only; sim/model code uses
/// the virtual clock carried by the event loop.
#[allow(clippy::disallowed_methods)]
pub fn wall_now() -> Instant {
    Instant::now()
}
