//! Cluster model: heterogeneous worker groups and runtime distributions.
//!
//! Mirrors §II of the paper: `N` workers partitioned into `G` groups; group
//! `j` has `N_j` workers, straggling parameter `μ_(j)` and shift parameter
//! `α_(j)`; workers in a group receive the same number of coded rows
//! `l_(j)`.

#![forbid(unsafe_code)]

pub mod analytic;
pub mod clustering;
pub mod estimator;
pub mod order_stats;
pub mod runtime_dist;

pub use analytic::clt_expected_latency;
pub use clustering::cluster_workers;
pub use estimator::{
    CensoredSample, EstimatorConfig, GroupEstimate, SpeedEstimator,
};
pub use order_stats::{group_latency, group_latency_exact, xi, xi_star};
pub use runtime_dist::{LatencyModel, RuntimeDist};

use crate::{Error, Result};

/// One homogeneous group of workers.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Group {
    /// Number of workers `N_j`.
    pub n: usize,
    /// Straggling parameter `μ_(j)` (rate of the exponential tail).
    pub mu: f64,
    /// Shift parameter `α_(j)` (deterministic minimum time).
    pub alpha: f64,
}

impl Group {
    /// Construct a group, validating parameters.
    pub fn new(n: usize, mu: f64, alpha: f64) -> Result<Self> {
        if n == 0 {
            return Err(Error::InvalidSpec("group has zero workers".into()));
        }
        if !(mu > 0.0) || !mu.is_finite() {
            return Err(Error::InvalidSpec(format!("mu must be positive, got {mu}")));
        }
        if !(alpha > 0.0) || !alpha.is_finite() {
            return Err(Error::InvalidSpec(format!(
                "alpha must be positive, got {alpha}"
            )));
        }
        Ok(Group { n, mu, alpha })
    }
}

/// A heterogeneous cluster: `G` groups plus the data-matrix row count `k`.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterSpec {
    /// Worker groups (`G = groups.len()`).
    pub groups: Vec<Group>,
    /// Rows of the uncoded data matrix `A` (the MDS dimension `k`).
    pub k: usize,
}

impl ClusterSpec {
    /// Construct and validate a cluster spec.
    pub fn new(groups: Vec<Group>, k: usize) -> Result<Self> {
        if groups.is_empty() {
            return Err(Error::InvalidSpec("cluster has no groups".into()));
        }
        if k == 0 {
            return Err(Error::InvalidSpec("k must be positive".into()));
        }
        Ok(ClusterSpec { groups, k })
    }

    /// Total number of workers `N = Σ N_j`.
    pub fn total_workers(&self) -> usize {
        self.groups.iter().map(|g| g.n).sum()
    }

    /// Number of groups `G`.
    pub fn num_groups(&self) -> usize {
        self.groups.len()
    }

    /// Scale every `μ_(j)` by `q` (the paper's scale factor in Figs. 2, 5–7).
    pub fn scaled_mu(&self, q: f64) -> ClusterSpec {
        ClusterSpec {
            groups: self
                .groups
                .iter()
                .map(|g| Group {
                    n: g.n,
                    mu: g.mu * q,
                    alpha: g.alpha,
                })
                .collect(),
            k: self.k,
        }
    }

    /// Scale the total worker count: each `N_j` is multiplied by `factor`
    /// (used for the Fig. 4 sweep where `N_j ∝ N`).
    pub fn scaled_workers(&self, factor: f64) -> ClusterSpec {
        ClusterSpec {
            groups: self
                .groups
                .iter()
                .map(|g| Group {
                    n: ((g.n as f64 * factor).round() as usize).max(1),
                    mu: g.mu,
                    alpha: g.alpha,
                })
                .collect(),
            k: self.k,
        }
    }

    /// The five-group cluster used throughout §IV (Figs. 4–7):
    /// `N = (3,4,5,6,7)·N/25`, `μ = (16,12,8,4,1)`, `α = 1`.
    pub fn paper_five_group(total_n: usize, k: usize) -> ClusterSpec {
        let fracs = [3.0, 4.0, 5.0, 6.0, 7.0];
        let mus = [16.0, 12.0, 8.0, 4.0, 1.0];
        let groups = fracs
            .iter()
            .zip(mus.iter())
            .map(|(&f, &mu)| Group {
                n: ((f / 25.0) * total_n as f64).round() as usize,
                mu,
                alpha: 1.0,
            })
            .collect();
        ClusterSpec { groups, k }
    }

    /// The two-group cluster of Fig. 8: `N=(300,600)`, `μ=(4,0.5)`, `α=1`.
    pub fn paper_two_group(k: usize) -> ClusterSpec {
        ClusterSpec {
            groups: vec![
                Group { n: 300, mu: 4.0, alpha: 1.0 },
                Group { n: 600, mu: 0.5, alpha: 1.0 },
            ],
            k,
        }
    }

    /// The three-group model-B cluster of Fig. 9:
    /// `N=(3,3,4)·N/10`, `μ=(1,4,8)`, `α=(1,4,12)`.
    pub fn paper_three_group_b(total_n: usize, k: usize) -> ClusterSpec {
        let fracs = [3.0, 3.0, 4.0];
        let mus = [1.0, 4.0, 8.0];
        let alphas = [1.0, 4.0, 12.0];
        let groups = (0..3)
            .map(|j| Group {
                n: ((fracs[j] / 10.0) * total_n as f64).round() as usize,
                mu: mus[j],
                alpha: alphas[j],
            })
            .collect();
        ClusterSpec { groups, k }
    }

    /// The three-group cluster of Fig. 2: `N=(1000,2000,3000)`,
    /// `μ=(2,1,0.5)`, `α=1`.
    pub fn paper_fig2(k: usize) -> ClusterSpec {
        ClusterSpec {
            groups: vec![
                Group { n: 1000, mu: 2.0, alpha: 1.0 },
                Group { n: 2000, mu: 1.0, alpha: 1.0 },
                Group { n: 3000, mu: 0.5, alpha: 1.0 },
            ],
            k,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_validation() {
        assert!(Group::new(0, 1.0, 1.0).is_err());
        assert!(Group::new(1, -1.0, 1.0).is_err());
        assert!(Group::new(1, 1.0, 0.0).is_err());
        assert!(Group::new(1, f64::NAN, 1.0).is_err());
        assert!(Group::new(10, 2.0, 1.0).is_ok());
    }

    #[test]
    fn cluster_validation_and_totals() {
        assert!(ClusterSpec::new(vec![], 10).is_err());
        let c = ClusterSpec::paper_five_group(2500, 10_000);
        assert_eq!(c.num_groups(), 5);
        assert_eq!(c.total_workers(), 2500);
        assert_eq!(c.groups[0].n, 300);
        assert_eq!(c.groups[4].n, 700);
    }

    #[test]
    fn mu_scaling() {
        let c = ClusterSpec::paper_five_group(2500, 100);
        let s = c.scaled_mu(0.5);
        assert_eq!(s.groups[0].mu, 8.0);
        assert_eq!(s.groups[4].mu, 0.5);
        assert_eq!(s.groups[0].n, c.groups[0].n);
    }

    #[test]
    fn worker_scaling_preserves_proportions() {
        let c = ClusterSpec::paper_five_group(2500, 100);
        let s = c.scaled_workers(2.0);
        assert_eq!(s.total_workers(), 5000);
        assert_eq!(s.groups[0].n, 600);
    }

    #[test]
    fn paper_fig9_cluster() {
        let c = ClusterSpec::paper_three_group_b(1000, 100_000);
        assert_eq!(c.groups[0].n, 300);
        assert_eq!(c.groups[2].n, 400);
        assert_eq!(c.groups[2].alpha, 12.0);
    }
}
