//! Analytic order statistics for shifted exponentials (paper eq. (6), (9)).
//!
//! For group `j` with `N_j` workers, load `l_(j)` and parameters
//! `(μ_(j), α_(j))`, the expected time until `r_j` of its workers finish is
//!
//! ```text
//! λ^{l}_{r:N} = (l/k) · ( α + (H_N - H_{N-r}) / μ )            [model A]
//!             =  l    · ( α + (H_N - H_{N-r}) / μ )            [model B]
//! ```
//!
//! with the paper's approximation `H_N - H_{N-r} ≈ log(N/(N-r))` available
//! as the default (`group_latency`) and the exact harmonic form as
//! [`group_latency_exact`].

use crate::math::{harmonic, wm1_neg_exp};
use crate::model::{LatencyModel, RuntimeDist};

/// The paper's `ξ(r, N, μ)` with shift `α` (eq. (9)):
/// `ξ = α + (1/μ) log(N / (N - r))`. `r` is real-valued, `0 <= r < N`.
pub fn xi(r: f64, n: f64, mu: f64, alpha: f64) -> f64 {
    assert!(r >= 0.0 && r < n, "need 0 <= r < N (r={r}, N={n})");
    alpha + (n / (n - r)).ln() / mu
}

/// `ξ` evaluated at the optimal `r*` (eq. (17)):
/// `ξ* = α + (1/μ) log(-W_{-1}(-e^{-(αμ+1)}))`.
///
/// Computed through the log-space Lambert evaluation so it is stable for
/// large `αμ`.
pub fn xi_star(mu: f64, alpha: f64) -> f64 {
    let w = wm1_neg_exp(alpha * mu + 1.0);
    alpha + (-w).ln() / mu
}

/// Expected `r`-th order statistic of the group runtime (eq. (6)), using the
/// paper's `log` approximation. `r` real-valued in `[0, N)`.
pub fn group_latency(
    model: LatencyModel,
    load: f64,
    k: f64,
    n: f64,
    r: f64,
    mu: f64,
    alpha: f64,
) -> f64 {
    let x = xi(r, n, mu, alpha);
    match model {
        LatencyModel::A => load / k * x,
        LatencyModel::B => load * x,
    }
}

/// Exact-harmonic version of [`group_latency`] for integer `r`.
pub fn group_latency_exact(
    model: LatencyModel,
    load: f64,
    k: f64,
    n: u64,
    r: u64,
    mu: f64,
    alpha: f64,
) -> f64 {
    assert!(r >= 1 && r <= n);
    let x = alpha + (harmonic(n) - harmonic(n - r)) / mu;
    match model {
        LatencyModel::A => load / k * x,
        LatencyModel::B => load * x,
    }
}

/// Model-time hedge deadline for one worker of a group: the `quantile`-th
/// quantile of the worker's shifted-exponential runtime law, floored at
/// `floor`.
///
/// The quantile falls out of the group completion law already in this
/// module: a single worker's runtime CDF is `F(t) = 1 - e^{-μ'(t-α')}`
/// (with `(μ', α')` the load-scaled parameters), so its `q`-quantile is
/// `α' - ln(1-q)/μ'` — and since `ln(N/(N-qN)) = -ln(1-q)`, that is
/// exactly [`group_latency`] evaluated at `r = q·N` for *any* `N`. The
/// deadline is therefore literally "a configurable quantile of the
/// analytic per-group completion law", computed here in pure model time
/// (no clock reads — rule D4 bans wall time in `model/`); callers scale
/// to wall seconds via `JobConfig::time_scale`.
///
/// `quantile` must lie in `(0, 1)`; `floor` (also model time) guards
/// against degenerate deadlines when a worker's load rounds to a few
/// rows.
pub fn hedge_deadline(
    model: LatencyModel,
    load: f64,
    k: f64,
    quantile: f64,
    mu: f64,
    alpha: f64,
    floor: f64,
) -> f64 {
    assert!(
        quantile > 0.0 && quantile < 1.0,
        "hedge quantile must be in (0, 1), got {quantile}"
    );
    // Any N works — the law only depends on r/N = quantile; use N = 1.
    group_latency(model, load, k, 1.0, quantile, mu, alpha).max(floor)
}

/// CLT variance of the central order statistic (Proposition 1):
/// `σ² = q(1-q) / (N f(η)²)` where `η = F⁻¹(q)`.
///
/// Used to verify the concentration argument behind Theorem 3.
pub fn central_order_stat_variance(dist: &RuntimeDist, n: f64, q: f64) -> f64 {
    assert!(q > 0.0 && q < 1.0);
    let eta = dist.quantile(q);
    // pdf of the shifted exponential at eta.
    let f = (1.0 - dist.cdf(eta)) / dist.scale();
    q * (1.0 - q) / (n * f * f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::Rng;

    #[test]
    fn xi_matches_formula() {
        let v = xi(50.0, 100.0, 2.0, 1.0);
        assert!((v - (1.0 + 0.5 * (2.0f64).ln())).abs() < 1e-14);
        // r = 0 gives just alpha.
        assert_eq!(xi(0.0, 10.0, 1.0, 3.0), 3.0);
    }

    #[test]
    fn xi_star_identity_eq17() {
        // The identity log(-W_{-1}(z)) + W_{-1}(z) = log(-z) gives
        // xi* = alpha + (1/mu)(alpha*mu + 1 + w) where w = W_{-1}(-e^{-(αμ+1)}).
        for (mu, alpha) in [(1.0, 1.0), (4.0, 2.0), (0.5, 1.0), (16.0, 1.0)] {
            let w = wm1_neg_exp(alpha * mu + 1.0);
            let lhs = xi_star(mu, alpha);
            let rhs = alpha + (alpha * mu + 1.0 + w) * (-1.0) / mu * (-1.0);
            // log(-w) = -(t + w) with t = alpha*mu+1, so
            // xi* = alpha - (t + w)/mu... careful: ln(-w) = -t - w.
            let direct = alpha + (-(alpha * mu + 1.0) - w) / mu;
            assert!((lhs - direct).abs() < 1e-10, "{lhs} vs {direct}");
            let _ = rhs;
        }
    }

    #[test]
    fn group_latency_log_vs_exact_converge() {
        // For large N the log approximation matches the harmonic form.
        let (n, r) = (100_000u64, 50_000u64);
        let a = group_latency(LatencyModel::A, 10.0, 1000.0, n as f64, r as f64, 2.0, 1.0);
        let e = group_latency_exact(LatencyModel::A, 10.0, 1000.0, n, r, 2.0, 1.0);
        assert!((a - e).abs() / e < 1e-4, "{a} vs {e}");
    }

    #[test]
    fn group_latency_monte_carlo_agreement() {
        // Sample N runtimes, take the r-th order statistic, compare to eq (6).
        let (n, r) = (200usize, 120usize);
        let (load, k, mu, alpha) = (25.0, 1000.0, 3.0, 1.0);
        let dist = RuntimeDist::new(LatencyModel::A, load, k, mu, alpha);
        let mut rng = Rng::new(31);
        let trials = 20_000;
        let mut acc = 0.0;
        let mut ts = vec![0.0f64; n];
        for _ in 0..trials {
            for t in ts.iter_mut() {
                *t = dist.sample(&mut rng);
            }
            ts.sort_by(f64::total_cmp);
            acc += ts[r - 1];
        }
        let mc = acc / trials as f64;
        let analytic =
            group_latency_exact(LatencyModel::A, load, k, n as u64, r as u64, mu, alpha);
        assert!(
            (mc - analytic).abs() / analytic < 0.01,
            "MC {mc} vs analytic {analytic}"
        );
    }

    #[test]
    fn model_b_scales_with_absolute_load() {
        let a1 = group_latency(LatencyModel::B, 10.0, 1.0, 100.0, 50.0, 2.0, 1.0);
        let a2 = group_latency(LatencyModel::B, 20.0, 1.0, 100.0, 50.0, 2.0, 1.0);
        assert!((a2 / a1 - 2.0).abs() < 1e-12);
    }

    #[test]
    fn hedge_deadline_is_the_quantile_of_the_group_law() {
        // The q-quantile of one worker's shifted-exponential runtime is
        // the group completion law at r = q·N — check against the direct
        // inverse-CDF form alpha' - ln(1-q)/mu' for both models.
        let (load, k, mu, alpha) = (25.0, 1000.0, 3.0, 1.0);
        for q in [0.5, 0.9, 0.95, 0.99] {
            let d = hedge_deadline(LatencyModel::A, load, k, q, mu, alpha, 0.0);
            let scale = load / k;
            let direct = scale * (alpha - (1.0 - q).ln() / mu);
            assert!((d - direct).abs() < 1e-12, "q={q}: {d} vs {direct}");
            let db = hedge_deadline(LatencyModel::B, load, k, q, mu, alpha, 0.0);
            assert!((db - load * (alpha - (1.0 - q).ln() / mu)).abs() < 1e-9);
        }
        // Agrees with group_latency at r = q·N for a non-trivial N too.
        let q = 0.95;
        let via_group =
            group_latency(LatencyModel::A, load, k, 40.0, q * 40.0, mu, alpha);
        let via_hedge =
            hedge_deadline(LatencyModel::A, load, k, q, mu, alpha, 0.0);
        assert!((via_group - via_hedge).abs() < 1e-12);
        // The floor wins when the analytic quantile is tiny.
        assert_eq!(
            hedge_deadline(LatencyModel::A, 1.0, 1e9, 0.5, mu, alpha, 7.5),
            7.5
        );
        // Quantiles are sampled from the worker's own runtime law: the
        // empirical exceedance rate at the p95 deadline is ~5%.
        let dist = RuntimeDist::new(LatencyModel::A, load, k, mu, alpha);
        let dl = hedge_deadline(LatencyModel::A, load, k, 0.95, mu, alpha, 0.0);
        let mut rng = Rng::new(17);
        let blown = (0..20_000).filter(|_| dist.sample(&mut rng) > dl).count();
        let rate = blown as f64 / 20_000.0;
        assert!((rate - 0.05).abs() < 0.01, "exceedance {rate}");
    }

    #[test]
    fn clt_variance_shrinks_with_n() {
        let d = RuntimeDist::new(LatencyModel::A, 10.0, 100.0, 2.0, 1.0);
        let v1 = central_order_stat_variance(&d, 100.0, 0.5);
        let v2 = central_order_stat_variance(&d, 10_000.0, 0.5);
        assert!(v2 < v1 / 50.0);
    }
}
