//! CLT-based analytic latency estimator — expected latency predictions for
//! *any* load allocation without Monte Carlo.
//!
//! The collected coded-row count at time `t` is
//! `L(t) = Σ_j l_(j) · Bin(N_j, F_j(t))`, a load-weighted sum of independent
//! binomials. By the normal approximation (the same machinery as the paper's
//! Proposition 1),
//!
//! ```text
//! E[T_k] = ∫₀^∞ P(L(t) < k) dt ≈ ∫ Φ( (k − μ(t)) / σ(t) ) dt
//! μ(t) = Σ_j N_j l_j F_j(t),   σ²(t) = Σ_j N_j l_j² F_j(t)(1 − F_j(t)).
//! ```
//!
//! The integral is evaluated with adaptive Simpson over a bracketed window
//! (below the smallest shift the integrand is exactly 1; above the
//! `Φ→0` crossing it vanishes). This gives sub-second predictions the MC
//! engine can only match with ~10⁵ samples, and is validated against MC in
//! the tests and used by the integer-load optimizer.

use crate::math::special::normal_cdf;
use crate::model::{ClusterSpec, LatencyModel, RuntimeDist};
use crate::{Error, Result};

/// Analytic (CLT) estimate of the expected latency for per-group `loads`.
pub fn clt_expected_latency(
    spec: &ClusterSpec,
    loads: &[f64],
    model: LatencyModel,
) -> Result<f64> {
    if loads.len() != spec.num_groups() {
        return Err(Error::InvalidSpec(format!(
            "{} loads for {} groups",
            loads.len(),
            spec.num_groups()
        )));
    }
    if loads.iter().any(|&l| !(l > 0.0)) {
        return Err(Error::InvalidSpec("loads must be positive".into()));
    }
    let k = spec.k as f64;
    let dists: Vec<(f64, RuntimeDist)> = spec
        .groups
        .iter()
        .zip(loads)
        .map(|(g, &l)| {
            (
                g.n as f64,
                RuntimeDist::new(model, l, k, g.mu, g.alpha),
            )
        })
        .collect();
    let total: f64 = dists
        .iter()
        .zip(loads)
        .map(|((n, _), &l)| n * l)
        .sum();
    if total + 1e-9 < k {
        return Err(Error::InvalidSpec(format!(
            "total coded rows {total:.3} < k = {k}; undecodable"
        )));
    }

    // P(L(t) < k) under the normal approximation (continuity-corrected).
    let tail = |t: f64| -> f64 {
        let mut mu = 0.0;
        let mut var = 0.0;
        for ((n, dist), &l) in dists.iter().zip(loads) {
            let p = dist.cdf(t);
            mu += n * l * p;
            var += n * l * l * p * (1.0 - p);
        }
        if var <= 0.0 {
            return if mu < k { 1.0 } else { 0.0 };
        }
        normal_cdf((k - 0.5 - mu) / var.sqrt())
    };

    // Bracket the support of the integrand.
    let t_lo = dists
        .iter()
        .map(|(_, d)| d.shift())
        .fold(f64::INFINITY, f64::min);
    // Upper end: grow until the tail probability is negligible.
    let mut t_hi = dists
        .iter()
        .map(|(_, d)| d.shift() + 2.0 * d.scale())
        .fold(0.0f64, f64::max)
        .max(t_lo * 1.5 + 1e-12);
    let mut guard = 0;
    while tail(t_hi) > 1e-12 {
        t_hi *= 1.5;
        guard += 1;
        if guard > 200 {
            return Err(Error::Numerical("latency integrand does not decay".into()));
        }
    }
    // E[T] = t_lo + ∫_{t_lo}^{t_hi} P(L(t) < k) dt.
    Ok(t_lo + adaptive_simpson(&tail, t_lo, t_hi, 1e-10, 24))
}

/// Adaptive Simpson quadrature.
fn adaptive_simpson(f: &dyn Fn(f64) -> f64, a: f64, b: f64, eps: f64, depth: u32) -> f64 {
    let c = 0.5 * (a + b);
    let (fa, fb, fc) = (f(a), f(b), f(c));
    let whole = (b - a) / 6.0 * (fa + 4.0 * fc + fb);
    simpson_rec(f, a, b, fa, fb, fc, whole, eps, depth)
}

#[allow(clippy::too_many_arguments)]
fn simpson_rec(
    f: &dyn Fn(f64) -> f64,
    a: f64,
    b: f64,
    fa: f64,
    fb: f64,
    fc: f64,
    whole: f64,
    eps: f64,
    depth: u32,
) -> f64 {
    let c = 0.5 * (a + b);
    let d = 0.5 * (a + c);
    let e = 0.5 * (c + b);
    let (fd, fe) = (f(d), f(e));
    let left = (c - a) / 6.0 * (fa + 4.0 * fd + fc);
    let right = (b - c) / 6.0 * (fc + 4.0 * fe + fb);
    if depth == 0 || (left + right - whole).abs() <= 15.0 * eps {
        left + right + (left + right - whole) / 15.0
    } else {
        simpson_rec(f, a, c, fa, fc, fd, left, eps * 0.5, depth - 1)
            + simpson_rec(f, c, b, fc, fb, fe, right, eps * 0.5, depth - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocation::proposed_allocation;
    use crate::model::Group;
    use crate::sim::{latency_any_k, SimConfig};

    fn sim_cfg() -> SimConfig {
        SimConfig { samples: 20_000, seed: 5, threads: 0 }
    }

    #[test]
    fn matches_monte_carlo_proposed_allocation() {
        let spec = ClusterSpec::paper_five_group(2500, 10_000);
        let a = proposed_allocation(LatencyModel::A, &spec).unwrap();
        let analytic =
            clt_expected_latency(&spec, &a.loads, LatencyModel::A).unwrap();
        let mc = latency_any_k(&spec, &a.loads, LatencyModel::A, &sim_cfg()).unwrap();
        let rel = (analytic - mc.mean()).abs() / mc.mean();
        assert!(rel < 0.01, "analytic {analytic} vs MC {} ({rel})", mc.mean());
    }

    #[test]
    fn matches_monte_carlo_uniform_allocation() {
        let spec = ClusterSpec::paper_five_group(1000, 10_000);
        let loads = vec![20.0; 5]; // rate 1/2 uniform
        let analytic = clt_expected_latency(&spec, &loads, LatencyModel::A).unwrap();
        let mc = latency_any_k(&spec, &loads, LatencyModel::A, &sim_cfg()).unwrap();
        let rel = (analytic - mc.mean()).abs() / mc.mean();
        assert!(rel < 0.015, "analytic {analytic} vs MC {} ({rel})", mc.mean());
    }

    #[test]
    fn matches_monte_carlo_model_b() {
        let spec = ClusterSpec::paper_three_group_b(1000, 100_000);
        let a = proposed_allocation(LatencyModel::B, &spec).unwrap();
        let analytic =
            clt_expected_latency(&spec, &a.loads, LatencyModel::B).unwrap();
        let mc = latency_any_k(&spec, &a.loads, LatencyModel::B, &sim_cfg()).unwrap();
        let rel = (analytic - mc.mean()).abs() / mc.mean();
        assert!(rel < 0.01, "analytic {analytic} vs MC {} ({rel})", mc.mean());
    }

    #[test]
    fn respects_shift_lower_bound() {
        // E[T] can never be below the smallest per-worker shift needed to
        // cover k rows.
        let spec = ClusterSpec::new(
            vec![Group { n: 10, mu: 100.0, alpha: 1.0 }],
            100,
        )
        .unwrap();
        let loads = vec![20.0]; // each worker shift = 20/100 * 1 = 0.2
        let t = clt_expected_latency(&spec, &loads, LatencyModel::A).unwrap();
        assert!(t >= 0.2, "t = {t}");
    }

    #[test]
    fn rejects_undecodable_and_bad_inputs() {
        let spec = ClusterSpec::paper_two_group(10_000);
        assert!(clt_expected_latency(&spec, &[1.0, 1.0], LatencyModel::A).is_err());
        assert!(clt_expected_latency(&spec, &[10.0], LatencyModel::A).is_err());
        assert!(
            clt_expected_latency(&spec, &[-1.0, 50.0], LatencyModel::A).is_err()
        );
    }

    #[test]
    fn proposed_minimizes_among_perturbations() {
        // Perturbing the optimal loads (keeping n fixed by rebalancing)
        // should not reduce the analytic latency.
        let spec = ClusterSpec::paper_two_group(10_000);
        let a = proposed_allocation(LatencyModel::A, &spec).unwrap();
        let base = clt_expected_latency(&spec, &a.loads, LatencyModel::A).unwrap();
        let (n1, n2) = (spec.groups[0].n as f64, spec.groups[1].n as f64);
        for delta in [-0.2, -0.1, 0.1, 0.2] {
            // Shift delta·l1 rows/worker from group 1 to group 2 preserving n.
            let l1 = a.loads[0] * (1.0 + delta);
            let l2 = a.loads[1] - a.loads[0] * delta * n1 / n2;
            if l2 <= 0.0 {
                continue;
            }
            let t = clt_expected_latency(&spec, &[l1, l2], LatencyModel::A).unwrap();
            assert!(
                t >= base * 0.999,
                "perturbation {delta} improved latency: {t} < {base}"
            );
        }
    }
}
