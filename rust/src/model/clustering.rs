//! Grouping fully-heterogeneous workers (paper footnote 1).
//!
//! The paper's analysis assumes *group* heterogeneity but notes that a fully
//! heterogeneous fleet can be approximated by clustering workers on their
//! `(μ_i, α_i)` parameters. This module implements a small k-means (Lloyd)
//! over the 2-D parameter space with k-means++-style seeding from the
//! deterministic in-repo RNG.

use crate::math::Rng;
use crate::model::Group;
use crate::{Error, Result};

/// Per-worker straggling parameters before grouping.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WorkerParams {
    /// Straggling parameter `μ_i`.
    pub mu: f64,
    /// Shift parameter `α_i`.
    pub alpha: f64,
}

/// Cluster `workers` into at most `g` groups; returns groups with the
/// centroid `(μ, α)` and the member count, plus the assignment vector.
///
/// Workers are normalized per-dimension before distance computation so `μ`
/// and `α` ranges do not dominate each other.
pub fn cluster_workers(
    workers: &[WorkerParams],
    g: usize,
    seed: u64,
) -> Result<(Vec<Group>, Vec<usize>)> {
    if workers.is_empty() {
        return Err(Error::InvalidSpec("no workers to cluster".into()));
    }
    if g == 0 || g > workers.len() {
        return Err(Error::InvalidSpec(format!(
            "need 1 <= g <= {} workers, got g={g}",
            workers.len()
        )));
    }
    let mut rng = Rng::new(seed);

    // Normalize each dimension to [0, 1].
    let (mut mu_lo, mut mu_hi) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut al_lo, mut al_hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for w in workers {
        mu_lo = mu_lo.min(w.mu);
        mu_hi = mu_hi.max(w.mu);
        al_lo = al_lo.min(w.alpha);
        al_hi = al_hi.max(w.alpha);
    }
    let mu_span = (mu_hi - mu_lo).max(1e-12);
    let al_span = (al_hi - al_lo).max(1e-12);
    let pts: Vec<[f64; 2]> = workers
        .iter()
        .map(|w| [(w.mu - mu_lo) / mu_span, (w.alpha - al_lo) / al_span])
        .collect();

    // k-means++ seeding.
    let mut centers: Vec<[f64; 2]> = Vec::with_capacity(g);
    centers.push(pts[rng.gen_range(pts.len() as u64) as usize]);
    while centers.len() < g {
        let d2: Vec<f64> = pts
            .iter()
            .map(|p| {
                centers
                    .iter()
                    .map(|c| dist2(p, c))
                    .fold(f64::INFINITY, f64::min)
            })
            .collect();
        let total: f64 = d2.iter().sum();
        if total <= 0.0 {
            // All points coincide with existing centers; duplicate one.
            centers.push(centers[0]);
            continue;
        }
        let mut target = rng.next_f64() * total;
        let mut idx = 0;
        for (i, &d) in d2.iter().enumerate() {
            target -= d;
            if target <= 0.0 {
                idx = i;
                break;
            }
        }
        centers.push(pts[idx]);
    }

    // Lloyd iterations.
    let mut assign = vec![0usize; pts.len()];
    for _ in 0..100 {
        let mut changed = false;
        for (i, p) in pts.iter().enumerate() {
            // total_cmp: distances are finite (inputs are finite mus),
            // so this is the same order partial_cmp gave, minus the
            // NaN panic path; ties keep the lowest index either way.
            let best = (0..centers.len())
                .min_by(|&a, &b| {
                    dist2(p, &centers[a]).total_cmp(&dist2(p, &centers[b]))
                })
                .unwrap_or(0);
            if assign[i] != best {
                assign[i] = best;
                changed = true;
            }
        }
        let mut sums = vec![[0.0f64; 2]; centers.len()];
        let mut counts = vec![0usize; centers.len()];
        for (i, p) in pts.iter().enumerate() {
            sums[assign[i]][0] += p[0];
            sums[assign[i]][1] += p[1];
            counts[assign[i]] += 1;
        }
        for (c, (s, &cnt)) in centers.iter_mut().zip(sums.iter().zip(&counts)) {
            if cnt > 0 {
                *c = [s[0] / cnt as f64, s[1] / cnt as f64];
            }
        }
        if !changed {
            break;
        }
    }

    // Build groups from *original-space* centroids of the members, dropping
    // empty clusters and compacting the assignment indices.
    let mut groups = Vec::new();
    let mut remap = vec![usize::MAX; centers.len()];
    for c in 0..centers.len() {
        let members: Vec<usize> = (0..pts.len()).filter(|&i| assign[i] == c).collect();
        if members.is_empty() {
            continue;
        }
        let mu = members.iter().map(|&i| workers[i].mu).sum::<f64>() / members.len() as f64;
        let alpha =
            members.iter().map(|&i| workers[i].alpha).sum::<f64>() / members.len() as f64;
        remap[c] = groups.len();
        groups.push(Group { n: members.len(), mu, alpha });
    }
    let assign: Vec<usize> = assign.into_iter().map(|c| remap[c]).collect();
    Ok((groups, assign))
}

#[inline]
fn dist2(a: &[f64; 2], b: &[f64; 2]) -> f64 {
    let dx = a[0] - b[0];
    let dy = a[1] - b[1];
    dx * dx + dy * dy
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fleet(groups: &[(usize, f64, f64)], jitter: f64, seed: u64) -> Vec<WorkerParams> {
        let mut rng = Rng::new(seed);
        let mut out = Vec::new();
        for &(n, mu, alpha) in groups {
            for _ in 0..n {
                out.push(WorkerParams {
                    mu: mu * (1.0 + jitter * (rng.next_f64() - 0.5)),
                    alpha: alpha * (1.0 + jitter * (rng.next_f64() - 0.5)),
                });
            }
        }
        out
    }

    #[test]
    fn recovers_well_separated_groups() {
        let workers = fleet(&[(30, 1.0, 1.0), (40, 8.0, 1.0), (50, 16.0, 4.0)], 0.05, 1);
        let (groups, assign) = cluster_workers(&workers, 3, 7).unwrap();
        assert_eq!(groups.len(), 3);
        assert_eq!(assign.len(), 120);
        let mut sizes: Vec<usize> = groups.iter().map(|g| g.n).collect();
        sizes.sort_unstable();
        assert_eq!(sizes, vec![30, 40, 50]);
        // Centroid mus should approximate the true centers.
        let mut mus: Vec<f64> = groups.iter().map(|g| g.mu).collect();
        mus.sort_by(f64::total_cmp);
        assert!((mus[0] - 1.0).abs() < 0.2);
        assert!((mus[1] - 8.0).abs() < 0.8);
        assert!((mus[2] - 16.0).abs() < 1.6);
    }

    #[test]
    fn assignment_consistent_with_group_sizes() {
        let workers = fleet(&[(20, 2.0, 1.0), (20, 10.0, 2.0)], 0.1, 3);
        let (groups, assign) = cluster_workers(&workers, 2, 11).unwrap();
        for (gi, g) in groups.iter().enumerate() {
            let cnt = assign.iter().filter(|&&a| a == gi).count();
            assert_eq!(cnt, g.n);
        }
    }

    #[test]
    fn g_equals_workers_is_identity_sized() {
        let workers = fleet(&[(5, 1.0, 1.0)], 0.5, 5);
        let (groups, _) = cluster_workers(&workers, 5, 13).unwrap();
        // Each worker its own group (some may merge if identical).
        assert!(groups.len() >= 1 && groups.len() <= 5);
        assert_eq!(groups.iter().map(|g| g.n).sum::<usize>(), 5);
    }

    #[test]
    fn rejects_bad_inputs() {
        assert!(cluster_workers(&[], 1, 0).is_err());
        let w = fleet(&[(3, 1.0, 1.0)], 0.0, 0);
        assert!(cluster_workers(&w, 0, 0).is_err());
        assert!(cluster_workers(&w, 4, 0).is_err());
    }
}
