//! The paper's two shifted-exponential runtime models.
//!
//! **Model A** (eq. (1), the paper's own model): a worker in group `j`
//! assigned `l` coded rows (out of MDS dimension `k`) finishes at
//!
//! ```text
//! T = (l/k) · α_j + (l/k) · X / μ_j,     X ~ Exp(1)
//! ```
//!
//! i.e. the CDF `F(t) = 1 - exp(-(k μ_j / l)(t - α_j l / k))`. Both the shift
//! and the scale are proportional to `l/k` — a worker doing half the rows is
//! twice as fast in distribution.
//!
//! **Model B** (eq. (30), the model of Reisizadeh et al. [32]): time to
//! compute `l` rows is
//!
//! ```text
//! T = α_j · l + l · X / μ_j,             X ~ Exp(1)
//! ```
//!
//! with CDF `F(t) = 1 - exp(-(μ_j / l)(t - α_j l))` — per-row scaling without
//! the `1/k` normalization, so latency grows with the absolute row count.

use crate::math::Rng;

/// Which latency model a simulation uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LatencyModel {
    /// Paper eq. (1): load normalized by `k`.
    A,
    /// Paper eq. (30) / [32]: per-row scaling.
    B,
}

/// A concrete runtime distribution for one worker with load `l`.
#[derive(Clone, Copy, Debug)]
pub struct RuntimeDist {
    model: LatencyModel,
    /// Assigned coded rows (real-valued; analysis relaxes integrality).
    pub load: f64,
    /// MDS dimension `k` (used by model A normalization only).
    pub k: f64,
    /// Straggling parameter `μ_(j)`.
    pub mu: f64,
    /// Shift parameter `α_(j)`.
    pub alpha: f64,
}

impl RuntimeDist {
    /// Build a distribution; panics on non-positive parameters.
    pub fn new(model: LatencyModel, load: f64, k: f64, mu: f64, alpha: f64) -> Self {
        assert!(load > 0.0 && k > 0.0 && mu > 0.0 && alpha > 0.0);
        RuntimeDist { model, load, k, mu, alpha }
    }

    /// The deterministic shift (minimum possible completion time).
    #[inline]
    pub fn shift(&self) -> f64 {
        match self.model {
            LatencyModel::A => self.alpha * self.load / self.k,
            LatencyModel::B => self.alpha * self.load,
        }
    }

    /// The exponential scale (mean of the stochastic part).
    #[inline]
    pub fn scale(&self) -> f64 {
        match self.model {
            LatencyModel::A => self.load / (self.k * self.mu),
            LatencyModel::B => self.load / self.mu,
        }
    }

    /// Sample one completion time.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        self.shift() + self.scale() * rng.exp1()
    }

    /// CDF `Pr(T <= t)`.
    pub fn cdf(&self, t: f64) -> f64 {
        if t < self.shift() {
            0.0
        } else {
            1.0 - (-(t - self.shift()) / self.scale()).exp()
        }
    }

    /// Quantile function (inverse CDF) for `p ∈ [0, 1)`.
    pub fn quantile(&self, p: f64) -> f64 {
        assert!((0.0..1.0).contains(&p));
        self.shift() - self.scale() * (1.0 - p).ln()
    }

    /// Mean completion time `shift + scale`.
    pub fn mean(&self) -> f64 {
        self.shift() + self.scale()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_a_shift_and_scale_match_eq1() {
        // F(t) = 1 - exp(-(k mu / l)(t - alpha l / k)).
        let d = RuntimeDist::new(LatencyModel::A, 50.0, 1000.0, 2.0, 1.5);
        assert!((d.shift() - 1.5 * 50.0 / 1000.0).abs() < 1e-15);
        assert!((d.scale() - 50.0 / (1000.0 * 2.0)).abs() < 1e-15);
        // CDF at shift is 0; far right tends to 1.
        assert_eq!(d.cdf(d.shift() - 1e-9), 0.0);
        assert!((d.cdf(d.shift() + 20.0 * d.scale()) - 1.0).abs() < 1e-8);
    }

    #[test]
    fn model_b_shift_and_scale_match_eq30() {
        // F(t) = 1 - exp(-(mu/l)(t - alpha l)).
        let d = RuntimeDist::new(LatencyModel::B, 50.0, 1000.0, 2.0, 1.5);
        assert!((d.shift() - 1.5 * 50.0).abs() < 1e-15);
        assert!((d.scale() - 50.0 / 2.0).abs() < 1e-15);
    }

    #[test]
    fn quantile_inverts_cdf() {
        let d = RuntimeDist::new(LatencyModel::A, 10.0, 100.0, 4.0, 1.0);
        for p in [0.0, 0.1, 0.5, 0.9, 0.999] {
            let t = d.quantile(p);
            assert!((d.cdf(t) - p).abs() < 1e-12, "p={p}");
        }
    }

    #[test]
    fn sample_mean_matches_analytic() {
        let d = RuntimeDist::new(LatencyModel::A, 10.0, 100.0, 4.0, 1.0);
        let mut rng = Rng::new(3);
        let n = 200_000;
        let mut s = 0.0;
        for _ in 0..n {
            s += d.sample(&mut rng);
        }
        let mean = s / n as f64;
        assert!(
            (mean - d.mean()).abs() < 3e-3 * d.mean(),
            "{mean} vs {}",
            d.mean()
        );
    }

    #[test]
    fn samples_respect_shift() {
        let d = RuntimeDist::new(LatencyModel::B, 5.0, 100.0, 1.0, 2.0);
        let mut rng = Rng::new(9);
        for _ in 0..10_000 {
            assert!(d.sample(&mut rng) >= d.shift());
        }
    }

    #[test]
    fn homogeneous_reduction_to_lee_et_al() {
        // With G=1, alpha=1, l=k/N, model A reduces to the model of [4]:
        // shift = 1/N, scale = 1/(N mu).
        let n_workers = 10.0;
        let k = 1000.0;
        let d = RuntimeDist::new(LatencyModel::A, k / n_workers, k, 2.0, 1.0);
        assert!((d.shift() - 1.0 / n_workers).abs() < 1e-15);
        assert!((d.scale() - 1.0 / (n_workers * 2.0)).abs() < 1e-15);
    }
}
