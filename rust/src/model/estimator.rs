//! Online estimation of per-group straggle parameters `(μ̂, α̂)`.
//!
//! The paper's allocation (Theorem 2) assumes the group parameters are
//! known and static. A serving system only *observes* worker completion
//! times — and machines drift. This module recovers the shifted-exponential
//! parameters from exactly what the master sees: for each job, the
//! completion times of the workers whose replies it consumed before
//! reaching `k` rows, i.e. the `r` **smallest** of the group's `n` order
//! statistics (a type-II right-censored sample).
//!
//! # Normalization
//!
//! A worker in group `j` with load `l` finishes at
//! `T = shift(l) + scale(l)·X`, `X ~ Exp(1)` (eq. (1)/(30)). Normalizing
//! `u = T·k/l` (model A) or `u = T/l` (model B) gives `u ~ α_j + Exp(1)/μ_j`
//! independent of the load — so observations taken under *different*
//! allocations (before/after a re-allocation) pool cleanly.
//!
//! # Censored MLE
//!
//! For one job contributing the `r` smallest of `n` normalized times,
//! observed up to the (normalized) job-completion horizon `c`, the
//! shifted-exponential likelihood gives the classical estimates
//!
//! ```text
//! α̂ = u_(1)                                   (sample minimum)
//! μ̂ = (R - 1) / Σ_jobs [ Σ_i (u_i - α̂) + (n - r)(c - α̂) ]
//! ```
//!
//! where `R = Σ_jobs r` and the `(n - r)(c - α̂)` term accounts for the
//! workers the master never waited for. The censor point is the **job
//! completion time** (the moment the master stopped listening), not the
//! group's last consumed reply: a worker that stayed silent is known to
//! exceed the whole job's horizon, and crediting only the group's own
//! last reply under-counts that exposure and biases `μ̂` upward for
//! heavily-straggling groups. `R - 1` in place of `R` removes the
//! first-order bias from estimating the shift by the minimum. Records are
//! kept in a sliding window of the most recent jobs so estimates track
//! drift.

use crate::model::{ClusterSpec, LatencyModel};
use crate::{Error, Result};
use std::collections::VecDeque;

/// Knobs shared by every adaptive loop (workload simulation and live
/// serving path).
#[derive(Clone, Copy, Debug)]
pub struct EstimatorConfig {
    /// Sliding window: per-group job records retained.
    pub window: usize,
    /// Minimum pooled observations `R` before an estimate is trusted.
    pub min_obs: usize,
    /// Relative deviation of `μ̂` or `α̂` from the currently assumed value
    /// that triggers a re-allocation.
    pub threshold: f64,
    /// Check for drift every this many jobs/batches.
    pub check_every: usize,
}

impl Default for EstimatorConfig {
    fn default() -> Self {
        EstimatorConfig {
            window: 50,
            min_obs: 100,
            threshold: 0.30,
            check_every: 10,
        }
    }
}

impl EstimatorConfig {
    /// Validate the knobs.
    pub fn validate(&self) -> Result<()> {
        if self.window == 0 || self.check_every == 0 {
            return Err(Error::InvalidSpec(
                "estimator window/check_every must be positive".into(),
            ));
        }
        if !(self.threshold > 0.0) || !self.threshold.is_finite() {
            return Err(Error::InvalidSpec(format!(
                "estimator threshold must be positive and finite, got {}",
                self.threshold
            )));
        }
        Ok(())
    }
}

/// One group's recovered parameters.
#[derive(Clone, Copy, Debug)]
pub struct GroupEstimate {
    /// Estimated straggling parameter `μ̂_j`.
    pub mu_hat: f64,
    /// Estimated shift parameter `α̂_j`.
    pub alpha_hat: f64,
    /// Pooled observations `R` behind the estimate.
    pub observations: u64,
}

/// One job's censored sample from one group, as the sufficient statistics
/// of the estimator's likelihood (raw model-time units; normalization by
/// load happens inside [`SpeedEstimator::observe_stats`]). Callers that
/// already aggregate min/sum while collecting (the drift simulation's
/// merge loop) feed this directly; callers holding raw times use
/// [`SpeedEstimator::observe`].
#[derive(Clone, Copy, Debug)]
pub struct CensoredSample {
    /// Responders consumed (`r ≥ 1`).
    pub r: usize,
    /// Workers dispatched in the group (`n ≥ r`).
    pub n: usize,
    /// Smallest consumed completion time.
    pub min_t: f64,
    /// Sum of consumed completion times.
    pub sum_t: f64,
    /// Largest consumed completion time.
    pub max_t: f64,
    /// Observation horizon (job completion; clamped up to `max_t`).
    pub censor_t: f64,
}

/// Normalized per-job record the sliding window retains.
#[derive(Clone, Copy, Debug)]
struct JobRecord {
    /// Responders consumed (`r`).
    r: usize,
    /// Workers dispatched in the group (`n ≥ r`).
    n: usize,
    /// Smallest normalized time.
    min_u: f64,
    /// Sum of normalized times over the `r` responders.
    sum_u: f64,
    /// Normalized censoring horizon (job completion; the `n - r` silent
    /// workers are known to exceed it).
    censor_u: f64,
}

/// Sliding-window estimator of per-group `(μ̂, α̂)` from censored
/// completion-time observations.
#[derive(Clone, Debug)]
pub struct SpeedEstimator {
    model: LatencyModel,
    k: f64,
    window: usize,
    recs: Vec<VecDeque<JobRecord>>,
}

impl SpeedEstimator {
    /// New estimator for `num_groups` groups under `model` with MDS
    /// dimension `k` (model A normalization) and a per-group window of
    /// `window` job records.
    pub fn new(
        num_groups: usize,
        model: LatencyModel,
        k: usize,
        window: usize,
    ) -> Result<SpeedEstimator> {
        if num_groups == 0 || k == 0 || window == 0 {
            return Err(Error::InvalidSpec(
                "estimator needs groups, k and a positive window".into(),
            ));
        }
        Ok(SpeedEstimator {
            model,
            k: k as f64,
            window,
            recs: vec![VecDeque::new(); num_groups],
        })
    }

    /// Normalization factor turning a raw completion time into
    /// `u ~ α + Exp(1)/μ` for a worker with load `load`.
    fn norm(&self, load: f64) -> f64 {
        match self.model {
            LatencyModel::A => self.k / load,
            LatencyModel::B => 1.0 / load,
        }
    }

    /// Record one job's consumed responder times for `group`: `times` are
    /// the raw (model-time) completions of the `times.len()` fastest of
    /// `n_dispatched` workers, each of which carried `load` coded rows,
    /// and `censor` is the raw observation horizon — the job's completion
    /// time, past which nothing was consumed (clamped up to the largest
    /// observation, so a pure type-II sample may pass its own `u_(r)`).
    /// Invalid inputs (no responders, nonpositive load, r > n) are ignored
    /// rather than poisoning the window.
    pub fn observe(
        &mut self,
        group: usize,
        load: f64,
        n_dispatched: usize,
        times: &[f64],
        censor: f64,
    ) {
        if times.is_empty() || times.iter().any(|t| !t.is_finite()) {
            return;
        }
        let mut min_t = f64::INFINITY;
        let mut max_t = f64::NEG_INFINITY;
        let mut sum_t = 0.0;
        for &t in times {
            min_t = min_t.min(t);
            max_t = max_t.max(t);
            sum_t += t;
        }
        self.observe_stats(
            group,
            load,
            CensoredSample {
                r: times.len(),
                n: n_dispatched,
                min_t,
                sum_t,
                max_t,
                censor_t: censor,
            },
        );
    }

    /// [`SpeedEstimator::observe`] from pre-aggregated sufficient
    /// statistics — the likelihood only ever reads `(r, n, min, sum,
    /// censor)`, so callers that accumulate while collecting replies need
    /// not materialize a times vector. Invalid samples are ignored.
    pub fn observe_stats(&mut self, group: usize, load: f64, s: CensoredSample) {
        if group >= self.recs.len()
            || s.r == 0
            || s.r > s.n
            || !(load > 0.0)
            || !s.censor_t.is_finite()
            || !s.min_t.is_finite()
            || !s.sum_t.is_finite()
            || !s.max_t.is_finite()
        {
            return;
        }
        let c = self.norm(load);
        let censor_u = (s.censor_t * c).max(s.max_t * c);
        let q = &mut self.recs[group];
        if q.len() == self.window {
            q.pop_front();
        }
        q.push_back(JobRecord {
            r: s.r,
            n: s.n,
            min_u: s.min_t * c,
            sum_u: s.sum_t * c,
            censor_u,
        });
    }

    /// Drop every record (called after a re-allocation so the next
    /// estimate reflects only the new regime).
    pub fn flush(&mut self) {
        for q in &mut self.recs {
            q.clear();
        }
    }

    /// Pooled observations currently windowed for `group`.
    pub fn observations(&self, group: usize) -> u64 {
        self.recs
            .get(group)
            .map(|q| q.iter().map(|r| r.r as u64).sum())
            .unwrap_or(0)
    }

    /// Censored-MLE estimate for `group`, or `None` when fewer than
    /// `min_obs` (or 2) pooled observations are available or the sample is
    /// degenerate.
    pub fn estimate(&self, group: usize, min_obs: usize) -> Option<GroupEstimate> {
        let q = self.recs.get(group)?;
        let total_r: u64 = q.iter().map(|r| r.r as u64).sum();
        if total_r < min_obs.max(2) as u64 {
            return None;
        }
        let alpha_hat = q.iter().map(|r| r.min_u).fold(f64::INFINITY, f64::min);
        let mut d = 0.0;
        for r in q {
            d += (r.sum_u - r.r as f64 * alpha_hat)
                + (r.n - r.r) as f64 * (r.censor_u - alpha_hat);
        }
        if !(d > 0.0) || !(alpha_hat > 0.0) || !alpha_hat.is_finite() {
            return None;
        }
        Some(GroupEstimate {
            mu_hat: (total_r - 1) as f64 / d,
            alpha_hat,
            observations: total_r,
        })
    }

    /// Does any group's estimate deviate from `assumed` by more than
    /// `threshold` (relative, in `μ` or `α`)? Groups without a trustworthy
    /// estimate never vote.
    ///
    /// The `μ̂` test additionally requires statistical significance: the
    /// relative standard error of the censored MLE is ≈ `1/√R`, so a
    /// deviation must clear `max(threshold, 4.5/√R)`. Without the floor, a
    /// window that has just crossed `min_obs` (large `1/√R`) fires on pure
    /// estimation noise every few hundred checks — validated to zero false
    /// re-allocations over 20 seeded no-drift runs with it. `α̂` needs no
    /// floor: the minimum estimator's upward bias is `O(1/(μR))`,
    /// negligible against any sane threshold.
    pub fn deviates_from(
        &self,
        assumed: &ClusterSpec,
        threshold: f64,
        min_obs: usize,
    ) -> bool {
        assumed.groups.iter().enumerate().any(|(j, g)| {
            self.estimate(j, min_obs).is_some_and(|e| {
                let floor = threshold.max(4.5 / (e.observations as f64).sqrt());
                (e.mu_hat / g.mu - 1.0).abs() > floor
                    || (e.alpha_hat / g.alpha - 1.0).abs() > threshold
            })
        })
    }

    /// Build the spec the allocator should re-solve against: group sizes
    /// from `alive` (cluster membership is observed, e.g. via heartbeats;
    /// speeds are what must be estimated), `(μ, α)` from the estimator
    /// where trustworthy and from `assumed` otherwise. Groups with zero
    /// survivors keep their parameters but contribute no workers.
    pub fn estimated_spec(
        &self,
        assumed: &ClusterSpec,
        alive: &[usize],
        min_obs: usize,
    ) -> Result<ClusterSpec> {
        if alive.len() != assumed.num_groups() {
            return Err(Error::InvalidSpec(format!(
                "{} alive counts for {} groups",
                alive.len(),
                assumed.num_groups()
            )));
        }
        let groups = assumed
            .groups
            .iter()
            .zip(alive)
            .enumerate()
            .map(|(j, (g, &n_alive))| {
                let (mu, alpha) = match self.estimate(j, min_obs) {
                    Some(e) => (e.mu_hat, e.alpha_hat),
                    None => (g.mu, g.alpha),
                };
                crate::model::Group { n: n_alive, mu, alpha }
            })
            .collect();
        ClusterSpec::new(groups, assumed.k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::Rng;
    use crate::model::{Group, RuntimeDist};

    /// Feed `jobs` synthetic censored samples drawn from the true
    /// distribution: each job observes the `r` smallest of `n` worker
    /// times for a group whose runtime law is `dist`.
    fn feed(
        est: &mut SpeedEstimator,
        group: usize,
        dist: &RuntimeDist,
        n: usize,
        r: usize,
        jobs: usize,
        rng: &mut Rng,
    ) {
        for _ in 0..jobs {
            let mut ts: Vec<f64> = (0..n).map(|_| dist.sample(rng)).collect();
            ts.sort_by(f64::total_cmp);
            // Pure type-II sample: the horizon is the last consumed reply.
            est.observe(group, dist.load, n, &ts[..r], ts[r - 1]);
        }
    }

    #[test]
    fn recovers_known_parameters_from_censored_samples() {
        let (mu, alpha) = (4.0, 1.5);
        let dist = RuntimeDist::new(LatencyModel::A, 40.0, 1000.0, mu, alpha);
        let mut est = SpeedEstimator::new(1, LatencyModel::A, 1000, 200).unwrap();
        let mut rng = Rng::new(42);
        feed(&mut est, 0, &dist, 30, 20, 100, &mut rng);
        let e = est.estimate(0, 100).unwrap();
        assert!(
            (e.mu_hat / mu - 1.0).abs() < 0.12,
            "mu_hat {} vs {mu}",
            e.mu_hat
        );
        assert!(
            (e.alpha_hat / alpha - 1.0).abs() < 0.05,
            "alpha_hat {} vs {alpha}",
            e.alpha_hat
        );
        assert!(e.observations >= 2000);
    }

    #[test]
    fn normalization_pools_across_loads_and_models() {
        // Same (mu, alpha), two different loads: pooled estimate stays
        // accurate because observations are normalized before pooling.
        for model in [LatencyModel::A, LatencyModel::B] {
            let (mu, alpha) = (2.0, 1.0);
            let light = RuntimeDist::new(model, 20.0, 500.0, mu, alpha);
            let heavy = RuntimeDist::new(model, 55.0, 500.0, mu, alpha);
            let mut est = SpeedEstimator::new(1, model, 500, 400).unwrap();
            let mut rng = Rng::new(7);
            feed(&mut est, 0, &light, 20, 14, 80, &mut rng);
            feed(&mut est, 0, &heavy, 20, 14, 80, &mut rng);
            let e = est.estimate(0, 200).unwrap();
            assert!(
                (e.mu_hat / mu - 1.0).abs() < 0.15,
                "{model:?}: mu_hat {}",
                e.mu_hat
            );
            assert!(
                (e.alpha_hat / alpha - 1.0).abs() < 0.05,
                "{model:?}: alpha_hat {}",
                e.alpha_hat
            );
        }
    }

    #[test]
    fn window_tracks_drift_and_flush_clears() {
        let old = RuntimeDist::new(LatencyModel::A, 30.0, 1000.0, 8.0, 1.0);
        let slowed = RuntimeDist::new(LatencyModel::A, 30.0, 1000.0, 4.0, 2.0);
        let mut est = SpeedEstimator::new(1, LatencyModel::A, 1000, 60).unwrap();
        let mut rng = Rng::new(3);
        // Old regime, then a 2x slowdown (mu/2, alpha*2); window slides.
        feed(&mut est, 0, &old, 24, 16, 60, &mut rng);
        feed(&mut est, 0, &slowed, 24, 16, 60, &mut rng);
        let e = est.estimate(0, 100).unwrap();
        assert!((e.mu_hat / 4.0 - 1.0).abs() < 0.15, "mu_hat {}", e.mu_hat);
        assert!(
            (e.alpha_hat / 2.0 - 1.0).abs() < 0.05,
            "alpha_hat {}",
            e.alpha_hat
        );
        est.flush();
        assert!(est.estimate(0, 1).is_none());
        assert_eq!(est.observations(0), 0);
    }

    #[test]
    fn deviation_detection_fires_only_on_real_drift() {
        let spec = ClusterSpec::new(
            vec![Group { n: 24, mu: 8.0, alpha: 1.0 }],
            1000,
        )
        .unwrap();
        let healthy = RuntimeDist::new(LatencyModel::A, 30.0, 1000.0, 8.0, 1.0);
        let slowed = RuntimeDist::new(LatencyModel::A, 30.0, 1000.0, 4.0, 2.0);
        let mut est = SpeedEstimator::new(1, LatencyModel::A, 1000, 100).unwrap();
        let mut rng = Rng::new(9);
        feed(&mut est, 0, &healthy, 24, 16, 80, &mut rng);
        assert!(!est.deviates_from(&spec, 0.30, 100), "false positive");
        est.flush();
        feed(&mut est, 0, &slowed, 24, 16, 80, &mut rng);
        assert!(est.deviates_from(&spec, 0.30, 100), "missed a 2x slowdown");
    }

    #[test]
    fn insufficient_or_degenerate_data_yields_none() {
        let mut est = SpeedEstimator::new(2, LatencyModel::A, 100, 10).unwrap();
        assert!(est.estimate(0, 1).is_none());
        est.observe(0, 10.0, 4, &[1.0, 1.1, 1.2], 1.2);
        assert!(est.estimate(0, 100).is_none(), "below min_obs");
        // Degenerate: identical uncensored times leave zero spread.
        est.flush();
        est.observe(1, 10.0, 2, &[1.0, 1.0], 1.0);
        assert!(est.estimate(1, 2).is_none());
        // Ignored malformed observations leave the window empty.
        est.observe(0, 0.0, 4, &[1.0], 1.0);
        est.observe(0, 10.0, 1, &[1.0, 2.0], 2.0);
        est.observe(0, 10.0, 4, &[f64::NAN], 1.0);
        est.observe(0, 10.0, 4, &[1.0], f64::INFINITY);
        est.observe(5, 10.0, 4, &[1.0], 1.0);
        assert_eq!(est.observations(0), 0);
    }

    #[test]
    fn horizon_censoring_stays_calibrated_with_variable_responder_counts() {
        // Type-I censoring at a horizon past the last consumed reply —
        // the any-k master's view of a straggling group (it stops
        // listening at job completion, not at the group's own last
        // reply). Each job observes however many workers beat the
        // horizon; the silent rest are credited exposure up to it. The
        // MLE must stay calibrated (crediting only up to the group's last
        // reply inflates μ̂ for heavily censored groups).
        let dist = RuntimeDist::new(LatencyModel::A, 30.0, 1000.0, 1.0, 1.0);
        // Horizon in raw model time: normalized u = α + Exp/μ, cut at
        // u = 2 (≈ 63% of workers respond), i.e. t = 2·l/k.
        let horizon = 2.0 * 30.0 / 1000.0;
        let mut est = SpeedEstimator::new(1, LatencyModel::A, 1000, 400).unwrap();
        let mut rng = Rng::new(21);
        for _ in 0..300 {
            let mut ts: Vec<f64> = (0..10).map(|_| dist.sample(&mut rng)).collect();
            ts.sort_by(f64::total_cmp);
            let consumed: Vec<f64> =
                ts.iter().copied().filter(|&t| t <= horizon).collect();
            if !consumed.is_empty() {
                est.observe(0, dist.load, 10, &consumed, horizon);
            }
        }
        let e = est.estimate(0, 100).unwrap();
        assert!(
            (e.mu_hat - 1.0).abs() < 0.10,
            "mu_hat {} should be ~1.0 under horizon censoring",
            e.mu_hat
        );
        assert!((e.alpha_hat - 1.0).abs() < 0.05, "alpha_hat {}", e.alpha_hat);
    }

    #[test]
    fn estimated_spec_merges_alive_counts_and_estimates() {
        let assumed = ClusterSpec::new(
            vec![
                Group { n: 10, mu: 8.0, alpha: 1.0 },
                Group { n: 20, mu: 1.0, alpha: 1.0 },
            ],
            1000,
        )
        .unwrap();
        let shifted = RuntimeDist::new(LatencyModel::A, 30.0, 1000.0, 4.0, 2.0);
        let mut est = SpeedEstimator::new(2, LatencyModel::A, 1000, 100).unwrap();
        let mut rng = Rng::new(12);
        feed(&mut est, 0, &shifted, 10, 8, 60, &mut rng);
        let spec = est.estimated_spec(&assumed, &[8, 20], 100).unwrap();
        assert_eq!(spec.groups[0].n, 8);
        assert!((spec.groups[0].mu / 4.0 - 1.0).abs() < 0.2);
        // Group 1 never observed: falls back to assumed parameters.
        assert_eq!(spec.groups[1].mu, 1.0);
        assert_eq!(spec.groups[1].n, 20);
        assert!(est.estimated_spec(&assumed, &[1], 100).is_err());
    }
}
