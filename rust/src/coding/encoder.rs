//! Row encoder: `Ã = G·A` and per-worker chunking.

use crate::coding::{Generator, GeneratorKind, Matrix};
use crate::runtime::pool::WorkPool;
use crate::{Error, Result};
use std::sync::atomic::{AtomicU64, Ordering};

/// Encodes a data matrix and slices the coded rows into per-worker chunks
/// according to a load allocation.
///
/// The encoder counts its own `encode` invocations
/// ([`Encoder::encode_calls`]) so serving paths can *measure* — not merely
/// declare — that steady-state batches perform no encode work. Row-level
/// accounting rides alongside: [`Encoder::rows_encoded`] counts every
/// coded row produced, and [`Encoder::re_encoded_rows`] counts rows whose
/// global index had already been encoded through this instance — the
/// counter the rateless elasticity contract pins to zero (extending the
/// stream mints *fresh* ranges, it never recomputes issued rows).
#[derive(Debug)]
pub struct Encoder {
    generator: Generator,
    encodes: AtomicU64,
    /// Total coded rows produced (full encodes and range encodes alike).
    rows_encoded: AtomicU64,
    /// Rows produced whose global index overlapped the high-watermark of
    /// previously encoded rows — i.e. redundant encode work.
    re_encoded_rows: AtomicU64,
    /// One past the highest global row index ever encoded here.
    watermark: AtomicU64,
}

impl Clone for Encoder {
    /// Clones the generator binding; the clone's call counter starts at 0.
    fn clone(&self) -> Self {
        Encoder::new(self.generator.clone())
    }
}

/// One worker's coded chunk: the coded rows it must multiply with `x`,
/// together with their global row indices in `Ã` (needed for decoding).
#[derive(Clone, Debug)]
pub struct WorkerChunk {
    /// Worker id (0-based, global across groups).
    pub worker: usize,
    /// Global coded-row indices covered by this chunk.
    pub row_range: std::ops::Range<usize>,
    /// The coded rows `Ã_i ∈ R^{l_i × d}`.
    pub rows: Matrix,
}

impl Encoder {
    /// Wrap a generator.
    pub fn new(generator: Generator) -> Self {
        Encoder {
            generator,
            encodes: AtomicU64::new(0),
            rows_encoded: AtomicU64::new(0),
            re_encoded_rows: AtomicU64::new(0),
            watermark: AtomicU64::new(0),
        }
    }

    /// The underlying generator.
    pub fn generator(&self) -> &Generator {
        &self.generator
    }

    /// Number of `encode`/`encode_with_threads` calls made through this
    /// encoder instance.
    pub fn encode_calls(&self) -> u64 {
        self.encodes.load(Ordering::Relaxed)
    }

    /// Total coded rows produced through this instance (full and range
    /// encodes combined).
    pub fn rows_encoded(&self) -> u64 {
        self.rows_encoded.load(Ordering::Relaxed)
    }

    /// Rows whose global index had already been encoded through this
    /// instance when they were encoded again. The rateless scale-out path
    /// asserts this stays 0 — fresh ranges only, no recompute of issued
    /// rows.
    pub fn re_encoded_rows(&self) -> u64 {
        self.re_encoded_rows.load(Ordering::Relaxed)
    }

    /// One past the highest global row index encoded so far (0 if no
    /// encode has happened).
    pub fn encode_watermark(&self) -> u64 {
        self.watermark.load(Ordering::Relaxed)
    }

    /// Extend the underlying generator's materialized prefix (rateless
    /// family only — delegates to [`Generator::extend_to`]). Performs no
    /// encode work itself; pair with [`Encoder::encode_rows`] on the
    /// fresh range so [`Encoder::chunk`]/[`Encoder::rechunk`] validation
    /// sees the new `n`.
    pub fn extend_to(&mut self, new_n: usize) -> Result<()> {
        self.generator.extend_to(new_n)
    }

    /// Account `range` against the row-level counters: bump the total,
    /// charge the overlap with the previously-encoded watermark as
    /// re-encoded work, and advance the watermark.
    fn count_rows(&self, range: &std::ops::Range<usize>) {
        let (start, end) = (range.start as u64, range.end as u64);
        self.rows_encoded.fetch_add(end - start, Ordering::Relaxed);
        let prev = self.watermark.fetch_max(end, Ordering::Relaxed);
        let overlap = prev.min(end).saturating_sub(start);
        self.re_encoded_rows.fetch_add(overlap, Ordering::Relaxed);
    }

    /// Encode: `Ã = G·A`, where `A ∈ R^{k×d}`, on the shared global
    /// [`WorkPool`].
    pub fn encode(&self, a: &Matrix) -> Result<Matrix> {
        self.encode_on(a, WorkPool::global_ref())
    }

    /// Encode on an explicit pool handle — the serving-path entry point
    /// ([`crate::coordinator::JobConfig`] threads one pool through every
    /// encode of a session). The encode is the setup-path bottleneck at
    /// serving sizes — O(n·k·d) — and parallelizes over coded rows through
    /// the register-blocked matmul kernel with bit-identical results for
    /// any pool size.
    pub fn encode_on(&self, a: &Matrix, pool: &WorkPool) -> Result<Matrix> {
        self.encode_capped(a, pool, pool.threads())
    }

    /// [`Encoder::encode_on`] with an explicit cap on the task split —
    /// how the per-request cold path honors
    /// [`crate::coordinator::JobConfig`]'s `encode_threads` as a
    /// concurrency bound without constructing a pool per call. Results
    /// are bit-identical for any cap.
    ///
    /// This is the one dispatch point between the dense and sparse encode
    /// kernels: a generator carrying a CSR mirror ([`Generator::sparse`],
    /// e.g. the `SparseParity` family) encodes through the O(nnz·d)
    /// sparse kernel, everything else through the dense register-blocked
    /// matmul — bit-identical to each other for finite inputs (see
    /// [`crate::coding::CsrMatrix::matmul_on`]), so which kernel ran is
    /// unobservable in the coded rows.
    pub fn encode_capped(
        &self,
        a: &Matrix,
        pool: &WorkPool,
        max_streams: usize,
    ) -> Result<Matrix> {
        self.check_shape(a)?;
        self.encodes.fetch_add(1, Ordering::Relaxed);
        self.count_rows(&(0..self.generator.n()));
        Ok(match self.generator.sparse() {
            Some(csr) => csr.matmul_streams(a, pool, max_streams),
            None => self.generator.matrix().matmul_streams(a, pool, max_streams),
        })
    }

    /// Encode only the coded rows in `range`: `Ã[range] = G[range]·A` —
    /// the extend-`n` surface of the rateless stream. For the rateless
    /// family the range may lie (partly) beyond the materialized prefix:
    /// the coefficient rows are derived on demand from `(seed, i)`
    /// ([`Generator::submatrix`]), so splitting one range into several
    /// calls is byte-identical to a single call (pinned by
    /// `code_golden.rs`). Finite families may range-encode too, but only
    /// within their fixed `[0, n)`.
    ///
    /// Does **not** bump [`Encoder::encode_calls`] — that counter means
    /// "full setup encodes" to the serving invariants
    /// (`post_setup_encodes == 0`); range encodes are accounted at row
    /// granularity by [`Encoder::rows_encoded`] /
    /// [`Encoder::re_encoded_rows`] instead.
    pub fn encode_rows(
        &self,
        a: &Matrix,
        range: std::ops::Range<usize>,
        pool: &WorkPool,
        max_streams: usize,
    ) -> Result<Matrix> {
        self.check_shape(a)?;
        if range.start > range.end {
            return Err(Error::InvalidSpec(format!(
                "encode_rows range {}..{} is inverted",
                range.start, range.end
            )));
        }
        if self.generator.kind() != GeneratorKind::RatelessRlc
            && range.end > self.generator.n()
        {
            return Err(Error::InvalidSpec(format!(
                "encode_rows range {}..{} exceeds n={} and {:?} is not \
                 rateless",
                range.start,
                range.end,
                self.generator.n(),
                self.generator.kind()
            )));
        }
        self.count_rows(&range);
        let idx: Vec<usize> = range.collect();
        let g_rows = self.generator.submatrix(&idx);
        Ok(g_rows.matmul_streams(a, pool, max_streams))
    }

    /// Pre-pool compatibility shim: `threads` now only caps the task
    /// split; execution happens on the shared global [`WorkPool`] (no
    /// per-call thread spawns).
    ///
    /// Migration: `encoder.encode_on(&a, &pool)` with a
    /// [`crate::runtime::pool::PoolHandle`] (or plain [`Encoder::encode`]
    /// for the global pool).
    #[deprecated(
        since = "0.3.0",
        note = "use encode_on with a runtime::pool::WorkPool handle \
                (or encode() for the global pool)"
    )]
    pub fn encode_with_threads(&self, a: &Matrix, threads: usize) -> Result<Matrix> {
        self.check_shape(a)?;
        self.encodes.fetch_add(1, Ordering::Relaxed);
        self.count_rows(&(0..self.generator.n()));
        #[allow(deprecated)]
        let coded = self.generator.matrix().matmul_blocked(a, threads);
        Ok(coded)
    }

    fn check_shape(&self, a: &Matrix) -> Result<()> {
        if a.rows() != self.generator.k() {
            return Err(Error::InvalidSpec(format!(
                "data matrix has {} rows, code dimension k={}",
                a.rows(),
                self.generator.k()
            )));
        }
        Ok(())
    }

    /// Split coded rows into per-worker chunks by an integer load vector
    /// (one entry per worker, `Σ l_i = n`).
    pub fn chunk(&self, coded: &Matrix, loads: &[usize]) -> Result<Vec<WorkerChunk>> {
        let total: usize = loads.iter().sum();
        if total != self.generator.n() {
            return Err(Error::InvalidSpec(format!(
                "loads sum to {total}, code length n={}",
                self.generator.n()
            )));
        }
        if loads.iter().any(|&l| l == 0) {
            return Err(Error::InvalidSpec("worker assigned zero rows".into()));
        }
        self.slice(coded, loads)
    }

    /// Re-slice an **already-encoded** matrix into a new per-worker split —
    /// the re-allocation primitive. Unlike [`Encoder::chunk`] it accepts a
    /// partial cover (`k ≤ Σ l_i ≤ n`: re-allocation cannot mint coded
    /// rows beyond the `n` that exist without re-encoding, and any `≥ k`
    /// subset of an MDS code decodes) and zero loads (dead or drained
    /// workers simply receive no chunk). Performs no encode work — the
    /// encode-call counter is untouched, which is what lets serving paths
    /// *measure* that adaptation never re-encodes.
    pub fn rechunk(&self, coded: &Matrix, loads: &[usize]) -> Result<Vec<WorkerChunk>> {
        let total: usize = loads.iter().sum();
        if total > self.generator.n() {
            return Err(Error::InvalidSpec(format!(
                "rechunk loads sum to {total} but only n={} coded rows exist \
                 (re-encoding is the only way to mint more)",
                self.generator.n()
            )));
        }
        if total < self.generator.k() {
            return Err(Error::InvalidSpec(format!(
                "rechunk loads sum to {total} < k={}; undecodable",
                self.generator.k()
            )));
        }
        self.slice(coded, loads)
    }

    /// Shared slicer: contiguous coded-row ranges in worker order, skipping
    /// zero loads.
    fn slice(&self, coded: &Matrix, loads: &[usize]) -> Result<Vec<WorkerChunk>> {
        if coded.rows() != self.generator.n() {
            return Err(Error::InvalidSpec(format!(
                "coded matrix has {} rows, expected n={}",
                coded.rows(),
                self.generator.n()
            )));
        }
        let mut chunks = Vec::with_capacity(loads.len());
        let mut start = 0usize;
        for (w, &l) in loads.iter().enumerate() {
            if l == 0 {
                continue;
            }
            let range = start..start + l;
            let idx: Vec<usize> = range.clone().collect();
            chunks.push(WorkerChunk {
                worker: w,
                row_range: range,
                rows: coded.select_rows(&idx),
            });
            start += l;
        }
        Ok(chunks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::GeneratorKind;
    use crate::math::Rng;

    fn random_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_fn(rows, cols, |_, _| rng.normal())
    }

    #[test]
    fn systematic_encode_preserves_data_rows() {
        let g = Generator::new(GeneratorKind::SystematicRandom, 10, 4, 1).unwrap();
        let enc = Encoder::new(g);
        let a = random_matrix(4, 6, 2);
        assert_eq!(enc.encode_calls(), 0);
        let coded = enc.encode(&a).unwrap();
        assert_eq!(coded.rows(), 10);
        for i in 0..4 {
            assert_eq!(coded.row(i), a.row(i), "systematic row {i}");
        }
        // The call counter measures actual encode invocations (pool size
        // is irrelevant, and results are bit-identical).
        let pool = crate::runtime::pool::WorkPool::new(3);
        let pooled = enc.encode_on(&a, &pool).unwrap();
        assert_eq!(pooled, coded);
        #[allow(deprecated)] // the shim must keep counting and matching
        let threaded = enc.encode_with_threads(&a, 0).unwrap();
        assert_eq!(threaded, coded);
        assert_eq!(enc.encode_calls(), 3);
        assert_eq!(enc.clone().encode_calls(), 0);
    }

    #[test]
    fn sparse_encode_routes_through_csr_and_matches_dense() {
        // The SparseParity generator encodes through the CSR kernel; the
        // result must be byte-equal to pushing its dense mirror through
        // the dense kernel (which kernel ran is unobservable).
        let g = Generator::new(GeneratorKind::SparseParity, 40, 16, 9).unwrap();
        let enc = Encoder::new(g.clone());
        let a = random_matrix(16, 12, 10);
        let coded = enc.encode(&a).unwrap();
        assert_eq!(enc.encode_calls(), 1);
        assert_eq!(coded.rows(), 40);
        // Systematic prefix passes the data through untouched.
        for i in 0..16 {
            assert_eq!(coded.row(i), a.row(i), "systematic row {i}");
        }
        let dense = g.matrix().matmul(&a);
        assert!(
            coded
                .data()
                .iter()
                .zip(dense.data())
                .all(|(c, d)| c.to_bits() == d.to_bits()),
            "sparse encode diverged from dense mirror"
        );
    }

    #[test]
    fn encode_rejects_wrong_k() {
        let g = Generator::new(GeneratorKind::SystematicRandom, 10, 4, 1).unwrap();
        let enc = Encoder::new(g);
        let a = random_matrix(5, 6, 2);
        assert!(enc.encode(&a).is_err());
    }

    #[test]
    fn chunking_partitions_all_rows() {
        let g = Generator::new(GeneratorKind::SystematicRandom, 12, 4, 1).unwrap();
        let enc = Encoder::new(g);
        let a = random_matrix(4, 3, 3);
        let coded = enc.encode(&a).unwrap();
        let chunks = enc.chunk(&coded, &[3, 3, 3, 3]).unwrap();
        assert_eq!(chunks.len(), 4);
        let mut covered = vec![false; 12];
        for ch in &chunks {
            assert_eq!(ch.rows.rows(), 3);
            for (local, global) in ch.row_range.clone().enumerate() {
                assert!(!covered[global]);
                covered[global] = true;
                assert_eq!(ch.rows.row(local), coded.row(global));
            }
        }
        assert!(covered.iter().all(|&c| c));
    }

    #[test]
    fn chunking_validates_loads() {
        let g = Generator::new(GeneratorKind::SystematicRandom, 12, 4, 1).unwrap();
        let enc = Encoder::new(g);
        let a = random_matrix(4, 3, 3);
        let coded = enc.encode(&a).unwrap();
        assert!(enc.chunk(&coded, &[3, 3, 3]).is_err()); // sums to 9 != 12
        assert!(enc.chunk(&coded, &[12, 0]).is_err()); // zero load
    }

    #[test]
    fn rechunk_reslices_without_reencoding() {
        let g = Generator::new(GeneratorKind::SystematicRandom, 12, 4, 1).unwrap();
        let enc = Encoder::new(g);
        let a = random_matrix(4, 3, 3);
        let coded = enc.encode(&a).unwrap();
        assert_eq!(enc.encode_calls(), 1);
        // Partial cover with a zero-load (dead) worker: rows 0..9 go to
        // workers 0, 2, 3; rows 9..12 are left unassigned.
        let chunks = enc.rechunk(&coded, &[4, 0, 3, 2]).unwrap();
        assert_eq!(enc.encode_calls(), 1, "rechunk must not re-encode");
        assert_eq!(chunks.len(), 3);
        assert_eq!(chunks[0].worker, 0);
        assert_eq!(chunks[1].worker, 2);
        assert_eq!(chunks[2].worker, 3);
        assert_eq!(chunks[0].row_range, 0..4);
        assert_eq!(chunks[1].row_range, 4..7);
        assert_eq!(chunks[2].row_range, 7..9);
        for ch in &chunks {
            for (local, global) in ch.row_range.clone().enumerate() {
                assert_eq!(ch.rows.row(local), coded.row(global));
            }
        }
    }

    #[test]
    fn rechunk_validates_cover_bounds() {
        let g = Generator::new(GeneratorKind::SystematicRandom, 12, 4, 1).unwrap();
        let enc = Encoder::new(g);
        let a = random_matrix(4, 3, 3);
        let coded = enc.encode(&a).unwrap();
        assert!(enc.rechunk(&coded, &[13]).is_err(), "beyond n");
        assert!(enc.rechunk(&coded, &[3, 0]).is_err(), "below k");
        assert!(enc.rechunk(&coded, &[4, 4, 4]).is_ok(), "full cover ok");
        assert!(enc.rechunk(&coded, &[4]).is_ok(), "k-exact cover ok");
        // Wrong coded matrix shape still rejected.
        let wrong = random_matrix(11, 3, 4);
        assert!(enc.rechunk(&wrong, &[4, 4]).is_err());
    }

    #[test]
    fn encode_rows_splits_are_byte_identical_and_counted() {
        let g = Generator::new(GeneratorKind::RatelessRlc, 8, 4, 21).unwrap();
        let enc = Encoder::new(g);
        let a = random_matrix(4, 6, 2);
        let pool = crate::runtime::pool::WorkPool::new(2);
        // One call over [0, 14) vs. three incremental extends.
        let whole = enc.encode_rows(&a, 0..14, &pool, 2).unwrap();
        let enc2 = enc.clone();
        let parts = [0..5usize, 5..8, 8..14]
            .into_iter()
            .map(|r| enc2.encode_rows(&a, r, &pool, 2).unwrap())
            .collect::<Vec<_>>();
        let split: Vec<u64> = parts
            .iter()
            .flat_map(|m| m.data().iter().map(|v| v.to_bits()))
            .collect();
        let whole_bits: Vec<u64> =
            whole.data().iter().map(|v| v.to_bits()).collect();
        assert_eq!(whole_bits, split, "call splits must be byte-identical");
        // Row accounting: fresh ranges never count as re-encodes; the
        // full-call counter is untouched by range encodes.
        assert_eq!(enc2.rows_encoded(), 14);
        assert_eq!(enc2.re_encoded_rows(), 0);
        assert_eq!(enc2.encode_watermark(), 14);
        assert_eq!(enc2.encode_calls(), 0);
        // Overlapping range is charged as re-encoded work.
        enc2.encode_rows(&a, 10..16, &pool, 2).unwrap();
        assert_eq!(enc2.re_encoded_rows(), 4);
        assert_eq!(enc2.encode_watermark(), 16);
    }

    #[test]
    fn encode_rows_bounds_and_full_encode_accounting() {
        let g = Generator::new(GeneratorKind::SystematicRandom, 10, 4, 1).unwrap();
        let enc = Encoder::new(g);
        let a = random_matrix(4, 3, 3);
        let pool = crate::runtime::pool::WorkPool::new(1);
        // Finite families may range-encode inside [0, n)…
        let sub = enc.encode_rows(&a, 2..7, &pool, 1).unwrap();
        assert_eq!(sub.rows(), 5);
        // …but not beyond it.
        assert!(enc.encode_rows(&a, 8..12, &pool, 1).is_err());
        // A full encode counts all n rows and advances the watermark; a
        // second full encode is pure re-encode work.
        let coded = enc.encode(&a).unwrap();
        assert_eq!(sub.row(0), coded.row(2), "range slice matches full");
        assert_eq!(enc.rows_encoded(), 15);
        assert_eq!(enc.re_encoded_rows(), 5);
        enc.encode(&a).unwrap();
        assert_eq!(enc.re_encoded_rows(), 15);
        // Clone resets row accounting along with the call counter.
        assert_eq!(enc.clone().rows_encoded(), 0);
    }

    #[test]
    fn extend_to_grows_rateless_n_for_chunk_validation() {
        let g = Generator::new(GeneratorKind::RatelessRlc, 6, 3, 4).unwrap();
        let mut enc = Encoder::new(g);
        let a = random_matrix(3, 2, 5);
        let pool = crate::runtime::pool::WorkPool::new(1);
        let mut coded = enc.encode_rows(&a, 0..6, &pool, 1).unwrap();
        let more = enc.encode_rows(&a, 6..9, &pool, 1).unwrap();
        // Before extension, chunking to 9 rows fails the n check.
        assert!(enc.rechunk(&coded, &[3, 3]).is_ok());
        enc.extend_to(9).unwrap();
        for r in 0..more.rows() {
            coded.push_row(more.row(r)).unwrap();
        }
        let chunks = enc.chunk(&coded, &[3, 3, 3]).unwrap();
        assert_eq!(chunks.len(), 3);
        assert_eq!(chunks[2].row_range, 6..9);
        assert_eq!(enc.re_encoded_rows(), 0, "extension mints fresh rows");
    }

    #[test]
    fn chunk_inner_products_match_direct_computation() {
        let g = Generator::new(GeneratorKind::SystematicRandom, 8, 4, 5).unwrap();
        let enc = Encoder::new(g.clone());
        let a = random_matrix(4, 5, 7);
        let x: Vec<f64> = (0..5).map(|i| i as f64 + 0.5).collect();
        let coded = enc.encode(&a).unwrap();
        let chunks = enc.chunk(&coded, &[2, 2, 2, 2]).unwrap();
        let full = coded.matvec(&x);
        for ch in &chunks {
            let y = ch.rows.matvec(&x);
            for (local, global) in ch.row_range.clone().enumerate() {
                assert!((y[local] - full[global]).abs() < 1e-12);
            }
        }
    }
}
