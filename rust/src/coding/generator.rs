//! MDS generator matrices over the reals.

use crate::coding::Matrix;
use crate::math::Rng;
use crate::{Error, Result};

/// Which generator construction to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GeneratorKind {
    /// Chebyshev-node Vandermonde: provably MDS, conditioning degrades
    /// exponentially in `k` (use for small `k`).
    Vandermonde,
    /// Systematic `[I_k; R]` with Gaussian `R`: MDS with probability 1,
    /// well-conditioned at practical `k`. The default.
    SystematicRandom,
}

/// An `(n, k)` generator matrix with construction metadata.
#[derive(Clone, Debug)]
pub struct Generator {
    kind: GeneratorKind,
    n: usize,
    k: usize,
    g: Matrix,
    /// Evaluation nodes (Vandermonde construction only) — lets the decoder
    /// use the O(k²) Björck–Pereyra solver instead of LU.
    nodes: Option<Vec<f64>>,
}

impl Generator {
    /// Build an `(n, k)` generator. `seed` only affects
    /// [`GeneratorKind::SystematicRandom`].
    pub fn new(kind: GeneratorKind, n: usize, k: usize, seed: u64) -> Result<Self> {
        if k == 0 || n < k {
            return Err(Error::InvalidSpec(format!(
                "generator needs n >= k >= 1, got n={n}, k={k}"
            )));
        }
        let (g, nodes) = match kind {
            GeneratorKind::Vandermonde => {
                // Distinct Chebyshev nodes on [-1, 1]: x_i = cos((2i+1)π/2n).
                let nodes: Vec<f64> = (0..n)
                    .map(|i| {
                        ((2 * i + 1) as f64 * std::f64::consts::PI / (2 * n) as f64).cos()
                    })
                    .collect();
                (
                    Matrix::from_fn(n, k, |i, j| nodes[i].powi(j as i32)),
                    Some(nodes),
                )
            }
            GeneratorKind::SystematicRandom => {
                let mut rng = Rng::new(seed);
                (
                    Matrix::from_fn(n, k, |i, j| {
                        if i < k {
                            if i == j {
                                1.0
                            } else {
                                0.0
                            }
                        } else {
                            rng.normal() / (k as f64).sqrt()
                        }
                    }),
                    None,
                )
            }
        };
        Ok(Generator { kind, n, k, g, nodes })
    }

    /// Evaluation nodes (Vandermonde construction only).
    pub fn nodes(&self) -> Option<&[f64]> {
        self.nodes.as_deref()
    }

    /// Code length `n`.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Code dimension `k`.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Construction kind.
    pub fn kind(&self) -> GeneratorKind {
        self.kind
    }

    /// Code rate `k/n`.
    pub fn rate(&self) -> f64 {
        self.k as f64 / self.n as f64
    }

    /// The full generator matrix `G ∈ R^{n×k}`.
    pub fn matrix(&self) -> &Matrix {
        &self.g
    }

    /// The `|B|×k` submatrix of `G` on rows `B` (decode system matrix).
    pub fn submatrix(&self, rows: &[usize]) -> Matrix {
        self.g.select_rows(rows)
    }

    /// Check the MDS property on a specific row set (diagnostic; O(k³)).
    pub fn rows_invertible(&self, rows: &[usize]) -> bool {
        if rows.len() != self.k {
            return false;
        }
        self.submatrix(rows).lu().is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vandermonde_any_k_rows_invertible() {
        let g = Generator::new(GeneratorKind::Vandermonde, 8, 4, 0).unwrap();
        // Exhaustively check all C(8,4)=70 row subsets.
        let idx: Vec<usize> = (0..8).collect();
        let mut count = 0;
        for a in 0..8 {
            for b in (a + 1)..8 {
                for c in (b + 1)..8 {
                    for d in (c + 1)..8 {
                        let rows = [idx[a], idx[b], idx[c], idx[d]];
                        assert!(g.rows_invertible(&rows), "rows {rows:?} singular");
                        count += 1;
                    }
                }
            }
        }
        assert_eq!(count, 70);
    }

    #[test]
    fn systematic_random_prefix_is_identity() {
        let g = Generator::new(GeneratorKind::SystematicRandom, 12, 5, 42).unwrap();
        for i in 0..5 {
            for j in 0..5 {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert_eq!(g.matrix()[(i, j)], expect);
            }
        }
    }

    #[test]
    fn systematic_random_mixed_rows_invertible() {
        let g = Generator::new(GeneratorKind::SystematicRandom, 20, 8, 7).unwrap();
        // A few mixed systematic/parity row subsets.
        for rows in [
            vec![0, 1, 2, 3, 4, 5, 6, 7],
            vec![12, 13, 14, 15, 16, 17, 18, 19],
            vec![0, 2, 4, 6, 9, 11, 13, 15],
            vec![7, 8, 10, 12, 14, 16, 18, 19],
        ] {
            assert!(g.rows_invertible(&rows), "rows {rows:?}");
        }
    }

    #[test]
    fn parameters_validated() {
        assert!(Generator::new(GeneratorKind::Vandermonde, 3, 5, 0).is_err());
        assert!(Generator::new(GeneratorKind::SystematicRandom, 3, 0, 0).is_err());
        let g = Generator::new(GeneratorKind::Vandermonde, 6, 3, 0).unwrap();
        assert_eq!(g.n(), 6);
        assert_eq!(g.k(), 3);
        assert!((g.rate() - 0.5).abs() < 1e-15);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = Generator::new(GeneratorKind::SystematicRandom, 10, 4, 9).unwrap();
        let b = Generator::new(GeneratorKind::SystematicRandom, 10, 4, 9).unwrap();
        assert_eq!(a.matrix(), b.matrix());
        let c = Generator::new(GeneratorKind::SystematicRandom, 10, 4, 10).unwrap();
        assert_ne!(a.matrix(), c.matrix());
    }
}
