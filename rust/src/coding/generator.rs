//! Generator matrices over the reals: two MDS families, an LDPC-style
//! sparse-parity family, and a rateless random-linear fountain family
//! whose row stream is infinite ([`GeneratorKind::RatelessRlc`]).

use crate::coding::{CsrMatrix, Matrix};
use crate::math::Rng;
use crate::{Error, Result};

/// Nonzeros per parity row of the [`GeneratorKind::SparseParity`]
/// construction (capped at `k`). Weight 8 keeps the encode O(nnz) = O(8·n)
/// while leaving random k-subsets overwhelmingly likely to be invertible
/// at serving-scale `k`.
const SPARSE_PARITY_WEIGHT: usize = 8;

/// Per-row stream separation constant for the rateless derivation
/// (the 64-bit golden ratio, as in
/// [`crate::coordinator::derive_stream_seed`]). Row `i` of a
/// [`GeneratorKind::RatelessRlc`] generator seeds its own [`Rng`] with
/// `seed ^ (i+1)·φ64`, so every row is a pure function of `(seed, i)` —
/// independent of how much of the stream has been materialized.
const RATELESS_ROW_TAG: u64 = 0x9e37_79b9_7f4a_7c15;

/// Which generator construction to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GeneratorKind {
    /// Chebyshev-node Vandermonde: provably MDS, conditioning degrades
    /// exponentially in `k` (use for small `k`).
    Vandermonde,
    /// Systematic `[I_k; R]` with Gaussian `R`: MDS with probability 1,
    /// well-conditioned at practical `k`. The default.
    SystematicRandom,
    /// Systematic `[I_k; S]` with **sparse** `S`: each parity row holds
    /// `min(k, 8)` entries of value `±1/√w` — the real-field analogue of an
    /// LDPC XOR parity (a signed, scaled sum of `w` data rows). The
    /// nonzeros are mirrored in a [`CsrMatrix`] ([`Generator::sparse`]), so
    /// the encode costs O(nnz·d) instead of O(n·k·d). **Not MDS**: a
    /// specific k-subset of rows can be structurally singular, in which
    /// case decode reports a clean error ([`Generator::rows_invertible`]
    /// returns `false`) rather than an answer.
    SparseParity,
    /// Rateless random-linear fountain code: an **infinite** row stream
    /// where row `i ∈ [0, ∞)` is `k` Gaussians scaled by `1/√k`, derived
    /// purely from `(seed, i)` — so the generator has no intrinsic `n`.
    /// The `n` passed to [`Generator::new`] is merely the materialized
    /// *prefix*; [`Generator::extend_to`] mints more rows without touching
    /// existing ones, and [`Generator::submatrix`] derives rows beyond the
    /// prefix on demand (decode never needs the horizon extended). Any
    /// k-subset of rows is invertible with probability 1. Non-systematic.
    RatelessRlc,
}

/// Coefficient row `i` of the rateless stream: `k` Gaussians scaled by
/// `1/√k`, from an [`Rng`] seeded by `(seed, i)` alone. This is the
/// single definition of the infinite generator — prefix materialization,
/// extension, and on-demand decode rows all call it, which is the whole
/// determinism argument: there is nothing else they *could* disagree on.
fn rateless_row(seed: u64, k: usize, i: usize) -> Vec<f64> {
    let mut rng =
        Rng::new(seed ^ (i as u64 + 1).wrapping_mul(RATELESS_ROW_TAG));
    let scale = 1.0 / (k as f64).sqrt();
    (0..k).map(|_| rng.normal() * scale).collect()
}

/// An `(n, k)` generator matrix with construction metadata.
#[derive(Clone, Debug)]
pub struct Generator {
    kind: GeneratorKind,
    n: usize,
    k: usize,
    /// Construction seed — retained so the rateless family can derive
    /// rows beyond the materialized prefix ([`Generator::extend_to`],
    /// on-demand [`Generator::submatrix`]). The finite families never
    /// read it after construction.
    seed: u64,
    g: Matrix,
    /// Evaluation nodes (Vandermonde construction only) — lets the decoder
    /// use the O(k²) Björck–Pereyra solver instead of LU.
    nodes: Option<Vec<f64>>,
    /// CSR mirror of `g` (sparse constructions only) — routes the encoder
    /// onto the O(nnz) sparse kernel ([`CsrMatrix::matmul_on`]).
    sparse: Option<CsrMatrix>,
}

impl Generator {
    /// Build an `(n, k)` generator. `seed` only affects the random
    /// families ([`GeneratorKind::SystematicRandom`],
    /// [`GeneratorKind::SparseParity`]).
    pub fn new(kind: GeneratorKind, n: usize, k: usize, seed: u64) -> Result<Self> {
        if k == 0 || n < k {
            return Err(Error::InvalidSpec(format!(
                "generator needs n >= k >= 1, got n={n}, k={k}"
            )));
        }
        let (g, nodes, sparse) = match kind {
            GeneratorKind::Vandermonde => {
                // Distinct Chebyshev nodes on [-1, 1]: x_i = cos((2i+1)π/2n).
                let nodes: Vec<f64> = (0..n)
                    .map(|i| {
                        ((2 * i + 1) as f64 * std::f64::consts::PI / (2 * n) as f64).cos()
                    })
                    .collect();
                (
                    Matrix::from_fn(n, k, |i, j| nodes[i].powi(j as i32)),
                    Some(nodes),
                    None,
                )
            }
            GeneratorKind::SystematicRandom => {
                let mut rng = Rng::new(seed);
                (
                    Matrix::from_fn(n, k, |i, j| {
                        if i < k {
                            if i == j {
                                1.0
                            } else {
                                0.0
                            }
                        } else {
                            rng.normal() / (k as f64).sqrt()
                        }
                    }),
                    None,
                    None,
                )
            }
            GeneratorKind::SparseParity => {
                let w = k.min(SPARSE_PARITY_WEIGHT);
                let scale = 1.0 / (w as f64).sqrt();
                let mut rng = Rng::new(seed);
                let mut g = Matrix::zeros(n, k);
                for i in 0..k {
                    g[(i, i)] = 1.0;
                }
                let mut cols: Vec<usize> = Vec::with_capacity(w);
                for i in k..n {
                    // Staircase guarantee: parity row i always touches data
                    // row (i - k) mod k, so every data row is covered as
                    // soon as n - k >= k; the remaining w - 1 columns are
                    // rejection-sampled distinct.
                    cols.clear();
                    cols.push((i - k) % k);
                    while cols.len() < w {
                        let c = rng.gen_range(k as u64) as usize;
                        if !cols.contains(&c) {
                            cols.push(c);
                        }
                    }
                    cols.sort_unstable();
                    for &c in &cols {
                        let sign = if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 };
                        g[(i, c)] = sign * scale;
                    }
                }
                let csr = CsrMatrix::from_dense(&g);
                (g, None, Some(csr))
            }
            GeneratorKind::RatelessRlc => {
                (Self::rateless_prefix(seed, n, k), None, None)
            }
        };
        Ok(Generator { kind, n, k, seed, g, nodes, sparse })
    }

    /// Materialize rateless rows `[0, n)` — each row derived independently
    /// by [`rateless_row`], so the prefix is byte-identical no matter how
    /// it was reached (one shot here or incremental
    /// [`Generator::extend_to`] calls).
    fn rateless_prefix(seed: u64, n: usize, k: usize) -> Matrix {
        let mut g = Matrix::zeros(n, k);
        for i in 0..n {
            let row = rateless_row(seed, k, i);
            for (j, v) in row.iter().enumerate() {
                g[(i, j)] = *v;
            }
        }
        g
    }

    /// Extend the materialized prefix of a rateless generator to
    /// `new_n` rows. Idempotent (`new_n <= n` is a no-op), and existing
    /// rows are never recomputed differently — every row is a pure
    /// function of `(seed, i)`, so the extended matrix is byte-identical
    /// to constructing at `new_n` directly (pinned by tests). Errors for
    /// the finite families, whose `n` is fixed at construction.
    pub fn extend_to(&mut self, new_n: usize) -> Result<()> {
        if self.kind != GeneratorKind::RatelessRlc {
            return Err(Error::InvalidSpec(format!(
                "extend_to is only defined for the rateless family, \
                 not {:?} (finite n fixed at construction)",
                self.kind
            )));
        }
        if new_n <= self.n {
            return Ok(());
        }
        let mut g = Matrix::zeros(new_n, self.k);
        for i in 0..self.n {
            for j in 0..self.k {
                g[(i, j)] = self.g[(i, j)];
            }
        }
        for i in self.n..new_n {
            let row = rateless_row(self.seed, self.k, i);
            for (j, v) in row.iter().enumerate() {
                g[(i, j)] = *v;
            }
        }
        self.g = g;
        self.n = new_n;
        Ok(())
    }

    /// CSR mirror of the generator (sparse constructions only) — the
    /// encoder dispatches through this onto the O(nnz) sparse kernel when
    /// present.
    pub fn sparse(&self) -> Option<&CsrMatrix> {
        self.sparse.as_ref()
    }

    /// Evaluation nodes (Vandermonde construction only).
    pub fn nodes(&self) -> Option<&[f64]> {
        self.nodes.as_deref()
    }

    /// Code length `n`.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Code dimension `k`.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Construction kind.
    pub fn kind(&self) -> GeneratorKind {
        self.kind
    }

    /// Code rate `k/n`.
    pub fn rate(&self) -> f64 {
        self.k as f64 / self.n as f64
    }

    /// The full generator matrix `G ∈ R^{n×k}`.
    pub fn matrix(&self) -> &Matrix {
        &self.g
    }

    /// The `|B|×k` submatrix of `G` on rows `B` (decode system matrix).
    ///
    /// For the rateless family, row indices beyond the materialized
    /// prefix are derived on demand from `(seed, i)` — byte-identical to
    /// what [`Generator::extend_to`] would materialize — so decode works
    /// for any row index the stream ever issued without the decoder's
    /// generator clone having to track the encoder's horizon.
    pub fn submatrix(&self, rows: &[usize]) -> Matrix {
        if self.kind == GeneratorKind::RatelessRlc
            && rows.iter().any(|&r| r >= self.n)
        {
            let mut m = Matrix::zeros(rows.len(), self.k);
            for (out, &r) in rows.iter().enumerate() {
                if r < self.n {
                    for j in 0..self.k {
                        m[(out, j)] = self.g[(r, j)];
                    }
                } else {
                    let row = rateless_row(self.seed, self.k, r);
                    for (j, v) in row.iter().enumerate() {
                        m[(out, j)] = *v;
                    }
                }
            }
            return m;
        }
        self.g.select_rows(rows)
    }

    /// Check the MDS property on a specific row set (diagnostic; O(k³)).
    pub fn rows_invertible(&self, rows: &[usize]) -> bool {
        if rows.len() != self.k {
            return false;
        }
        self.submatrix(rows).lu().is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vandermonde_any_k_rows_invertible() {
        let g = Generator::new(GeneratorKind::Vandermonde, 8, 4, 0).unwrap();
        // Exhaustively check all C(8,4)=70 row subsets.
        let idx: Vec<usize> = (0..8).collect();
        let mut count = 0;
        for a in 0..8 {
            for b in (a + 1)..8 {
                for c in (b + 1)..8 {
                    for d in (c + 1)..8 {
                        let rows = [idx[a], idx[b], idx[c], idx[d]];
                        assert!(g.rows_invertible(&rows), "rows {rows:?} singular");
                        count += 1;
                    }
                }
            }
        }
        assert_eq!(count, 70);
    }

    #[test]
    fn systematic_random_prefix_is_identity() {
        let g = Generator::new(GeneratorKind::SystematicRandom, 12, 5, 42).unwrap();
        for i in 0..5 {
            for j in 0..5 {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert_eq!(g.matrix()[(i, j)], expect);
            }
        }
    }

    #[test]
    fn systematic_random_mixed_rows_invertible() {
        let g = Generator::new(GeneratorKind::SystematicRandom, 20, 8, 7).unwrap();
        // A few mixed systematic/parity row subsets.
        for rows in [
            vec![0, 1, 2, 3, 4, 5, 6, 7],
            vec![12, 13, 14, 15, 16, 17, 18, 19],
            vec![0, 2, 4, 6, 9, 11, 13, 15],
            vec![7, 8, 10, 12, 14, 16, 18, 19],
        ] {
            assert!(g.rows_invertible(&rows), "rows {rows:?}");
        }
    }

    #[test]
    fn parameters_validated() {
        assert!(Generator::new(GeneratorKind::Vandermonde, 3, 5, 0).is_err());
        assert!(Generator::new(GeneratorKind::SystematicRandom, 3, 0, 0).is_err());
        let g = Generator::new(GeneratorKind::Vandermonde, 6, 3, 0).unwrap();
        assert_eq!(g.n(), 6);
        assert_eq!(g.k(), 3);
        assert!((g.rate() - 0.5).abs() < 1e-15);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = Generator::new(GeneratorKind::SystematicRandom, 10, 4, 9).unwrap();
        let b = Generator::new(GeneratorKind::SystematicRandom, 10, 4, 9).unwrap();
        assert_eq!(a.matrix(), b.matrix());
        let c = Generator::new(GeneratorKind::SystematicRandom, 10, 4, 10).unwrap();
        assert_ne!(a.matrix(), c.matrix());
        let s1 = Generator::new(GeneratorKind::SparseParity, 10, 4, 9).unwrap();
        let s2 = Generator::new(GeneratorKind::SparseParity, 10, 4, 9).unwrap();
        assert_eq!(s1.matrix(), s2.matrix());
        assert_eq!(s1.sparse(), s2.sparse());
    }

    #[test]
    fn sparse_parity_structure() {
        let (n, k) = (48, 32);
        let g = Generator::new(GeneratorKind::SparseParity, n, k, 11).unwrap();
        // Dense-only families expose no CSR mirror.
        assert!(Generator::new(GeneratorKind::Vandermonde, 8, 4, 0)
            .unwrap()
            .sparse()
            .is_none());
        assert!(Generator::new(GeneratorKind::SystematicRandom, 8, 4, 0)
            .unwrap()
            .sparse()
            .is_none());
        let csr = g.sparse().expect("sparse family carries a CSR mirror");
        // The mirror is exactly the dense matrix, compressed.
        assert_eq!(&csr.to_dense(), g.matrix());
        // Systematic prefix: identity rows of weight 1.
        for i in 0..k {
            let (cols, vals) = csr.row_entries(i);
            assert_eq!(cols, &[i]);
            assert_eq!(vals, &[1.0]);
        }
        // Parity rows: weight min(k, 8), entries ±1/√w, staircase column
        // (i − k) mod k always present.
        let w = k.min(8);
        let scale = 1.0 / (w as f64).sqrt();
        for i in k..n {
            let (cols, vals) = csr.row_entries(i);
            assert_eq!(cols.len(), w, "parity row {i}");
            assert!(cols.contains(&((i - k) % k)), "parity row {i} staircase");
            assert!(cols.windows(2).all(|p| p[0] < p[1]), "parity row {i} order");
            assert!(
                vals.iter().all(|v| (v.abs() - scale).abs() < 1e-15),
                "parity row {i} magnitudes"
            );
        }
        // nnz = k identity entries + w per parity row.
        assert_eq!(csr.nnz(), k + (n - k) * w);
        // Weight caps at k when k < 8.
        let tiny = Generator::new(GeneratorKind::SparseParity, 7, 3, 5).unwrap();
        let (cols, _) = tiny.sparse().unwrap().row_entries(5);
        assert_eq!(cols, &[0, 1, 2]);
    }

    #[test]
    fn rateless_extension_is_byte_identical_to_direct_construction() {
        // Rows are pure functions of (seed, i): growing 8 → 20 in two
        // extends must reproduce, bit for bit, the generator built at 20
        // directly — and never perturb the rows that already existed.
        let direct = Generator::new(GeneratorKind::RatelessRlc, 20, 5, 77).unwrap();
        let mut grown = Generator::new(GeneratorKind::RatelessRlc, 8, 5, 77).unwrap();
        let prefix_bits: Vec<u64> =
            grown.matrix().data().iter().map(|v| v.to_bits()).collect();
        grown.extend_to(13).unwrap();
        grown.extend_to(20).unwrap();
        assert_eq!(grown.n(), 20);
        assert_eq!(grown.matrix(), direct.matrix());
        assert!(grown
            .matrix()
            .data()
            .iter()
            .take(prefix_bits.len())
            .map(|v| v.to_bits())
            .eq(prefix_bits.iter().copied()));
        // Idempotent: shrinking requests are no-ops.
        grown.extend_to(4).unwrap();
        assert_eq!(grown.n(), 20);
    }

    #[test]
    fn rateless_submatrix_derives_rows_beyond_the_prefix() {
        // The decoder's generator clone may lag the encoder's horizon:
        // submatrix must derive out-of-prefix rows on demand, equal to
        // what extension would materialize.
        let g = Generator::new(GeneratorKind::RatelessRlc, 6, 4, 3).unwrap();
        let rows = [1usize, 5, 9, 40];
        let sub = g.submatrix(&rows);
        let mut big = g.clone();
        big.extend_to(41).unwrap();
        assert_eq!(sub, big.submatrix(&rows));
        assert!(g.rows_invertible(&rows), "any k-subset invertible w.p. 1");
        assert!(!g.rows_invertible(&rows[..3]), "sub-k honest");
    }

    #[test]
    fn rateless_rows_deterministic_and_extend_rejected_for_finite_kinds() {
        let a = Generator::new(GeneratorKind::RatelessRlc, 10, 4, 9).unwrap();
        let b = Generator::new(GeneratorKind::RatelessRlc, 10, 4, 9).unwrap();
        assert_eq!(a.matrix(), b.matrix());
        let c = Generator::new(GeneratorKind::RatelessRlc, 10, 4, 10).unwrap();
        assert_ne!(a.matrix(), c.matrix());
        assert!(a.sparse().is_none());
        assert!(a.nodes().is_none());
        for kind in [
            GeneratorKind::Vandermonde,
            GeneratorKind::SystematicRandom,
            GeneratorKind::SparseParity,
        ] {
            let mut g = Generator::new(kind, 10, 4, 1).unwrap();
            assert!(g.extend_to(12).is_err(), "{kind:?} must not extend");
        }
    }

    #[test]
    fn sparse_parity_systematic_subset_decodes() {
        // The k systematic rows are the identity — always invertible — and
        // rows_invertible is honest about sub/super-sized subsets.
        let g = Generator::new(GeneratorKind::SparseParity, 20, 8, 3).unwrap();
        let systematic: Vec<usize> = (0..8).collect();
        assert!(g.rows_invertible(&systematic));
        assert!(!g.rows_invertible(&systematic[..7]));
    }
}
