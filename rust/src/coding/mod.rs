//! Real-valued MDS coding over the rows of the data matrix.
//!
//! The paper applies an `(n, k)` MDS code to the rows of `A ∈ R^{k×d}`:
//! `Ã = G·A` with `G ∈ R^{n×k}` such that any `k` rows of `G` are linearly
//! independent. The master recovers `A·x` from any `k` coded inner products
//! by solving `G_B · z = y_B`.
//!
//! Four generator families are provided:
//!
//! - [`GeneratorKind::Vandermonde`]: rows `[1, x_i, …, x_i^{k-1}]` on distinct
//!   Chebyshev nodes — *provably* MDS over the reals, but the decode system's
//!   conditioning degrades exponentially in `k` (fine for `k ≲ 24`).
//! - [`GeneratorKind::SystematicRandom`]: `G = [I_k; R]` with Gaussian `R` —
//!   MDS with probability 1 and well-conditioned at practical `k` (the
//!   default; this is what the live coordinator uses).
//! - [`GeneratorKind::SparseParity`]: `G = [I_k; S]` with sparse `±1/√w`
//!   parity rows — the LDPC-style analogue; *not* MDS, but encodes in
//!   O(nnz·d) through the CSR kernel instead of dense FLOPs.
//! - [`GeneratorKind::RatelessRlc`]: a rateless random-linear fountain —
//!   an *infinite* row stream where row `i` derives purely from
//!   `(seed, i)`, so `n` is just a materialized prefix that
//!   [`Generator::extend_to`] grows without re-encoding ([`rateless`]).
//!
//! Codes are pluggable: the [`code::Code`] trait bundles generator
//! construction, encode, and decode behind one object, and the registry in
//! [`code`] (mirroring the policy registry) maps CLI names — `mds-random`,
//! `mds-vandermonde`, `sparse-parity`, `rateless-rlc` — to
//! implementations.
//!
//! The dense linear algebra (LU with partial pivoting, matmul, matvec) is
//! implemented in [`linalg`] from scratch, alongside the [`CsrMatrix`]
//! sparse type and its pool-parallel SpMM kernel.

#![forbid(unsafe_code)]

pub mod bjorck_pereyra;
pub mod code;
pub mod decoder;
pub mod encoder;
pub mod generator;
pub mod linalg;
pub mod rateless;

pub use bjorck_pereyra::VandermondeFactor;
pub use code::{Code, CodeEntry, MdsCode, SparseParityCode};
pub use rateless::RatelessCode;
pub use decoder::{Decoder, DEFAULT_FACTOR_CACHE};
pub use encoder::Encoder;
pub use generator::{Generator, GeneratorKind};
pub use linalg::{CsrMatrix, Lu, Matrix};
