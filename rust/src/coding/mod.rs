//! Real-valued MDS coding over the rows of the data matrix.
//!
//! The paper applies an `(n, k)` MDS code to the rows of `A ∈ R^{k×d}`:
//! `Ã = G·A` with `G ∈ R^{n×k}` such that any `k` rows of `G` are linearly
//! independent. The master recovers `A·x` from any `k` coded inner products
//! by solving `G_B · z = y_B`.
//!
//! Three generator families are provided:
//!
//! - [`GeneratorKind::Vandermonde`]: rows `[1, x_i, …, x_i^{k-1}]` on distinct
//!   Chebyshev nodes — *provably* MDS over the reals, but the decode system's
//!   conditioning degrades exponentially in `k` (fine for `k ≲ 24`).
//! - [`GeneratorKind::SystematicRandom`]: `G = [I_k; R]` with Gaussian `R` —
//!   MDS with probability 1 and well-conditioned at practical `k` (the
//!   default; this is what the live coordinator uses).
//! - [`GeneratorKind::SparseParity`]: `G = [I_k; S]` with sparse `±1/√w`
//!   parity rows — the LDPC-style analogue; *not* MDS, but encodes in
//!   O(nnz·d) through the CSR kernel instead of dense FLOPs.
//!
//! Codes are pluggable: the [`code::Code`] trait bundles generator
//! construction, encode, and decode behind one object, and the registry in
//! [`code`] (mirroring the policy registry) maps CLI names — `mds-random`,
//! `mds-vandermonde`, `sparse-parity` — to implementations.
//!
//! The dense linear algebra (LU with partial pivoting, matmul, matvec) is
//! implemented in [`linalg`] from scratch, alongside the [`CsrMatrix`]
//! sparse type and its pool-parallel SpMM kernel.

#![forbid(unsafe_code)]

pub mod bjorck_pereyra;
pub mod code;
pub mod decoder;
pub mod encoder;
pub mod generator;
pub mod linalg;

pub use bjorck_pereyra::VandermondeFactor;
pub use code::{Code, CodeEntry, MdsCode, SparseParityCode};
pub use decoder::{Decoder, DEFAULT_FACTOR_CACHE};
pub use encoder::Encoder;
pub use generator::{Generator, GeneratorKind};
pub use linalg::{CsrMatrix, Lu, Matrix};
