//! The pluggable [`Code`] trait and the central code **registry** — the
//! single source of truth for erasure-code names, mirroring
//! [`crate::allocation::policy`].
//!
//! The paper fixes one `(n, k)` MDS code; the serving stack does not need
//! to. A [`Code`] bundles the four decisions that vary between codes —
//! how the generator is constructed ([`Code::setup`] /
//! [`Code::generator`]), how `Ã = G·A` is computed ([`Code::encode`]),
//! and how request columns are recovered from an aggregated row set
//! ([`Code::decode_rows`]) — while everything else (load allocation,
//! chunking, straggle handling, the re-allocation `rechunk` path) is
//! code-agnostic and flows through unchanged. The coordinator resolves a
//! code once per job ([`crate::coordinator::JobConfig::resolve_code`])
//! and routes every setup/encode/decode through it; the default method
//! bodies delegate to the existing [`Encoder`]/[`Decoder`] machinery, so
//! the call chain for the dense MDS codes is **identical** to the
//! pre-trait code path — bit-identity across the refactor is pinned by
//! `rust/tests/code_golden.rs`, and the any-k contract for every registry
//! entry by `rust/tests/code_roundtrip.rs`.
//!
//! # Example
//!
//! ```
//! use hetcoded::coding::code::{self, Code};
//!
//! let code = code::resolve("sparse-parity")?;
//! let gen = code.setup(12, 4, 7)?;
//! assert!(gen.sparse().is_some()); // encodes through the CSR kernel
//! let names = code::code_names();
//! assert!(names.contains(&"mds-random"));
//! # Ok::<(), hetcoded::Error>(())
//! ```

use crate::coding::{Decoder, Encoder, Generator, GeneratorKind, Matrix};
use crate::runtime::pool::WorkPool;
use crate::{Error, Result};

/// One erasure code: generator construction + encode kernel + decode
/// path. Implementations are cheap value objects; the registry hands them
/// out as `Box<dyn Code>`.
///
/// The default `setup`/`encode`/`decode_rows` bodies route through the
/// shared [`Generator`]/[`Encoder`]/[`Decoder`] machinery, which keeps
/// the measured serving invariants (encode-call counter, factorization
/// cache, allocation-free decode staging) uniform across codes — a new
/// code only overrides what it actually does differently.
pub trait Code: Send + Sync + std::fmt::Debug {
    /// Registry-facing name (the `--code` spelling).
    fn name(&self) -> &'static str;

    /// The generator-construction family [`Code::setup`] builds.
    fn generator(&self) -> GeneratorKind;

    /// Build the `(n, k)` generator for this code. `seed` fixes the
    /// random families; the call chain is exactly [`Generator::new`], so
    /// a code resolved from a [`GeneratorKind`] reproduces the pre-trait
    /// generator bit for bit.
    fn setup(&self, n: usize, k: usize, seed: u64) -> Result<Generator> {
        Generator::new(self.generator(), n, k, seed)
    }

    /// Encode `Ã = G·A` on `pool` with the task split capped at
    /// `max_streams`. The default delegates to
    /// [`Encoder::encode_capped`], which dispatches dense generators onto
    /// the register-blocked dense kernel and sparse generators onto the
    /// O(nnz·d) CSR kernel — and counts the call, so the
    /// `encodes == 1` serving invariant stays measured for every code.
    fn encode(
        &self,
        encoder: &Encoder,
        a: &Matrix,
        pool: &WorkPool,
        max_streams: usize,
    ) -> Result<Matrix> {
        encoder.encode_capped(a, pool, max_streams)
    }

    /// Encode only the coded rows in `range` — the extend-`n` surface the
    /// rateless family added to the trait. The default delegates to
    /// [`Encoder::encode_rows`], which derives out-of-prefix coefficient
    /// rows on demand for the rateless family (split-invariant: many
    /// range calls are byte-identical to one) and bounds finite families
    /// to their fixed `[0, n)`. Row-granular work is accounted by
    /// [`Encoder::rows_encoded`] / [`Encoder::re_encoded_rows`], never by
    /// the full-call counter.
    fn encode_rows(
        &self,
        encoder: &Encoder,
        a: &Matrix,
        range: std::ops::Range<usize>,
        pool: &WorkPool,
        max_streams: usize,
    ) -> Result<Matrix> {
        encoder.encode_rows(a, range, pool, max_streams)
    }

    /// Recover every request column from the aggregated coded rows
    /// (`rows` are global coded-row indices; `columns[c]` holds request
    /// `c`'s inner products at those rows). The default delegates to
    /// [`Decoder::decode_batch`] — the factorization-cached any-k path.
    /// Non-MDS codes surface structurally singular row sets as a clean
    /// `Err`, never a wrong answer or a hang.
    fn decode_rows(
        &self,
        decoder: &mut Decoder,
        rows: &[usize],
        columns: &[Vec<f64>],
    ) -> Result<Vec<Vec<f64>>> {
        decoder.decode_batch(rows, columns)
    }
}

/// The paper's dense MDS codes behind the [`Code`] trait: one value per
/// generator family, differing only in [`Code::generator`].
#[derive(Clone, Copy, Debug)]
pub struct MdsCode {
    kind: GeneratorKind,
    name: &'static str,
}

impl MdsCode {
    /// Systematic `[I_k; R]` with Gaussian `R` — the crate default
    /// ([`GeneratorKind::SystematicRandom`]).
    pub fn random() -> MdsCode {
        MdsCode { kind: GeneratorKind::SystematicRandom, name: "mds-random" }
    }

    /// Chebyshev-node Vandermonde with the O(k²) Björck–Pereyra decode
    /// ([`GeneratorKind::Vandermonde`]).
    pub fn vandermonde() -> MdsCode {
        MdsCode { kind: GeneratorKind::Vandermonde, name: "mds-vandermonde" }
    }
}

impl Code for MdsCode {
    fn name(&self) -> &'static str {
        self.name
    }

    fn generator(&self) -> GeneratorKind {
        self.kind
    }
}

/// The LDPC-style sparse code ([`GeneratorKind::SparseParity`]): weight-8
/// `±1/√w` parity rows, encoded through the CSR kernel in O(nnz·d).
/// **Not MDS** — a specific k-subset of rows can be structurally
/// singular, in which case decode returns a clean error.
#[derive(Clone, Copy, Debug, Default)]
pub struct SparseParityCode;

impl Code for SparseParityCode {
    fn name(&self) -> &'static str {
        "sparse-parity"
    }

    fn generator(&self) -> GeneratorKind {
        GeneratorKind::SparseParity
    }
}

/// The [`Code`] for a bare [`GeneratorKind`] — how configs that predate
/// the registry (`JobConfig::generator`) resolve to a code without
/// changing behaviour.
pub fn for_kind(kind: GeneratorKind) -> Box<dyn Code> {
    match kind {
        GeneratorKind::SystematicRandom => Box::new(MdsCode::random()),
        GeneratorKind::Vandermonde => Box::new(MdsCode::vandermonde()),
        GeneratorKind::SparseParity => Box::new(SparseParityCode),
        GeneratorKind::RatelessRlc => {
            Box::new(crate::coding::rateless::RatelessCode)
        }
    }
}

/// One registry row: the CLI-facing name, a summary for `help`, and the
/// constructor.
pub struct CodeEntry {
    /// CLI-facing code name (`--code`).
    pub name: &'static str,
    /// One-line description for help output.
    pub summary: &'static str,
    builder: fn() -> Box<dyn Code>,
}

impl CodeEntry {
    /// Build the code.
    pub fn build(&self) -> Box<dyn Code> {
        (self.builder)()
    }
}

impl std::fmt::Debug for CodeEntry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CodeEntry").field("name", &self.name).finish()
    }
}

/// The registry itself. **This slice is the single source of truth for
/// code names**: the CLI `--code` flag, `SessionBuilder::code`, and the
/// test suites resolve through it. Adding a code = implementing [`Code`]
/// and appending one entry here.
pub static REGISTRY: &[CodeEntry] = &[
    CodeEntry {
        name: "mds-random",
        summary: "systematic (n,k) MDS, Gaussian parity rows (default)",
        builder: || Box::new(MdsCode::random()),
    },
    CodeEntry {
        name: "mds-vandermonde",
        summary: "Chebyshev-node Vandermonde MDS, O(k²) decode (small k)",
        builder: || Box::new(MdsCode::vandermonde()),
    },
    CodeEntry {
        name: "sparse-parity",
        summary: "LDPC-style weight-8 sparse parity, O(nnz) encode (not MDS)",
        builder: || Box::new(SparseParityCode),
    },
    CodeEntry {
        name: "rateless-rlc",
        summary: "rateless random-linear fountain, infinite row stream \
                  (stream until any-k)",
        builder: || Box::new(crate::coding::rateless::RatelessCode),
    },
];

/// All registry rows, in display order.
pub fn entries() -> &'static [CodeEntry] {
    REGISTRY
}

/// Look up one registry row by CLI name.
pub fn entry(name: &str) -> Option<&'static CodeEntry> {
    REGISTRY.iter().find(|e| e.name == name)
}

/// Every registered CLI code name, in display order.
pub fn code_names() -> Vec<&'static str> {
    REGISTRY.iter().map(|e| e.name).collect()
}

/// Resolve a code by registry name. Unknown names list the registry.
pub fn resolve(name: &str) -> Result<Box<dyn Code>> {
    let e = entry(name.trim()).ok_or_else(|| unknown_code(name.trim()))?;
    Ok(e.build())
}

/// The error for an unresolvable code name, listing what the registry
/// does know.
pub fn unknown_code(name: &str) -> Error {
    Error::InvalidSpec(format!(
        "unknown code `{name}` (known: {})",
        code_names().join(", ")
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::Rng;

    #[test]
    fn registry_names_are_unique_and_resolve() {
        let names = code_names();
        for (i, n) in names.iter().enumerate() {
            assert!(!names[i + 1..].contains(n), "duplicate code name `{n}`");
            let c = resolve(n).unwrap_or_else(|e| panic!("{n}: {e}"));
            assert_eq!(c.name(), *n, "registry name and Code::name diverge");
        }
        assert!(resolve("no-such-code").is_err());
        let msg = format!("{}", unknown_code("x"));
        for n in names {
            assert!(msg.contains(n), "unknown-code error must list `{n}`");
        }
    }

    #[test]
    fn for_kind_covers_every_generator_family() {
        for (kind, name) in [
            (GeneratorKind::SystematicRandom, "mds-random"),
            (GeneratorKind::Vandermonde, "mds-vandermonde"),
            (GeneratorKind::SparseParity, "sparse-parity"),
            (GeneratorKind::RatelessRlc, "rateless-rlc"),
        ] {
            let c = for_kind(kind);
            assert_eq!(c.name(), name);
            assert_eq!(c.generator(), kind);
        }
    }

    #[test]
    fn default_methods_roundtrip_through_the_shared_machinery() {
        let mut rng = Rng::new(17);
        let (n, k, d) = (12usize, 5usize, 4usize);
        let a = Matrix::from_fn(k, d, |_, _| rng.normal());
        let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let truth = a.matvec(&x);
        for e in entries() {
            let code = e.build();
            let gen = code.setup(n, k, 23).unwrap();
            let encoder = Encoder::new(gen.clone());
            let coded = code
                .encode(&encoder, &a, WorkPool::global_ref(), 1)
                .unwrap();
            assert_eq!(encoder.encode_calls(), 1, "{}", e.name);
            let y = coded.matvec(&x);
            // Decode from the first k rows (systematic for the systematic
            // families, invertible Vandermonde rows otherwise).
            let rows: Vec<usize> = (0..k).collect();
            let col: Vec<f64> = rows.iter().map(|&r| y[r]).collect();
            let mut decoder = Decoder::new(gen);
            let decoded =
                code.decode_rows(&mut decoder, &rows, &[col]).unwrap();
            for (got, want) in decoded[0].iter().zip(&truth) {
                assert!(
                    (got - want).abs() < 1e-8,
                    "{}: decode error {got} vs {want}",
                    e.name
                );
            }
            // Sub-k row sets fail cleanly.
            let short: Vec<usize> = (0..k - 1).collect();
            let short_col: Vec<f64> = short.iter().map(|&r| y[r]).collect();
            assert!(
                code.decode_rows(&mut decoder, &short, &[short_col]).is_err(),
                "{}: sub-k decode must error",
                e.name
            );
        }
    }
}
