//! Björck–Pereyra solver for Vandermonde systems.
//!
//! Solves the **primal** Vandermonde system `V·a = f` where
//! `V[i][j] = x_i^j` with distinct nodes, in O(k²) time and — crucially —
//! with far better accuracy than generic LU on the same (exponentially
//! ill-conditioned) matrix, because it works on the Newton form of the
//! interpolation problem instead of the monomial matrix.
//!
//! Used by the decoder when the generator is [`super::GeneratorKind::Vandermonde`]:
//! a decode from rows `B` is exactly polynomial interpolation on nodes
//! `{x_i : i ∈ B}` (`a` = coefficient vector such that `p(x_i) = f_i`,
//! `z = a` recovers `A·x` coordinates). Reference: Björck & Pereyra,
//! "Solution of Vandermonde systems of equations", Math. Comp. 24 (1970).

use crate::{Error, Result};

/// Solve `V a = f` for `V[i][j] = nodes[i]^j` (square, distinct nodes).
pub fn solve_vandermonde(nodes: &[f64], f: &[f64]) -> Result<Vec<f64>> {
    let n = nodes.len();
    if f.len() != n {
        return Err(Error::Numerical("rhs length mismatch".into()));
    }
    if n == 0 {
        return Ok(vec![]);
    }
    // Distinctness guard (the MDS property requires it).
    for i in 0..n {
        for j in (i + 1)..n {
            if (nodes[i] - nodes[j]).abs() < 1e-14 {
                return Err(Error::Numerical(format!(
                    "nodes {i} and {j} coincide ({})",
                    nodes[i]
                )));
            }
        }
    }
    let mut a = f.to_vec();
    // Stage 1: divided differences (Newton coefficients).
    for level in 1..n {
        for i in (level..n).rev() {
            a[i] = (a[i] - a[i - 1]) / (nodes[i] - nodes[i - level]);
        }
    }
    // Stage 2: expand Newton form into monomial coefficients.
    for level in (0..n - 1).rev() {
        for i in level..n - 1 {
            let t = a[i + 1] * nodes[level];
            a[i] -= t;
        }
    }
    Ok(a)
}

/// Evaluate `p(x) = Σ a_j x^j` (Horner) — used by tests to verify residuals.
pub fn eval_poly(a: &[f64], x: f64) -> f64 {
    let mut acc = 0.0;
    for &c in a.iter().rev() {
        acc = acc * x + c;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::Matrix;
    use crate::math::Rng;

    fn chebyshev_nodes(n: usize) -> Vec<f64> {
        (0..n)
            .map(|i| ((2 * i + 1) as f64 * std::f64::consts::PI / (2 * n) as f64).cos())
            .collect()
    }

    #[test]
    fn solves_small_system_exactly() {
        // p(x) = 1 + 2x + 3x²; nodes 0, 1, 2 → f = 1, 6, 17.
        let a = solve_vandermonde(&[0.0, 1.0, 2.0], &[1.0, 6.0, 17.0]).unwrap();
        assert!((a[0] - 1.0).abs() < 1e-12);
        assert!((a[1] - 2.0).abs() < 1e-12);
        assert!((a[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn residuals_stay_small_where_lu_fails() {
        // At k=24 Chebyshev-node Vandermonde LU produces O(10) errors (see
        // the ablation bench); Björck–Pereyra keeps the residual tiny.
        let mut rng = Rng::new(5);
        for k in [8usize, 16, 24, 32] {
            let nodes = chebyshev_nodes(k);
            let coeffs: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
            let f: Vec<f64> = nodes.iter().map(|&x| eval_poly(&coeffs, x)).collect();
            let a = solve_vandermonde(&nodes, &f).unwrap();
            let worst = a
                .iter()
                .zip(&coeffs)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f64, f64::max);
            assert!(worst < 1e-6 * (1 << (k / 8)) as f64, "k={k}: err {worst}");
        }
    }

    #[test]
    fn beats_lu_on_vandermonde_k24() {
        let k = 24;
        let nodes = chebyshev_nodes(k);
        let mut rng = Rng::new(7);
        let coeffs: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
        let f: Vec<f64> = nodes.iter().map(|&x| eval_poly(&coeffs, x)).collect();
        // LU path.
        let v = Matrix::from_fn(k, k, |i, j| nodes[i].powi(j as i32));
        let lu_err = match v.solve(&f) {
            Ok(sol) => sol
                .iter()
                .zip(&coeffs)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f64, f64::max),
            Err(_) => f64::INFINITY,
        };
        // BP path.
        let bp = solve_vandermonde(&nodes, &f).unwrap();
        let bp_err = bp
            .iter()
            .zip(&coeffs)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f64, f64::max);
        // On a forward-generated (bounded-coefficient) system LU is not
        // catastrophic; BP must still be at least as accurate, and tiny.
        assert!(bp_err <= lu_err * 1.5, "BP err {bp_err} vs LU err {lu_err}");
        assert!(bp_err < 1e-7, "BP err {bp_err}");
    }

    #[test]
    fn rejects_coincident_nodes_and_bad_rhs() {
        assert!(solve_vandermonde(&[1.0, 1.0], &[0.0, 0.0]).is_err());
        assert!(solve_vandermonde(&[1.0, 2.0], &[0.0]).is_err());
        assert!(solve_vandermonde(&[], &[]).unwrap().is_empty());
    }
}
