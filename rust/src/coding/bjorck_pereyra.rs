//! Björck–Pereyra solver for Vandermonde systems.
//!
//! Solves the **primal** Vandermonde system `V·a = f` where
//! `V[i][j] = x_i^j` with distinct nodes, in O(k²) time and — crucially —
//! with far better accuracy than generic LU on the same (exponentially
//! ill-conditioned) matrix, because it works on the Newton form of the
//! interpolation problem instead of the monomial matrix.
//!
//! Used by the decoder when the generator is [`super::GeneratorKind::Vandermonde`]:
//! a decode from rows `B` is exactly polynomial interpolation on nodes
//! `{x_i : i ∈ B}` (`a` = coefficient vector such that `p(x_i) = f_i`,
//! `z = a` recovers `A·x` coordinates). Reference: Björck & Pereyra,
//! "Solution of Vandermonde systems of equations", Math. Comp. 24 (1970).

use crate::{Error, Result};

/// Solve `V a = f` for `V[i][j] = nodes[i]^j` (square, distinct nodes).
///
/// One-shot convenience over [`VandermondeFactor`]; callers solving many
/// RHS on the same node set should build the factor once instead.
pub fn solve_vandermonde(nodes: &[f64], f: &[f64]) -> Result<Vec<f64>> {
    VandermondeFactor::new(nodes)?.solve(f)
}

/// Precomputed Björck–Pereyra "factorization" of a Vandermonde system on a
/// fixed node set.
///
/// Stage 1 of BP divides each divided difference by a node difference
/// `x_i − x_{i−level}` that depends only on the nodes, not the RHS. This
/// type inverts all `n(n−1)/2` of them once, so every subsequent solve is
/// pure multiply-adds — the per-RHS critical path of a decode on a repeated
/// straggler pattern. This is what the decoder's factorization cache stores
/// for Vandermonde generators.
#[derive(Clone, Debug)]
pub struct VandermondeFactor {
    nodes: Vec<f64>,
    /// `1 / (x_i − x_{i−level})`, flattened over `level = 1..n`, `i = level..n`.
    inv: Vec<f64>,
}

impl VandermondeFactor {
    /// Validate node distinctness and precompute the reciprocals.
    pub fn new(nodes: &[f64]) -> Result<Self> {
        let n = nodes.len();
        // Distinctness guard (the MDS property requires it).
        for i in 0..n {
            for j in (i + 1)..n {
                if (nodes[i] - nodes[j]).abs() < 1e-14 {
                    return Err(Error::Numerical(format!(
                        "nodes {i} and {j} coincide ({})",
                        nodes[i]
                    )));
                }
            }
        }
        let mut inv = Vec::with_capacity(n * n.saturating_sub(1) / 2);
        for level in 1..n {
            for i in level..n {
                inv.push(1.0 / (nodes[i] - nodes[i - level]));
            }
        }
        Ok(VandermondeFactor { nodes: nodes.to_vec(), inv })
    }

    /// System size `n`.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// True for the degenerate 0×0 system.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Solve in place: `a` enters as the RHS `f`, leaves as the monomial
    /// coefficients.
    pub fn solve_into(&self, a: &mut [f64]) -> Result<()> {
        let n = self.nodes.len();
        if a.len() != n {
            return Err(Error::Numerical("rhs length mismatch".into()));
        }
        if n == 0 {
            return Ok(());
        }
        // Stage 1: divided differences (Newton coefficients).
        let mut off = 0usize;
        for level in 1..n {
            let lvl_inv = &self.inv[off..off + (n - level)];
            for i in (level..n).rev() {
                a[i] = (a[i] - a[i - 1]) * lvl_inv[i - level];
            }
            off += n - level;
        }
        // Stage 2: expand Newton form into monomial coefficients.
        for level in (0..n - 1).rev() {
            for i in level..n - 1 {
                let t = a[i + 1] * self.nodes[level];
                a[i] -= t;
            }
        }
        Ok(())
    }

    /// Solve a single RHS.
    pub fn solve(&self, f: &[f64]) -> Result<Vec<f64>> {
        let mut a = f.to_vec();
        self.solve_into(&mut a)?;
        Ok(a)
    }

    /// Solve a batch of RHS vectors on the same node set (multi-RHS
    /// decode). Each output equals [`VandermondeFactor::solve`] of the
    /// corresponding input.
    pub fn solve_multi(&self, fs: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
        fs.iter().map(|f| self.solve(f)).collect()
    }
}

/// Evaluate `p(x) = Σ a_j x^j` (Horner) — used by tests to verify residuals.
pub fn eval_poly(a: &[f64], x: f64) -> f64 {
    let mut acc = 0.0;
    for &c in a.iter().rev() {
        acc = acc * x + c;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::Matrix;
    use crate::math::Rng;

    fn chebyshev_nodes(n: usize) -> Vec<f64> {
        (0..n)
            .map(|i| ((2 * i + 1) as f64 * std::f64::consts::PI / (2 * n) as f64).cos())
            .collect()
    }

    #[test]
    fn solves_small_system_exactly() {
        // p(x) = 1 + 2x + 3x²; nodes 0, 1, 2 → f = 1, 6, 17.
        let a = solve_vandermonde(&[0.0, 1.0, 2.0], &[1.0, 6.0, 17.0]).unwrap();
        assert!((a[0] - 1.0).abs() < 1e-12);
        assert!((a[1] - 2.0).abs() < 1e-12);
        assert!((a[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn residuals_stay_small_where_lu_fails() {
        // At k=24 Chebyshev-node Vandermonde LU produces O(10) errors (see
        // the ablation bench); Björck–Pereyra keeps the residual tiny.
        let mut rng = Rng::new(5);
        for k in [8usize, 16, 24, 32] {
            let nodes = chebyshev_nodes(k);
            let coeffs: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
            let f: Vec<f64> = nodes.iter().map(|&x| eval_poly(&coeffs, x)).collect();
            let a = solve_vandermonde(&nodes, &f).unwrap();
            let worst = a
                .iter()
                .zip(&coeffs)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f64, f64::max);
            assert!(worst < 1e-6 * (1 << (k / 8)) as f64, "k={k}: err {worst}");
        }
    }

    #[test]
    fn beats_lu_on_vandermonde_k24() {
        let k = 24;
        let nodes = chebyshev_nodes(k);
        let mut rng = Rng::new(7);
        let coeffs: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
        let f: Vec<f64> = nodes.iter().map(|&x| eval_poly(&coeffs, x)).collect();
        // LU path.
        let v = Matrix::from_fn(k, k, |i, j| nodes[i].powi(j as i32));
        let lu_err = match v.solve(&f) {
            Ok(sol) => sol
                .iter()
                .zip(&coeffs)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f64, f64::max),
            Err(_) => f64::INFINITY,
        };
        // BP path.
        let bp = solve_vandermonde(&nodes, &f).unwrap();
        let bp_err = bp
            .iter()
            .zip(&coeffs)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f64, f64::max);
        // On a forward-generated (bounded-coefficient) system LU is not
        // catastrophic; BP must still be at least as accurate, and tiny.
        assert!(bp_err <= lu_err * 1.5, "BP err {bp_err} vs LU err {lu_err}");
        assert!(bp_err < 1e-7, "BP err {bp_err}");
    }

    #[test]
    fn factor_reuse_is_bit_identical_and_multi_matches_single() {
        let nodes = chebyshev_nodes(20);
        let mut rng = Rng::new(11);
        let fs: Vec<Vec<f64>> =
            (0..6).map(|_| (0..20).map(|_| rng.normal()).collect()).collect();
        let factor = VandermondeFactor::new(&nodes).unwrap();
        assert_eq!(factor.len(), 20);
        assert!(!factor.is_empty());
        let multi = factor.solve_multi(&fs).unwrap();
        for (f, m) in fs.iter().zip(&multi) {
            // The one-shot helper builds the same factor, so results are
            // bit-identical across single / multi / repeated solves.
            assert_eq!(m, &solve_vandermonde(&nodes, f).unwrap());
            assert_eq!(m, &factor.solve(f).unwrap());
        }
    }

    #[test]
    fn rejects_coincident_nodes_and_bad_rhs() {
        assert!(solve_vandermonde(&[1.0, 1.0], &[0.0, 0.0]).is_err());
        assert!(solve_vandermonde(&[1.0, 2.0], &[0.0]).is_err());
        assert!(solve_vandermonde(&[], &[]).unwrap().is_empty());
    }
}
