//! Decoder: recover `A·x` from any `k` coded inner products.
//!
//! The master receives pairs `(global_row_index, ⟨Ã_row, x⟩)`. Since
//! `⟨Ã_i, x⟩ = G_i · (A x)`, collecting a row set `B` with `|B| = k` yields
//! the linear system `G_B · z = y_B` whose solution is `z = A·x`.
//!
//! # Serving fast path
//!
//! A serving system decodes thousands of times against the same generator,
//! and — because straggling is dominated by the group structure — the same
//! few received-row patterns recur constantly. The decoder therefore keeps:
//!
//! - **reusable scratch** (a duplicate-check bitset and staging buffers),
//!   so the hot path performs no per-call allocation of `O(n)` temporaries;
//! - an **LRU factorization cache** keyed by the sorted first-`k` received
//!   row set: a repeated pattern — in any arrival order — skips the `O(k³)`
//!   LU factorization (or the `O(k²)` Björck–Pereyra reciprocal setup) and
//!   pays only the `O(k²)` solve;
//! - a **batched multi-RHS path** ([`Decoder::decode_batch`]) that decodes
//!   a whole request batch sharing one row support through a single
//!   factorization (the LU arm additionally sweeps all columns per
//!   substitution pass).

use crate::coding::bjorck_pereyra::VandermondeFactor;
use crate::coding::linalg::Lu;
use crate::coding::{Generator, GeneratorKind, Matrix};
use crate::runtime::pool::PoolHandle;
use crate::{Error, Result};
use std::collections::BTreeMap;
use std::sync::Mutex;

/// Default number of cached decode factorizations. Under group
/// heterogeneity only ~`G` distinct group-boundary straggle patterns
/// dominate, so a small cache captures the steady state.
pub const DEFAULT_FACTOR_CACHE: usize = 32;

/// One decode-system factorization: LU for general generators,
/// Björck–Pereyra reciprocals for Vandermonde generators.
enum Factor {
    Lu(Lu),
    Vandermonde(VandermondeFactor),
}

impl Factor {
    /// Solve for a single RHS.
    fn solve_one(&self, ys: &[f64]) -> Result<Vec<f64>> {
        match self {
            Factor::Lu(lu) => lu.solve(ys),
            Factor::Vandermonde(v) => v
                .solve(ys)
                .map_err(|e| Error::Decode(format!("BP solve failed: {e}"))),
        }
    }

    /// Solve for a batch of RHS columns (each of length `k`) sharing this
    /// factorization: the LU arm sweeps all columns per substitution pass
    /// through a reusable flat staging buffer ([`Lu::solve_columns`] — no
    /// per-call `O(k·B)` allocation beyond the returned solutions); the
    /// Vandermonde arm solves per column but shares the precomputed
    /// reciprocals. Column `b` of the result equals [`Factor::solve_one`]
    /// of input `b`.
    fn solve_many(
        &self,
        columns: &[Vec<f64>],
        lu_scratch: &mut Vec<f64>,
    ) -> Result<Vec<Vec<f64>>> {
        match self {
            Factor::Lu(lu) => lu.solve_columns(columns, lu_scratch),
            Factor::Vandermonde(v) => v
                .solve_multi(columns)
                .map_err(|e| Error::Decode(format!("BP solve failed: {e}"))),
        }
    }
}

/// Build the factorization for an ordered row subset of the generator.
fn factor_rows(generator: &Generator, rows: &[usize]) -> Result<Factor> {
    if let Some(nodes) = generator.nodes() {
        // Vandermonde decode IS polynomial interpolation on the received
        // rows' nodes — O(k²) and far more accurate than LU on the same
        // exponentially ill-conditioned monomial system.
        let xs: Vec<f64> = rows.iter().map(|&i| nodes[i]).collect();
        return Ok(Factor::Vandermonde(VandermondeFactor::new(&xs)?));
    }
    Ok(Factor::Lu(generator.submatrix(rows).lu()?))
}

struct CacheEntry {
    last_used: u64,
    factor: Factor,
}

/// LRU cache of decode factorizations keyed by the **sorted** first-`k`
/// received row subset. The decode system's solution does not depend on
/// equation order, so the decoder always solves the row-sorted system:
/// two batches whose first `k` rows are the same *set* — the common case
/// under group heterogeneity, where thread scheduling jitters the arrival
/// order within a straggle pattern — share one cache entry and produce
/// bit-identical results.
/// BTreeMap rather than HashMap: the LRU eviction scan iterates the map,
/// and rule D2 keeps iteration out of hash containers in `coding/`. The
/// scan was already deterministic (stamps are unique), but ordered keys
/// make that a structural property instead of an argument.
struct FactorCache {
    cap: usize,
    stamp: u64,
    map: BTreeMap<Vec<usize>, CacheEntry>,
    /// Holding slot when caching is disabled (`cap == 0`) or when the
    /// thrash guard bypasses insertion.
    uncached: Option<Factor>,
    hits: u64,
    misses: u64,
    /// Consecutive misses since the last hit — the thrash signal.
    miss_streak: u64,
    /// Misses served without inserting (thrash-guard bypasses).
    bypassed: u64,
}

/// Thrash guard: once a full cache has missed `2·cap` times in a row, the
/// working set clearly exceeds the cache (rateless receipt sets rarely
/// repeat — every insert would evict an entry that might still recur) and
/// new factorizations bypass insertion until a hit proves patterns repeat
/// again. The multiplier trades how fast a genuine working-set shift
/// repopulates the cache against how much an adversarial non-repeating
/// stream can churn it.
const CACHE_BYPASS_STREAK_FACTOR: u64 = 2;

impl FactorCache {
    fn new(cap: usize) -> Self {
        FactorCache {
            cap,
            stamp: 0,
            map: BTreeMap::new(),
            uncached: None,
            hits: 0,
            misses: 0,
            miss_streak: 0,
            bypassed: 0,
        }
    }

    /// Fetch the factorization for `rows`, building it on a miss. At
    /// capacity the least-recently-used entry is evicted (O(cap) scan —
    /// the cache is small by design), unless the thrash guard
    /// ([`CACHE_BYPASS_STREAK_FACTOR`]) is tripped, in which case the
    /// fresh factorization is served from the holding slot and the
    /// resident entries — and their LRU order — are left untouched.
    /// Build failures are not cached.
    ///
    /// The hit path hashes the key twice (`get_mut` + the final `get`):
    /// returning the reference out of the `get_mut` borrow would extend
    /// that borrow over the insert arm, which NLL rejects. Hashing an
    /// O(k) key is noise next to the O(k²) solve that follows.
    fn get_or_build<F>(&mut self, rows: &[usize], build: F) -> Result<&Factor>
    where
        F: FnOnce() -> Result<Factor>,
    {
        self.stamp += 1;
        if self.cap == 0 {
            self.misses += 1;
            self.uncached = Some(build()?);
            return Ok(self.uncached.as_ref().expect("just stored"));
        }
        if let Some(e) = self.map.get_mut(rows) {
            self.hits += 1;
            self.miss_streak = 0;
            e.last_used = self.stamp;
        } else {
            self.misses += 1;
            self.miss_streak += 1;
            let factor = build()?;
            if self.map.len() >= self.cap
                && self.miss_streak >= CACHE_BYPASS_STREAK_FACTOR * self.cap as u64
            {
                self.bypassed += 1;
                self.uncached = Some(factor);
                return Ok(self.uncached.as_ref().expect("just stored"));
            }
            if self.map.len() >= self.cap {
                if let Some(victim) = self
                    .map
                    .iter()
                    .min_by_key(|(_, e)| e.last_used)
                    .map(|(key, _)| key.clone())
                {
                    self.map.remove(&victim);
                }
            }
            self.map.insert(
                rows.to_vec(),
                CacheEntry { last_used: self.stamp, factor },
            );
        }
        Ok(&self.map.get(rows).expect("present or just inserted").factor)
    }

    /// Drop the `cap == 0` holding slot so a disabled cache does not keep
    /// the last O(k²) factorization alive between decodes.
    fn release_uncached(&mut self) {
        self.uncached = None;
    }
}

/// Reusable per-decoder scratch so the decode hot path allocates nothing
/// proportional to `n` per call.
#[derive(Default)]
struct DecodeScratch {
    /// Duplicate/range bitset over coded-row indices, one bit per row.
    seen: Vec<u64>,
    /// Staged first-`k` row indices in arrival order (mutated by the
    /// singular fallback).
    rows: Vec<usize>,
    /// Staged first-`k` values in arrival order.
    ys: Vec<f64>,
    /// Argsort of `rows` (the arrival → sorted permutation).
    order: Vec<usize>,
    /// `rows` in sorted order — the cache key and solve row order.
    sorted_rows: Vec<usize>,
    /// `ys` permuted to match `sorted_rows`.
    sorted_ys: Vec<f64>,
    /// Batch-path RHS staging: request columns permuted to `sorted_rows`
    /// order. Outer and inner `Vec`s are reused across batches — the
    /// decode-RHS arena of the allocation-free serving hot path.
    sorted_cols: Vec<Vec<f64>>,
}

impl DecodeScratch {
    /// Rebuild `order` (argsort) and `sorted_rows` from the staged rows.
    fn sort_staged_rows(&mut self) {
        let k = self.rows.len();
        self.order.clear();
        self.order.extend(0..k);
        let rows = &self.rows;
        self.order.sort_unstable_by_key(|&i| rows[i]);
        self.sorted_rows.clear();
        for &i in &self.order {
            self.sorted_rows.push(self.rows[i]);
        }
    }

    /// Permute the staged values to match `sorted_rows` (single-RHS path;
    /// the batch path permutes each request column directly via `order`).
    fn permute_ys(&mut self) {
        self.sorted_ys.clear();
        for &i in &self.order {
            self.sorted_ys.push(self.ys[i]);
        }
    }
}

/// Per-FLOP granularity for splitting a multi-RHS decode across the pool
/// (mirrors the matmul kernel's task sizing: a column chunk must carry
/// enough substitution work to amortize pool dispatch).
const DECODE_TASK_FLOPS: usize = 1 << 17;

/// Decoder bound to a generator.
pub struct Decoder {
    generator: Generator,
    scratch: DecodeScratch,
    cache: FactorCache,
    /// Pool for the multi-RHS batch solve (`None` = single-threaded).
    pool: Option<PoolHandle>,
    /// Per-stream LU staging buffers for the parallel batch solve, reused
    /// across batches (index `s` belongs to column chunk `s`; the `Mutex`
    /// only satisfies the borrow checker — chunk indices are disjoint, so
    /// locks are never contended).
    solve_scratches: Vec<Mutex<Vec<f64>>>,
    /// Decode-scratch allocation/grow events (see
    /// [`Decoder::scratch_grows`]).
    grows: u64,
}

impl Clone for Decoder {
    /// Clones the generator binding and pool handle; scratch and cache
    /// start empty.
    fn clone(&self) -> Self {
        let mut d =
            Decoder::with_cache_capacity(self.generator.clone(), self.cache.cap);
        d.pool = self.pool.clone();
        d
    }
}

impl std::fmt::Debug for Decoder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Decoder")
            .field("generator", &self.generator)
            .field("cache_entries", &self.cache.map.len())
            .field("cache_hits", &self.cache.hits)
            .field("cache_misses", &self.cache.misses)
            .finish()
    }
}

impl Decoder {
    /// Wrap a generator (factorization cache at the default capacity).
    ///
    /// Memory note: the cache is capped by *entry count*, and each cached
    /// LU factorization holds `k²` doubles (a `VandermondeFactor` holds
    /// `~k²/2`) — at `k = 1024` that is 8 MiB per entry, up to ~256 MiB at
    /// the default capacity of 32. Size it explicitly via
    /// [`Decoder::with_cache_capacity`] when `k` is large or straggle
    /// patterns are diverse.
    pub fn new(generator: Generator) -> Self {
        Decoder::with_cache_capacity(generator, DEFAULT_FACTOR_CACHE)
    }

    /// Wrap a generator with an explicit factorization-cache capacity
    /// (`0` disables caching — every decode refactorizes). Each entry
    /// costs `O(k²)` doubles; see [`Decoder::new`].
    pub fn with_cache_capacity(generator: Generator, capacity: usize) -> Self {
        Decoder {
            generator,
            scratch: DecodeScratch::default(),
            cache: FactorCache::new(capacity),
            pool: None,
            solve_scratches: Vec::new(),
            grows: 0,
        }
    }

    /// Attach (or detach) the compute pool the multi-RHS batch solve runs
    /// on. With a pool, [`Decoder::decode_batch`] splits its column chunk
    /// work across the pool's workers — bit-identical results, the chunks
    /// are reduced in column order.
    pub fn set_pool(&mut self, pool: Option<PoolHandle>) {
        self.pool = pool;
    }

    /// Scratch-arena allocation/grow events since construction: the number
    /// of decode calls that had to allocate or enlarge a staging buffer
    /// (row/permutation scratch, the batch RHS arena, or the per-stream LU
    /// staging). After the first batch of a steady-state serving stream
    /// this stays flat — the measured half of the "allocation-free hot
    /// path" invariant ([`crate::coordinator::ServeOutcome`]'s
    /// `steady_allocs`).
    pub fn scratch_grows(&self) -> u64 {
        self.grows
    }

    /// Factorization-cache hit/miss counters (since construction).
    pub fn cache_stats(&self) -> (u64, u64) {
        (self.cache.hits, self.cache.misses)
    }

    /// Misses the thrash guard served without inserting (and so without
    /// evicting a resident entry). Nonzero means the received-row working
    /// set exceeded the cache — the expected regime for rateless receipt
    /// sets, which rarely repeat.
    pub fn cache_bypasses(&self) -> u64 {
        self.cache.bypassed
    }

    /// Number of factorizations currently cached.
    pub fn cache_len(&self) -> usize {
        self.cache.map.len()
    }

    /// Upper bound for row-index validation: finite families bound by
    /// their fixed `n`; the rateless stream has no ceiling — any index it
    /// could ever issue is legal (the generator derives the coefficient
    /// row on demand), so only duplicates are rejected.
    fn index_bound(
        generator: &Generator,
        indices: impl Iterator<Item = usize>,
    ) -> usize {
        if generator.kind() == GeneratorKind::RatelessRlc {
            indices
                .map(|i| i.saturating_add(1))
                .max()
                .unwrap_or(0)
                .max(generator.n())
        } else {
            generator.n()
        }
    }

    /// Reject duplicate / out-of-range indices using the reusable bitset.
    fn check_indices<'a>(
        seen: &mut Vec<u64>,
        n: usize,
        indices: impl Iterator<Item = &'a usize>,
    ) -> Result<()> {
        seen.resize(n.div_ceil(64), 0);
        seen.fill(0);
        for &idx in indices {
            if idx >= n {
                return Err(Error::Decode(format!("row index {idx} out of range")));
            }
            let (word, bit) = (idx / 64, idx % 64);
            if (seen[word] >> bit) & 1 == 1 {
                return Err(Error::Decode(format!("duplicate row index {idx}")));
            }
            seen[word] |= 1 << bit;
        }
        Ok(())
    }

    /// Decode `A·x` from received `(row_index, value)` pairs.
    ///
    /// Uses the first `k` received rows; if that submatrix is singular
    /// (probability-zero for the random construction, impossible for
    /// Vandermonde), later rows are substituted in one at a time. The
    /// system is solved in row-sorted order — the solution is
    /// order-independent, and sorting makes the factorization cache hit on
    /// any arrival permutation of a repeated straggler *set*, skipping
    /// straight to the `O(k²)` solve.
    pub fn decode(&mut self, received: &[(usize, f64)]) -> Result<Vec<f64>> {
        let Decoder { generator, scratch, cache, .. } = self;
        let k = generator.k();
        if received.len() < k {
            return Err(Error::Decode(format!(
                "need {k} rows, got {}",
                received.len()
            )));
        }
        let bound =
            Self::index_bound(generator, received.iter().map(|(idx, _)| *idx));
        Self::check_indices(
            &mut scratch.seen,
            bound,
            received.iter().map(|(idx, _)| idx),
        )?;
        scratch.rows.clear();
        scratch.ys.clear();
        for &(idx, v) in &received[..k] {
            scratch.rows.push(idx);
            scratch.ys.push(v);
        }
        let mut spare = k; // next candidate in `received` to swap in
        loop {
            scratch.sort_staged_rows();
            let rows = &scratch.sorted_rows[..];
            match cache.get_or_build(rows, || factor_rows(generator, rows)) {
                Ok(factor) => {
                    scratch.permute_ys();
                    let out = factor.solve_one(&scratch.sorted_ys);
                    cache.release_uncached();
                    return out;
                }
                Err(_) if spare < received.len() => {
                    // Replace the row most likely to be the dependent one:
                    // rotate through positions deterministically.
                    let pos = (spare - k) % k;
                    scratch.rows[pos] = received[spare].0;
                    scratch.ys[pos] = received[spare].1;
                    spare += 1;
                }
                Err(e) => {
                    return Err(Error::Decode(format!(
                        "no invertible k-subset among received rows: {e}"
                    )))
                }
            }
        }
    }

    /// Decode a whole request batch sharing one received row support.
    ///
    /// `rows` lists the received coded-row indices in arrival order
    /// (`rows.len() >= k`); `columns[b]` holds request `b`'s received
    /// values aligned with `rows`. One factorization (cached or fresh) of
    /// the sorted first-`k` subset serves every request; each output is
    /// bit-identical to what [`Decoder::decode`] returns for the
    /// corresponding `(row, value)` pairs.
    pub fn decode_batch(
        &mut self,
        rows: &[usize],
        columns: &[Vec<f64>],
    ) -> Result<Vec<Vec<f64>>> {
        let k = self.generator.k();
        if rows.len() < k {
            return Err(Error::Decode(format!(
                "need {k} rows, got {}",
                rows.len()
            )));
        }
        for (b, col) in columns.iter().enumerate() {
            if col.len() != rows.len() {
                return Err(Error::Decode(format!(
                    "request {b} has {} values for {} rows",
                    col.len(),
                    rows.len()
                )));
            }
        }
        {
            let Decoder {
                generator,
                scratch,
                cache,
                pool,
                solve_scratches,
                grows,
            } = &mut *self;
            let mut grew = scratch.rows.capacity() < k;
            let bound = Self::index_bound(generator, rows.iter().copied());
            Self::check_indices(&mut scratch.seen, bound, rows.iter())?;
            // Sort the shared first-`k` support once; permute each
            // request's values to match.
            scratch.rows.clear();
            scratch.rows.extend_from_slice(&rows[..k]);
            scratch.sort_staged_rows();
            let key = &scratch.sorted_rows[..];
            if let Ok(factor) =
                cache.get_or_build(key, || factor_rows(generator, key))
            {
                let m = columns.len();
                // Stage the permuted RHS columns in the reusable arena.
                let order = &scratch.order;
                let staging = &mut scratch.sorted_cols;
                if staging.len() < m {
                    grew = true;
                    staging.resize_with(m, Vec::new);
                }
                for (dst, col) in staging.iter_mut().zip(columns) {
                    grew |= dst.capacity() < order.len();
                    dst.clear();
                    dst.extend(order.iter().map(|&i| col[i]));
                }
                let staged = &staging[..m];
                // Split the batch into column chunks with enough
                // substitution work each (~k² FLOPs per column) to
                // amortize pool dispatch; chunk results are reduced in
                // column order, so the split is invisible in the output.
                // `streams` is recomputed from the chunk width so no task
                // is ever empty (ceil-of-ceil can strand a tail task).
                let target = match pool {
                    Some(p) => (k.saturating_mul(k).saturating_mul(m)
                        / DECODE_TASK_FLOPS)
                        .clamp(1, p.threads())
                        .min(m),
                    None => 1,
                };
                let per = m.div_ceil(target);
                let streams = m.div_ceil(per);
                if solve_scratches.len() < streams {
                    grew = true;
                    solve_scratches.resize_with(streams, Mutex::default);
                }
                if matches!(factor, Factor::Lu(_)) {
                    // Only the LU arm stages into the flat solve scratch
                    // (the BP arm would otherwise tick the counter
                    // forever), and slot `s` only ever needs its own
                    // chunk's width — the tail chunk is shorter.
                    for (s, slot) in
                        solve_scratches.iter().take(streams).enumerate()
                    {
                        let chunk_len = per.min(m - s * per);
                        let cap = slot.lock().expect("solve scratch").capacity();
                        grew |= cap < k * chunk_len;
                    }
                }
                *grows += u64::from(grew);
                let out = if streams <= 1 {
                    let mut lu_scratch =
                        solve_scratches[0].lock().expect("solve scratch");
                    factor.solve_many(staged, &mut lu_scratch)
                } else {
                    let p = pool.as_ref().expect("streams > 1 implies a pool");
                    let chunks = p.run_collect(streams, |s| {
                        let c0 = s * per;
                        let c1 = (c0 + per).min(m);
                        let mut lu_scratch =
                            solve_scratches[s].lock().expect("solve scratch");
                        factor.solve_many(&staged[c0..c1], &mut lu_scratch)
                    });
                    chunks
                        .into_iter()
                        .collect::<Result<Vec<_>>>()
                        .map(|v| v.into_iter().flatten().collect())
                };
                cache.release_uncached();
                return out;
            }
        }
        // Probability-zero path: the shared first-`k` submatrix is
        // singular. Fall back to per-request decode, which substitutes
        // spare rows until an invertible subset is found.
        columns
            .iter()
            .map(|col| {
                let pairs: Vec<(usize, f64)> =
                    rows.iter().copied().zip(col.iter().copied()).collect();
                self.decode(&pairs)
            })
            .collect()
    }

    /// Convenience for tests: decode and compare against ground truth,
    /// returning the max absolute error.
    pub fn decode_error(
        &mut self,
        received: &[(usize, f64)],
        truth: &[f64],
    ) -> Result<f64> {
        let z = self.decode(received)?;
        if z.len() != truth.len() {
            return Err(Error::Decode("length mismatch vs truth".into()));
        }
        Ok(z.iter()
            .zip(truth)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max))
    }

    /// The underlying generator.
    pub fn generator(&self) -> &Generator {
        &self.generator
    }
}

/// End-to-end helper: encode, evaluate inner products on a row subset and
/// decode back (used by tests and the simulator's correctness checks).
pub fn roundtrip_check(
    gen: &Generator,
    a: &Matrix,
    x: &[f64],
    rows: &[usize],
) -> Result<f64> {
    let coded = gen.matrix().matmul(a);
    let truth = a.matvec(x);
    let received: Vec<(usize, f64)> = rows
        .iter()
        .map(|&i| {
            let mut acc = 0.0;
            for (av, xv) in coded.row(i).iter().zip(x) {
                acc += av * xv;
            }
            (i, acc)
        })
        .collect();
    Decoder::new(gen.clone()).decode_error(&received, &truth)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::GeneratorKind;
    use crate::math::Rng;

    fn random_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_fn(rows, cols, |_, _| rng.normal())
    }

    #[test]
    fn decode_from_systematic_rows_is_exact() {
        let gen = Generator::new(GeneratorKind::SystematicRandom, 10, 4, 1).unwrap();
        let a = random_matrix(4, 6, 2);
        let x: Vec<f64> = (0..6).map(|i| (i as f64).sin() + 1.0).collect();
        let err = roundtrip_check(&gen, &a, &x, &[0, 1, 2, 3]).unwrap();
        assert!(err < 1e-12, "err={err}");
    }

    #[test]
    fn decode_from_parity_rows() {
        let gen = Generator::new(GeneratorKind::SystematicRandom, 10, 4, 1).unwrap();
        let a = random_matrix(4, 6, 3);
        let x: Vec<f64> = (0..6).map(|i| i as f64 - 2.5).collect();
        let err = roundtrip_check(&gen, &a, &x, &[6, 7, 8, 9]).unwrap();
        assert!(err < 1e-9, "err={err}");
    }

    #[test]
    fn decode_from_mixed_rows_many_subsets() {
        let gen = Generator::new(GeneratorKind::SystematicRandom, 16, 6, 11).unwrap();
        let a = random_matrix(6, 4, 5);
        let x = vec![0.3, -1.2, 2.0, 0.7];
        let mut rng = Rng::new(99);
        for _ in 0..50 {
            let mut all: Vec<usize> = (0..16).collect();
            rng.shuffle(&mut all);
            let rows = &all[..6];
            let err = roundtrip_check(&gen, &a, &x, rows).unwrap();
            assert!(err < 1e-8, "rows {rows:?} err={err}");
        }
    }

    #[test]
    fn vandermonde_decode_small_k() {
        let gen = Generator::new(GeneratorKind::Vandermonde, 9, 5, 0).unwrap();
        let a = random_matrix(5, 3, 8);
        let x = vec![1.0, -1.0, 0.5];
        for rows in [[0, 1, 2, 3, 4], [4, 5, 6, 7, 8], [0, 2, 4, 6, 8]] {
            let err = roundtrip_check(&gen, &a, &x, &rows).unwrap();
            assert!(err < 1e-7, "rows {rows:?} err={err}");
        }
    }

    #[test]
    fn vandermonde_decode_larger_k_via_bjorck_pereyra() {
        // LU on a k=32 Chebyshev Vandermonde produces O(100) errors (see
        // the ablation bench); the BP decode path stays accurate.
        let gen = Generator::new(GeneratorKind::Vandermonde, 48, 32, 0).unwrap();
        let a = random_matrix(32, 3, 12);
        let x = vec![0.5, -1.0, 2.0];
        let rows: Vec<usize> = (8..40).collect(); // mixed middle rows
        let err = roundtrip_check(&gen, &a, &x, &rows).unwrap();
        // The decode is still an ill-conditioned interpolation (the row
        // subset is not itself a Chebyshev grid), but BP keeps the error
        // ~3 orders below what LU produced at this k (O(100), see the
        // ablation bench).
        assert!(err < 0.05, "err={err}");
    }

    #[test]
    fn decode_needs_k_rows() {
        let gen = Generator::new(GeneratorKind::SystematicRandom, 10, 4, 1).unwrap();
        let mut dec = Decoder::new(gen);
        assert!(dec.decode(&[(0, 1.0), (1, 2.0), (2, 3.0)]).is_err());
    }

    #[test]
    fn decode_rejects_duplicates_and_out_of_range() {
        let gen = Generator::new(GeneratorKind::SystematicRandom, 10, 4, 1).unwrap();
        let mut dec = Decoder::new(gen);
        let dup = [(0, 1.0), (0, 1.0), (1, 2.0), (2, 3.0)];
        assert!(dec.decode(&dup).is_err());
        let oor = [(0, 1.0), (1, 2.0), (2, 3.0), (99, 4.0)];
        assert!(dec.decode(&oor).is_err());
        // Batch path enforces the same invariants plus column alignment.
        assert!(dec.decode_batch(&[0, 0, 1, 2], &[vec![0.0; 4]]).is_err());
        assert!(dec.decode_batch(&[0, 1, 2, 99], &[vec![0.0; 4]]).is_err());
        assert!(dec.decode_batch(&[0, 1, 2], &[vec![0.0; 3]]).is_err());
        assert!(dec.decode_batch(&[0, 1, 2, 3], &[vec![0.0; 3]]).is_err());
    }

    #[test]
    fn extra_rows_are_harmless() {
        let gen = Generator::new(GeneratorKind::SystematicRandom, 12, 4, 21).unwrap();
        let a = random_matrix(4, 5, 22);
        let x = vec![2.0, 0.0, -1.0, 1.0, 3.0];
        let err = roundtrip_check(&gen, &a, &x, &[1, 3, 5, 7, 9, 11]).unwrap();
        assert!(err < 1e-9);
    }

    #[test]
    fn decode_at_moderate_k_stays_stable() {
        // Conditioning check for the random construction at k=128.
        let k = 128;
        let n = 192;
        let gen = Generator::new(GeneratorKind::SystematicRandom, n, k, 33).unwrap();
        let a = random_matrix(k, 8, 34);
        let x = vec![1.0; 8];
        // All-parity decode (worst case for conditioning).
        let rows: Vec<usize> = (n - k..n).collect();
        let err = roundtrip_check(&gen, &a, &x, &rows).unwrap();
        assert!(err < 1e-6, "err={err}");
    }

    #[test]
    fn repeated_pattern_hits_cache_and_stays_bit_identical() {
        for kind in [GeneratorKind::SystematicRandom, GeneratorKind::Vandermonde] {
            let gen = Generator::new(kind, 24, 12, 3).unwrap();
            let mut rng = Rng::new(44);
            let received: Vec<(usize, f64)> =
                (4..16).map(|i| (i, rng.normal())).collect();
            let mut cached = Decoder::new(gen.clone());
            let mut cold = Decoder::with_cache_capacity(gen, 0);
            let first = cached.decode(&received).unwrap();
            let again = cached.decode(&received).unwrap();
            let uncached = cold.decode(&received).unwrap();
            assert_eq!(first, again, "{kind:?}: cache hit changed the result");
            assert_eq!(first, uncached, "{kind:?}: caching changed the result");
            let (hits, misses) = cached.cache_stats();
            assert_eq!((hits, misses), (1, 1), "{kind:?}");
            assert_eq!(cached.cache_len(), 1);
            let (h0, m0) = cold.cache_stats();
            assert_eq!((h0, m0), (0, 2), "{kind:?}: disabled cache must miss");
            assert_eq!(cold.cache_len(), 0);
        }
    }

    #[test]
    fn arrival_order_permutations_share_one_factorization() {
        // The cache keys on the sorted row *set*; any arrival order of the
        // same straggle pattern hits it and decodes to identical values.
        let gen =
            Generator::new(GeneratorKind::SystematicRandom, 16, 6, 13).unwrap();
        let pairs: Vec<(usize, f64)> = vec![
            (2, 0.7),
            (11, -1.3),
            (5, 2.2),
            (14, 0.1),
            (8, -0.4),
            (0, 1.9),
        ];
        let mut dec = Decoder::new(gen);
        let baseline = dec.decode(&pairs).unwrap();
        let mut rng = Rng::new(66);
        for _ in 0..5 {
            let mut shuffled = pairs.clone();
            rng.shuffle(&mut shuffled);
            assert_eq!(dec.decode(&shuffled).unwrap(), baseline);
        }
        let (hits, misses) = dec.cache_stats();
        assert_eq!((hits, misses), (5, 1));
    }

    #[test]
    fn cache_evicts_least_recently_used() {
        let gen = Generator::new(GeneratorKind::SystematicRandom, 12, 4, 5).unwrap();
        let mut dec = Decoder::with_cache_capacity(gen, 2);
        let pat = |s: usize| -> Vec<(usize, f64)> {
            (s..s + 4).map(|i| (i, i as f64 + 0.5)).collect()
        };
        dec.decode(&pat(0)).unwrap(); // miss → {0}
        dec.decode(&pat(1)).unwrap(); // miss → {0,1}
        dec.decode(&pat(0)).unwrap(); // hit, refreshes 0
        dec.decode(&pat(2)).unwrap(); // miss → evicts 1 → {0,2}
        dec.decode(&pat(1)).unwrap(); // miss again (was evicted)
        let (hits, misses) = dec.cache_stats();
        assert_eq!((hits, misses), (1, 4));
        assert_eq!(dec.cache_len(), 2);
    }

    #[test]
    fn thrash_guard_bypasses_without_evicting_resident_entries() {
        // cap=2, bypass streak threshold = 2·cap = 4 consecutive misses.
        let gen = Generator::new(GeneratorKind::SystematicRandom, 24, 4, 5).unwrap();
        let mut dec = Decoder::with_cache_capacity(gen, 2);
        let pat = |s: usize| -> Vec<(usize, f64)> {
            (s..s + 4).map(|i| (i, i as f64 + 0.5)).collect()
        };
        let warm0 = dec.decode(&pat(0)).unwrap(); // miss → {0}
        let warm1 = dec.decode(&pat(4)).unwrap(); // miss → {0,4}
        dec.decode(&pat(0)).unwrap(); // hit — streak resets
        // Four fresh patterns: the first three evict/insert (streaks 1..3
        // stay under the threshold at full cap), then the guard trips.
        dec.decode(&pat(8)).unwrap(); // miss, insert → {4 evicted}
        dec.decode(&pat(12)).unwrap(); // miss, insert
        dec.decode(&pat(16)).unwrap(); // miss, streak 3 → still inserts
        assert_eq!(dec.cache_bypasses(), 0);
        dec.decode(&pat(20)).unwrap(); // miss, streak 4 → bypass
        assert_eq!(dec.cache_bypasses(), 1);
        assert_eq!(dec.cache_len(), 2);
        // Bypassed decodes leave the resident set untouched: the two most
        // recently inserted patterns still hit, and re-decoding a bypassed
        // pattern misses again (it was never inserted).
        let (_, m_before) = dec.cache_stats();
        dec.decode(&pat(12)).unwrap();
        dec.decode(&pat(16)).unwrap();
        let (h, m) = dec.cache_stats();
        assert_eq!(m, m_before, "resident entries must still hit");
        assert!(h >= 3);
        // A hit reset the streak, so fresh patterns insert again.
        dec.decode(&pat(20)).unwrap();
        assert_eq!(dec.cache_bypasses(), 1, "post-hit miss inserts normally");
        // Bit-identity: bypassed results equal cached results.
        assert_eq!(dec.decode(&pat(0)).unwrap(), warm0);
        assert_eq!(dec.decode(&pat(4)).unwrap(), warm1);
    }

    #[test]
    fn eviction_order_is_unchanged_by_bypassed_decodes() {
        // Regression for the guard: bypassed traffic must not perturb the
        // LRU stamps of resident entries, so the next real insert evicts
        // the same victim it would have without the bypass burst.
        let gen = Generator::new(GeneratorKind::SystematicRandom, 60, 4, 7).unwrap();
        let mut dec = Decoder::with_cache_capacity(gen, 2);
        let pat = |s: usize| -> Vec<(usize, f64)> {
            (s..s + 4).map(|i| (i, i as f64 - 1.5)).collect()
        };
        // Burst of 7 fresh patterns, no hits: streaks 1..3 insert (pat(8)
        // evicts pat(0) at full cap), streak 4 trips the guard and every
        // later miss bypasses. Residents after the burst: pat(4) (older
        // stamp) and pat(8) (newer).
        for s in (0..28).step_by(4) {
            dec.decode(&pat(s)).unwrap();
        }
        assert_eq!(dec.cache_bypasses(), 4, "streaks 4..7 must all bypass");
        assert_eq!(dec.cache_len(), 2);
        // More bypassed traffic — resident stamps must not move.
        dec.decode(&pat(32)).unwrap();
        dec.decode(&pat(36)).unwrap();
        assert_eq!(dec.cache_bypasses(), 6);
        // Refresh pat(4): now pat(8) is the true LRU.
        dec.decode(&pat(4)).unwrap(); // hit — resets the streak too
        // Next insert evicts pat(8), not the refreshed pat(4).
        dec.decode(&pat(40)).unwrap(); // miss, streak 1 → real insert
        let (_, m0) = dec.cache_stats();
        dec.decode(&pat(4)).unwrap(); // survived → hit
        let (_, m1) = dec.cache_stats();
        assert_eq!(m1, m0, "refreshed resident must survive the eviction");
        dec.decode(&pat(8)).unwrap(); // evicted → miss
        let (_, m2) = dec.cache_stats();
        assert_eq!(m2, m1 + 1, "true LRU resident must have been evicted");
    }

    #[test]
    fn rateless_decode_accepts_rows_beyond_the_materialized_prefix() {
        // The decoder's generator clone keeps the setup-time prefix; rows
        // the stream issued later are derived on demand and must decode.
        let (n, k) = (6usize, 4usize);
        let gen = Generator::new(GeneratorKind::RatelessRlc, n, k, 19).unwrap();
        let a = random_matrix(k, 3, 20);
        let x = vec![1.0, -0.5, 2.0];
        let truth = a.matvec(&x);
        let rows = vec![2usize, 5, 9, 13]; // 9, 13 beyond n=6
        let mut big = gen.clone();
        big.extend_to(16).unwrap();
        let coded = big.matrix().matmul(&a);
        let received: Vec<(usize, f64)> = rows
            .iter()
            .map(|&i| {
                let acc: f64 =
                    coded.row(i).iter().zip(&x).map(|(a, b)| a * b).sum();
                (i, acc)
            })
            .collect();
        let mut dec = Decoder::new(gen);
        let z = dec.decode(&received).unwrap();
        for (got, want) in z.iter().zip(&truth) {
            assert!((got - want).abs() < 1e-9, "{got} vs {want}");
        }
        // Batch path too, and duplicates beyond n are still rejected.
        let col: Vec<f64> = received.iter().map(|&(_, v)| v).collect();
        let batch = dec.decode_batch(&rows, &[col.clone()]).unwrap();
        assert_eq!(batch[0], z);
        assert!(dec.decode_batch(&[2, 9, 9, 13], &[vec![0.0; 4]]).is_err());
        // Finite families keep the hard n bound.
        let fixed =
            Generator::new(GeneratorKind::SystematicRandom, 10, 4, 1).unwrap();
        let mut fdec = Decoder::new(fixed);
        assert!(fdec.decode_batch(&[0, 1, 2, 10], &[vec![0.0; 4]]).is_err());
    }

    #[test]
    fn decode_batch_matches_single_decodes_bitwise() {
        for kind in [GeneratorKind::SystematicRandom, GeneratorKind::Vandermonde] {
            let gen = Generator::new(kind, 20, 10, 6).unwrap();
            let mut rng = Rng::new(55);
            let rows: Vec<usize> = vec![3, 17, 5, 11, 0, 19, 8, 2, 14, 9, 6, 12];
            let columns: Vec<Vec<f64>> = (0..5)
                .map(|_| (0..rows.len()).map(|_| rng.normal()).collect())
                .collect();
            let mut dec = Decoder::new(gen);
            let batch = dec.decode_batch(&rows, &columns).unwrap();
            assert_eq!(batch.len(), 5);
            for (col, got) in columns.iter().zip(&batch) {
                let pairs: Vec<(usize, f64)> =
                    rows.iter().copied().zip(col.iter().copied()).collect();
                let single = dec.decode(&pairs).unwrap();
                assert_eq!(got, &single, "{kind:?}");
            }
        }
    }

    #[test]
    fn pooled_decode_batch_is_bit_identical_and_stops_growing() {
        use crate::runtime::pool::WorkPool;
        use std::sync::Arc;
        // Sizes big enough that k²·B crosses the parallel-split threshold
        // (96²·50 ≈ 460 KFLOP → 3 column chunks on a big enough pool),
        // with B chosen so the split is uneven (17/17/16): the tail
        // chunk's shorter scratch must not tick the grow counter forever.
        let (n, k, b) = (144usize, 96usize, 50usize);
        let gen = Generator::new(GeneratorKind::SystematicRandom, n, k, 8).unwrap();
        let mut rng = Rng::new(77);
        let rows: Vec<usize> = (n - k..n).collect();
        let columns: Vec<Vec<f64>> = (0..b)
            .map(|_| (0..k).map(|_| rng.normal()).collect())
            .collect();
        let mut baseline = Decoder::new(gen.clone());
        let want = baseline.decode_batch(&rows, &columns).unwrap();
        for pool_size in [1usize, 2, 7, 16] {
            let mut dec = Decoder::new(gen.clone());
            dec.set_pool(Some(Arc::new(WorkPool::new(pool_size))));
            let got = dec.decode_batch(&rows, &columns).unwrap();
            assert_eq!(got, want, "pool={pool_size}");
            // First batch may size the arenas; repeats must not grow.
            let after_first = dec.scratch_grows();
            for _ in 0..5 {
                let again = dec.decode_batch(&rows, &columns).unwrap();
                assert_eq!(again, want, "pool={pool_size}");
            }
            assert_eq!(
                dec.scratch_grows(),
                after_first,
                "pool={pool_size}: steady-state decode grew a scratch buffer"
            );
        }
    }
}
